"""Online-mutation quality and rebalance cost (the index lifecycle).

For each mutation fraction f, interleave ``f*N/2`` deletes and ``f*N/2``
adds (round-robin, the skewed-traffic pattern the paper's edge indices
live under), then measure recall@10 against a fresh exact ground truth of
the surviving corpus at three points:

  * ``mutated``     — after the adds/deletes (dirty-bucket trees already
    incrementally rebuilt on the tree bottom);
  * ``rebalanced``  — after one ``rebalance()`` (drift recenter + reroute),
    with the pass's wall time as the *rebalance cost*;
  * ``rebuilt``     — a from-scratch build on the same surviving corpus
    (the quality ceiling the mutated index is judged against, and the
    cost a build-once index would pay on every update).

Rows land in ``benchmarks/results/updates.csv`` and on stdout via
``common.csv_row``.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import RESULTS, csv_row


def _mk(rng, centers, n, d):
    return (centers[rng.integers(0, centers.shape[0], n)]
            + rng.normal(size=(n, d))).astype(np.float32)


def run(n: int = 20000, d: int = 32, n_clusters: int = 64,
        fractions=(0.1, 0.2, 0.3, 0.5), bottoms=("brute", "tree"),
        nq: int = 256) -> None:
    from repro.core.brute import brute_search
    from repro.core.metrics import recall_at_k
    from repro.core.two_level import TwoLevelConfig, build_two_level

    rng = np.random.default_rng(0)
    centers = rng.normal(size=(64, d)) * 4
    rows = []
    for bottom in bottoms:
        for frac in fractions:
            rng = np.random.default_rng(17)
            db = _mk(rng, centers, n, d)
            cfg = TwoLevelConfig(n_clusters=n_clusters, top="brute",
                                 bottom=bottom, kmeans_iters=5,
                                 tree_leaf=8)
            idx = build_two_level(db, cfg)
            half = int(frac * n / 2)
            chunk = max(1, half // 4)
            t_mut = time.perf_counter()
            done = 0
            while done < half:
                c = min(chunk, half - done)
                live = np.nonzero(idx.alive)[0]
                idx.delete_entities(rng.choice(live, c, replace=False))
                idx.add_entities(_mk(rng, centers, c, d))
                done += c
            t_mut = time.perf_counter() - t_mut
            live = np.nonzero(idx.alive)[0]
            surv = idx.db[live]
            q = _mk(rng, centers, nq, d)
            _, truth = brute_search(q, surv, 10)

            def recall(index, mapped):
                _, ids, _ = index.search(q, 10, nprobe=8, beam_width=8)
                t = live[truth] if mapped else truth
                return recall_at_k(np.asarray(ids), t)

            r_mut = recall(idx, True)
            t0 = time.perf_counter()
            stats = idx.rebalance()
            t_reb = (time.perf_counter() - t0) * 1e3
            r_reb = recall(idx, True)
            t0 = time.perf_counter()
            idx2 = build_two_level(surv, cfg)
            t_build = (time.perf_counter() - t0) * 1e3
            r_new = recall(idx2, False)
            rows.append((bottom, frac, r_mut, r_reb, r_new, t_reb,
                         t_build, stats["n_drifted"],
                         stats["n_rebuilt_buckets"]))
            csv_row(
                f"updates_{bottom}_f{frac}", t_reb * 1e3,
                f"recall_mut={r_mut:.4f},recall_reb={r_reb:.4f},"
                f"recall_rebuild={r_new:.4f},rebuild_ms={t_build:.0f},"
                f"mutate_s={t_mut:.1f},drifted={stats['n_drifted']}")
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "updates.csv"), "w") as f:
        f.write("bottom,fraction,recall_mutated,recall_rebalanced,"
                "recall_rebuilt,rebalance_ms,rebuild_ms,"
                "n_drifted,n_rebuilt_buckets\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")


if __name__ == "__main__":
    run()
