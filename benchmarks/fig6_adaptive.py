"""Fig. 6 (beyond paper): adaptive re-boost under a drifting-Zipf workload.

A QLBT is boosted for the traffic of phase 0; every later phase rotates
the Zipf head to a fresh random permutation (the "new things got popular"
regime).  Three strategies serve the same query stream:

  * ``stale``    — the phase-0 tree, never touched (a build-once index);
  * ``adaptive`` — the sketch -> drift -> ``reboost`` loop: an
    ``OnlineLikelihoodEstimator`` observes the returned top-1 ids and a
    reboost fires when total-variation drift crosses the threshold;
  * ``oracle``   — a from-scratch ``build_qlbt`` on the true phase
    likelihood (the quality ceiling, at full rebuild cost).

Reported per phase: mean work (internal dot products + exact distance
evals — fig1's machine-independent latency proxy), recall@10, and wall
p50/p99 per search call; plus the reboost-vs-rebuild cost ratio and the
recovered fraction of the stale->oracle work gap (the PR acceptance
asks >= 0.5).  A second segment measures the serving cache: hit rate and
p50/p99 of ``ServingEngine.search`` with and without the
``FrequencyAdmissionCache`` under the same Zipf traffic.

Rows land in ``benchmarks/results/adaptive.csv`` and on stdout.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import RESULTS, clustered_corpus, csv_row, lat_summary


def _phase_p(rng, n, alpha):
    from repro.core.likelihood import zipf_likelihood

    z = zipf_likelihood(n, alpha)
    perm = rng.permutation(n)
    p = np.empty(n)
    p[perm] = z
    return p


def run(n: int = 8192, d: int = 128, phases: int = 3,
        batches_per_phase: int = 10, batch: int = 256,
        zipf_alpha: float = 1.1, drift_threshold: float = 0.3,
        seed: int = 0) -> list:
    import jax.numpy as jnp

    from repro.adaptive import MaintenanceScheduler, OnlineLikelihoodEstimator
    from repro.core.index import SearchIndex
    from repro.core.likelihood import sample_queries
    from repro.core.metrics import recall_at_k
    from repro.core.protocol import IndexSpec
    from repro.core.tree import build_qlbt, tree_search

    rng = np.random.default_rng(seed)
    db = clustered_corpus(rng, n, d)
    dbj = jnp.asarray(db)
    p0 = _phase_p(rng, n, zipf_alpha)

    stale = build_qlbt(db, p0, seed=1, n_candidates=16, lam=0.2)
    # the adaptive strategy is the SHIPPED maintenance path, not a
    # re-implementation: a SearchIndex (whose base_tree keeps reboosts
    # deriving from the build) driven by the MaintenanceScheduler's own
    # trigger logic (threshold + mass gate + cooldown + raw re-anchor)
    adaptive = SearchIndex(
        spec=IndexSpec(kind="qlbt"), db=db,
        tree=build_qlbt(db, p0, seed=1, n_candidates=16, lam=0.2), p=p0)
    # halflife/threshold calibrated so stationary sampling noise settles
    # under the trigger (~0.22-0.25 at steady mass) while a head rotation
    # crosses it within 1-2 batches — detection speed dominates the
    # latency-vs-time curve, since a tree adapted to the previous head is
    # *worse* than a never-boosted one for the next rotation until the
    # reboost lands; the mass gate skips the noisy warmup
    est = OnlineLikelihoodEstimator(n, reference=p0, halflife=2 * batch)
    sched = MaintenanceScheduler(
        est, adaptive, interval_s=None, drift_threshold=drift_threshold,
        min_observations=2.7 * batch,     # warmup gate, in decayed mass
        cooldown_observations=3 * batch,  # debounce, in observations
        rebalance=False,
        reboost_kw=dict(n_candidates=12, lam=0.2))

    def padded_arrays(tree):
        """Pad the node/leaf tables to fixed buckets so a reboosted tree
        hits the already-compiled search kernel (the device-side analogue
        of ShardedSearchBackend's recorded shapes) — re-boost pauses must
        not turn into serving-loop compile spikes."""
        arrs = tree.device_arrays()
        import jax.numpy as jnp

        def bucket(x):                      # next multiple of 2048
            return -(-x // 2048) * 2048

        pn = bucket(tree.n_nodes)
        pl = bucket(max(tree.n_leaves, 1))
        out = {}
        out["proj"] = jnp.zeros((pn, arrs["proj"].shape[1]),
                                arrs["proj"].dtype).at[
            : tree.n_nodes].set(arrs["proj"])
        out["dims"] = jnp.zeros((pn,), arrs["dims"].dtype).at[
            : tree.n_nodes].set(arrs["dims"])
        out["tau"] = jnp.zeros((pn,), arrs["tau"].dtype).at[
            : tree.n_nodes].set(arrs["tau"])
        out["children"] = jnp.full((pn, 2), -1, arrs["children"].dtype).at[
            : tree.n_nodes].set(arrs["children"])
        out["leaf_row"] = jnp.full((pn,), -1, arrs["leaf_row"].dtype).at[
            : tree.n_nodes].set(arrs["leaf_row"])
        le = arrs["leaf_entities"]
        out["leaf_entities"] = jnp.full(
            (pl, le.shape[1] if le.size else tree.leaf_size), -1,
            le.dtype).at[: le.shape[0]].set(le)
        return out

    # padded arrays are per-publish state, not per-batch work: cache by
    # tree identity (keeping the tree ref pinned so ids can't be reused)
    pad_cache: dict = {}

    def arrays_of(tree):
        ent = pad_cache.get(id(tree))
        if ent is None or ent[0] is not tree:
            pad_cache[id(tree)] = ent = (tree, padded_arrays(tree))
        return ent[1]

    def searched(tree, qj):
        arrs = arrays_of(tree)
        t0 = time.perf_counter()
        res = tree_search(arrs, dbj, qj, beam_width=4, k=10, max_steps=64)
        res.ids.block_until_ready()
        wall = time.perf_counter() - t0
        work = np.asarray(res.internal_visits) + np.asarray(res.candidates)
        return np.asarray(res.ids), float(work.mean()), wall

    rows = []
    reboost_ms, rebuild_ms, reboosts = [], [], 0
    gaps, recovered = [], []
    for phase in range(phases):
        p_t = p0 if phase == 0 else _phase_p(rng, n, zipf_alpha)
        t0 = time.perf_counter()
        oracle = build_qlbt(db, p_t, seed=1, n_candidates=16, lam=0.2)
        rebuild_ms.append((time.perf_counter() - t0) * 1e3)
        walls = {"stale": [], "adaptive": [], "oracle": []}
        works = {"stale": [], "adaptive": [], "oracle": []}
        recalls = {"stale": [], "adaptive": [], "oracle": []}
        for _ in range(batches_per_phase):
            q, gt = sample_queries(rng, db, p_t, batch, noise_scale=0.05)
            qj = jnp.asarray(q)
            for name, tree in (("stale", stale),
                               ("adaptive", adaptive.tree),
                               ("oracle", oracle)):
                ids, work, wall = searched(tree, qj)
                works[name].append(work)
                walls[name].append(wall)
                recalls[name].append(recall_at_k(ids, gt))
                if name == "adaptive":
                    est.observe(ids[:, 0])
                    ev = sched.check_now()
                    if ev is not None:
                        reboost_ms.append(ev["duration_s"] * 1e3)
                        # warm the search kernel for the new tree as part
                        # of maintenance (untimed, like the rebuild's) —
                        # the scheduler compiles/pads off the serving path
                        # (the sharded backend reuses its jitted fn
                        # outright), so serving never eats it
                        searched(adaptive.tree, qj)
                        reboosts += 1
        row = {"phase": phase}
        for name in works:
            row[f"work_{name}"] = float(np.mean(works[name]))
            row[f"recall_{name}"] = float(np.mean(recalls[name]))
            row.update({f"{k}_{name}": v
                        for k, v in lat_summary(walls[name]).items()})
        rows.append(row)
        if phase > 0:
            gap = row["work_stale"] - row["work_oracle"]
            gaps.append(gap)
            recovered.append(row["work_stale"] - row["work_adaptive"])
        csv_row(
            f"fig6_phase{phase}", row["p50_ms_adaptive"] * 1e3,
            f"work_stale={row['work_stale']:.1f},"
            f"work_adapt={row['work_adaptive']:.1f},"
            f"work_oracle={row['work_oracle']:.1f},"
            f"recall_adapt={row['recall_adaptive']:.3f},"
            f"p99_ms_stale={row['p99_ms_stale']:.2f},"
            f"p99_ms_adapt={row['p99_ms_adaptive']:.2f}")

    frac = (float(np.sum(recovered) / np.sum(gaps))
            if gaps and np.sum(gaps) > 0 else float("nan"))
    mean_reb = float(np.mean(reboost_ms)) if reboost_ms else 0.0
    mean_bld = float(np.mean(rebuild_ms))
    csv_row(
        "fig6_summary", mean_reb * 1e3,
        f"recovered_frac={frac:.2f},reboosts={reboosts},"
        f"reboost_ms={mean_reb:.0f},rebuild_ms={mean_bld:.0f},"
        f"speedup={mean_bld / max(mean_reb, 1e-9):.1f}x")

    cache_row = _cache_segment(rng, db, adaptive.tree, p0, n, batch)

    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "adaptive.csv"), "w") as f:
        cols = sorted(rows[0])
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(str(r[c]) for c in cols) + "\n")
        f.write(f"# summary recovered_frac={frac:.3f} reboosts={reboosts} "
                f"reboost_ms={mean_reb:.1f} rebuild_ms={mean_bld:.1f}\n")
        f.write(f"# cache {cache_row}\n")
    return rows


def _cache_segment(rng, db, tree, p, n, batch):
    """Serving-cache segment: hit rate + p50/p99 with and without."""
    import jax.numpy as jnp

    from repro.adaptive import FrequencyAdmissionCache
    from repro.core.tree import tree_search
    from repro.serve.engine import ServingEngine

    dbj = jnp.asarray(db)

    def fn(qs):
        res = tree_search(tree.device_arrays(), dbj, jnp.asarray(qs),
                          beam_width=4, k=10,
                          max_steps=tree.max_depth + 4)
        return np.asarray(res.dists), np.asarray(res.ids)

    qids = rng.choice(n, 2000, p=p / p.sum())
    out = {}
    for label, cache in (("nocache", None),
                         ("cache", FrequencyAdmissionCache(capacity=512))):
        eng = ServingEngine(fn, cache=cache, max_batch=64, max_wait_ms=0.5)
        try:
            ts = []
            for qid in qids:
                t0 = time.perf_counter()
                eng.search(db[qid], timeout=30.0)
                ts.append(time.perf_counter() - t0)
            st = eng.stats()
            s = lat_summary(ts, stats=st)   # republish gauges ride along
            hit_rate = (st.cache_hits / max(st.cache_hits
                                            + st.cache_misses, 1))
            out[label] = {**s, "hit_rate": round(hit_rate, 3)}
            csv_row(f"fig6_serve_{label}", s["p50_ms"] * 1e3,
                    f"p99_ms={s['p99_ms']:.2f},hit_rate={hit_rate:.2f}")
        finally:
            eng.close()
    return out


if __name__ == "__main__":
    run()
