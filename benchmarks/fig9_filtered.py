"""Filtered + hybrid search: recall and latency vs filter selectivity.

Sweeps a metadata predicate from selectivity 1.0 (admits everything)
down to 0.01 over the brute, IVF and forest sharded backends, measuring
us/query-batch and recall@k against the pure-numpy filtered oracle.
Filters are compiled to mask *operands* (same shapes, same jit
signature), so the latency column shows the true marginal cost of
filtering — mask AND + the same scan — rather than a recompile.

The interesting curve is the approximate backends at low selectivity:
bucket/beam candidate generation is filter-blind, so a 1% predicate
leaves few admissible candidates per probe and recall sags — the
tuning guidance in ``docs/filtering.md`` (raise nprobe / fall back to
brute under ~5%) quotes these rows.

Hybrid rows run the fused ``alpha * semantic + (1-alpha) * lexical``
combiner on the brute backend at the same selectivities, so the cost of
carrying the BM25 slab scan shows up next to the dense-only rows.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import clustered_corpus, csv_row

SELS = ((1.0, (0, 99)), (0.5, (0, 49)), (0.2, (0, 19)),
        (0.05, (0, 4)), (0.01, (0, 0)))


def _recall(ids, oracle_ids):
    hits = want = 0
    for a, b in zip(np.asarray(ids), np.asarray(oracle_ids)):
        real = set(b[b >= 0].tolist())
        want += len(real)
        hits += len(set(a[a >= 0].tolist()) & real)
    return hits / max(1, want)


def run(n: int = 20000, nq: int = 64, k: int = 10) -> None:
    import jax

    from repro.core.lexical import build_lexical_slabs, query_operands
    from repro.core.metadata import FilterSpec, MetadataTable
    from repro.core.two_level import TwoLevelConfig, build_two_level
    from repro.distributed.backend import ShardedSearchBackend

    rng = np.random.default_rng(0)
    db = clustered_corpus(rng, n, 32)
    q = (db[rng.integers(0, n, nq)]
         + 0.05 * rng.normal(size=(nq, 32))).astype(np.float32)
    meta = MetadataTable({"pct": (rng.permutation(n) % 100)
                          .astype(np.int32)})
    nv = 500
    docs = [list(rng.integers(0, nv, 8)) for _ in range(n)]
    slabs = build_lexical_slabs(docs, nv, slots=8)
    qt, qw = query_operands(
        [list(rng.integers(0, nv, 4)) for _ in range(nq)], slabs)

    mesh = jax.make_mesh((1,), ("data",))
    kc = max(16, int(np.sqrt(n)))
    idx_i = build_two_level(db, TwoLevelConfig(
        n_clusters=kc, top="brute", bottom="brute", kmeans_iters=4),
        metadata=meta)
    idx_f = build_two_level(db, TwoLevelConfig(
        n_clusters=kc, top="brute", bottom="tree", kmeans_iters=4,
        tree_leaf=8), metadata=meta)
    kw = dict(k=k, axes=("data",), beam_width=8)
    backends = (
        ("brute", ShardedSearchBackend(mesh, db, metadata=meta,
                                       lexical=slabs, **kw)),
        ("ivf", ShardedSearchBackend(mesh, idx_i, nprobe_local=8, **kw)),
        ("forest", ShardedSearchBackend(mesh, idx_f, nprobe_local=8,
                                        **kw)),
    )

    d2 = ((q[:, None, :] - db[None, :, :]) ** 2).sum(-1)

    def oracle_ids(emask):
        dd = np.where(emask[None, :], d2, np.inf)
        oi = np.argsort(dd, axis=1, kind="stable")[:, :k]
        return np.where(np.isinf(np.take_along_axis(dd, oi, 1)), -1, oi)

    def timed_median(fn, iters=5):
        fn()                                      # warm the jit cache
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[len(ts) // 2] * 1e6

    for sel, (lo, hi) in SELS:
        fs = FilterSpec.range("pct", lo, hi)
        emask = fs.mask(meta, n)
        oi = oracle_ids(emask)
        for name, be in backends:
            us = timed_median(lambda: be(q, filter_spec=fs))
            _, ids = be(q, filter_spec=fs)
            csv_row(f"filtered_{name}_sel{sel}", us,
                    f"recall={_recall(ids, oi):.3f},sel={sel},"
                    f"n={n},B={nq},k={k}")
        # hybrid at the same selectivity (brute backend, alpha=0.5)
        be = backends[0][1]
        us = timed_median(lambda: be(
            q, filter_spec=fs, mode="hybrid", alpha=0.5,
            q_terms=qt, q_weights=qw))
        csv_row(f"filtered_hybrid_sel{sel}", us,
                f"alpha=0.5,sel={sel},n={n},B={nq},k={k}")

    # unfiltered baselines: the marginal cost of the mask AND
    for name, be in backends:
        us = timed_median(lambda: be(q))
        csv_row(f"filtered_{name}_nofilter", us, f"n={n},B={nq},k={k}")


if __name__ == "__main__":
    run()
