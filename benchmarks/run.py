"""Benchmark harness: one module per paper table/figure + roofline.

  python -m benchmarks.run             # default (CPU-sized) tiers
  python -m benchmarks.run --full      # paper-scale corpora (slow)
  python -m benchmarks.run --only fig1,roofline

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.csv_row).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale corpora (1M SIFT / 10M DEEP)")
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,table1,fig2d,fig3,sharded,"
                         "updates,adaptive,delta,roofline")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    t0 = time.time()
    if want("fig1"):
        from benchmarks import fig1_qlbt

        fig1_qlbt.run()
    if want("table1"):
        from benchmarks import table1_twolevel

        table1_twolevel.run(scale=1.0 if args.full else 0.2)
    if want("fig2d"):
        from benchmarks import fig2d_deep

        fig2d_deep.run(scale=1.0 if args.full else 0.1)
    if want("fig3"):
        from benchmarks import fig3_protocol

        fig3_protocol.run()
    if want("sharded"):
        from benchmarks import fig4_sharded

        fig4_sharded.run(shards=(1, 2, 4, 8) if args.full else (1, 2, 4),
                         n=100_000 if args.full else 20_000)
    if want("updates"):
        from benchmarks import fig5_updates

        fig5_updates.run(n=100_000 if args.full else 20_000)
    if want("adaptive"):
        from benchmarks import fig6_adaptive

        fig6_adaptive.run(n=20_000 if args.full else 8192)
    if want("delta"):
        from benchmarks import fig7_delta

        fig7_delta.run(n=100_000 if args.full else 20_000)
    if want("roofline"):
        from benchmarks import roofline

        try:
            roofline.run()
        except FileNotFoundError:
            print("roofline: no dryrun.json yet — run "
                  "python -m repro.launch.dryrun --all first",
                  file=sys.stderr)
    print(f"\nbenchmarks completed in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
