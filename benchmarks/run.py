"""Benchmark harness: one module per paper table/figure + roofline.

  python -m benchmarks.run             # default (CPU-sized) tiers
  python -m benchmarks.run --full      # paper-scale corpora (slow)
  python -m benchmarks.run --only fig1,roofline

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.csv_row)
and writes each figure's rows to ``benchmarks/results/BENCH_<fig>.json``
(numbers + run config + git sha) so a perf trajectory accumulates across
commits.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import common


def _figure(name: str, config: dict, fn) -> None:
    """Run one figure with BENCH_<name>.json recording around it."""
    common.begin_figure(name)
    try:
        fn()
    except BaseException:
        common.finish_figure(config=dict(config, aborted=True))
        raise
    path = common.finish_figure(config=config)
    if path:
        print(f"wrote {path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale corpora (1M SIFT / 10M DEEP)")
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,table1,fig2d,fig3,sharded "
                         "(alias: fig4),updates,adaptive,delta,fig8,"
                         "fig9,roofline")
    ap.add_argument("--ci", action="store_true",
                    help="CI-sized configs: tiny corpora/shard counts so "
                         "the fast job can persist BENCH_*.json artifacts")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    t0 = time.time()
    if want("fig1"):
        from benchmarks import fig1_qlbt

        _figure("fig1", {"full": args.full}, fig1_qlbt.run)
    if want("table1"):
        from benchmarks import table1_twolevel

        scale = 1.0 if args.full else 0.2
        _figure("table1", {"full": args.full, "scale": scale},
                lambda: table1_twolevel.run(scale=scale))
    if want("fig2d"):
        from benchmarks import fig2d_deep

        scale = 1.0 if args.full else 0.1
        _figure("fig2d", {"full": args.full, "scale": scale},
                lambda: fig2d_deep.run(scale=scale))
    if want("fig3"):
        from benchmarks import fig3_protocol

        _figure("fig3", {"full": args.full}, fig3_protocol.run)
    if want("sharded") or want("fig4"):
        from benchmarks import fig4_sharded

        if args.ci:
            shards, n = (1, 2), 4096
        elif args.full:
            shards, n = (1, 2, 4, 8), 100_000
        else:
            shards, n = (1, 2, 4), 20_000
        _figure("fig4_sharded", {"full": args.full, "ci": args.ci,
                                 "shards": shards, "n": n},
                lambda: fig4_sharded.run(shards=shards, n=n))
    if want("updates"):
        from benchmarks import fig5_updates

        n = 100_000 if args.full else 20_000
        _figure("fig5_updates", {"full": args.full, "n": n},
                lambda: fig5_updates.run(n=n))
    if want("adaptive"):
        from benchmarks import fig6_adaptive

        n = 20_000 if args.full else 8192
        _figure("fig6_adaptive", {"full": args.full, "n": n},
                lambda: fig6_adaptive.run(n=n))
    if want("delta"):
        from benchmarks import fig7_delta

        n = 100_000 if args.full else 20_000
        _figure("fig7_delta", {"full": args.full, "n": n},
                lambda: fig7_delta.run(n=n))
    if want("fig8"):
        from benchmarks import fig8_fleet

        n = 20_000 if args.full else 8192
        sizes = (2, 4, 8)
        _figure("fig8", {"full": args.full, "n": n,
                         "fleet_sizes": list(sizes)},
                lambda: fig8_fleet.run(n=n, fleet_sizes=sizes))
    if want("fig9"):
        from benchmarks import fig9_filtered

        if args.ci:
            n, nq = 4096, 16
        elif args.full:
            n, nq = 100_000, 64
        else:
            n, nq = 20_000, 64
        _figure("fig9", {"full": args.full, "ci": args.ci,
                         "n": n, "nq": nq},
                lambda: fig9_filtered.run(n=n, nq=nq))
    if want("roofline"):
        from benchmarks import roofline

        try:
            _figure("roofline", {"full": args.full}, roofline.run)
        except FileNotFoundError:
            print("roofline: no dryrun.json yet — run "
                  "python -m repro.launch.dryrun --all first",
                  file=sys.stderr)
    print(f"\nbenchmarks completed in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
