"""Paper Fig. 2(d): two-level PQ-top + brute-bottom on DEEP-scale data.

Validates that the SIFT conclusion transfers to the larger, lower-dim DEEP
corpus: the recall/latency frontier of the paper-optimal configuration at
increasing corpus sizes (default tier 1M x 96; --full 10M).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import cached_corpus, csv_row, ground_truth
from repro.core.metrics import recall_at_k
from repro.core.two_level import TwoLevelConfig, build_two_level


def run(scale: float = 0.1, n_queries: int = 256, seed: int = 0):
    from benchmarks.common import heldout_split

    db, q = heldout_split(cached_corpus("deep", scale, seed), n_queries)
    n = db.shape[0]
    _, gt = ground_truth(db, q, 10, tag=f"deep_ho_{scale}_{seed}")

    s = int(round(np.log2(n / 100)))
    cfg = TwoLevelConfig(n_clusters=1 << s, top="pq", bottom="brute",
                         kmeans_iters=5,
                         kmeans_minibatch=min(131072, n))
    t0 = time.perf_counter()
    idx = build_two_level(db, cfg)
    build_s = time.perf_counter() - t0
    rows = []
    for nprobe in (4, 8, 16, 32, 64):
        idx.search(q[:32], 10, nprobe=nprobe)          # warm
        t0 = time.perf_counter()
        _, ids, work = idx.search(q, 10, nprobe=nprobe)
        per_q = (time.perf_counter() - t0) / n_queries
        r = recall_at_k(ids, gt)
        rows.append((nprobe, r, per_q))
        csv_row(f"fig2d_deep_np{nprobe}", per_q * 1e6,
                f"recall={r:.3f};n={n};buckets=2^{s};"
                f"cand_per_q={work['candidates'] / n_queries:.0f}")
    csv_row("fig2d_deep_build", build_s * 1e6, f"n={n}")
    return rows


if __name__ == "__main__":
    run()
