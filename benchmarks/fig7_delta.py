"""Fig. 7 (beyond paper): delta vs full republish across mutation fractions.

The maintenance loop (fig5 mutations, fig6 reboosts) ends in a republish:
``ShardedSearchBackend.apply_updates`` re-places the mutated index onto
the mesh.  This benchmark measures what PR 5's delta shipping saves: for
each mutation fraction f the same mutated index is republished twice —

  * ``delta`` — ``apply_updates(idx, delta=idx.pop_delta())``: only the
    dirty-bucket slabs (forest), dirty bucket rows (IVF), or appended
    rows + validity mask (brute) cross the host->device boundary, applied
    in place by the jitted fixed-shape scatter;
  * ``full``  — the PR-3 path: every device array re-placed.

Two mutation patterns per fraction:

  * ``clustered`` — deletes drain the fullest buckets and adds land near
    those buckets' centroids (the paper's skewed-arrival regime: new
    things get popular *somewhere*, not everywhere).  This is the regime
    delta shipping targets: the dirty set stays a handful of buckets.
  * ``uniform``   — mutations spread over the whole corpus; at equal f
    they dirty far more buckets, so the delta fraction degrades toward
    (and past) the fallback threshold — reported honestly so the
    operating envelope is visible.

Reported per row: bytes shipped, bytes a full re-place ships, their
ratio (``delta_fraction``), and the apply wall time of both paths.  The
acceptance bound: at f <= 0.10 **clustered**, delta bytes <= 25% of
full.  The last segment routes one republish through ``ServingEngine``
so the ``EngineStats.republished_bytes`` / ``delta_fraction`` gauges
(the counters ``docs/tuning.md`` quotes) appear in the same CSV.

Rows land in ``benchmarks/results/delta.csv`` and on stdout.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import RESULTS, csv_row, lat_summary


def _mk(rng, centers, n, d):
    return (centers[rng.integers(0, centers.shape[0], n)]
            + rng.normal(size=(n, d))).astype(np.float32)


def _clustered_mutation(idx, rng, n_mut):
    """Delete ~n_mut/2 entities draining the fullest buckets; add the
    same count near those buckets' centroids."""
    half = n_mut // 2
    order = np.argsort(-idx.bucket_counts)
    dele, hot = [], []
    got = 0
    for b in order:
        if got >= half:
            break
        ids = idx.bucket_ids[b][: idx.bucket_counts[b]]
        ids = ids[ids >= 0]
        take = min(ids.size, half - got)
        dele.append(ids[:take].copy())
        hot.append(int(b))
        got += take
    dele = np.concatenate(dele) if dele else np.zeros(0, np.int64)
    idx.delete_entities(dele)
    cents = idx.centroids[rng.choice(hot, half)]
    new = (cents + 0.3 * rng.normal(size=cents.shape)).astype(np.float32)
    idx.add_entities(new)


def _uniform_mutation(idx, rng, n_mut, centers, d):
    half = n_mut // 2
    live = np.nonzero(idx.alive)[0]
    idx.delete_entities(rng.choice(live, half, replace=False))
    idx.add_entities(_mk(rng, centers, half, d))


def _timed_apply(fn, iters=2):
    out = fn()                             # first call pays any jit
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn()
        ts.append(time.perf_counter() - t0)
    return out, float(np.median(ts)) * 1e3


def run(n: int = 20000, d: int = 32, n_clusters: int = 64,
        fractions=(0.01, 0.05, 0.1, 0.3), seed: int = 0) -> list:
    import jax

    from repro.core.two_level import TwoLevelConfig, build_two_level
    from repro.distributed.backend import ShardedSearchBackend

    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(64, d)) * 4
    rows = []
    cases = [("forest", "tree"), ("ivf", "brute")]
    for kind, bottom in cases:
        for pattern in ("clustered", "uniform"):
            for frac in fractions:
                rng = np.random.default_rng(17)
                db = _mk(rng, centers, n, d)
                cfg = TwoLevelConfig(
                    n_clusters=n_clusters, top="brute", bottom=bottom,
                    kmeans_iters=5, tree_leaf=8)
                idx = build_two_level(db, cfg)
                kw = dict(kind=kind, k=10, axes=("data",),
                          nprobe_local=4, beam_width=8, headroom=1.5)
                beA = ShardedSearchBackend(mesh, idx, **kw)
                beB = ShardedSearchBackend(mesh, idx, **kw)
                n_mut = int(frac * n)
                if pattern == "clustered":
                    _clustered_mutation(idx, rng, n_mut)
                else:
                    _uniform_mutation(idx, rng, n_mut, centers, d)
                man = idx.pop_delta()
                st, t_delta = _timed_apply(
                    lambda: beA.apply_updates(idx, delta=man))
                _, t_full = _timed_apply(lambda: beB.apply_updates(idx))
                row = {
                    "kind": kind, "pattern": pattern, "frac": frac,
                    "mode": st["mode"],
                    "dirty_buckets": int(man.dirty_buckets.size),
                    "bytes": st["bytes"],
                    "full_bytes": st["full_bytes"],
                    "delta_fraction": round(
                        st["bytes"] / max(st["full_bytes"], 1), 4),
                    "t_delta_ms": round(t_delta, 2),
                    "t_full_ms": round(t_full, 2),
                }
                rows.append(row)
                csv_row(
                    f"fig7_{kind}_{pattern}_f{frac}", t_delta * 1e3,
                    f"mode={row['mode']},frac={row['delta_fraction']},"
                    f"dirty={row['dirty_buckets']},"
                    f"bytes={row['bytes']},full={row['full_bytes']},"
                    f"t_full_ms={row['t_full_ms']}")

    # brute kind: append-only growth + tombstones on a raw corpus
    from repro.core.delta import DeltaManifest

    for frac in fractions:
        rng = np.random.default_rng(17)
        db = _mk(rng, centers, n, d)
        beA = ShardedSearchBackend(mesh, db, k=10, axes=("data",),
                                   headroom=1.5)
        beB = ShardedSearchBackend(mesh, db, k=10, axes=("data",),
                                   headroom=1.5)
        half = int(frac * n) // 2
        grown = np.concatenate([db, _mk(rng, centers, half, d)])
        alive = np.ones(grown.shape[0], bool)
        alive[rng.choice(n, half, replace=False)] = False
        man = DeltaManifest(base_version=0, version=1, base_n=n,
                            n=grown.shape[0],
                            tombstones=np.nonzero(~alive)[0])
        st, t_delta = _timed_apply(
            lambda: beA.apply_updates(grown, alive=alive, delta=man))
        _, t_full = _timed_apply(
            lambda: beB.apply_updates(grown, alive=alive))
        row = {"kind": "brute", "pattern": "uniform", "frac": frac,
               "mode": st["mode"], "dirty_buckets": 0,
               "bytes": st["bytes"], "full_bytes": st["full_bytes"],
               "delta_fraction": round(
                   st["bytes"] / max(st["full_bytes"], 1), 4),
               "t_delta_ms": round(t_delta, 2),
               "t_full_ms": round(t_full, 2)}
        rows.append(row)
        csv_row(f"fig7_brute_f{frac}", t_delta * 1e3,
                f"mode={row['mode']},frac={row['delta_fraction']},"
                f"bytes={row['bytes']},full={row['full_bytes']}")

    # acceptance: clustered mutations at f <= 0.1 ship <= 25% of full
    acc = [r for r in rows
           if r["pattern"] == "clustered" and r["frac"] <= 0.1]
    worst = max((r["delta_fraction"] for r in acc), default=0.0)
    csv_row("fig7_summary", 0.0,
            f"worst_delta_fraction_at_10pct={worst:.3f},"
            f"target<=0.25,pass={worst <= 0.25}")

    # engine segment: the SAME counters surface through EngineStats —
    # fig7 and docs/tuning.md quote lat_summary(..., stats=eng.stats())
    engine_row = _engine_segment(mesh, rng, centers, n, d, n_clusters)
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "delta.csv"), "w") as f:
        cols = list(rows[0])
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(str(r[c]) for c in cols) + "\n")
        f.write(f"# summary worst_delta_fraction_at_10pct={worst:.4f} "
                f"pass={worst <= 0.25}\n")
        f.write(f"# engine {engine_row}\n")
    return rows


def _engine_segment(mesh, rng, centers, n, d, n_clusters):
    from repro.core.two_level import TwoLevelConfig, build_two_level
    from repro.serve.engine import ServingEngine

    db = _mk(rng, centers, n, d)
    idx = build_two_level(db, TwoLevelConfig(
        n_clusters=n_clusters, top="brute", bottom="tree",
        kmeans_iters=5, tree_leaf=8))
    eng = ServingEngine.sharded(
        mesh, idx, kind="forest", k=10, axes=("data",), nprobe_local=4,
        beam_width=8, headroom=1.5, max_batch=32, max_wait_ms=1.0)
    try:
        ts = []
        for j in range(64):
            t0 = time.perf_counter()
            eng.search(db[j], timeout=60.0)
            ts.append(time.perf_counter() - t0)
        _clustered_mutation(idx, rng, int(0.05 * n))
        eng.apply_updates(idx)            # pops + ships the delta
        s = lat_summary(ts, stats=eng.stats())
        csv_row("fig7_engine", s["p50_ms"] * 1e3,
                f"republished_bytes={s['republished_bytes']},"
                f"delta_fraction={s['delta_fraction']}")
        return s
    finally:
        eng.close()


if __name__ == "__main__":
    run()
