"""Paper Fig. 3 + §5.3: footprint & latency vs corpus size; the 30K
crossover; the configuration protocol end-to-end.

For sizes 5K..300K builds (a) one-level tree, (b) the protocol-selected
index, and reports footprint bytes (excluding raw vectors, which both need)
and P90 per-query wall time at recall@10 >= 0.9.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import cached_corpus, csv_row, ground_truth
from repro.core.index import auto_build_index, build_index
from repro.core.metrics import recall_at_k
from repro.core.protocol import IndexSpec
from repro.core.tree import build_rp_tree, tree_search

import jax.numpy as jnp


def _tree_p90_at_recall(db, q, gt, target=0.9):
    t = build_rp_tree(db, leaf_size=8, n_candidates=4, seed=0)
    dbj, qj = jnp.asarray(db), jnp.asarray(q)
    for w in (2, 4, 8, 16, 32, 64, 128, 256, 512):
        if w * 8 > db.shape[0]:
            break
        res = tree_search(t.device_arrays(), dbj, qj, beam_width=w, k=10,
                          max_steps=t.max_depth + 4)
        if recall_at_k(np.asarray(res.ids), gt) >= target:
            times = []
            for _ in range(3):
                t0 = time.perf_counter()
                tree_search(t.device_arrays(), dbj, qj, beam_width=w,
                            k=10, max_steps=t.max_depth + 4
                            ).ids.block_until_ready()
                times.append((time.perf_counter() - t0) / q.shape[0])
            return float(np.median(times)), t.footprint_bytes(), w
    return np.inf, t.footprint_bytes(), None


def _proto_p90_at_recall(db, q, gt, target=0.9):
    idx = auto_build_index(db)
    kind = idx.spec.kind
    sweep = ((4, 8, 16, 32, 64, 128, 256) if kind == "two_level" else
             (2, 4, 8, 16, 32, 64))
    for v in sweep:
        kw = dict(nprobe=v) if kind == "two_level" else dict(beam_width=v)
        _, ids, _ = idx.search(q, 10, **kw)
        if recall_at_k(ids, gt) >= target:
            times = []
            for _ in range(3):
                t0 = time.perf_counter()
                idx.search(q, 10, **kw)
                times.append((time.perf_counter() - t0) / q.shape[0])
            return (float(np.median(times)),
                    idx.footprint_bytes(include_db=False), v, kind)
    return np.inf, idx.footprint_bytes(include_db=False), None, kind


def run(n_queries: int = 256, seed: int = 0):
    from benchmarks.common import heldout_split

    rows = []
    for n in (5_000, 10_000, 30_000, 100_000, 300_000):
        scale = (n + n_queries) / 1_000_000
        db, q = heldout_split(
            np.asarray(cached_corpus("sift", scale, seed))[: n + n_queries],
            n_queries,
        )
        _, gt = ground_truth(db, q, 10, tag=f"fig3_ho_{n}_{seed}")
        t_tree, fp_tree, w = _tree_p90_at_recall(db, q, gt)
        t_pro, fp_pro, v, kind = _proto_p90_at_recall(db, q, gt)
        rows.append(dict(n=n, tree_us=t_tree * 1e6, proto_us=t_pro * 1e6,
                         tree_fp=fp_tree, proto_fp=fp_pro, kind=kind))
        csv_row(f"fig3_n{n}", t_pro * 1e6,
                f"kind={kind};tree_us={t_tree * 1e6:.0f};"
                f"fp_tree={fp_tree};fp_proto={fp_pro}")
    return rows


if __name__ == "__main__":
    run()
