"""Shared benchmark utilities: corpora caching, recall/latency sweeps."""
from __future__ import annotations

import os
import time

import numpy as np

CACHE = os.path.join(os.path.dirname(__file__), "results", "cache")
RESULTS = os.path.join(os.path.dirname(__file__), "results")


def cached_corpus(name: str, scale: float, seed: int = 0):
    from repro.data.synthetic import make_corpus

    os.makedirs(CACHE, exist_ok=True)
    fp = os.path.join(CACHE, f"{name}_{scale}_{seed}.npy")
    if os.path.exists(fp):
        return np.load(fp, mmap_mode="r")
    db = make_corpus(name, scale=scale, seed=seed)
    np.save(fp, db)
    return db


def ground_truth(db, queries, k=10, tag=None):
    from repro.core.brute import brute_search

    if tag is not None:
        os.makedirs(CACHE, exist_ok=True)
        fp = os.path.join(CACHE, f"gt_{tag}.npz")
        if os.path.exists(fp):
            z = np.load(fp)
            return z["d"], z["i"]
    d, i = brute_search(queries, np.asarray(db), k)
    if tag is not None:
        np.savez(os.path.join(CACHE, f"gt_{tag}.npz"), d=d, i=i)
    return d, i


def clustered_corpus(rng, n: int, d: int) -> "np.ndarray":
    """Mildly clustered entity embeddings (8-point clusters) — the shared
    workload shape for the QLBT figures (fig1, fig6)."""
    c = rng.normal(size=(n // 8, d)).astype(np.float32)
    x = (c[:, None, :] + 0.8 * rng.normal(size=(n // 8, 8, d)))
    return x.reshape(-1, d)[:n].astype(np.float32)


def heldout_split(db, n_queries: int):
    """Hold out the corpus tail as queries (SIFT-style true held-out —
    near-duplicate queries make one-level trees trivially strong and
    misrepresent Table 1; EXPERIMENTS.md §Paper-validation)."""
    db = np.asarray(db)
    return db[:-n_queries], db[-n_queries:].copy()


def timed(fn, *args, warmup=1, iters=3, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return out, float(np.median(ts))


def lat_summary(samples_s, stats=None) -> dict:
    """p50 AND p99 (plus mean) of a latency sample list, in ms.

    Benchmark summaries report the pair so tail effects — e.g. a
    maintenance pass stealing cycles from the serving loop — show up
    next to the median instead of hiding behind it.

    ``stats`` (an ``EngineStats``) additionally merges the republish
    counters — ``republished_bytes`` and ``delta_fraction`` — so the
    fig6/fig7 rows and ``docs/tuning.md`` quote the *same* gauges the
    engine exposes instead of re-deriving them.
    """
    a = np.asarray(list(samples_s), dtype=np.float64) * 1e3
    out = ({"p50_ms": 0.0, "p99_ms": 0.0, "mean_ms": 0.0}
           if a.size == 0 else
           {"p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99)),
            "mean_ms": float(a.mean())})
    if stats is not None:
        out["republished_bytes"] = int(
            getattr(stats, "republished_bytes", 0))
        out["delta_fraction"] = round(
            float(getattr(stats, "delta_fraction", 0.0)), 4)
    return out


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
