"""Shared benchmark utilities: corpora caching, recall/latency sweeps."""
from __future__ import annotations

import os
import time

import numpy as np

CACHE = os.path.join(os.path.dirname(__file__), "results", "cache")
RESULTS = os.path.join(os.path.dirname(__file__), "results")


def cached_corpus(name: str, scale: float, seed: int = 0):
    from repro.data.synthetic import make_corpus

    os.makedirs(CACHE, exist_ok=True)
    fp = os.path.join(CACHE, f"{name}_{scale}_{seed}.npy")
    if os.path.exists(fp):
        return np.load(fp, mmap_mode="r")
    db = make_corpus(name, scale=scale, seed=seed)
    np.save(fp, db)
    return db


def ground_truth(db, queries, k=10, tag=None):
    from repro.core.brute import brute_search

    if tag is not None:
        os.makedirs(CACHE, exist_ok=True)
        fp = os.path.join(CACHE, f"gt_{tag}.npz")
        if os.path.exists(fp):
            z = np.load(fp)
            return z["d"], z["i"]
    d, i = brute_search(queries, np.asarray(db), k)
    if tag is not None:
        np.savez(os.path.join(CACHE, f"gt_{tag}.npz"), d=d, i=i)
    return d, i


def heldout_split(db, n_queries: int):
    """Hold out the corpus tail as queries (SIFT-style true held-out —
    near-duplicate queries make one-level trees trivially strong and
    misrepresent Table 1; EXPERIMENTS.md §Paper-validation)."""
    db = np.asarray(db)
    return db[:-n_queries], db[-n_queries:].copy()


def timed(fn, *args, warmup=1, iters=3, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return out, float(np.median(ts))


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
