"""Shared benchmark utilities: corpora caching, recall/latency sweeps,
and machine-readable result persistence.

Every row printed through :func:`csv_row` between :func:`begin_figure`
and :func:`finish_figure` is also recorded and written to
``benchmarks/results/BENCH_<figure>.json`` — numbers + run config + git
sha — so successive runs leave a perf trajectory instead of scrollback.
The same record is mirrored to ``BENCH_<figure>.json`` at the repo root,
which is what the cross-commit trajectory collector (and the CI bench
artifact upload) reads — results/ is scratch, the root copy is the
committed trajectory point.
"""
from __future__ import annotations

import json
import os
import subprocess
import time

import numpy as np

CACHE = os.path.join(os.path.dirname(__file__), "results", "cache")
RESULTS = os.path.join(os.path.dirname(__file__), "results")

_RECORDING: "dict | None" = None


def git_sha() -> str:
    """Commit the numbers were measured at (dirty trees get a suffix)."""
    try:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=root,
            capture_output=True, text=True, timeout=10).stdout.strip()
        if not sha:
            return "unknown"
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], cwd=root,
            capture_output=True, text=True, timeout=10).stdout.strip()
        return sha + ("-dirty" if dirty else "")
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def begin_figure(name: str) -> None:
    """Start recording csv_row output for ``BENCH_<name>.json``."""
    global _RECORDING
    _RECORDING = {"figure": name, "rows": []}


def finish_figure(config: "dict | None" = None) -> "str | None":
    """Write the recorded rows (plus ``config`` and git sha) and return
    the written path, or None when nothing was recorded.

    Writes twice: ``benchmarks/results/BENCH_<fig>.json`` (scratch) and
    ``BENCH_<fig>.json`` at the repo root — the copy the cross-commit
    trajectory collector and the CI artifact upload read."""
    global _RECORDING
    rec, _RECORDING = _RECORDING, None
    if rec is None:
        return None
    rec["config"] = config or {}
    rec["git_sha"] = git_sha()
    rec["unix_time"] = int(time.time())
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"BENCH_{rec['figure']}.json")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for p in (path, os.path.join(root, f"BENCH_{rec['figure']}.json")):
        with open(p, "w", encoding="utf-8") as f:
            json.dump(rec, f, indent=1, sort_keys=True)
            f.write("\n")
    return path


def cached_corpus(name: str, scale: float, seed: int = 0):
    from repro.data.synthetic import make_corpus

    os.makedirs(CACHE, exist_ok=True)
    fp = os.path.join(CACHE, f"{name}_{scale}_{seed}.npy")
    if os.path.exists(fp):
        return np.load(fp, mmap_mode="r")
    db = make_corpus(name, scale=scale, seed=seed)
    np.save(fp, db)
    return db


def ground_truth(db, queries, k=10, tag=None):
    from repro.core.brute import brute_search

    if tag is not None:
        os.makedirs(CACHE, exist_ok=True)
        fp = os.path.join(CACHE, f"gt_{tag}.npz")
        if os.path.exists(fp):
            z = np.load(fp)
            return z["d"], z["i"]
    d, i = brute_search(queries, np.asarray(db), k)
    if tag is not None:
        np.savez(os.path.join(CACHE, f"gt_{tag}.npz"), d=d, i=i)
    return d, i


def clustered_corpus(rng, n: int, d: int) -> "np.ndarray":
    """Mildly clustered entity embeddings (8-point clusters) — the shared
    workload shape for the QLBT figures (fig1, fig6)."""
    c = rng.normal(size=(n // 8, d)).astype(np.float32)
    x = (c[:, None, :] + 0.8 * rng.normal(size=(n // 8, 8, d)))
    return x.reshape(-1, d)[:n].astype(np.float32)


def heldout_split(db, n_queries: int):
    """Hold out the corpus tail as queries (SIFT-style true held-out —
    near-duplicate queries make one-level trees trivially strong and
    misrepresent Table 1; EXPERIMENTS.md §Paper-validation)."""
    db = np.asarray(db)
    return db[:-n_queries], db[-n_queries:].copy()


def timed(fn, *args, warmup=1, iters=3, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return out, float(np.median(ts))


def lat_summary(samples_s, stats=None) -> dict:
    """p50 AND p99 (plus mean) of a latency sample list, in ms.

    Benchmark summaries report the pair so tail effects — e.g. a
    maintenance pass stealing cycles from the serving loop — show up
    next to the median instead of hiding behind it.

    ``stats`` (an ``EngineStats``) additionally merges the republish
    counters — ``republished_bytes`` and ``delta_fraction`` — so the
    fig6/fig7 rows and ``docs/tuning.md`` quote the *same* gauges the
    engine exposes instead of re-deriving them.  Fleet-level stats (a
    ``CellRouter.stats()``) further merge the routing counters
    (``shed``/``rerouted``/``hedge_cell``/``cancelled``) and a
    ``cells`` breakdown (per-cell n/p50/p99) so fig8 can attribute a
    p99 move to a routing decision rather than to one hot cell.

    When ``stats`` carries the registry-backed view (``stats.n`` > 0 /
    ``stats.stages``), the engine's histogram-derived percentiles land
    under ``"engine"`` and the per-stage (queue/batch/dispatch/kernel/
    rerank) summaries under ``"stages"`` — the client-observed sample
    percentiles above stay the headline numbers, the registry view says
    where the time went.
    """
    a = np.asarray(list(samples_s), dtype=np.float64) * 1e3
    out = ({"p50_ms": 0.0, "p99_ms": 0.0, "mean_ms": 0.0}
           if a.size == 0 else
           {"p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99)),
            "mean_ms": float(a.mean())})
    if stats is not None:
        out["republished_bytes"] = int(
            getattr(stats, "republished_bytes", 0))
        out["delta_fraction"] = round(
            float(getattr(stats, "delta_fraction", 0.0)), 4)
        for ctr in ("shed", "rerouted", "hedge_cell", "cancelled"):
            v = int(getattr(stats, ctr, 0))
            if v:
                out[ctr] = v
        n_eng = int(getattr(stats, "n", 0) or 0)
        if n_eng:
            out["engine"] = {
                "n": n_eng,
                "p50_ms": round(float(stats.p50_ms), 3),
                "p99_ms": round(float(stats.p99_ms), 3)}
        stages = getattr(stats, "stages", None)
        if stages:
            out["stages"] = {
                name: {"n": int(s.get("n", 0)),
                       "p50_ms": round(float(s.get("p50_ms", 0.0)), 3),
                       "p99_ms": round(float(s.get("p99_ms", 0.0)), 3)}
                for name, s in stages.items() if s.get("n")}
        cells = getattr(stats, "cells", None)
        if cells:
            out["cells"] = {
                name: {"n": int(s.n),
                       "p50_ms": round(float(s.p50_ms), 3),
                       "p99_ms": round(float(s.p99_ms), 3),
                       "queue_ms": round(float(s.queue_ms), 3),
                       "hedges": int(s.hedges),
                       "cache_hits": int(s.cache_hits)}
                for name, s in cells.items()}
    return out


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
    if _RECORDING is not None:
        _RECORDING["rows"].append({
            "name": name,
            "us_per_call": round(float(us_per_call), 2),
            "derived": derived,
        })
