"""§Roofline: three-term analysis per (arch x shape x mesh) from the
dry-run's compiled artifacts (launch/dryrun.py -> results/dryrun.json).

  compute   = matmul_flops_per_dev / peak_flops      (197 TFLOP/s bf16)
  memory    = mem_bytes_proxy_per_dev / hbm_bw       (819 GB/s)
  collective= collective_bytes_per_dev / link_bw     (50 GB/s/link ICI)

All three use the trip-count-corrected HLO accounting
(launch/hlo_analysis.py) — raw cost_analysis counts while bodies once.
MODEL_FLOPS is the analytic useful-flops estimate below; the ratio
MODEL_FLOPS / (HLO flops x chips) exposes remat/padding/redundancy waste.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.configs.base import (
    AnnConfig,
    DCNConfig,
    DINConfig,
    DLRMConfig,
    LMConfig,
    SASRecConfig,
    SchNetConfig,
)
from repro.configs.registry import get_arch, get_shapes

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def _mlp_flops(dims) -> float:
    return 2.0 * sum(a * b for a, b in zip(dims[:-1], dims[1:]))


def lm_active_params(cfg: LMConfig) -> float:
    """Matmul params on the per-token path (embed gather excluded,
    unembed included); MoE counts top-k + shared experts only."""
    d = cfg.d_model
    if cfg.attn_kind == "mla":
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        attn = (d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * qk
                + d * m.kv_lora_rank + d * m.qk_rope_head_dim
                + m.kv_lora_rank * cfg.n_heads * m.qk_nope_head_dim
                + m.kv_lora_rank * cfg.n_heads * m.v_head_dim
                + cfg.n_heads * m.v_head_dim * d)
    else:
        attn = (d * cfg.n_heads * cfg.d_head * 2
                + d * cfg.n_kv_heads * cfg.d_head * 2)
    dense_mlp = (3 if cfg.mlp_kind == "swiglu" else 2) * d * cfg.d_ff
    n_dense = cfg.n_dense_layers if cfg.moe else cfg.n_layers
    total = n_dense * (attn + dense_mlp)
    if cfg.moe:
        mo = cfg.moe
        moe_mlp = (mo.top_k + mo.n_shared) * 3 * d * mo.d_ff \
            + d * mo.n_experts
        total += cfg.n_moe_layers * (attn + moe_mlp)
    total += d * cfg.vocab            # unembed
    if cfg.mtp:
        total += attn + 3 * d * (cfg.moe.d_ff * 8 if cfg.moe else cfg.d_ff)
    return float(total)


def lm_attention_flops(cfg: LMConfig, b: int, s: int, decode: bool):
    if cfg.attn_kind == "mla":
        qk = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
        dv = cfg.mla.v_head_dim
    else:
        qk = dv = cfg.d_head
    h = cfg.n_heads
    if decode:
        return 2.0 * b * s * h * (qk + dv) * cfg.n_layers
    return 2.0 * b * s * s * 0.5 * h * (qk + dv) * cfg.n_layers


def useful_flops(arch: str, cfg, family: str, shape) -> float:
    """Analytic MODEL_FLOPS per step (global, all chips)."""
    if family == "lm":
        n_act = lm_active_params(cfg)
        b = shape["batch"]
        s = shape["seq"]
        if shape.kind == "train":
            toks = b * s
            return 6.0 * n_act * toks + 3.0 * lm_attention_flops(
                cfg, b, s, False)
        if shape.kind == "prefill":
            return 2.0 * n_act * b * s + lm_attention_flops(cfg, b, s,
                                                            False)
        return 2.0 * n_act * b + lm_attention_flops(cfg, b, s, True)
    if family == "gnn":
        c: SchNetConfig = cfg
        dims = shape.dims
        if shape.name == "minibatch_lg":
            bn = dims["batch_nodes"]
            f1, f2 = dims["fanout"]
            n = bn * (1 + f1) + bn * f1 * f2
            e = bn * f1 + bn * f1 * f2
        elif shape.name == "molecule":
            n = dims["batch"] * dims["n_nodes"]
            e = dims["batch"] * dims["n_edges"]
        else:
            n, e = dims["n_nodes"], dims["n_edges"]
        d_feat = dims.get("d_feat", c.d_feat)
        h, r = c.d_hidden, c.n_rbf
        per_edge = 2.0 * (r * h + h * h) + 2 * h
        per_node = 2.0 * (2 * h * h)
        fwd = (e * per_edge + n * per_node) * c.n_interactions \
            + 2.0 * n * d_feat * h + 2.0 * n * (h * h // 2)
        return 3.0 * fwd       # train
    if family == "recsys":
        if shape.kind == "retrieval":
            b = shape["n_candidates"]
        else:
            b = shape["batch"]
        if isinstance(cfg, DLRMConfig):
            per = _mlp_flops((cfg.n_dense,) + tuple(cfg.bot_mlp)) + \
                _mlp_flops((378 + cfg.embed_dim,) + tuple(cfg.top_mlp)) + \
                2.0 * 27 * 27 * cfg.embed_dim
        elif isinstance(cfg, DCNConfig):
            d0 = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
            per = cfg.n_cross_layers * 2.0 * d0 * d0 + \
                _mlp_flops((d0,) + tuple(cfg.mlp) + (1,))
        elif isinstance(cfg, DINConfig):
            d2 = 2 * cfg.embed_dim
            per = cfg.seq_len * _mlp_flops((4 * d2,) + tuple(cfg.attn_mlp)
                                           + (1,)) + \
                _mlp_flops((2 * d2,) + tuple(cfg.mlp) + (1,))
        else:  # SASRec
            d = cfg.embed_dim
            L = cfg.seq_len
            per = cfg.n_blocks * (L * 8.0 * d * d + 2.0 * L * L * d * 2)
            if shape.kind == "retrieval":
                per = 2.0 * d     # dot per candidate
        mult = 3.0 if shape.kind == "train" else 1.0
        return mult * per * b
    if family == "ann":
        c: AnnConfig = cfg
        b = shape["batch"]
        cap = int(np.ceil(2.5 * c.n / c.n_clusters))
        # top-level centroid scan + nprobe bucket scans
        return b * 2.0 * c.d * (c.n_clusters + c.nprobe * cap)
    raise ValueError(family)


def chips_of(mesh_name: str) -> int:
    return 512 if "multi" in mesh_name else 256


# ---------------------------------------------------------------------------
# analytic ann-scan roofline: fused vs unfused vs int8 (PR-8)
# ---------------------------------------------------------------------------


def ann_scan_rows(b: int = 64, n: int = 1_000_000, d: int = 128,
                  k: int = 10) -> list:
    """Three-variant HBM-traffic model of the per-shard brute scan.

    The scan is bandwidth-bound (2*B*N*D flops over >= N*D*4 bytes is
    ~2B flops/byte at B=64 — far below the ~240 flops/byte ridge), so
    the variants differ almost purely in bytes moved:

      unfused : read db (N*D*4) + write the (B, N) f32 distance matrix
                and read it back for top_k        -> + 2*B*N*4 bytes
      fused   : read db once; the running top-k lives in the revisited
                output block                      -> + B*k*8 bytes
      int8    : fused traffic with the corpus as per-row-scaled int8
                codes                             -> db bytes / 4

    Returns rows shaped like :func:`build_table`'s (arch/shape/mesh
    keys reused so the markdown table renders them), with
    ``roofline_frac`` = useful-byte fraction: db bytes / total bytes —
    the figure-of-merit the fused kernel raises."""
    flops = 2.0 * b * n * d
    db_f32 = n * d * 4.0
    db_int8 = n * d * 1.0 + n * 4.0          # codes + per-row scales
    out = b * k * 8.0                        # (dists f32, ids int32)
    variants = [
        ("unfused", db_f32, db_f32 + 2.0 * b * n * 4.0 + out),
        ("fused", db_f32, db_f32 + out),
        ("fused-int8", db_int8, db_int8 + out),
    ]
    rows = []
    for name, useful_bytes, bytes_moved in variants:
        t_comp = flops / PEAK_FLOPS
        t_mem = bytes_moved / HBM_BW
        rows.append(dict(
            arch=f"ann-scan-{name}", shape=f"B{b}xN{n}xD{d}", mesh="1chip",
            status="ok", chips=1,
            gib_per_dev=bytes_moved / 2**30,
            gib_tpu_adj=bytes_moved / 2**30,
            t_compute_s=t_comp, t_memory_s=t_mem, t_collective_s=0.0,
            bottleneck="memory" if t_mem >= t_comp else "compute",
            model_flops=flops, hlo_flops_total=flops,
            useful_ratio=1.0,
            roofline_frac=useful_bytes / bytes_moved,
        ))
    return rows


def build_table(results_path=None):
    results_path = results_path or os.path.join(RESULTS, "dryrun.json")
    with open(results_path) as f:
        results = json.load(f)
    rows = []
    for key, rec in sorted(results.items()):
        arch, shape_name, mesh = key.split("|")
        if rec["status"] == "skipped":
            rows.append(dict(arch=arch, shape=shape_name, mesh=mesh,
                             status="skipped",
                             reason=rec.get("reason", "")))
            continue
        if rec["status"] != "ok":
            rows.append(dict(arch=arch, shape=shape_name, mesh=mesh,
                             status="error", reason=rec.get("error", "")))
            continue
        cfg, family = get_arch(arch)
        shape = next(s for s in get_shapes(family)
                     if s.name == shape_name)
        chips = chips_of(rec["mesh"])
        a = rec["analysis"]
        t_comp = a["matmul_flops"] / PEAK_FLOPS
        t_mem = a["mem_bytes_proxy"] / HBM_BW
        t_coll = a["collective_bytes"]["total"] / LINK_BW
        dom = max((("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll)), key=lambda kv: kv[1])[0]
        uf = useful_flops(arch, cfg, family, shape)
        hlo_total = a["matmul_flops"] * chips
        hoist = a.get("entry_f32_weight_convert_bytes", 0.0)
        rows.append(dict(
            arch=arch, shape=shape_name, mesh=mesh, status="ok",
            chips=chips,
            gib_per_dev=rec["memory"]["per_device_total"] / 2**30,
            gib_tpu_adj=(rec["memory"]["per_device_total"] - hoist) / 2**30,
            t_compute_s=t_comp, t_memory_s=t_mem, t_collective_s=t_coll,
            bottleneck=dom,
            model_flops=uf,
            hlo_flops_total=hlo_total,
            useful_ratio=(uf / hlo_total) if hlo_total else 0.0,
            roofline_frac=(
                t_comp / max(t_comp, t_mem, t_coll)
                if max(t_comp, t_mem, t_coll) > 0 else 0.0),
        ))
    return rows


def markdown(rows) -> str:
    out = ["| arch | shape | mesh | GiB/dev (tpu-adj) | compute s | "
           "memory s | collective s | bottleneck | MODEL/HLO | "
           "roofline frac |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | "
                       f"— | — | — | SKIP (listed) | — | — |")
            continue
        if r["status"] == "error":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | "
                       f"— | — | — | ERROR | — | — |")
            continue
        adj = r.get("gib_tpu_adj", r["gib_per_dev"])
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['gib_per_dev']:.1f} ({adj:.1f}) | "
            f"{r['t_compute_s']:.3g} | "
            f"{r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.2f} |"
        )
    return "\n".join(out)


def run():
    # the analytic ann-scan rows need no dryrun artifacts; the compiled
    # (arch x shape x mesh) table is additive when dryrun.json exists
    rows = ann_scan_rows()
    try:
        rows += build_table()
    except FileNotFoundError:
        import sys

        print("roofline: no dryrun.json — emitting only the analytic "
              "ann-scan rows (run python -m repro.launch.dryrun --all "
              "for the compiled table)", file=sys.stderr)
    md = markdown(rows)
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "roofline.md"), "w") as f:
        f.write(md + "\n")
    ok = [r for r in rows if r["status"] == "ok"]
    from benchmarks.common import csv_row

    for r in ok:
        t_total = max(r["t_compute_s"], r["t_memory_s"],
                      r["t_collective_s"])
        csv_row(
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            t_total * 1e6,
            f"bottleneck={r['bottleneck']};frac={r['roofline_frac']:.2f};"
            f"useful={r['useful_ratio']:.2f};gib={r['gib_per_dev']:.1f}",
        )
    print(f"\nroofline table written to {RESULTS}/roofline.md "
          f"({len(ok)} ok rows)")
    return rows


if __name__ == "__main__":
    run()
