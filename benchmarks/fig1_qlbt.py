"""Paper Fig. 1: QLBT latency gain vs query-likelihood unbalance score.

Reproduces the §4.2 simulation: 256 radio-station-like entities, traffic
from Beta distributions swept over unbalance scores, queries sampled from
the likelihood.  We report, per unbalance level:

  * E[Depth] for balanced SPPT vs QLBT (the paper's objective),
  * mean + P90 *work* (distance evaluations + node dot products) at
    recall@10 >= 0.95 — the machine-independent latency proxy,
  * wall-clock per query on this host (relative numbers are what the paper
    reports; DESIGN.md §2),
  * the beyond-paper greedy-split variant, recorded separately.

Paper claims: gain grows with unbalance; ~15% at U=0.23 (the real Radio
Station traffic's score).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import clustered_corpus, csv_row, timed
from repro.core.likelihood import beta_for_unbalance, sample_queries
from repro.core.metrics import recall_at_k
from repro.core.tree import build_qlbt, build_rp_tree, tree_search

import jax.numpy as jnp


def _corpus(rng, n=256, d=256):
    # mild cluster structure like real entity embeddings
    return clustered_corpus(rng, n, d)


def _work_at_recall(tree, db, q, gt, target=0.95):
    dbj = jnp.asarray(db)
    qj = jnp.asarray(q)
    best = None
    for w in (1, 2, 3, 4, 6, 8, 12, 16, 24, 32):
        res = tree_search(tree.device_arrays(), dbj, qj, beam_width=w,
                          k=10, max_steps=tree.max_depth + 4)
        r = recall_at_k(np.asarray(res.ids), gt)
        if r >= target:
            work = (np.asarray(res.internal_visits)
                    + np.asarray(res.candidates))
            _, wall = timed(
                lambda: tree_search(tree.device_arrays(), dbj, qj,
                                    beam_width=w, k=10,
                                    max_steps=tree.max_depth + 4
                                    ).ids.block_until_ready(), iters=3)
            best = dict(beam=w, recall=r, mean=float(work.mean()),
                        p90=float(np.percentile(work, 90)),
                        wall_us=wall / q.shape[0] * 1e6)
            break
    return best


def run(n_queries: int = 2000, seed: int = 0):
    rng = np.random.default_rng(seed)
    db = _corpus(rng)
    rows = []
    for target_u in (0.02, 0.12, 0.23, 0.35, 0.45):
        _, u, p = beta_for_unbalance(target_u, db.shape[0], seed=3)
        q, gt = sample_queries(rng, db, p, n_queries, noise_scale=0.05)
        bal = build_rp_tree(db, seed=1, n_candidates=16)
        ql = build_qlbt(db, p, seed=1, n_candidates=16, lam=0.2)
        gr = build_qlbt(db, p, seed=1, n_candidates=16, lam=0.2,
                        objective="greedy")
        wb = _work_at_recall(bal, db, q, gt)
        wq = _work_at_recall(ql, db, q, gt)
        wg = _work_at_recall(gr, db, q, gt)
        if not (wb and wq and wg):
            continue
        row = dict(
            unbalance=round(u, 3),
            e_depth_bal=round(bal.expected_depth(p), 2),
            e_depth_qlbt=round(ql.expected_depth(p), 2),
            e_depth_greedy=round(gr.expected_depth(p), 2),
            mean_gain_qlbt=round(1 - wq["mean"] / wb["mean"], 3),
            p90_gain_qlbt=round(1 - wq["p90"] / wb["p90"], 3),
            mean_gain_greedy=round(1 - wg["mean"] / wb["mean"], 3),
            wall_us_bal=round(wb["wall_us"], 1),
            wall_us_qlbt=round(wq["wall_us"], 1),
        )
        rows.append(row)
        csv_row(
            f"fig1_qlbt_u{row['unbalance']}", row["wall_us_qlbt"],
            f"mean_gain={row['mean_gain_qlbt']};"
            f"p90_gain={row['p90_gain_qlbt']};"
            f"greedy_gain={row['mean_gain_greedy']};"
            f"ED={row['e_depth_bal']}->{row['e_depth_qlbt']}",
        )
    return rows


if __name__ == "__main__":
    run()
