"""Shard-count scaling of the distributed search subsystem.

Runs the sharded brute / IVF / forest backends at 1, 2, 4 and 8 shards
(fake CPU devices, one subprocess per shard count so XLA_FLAGS takes
effect) and records us/query-batch per backend.  Per-shard work shrinks
with the shard count while the merge stays O(shards * B * k), so the curve
exposes the collective overhead the roofline predicts.  On fake devices
the absolute numbers measure dispatch+merge structure, not real speedup —
the shape of the curve is the deliverable.

Each kind also runs ``fused=False`` (the pre-kernel jnp locals) next to
the fused default, plus the int8-footprint brute variant, so the
fused-vs-unfused claim in ``benchmarks/roofline.py`` (ann-scan rows) has
a measured counterpart in the same BENCH_fig4_sharded.json.

Rows land in ``benchmarks/results/sharded_scaling.csv`` and on stdout via
``common.csv_row``.
"""
from __future__ import annotations

import os
import subprocess
import sys

from benchmarks.common import RESULTS, csv_row

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CHILD = """
import os, sys, time
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=" + sys.argv[1])
import warnings; warnings.filterwarnings("ignore")
import jax, numpy as np
from repro.core.two_level import TwoLevelConfig, build_two_level
from repro.distributed.backend import ShardedSearchBackend

S = int(sys.argv[1]); n = int(sys.argv[2]); nq = int(sys.argv[3])
mesh = jax.make_mesh((S,), ("data",))
rng = np.random.default_rng(0)
c = rng.normal(size=(32, 32)) * 4
db = (c[rng.integers(0, 32, n)] + rng.normal(size=(n, 32))).astype(np.float32)
q = (db[:nq] + rng.normal(size=(nq, 32)) * 0.05).astype(np.float32)
idx_b = build_two_level(db, TwoLevelConfig(
    n_clusters=64, top="brute", bottom="brute", kmeans_iters=4))
idx_f = build_two_level(db, TwoLevelConfig(
    n_clusters=64, top="brute", bottom="tree", kmeans_iters=4, tree_leaf=8))
cases = (("brute", db, {}),
         ("brute_unfused", db, {"fused": False}),
         ("brute_int8", db, {"precision": "int8"}),
         ("ivf", idx_b, {}),
         ("ivf_unfused", idx_b, {"fused": False}),
         ("forest", idx_f, {}),
         ("forest_unfused", idx_f, {"fused": False}))
for name, target, extra in cases:
    kind = name.split("_")[0]
    fn = ShardedSearchBackend(mesh, target, kind=kind, k=10,
                              axes=("data",), nprobe_local=4, **extra)
    fn(q)                                   # warm the jit cache
    ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        fn(q)
        ts.append(time.perf_counter() - t0)
    print(name, sorted(ts)[len(ts) // 2] * 1e6)
"""


def run(shards=(1, 2, 4, 8), n=20000, nq=64) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    rows = []
    for s in shards:
        r = subprocess.run(
            [sys.executable, "-c", _CHILD, str(s), str(n), str(nq)],
            capture_output=True, text=True, timeout=1200, env=env,
            cwd=_REPO,
        )
        if r.returncode != 0:
            print(f"sharded s={s}: FAILED\n{r.stderr[-2000:]}",
                  file=sys.stderr)
            continue
        for line in r.stdout.strip().splitlines():
            kind, us = line.split()
            rows.append((s, kind, float(us)))
            csv_row(f"sharded_{kind}_s{s}", float(us),
                    f"shards={s},n={n},B={nq}")
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "sharded_scaling.csv"), "w") as f:
        f.write("shards,kind,us_per_batch\n")
        for s, kind, us in rows:
            f.write(f"{s},{kind},{us:.1f}\n")


if __name__ == "__main__":
    run()
