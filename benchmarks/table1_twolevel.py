"""Paper Table 1 + Fig. 2(b,c): one-level vs two-level on SIFT-scale data.

Sweeps split counts 2^s and bottom algorithms {tree, lsh, brute} with a PQ
top level, against one-level tree and LSH baselines, on a synthetic
SIFT-analog corpus (DESIGN.md §8).  Reports recall@10 at a matched
wall-clock budget (the budget = P90 time of the paper-optimal config,
analogous to the paper's 80 ms on t3.xlarge) plus the full recall/latency
frontier.

Paper claims validated here: (1) neither one-level method reaches the
recall target at budget; (2) two-level dominates; (3) brute is the best
bottom level; (4) the optimum sits near ~100 entities per bucket.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import cached_corpus, csv_row, ground_truth
from repro.core.metrics import recall_at_k
from repro.core.tree import build_rp_tree, tree_search
from repro.core.lsh import lsh_build, lsh_search
from repro.core.two_level import TwoLevelConfig, build_two_level

import jax.numpy as jnp


def _one_level_tree(db, q, gt, budget_s):
    t = build_rp_tree(db, leaf_size=8, n_candidates=4, seed=0)
    dbj, qj = jnp.asarray(db), jnp.asarray(q)
    best = 0.0
    for w in (4, 16, 64, 256):
        t0 = time.perf_counter()
        res = tree_search(t.device_arrays(), dbj, qj, beam_width=w, k=10,
                          max_steps=t.max_depth + 4)
        np.asarray(res.ids)
        dt = (time.perf_counter() - t0) / q.shape[0]
        r = recall_at_k(np.asarray(res.ids), gt)
        if dt <= budget_s:
            best = max(best, r)
    return best


def _one_level_lsh(db, q, gt, budget_s):
    idx = lsh_build(db, n_bits=96, seed=0)
    best = 0.0
    for cand in (64, 256, 1024):
        t0 = time.perf_counter()
        _, ids = lsh_search(idx, db, q, 10, n_candidates=cand)
        dt = (time.perf_counter() - t0) / q.shape[0]
        r = recall_at_k(ids, gt)
        if dt <= budget_s:
            best = max(best, r)
    return best


def _two_level(db, q, gt, n_clusters, bottom, budget_s, nprobes):
    cfg = TwoLevelConfig(
        n_clusters=n_clusters, top="pq", bottom=bottom,
        kmeans_iters=6, kmeans_minibatch=min(131072, db.shape[0]),
        tree_candidates=2,
    )
    idx = build_two_level(db, cfg)
    out = []
    for nprobe in nprobes:
        # warm then measure
        idx.search(q[:32], 10, nprobe=nprobe)
        t0 = time.perf_counter()
        _, ids, work = idx.search(q, 10, nprobe=nprobe, beam_width=8)
        dt = (time.perf_counter() - t0) / q.shape[0]
        out.append((recall_at_k(ids, gt), dt, work))
    within = [r for r, dt, _ in out if dt <= budget_s]
    return (max(within) if within else 0.0), out


def run(scale: float = 0.2, n_queries: int = 512, seed: int = 0):
    """Default tier: 200K x 128 (=0.2 x SIFT); --full uses scale=1.0."""
    from benchmarks.common import heldout_split

    db, q = heldout_split(cached_corpus("sift", scale, seed), n_queries)
    gt_d, gt_i = ground_truth(db, q, 10, tag=f"sift_ho_{scale}_{seed}")
    n = db.shape[0]

    # paper-optimal config defines the latency budget (~100/bucket)
    s_opt = int(round(np.log2(n / 100)))
    _, curve = _two_level(db, q, gt_i, 1 << s_opt, "brute", np.inf,
                          (8, 16, 32))
    # budget = time of the config that first reaches recall 0.9
    budget = max(dt for r, dt, _ in curve if r >= max(
        0.8, min(r for r, _, _ in curve)))
    rows = []
    r_tree = _one_level_tree(db, q, gt_i, budget)
    r_lsh = _one_level_lsh(db, q, gt_i, budget)
    rows.append(("one-level/tree", r_tree))
    rows.append(("one-level/lsh", r_lsh))
    csv_row("table1_onelevel_tree", budget * 1e6, f"recall={r_tree:.3f}")
    csv_row("table1_onelevel_lsh", budget * 1e6, f"recall={r_lsh:.3f}")

    best = {}
    for s in (s_opt - 2, s_opt - 1, s_opt, s_opt + 1):
        for bottom in ("tree", "lsh", "brute"):
            r, _ = _two_level(db, q, gt_i, 1 << s, bottom, budget,
                              (4, 8, 16, 32))
            name = f"PQ-2^{s}/{bottom}"
            rows.append((name, r))
            best[name] = r
            csv_row(f"table1_{name.replace('/', '_')}", budget * 1e6,
                    f"recall={r:.3f};avg_bucket={n / (1 << s):.0f}")
    return {"budget_s": budget, "rows": rows}


if __name__ == "__main__":
    run()
