"""Fig. 8 (beyond paper): fleet serving under drifting-Zipf load.

A "million-user day" compressed to a benchmark: N serving cells
(`repro.serve.fleet.CellRouter`) on logically-separate meshes serve
high-QPS Zipf traffic from concurrent client threads, and the three
things that happen to a real fleet happen mid-run:

  * **steady**   — head-skewed traffic against a healthy fleet
    (cache-affinity routing keeps each cell's TinyLFU head coherent);
  * **maint**    — the query head rotates, the index takes a clustered
    mutation, and the leader fans ONE popped `DeltaManifest` out to
    every cell with a rolling drain (`router.apply_updates`) while
    clients keep hammering — the acceptance bar is p99 within 2x of
    steady-state;
  * **fail**     — one cell's backend starts throwing mid-window; every
    in-flight and future request must complete via fail-fast rerouting
    (the bar is ZERO lost requests), with rendezvous hashing remapping
    only the dead cell's keys.

Clients retry shed requests (`FleetOverloadError.retriable`) with a tiny
backoff — shedding is back-pressure, not loss — and every row records the
routing counters (`shed`/`rerouted`/`hedge_cell`) next to the p99s so a
tail move is attributable.  Rows land in ``BENCH_fig8.json`` via
``benchmarks/run.py`` and ``benchmarks/results/fleet.csv``.

The run installs a fresh :class:`repro.obs.Tracer` so every request is
traced end to end; the Chrome-trace export lands in
``benchmarks/results/fig8_trace.json`` (open in Perfetto / about:tracing)
and covers routed, hedged, rerouted, and cancelled requests plus the
mid-window maintenance fan-out.  Per-stage medians
(queue/batch/dispatch/kernel) from the registry-backed stage histograms
go into each row so ``BENCH_fig8.json`` says where the latency lives.
"""
from __future__ import annotations

import os
import threading
import time

import numpy as np

from benchmarks.common import RESULTS, clustered_corpus, csv_row, lat_summary
from repro.obs import Tracer, set_tracer


class _Failable:
    """Backend proxy with an injectable failure switch: once ``fail()``
    is called every search raises, exactly like a wedged mesh — the
    cell's worker turns that into ``CellFailure`` sentinels and the
    router reroutes."""

    def __init__(self, fn):
        self._fn = fn
        self._dead = threading.Event()

    def fail(self):
        self._dead.set()

    def __call__(self, qs):
        if self._dead.is_set():
            raise RuntimeError("injected cell failure (fig8)")
        return self._fn(qs)

    def apply_updates(self, *a, **kw):
        return self._fn.apply_updates(*a, **kw)

    def jit_cache_size(self):
        return self._fn.jit_cache_size()

    @property
    def metrics(self):
        # expose the wrapped backend's registry so the cell's stage
        # breakdown still sees kernel/rerank histograms through the proxy
        return self._fn.metrics


def _zipf_qids(rng, n, alpha, size):
    from repro.core.likelihood import zipf_likelihood

    z = zipf_likelihood(n, alpha)
    perm = rng.permutation(n)
    p = np.empty(n)
    p[perm] = z
    return rng.choice(n, size=size, p=p / p.sum())


def _drive(router, db, qid_chunks, *, mid_action=None, mid_delay_s=0.15,
           timeout_s=15.0, max_retries=200):
    """Run one traffic segment: each chunk of query ids gets a client
    thread; ``mid_action`` fires on the main thread mid-window (the
    leader fan-out / the cell failure).  Returns merged per-request
    latencies (including shed-retry backoff — the client-observed
    truth), plus lost/retry counts."""
    results = [None] * len(qid_chunks)

    def client(slot, qids):
        lat, lost, retries = [], 0, 0
        for qid in qids:
            q = db[int(qid)]
            t0 = time.perf_counter()
            for _ in range(max_retries):
                try:
                    router.search(q, timeout=timeout_s)
                    lat.append(time.perf_counter() - t0)
                    break
                except Exception as e:
                    if getattr(e, "retriable", False):
                        retries += 1
                        time.sleep(1e-3)
                        continue
                    lost += 1
                    break
            else:
                lost += 1
        results[slot] = (lat, lost, retries)

    threads = [threading.Thread(target=client, args=(i, c), daemon=True)
               for i, c in enumerate(qid_chunks)]
    t_start = time.perf_counter()
    for t in threads:
        t.start()
    if mid_action is not None:
        time.sleep(mid_delay_s)
        mid_action()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t_start
    lat = [x for r in results for x in r[0]]
    lost = sum(r[1] for r in results)
    retries = sum(r[2] for r in results)
    return lat, lost, retries, wall


def run(n: int = 8192, d: int = 64, fleet_sizes=(2, 4, 8),
        clients: int = 8, reqs_per_client: int = 120,
        zipf_alpha: float = 1.1, k: int = 10, seed: int = 0) -> list:
    from repro.adaptive import FrequencyAdmissionCache
    from repro.core.two_level import TwoLevelConfig, build_two_level
    from repro.distributed.backend import ShardedSearchBackend
    from repro.launch.mesh import make_cell_meshes
    from repro.serve.cell import ServingCell
    from repro.serve.fleet import CellRouter

    rng = np.random.default_rng(seed)
    db = clustered_corpus(rng, n, d)
    n_clusters = 64
    idx = build_two_level(db, TwoLevelConfig(
        n_clusters=n_clusters, top="brute", bottom="brute",
        kmeans_iters=4, kmeans_minibatch=None, bucket_cap=None))

    # fresh tracer for the run: the exported Chrome-trace covers routed,
    # hedged, rerouted, and cancelled requests plus the leader fan-out
    tracer = Tracer(capacity=65536)
    prev_tracer = set_tracer(tracer)

    rows = []
    try:
        for size in fleet_sizes:
            meshes = make_cell_meshes(size, share_devices=True)
            proxies, cells = [], []
            for i, mesh in enumerate(meshes):
                be = ShardedSearchBackend(
                    mesh, idx, kind="ivf", k=k, axes=tuple(mesh.axis_names),
                    nprobe_local=8, headroom=1.5)
                proxy = _Failable(be)
                proxies.append(proxy)
                cells.append(ServingCell(
                    proxy, name=f"cell{i}",
                    cache=FrequencyAdmissionCache(capacity=512),
                    max_wait_ms=0.5))
            router = CellRouter(cells, max_queue_depth=64, hedge_ms=75.0)
            try:
                # warm every pow2 batch bucket concurrent clients can form
                # (1..clients) on every cell, off the clock — otherwise the
                # steady window measures XLA compiles, not serving
                bb = 1
                while bb <= clients:
                    for c in cells:
                        c.search_fn(db[:bb])
                    bb <<= 1

                def chunks(alpha_rng):
                    qids = _zipf_qids(alpha_rng, idx.db.shape[0], zipf_alpha,
                                      clients * reqs_per_client)
                    return np.array_split(qids, clients)

                # -- steady state --------------------------------------
                lat_s, lost_s, retr_s, wall_s = _drive(
                    router, idx.db, chunks(np.random.default_rng(seed + 1)))

                # -- rolling maintenance -------------------------------
                # the head rotates AND the corpus mutates (delete part of
                # the fullest bucket, add mass near another centroid);
                # mid-window the leader pops one manifest and rolls it
                # across the fleet while clients keep hammering
                b = int(np.argmax(idx.bucket_counts))
                idx.delete_entities(np.asarray(idx.bucket_ids[b][:16]).copy())
                new = (np.asarray(idx.centroids[1])[None, :]
                       + 0.1 * rng.normal(size=(16, d))).astype(np.float32)
                idx.add_entities(new)
                fan = {}

                def leader_fanout():
                    fan.update(router.apply_updates(idx))

                lat_m, lost_m, retr_m, wall_m = _drive(
                    router, idx.db, chunks(np.random.default_rng(seed + 2)),
                    mid_action=leader_fanout)

                # -- single-cell failure mid-run -----------------------
                lat_f, lost_f, retr_f, wall_f = _drive(
                    router, idx.db, chunks(np.random.default_rng(seed + 3)),
                    mid_action=proxies[0].fail)

                st = router.stats()
                s_steady = lat_summary(lat_s)
                s_maint = lat_summary(lat_m)
                s_fail = lat_summary(lat_f, stats=st)
                total = 3 * clients * reqs_per_client
                ratio = (s_maint["p99_ms"] / s_steady["p99_ms"]
                         if s_steady["p99_ms"] else float("inf"))
                row = {
                    "cells": size,
                    "requests": total,
                    "qps_steady": round(len(lat_s) / wall_s, 1),
                    "p99_steady_ms": round(s_steady["p99_ms"], 3),
                    "p99_maint_ms": round(s_maint["p99_ms"], 3),
                    "p99_fail_ms": round(s_fail["p99_ms"], 3),
                    "p50_steady_ms": round(s_steady["p50_ms"], 3),
                    "maint_over_steady": round(ratio, 3),
                    "fanout_mode": fan.get("mode"),
                    "fanout_bytes": fan.get("bytes"),
                    "lost": lost_s + lost_m + lost_f,
                    "shed_retries": retr_s + retr_m + retr_f,
                    "shed": int(st.shed),
                    "rerouted": int(st.rerouted),
                    "hedge_cell": int(st.hedge_cell),
                    "cache_hit_rate": round(
                        st.cache_hits / max(st.cache_hits + st.cache_misses, 1),
                        3),
                    "down_cells": sorted(router.down_cells()),
                }
                # per-stage medians from the registry-backed histograms:
                # where a request's time went (queue wait vs batch close
                # vs dispatch vs device kernel), not just that it went
                for stage in ("queue", "batch", "dispatch", "kernel"):
                    s = (st.stages or {}).get(stage)
                    if s and s.get("n"):
                        row[f"{stage}_p50_ms"] = round(
                            float(s["p50_ms"]), 3)
                rows.append(row)
                csv_row(
                    f"fig8_cells{size}", s_steady["p50_ms"] * 1e3,
                    f"qps={row['qps_steady']},"
                    f"p99_steady={row['p99_steady_ms']:.2f},"
                    f"p99_maint={row['p99_maint_ms']:.2f},"
                    f"p99_fail={row['p99_fail_ms']:.2f},"
                    f"maint_over_steady={row['maint_over_steady']:.2f},"
                    f"lost={row['lost']},shed={row['shed']},"
                    f"rerouted={row['rerouted']},"
                    f"hedge_cell={row['hedge_cell']}")
                # the fleet contract is loss-free failure — this is the
                # acceptance criterion, not a soft metric
                assert row["lost"] == 0, \
                    f"{row['lost']} requests lost at fleet size {size}"
                if ratio > 2.0:
                    print(f"# WARN fig8: maint p99 {ratio:.2f}x steady at "
                          f"{size} cells (bar: 2x)")
            finally:
                router.close()
    finally:
        set_tracer(prev_tracer)

    os.makedirs(RESULTS, exist_ok=True)
    trace_path = os.path.join(RESULTS, "fig8_trace.json")
    tracer.export(trace_path)
    print(f"# fig8: {len(tracer.events())} trace events "
          f"({tracer.n_dropped} dropped) -> {trace_path}")
    with open(os.path.join(RESULTS, "fleet.csv"), "w") as f:
        cols = sorted(set().union(*rows))
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(str(r.get(c, "")) for c in cols) + "\n")
    return rows


if __name__ == "__main__":
    run()
