"""kmeans / PQ / LSH / brute / two-level / protocol invariants."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from proptest import sweep
from repro.core.brute import brute_search, l2_topk_exact
from repro.core.graph_build import radius_graph
from repro.core.index import auto_build_index, build_index
from repro.core.kmeans import kmeans_assign, kmeans_fit
from repro.core.lsh import hamming_scores, lsh_build, lsh_search, pack_bits
from repro.core.metrics import recall_at_k
from repro.core.pq import adc_lut, adc_scores, pq_search, pq_train
from repro.core.protocol import select_index_spec
from repro.core.two_level import TwoLevelConfig, build_two_level


def _clustered(rng, n, d, k=16):
    c = rng.normal(size=(k, d)) * 4
    x = c[rng.integers(0, k, n)] + rng.normal(size=(n, d))
    return x.astype(np.float32)


@sweep(n_cases=4, base_seed=40)
def test_kmeans_inertia_decreases(case):
    x = _clustered(case.rng, case.int_(200, 1500), case.int_(4, 32))
    k = case.int_(4, 32)
    r1 = kmeans_fit(x, k, iters=1, seed=case.seed)
    r5 = kmeans_fit(x, k, iters=8, seed=case.seed)
    assert r5.inertia <= r1.inertia * 1.001
    a, _ = kmeans_assign(x, r5.centroids)
    assert (a == r5.assignments).all()
    assert a.min() >= 0 and a.max() < k


def test_brute_matches_numpy():
    rng = np.random.default_rng(0)
    q = rng.normal(size=(10, 24)).astype(np.float32)
    x = rng.normal(size=(500, 24)).astype(np.float32)
    d, i = brute_search(q, x, 7)
    d2 = ((q[:, None] - x[None]) ** 2).sum(-1)
    i_true = np.argsort(d2, axis=1)[:, :7]
    assert (i == i_true).mean() > 0.99
    np.testing.assert_allclose(d, np.take_along_axis(d2, i_true, 1),
                               rtol=1e-4, atol=1e-4)


def test_brute_chunking_invariant():
    rng = np.random.default_rng(1)
    q = rng.normal(size=(5, 16)).astype(np.float32)
    x = rng.normal(size=(333, 16)).astype(np.float32)
    d1, i1 = l2_topk_exact(jnp.asarray(q), jnp.asarray(x), 5, chunk=64)
    d2, i2 = l2_topk_exact(jnp.asarray(q), jnp.asarray(x), 5, chunk=333)
    assert (np.asarray(i1) == np.asarray(i2)).all()


def test_pq_adc_is_exact_for_codebook_points():
    """ADC distance == true distance when vectors are exactly codewords."""
    rng = np.random.default_rng(2)
    x = _clustered(rng, 400, 32, k=8)
    pq = pq_train(x, m=4, n_codes=16, seed=0)
    # reconstruct from codes -> ADC to the reconstruction must be exact
    recon = np.concatenate(
        [pq.codebooks[j][pq.codes[:, j]] for j in range(pq.m)], axis=1
    )
    q = recon[:5]
    lut = adc_lut(jnp.asarray(q), jnp.asarray(pq.codebooks))
    s = np.asarray(adc_scores(lut, jnp.asarray(pq.codes)))
    true = ((q[:, None] - recon[None]) ** 2).sum(-1)
    np.testing.assert_allclose(s, true, rtol=1e-3, atol=1e-3)


def test_pq_search_recall_on_clustered():
    rng = np.random.default_rng(3)
    x = _clustered(rng, 2000, 32)
    pq = pq_train(x, m=8, seed=0)
    q = x[:32] + rng.normal(size=(32, 32)).astype(np.float32) * 0.01
    _, i_true = brute_search(q, x, 10)
    _, i_pq = pq_search(pq, q, 10)
    assert recall_at_k(i_pq, i_true) > 0.5   # coarse but must beat chance


def test_pack_bits_roundtrip():
    rng = np.random.default_rng(4)
    bits = rng.integers(0, 2, size=(13, 70)).astype(np.uint8)
    packed = pack_bits(bits)
    assert packed.shape == (13, 3)
    # hamming distance from packed == direct bit diff
    h = np.asarray(hamming_scores(jnp.asarray(packed), jnp.asarray(packed)))
    direct = (bits[:, None, :] != bits[None, :, :]).sum(-1)
    assert (h == direct).all()


def test_lsh_better_than_random():
    rng = np.random.default_rng(5)
    x = _clustered(rng, 3000, 64)
    idx = lsh_build(x, n_bits=128, seed=0)
    q = x[:64] + rng.normal(size=(64, 64)).astype(np.float32) * 0.01
    _, i_true = brute_search(q, x, 10)
    _, i_lsh = lsh_search(idx, x, q, 10, n_candidates=256)
    assert recall_at_k(i_lsh, i_true) > 0.6


@pytest.mark.parametrize("top,bottom", [
    ("brute", "brute"), ("pq", "brute"), ("pq", "lsh"),
    ("pq", "tree"), ("kdtree", "brute"),
])
def test_two_level_recall(top, bottom):
    rng = np.random.default_rng(6)
    x = _clustered(rng, 4000, 32, k=64)
    feats = x[:, :3] if top == "kdtree" else None
    cfg = TwoLevelConfig(n_clusters=64, top=top, bottom=bottom,
                         kmeans_iters=5, kmeans_minibatch=None)
    idx = build_two_level(x, cfg, partition_features=feats)
    q = x[:128] + rng.normal(size=(128, 32)).astype(np.float32) * 0.02
    kw = {}
    if top == "kdtree":
        kw["query_partition_features"] = q[:, :3]
    d, i, work = idx.search(q, 10, nprobe=16, beam_width=16, **kw)
    _, i_true = brute_search(q, x, 10)
    r = recall_at_k(i, i_true)
    floor = 0.85 if bottom == "brute" else 0.45
    assert r > floor, f"{top}/{bottom} recall {r}"
    # all entities indexed exactly once across buckets
    ids = idx.bucket_ids[idx.bucket_ids >= 0]
    assert sorted(ids.tolist()) == list(range(4000))


def test_two_level_more_probes_monotone():
    rng = np.random.default_rng(7)
    x = _clustered(rng, 3000, 16, k=32)
    idx = build_two_level(x, TwoLevelConfig(n_clusters=64, top="brute",
                                            bottom="brute", kmeans_iters=4))
    q = x[:64] + rng.normal(size=(64, 16)).astype(np.float32) * 0.05
    _, i_true = brute_search(q, x, 10)
    rs = []
    for nprobe in (1, 4, 16, 64):
        _, i, _ = idx.search(q, 10, nprobe=nprobe)
        rs.append(recall_at_k(i, i_true))
    assert all(b >= a - 0.02 for a, b in zip(rs, rs[1:])), rs
    assert rs[-1] > 0.95


def test_protocol_matches_paper_rules():
    s = select_index_spec(10_000, traffic_available=True)
    assert s.kind == "qlbt"
    s = select_index_spec(10_000, traffic_available=False)
    assert s.kind == "tree"
    s = select_index_spec(1_000_000, embedding_dim=128)
    assert s.kind == "two_level" and s.two_level.top == "pq" \
        and s.two_level.bottom == "brute"
    # ~100 entities per bucket (paper §5.2 optimum)
    avg = 1_000_000 / s.two_level.n_clusters
    assert 50 <= avg <= 200
    s = select_index_spec(1_000_000, partition_dim=2)
    assert s.two_level.top == "kdtree"


def test_auto_build_end_to_end():
    rng = np.random.default_rng(8)
    x = _clustered(rng, 2000, 24)
    p = rng.dirichlet(np.full(2000, 0.5))
    idx = auto_build_index(x, p=p)
    assert idx.spec.kind == "qlbt"
    q = x[:32] + rng.normal(size=(32, 24)).astype(np.float32) * 0.01
    d, i, work = idx.search(q, 10, beam_width=16)
    _, i_true = brute_search(q, x, 10)
    assert recall_at_k(i, i_true) > 0.8
    assert idx.footprint_bytes() > 0


def test_radius_graph_two_level_matches_brute():
    rng = np.random.default_rng(9)
    pos = rng.normal(size=(500, 3)).astype(np.float32) * 3
    s1, d1 = radius_graph(pos, 1.5, method="brute")
    s2, d2 = radius_graph(pos, 1.5, method="two_level", n_buckets=16,
                          nprobe=8)
    e1 = set(zip(s1.tolist(), d1.tolist()))
    e2 = set(zip(s2.tolist(), d2.tolist()))
    assert len(e2 & e1) / max(len(e1), 1) > 0.95


def test_rerank_dedupes_duplicate_candidates():
    """One entity must hold at most one top-k slot even when overlapping
    probes surface it several times (satellite of the forest dedupe fix)."""
    from repro.core.two_level import _rerank

    rng = np.random.default_rng(11)
    db = rng.normal(size=(50, 8)).astype(np.float32)
    q = rng.normal(size=(3, 8)).astype(np.float32)
    # heavy duplication + pads; unique real candidates: {1, 3, 5, 7}
    row = np.array([3, 3, 7, 1, 3, -1, 7, 5, -1, 3], np.int32)
    cand = np.tile(row, (3, 1))
    d, i = _rerank(jnp.asarray(db), jnp.asarray(q), jnp.asarray(cand), 6)
    d, i = np.asarray(d), np.asarray(i)
    uniq = np.array([1, 3, 5, 7])
    d_true, i_true = brute_search(q, db[uniq], 4)
    for b in range(3):
        real = i[b][i[b] >= 0]
        assert len(set(real.tolist())) == len(real) == 4   # unique, all 4
        assert np.array_equal(uniq[i_true[b]], real)       # right order
        assert np.allclose(d[b, :4], d_true[b], atol=1e-5)
        assert (i[b, 4:] == -1).all() and np.isinf(d[b, 4:]).all()


def test_add_entities_grows_bucket_pad_on_overflow():
    """Incremental insert past total pad capacity must grow the pad width
    and keep every entity indexed exactly once."""
    rng = np.random.default_rng(12)
    db = _clustered(rng, 40, 8, k=2)
    cap = 30
    idx = build_two_level(db, TwoLevelConfig(
        n_clusters=2, top="brute", bottom="brute", kmeans_iters=4,
        bucket_cap=cap))
    assert idx.bucket_ids.shape[1] == cap
    new = _clustered(rng, 25, 8, k=2)          # 65 > 2 * 30 total capacity
    ids = idx.add_entities(new)
    assert idx.bucket_ids.shape[1] > cap       # pad width grew
    flat = idx.bucket_ids[idx.bucket_ids >= 0]
    assert sorted(flat.tolist()) == list(range(65))   # each exactly once
    assert np.array_equal(ids, np.arange(40, 65))
    assert np.array_equal(
        idx.bucket_counts,
        np.array([(idx.bucket_ids[b] >= 0).sum() for b in range(2)]))
    d, i, _ = idx.search(new, 1, nprobe=2)
    assert (i[:, 0] >= 40).mean() > 0.9        # new points are findable
