"""Observability tier tests: metrics registry correctness (bucket
boundaries, quantile error bounds, thread safety, exposition round-trip),
tracer semantics (nesting, cross-thread spans, bounded ring), the
serving-stack integration (bounded telemetry after >10k requests, outcome
span coverage for routed/hedged/rerouted/cancelled requests), and the
measured-overhead bound the docs quote."""
import json
import math
import threading
import time

import numpy as np
import pytest

from repro.obs import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Tracer,
    backend_cost,
    merge_snapshots,
    parse_exposition,
    set_tracer,
)


# ---------------------------------------------------------------------------
# histogram: bucket boundaries and quantile error bounds
# ---------------------------------------------------------------------------


class TestHistogram:
    def test_bucket_boundary_le_semantics(self):
        """Prometheus `le` semantics: a value exactly on an edge lands in
        the bucket whose upper bound IS that edge, not the next one."""
        h = Histogram("t", lo=1.0, hi=1000.0, per_decade=1)
        # edges: [1, 10, 100, 1000] (+overflow)
        for v in (1.0, 10.0, 100.0, 1000.0):
            h.observe(v)
        counts = {float(h.edges[i]): int(c)
                  for i, c in enumerate(h._counts[:-1]) if c}
        assert counts == {1.0: 1, 10.0: 1, 100.0: 1, 1000.0: 1}
        assert int(h._counts[-1]) == 0, "an edge value leaked to overflow"
        h.observe(1000.0001)
        assert int(h._counts[-1]) == 1, "v > hi must land in overflow"
        h.observe(0.5)          # v <= lo clamps into bucket 0
        assert int(h._counts[0]) == 2

    def test_quantiles_match_exact_within_bucket_ratio(self):
        """Approximate quantiles vs numpy's exact ones: the log-bucket
        design guarantees relative error bounded by one bucket ratio
        (10^(1/20) - 1 ~ 12% at per_decade=20) across the range."""
        rng = np.random.default_rng(0)
        xs = np.exp(rng.normal(loc=1.0, scale=1.2, size=20000))  # ms-ish
        h = Histogram("lat")
        for v in xs:
            h.observe(float(v))
        bucket_ratio = 10 ** (1 / 20)
        for q in (0.01, 0.1, 0.5, 0.9, 0.99, 0.999):
            exact = float(np.quantile(xs, q))
            approx = h.quantile(q)
            assert exact / bucket_ratio <= approx <= exact * bucket_ratio, \
                f"q={q}: approx {approx} vs exact {exact}"
        # exact ride-alongs are exact, not approximated
        assert h.count == len(xs)
        assert h.sum == pytest.approx(float(xs.sum()), rel=1e-9)
        assert h.mean() == pytest.approx(float(xs.mean()), rel=1e-9)

    def test_quantile_clamps_to_observed_range(self):
        h = Histogram("t")
        h.observe(7.0)
        assert h.quantile(0.0) == 7.0
        assert h.quantile(1.0) == 7.0
        assert Histogram("empty").quantile(0.5) == 0.0

    def test_nonfinite_observations_dropped(self):
        h = Histogram("t")
        h.observe(float("nan"))
        h.observe(float("inf"))
        h.observe(2.0)
        assert h.count == 1 and h.n_dropped == 2
        assert math.isfinite(h.sum)

    def test_footprint_invariant_under_observations(self):
        h = Histogram("t")
        before = h.footprint_bytes()
        for v in np.geomspace(1e-4, 1e6, 5000):
            h.observe(float(v))
        assert h.footprint_bytes() == before

    def test_merged_sums_counts_and_bounds(self):
        a, b = Histogram("a"), Histogram("b")
        for v in (1.0, 2.0, 3.0):
            a.observe(v)
        for v in (100.0, 200.0):
            b.observe(v)
        m = Histogram.merged("m", [a, b])
        assert m.count == 5
        assert m.sum == pytest.approx(306.0)
        assert m.quantile(0.0) == 1.0 and m.quantile(1.0) == 200.0
        with pytest.raises(ValueError, match="bucket layout"):
            Histogram.merged("x", [a, Histogram("c", lo=1.0, hi=10.0)])


# ---------------------------------------------------------------------------
# thread safety: concurrent writers, no lost updates
# ---------------------------------------------------------------------------


class TestConcurrency:
    N_THREADS = 8
    PER_THREAD = 2000

    def _hammer(self, fn):
        errs = []

        def worker():
            try:
                for i in range(self.PER_THREAD):
                    fn(i)
            except Exception as e:                 # pragma: no cover
                errs.append(e)

        ts = [threading.Thread(target=worker)
              for _ in range(self.N_THREADS)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert errs == []

    def test_counter_no_lost_increments(self):
        c = Counter("hits")
        self._hammer(lambda i: c.inc())
        assert c.value == self.N_THREADS * self.PER_THREAD

    def test_histogram_no_lost_observations(self):
        h = Histogram("lat")
        self._hammer(lambda i: h.observe(1.0 + (i % 7)))
        total = self.N_THREADS * self.PER_THREAD
        assert h.count == total
        assert int(h._counts.sum()) == total

    def test_registry_get_or_create_races_to_one_instance(self):
        reg = MetricsRegistry()
        seen = []

        def worker():
            seen.append(reg.counter("shared"))

        ts = [threading.Thread(target=worker) for _ in range(8)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert all(c is seen[0] for c in seen)

    def test_registry_kind_clash_raises(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(TypeError, match="already registered"):
            reg.histogram("x")


# ---------------------------------------------------------------------------
# exposition round-trip + snapshot merging
# ---------------------------------------------------------------------------


class TestExposition:
    def _populated(self):
        reg = MetricsRegistry()
        reg.counter("requests").inc(42)
        reg.gauge("drift").set(0.125)
        h = reg.histogram("latency_ms")
        for v in (0.5, 2.0, 2.0, 40.0, 900.0):
            h.observe(v)
        return reg

    def test_round_trip(self):
        reg = self._populated()
        back = parse_exposition(reg.exposition(prefix="cell0."))
        assert back["cell0_requests"] == {"type": "counter", "value": 42}
        assert back["cell0_drift"] == {"type": "gauge", "value": 0.125}
        hist = back["cell0_latency_ms"]
        assert hist["type"] == "histogram"
        assert hist["count"] == 5
        assert hist["sum"] == pytest.approx(944.5)
        # cumulative le buckets: monotone, ending at the total count
        cums = [hist["buckets"][k] for k in hist["buckets"]]
        assert cums == sorted(cums) and cums[-1] == 5
        assert "+Inf" in hist["buckets"]

    def test_snapshot_is_json_safe_and_merged(self):
        a, b = self._populated(), MetricsRegistry()
        b.counter("requests").inc(1)
        snap = merge_snapshots({"cell0.": a, "cell1.": b})
        json.dumps(snap)                    # must not raise
        assert snap["cell0.requests"]["value"] == 42
        assert snap["cell1.requests"]["value"] == 1
        assert snap["cell0.latency_ms"]["count"] == 5
        assert snap["cell0.latency_ms"]["p50"] > 0


# ---------------------------------------------------------------------------
# tracer: nesting, ordering, cross-thread spans, bounded ring
# ---------------------------------------------------------------------------


class TestTracer:
    def test_span_nesting_and_trace_id_inheritance(self):
        tr = Tracer(capacity=64)
        with tr.span("route", q=1) as outer:
            with tr.span("dispatch") as inner:
                assert inner.trace_id == outer.trace_id
                assert inner.parent_id == outer.span_id
        evs = tr.events()
        # children close (and emit) before parents
        assert [e["name"] for e in evs] == ["dispatch", "route"]
        d, r = evs
        assert d["args"]["parent"] == r["args"]["span_id"]
        assert d["args"]["trace_id"] == r["args"]["trace_id"]
        # child interval nested inside parent interval
        assert r["ts"] <= d["ts"]
        assert d["ts"] + d["dur"] <= r["ts"] + r["dur"] + 1e-3

    def test_exported_chrome_trace_shape(self, tmp_path):
        tr = Tracer(capacity=64)
        with tr.span("route"):
            tr.instant("hedge-fired", cell="cell0")
        p = tr.export(str(tmp_path / "trace.json"))
        doc = json.load(open(p))
        assert doc["displayTimeUnit"] == "ms"
        by_name = {e["name"]: e for e in doc["traceEvents"]}
        assert by_name["route"]["ph"] == "X"
        assert by_name["route"]["dur"] >= 0
        assert by_name["hedge-fired"]["ph"] == "i"
        assert by_name["hedge-fired"]["args"]["cell"] == "cell0"

    def test_cross_thread_record_span(self):
        """The queue-wait shape: started on the caller thread, recorded
        later by the worker thread under an explicit trace_id."""
        tr = Tracer(capacity=64)
        tid0 = tr.new_trace_id()
        t0 = time.perf_counter()
        done = threading.Event()

        def worker():
            tr.record_span("queue", t0, time.perf_counter(),
                           trace_id=tid0, cell="c0")
            done.set()

        threading.Thread(target=worker).start()
        assert done.wait(5.0)
        (ev,) = tr.events("queue")
        assert ev["args"]["trace_id"] == tid0
        assert ev["tid"] != threading.get_ident()

    def test_ring_is_bounded_and_counts_drops(self):
        tr = Tracer(capacity=16)
        for i in range(100):
            tr.instant("tick", i=i)
        assert len(tr.events()) == 16
        assert tr.n_dropped == 84
        # the ring keeps the newest events
        assert tr.events()[-1]["args"]["i"] == 99

    def test_exception_tags_span_and_reraises(self):
        tr = Tracer(capacity=16)
        with pytest.raises(ValueError):
            with tr.span("boom"):
                raise ValueError("x")
        (ev,) = tr.events("boom")
        assert ev["args"]["error"] == "ValueError"

    def test_disabled_tracer_records_nothing(self):
        tr = Tracer(capacity=16, enabled=False)
        with tr.span("route") as sp:
            sp.set(outcome="ok")            # null span absorbs set()
            tr.instant("tick")
        assert tr.events() == []


# ---------------------------------------------------------------------------
# serving-stack integration: bounded telemetry, outcome span coverage
# ---------------------------------------------------------------------------


def _ok_fn(qs):
    b = qs.shape[0]
    return (np.zeros((b, 3), np.float32),
            np.tile(np.arange(3), (b, 1)).astype(np.int64))


class TestServingIntegration:
    def test_bounded_telemetry_after_10k_requests(self):
        """The PR-9 regression guard: the pre-obs cell grew one float per
        request in `latencies`/`queue_waits` forever; the registry must
        hold a byte-identical footprint from request 1 to request N."""
        from repro.serve.cell import ServingCell

        cell = ServingCell(_ok_fn, name="c0", max_wait_ms=0.0,
                           max_batch=64)
        try:
            q = np.ones(4, np.float32)
            futs = [cell.submit(q) for _ in range(64)]
            for f in futs:
                f.get(timeout=10.0)
            baseline = cell.metrics.footprint_bytes()
            n = 12000
            for _ in range(n // 64):
                futs = [cell.submit(q) for _ in range(64)]
                for f in futs:
                    f.get(timeout=10.0)
            st = cell.stats()
            assert st.n >= 10000
            assert cell.metrics.footprint_bytes() == baseline, \
                "telemetry footprint grew with request count"
            # the sidecar batch log is a bounded deque, not a list
            assert len(cell._recent_batches) <= 100
        finally:
            cell.close()

    def test_outcome_span_coverage(self):
        """The exported trace must carry every request outcome the fleet
        produces: routed (ok), hedged, rerouted, and cancelled — plus the
        pipeline stages admission/queue/batch/dispatch under the same
        trace ids."""
        from repro.serve.cell import ServingCell
        from repro.serve.fleet import CellRouter

        tr = Tracer(capacity=4096)
        prev = set_tracer(tr)
        slow = {"on": False}
        boom = {"on": False}

        def flaky(qs):
            if boom["on"]:
                raise RuntimeError("injected")
            if slow["on"]:
                time.sleep(0.2)
            return _ok_fn(qs)

        cells = [ServingCell(flaky, name="cell0", max_wait_ms=0.5),
                 ServingCell(_ok_fn, name="cell1", max_wait_ms=0.5)]
        router = CellRouter(cells, hedge_ms=40.0)
        try:
            rng = np.random.default_rng(3)
            for _ in range(1000):
                q0 = rng.normal(size=(4,)).astype(np.float32)
                if router.preferred_cell(q0).name == "cell0":
                    break
            else:
                raise AssertionError("no query routed to cell0")
            # routed
            router.search(q0, timeout=5.0)
            # hedged: primary slow past hedge_ms, alternate answers
            slow["on"] = True
            router.search(q0, timeout=5.0)
            # cancelled: nobody answers in time
            with pytest.raises(TimeoutError):
                router.search(q0, timeout=0.01)
            slow["on"] = False
            time.sleep(0.5)      # let cell0's worker finish the slow
            # batch — otherwise the next request hedges (primary still
            # busy) instead of rerouting on the injected failure
            # rerouted: primary raises, router fails over
            boom["on"] = True
            router.search(q0, timeout=5.0)
            boom["on"] = False
            time.sleep(0.4)                  # drain stragglers

            routes = tr.events("route")
            outcomes = {e["args"].get("outcome") for e in routes}
            assert {"ok", "hedged", "cancelled", "rerouted"} <= outcomes
            names = tr.span_names()
            assert {"admission", "queue", "batch", "dispatch",
                    "hedge-cell", "reroute", "cancel"} <= names
            # stage spans tie back to their route's trace id
            ok = next(e for e in routes
                      if e["args"].get("outcome") == "ok")
            stage_tids = {e["args"]["trace_id"]
                          for e in tr.events("dispatch")}
            assert ok["args"]["trace_id"] in stage_tids
        finally:
            set_tracer(prev)
            router.close()

    def test_fleet_snapshot_and_exposition_surface(self):
        from repro.serve.cell import ServingCell
        from repro.serve.fleet import CellRouter

        cells = [ServingCell(_ok_fn, name=f"cell{i}", max_wait_ms=0.5)
                 for i in range(2)]
        router = CellRouter(cells)
        try:
            rng = np.random.default_rng(5)
            for _ in range(8):
                router.search(rng.normal(size=(4,)).astype(np.float32),
                              timeout=5.0)
            snap = router.metrics_snapshot()
            json.dumps(snap)
            lat_keys = [k for k in snap if k.endswith("latency_ms")]
            assert lat_keys and sum(
                snap[k]["count"] for k in lat_keys) == 8
            text = router.exposition()
            back = parse_exposition(text)
            assert any(k.endswith("latency_ms_bucket") or
                       k.endswith("latency_ms") for k in back)
            st = router.stats()
            assert st.stages and st.stages["queue"]["n"] >= 8
        finally:
            router.close()


# ---------------------------------------------------------------------------
# profiling: analytic cost model + overhead bound
# ---------------------------------------------------------------------------


class TestProfiling:
    def test_backend_cost_fused_vs_unfused_vs_int8(self):
        kw = dict(n_rows=100_000, d=128, b=64, k=10)
        fused = backend_cost("brute", fused=True, precision="f32", **kw)
        unfused = backend_cost("brute", fused=False, precision="f32", **kw)
        int8 = backend_cost("brute", fused=True, precision="int8", **kw)
        # same useful bytes, unfused pays the (B, N) materialization
        # (write + read-back) on top
        assert fused["useful_bytes"] == unfused["useful_bytes"]
        assert unfused["bytes_moved"] - fused["bytes_moved"] == \
            2 * 64 * 100_000 * 4
        assert fused["analytic_frac"] > 0.99 > unfused["analytic_frac"]
        # int8 moves ~1/4 the corpus bytes of f32
        assert int8["useful_bytes"] < 0.3 * fused["useful_bytes"]
        assert not fused["estimate"]
        ivf = backend_cost("ivf", fused=True, precision="f32",
                           n_rows=100_000, d=128, b=64, k=10,
                           n_probe_rows=8000, n_centroids=64)
        assert ivf["estimate"] and \
            ivf["useful_bytes"] < fused["useful_bytes"]

    def test_measured_overhead_bound(self):
        """The docs claim sub-10us per traced span / observed sample;
        hold the benchmark to ~50us in CI headroom terms — an order of
        magnitude under the ~1ms serving path it instruments."""
        tr = Tracer(capacity=1024)
        h = Histogram("lat")
        n = 3000
        t0 = time.perf_counter()
        for i in range(n):
            with tr.span("probe"):
                h.observe(1.0 + (i & 7))
        per_iter_us = (time.perf_counter() - t0) / n * 1e6
        assert per_iter_us < 50.0, \
            f"span+observe costs {per_iter_us:.1f}us/iter"
