"""Oracle-driven conformance fuzzer for the mutable filtered index.

Each case drives a seeded-random op sequence — ``add``, ``delete``,
``rebalance``, ``reboost``, ``delta-apply``, and filtered / lexical /
hybrid searches — over a randomly drawn ``top x bottom`` combo (or the
raw brute backend), and checks every search against a pure-numpy oracle
that mirrors the backend's *snapshot* state: the oracle advances only
at apply steps, exactly like the device arrays, so searches issued
between a mutation and its republish are checked against what the
backend actually serves, not the drifting host index.

Contract per search:

  * ids are unique, in-range for the snapshot, and every returned id
    satisfies the filter predicate AND the snapshot liveness mask — a
    tombstone applied in any earlier republish can never resurface;
  * an unsatisfiable predicate yields the full ``(inf, -1)`` sentinel
    surface with no NaNs;
  * the raw brute backend and the ivf kind (full probe scans every
    bucket) return *exactly* the oracle's top-k id set; the forest kind
    (approximate beam) must clear a calibrated recall floor;
  * lexical / hybrid answers on the raw backend match the BM25 oracle
    computed over snapshot slabs.

Failures re-raise with the reproduction seed (``proptest.run_cases``)
plus the tail of the op trace, so any violation replays exactly.

The fast suite spends ``FAST_STEPS`` total op-steps; the ``slow``
marker buys a deeper sweep of the same property.
"""
import jax
import numpy as np
import pytest

from proptest import run_cases
from repro.core.delta import DeltaManifest
from repro.core.lexical import bm25_dists, build_lexical_slabs, query_operands
from repro.core.metadata import FilterSpec, MetadataTable
from repro.core.two_level import (
    BOTTOM_ALGOS,
    TOP_ALGOS,
    TwoLevelConfig,
    build_two_level,
)
from repro.distributed.backend import ShardedSearchBackend

N0, D, K, CAP, TOPK = 400, 8, 12, 80, 8
HEADROOM = 1.6
MAX_ROWS = int(N0 * 1.4)          # stay under the placed device capacity
FAST_STEPS = 200                  # total op-steps across the fast cases
SLOW_STEPS = 600

_MESH = None


def _mesh():
    global _MESH
    if _MESH is None:
        _MESH = jax.make_mesh((1,), ("data",))
    return _MESH


def _corpus(rng, n):
    c = rng.normal(size=(8, D)) * 4
    return (c[rng.integers(0, 8, n)]
            + rng.normal(size=(n, D))).astype(np.float32)


def _draw_spec(case):
    """Random predicate over the ``pct`` column (None = unfiltered;
    ``eq 777`` is unsatisfiable — the selectivity-0 probe)."""
    r = case.int_(0, 6)
    if r == 0:
        return None
    if r == 1:
        return FilterSpec.eq("pct", 777)
    if r == 2:
        return FilterSpec.eq("pct", case.int_(0, 100))
    if r == 3:
        lo = case.int_(0, 95)
        return FilterSpec.range("pct", lo, lo + case.int_(0, 40))
    if r == 4:
        return FilterSpec.isin(
            "pct", case.rng.choice(100, size=7, replace=False))
    return (FilterSpec.range("pct", 0, 60)
            & FilterSpec.isin("pct", case.rng.choice(61, size=9,
                                                     replace=False)))


def _oracle_topk(q, db, ok, k):
    d = ((q[:, None, :] - db[None, :, :]) ** 2).sum(-1)
    d = np.where(ok[None, :], d, np.inf)
    idx = np.argsort(d, axis=1, kind="stable")[:, :k]
    dd = np.take_along_axis(d, idx, 1)
    return dd, np.where(np.isinf(dd), -1, idx)


def _check_search(tag, trace, d, i, ok, exact, snap_db, q):
    """The per-search contract vs the snapshot oracle."""
    ctx = f"[{tag}] trace tail: {trace[-6:]}"
    snap_n = ok.shape[0]
    assert not np.isnan(d).any(), f"NaN distances {ctx}"
    real = i[i >= 0]
    assert (real < snap_n).all(), f"id beyond snapshot {ctx}"
    for row in i:
        r = row[row >= 0]
        assert len(set(r.tolist())) == len(r), f"duplicate ids {ctx}"
    assert ok[real].all(), (
        f"returned id violates filter/tombstone {ctx}")
    n_ok = int(ok.sum())
    if n_ok == 0:
        assert np.all(i == -1) and np.all(np.isinf(d)), (
            f"unsatisfiable predicate not the sentinel surface {ctx}")
        return
    od, oi = _oracle_topk(q, snap_db, ok, TOPK)
    if exact:
        for r in range(i.shape[0]):
            assert set(i[r].tolist()) == set(oi[r].tolist()), (
                f"exact backend diverged from oracle row {r}: "
                f"{i[r]} vs {oi[r]} {ctx}")
    elif n_ok >= 3 * TOPK:
        hits = sum(len(set(i[r][i[r] >= 0].tolist())
                       & set(oi[r][oi[r] >= 0].tolist()))
                   for r in range(i.shape[0]))
        want = sum(int((oi[r] >= 0).sum()) for r in range(i.shape[0]))
        rec = hits / max(1, want)
        assert rec >= 0.2, (
            f"forest recall {rec:.3f} under the calibrated floor "
            f"(n_ok={n_ok}) {ctx}")


# ---------------------------------------------------------------------------
# flavor 1: a random top x bottom combo through the delta/republish cycle
# ---------------------------------------------------------------------------


def _fuzz_two_level(case, n_steps):
    rng = case.rng
    top = case.choice(TOP_ALGOS)
    bottom = case.choice(BOTTOM_ALGOS)
    db = _corpus(rng, N0)
    host_db = db.copy()
    meta = MetadataTable(
        {"pct": (rng.permutation(N0) % 100).astype(np.int32)})
    p = rng.dirichlet(np.full(N0, 0.5)) if bottom == "qlbt" else None
    idx = build_two_level(db, TwoLevelConfig(
        n_clusters=K, top=top, bottom=bottom, kmeans_iters=3,
        kmeans_minibatch=None, bucket_cap=CAP, tree_leaf=4,
        lsh_bits=32, pq_m=4), p=p, metadata=meta)
    be = ShardedSearchBackend(
        _mesh(), idx, k=TOPK, axes=("data",), nprobe_local=K,
        beam_width=8, headroom=HEADROOM)
    exact = be.kind == "ivf"          # full probe scans every bucket
    tag = f"{top}/{bottom} seed={case.seed}"

    snap = dict(db=host_db.copy(),
                alive=np.ones(N0, bool),
                meta=meta.snapshot())
    trace = []
    for _ in range(n_steps):
        op = case.choice(["search", "search", "search", "search",
                          "add", "delete", "apply", "apply",
                          "rebalance", "reboost"])
        trace.append(op)
        if op == "add":
            m = case.int_(1, 9)
            if host_db.shape[0] + m > MAX_ROWS:
                continue
            new = _corpus(rng, m)
            idx.add_entities(new, metadata={
                "pct": rng.integers(0, 100, m).astype(np.int32)})
            host_db = np.concatenate([host_db, new])
        elif op == "delete":
            alive_now = (np.ones(idx.n, bool) if idx.alive is None
                         else np.asarray(idx.alive, bool))
            live = np.flatnonzero(alive_now)
            if live.size <= 4 * TOPK:
                continue
            dele = rng.choice(live, size=case.int_(1, 8), replace=False)
            idx.delete_entities(dele)
        elif op == "rebalance":
            idx.rebalance()
        elif op == "reboost":
            idx.reboost(rng.dirichlet(np.full(idx.n, 0.5)))
        elif op == "apply":
            man = idx.pop_delta()
            be.apply_updates(idx, delta=man)
            snap = dict(
                db=host_db.copy(),
                alive=(np.ones(idx.n, bool) if idx.alive is None
                       else np.asarray(idx.alive, bool).copy()),
                meta=meta.snapshot())
            trace[-1] = f"apply(v{man.version})"
        else:
            q = _corpus(rng, 4)
            fs = _draw_spec(case)
            d, i = be(q, filter_spec=fs)
            ok = (FilterSpec() if fs is None else fs).mask(
                snap["meta"], snap["db"].shape[0]) & snap["alive"]
            _check_search(tag, trace, d, i, ok, exact, snap["db"], q)


# ---------------------------------------------------------------------------
# flavor 2: the raw brute backend — exact everywhere, plus lexical/hybrid
# ---------------------------------------------------------------------------


def _fuzz_raw_brute(case, n_steps):
    rng = case.rng
    nv = 60
    db = _corpus(rng, N0)
    host_db = db.copy()
    meta = MetadataTable(
        {"pct": (rng.permutation(N0) % 100).astype(np.int32)})
    docs = [list(rng.integers(0, nv, rng.integers(3, 10)))
            for _ in range(N0)]
    slabs = build_lexical_slabs(docs, nv)
    be = ShardedSearchBackend(
        _mesh(), db, k=TOPK, axes=("data",), headroom=HEADROOM,
        metadata=meta, lexical=slabs, delta_max_fraction=1.0)
    tag = f"raw-brute seed={case.seed}"

    snap = dict(db=host_db.copy(), alive=np.ones(N0, bool),
                meta=meta.snapshot(), terms=slabs.terms.copy(),
                tf=slabs.tf_sat.copy())
    version = 0
    base_n = N0
    pending_tombs: list = []
    alive_host = np.ones(N0, bool)
    trace = []
    for _ in range(n_steps):
        op = case.choice(["search", "search", "search", "search",
                          "add", "delete", "apply", "apply"])
        trace.append(op)
        if op == "add":
            m = case.int_(1, 9)
            if host_db.shape[0] + m > MAX_ROWS:
                continue
            new = _corpus(rng, m)
            host_db = np.concatenate([host_db, new])
            alive_host = np.concatenate([alive_host, np.ones(m, bool)])
            slabs.append_docs(
                [list(rng.integers(0, nv, 6)) for _ in range(m)])
            meta.append_rows(
                {"pct": rng.integers(0, 100, m).astype(np.int32)}, m)
        elif op == "delete":
            live = np.flatnonzero(alive_host)
            if live.size <= 4 * TOPK:
                continue
            dele = rng.choice(live, size=case.int_(1, 8), replace=False)
            alive_host[dele] = False
            pending_tombs.extend(int(x) for x in dele)
        elif op == "apply":
            man = DeltaManifest(
                base_version=version, version=version + 1,
                base_n=base_n, n=host_db.shape[0],
                tombstones=np.asarray(sorted(pending_tombs), np.int64))
            be.apply_updates(host_db, delta=man)
            version += 1
            base_n = host_db.shape[0]
            pending_tombs = []
            snap = dict(db=host_db.copy(), alive=alive_host.copy(),
                        meta=meta.snapshot(), terms=slabs.terms.copy(),
                        tf=slabs.tf_sat.copy())
            trace[-1] = f"apply(v{version})"
        else:
            q = _corpus(rng, 3)
            fs = _draw_spec(case)
            mode = case.choice(["semantic", "semantic", "lexical",
                                "hybrid"])
            ok = (FilterSpec() if fs is None else fs).mask(
                snap["meta"], snap["db"].shape[0]) & snap["alive"]
            if mode == "semantic":
                d, i = be(q, filter_spec=fs)
                _check_search(tag, trace, d, i, ok, True, snap["db"], q)
                continue
            qt, qw = query_operands(
                [list(rng.integers(0, nv, 5)) for _ in range(3)], slabs)
            alpha = float(case.floats(0.0, 1.0))
            kw = dict(filter_spec=fs, q_terms=qt, q_weights=qw)
            d, i = be(q, mode=mode, alpha=alpha, **kw)
            trace[-1] = f"search({mode})"
            bd = bm25_dists(snap["terms"], snap["tf"],
                            np.asarray(qt), np.asarray(qw))
            if mode == "lexical":
                comb = bd
            else:
                d2 = ((q[:, None, :] - snap["db"][None, :, :]) ** 2
                      ).sum(-1)
                comb = alpha * d2 + (1.0 - alpha) * bd
            comb = np.where(ok[None, :], comb, np.inf)
            order = np.argsort(comb, axis=1, kind="stable")[:, :TOPK]
            od = np.take_along_axis(comb, order, 1)
            ctx = f"[{tag}] trace tail: {trace[-6:]}"
            assert not np.isnan(d).any(), f"NaN distances {ctx}"
            real = i[i >= 0]
            assert ok[real].all(), (
                f"{mode} returned id violating filter/tombstone {ctx}")
            if int(ok.sum()) == 0:
                assert np.all(i == -1) and np.all(np.isinf(d)), (
                    f"{mode}: unsatisfiable predicate not the sentinel "
                    f"surface {ctx}")
            else:
                fin = np.isfinite(od)
                np.testing.assert_allclose(
                    d[fin], od[fin], rtol=1e-4, atol=1e-4,
                    err_msg=f"{mode} distances diverged {ctx}")


# ---------------------------------------------------------------------------
# suites
# ---------------------------------------------------------------------------


def test_fuzz_two_level_fast():
    # 4 cases x 30 steps = 120 of the 200 fast-suite op-steps
    run_cases(_fuzz_two_level, n_cases=4, base_seed=41,
              n_steps=FAST_STEPS * 3 // 10 // 2)


def test_fuzz_raw_brute_fast():
    # 2 cases x 40 steps = the remaining 80 fast-suite op-steps
    run_cases(_fuzz_raw_brute, n_cases=2, base_seed=43,
              n_steps=FAST_STEPS // 5)


@pytest.mark.slow
def test_fuzz_two_level_deep():
    run_cases(_fuzz_two_level, n_cases=6, base_seed=47,
              n_steps=SLOW_STEPS * 3 // 5 // 6)


@pytest.mark.slow
def test_fuzz_raw_brute_deep():
    run_cases(_fuzz_raw_brute, n_cases=2, base_seed=53,
              n_steps=SLOW_STEPS // 5)
