import jax.numpy as jnp
import numpy as np
import pytest

from proptest import sweep
from repro.core.brute import brute_search
from repro.core.likelihood import beta_for_unbalance, sample_queries
from repro.core.metrics import recall_at_k
from repro.core.tree import (
    build_kd_tree,
    build_qlbt,
    build_rp_tree,
    tree_search,
)


def _db(rng, n=300, d=32):
    return rng.normal(size=(n, d)).astype(np.float32)


@sweep(n_cases=6, base_seed=10)
def test_leaves_partition_entities(case):
    """Every entity appears in exactly one leaf (paper pre-grouping)."""
    n = case.int_(20, 500)
    db = case.array((n, case.int_(4, 64)))
    t = build_rp_tree(db, leaf_size=case.choice([4, 8]), seed=case.seed)
    ids = t.leaf_entities[t.leaf_entities >= 0]
    assert sorted(ids.tolist()) == list(range(n))
    # children are consistent: internal nodes have two valid children
    internal = t.children[:, 0] >= 0
    assert (t.children[internal] >= 0).all()
    assert (t.leaf_row[internal] == -1).all()


@sweep(n_cases=4, base_seed=11)
def test_full_beam_reaches_exact_recall(case):
    """With beam >= n_leaves the descent degenerates to exhaustive search."""
    rng = case.rng
    db = _db(rng, n=case.int_(64, 200), d=16)
    t = build_rp_tree(db, leaf_size=8, seed=case.seed)
    q = _db(rng, n=16, d=16)
    res = tree_search(
        t.device_arrays(), jnp.asarray(db), jnp.asarray(q),
        beam_width=t.n_leaves, k=5, max_steps=t.max_depth + 4,
    )
    _, i_true = brute_search(q, db, 5)
    assert (np.asarray(res.ids) == i_true).mean() > 0.99


def test_qlbt_reduces_expected_depth_high_skew():
    rng = np.random.default_rng(0)
    db = _db(rng, n=256, d=64)
    _, u, p = beta_for_unbalance(0.4, 256, seed=3)
    bal = build_rp_tree(db, seed=1, n_candidates=16)
    ql = build_qlbt(db, p, seed=1, n_candidates=16, lam=0.2)
    assert ql.expected_depth(p) < bal.expected_depth(p)
    # beyond-paper greedy objective at least matches Alg. 1
    gr = build_qlbt(db, p, seed=1, n_candidates=16, lam=0.2,
                    objective="greedy")
    assert gr.expected_depth(p) <= ql.expected_depth(p) + 0.05


def test_qlbt_mean_work_reduction_at_paper_operating_point():
    """Paper §5.1: ~15% mean latency gain at unbalance ~0.23 on head-heavy
    traffic. We assert the machine-independent work metric improves."""
    rng = np.random.default_rng(0)
    n, d = 256, 128
    db = (rng.normal(size=(n // 8, d))[:, None, :]
          + 0.8 * rng.normal(size=(n // 8, 8, d))).reshape(n, d)
    db = db.astype(np.float32)
    _, u, p = beta_for_unbalance(0.23, n, seed=3)
    q, gt = sample_queries(rng, db, p, 1500, noise_scale=0.05)
    bal = build_rp_tree(db, seed=1, n_candidates=16)
    ql = build_qlbt(db, p, seed=1, n_candidates=16, lam=0.2)

    def mean_work(t):
        res = tree_search(t.device_arrays(), jnp.asarray(db),
                          jnp.asarray(q), beam_width=2, k=10,
                          max_steps=t.max_depth + 4)
        r = recall_at_k(np.asarray(res.ids), gt)
        assert r > 0.9, f"recall collapsed: {r}"
        work = np.asarray(res.internal_visits) + np.asarray(res.candidates)
        return work.mean()

    gain = 1.0 - mean_work(ql) / mean_work(bal)
    assert gain > 0.05, f"QLBT mean-work gain too small: {gain:.3f}"


@sweep(n_cases=4, base_seed=12)
def test_kd_tree_exact_on_low_dim(case):
    rng = case.rng
    n = case.int_(64, 400)
    pts = case.array((n, case.int_(2, 4)))
    t = build_kd_tree(pts, leaf_size=8)
    q = pts[: min(32, n)] + case.array((min(32, n), pts.shape[1]),
                                       scale=1e-4)
    res = tree_search(t.device_arrays(), jnp.asarray(pts), jnp.asarray(q),
                      kind="kd", beam_width=t.n_leaves, k=1,
                      max_steps=t.max_depth + 4)
    assert (np.asarray(res.ids)[:, 0] == np.arange(q.shape[0])).mean() \
        > 0.99


def test_search_early_exit_bounds_steps():
    rng = np.random.default_rng(0)
    db = _db(rng, 128, 16)
    t = build_rp_tree(db, leaf_size=8, seed=0)
    q = _db(rng, 8, 16)
    res = tree_search(t.device_arrays(), jnp.asarray(db), jnp.asarray(q),
                      beam_width=4, k=5, max_steps=64)
    assert np.asarray(res.steps).max() <= t.max_depth + 1


def test_roots_parameter_descends_subtree():
    rng = np.random.default_rng(1)
    db = _db(rng, 64, 8)
    t = build_rp_tree(db, leaf_size=4, seed=0)
    q = _db(rng, 4, 8)
    left_root = int(t.children[0, 0])
    res = tree_search(t.device_arrays(), jnp.asarray(db), jnp.asarray(q),
                      beam_width=64, k=64,
                      max_steps=t.max_depth + 4,
                      roots=jnp.full((4,), left_root, jnp.int32))
    got = set(np.asarray(res.ids)[np.asarray(res.ids) >= 0].tolist())
    # candidates must be a strict subset: only the left subtree's entities
    assert 0 < len(got) < 64
