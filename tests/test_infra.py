"""Infrastructure units: HLO analysis (trip counts), ShardPlan, optimizer
state specs, serving engine bucketing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    MULTI_POD_PLAN,
    SINGLE_POD_PLAN,
    ShardPlan,
)
from repro.launch.hlo_analysis import analyze_hlo, peak_liveness
from repro.train import optim


def test_analyze_hlo_weights_scan_bodies_by_trip_count():
    def scanned(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    c = jax.jit(scanned).lower(
        jax.ShapeDtypeStruct((32, 64, 64), jnp.float32),
        jax.ShapeDtypeStruct((8, 64), jnp.float32),
    ).compile()
    a = analyze_hlo(c.as_text())
    # exact matmul flops: 32 iterations x 2*8*64*64
    want = 32 * 2 * 8 * 64 * 64
    assert abs(a["matmul_flops"] - want) / want < 0.01
    assert any(abs(v - 32) < 0.5
               for v in a["while_trip_multipliers"].values())


def test_analyze_hlo_counts_collectives():
    import os
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.hlo_analysis import analyze_hlo
    mesh = jax.make_mesh((8,), ("d",))
    def f(x, w):
        return (x @ w).sum()
    with mesh:
        c = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, "d")),
                                     NamedSharding(mesh, P("d", None))),
                    out_shardings=NamedSharding(mesh, P())).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32),
            jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    a = analyze_hlo(c.as_text())
    print("COLL", a["collective_bytes"]["total"] > 0)
    """)
    from conftest import REPO, subprocess_env

    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300,
                       env=subprocess_env(), cwd=REPO)
    assert r.returncode == 0, r.stderr[-1500:]
    assert "COLL True" in r.stdout


def test_peak_liveness_returns_buffers():
    def f(x):
        a = jnp.tanh(x @ x.T)
        b = a @ a
        return b.sum()

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile()
    pl = peak_liveness(c.as_text())
    peaks = [v["peak_bytes"] for v in pl.values()]
    assert max(peaks) >= 256 * 256 * 4


def test_shard_plan_roles_resolve():
    p = SINGLE_POD_PLAN
    assert p.p("dp", None) == P(("data",), None)
    assert p.p("fsdp", "tp") == P(("data",), ("model",))
    assert p.p(("dp", "tp")) == P(("data", "model"))
    m = MULTI_POD_PLAN
    assert m.p("dp") == P(("pod", "data"))
    assert m.resolve("ep") == ("data", "model")
    # empty plan -> fully replicated
    assert ShardPlan().p("dp", "tp") == P(None, None)


def test_div_p_drops_indivisible_dims():
    import numpy as np_
    from repro.launch.mesh import make_test_mesh

    # mesh needs real devices; emulate sizes via a fake plan with mesh=None
    # -> size 1 divides everything, roles keep
    p = ShardPlan(dp=("data",), fsdp=("data",), tp=("model",))
    # without a mesh sizes are 1 -> everything "divides"
    assert p.div_p((13, 512), "fsdp", "tp") == P(("data",), ("model",))


def test_state_specs_match_state_structure():
    params = {"w": jnp.zeros((256, 512)), "b": jnp.zeros((512,))}
    specs = {"w": P("data", "model"), "b": P(None)}
    shapes = jax.eval_shape(lambda: params)
    for opt in (optim.adamw(optim.constant_lr(1e-3)),
                optim.sgd(optim.constant_lr(1e-3)),
                optim.adafactor(optim.constant_lr(1e-3),
                                min_dim_factored=128)):
        state = opt.init(params)
        sspecs = optim.state_specs(opt, specs, shapes)
        # structures must match exactly (zip in jit sharding paths)
        jax.tree.map(lambda a, b: None, state, sspecs,
                     is_leaf=lambda x: isinstance(x, P))


def test_adafactor_factored_spec_shapes():
    opt = optim.adafactor(optim.constant_lr(1e-2), min_dim_factored=128)
    spec = opt.state_spec_fn(P("data", "model"), (256, 512))
    assert spec == {"vr": P("data"), "vc": P("model")}
    spec = opt.state_spec_fn(P(None), (64,))
    assert spec == {"v": P(None)}


def test_doc_links_resolve():
    """Every intra-repo markdown link must resolve (the CI docs job runs
    the same checker; this keeps it enforced in the tier-1 suite too)."""
    import subprocess
    import sys

    from conftest import REPO

    r = subprocess.run(
        [sys.executable, "tools/check_doc_links.py"],
        capture_output=True, text=True, cwd=REPO, timeout=60,
    )
    assert r.returncode == 0, r.stdout + r.stderr
