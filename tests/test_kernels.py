"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracle.

Shapes and dtypes sweep per the brief; ids must match exactly, distances to
fp32 tolerance.  interpret=True executes the actual kernel body (BlockSpec
tiling, revisited output accumulators, masking) on CPU.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import sweep
from repro.kernels import ref
from repro.kernels.ops import (
    candidate_topk_op,
    hamming_topk_op,
    l2_topk_int8_op,
    l2_topk_op,
    pq_adc_topk_op,
    quantize_rows_int8,
)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,n,d,k,bq,bn",
    [
        (8, 64, 16, 5, 8, 32),        # tiny
        (37, 1234, 64, 10, 16, 256),  # ragged vs grid
        (128, 4096, 128, 10, 64, 512),  # TPU-aligned
        (3, 9, 8, 4, 8, 8),           # k near n
    ],
)
def test_l2_topk_matches_ref(b, n, d, k, bq, bn, dtype):
    rng = np.random.default_rng(b * n + d)
    q = rng.normal(size=(b, d)).astype(np.float32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    qd = jnp.asarray(q, dtype=dtype)
    xd = jnp.asarray(x, dtype=dtype)
    d1, i1 = l2_topk_op(qd, xd, k, force_pallas=True, bq=bq, bn=bn)
    d2, i2 = ref.l2_topk_ref(qd, xd, k)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=2e-4, atol=2e-4)
    assert (np.asarray(i1) == np.asarray(i2)).mean() > 0.98  # fp ties


@pytest.mark.parametrize(
    "b,n,m,k,bq,bn",
    [
        (4, 100, 4, 3, 4, 32),
        (17, 999, 8, 7, 8, 128),
        (64, 8192, 16, 10, 32, 1024),
    ],
)
def test_pq_adc_matches_ref(b, n, m, k, bq, bn):
    rng = np.random.default_rng(b + n + m)
    lut = (rng.normal(size=(b, m, 256)) ** 2).astype(np.float32)
    codes = rng.integers(0, 256, size=(n, m)).astype(np.int32)
    d1, i1 = pq_adc_topk_op(lut, codes, k, force_pallas=True, bq=bq, bn=bn)
    d2, i2 = ref.pq_adc_topk_ref(jnp.asarray(lut), jnp.asarray(codes), k)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=1e-4, atol=1e-4)
    assert (np.asarray(i1) == np.asarray(i2)).mean() > 0.98


@pytest.mark.parametrize(
    "b,n,w,k,bq,bn",
    [
        (8, 200, 2, 5, 8, 64),
        (23, 555, 4, 5, 8, 128),
        (64, 4096, 8, 10, 32, 512),
    ],
)
def test_hamming_matches_ref(b, n, w, k, bq, bn):
    rng = np.random.default_rng(b + n + w)
    qc = rng.integers(-2**31, 2**31, size=(b, w)).astype(np.int64) \
        .astype(np.int32)
    cc = rng.integers(-2**31, 2**31, size=(n, w)).astype(np.int64) \
        .astype(np.int32)
    d1, i1 = hamming_topk_op(qc, cc, k, force_pallas=True, bq=bq, bn=bn)
    d2, i2 = ref.hamming_topk_ref(jnp.asarray(qc), jnp.asarray(cc), k)
    assert (np.asarray(d1) == np.asarray(d2)).all()   # integer distances
    # hamming has many exact ties -> compare distance multisets too
    assert (np.asarray(i1) >= 0).all()


@sweep(n_cases=6, base_seed=30)
def test_l2_topk_random_shapes(case):
    b = case.int_(1, 40)
    n = case.int_(10, 2000)
    d = case.int_(3, 96)
    k = case.int_(1, min(10, n))
    q = case.array((b, d))
    x = case.array((n, d))
    d1, i1 = l2_topk_op(q, x, k, force_pallas=True,
                        bq=case.choice([8, 16, 32]),
                        bn=case.choice([32, 128, 512]))
    d2, i2 = ref.l2_topk_ref(jnp.asarray(q), jnp.asarray(x), k)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# edge shapes and the kernel result contract (PR-8): k clamped internally,
# dead rows never rank, unfilled slots return the (inf, -1) sentinel —
# the Pallas body (interpret=True) must match the jnp oracle on every edge
# ---------------------------------------------------------------------------


def _case(b, n, d, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(b, d)).astype(np.float32),
            rng.normal(size=(n, d)).astype(np.float32))


def _both_l2(q, x, k, valid=None, bq=8, bn=32):
    dp, ip = l2_topk_op(q, x, k, valid=valid, force_pallas=True,
                        bq=bq, bn=bn)
    dr, ir = ref.l2_topk_ref(jnp.asarray(q), jnp.asarray(x), k,
                             valid=None if valid is None
                             else jnp.asarray(valid))
    return (np.asarray(dp), np.asarray(ip)), (np.asarray(dr), np.asarray(ir))


def test_l2_topk_single_query_row():
    q, x = _case(1, 100, 8)
    (dp, ip), (dr, ir) = _both_l2(q, x, 5)
    np.testing.assert_allclose(dp, dr, rtol=2e-4, atol=2e-4)
    assert (ip == ir).all()


def test_l2_topk_n_not_multiple_of_bn():
    q, x = _case(4, 77, 8)                    # 77 % 32 != 0 -> grid pad
    (dp, ip), (dr, ir) = _both_l2(q, x, 5, bq=8, bn=32)
    np.testing.assert_allclose(dp, dr, rtol=2e-4, atol=2e-4)
    assert (ip == ir).all()
    assert (ip < 77).all(), "grid-pad row leaked into the result"


def test_l2_topk_k_exceeds_n_pads_sentinel():
    q, x = _case(3, 6, 8)
    (dp, ip), (dr, ir) = _both_l2(q, x, 10)
    assert dp.shape == (3, 10) and ip.shape == (3, 10)
    np.testing.assert_allclose(dp, dr, rtol=2e-4, atol=2e-4)
    assert (ip == ir).all()
    assert np.isinf(dp[:, 6:]).all() and (ip[:, 6:] == -1).all(), (
        "k > N slots must carry the (inf, -1) sentinel")


def test_l2_topk_all_dead_valid_mask():
    q, x = _case(4, 50, 8)
    valid = np.zeros(50, np.int32)
    (dp, ip), (dr, ir) = _both_l2(q, x, 5, valid=valid)
    assert np.isinf(dp).all() and (ip == -1).all(), (
        "a fully-dead corpus must return only sentinels")
    assert np.isinf(dr).all() and (ir == -1).all()


def test_l2_topk_partial_valid_never_ranks_dead_rows():
    q, x = _case(6, 120, 8)
    rng = np.random.default_rng(3)
    valid = (rng.random(120) > 0.5).astype(np.int32)
    dead = np.flatnonzero(valid == 0)
    (dp, ip), (dr, ir) = _both_l2(q, x, 7, valid=valid)
    np.testing.assert_allclose(dp, dr, rtol=2e-4, atol=2e-4)
    assert (ip == ir).all()
    assert not np.isin(ip, dead).any(), "dead row ranked"


def test_l2_topk_duplicate_distances_deterministic():
    """Duplicated rows produce exact distance ties; the (distance, id)
    tie order must make the kernel agree with the oracle exactly (the
    oracle's lax.top_k prefers the lower scan position, and scan ids
    are ordered — so both pick the lower id)."""
    rng = np.random.default_rng(4)
    base = rng.normal(size=(25, 8)).astype(np.float32)
    x = np.concatenate([base, base])          # every distance duplicated
    q = base[:5] + 0.01 * rng.normal(size=(5, 8)).astype(np.float32)
    (dp, ip), (dr, ir) = _both_l2(q, x, 9)
    np.testing.assert_allclose(dp, dr, rtol=2e-4, atol=2e-4)
    assert (ip == ir).all(), "tie order diverged on duplicate distances"


def test_pq_adc_valid_and_k_clamp():
    rng = np.random.default_rng(5)
    lut = (rng.normal(size=(3, 4, 256)) ** 2).astype(np.float32)
    codes = rng.integers(0, 256, size=(40, 4)).astype(np.int32)
    valid = (rng.random(40) > 0.3).astype(np.int32)
    dead = np.flatnonzero(valid == 0)
    dp, ip = pq_adc_topk_op(lut, codes, 50, valid=valid,
                            force_pallas=True, bq=4, bn=32)
    dr, ir = ref.pq_adc_topk_ref(jnp.asarray(lut), jnp.asarray(codes), 50,
                                 valid=jnp.asarray(valid))
    dp, ip, dr, ir = map(np.asarray, (dp, ip, dr, ir))
    assert dp.shape == (3, 50)
    np.testing.assert_allclose(dp, dr, rtol=1e-4, atol=1e-4)
    assert (ip == ir).all()
    assert not np.isin(ip, dead).any()
    assert (ip[np.isinf(dp)] == -1).all()


def test_hamming_k_clamp_pads_sentinel():
    rng = np.random.default_rng(6)
    qc = rng.integers(0, 2**16, size=(3, 2)).astype(np.int32)
    cc = rng.integers(0, 2**16, size=(7, 2)).astype(np.int32)
    dp, ip = hamming_topk_op(qc, cc, 12, force_pallas=True, bq=8, bn=8)
    dr, ir = ref.hamming_topk_ref(jnp.asarray(qc), jnp.asarray(cc), 12)
    dp, ip, dr, ir = map(np.asarray, (dp, ip, dr, ir))
    assert (dp == dr).all() and (ip == ir).all()
    assert np.isinf(dp[:, 7:]).all() and (ip[:, 7:] == -1).all()


def test_int8_scan_within_quantization_tolerance():
    """The int8 scan is exact w.r.t. its *dequantized* corpus (oracle
    parity is exact-ids), and close to the f32 scan within the per-row
    quantization error bound."""
    q, x = _case(8, 300, 16, seed=7)
    codes, scales = quantize_rows_int8(x)
    dp, ip = l2_topk_int8_op(q, codes, scales, 10, force_pallas=True,
                             bq=8, bn=64)
    dr, ir = ref.l2_topk_int8_ref(jnp.asarray(q), jnp.asarray(codes),
                                  jnp.asarray(scales), 10)
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dr),
                               rtol=2e-4, atol=2e-4)
    assert (np.asarray(ip) == np.asarray(ir)).all()
    # vs the f32 scan: recall@10 stays near 1 under int8 rounding
    _, i32 = l2_topk_op(q, x, 10)
    overlap = np.mean([len(set(a.tolist()) & set(b.tolist())) / 10
                       for a, b in zip(np.asarray(ip), np.asarray(i32))])
    assert overlap > 0.9, f"int8 strayed too far from f32: {overlap}"


def test_int8_all_zero_rows_quantize_exactly():
    x = np.zeros((5, 8), np.float32)
    codes, scales = quantize_rows_int8(x)
    assert (codes == 0).all() and (scales == 1.0).all()


def test_candidate_topk_edges_match_ref():
    """bucket_topk edges: dead slots (-1 ids), k > C sentinel fill, and
    the carried-best seeding (IVF probe-chain pattern)."""
    rng = np.random.default_rng(8)
    B, C, D, k = 5, 37, 8, 6
    q = rng.normal(size=(B, D)).astype(np.float32)
    vecs = rng.normal(size=(B, C, D)).astype(np.float32)
    ids = rng.integers(0, 500, size=(B, C)).astype(np.int32)
    ids[:, ::5] = -1                          # dead slots sprinkled in
    dp, ip = candidate_topk_op(q, vecs, ids, k, force_pallas=True,
                               bq=8, bc=16)
    dr, ir = ref.candidate_topk_ref(jnp.asarray(q), jnp.asarray(vecs),
                                    jnp.asarray(ids), k)
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dr),
                               rtol=2e-4, atol=2e-4)
    assert (np.asarray(ip) == np.asarray(ir)).all()

    # k > C: both pad with the sentinel
    big = C + 10
    dp, ip = candidate_topk_op(q, vecs, ids, big, force_pallas=True,
                               bq=8, bc=16)
    dp, ip = np.asarray(dp), np.asarray(ip)
    assert dp.shape == (B, big)
    assert (ip[np.isinf(dp)] == -1).all()

    # carried best: the merged result equals the oracle's concat+top_k
    bd = np.sort(rng.random((B, k)).astype(np.float32) * 0.5, axis=1)
    bi = rng.integers(1000, 2000, size=(B, k)).astype(np.int32)
    vecs2 = rng.normal(size=(B, C, D)).astype(np.float32)
    ids2 = rng.integers(0, 500, size=(B, C)).astype(np.int32)
    dp, ip = candidate_topk_op(q, vecs2, ids2, k, best_d=bd, best_i=bi,
                               force_pallas=True, bq=8, bc=16)
    dr, ir = ref.candidate_topk_ref(jnp.asarray(q), jnp.asarray(vecs2),
                                    jnp.asarray(ids2), k,
                                    best_d=jnp.asarray(bd),
                                    best_i=jnp.asarray(bi))
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dr),
                               rtol=2e-4, atol=2e-4)
    assert (np.asarray(ip) == np.asarray(ir)).all()


def test_candidate_topk_all_dead_tile():
    rng = np.random.default_rng(9)
    q = rng.normal(size=(3, 8)).astype(np.float32)
    vecs = rng.normal(size=(3, 20, 8)).astype(np.float32)
    ids = np.full((3, 20), -1, np.int32)
    dp, ip = candidate_topk_op(q, vecs, ids, 4, force_pallas=True,
                               bq=8, bc=16)
    assert np.isinf(np.asarray(dp)).all() and (np.asarray(ip) == -1).all()


def test_popcount_exhaustive_16bit():
    from repro.kernels.common import popcount32

    x = jnp.arange(1 << 16, dtype=jnp.int32)
    got = np.asarray(popcount32(x))
    want = np.array([bin(i).count("1") for i in range(1 << 16)])
    assert (got == want).all()


# ---------------------------------------------------------------------------
# mask-composition edges: the filter surface (repro.core.metadata) compiles
# predicates into the SAME ``valid`` / ``ids`` operands these kernels
# already take, so a filtered + tombstoned + delta-padded backend dispatch
# is exactly: filter mask AND liveness mask, over a grid-padded corpus.
# Every kernel must hold the contract on the four edges: selectivity 0
# (full sentinel surface, no NaNs), selectivity 1 (bitwise-equal to the
# unfiltered call), filter AND tombstone AND grid pad, and fewer-than-k
# survivors.
# ---------------------------------------------------------------------------

_EN, _EB, _EK = 77, 5, 8        # 77 % 32 != 0 -> the grid pad is always on


def _edge_dispatch(name):
    """(n, dispatch) where dispatch(valid_or_None) -> (d, i) np arrays."""
    from repro.kernels.ops import bm25_topk_op, hybrid_topk_op

    rng = np.random.default_rng(hash(name) % 2**31)
    n, b, k, d = _EN, _EB, _EK, 8
    if name == "l2":
        q = rng.normal(size=(b, d)).astype(np.float32)
        x = rng.normal(size=(n, d)).astype(np.float32)
        fn = lambda v: l2_topk_op(q, x, k, valid=v, force_pallas=True,
                                  bq=8, bn=32)
    elif name == "int8":
        q = rng.normal(size=(b, d)).astype(np.float32)
        x = rng.normal(size=(n, d)).astype(np.float32)
        codes, scales = quantize_rows_int8(x)
        fn = lambda v: l2_topk_int8_op(q, codes, scales, k, valid=v,
                                       force_pallas=True, bq=8, bn=32)
    elif name == "pq_adc":
        lut = (rng.normal(size=(b, 4, 256)) ** 2).astype(np.float32)
        codes = rng.integers(0, 256, size=(n, 4)).astype(np.int32)
        fn = lambda v: pq_adc_topk_op(lut, codes, k, valid=v,
                                      force_pallas=True, bq=4, bn=32)
    elif name == "hamming":
        qc = rng.integers(0, 2**16, size=(b, 2)).astype(np.int32)
        cc = rng.integers(0, 2**16, size=(n, 2)).astype(np.int32)
        fn = lambda v: hamming_topk_op(qc, cc, k, valid=v,
                                       force_pallas=True, bq=8, bn=32)
    elif name == "bm25":
        terms = np.where(rng.random((n, 6)) < 0.8,
                         rng.integers(0, 40, (n, 6)), -1).astype(np.int32)
        tf = np.where(terms >= 0, rng.random((n, 6)), 0.0) \
            .astype(np.float32)
        qt = rng.integers(0, 40, size=(b, 4)).astype(np.int32)
        qw = rng.random((b, 4)).astype(np.float32) + 0.1
        fn = lambda v: bm25_topk_op(qt, qw, terms, tf, k, valid=v,
                                    force_pallas=True, bq=8, bn=32)
    elif name == "hybrid":
        q = rng.normal(size=(b, d)).astype(np.float32)
        x = rng.normal(size=(n, d)).astype(np.float32)
        terms = np.where(rng.random((n, 6)) < 0.8,
                         rng.integers(0, 40, (n, 6)), -1).astype(np.int32)
        tf = np.where(terms >= 0, rng.random((n, 6)), 0.0) \
            .astype(np.float32)
        qt = rng.integers(0, 40, size=(b, 4)).astype(np.int32)
        qw = rng.random((b, 4)).astype(np.float32) + 0.1
        alpha = np.full((1, 1), 0.4, np.float32)
        fn = lambda v: hybrid_topk_op(q, x, qt, qw, terms, tf, alpha, k,
                                      valid=v, force_pallas=True,
                                      bq=8, bn=32)
    elif name == "bucket_topk":
        q = rng.normal(size=(b, d)).astype(np.float32)
        vecs = rng.normal(size=(b, n, d)).astype(np.float32)
        ids = rng.permutation(500)[:n].astype(np.int32)
        ids_bn = np.broadcast_to(ids, (b, n)).copy()
        fn = lambda v: candidate_topk_op(
            q, vecs,
            ids_bn if v is None else np.where(
                np.asarray(v, bool)[None, :], ids_bn, -1),
            k, force_pallas=True, bq=8, bc=16)
    else:
        raise AssertionError(name)

    def dispatch(v):
        dd, ii = fn(None if v is None else np.asarray(v, np.int32))
        return np.asarray(dd), np.asarray(ii)

    # bucket_topk ranks entity ids, not row positions: expose the map
    slot_ids = ids if name == "bucket_topk" else None
    return n, dispatch, slot_ids


@pytest.mark.parametrize(
    "name", ["l2", "int8", "pq_adc", "hamming", "bm25", "hybrid",
             "bucket_topk"])
def test_mask_composition_edges(name):
    n, dispatch, slot_ids = _edge_dispatch(name)
    rng = np.random.default_rng(99)

    def returned(i):
        return i[i >= 0]

    def id_pool(valid_bool):
        """Entity ids admissible under a slot/row mask."""
        if slot_ids is None:
            return np.flatnonzero(valid_bool)
        return slot_ids[valid_bool]

    # selectivity 0: the full (inf, -1) sentinel surface, never NaN
    d0, i0 = dispatch(np.zeros(n, np.int32))
    assert np.isinf(d0).all() and (i0 == -1).all(), (
        f"{name}: selectivity 0 must return only sentinels")
    assert not np.isnan(d0).any()

    # selectivity 1: bitwise-equal to the unfiltered dispatch
    d1, i1 = dispatch(np.ones(n, np.int32))
    du, iu = dispatch(None)
    assert np.array_equal(d1, du) and np.array_equal(i1, iu), (
        f"{name}: an all-true mask changed the unfiltered answer")

    # filter AND tombstone over the grid-padded corpus (77 % 32 != 0)
    filt = rng.random(n) < 0.5
    tomb = rng.random(n) < 0.2
    v = filt & ~tomb
    if not v.any():
        v[0] = True
    d2, i2 = dispatch(v.astype(np.int32))
    assert not np.isnan(d2).any()
    pool = set(id_pool(v).tolist())
    got = returned(i2)
    assert set(got.tolist()) <= pool, (
        f"{name}: composed mask leaked a dead/filtered/pad row")
    assert (i2[np.isinf(d2)] == -1).all(), (
        f"{name}: inf distance must pair with the -1 sentinel id")

    # fewer-than-k survivors: exactly those survivors, then sentinels
    surv = np.zeros(n, bool)
    surv[rng.choice(n, 3, replace=False)] = True
    d3, i3 = dispatch(surv.astype(np.int32))
    want = set(id_pool(surv).tolist())
    for r in range(d3.shape[0]):
        real = returned(i3[r])
        assert set(real.tolist()) == want and real.size == 3, (
            f"{name}: {real} != the 3 surviving rows {sorted(want)}")
        assert np.isinf(d3[r, 3:]).all() and (i3[r, 3:] == -1).all(), (
            f"{name}: slots past the survivors must be (inf, -1)")
