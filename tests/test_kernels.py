"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracle.

Shapes and dtypes sweep per the brief; ids must match exactly, distances to
fp32 tolerance.  interpret=True executes the actual kernel body (BlockSpec
tiling, revisited output accumulators, masking) on CPU.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import sweep
from repro.kernels import ref
from repro.kernels.ops import hamming_topk_op, l2_topk_op, pq_adc_topk_op


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,n,d,k,bq,bn",
    [
        (8, 64, 16, 5, 8, 32),        # tiny
        (37, 1234, 64, 10, 16, 256),  # ragged vs grid
        (128, 4096, 128, 10, 64, 512),  # TPU-aligned
        (3, 9, 8, 4, 8, 8),           # k near n
    ],
)
def test_l2_topk_matches_ref(b, n, d, k, bq, bn, dtype):
    rng = np.random.default_rng(b * n + d)
    q = rng.normal(size=(b, d)).astype(np.float32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    qd = jnp.asarray(q, dtype=dtype)
    xd = jnp.asarray(x, dtype=dtype)
    d1, i1 = l2_topk_op(qd, xd, k, force_pallas=True, bq=bq, bn=bn)
    d2, i2 = ref.l2_topk_ref(qd, xd, k)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=2e-4, atol=2e-4)
    assert (np.asarray(i1) == np.asarray(i2)).mean() > 0.98  # fp ties


@pytest.mark.parametrize(
    "b,n,m,k,bq,bn",
    [
        (4, 100, 4, 3, 4, 32),
        (17, 999, 8, 7, 8, 128),
        (64, 8192, 16, 10, 32, 1024),
    ],
)
def test_pq_adc_matches_ref(b, n, m, k, bq, bn):
    rng = np.random.default_rng(b + n + m)
    lut = (rng.normal(size=(b, m, 256)) ** 2).astype(np.float32)
    codes = rng.integers(0, 256, size=(n, m)).astype(np.int32)
    d1, i1 = pq_adc_topk_op(lut, codes, k, force_pallas=True, bq=bq, bn=bn)
    d2, i2 = ref.pq_adc_topk_ref(jnp.asarray(lut), jnp.asarray(codes), k)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=1e-4, atol=1e-4)
    assert (np.asarray(i1) == np.asarray(i2)).mean() > 0.98


@pytest.mark.parametrize(
    "b,n,w,k,bq,bn",
    [
        (8, 200, 2, 5, 8, 64),
        (23, 555, 4, 5, 8, 128),
        (64, 4096, 8, 10, 32, 512),
    ],
)
def test_hamming_matches_ref(b, n, w, k, bq, bn):
    rng = np.random.default_rng(b + n + w)
    qc = rng.integers(-2**31, 2**31, size=(b, w)).astype(np.int64) \
        .astype(np.int32)
    cc = rng.integers(-2**31, 2**31, size=(n, w)).astype(np.int64) \
        .astype(np.int32)
    d1, i1 = hamming_topk_op(qc, cc, k, force_pallas=True, bq=bq, bn=bn)
    d2, i2 = ref.hamming_topk_ref(jnp.asarray(qc), jnp.asarray(cc), k)
    assert (np.asarray(d1) == np.asarray(d2)).all()   # integer distances
    # hamming has many exact ties -> compare distance multisets too
    assert (np.asarray(i1) >= 0).all()


@sweep(n_cases=6, base_seed=30)
def test_l2_topk_random_shapes(case):
    b = case.int_(1, 40)
    n = case.int_(10, 2000)
    d = case.int_(3, 96)
    k = case.int_(1, min(10, n))
    q = case.array((b, d))
    x = case.array((n, d))
    d1, i1 = l2_topk_op(q, x, k, force_pallas=True,
                        bq=case.choice([8, 16, 32]),
                        bn=case.choice([32, 128, 512]))
    d2, i2 = ref.l2_topk_ref(jnp.asarray(q), jnp.asarray(x), k)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=2e-4, atol=2e-4)


def test_popcount_exhaustive_16bit():
    from repro.kernels.common import popcount32

    x = jnp.arange(1 << 16, dtype=jnp.int32)
    got = np.asarray(popcount32(x))
    want = np.array([bin(i).count("1") for i in range(1 << 16)])
    assert (got == want).all()
