"""Filtered + hybrid search conformance through the sharded scan.

The filter surface (``repro.core.metadata``) compiles predicates to row
masks that are *data, not shapes* — so the contract is strong:

  (a) fused and unfused backends are bitwise-identical under every
      filter, at selectivities {0, 0.05, 0.5, 1.0}, on the fresh index
      AND after a localized mutation shipped down the delta path, for
      every top x bottom combo;
  (b) no returned id ever violates the predicate (or a tombstone);
  (c) selectivity 0 yields the full ``(inf, -1)`` sentinel surface with
      no NaNs; a selectivity-1.0 predicate is bitwise-equal to the
      unfiltered call;
  (d) the brute kind is additionally *exact*: bitwise-equal to a pure
      numpy masked-scan oracle, fresh and post-delta;
  (e) lexical (BM25 slab) and hybrid modes match their numpy oracles
      and compose with filters, without minting jit signatures beyond
      the three per-mode callables;
  (f) the admission cache key isolates filter/mode/alpha: a filtered
      result can never satisfy an unfiltered request (or vice versa),
      and apply_updates still invalidates every variant.
"""
import jax
import numpy as np
import pytest

from repro.core.delta import DeltaManifest
from repro.core.lexical import bm25_dists, build_lexical_slabs, query_operands
from repro.core.metadata import FilterSpec, MetadataTable
from repro.core.two_level import (
    BOTTOM_ALGOS,
    TOP_ALGOS,
    TwoLevelConfig,
    build_two_level,
)
from repro.distributed.backend import ShardedSearchBackend

N, D, K, CAP, NQ, TOPK = 600, 8, 16, 96, 16, 10
COMBOS = [(t, b) for t in TOP_ALGOS for b in BOTTOM_ALGOS]

# ``pct`` is a permutation mod 100, so each range predicate admits its
# fraction of rows *exactly*; 777 never occurs (selectivity 0)
SPECS = [
    ("sel_0.00", FilterSpec.eq("pct", 777)),
    ("sel_0.05", FilterSpec.range("pct", 0, 4)),
    ("sel_0.50", FilterSpec.range("pct", 0, 49)),
    ("sel_1.00", FilterSpec.range("pct", 0, 99)),
]


def _corpus(rng, n):
    c = rng.normal(size=(8, D)) * 4
    return (c[rng.integers(0, 8, n)]
            + rng.normal(size=(n, D))).astype(np.float32)


def _meta_for(rng, n):
    return MetadataTable({"pct": (rng.permutation(n) % 100).astype(np.int32)})


def _build(db, top, bottom, p, metadata=None):
    cfg = TwoLevelConfig(
        n_clusters=K, top=top, bottom=bottom, kmeans_iters=3,
        kmeans_minibatch=None, bucket_cap=CAP, tree_leaf=4,
        lsh_bits=32, pq_m=4,
    )
    return build_two_level(db, cfg, p=p, metadata=metadata)


def _oracle(q, db, ok, k):
    """Pure-numpy masked brute scan: stable top-k over inf-masked L2."""
    d = ((q[:, None, :] - db[None, :, :]) ** 2).sum(-1)
    d = np.where(ok[None, :], d, np.inf)
    idx = np.argsort(d, axis=1, kind="stable")[:, :k]
    dd = np.take_along_axis(d, idx, 1)
    return dd, np.where(np.isinf(dd), -1, idx)


# ---------------------------------------------------------------------------
# (a)-(c): every top x bottom combo, every selectivity, fresh + post-delta
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("top,bottom", COMBOS)
def test_filtered_fused_vs_unfused(top, bottom):
    rng = np.random.default_rng(700 + TOP_ALGOS.index(top) * 10
                                + BOTTOM_ALGOS.index(bottom))
    db = _corpus(rng, N)
    p = rng.dirichlet(np.full(N, 0.5)) if bottom == "qlbt" else None
    meta = _meta_for(rng, N)
    idx = _build(db, top, bottom, p, metadata=meta)
    mesh = jax.make_mesh((1,), ("data",))
    kw = dict(k=TOPK, axes=("data",), nprobe_local=K, beam_width=8,
              headroom=1.5)
    be_f = ShardedSearchBackend(mesh, idx, fused=True, **kw)
    be_u = ShardedSearchBackend(mesh, idx, fused=False, **kw)
    q = _corpus(rng, NQ)

    def check(tag):
        alive = (np.ones(meta.n_rows, bool) if idx.alive is None
                 else np.asarray(idx.alive, bool))
        for name, fs in SPECS:
            df, i_f = be_f(q, filter_spec=fs)
            du, iu = be_u(q, filter_spec=fs)
            assert np.array_equal(df, du) and np.array_equal(i_f, iu), (
                f"{top}/{bottom} [{tag} {name}]: fused filtered scan "
                f"diverged from unfused")
            ok = fs.mask(meta, alive.shape[0]) & alive
            real = i_f[i_f >= 0]
            assert ok[real].all(), (
                f"{top}/{bottom} [{tag} {name}]: returned an id the "
                f"predicate (or a tombstone) excludes")
            if name == "sel_0.00":
                assert np.all(i_f == -1) and np.all(np.isinf(df)), (
                    f"{top}/{bottom} [{tag}]: selectivity-0 must be the "
                    f"full (inf, -1) sentinel surface")
                assert not np.isnan(df).any()
        # selectivity 1.0 (a real predicate admitting every row) must be
        # bitwise-equal to the unfiltered call
        d0, i0 = be_f(q)
        d1, i1 = be_f(q, filter_spec=SPECS[-1][1])
        assert np.array_equal(d0, d1) and np.array_equal(i0, i1), (
            f"{top}/{bottom} [{tag}]: selectivity-1.0 filter changed "
            f"the unfiltered answer")

    check("fresh")

    # localized mutation -> ONE popped manifest -> delta apply on BOTH;
    # appended rows carry metadata, so they are filterable immediately
    b = int(np.argmax(idx.bucket_counts))
    dele = idx.bucket_ids[b][:5].copy()
    idx.delete_entities(dele)
    new = (idx.centroids[1][None, :]
           + 0.1 * rng.normal(size=(5, D))).astype(np.float32)
    idx.add_entities(new, metadata={"pct": np.full(5, 2, np.int32)})
    man = idx.pop_delta()
    stf = be_f.apply_updates(idx, delta=man)
    stu = be_u.apply_updates(idx, delta=man)
    assert stf["mode"] == stu["mode"] == "delta", (stf, stu)
    check("post-delta")
    # tombstoned rows stay dead under every filter
    for _, fs in SPECS:
        _, i_f = be_f(q, filter_spec=fs)
        assert not np.isin(i_f, dele).any(), (
            f"{top}/{bottom}: deleted id returned through a filtered "
            f"delta-path search")


# ---------------------------------------------------------------------------
# (d): the brute kind is exact vs the numpy oracle, fresh and post-delta
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fused", [True, False])
def test_filtered_brute_exact_oracle(fused):
    rng = np.random.default_rng(800 + int(fused))
    db = _corpus(rng, N)
    meta = _meta_for(rng, N)
    mesh = jax.make_mesh((1,), ("data",))
    be = ShardedSearchBackend(
        mesh, db, k=TOPK, axes=("data",), headroom=1.5, fused=fused,
        metadata=meta, delta_max_fraction=1.0)
    q = _corpus(rng, NQ)
    compound = (FilterSpec.range("pct", 10, 80)
                & FilterSpec.isin("pct", tuple(range(0, 100, 3))))
    all_specs = SPECS + [("compound", compound)]

    def check(db_now, alive, tag):
        for name, fs in all_specs:
            d, i = be(q, filter_spec=fs)
            ok = fs.mask(meta, alive.shape[0]) & alive
            od, oi = _oracle(q, db_now, ok, TOPK)
            # ids are exact; distances match up to f32 accumulation
            # order (the kernel uses the expanded |q-x|^2 form)
            assert np.array_equal(i, oi), (
                f"brute [{tag} {name}]: filtered scan diverged from the "
                f"numpy oracle")
            assert np.array_equal(np.isinf(d), np.isinf(od))
            fin = np.isfinite(od)
            np.testing.assert_allclose(d[fin], od[fin], rtol=1e-4,
                                       atol=1e-4)

    check(db, np.ones(N, bool), "fresh")

    # tombstones + appended rows down the delta path, then re-check the
    # whole selectivity matrix against the oracle on the mutated corpus
    new = _corpus(rng, 16)
    db2 = np.concatenate([db, new])
    meta.append_rows({"pct": (np.arange(16) % 100).astype(np.int32)}, 16)
    tomb = np.arange(0, 60, 5).astype(np.int64)
    man = DeltaManifest(base_version=0, version=1, base_n=N, n=N + 16,
                        tombstones=tomb)
    st = be.apply_updates(db2, delta=man)
    assert st["mode"] == "delta", st
    alive2 = np.ones(N + 16, bool)
    alive2[tomb] = False
    check(db2, alive2, "post-delta")


# ---------------------------------------------------------------------------
# (e): lexical + hybrid modes vs their oracles, composed with filters
# ---------------------------------------------------------------------------


def test_lexical_and_hybrid_conformance():
    rng = np.random.default_rng(900)
    n, nv = 300, 60
    db = _corpus(rng, n)
    meta = MetadataTable(
        {"pct": (rng.permutation(n) % 100).astype(np.int32)})
    docs = [list(rng.integers(0, nv, rng.integers(3, 12)))
            for _ in range(n)]
    slabs = build_lexical_slabs(docs, nv)
    q = _corpus(rng, 6)
    qt, qw = query_operands(
        [list(rng.integers(0, nv, 5)) for _ in range(6)], slabs)
    mesh = jax.make_mesh((1,), ("data",))
    kw = dict(k=TOPK, axes=("data",), headroom=1.5, metadata=meta,
              lexical=slabs, delta_max_fraction=1.0)
    be_f = ShardedSearchBackend(mesh, db, fused=True, **kw)
    be_u = ShardedSearchBackend(mesh, db, fused=False, **kw)
    alive = np.ones(n, bool)
    fs = FilterSpec.range("pct", 0, 49)
    emask = fs.mask(meta, n)

    def lex_oracle(ok):
        bd = bm25_dists(slabs.terms, slabs.tf_sat,
                        np.asarray(qt), np.asarray(qw))
        bdm = np.where(ok[None, :], bd, np.inf)
        order = np.argsort(bdm, axis=1, kind="stable")[:, :TOPK]
        return np.take_along_axis(bdm, order, 1)

    def hyb_oracle(ok, alpha):
        d2 = ((q[:, None, :] - db[None, :, :]) ** 2).sum(-1)
        bd = bm25_dists(slabs.terms, slabs.tf_sat,
                        np.asarray(qt), np.asarray(qw))
        comb = np.where(ok[None, :],
                        alpha * d2 + (1.0 - alpha) * bd, np.inf)
        order = np.argsort(comb, axis=1, kind="stable")[:, :TOPK]
        return np.take_along_axis(comb, order, 1)

    # lexical: fused == unfused bitwise; distances match the BM25 oracle
    dl, il = be_f(q, mode="lexical", q_terms=qt, q_weights=qw)
    du, iu = be_u(q, mode="lexical", q_terms=qt, q_weights=qw)
    assert np.array_equal(dl, du) and np.array_equal(il, iu)
    assert np.allclose(dl, lex_oracle(alive), atol=1e-5)

    # hybrid across alphas: fused == unfused bitwise, oracle-close;
    # alpha is an operand, so no alpha mints a new jit signature
    for alpha in (0.0, 0.3, 1.0):
        dh, ih = be_f(q, mode="hybrid", alpha=alpha,
                      q_terms=qt, q_weights=qw)
        dhu, ihu = be_u(q, mode="hybrid", alpha=alpha,
                        q_terms=qt, q_weights=qw)
        assert np.array_equal(dh, dhu) and np.array_equal(ih, ihu), (
            f"hybrid alpha={alpha}: fused diverged from unfused")
        assert np.allclose(dh, hyb_oracle(alive, alpha), atol=1e-4), (
            f"hybrid alpha={alpha} diverged from the numpy oracle")

    # filters compose with both modes (predicate mask ANDed into valid)
    for mode in ("lexical", "hybrid"):
        d, i = be_f(q, mode=mode, filter_spec=fs,
                    q_terms=qt, q_weights=qw)
        real = i[i >= 0]
        assert emask[real].all(), (
            f"{mode}+filter returned an excluded id")
        d0, i0 = be_f(q, mode=mode, filter_spec=FilterSpec.eq("pct", 777),
                      q_terms=qt, q_weights=qw)
        assert np.all(i0 == -1) and np.all(np.isinf(d0))
        assert not np.isnan(d0).any()

    # exactly one jitted callable per mode, regardless of how many
    # filter/alpha combinations were dispatched above
    _ = be_f(q, filter_spec=fs)          # semantic mode, filtered
    assert be_f.jit_cache_size() == 3, be_f.jit_cache_size()

    # delta path: appended docs join the lexical scan, under a filter
    # that admits them, and the slab scatter is delta-shaped
    new = _corpus(rng, 8)
    db2 = np.concatenate([db, new])
    slabs.append_docs([list(rng.integers(0, nv, 6)) for _ in range(8)])
    meta.append_rows({"pct": np.full(8, 2, np.int32)}, 8)
    man = DeltaManifest(base_version=0, version=1, base_n=n, n=n + 8)
    st = be_f.apply_updates(db2, delta=man)
    assert st["mode"] == "delta", st
    d, i = be_f(q, mode="lexical", filter_spec=fs,
                q_terms=qt, q_weights=qw)
    emask2 = fs.mask(meta, n + 8)
    real = i[i >= 0]
    assert emask2[real].all()
    bd = bm25_dists(slabs.terms, slabs.tf_sat,
                    np.asarray(qt), np.asarray(qw))
    bdm = np.where(emask2[None, :], bd, np.inf)
    order = np.argsort(bdm, axis=1, kind="stable")[:, :TOPK]
    assert np.allclose(d, np.take_along_axis(bdm, order, 1), atol=1e-5), (
        "post-delta filtered lexical scan diverged from the oracle")
    assert be_f.jit_cache_size() == 3, "delta apply minted a signature"


def test_mode_and_filter_validation():
    rng = np.random.default_rng(901)
    db = _corpus(rng, 64)
    meta = MetadataTable({"pct": np.zeros(64, np.int32)})
    mesh = jax.make_mesh((1,), ("data",))
    be = ShardedSearchBackend(mesh, db, k=4, axes=("data",),
                              metadata=meta)
    q = _corpus(rng, 2)
    with pytest.raises(ValueError, match="mode"):
        be(q, mode="sparse")
    with pytest.raises(ValueError, match="lexical"):
        be(q, mode="lexical", q_terms=np.zeros((2, 4), np.int32),
           q_weights=np.zeros((2, 4), np.float32))
    with pytest.raises(KeyError, match="unknown metadata column"):
        be(q, filter_spec=FilterSpec.eq("nope", 1))
    with pytest.raises(ValueError, match="bad predicate"):
        FilterSpec((("gt", "pct", 3),))
    # an empty FilterSpec is the unfiltered path, bitwise
    d0, i0 = be(q)
    d1, i1 = be(q, filter_spec=FilterSpec())
    assert np.array_equal(d0, d1) and np.array_equal(i0, i1)


# ---------------------------------------------------------------------------
# (f): admission-cache key isolation + post-swap invalidation (regression:
# the key must fold in filter digest, mode, and alpha)
# ---------------------------------------------------------------------------


def test_cache_key_isolation_and_invalidation():
    from repro.adaptive import FrequencyAdmissionCache
    from repro.serve.cell import _opts_extra
    from repro.serve.engine import ServingEngine

    q = np.arange(8, dtype=np.float32)
    fs = FilterSpec.eq("pct", 1)
    # default options keep the historical key (extra == b"")
    assert _opts_extra(None, "semantic", 0.5) == b""
    k0 = FrequencyAdmissionCache.key_for(q)
    assert FrequencyAdmissionCache.key_for(
        q, _opts_extra(None, "semantic", 0.5)) == k0
    variants = {
        FrequencyAdmissionCache.key_for(q, _opts_extra(fs, "semantic", 0.5)),
        FrequencyAdmissionCache.key_for(
            q, _opts_extra(FilterSpec.eq("pct", 2), "semantic", 0.5)),
        FrequencyAdmissionCache.key_for(q, _opts_extra(None, "hybrid", 0.5)),
        FrequencyAdmissionCache.key_for(q, _opts_extra(None, "hybrid", 0.7)),
        FrequencyAdmissionCache.key_for(q, _opts_extra(fs, "hybrid", 0.5)),
        k0,
    }
    assert len(variants) == 6, "filter/mode/alpha variants collided"

    # end-to-end: filtered and unfiltered answers for the SAME query are
    # cached separately, both hit on re-ask, and a swap drops both
    rng = np.random.default_rng(902)
    n = 200
    db = _corpus(rng, n)
    meta = MetadataTable(
        {"pct": (rng.permutation(n) % 100).astype(np.int32)})
    mesh = jax.make_mesh((1,), ("data",))
    be = ShardedSearchBackend(mesh, db, k=TOPK, axes=("data",),
                              headroom=1.5, metadata=meta,
                              delta_max_fraction=1.0)
    cache = FrequencyAdmissionCache(capacity=64)
    eng = ServingEngine(be, cache=cache, max_wait_ms=0.5)
    try:
        fs = FilterSpec.range("pct", 0, 4)
        query = db[0].copy()
        d_u, i_u = eng.search(query, timeout=30.0)
        d_f, i_f = eng.search(query, timeout=30.0, filter=fs)
        emask = fs.mask(meta, n)
        assert not np.array_equal(i_u, i_f)
        assert emask[i_f[i_f >= 0]].all()
        h0 = cache.hits
        d_u2, i_u2 = eng.search(query, timeout=30.0)
        d_f2, i_f2 = eng.search(query, timeout=30.0, filter=fs)
        assert cache.hits >= h0 + 2, "variant keys missed the cache"
        assert np.array_equal(i_u, i_u2) and np.array_equal(i_f, i_f2)
        assert np.array_equal(d_u, d_u2) and np.array_equal(d_f, d_f2)

        # delete the filtered answer's best row; after the swap neither
        # the filtered nor the unfiltered cached variant may resurface it
        victim = int(i_f[0])
        db2 = db.copy()
        man = DeltaManifest(base_version=0, version=1, base_n=n, n=n,
                            tombstones=np.asarray([victim], np.int64))
        eng.apply_updates(db2, delta=man)
        _, i_u3 = eng.search(query, timeout=30.0)
        _, i_f3 = eng.search(query, timeout=30.0, filter=fs)
        assert victim not in i_u3 and victim not in i_f3, (
            "cache served a deleted entity after apply_updates")
    finally:
        eng.close()
