"""Cross-algorithm conformance suite for the two-level index.

Every ``top x bottom`` combination of :class:`TwoLevelConfig` must satisfy
the same contract, checked per combo on seeded random cases (``proptest``):

  (a) returned ids are unique per query (the rerank dedupe holds);
  (b) recall@k vs ``l2_topk_exact`` is monotone non-decreasing in
      ``nprobe`` (exact for the brute bottom — more probes mean a
      candidate *superset*; a small slack for LSH, whose fixed-size
      Hamming shortlist is not a superset under more probes);
  (c) results are invariant to corpus row permutation: exact (id-set)
      invariance at full probe for the brute bottom, recall-parity for
      the approximate bottoms (their build order legitimately shapes the
      tree/code structure).

Shapes are pinned (same n/d/K/cap across cases) so every case after the
first hits the jit cache.
"""
import numpy as np
import pytest

from proptest import run_cases
from repro.core.brute import brute_search
from repro.core.metrics import recall_at_k
from repro.core.two_level import (
    BOTTOM_ALGOS,
    TOP_ALGOS,
    TwoLevelConfig,
    build_two_level,
)

N, D, K, CAP, NQ, TOPK = 600, 8, 16, 96, 16, 10
COMBOS = [(t, b) for t in TOP_ALGOS for b in BOTTOM_ALGOS]


def _corpus(rng, n):
    c = rng.normal(size=(8, D)) * 4
    return (c[rng.integers(0, 8, n)]
            + rng.normal(size=(n, D))).astype(np.float32)


def _build(db, top, bottom, p):
    cfg = TwoLevelConfig(
        n_clusters=K, top=top, bottom=bottom, kmeans_iters=3,
        kmeans_minibatch=None, bucket_cap=CAP, tree_leaf=4,
        lsh_bits=32, pq_m=4,
    )
    return build_two_level(db, cfg, p=p)


def _search_ids(idx, q, nprobe, k=TOPK):
    # LSH keeps a fixed-size Hamming shortlist, which is NOT a candidate
    # superset as nprobe grows; scale the rerank budget with the probe
    # count so the monotonicity contract tests the algorithm, not an
    # artificially starved shortlist.
    d, i, _ = idx.search(q, k, nprobe=nprobe, beam_width=8,
                         lsh_candidates=64 * nprobe)
    return np.asarray(d), np.asarray(i)


@pytest.mark.parametrize("top,bottom", COMBOS)
def test_conformance_sweep(top, bottom):
    run_cases(
        _conformance_property, n_cases=2,
        base_seed=TOP_ALGOS.index(top) * 10 + BOTTOM_ALGOS.index(bottom),
        top=top, bottom=bottom)


def _conformance_property(case, top, bottom):
    rng = case.rng
    db = _corpus(rng, N)
    p = rng.dirichlet(np.full(N, 0.5)) if bottom == "qlbt" else None
    idx = _build(db, top, bottom, p)
    q = _corpus(rng, NQ)
    _, i_true = brute_search(q, db, TOPK)

    # (a) unique ids per query, at partial and full probe
    for nprobe in (4, K):
        _, ids = _search_ids(idx, q, nprobe)
        for b in range(NQ):
            real = ids[b][ids[b] >= 0]
            assert len(set(real.tolist())) == len(real), (
                f"{top}/{bottom} nprobe={nprobe}: duplicate ids {ids[b]}")

    # (b) recall monotone non-decreasing in nprobe
    recalls = []
    for nprobe in (1, 4, K):
        _, ids = _search_ids(idx, q, nprobe)
        recalls.append(recall_at_k(ids, i_true))
    slack = 0.05 if bottom == "lsh" else 1e-9
    assert all(b >= a - slack for a, b in zip(recalls, recalls[1:])), (
        f"{top}/{bottom}: recall not monotone in nprobe: {recalls}")

    # (c) corpus row permutation invariance
    perm = rng.permutation(N)
    p_perm = None if p is None else p[perm]
    idx_p = _build(db[perm], top, bottom, p_perm)
    d0, i0 = _search_ids(idx, q, K)
    dp, ip = _search_ids(idx_p, q, K)
    ip_mapped = np.where(ip >= 0, perm[np.maximum(ip, 0)], -1)
    if bottom == "brute":
        # full probe == exact scan -> identical answer sets
        np.testing.assert_allclose(dp, d0, rtol=1e-4, atol=1e-4)
        for b in range(NQ):
            assert set(ip_mapped[b].tolist()) == set(i0[b].tolist()), (
                f"{top}/{bottom}: permuted corpus changed the exact "
                f"result set")
    else:
        r0 = recall_at_k(i0, i_true)
        rp = recall_at_k(ip_mapped, i_true)
        assert abs(r0 - rp) < 0.25, (
            f"{top}/{bottom}: permutation moved recall "
            f"{r0:.3f} -> {rp:.3f}")


# ---------------------------------------------------------------------------
# adaptive paths: the same contract must hold after a reboost and through
# the serving cache (PR-4 acceptance: results after any reboost or cache
# invalidation never contain deleted or stale entries)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("top", TOP_ALGOS)
def test_conformance_reboosted(top):
    """(a)/(b) from the main contract, re-checked on a mutated-then-
    reboosted qlbt index: unique ids, no deleted ids at partial and full
    probe, recall still monotone in nprobe."""
    rng = np.random.default_rng(100 + TOP_ALGOS.index(top))
    db = _corpus(rng, N)
    p = rng.dirichlet(np.full(N, 0.5))
    idx = _build(db, top, "qlbt", p)
    dele = rng.choice(N, 60, replace=False)
    idx.delete_entities(dele)
    idx.reboost(rng.dirichlet(np.full(N, 0.5)))
    q = _corpus(rng, NQ)
    live = np.setdiff1d(np.arange(N), dele)
    _, i_true = brute_search(q, db[live], TOPK)
    recalls = []
    for nprobe in (1, 4, K):
        _, ids = _search_ids(idx, q, nprobe)
        assert not np.isin(ids, dele).any(), (
            f"{top}/qlbt reboosted: deleted id returned")
        for b in range(NQ):
            real = ids[b][ids[b] >= 0]
            assert len(set(real.tolist())) == len(real), (
                f"{top}/qlbt reboosted: duplicate ids")
        recalls.append(recall_at_k(ids, live[i_true]))
    assert all(b >= a - 1e-9 for a, b in zip(recalls, recalls[1:])), (
        f"{top}/qlbt reboosted: recall not monotone: {recalls}")


# ---------------------------------------------------------------------------
# delta shipping: applying a popped DeltaManifest must be indistinguishable
# from a full re-place — bitwise, on every combo (PR-5 acceptance)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("top,bottom", COMBOS)
def test_conformance_delta_parity(top, bottom):
    """``apply_updates(delta=...)`` and a full re-place of the same
    mutated index must produce *bitwise-identical* device state and
    search results, for every top x bottom combo, and the localized
    mutation must actually take the delta path (not silently fall back).
    """
    import jax

    from repro.distributed.backend import ShardedSearchBackend

    rng = np.random.default_rng(300 + TOP_ALGOS.index(top) * 10
                                + BOTTOM_ALGOS.index(bottom))
    db = _corpus(rng, N)
    p = rng.dirichlet(np.full(N, 0.5)) if bottom == "qlbt" else None
    idx = _build(db, top, bottom, p)
    mesh = jax.make_mesh((1,), ("data",))
    kw = dict(k=TOPK, axes=("data",), nprobe_local=K, beam_width=8,
              headroom=1.5)
    be_delta = ShardedSearchBackend(mesh, idx, **kw)
    be_full = ShardedSearchBackend(mesh, idx, **kw)

    # localized mutation: empty a few slots of one bucket, add mass near
    # another centroid — the dirty set stays a handful of buckets
    b = int(np.argmax(idx.bucket_counts))
    dele = idx.bucket_ids[b][:5].copy()
    idx.delete_entities(dele)
    new = (idx.centroids[1][None, :]
           + 0.1 * rng.normal(size=(5, D))).astype(np.float32)
    idx.add_entities(new)

    man = idx.pop_delta()
    st = be_delta.apply_updates(idx, delta=man)
    assert st["mode"] == "delta", st
    assert st["bytes"] < st["full_bytes"]
    be_full.apply_updates(idx)                    # full re-place control

    # device state parity: every placed array identical bit for bit
    for a, b in zip(be_delta._args, be_full._args):
        assert a.shape == b.shape
        assert np.array_equal(np.asarray(a), np.asarray(b))

    q = _corpus(rng, NQ)
    d1, i1 = be_delta(q)
    d2, i2 = be_full(q)
    assert np.array_equal(d1, d2) and np.array_equal(i1, i2), (
        f"{top}/{bottom}: delta apply diverged from full re-place")
    assert not np.isin(i1, dele).any(), (
        f"{top}/{bottom}: deleted id returned through the delta path")


# ---------------------------------------------------------------------------
# fused kernel path: routing the sharded scans through the Pallas kernel
# dispatch (fused=True, the default) must be bitwise-identical to the
# unfused jnp locals — initially AND after a mutation shipped as a delta
# (PR-8 acceptance)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("top,bottom", COMBOS)
def test_conformance_fused_vs_unfused(top, bottom):
    """``fused=True`` swaps the per-shard scan+top-k locals for the
    kernel dispatch (``repro.kernels.ops``).  The swap must be
    invisible: search results bitwise-identical to ``fused=False`` on
    the fresh index, and still bitwise-identical after a localized
    mutation applied through the delta path on both backends."""
    import jax

    from repro.distributed.backend import ShardedSearchBackend

    rng = np.random.default_rng(500 + TOP_ALGOS.index(top) * 10
                                + BOTTOM_ALGOS.index(bottom))
    db = _corpus(rng, N)
    p = rng.dirichlet(np.full(N, 0.5)) if bottom == "qlbt" else None
    idx = _build(db, top, bottom, p)
    mesh = jax.make_mesh((1,), ("data",))
    kw = dict(k=TOPK, axes=("data",), nprobe_local=K, beam_width=8,
              headroom=1.5)
    be_f = ShardedSearchBackend(mesh, idx, fused=True, **kw)
    be_u = ShardedSearchBackend(mesh, idx, fused=False, **kw)
    q = _corpus(rng, NQ)

    def bitwise_equal(tag):
        df, i_f = be_f(q)
        du, iu = be_u(q)
        assert np.array_equal(df, du) and np.array_equal(i_f, iu), (
            f"{top}/{bottom} [{tag}]: fused scan diverged from unfused")

    bitwise_equal("fresh")

    # localized mutation -> delta apply on BOTH -> still bitwise equal
    b = int(np.argmax(idx.bucket_counts))
    dele = idx.bucket_ids[b][:5].copy()
    idx.delete_entities(dele)
    new = (idx.centroids[1][None, :]
           + 0.1 * rng.normal(size=(5, D))).astype(np.float32)
    idx.add_entities(new)
    man = idx.pop_delta()
    stf = be_f.apply_updates(idx, delta=man)
    stu = be_u.apply_updates(idx, delta=man)
    assert stf["mode"] == stu["mode"] == "delta", (stf, stu)
    bitwise_equal("post-delta")
    _, i_f = be_f(q)
    assert not np.isin(i_f, dele).any(), (
        f"{top}/{bottom}: deleted id returned through the fused path")


def test_conformance_int8_brute_recall():
    """The int8-footprint brute scan is approximate (quantization), not
    bitwise — but it must track the f32 scan closely: recall@k vs the
    f32 result near 1, and survive the delta path (tombstone flips, and
    appended rows quantized on the way in)."""
    import jax

    from repro.core.delta import DeltaManifest
    from repro.distributed.backend import ShardedSearchBackend

    rng = np.random.default_rng(600)
    db = _corpus(rng, N)
    mesh = jax.make_mesh((1,), ("data",))
    kw = dict(k=TOPK, axes=("data",), headroom=1.5)
    be32 = ShardedSearchBackend(mesh, db, precision="f32", **kw)
    be8 = ShardedSearchBackend(mesh, db, precision="int8", **kw)
    q = _corpus(rng, NQ)
    _, i32 = be32(q)
    _, i8 = be8(q)
    assert recall_at_k(np.asarray(i8), np.asarray(i32)) > 0.9, (
        "int8 scan strayed too far from the f32 scan")

    # tombstone window, then an append window — both down the delta path
    dele = np.asarray([3, 17, 41])
    man = DeltaManifest(base_version=0, version=1, base_n=N, n=N,
                        tombstones=dele)
    assert be8.apply_updates(db, delta=man)["mode"] == "delta"
    be32.apply_updates(db, delta=man)
    grown = np.concatenate([db, _corpus(rng, 8)])
    man2 = DeltaManifest(base_version=1, version=2, base_n=N, n=N + 8)
    st = be8.apply_updates(grown, delta=man2)
    assert st["mode"] == "delta", st
    be32.apply_updates(grown, delta=man2)
    _, i32 = be32(q)
    _, i8 = be8(q)
    assert not np.isin(i8, dele).any(), "int8 delta path returned deleted id"
    assert recall_at_k(np.asarray(i8), np.asarray(i32)) > 0.9


# ---------------------------------------------------------------------------
# fleet conformance: a routed fleet is indistinguishable from one engine —
# bitwise on results, and bitwise on every cell's device state after a
# leader delta fan-out (PR-7 acceptance)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("top,bottom", COMBOS)
def test_conformance_fleet_bitwise(top, bottom):
    """Routing must be a pure placement decision: every query answered
    through the ``CellRouter`` is bitwise-identical to a standalone
    control backend, and a leader fan-out (ONE popped manifest applied
    to every cell) leaves every cell's device state bitwise-identical
    to a single-cell delta apply — for every top x bottom combo."""
    from repro.distributed.backend import ShardedSearchBackend
    from repro.launch.mesh import make_cell_meshes
    from repro.serve.fleet import build_fleet

    rng = np.random.default_rng(400 + TOP_ALGOS.index(top) * 10
                                + BOTTOM_ALGOS.index(bottom))
    db = _corpus(rng, N)
    p = rng.dirichlet(np.full(N, 0.5)) if bottom == "qlbt" else None
    idx = _build(db, top, bottom, p)
    meshes = make_cell_meshes(2, share_devices=True)
    bkw = dict(nprobe_local=K, beam_width=8, headroom=1.5)
    control = ShardedSearchBackend(
        meshes[0], idx, k=TOPK, axes=tuple(meshes[0].axis_names), **bkw)
    router = build_fleet(meshes, idx, k=TOPK, backend_kw=bkw,
                         cell_kw=dict(max_wait_ms=0.5))
    try:
        q = _corpus(rng, 8)

        def routed_matches_control():
            for j in range(q.shape[0]):
                dr, ir = router.search(q[j], timeout=30.0)
                dc, ic = control(q[j:j + 1])
                assert np.array_equal(dr, dc[0]) and \
                    np.array_equal(ir, ic[0]), (
                        f"{top}/{bottom}: routed result diverged from "
                        f"the standalone engine")

        routed_matches_control()

        # localized mutation -> ONE pop -> leader fan-out vs single-cell
        b = int(np.argmax(idx.bucket_counts))
        dele = idx.bucket_ids[b][:5].copy()
        idx.delete_entities(dele)
        new = (idx.centroids[1][None, :]
               + 0.1 * rng.normal(size=(5, D))).astype(np.float32)
        idx.add_entities(new)
        man = idx.pop_delta()
        agg = router.apply_updates(idx, delta=man)
        assert agg["mode"] == "delta", agg
        assert set(agg["cells"]) == {c.name for c in router.cells}
        control.apply_updates(idx, delta=man)

        for cell in router.cells:
            for a, c in zip(cell.search_fn._args, control._args):
                assert a.shape == c.shape
                assert np.array_equal(np.asarray(a), np.asarray(c)), (
                    f"{top}/{bottom}: {cell.name} device state diverged "
                    f"from single-cell delta apply")

        routed_matches_control()
        ir = np.stack([router.search(q[j], timeout=30.0)[1]
                       for j in range(q.shape[0])])
        assert not np.isin(ir, dele).any(), (
            f"{top}/{bottom}: deleted id returned through the fleet")
    finally:
        router.close()


def test_conformance_cached_serving_never_stale():
    """The cached serving path must track mutations: a result cached
    before delete+reboost+apply_updates can never resurface."""
    from repro.adaptive import FrequencyAdmissionCache, HostIndexBackend
    from repro.serve.engine import ServingEngine

    rng = np.random.default_rng(200)
    db = _corpus(rng, N)
    p = rng.dirichlet(np.full(N, 0.5))
    idx = _build(db, "brute", "qlbt", p)
    backend = HostIndexBackend(idx, k=5, nprobe=K, beam_width=16)
    cache = FrequencyAdmissionCache(capacity=64)
    eng = ServingEngine(backend, cache=cache, max_wait_ms=0.5)
    try:
        target = int(rng.integers(0, N))
        q = db[target].copy()
        _, ids0 = eng.search(q, timeout=30.0)
        assert target in ids0
        _, ids1 = eng.search(q, timeout=30.0)          # served from cache
        assert eng.stats().cache_hits >= 1
        idx.delete_entities(np.asarray([target]))
        idx.reboost(rng.dirichlet(np.full(N, 0.5)))
        eng.apply_updates(idx)                          # invalidates cache
        _, ids2 = eng.search(q, timeout=30.0)
        assert target not in ids2, "cache served a deleted entity"
    finally:
        eng.close()
