"""Self-test for repro.analysis: each rule family must catch its seeded
violations and stay quiet on the equivalent clean code.

Static rules are exercised through ``run_static_analysis`` on temp files
so the suppression reconciliation is part of the loop; the recompile
gate is exercised through ``run_entry_point`` on synthetic jitted entry
points seeded with the three classic triggers (varying shape, dtype
change, varying non-static arg).  The last test runs the whole static
pass over ``src/repro`` — the tree must be clean, which is exactly what
the CI lint job enforces.
"""
import os
import textwrap

import pytest

from conftest import REPO
from repro.analysis import run_static_analysis
from repro.analysis.recompile import Plan, run_entry_point
from repro.analysis.registry import ENTRY_POINTS, register_entry_point


def lint(tmp_path, source, name="mod.py", **kw):
    p = tmp_path / name
    p.write_text(textwrap.dedent(source))
    active, suppressed = run_static_analysis([str(p)], **kw)
    return active, suppressed


def rules_of(findings):
    return sorted(f.rule for f in findings)


# ---------------------------------------------------------------------------
# rule family 1: JAX compile-path lint
# ---------------------------------------------------------------------------


class TestJaxLint:
    def test_host_sync_three_ways(self, tmp_path):
        active, _ = lint(tmp_path, """
            import jax, numpy as np

            @jax.jit
            def f(x, y, z):
                a = x.item()
                b = float(y.sum())
                c = np.asarray(z)
                return a + b + c.sum()
        """)
        assert rules_of(active) == ["host-sync"] * 3

    def test_host_sync_quiet_on_clean(self, tmp_path):
        # shape/dtype reads are static; jnp.asarray stays on device;
        # .item() outside jit is ordinary host code
        active, _ = lint(tmp_path, """
            import jax, jax.numpy as jnp

            @jax.jit
            def f(x):
                n = x.shape[0]
                y = jnp.asarray(x, dtype=x.dtype)
                return y * n

            def host_side(x):
                return x.item()
        """)
        assert active == []

    def test_traced_branch_if_while_for(self, tmp_path):
        active, _ = lint(tmp_path, """
            import jax

            @jax.jit
            def f(x):
                s = x.sum()
                if s > 0:
                    x = x + 1
                while s > 0:
                    s = s - 1
                for row in x:
                    s = s + row.sum()
                return s
        """)
        assert rules_of(active) == ["traced-branch"] * 3

    def test_branch_on_shape_is_clean(self, tmp_path):
        active, _ = lint(tmp_path, """
            import jax

            @jax.jit
            def f(x):
                if x.shape[0] > 2:
                    return x[:2]
                return x
        """)
        assert active == []

    def test_missing_static_argnames_and_fix(self, tmp_path):
        active, _ = lint(tmp_path, """
            import jax

            @jax.jit
            def f(x, k):
                if k > 3:
                    return x[:3]
                return x[:k]
        """)
        assert rules_of(active) == ["missing-static-argnames"]
        active, _ = lint(tmp_path, """
            import jax
            from functools import partial

            @partial(jax.jit, static_argnames=("k",))
            def f(x, k):
                if k > 3:
                    return x[:3]
                return x[:k]
        """, name="fixed.py")
        assert active == []

    def test_implicit_dtype_three_creations(self, tmp_path):
        active, _ = lint(tmp_path, """
            import jax, jax.numpy as jnp

            @jax.jit
            def f(x):
                a = jnp.zeros(4)
                b = jnp.arange(x.shape[0])
                c = jnp.full((2, 2), 7)
                return a.sum() + b.sum() + c.sum() + x.sum()
        """)
        assert rules_of(active) == ["implicit-dtype"] * 3

    def test_explicit_dtype_is_clean(self, tmp_path):
        active, _ = lint(tmp_path, """
            import jax, jax.numpy as jnp

            @jax.jit
            def f(x):
                a = jnp.zeros(4, dtype=jnp.float32)
                b = jnp.arange(x.shape[0], dtype=jnp.int32)
                return a.sum() + b.sum() + x.sum()
        """)
        assert active == []

    def test_scatter_not_donated_and_donated(self, tmp_path):
        active, _ = lint(tmp_path, """
            import jax

            @jax.jit
            def scatter(db, rows, vals):
                return db.at[rows].set(vals)
        """)
        assert rules_of(active) == ["scatter-not-donated"]
        active, _ = lint(tmp_path, """
            import jax
            from functools import partial

            @partial(jax.jit, donate_argnums=(0,))
            def scatter(db, rows, vals):
                return db.at[rows].set(vals)
        """, name="donated.py")
        assert active == []

    def test_scatter_in_wrap_site_jit(self, tmp_path):
        # jit applied at a wrap site, not as a decorator
        active, _ = lint(tmp_path, """
            import jax

            def scatter(db, rows, vals):
                return db.at[rows].set(vals)

            scatter_j = jax.jit(scatter)
        """)
        assert rules_of(active) == ["scatter-not-donated"]

    def test_non_pow2_pad_vs_bucketed(self, tmp_path):
        active, _ = lint(tmp_path, """
            import jax
            import numpy as np

            @jax.jit
            def kernel(q):
                return q * 2

            def _pow2(n):
                return 1 << max(0, int(n - 1).bit_length())

            def serve_bad(q):
                n = q.shape[0] + 3
                q = np.pad(q, n)
                return kernel(q)

            def serve_good(q):
                n = _pow2(q.shape[0])
                q = np.pad(q, n)
                return kernel(q)

            def serve_const(q):
                q = np.pad(q, 16)
                return kernel(q)
        """)
        assert rules_of(active) == ["non-pow2-pad"]
        assert "serve_bad" in active[0].message

    def test_pad_without_jit_call_is_out_of_scope(self, tmp_path):
        active, _ = lint(tmp_path, """
            import numpy as np

            def host_only(q, n):
                return np.pad(q, n + 3)
        """)
        assert active == []


# ---------------------------------------------------------------------------
# rule family 2: lock discipline
# ---------------------------------------------------------------------------

_LOCKED_CLASS = """
    import threading
    from repro.analysis.annotations import guarded_by

    class Engine:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0          # __init__ writes are exempt
            self.items = []
            self.total = 0

        def good(self):
            with self._lock:
                self.count = 1
                self.items.append(1)
                self.total += 1

        def bad_assign(self):
            self.count = 2

        def bad_mutator(self):
            self.items.append(2)

        def bad_augassign(self):
            self.total += 2
"""


class TestLockDiscipline:
    def test_three_unguarded_write_kinds(self, tmp_path):
        active, _ = lint(tmp_path, _LOCKED_CLASS)
        assert rules_of(active) == ["unguarded-write"] * 3
        msgs = " ".join(f.message for f in active)
        for m in ("bad_assign", "bad_mutator", "bad_augassign"):
            assert m in msgs

    def test_guarded_by_annotation_satisfies(self, tmp_path):
        active, _ = lint(tmp_path, """
            import threading
            from repro.analysis.annotations import guarded_by

            class Engine:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.count = 0

                def caller(self):
                    with self._lock:
                        self._bump()

                @guarded_by("_lock")
                def _bump(self):
                    self.count += 1
        """)
        assert active == []

    def test_unguarded_call_of_guarded_method(self, tmp_path):
        active, _ = lint(tmp_path, """
            import threading
            from repro.analysis.annotations import guarded_by

            class Engine:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.count = 0

                def caller(self):
                    self._bump()

                @guarded_by("_lock")
                def _bump(self):
                    self.count += 1
        """)
        assert rules_of(active) == ["unguarded-call"]

    def test_unknown_lock_annotation(self, tmp_path):
        active, _ = lint(tmp_path, """
            import threading
            from repro.analysis.annotations import guarded_by

            class Engine:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.count = 0

                @guarded_by("_mutex")
                def bump(self):
                    with self._lock:
                        self.count += 1
        """)
        assert "unknown-lock" in rules_of(active)

    def test_closure_runs_without_the_lock(self, tmp_path):
        # a nested def is a thread target: even when the enclosing block
        # holds the lock, the closure body executes later, without it
        active, _ = lint(tmp_path, """
            import threading

            class Engine:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.count = 0

                def tick(self):
                    with self._lock:
                        self.count += 1

                def dispatch(self):
                    with self._lock:
                        def primary():
                            self.count += 1
                        return primary
        """)
        assert rules_of(active) == ["unguarded-write"]
        assert active[0].message.startswith("Engine.dispatch")

    def test_class_without_lock_is_skipped(self, tmp_path):
        active, _ = lint(tmp_path, """
            class Plain:
                def __init__(self):
                    self.count = 0

                def bump(self):
                    self.count += 1
        """)
        assert active == []

    def test_obs_instruments_exempt_from_lock_discipline(self, tmp_path):
        # attrs initialized from a repro.obs constructor in __init__ are
        # internally locked: writes to them mixed under/outside the
        # designated lock raise no finding (and infer no guard), while a
        # plain list in the same class keeps the full discipline — no
        # `# repro: allow` waiver involved
        active, _ = lint(tmp_path, """
            import threading
            from repro.obs.metrics import MetricsRegistry, Histogram

            class Engine:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.metrics = MetricsRegistry()
                    self._h = self.metrics.histogram("latency_ms")
                    self._c = self.metrics.counter("hits")
                    self.samples = []

                def locked_path(self):
                    with self._lock:
                        self._h = Histogram("latency_ms")
                        self.samples.append(1)

                def unlocked_path(self):
                    self._h = Histogram("latency_ms")
                    self._c = self.metrics.counter("hits")
                    self.samples.append(2)
        """)
        assert rules_of(active) == ["unguarded-write"]
        assert "self.samples" in active[0].message


# ---------------------------------------------------------------------------
# suppression hygiene
# ---------------------------------------------------------------------------


class TestSuppressions:
    def test_same_line_suppression(self, tmp_path):
        active, suppressed = lint(tmp_path, """
            import jax, jax.numpy as jnp

            @jax.jit
            def f(x):
                return jnp.zeros(4) + x  # repro: allow(implicit-dtype): seeded
        """)
        assert active == []
        assert rules_of(suppressed) == ["implicit-dtype"]

    def test_line_above_suppression(self, tmp_path):
        active, suppressed = lint(tmp_path, """
            import jax, jax.numpy as jnp

            @jax.jit
            def f(x):
                # repro: allow(implicit-dtype): seeded
                return jnp.zeros(4) + x
        """)
        assert active == []
        assert rules_of(suppressed) == ["implicit-dtype"]

    def test_bare_allow_is_reported(self, tmp_path):
        active, _ = lint(tmp_path, """
            import jax, jax.numpy as jnp

            @jax.jit
            def f(x):
                return jnp.zeros(4) + x  # repro: allow(implicit-dtype)
        """)
        assert "bad-suppression" in rules_of(active)

    def test_unknown_rule_id_is_reported(self, tmp_path):
        active, _ = lint(tmp_path, """
            x = 1  # repro: allow(made-up-rule): no such rule
        """)
        assert "unknown-rule" in rules_of(active)
        assert "unused-suppression" in rules_of(active)

    def test_unused_suppression_is_reported(self, tmp_path):
        active, _ = lint(tmp_path, """
            x = 1  # repro: allow(host-sync): nothing to suppress here
        """)
        assert rules_of(active) == ["unused-suppression"]

    def test_suppression_does_not_leak_to_far_lines(self, tmp_path):
        active, _ = lint(tmp_path, """
            import jax, jax.numpy as jnp

            # repro: allow(implicit-dtype): too far away to cover

            @jax.jit
            def f(x):
                return jnp.zeros(4) + x
        """)
        assert rules_of(active) == ["implicit-dtype", "unused-suppression"]


# ---------------------------------------------------------------------------
# rule family 3: recompile-stability gate (synthetic seeded entry points)
# ---------------------------------------------------------------------------


def _jitted_sum():
    import jax

    @jax.jit
    def f(x):
        return x.sum()

    return f


def _plan_of(steps, fn, warmup=1):
    return Plan(steps=steps,
                cache_size=lambda: fn._cache_size(),
                warmup_steps=warmup)


class TestRecompileGate:
    def test_varying_shape_triggers(self):
        import numpy as np

        f = _jitted_sum()

        def builder():
            return _plan_of(
                [("warmup", lambda: f(np.zeros(4, np.float32))),
                 ("grown-shape", lambda: f(np.zeros(5, np.float32)))], f)

        found = run_entry_point("seeded-shape", builder)
        assert rules_of(found) == ["recompile"]
        assert "grown-shape" in found[0].message

    def test_dtype_change_triggers(self):
        import numpy as np

        f = _jitted_sum()

        def builder():
            return _plan_of(
                [("warmup", lambda: f(np.zeros(4, np.float32))),
                 ("dtype-change", lambda: f(np.zeros(4, np.int32)))], f)

        found = run_entry_point("seeded-dtype", builder)
        assert rules_of(found) == ["recompile"]

    def test_varying_static_arg_triggers(self):
        import jax
        import numpy as np
        from functools import partial

        @partial(jax.jit, static_argnames=("k",))
        def topk(x, k):
            return jax.lax.top_k(x, k)

        def builder():
            x = np.arange(8, dtype=np.float32)
            return _plan_of(
                [("warmup", lambda: topk(x, 2)),
                 ("new-static-value", lambda: topk(x, 3))], topk)

        found = run_entry_point("seeded-static", builder)
        assert rules_of(found) == ["recompile"]

    def test_stable_shapes_stay_quiet(self):
        import numpy as np

        f = _jitted_sum()
        x = np.zeros(4, np.float32)

        def builder():
            return _plan_of(
                [("warmup", lambda: f(x)),
                 ("repeat-1", lambda: f(x + 1)),
                 ("repeat-2", lambda: f(x + 2))], f)

        assert run_entry_point("seeded-stable", builder) == []

    def test_multi_bucket_warmup_is_respected(self):
        import numpy as np

        f = _jitted_sum()

        def builder():
            return _plan_of(
                [("warmup-a", lambda: f(np.zeros(4, np.float32))),
                 ("warmup-b", lambda: f(np.zeros(8, np.float32))),
                 ("replay-a", lambda: f(np.ones(4, np.float32))),
                 ("replay-b", lambda: f(np.ones(8, np.float32)))],
                f, warmup=2)

        assert run_entry_point("seeded-two-buckets", builder) == []

    def test_builder_failure_is_a_finding(self):
        def builder():
            raise RuntimeError("boom")

        found = run_entry_point("seeded-broken", builder)
        assert rules_of(found) == ["entry-point-error"]
        assert "boom" in found[0].message

    def test_step_failure_is_a_finding(self):
        f = _jitted_sum()

        def bad_step():
            raise ValueError("step boom")

        def builder():
            return _plan_of([("bad", bad_step)], f)

        found = run_entry_point("seeded-bad-step", builder)
        assert rules_of(found) == ["entry-point-error"]
        assert "step boom" in found[0].message

    def test_register_entry_point_shadowing(self):
        before = dict(ENTRY_POINTS)
        try:
            @register_entry_point("seeded-shadow")
            def _seed():
                return Plan(steps=[], cache_size=lambda: 0)

            assert ENTRY_POINTS["seeded-shadow"] is _seed

            @register_entry_point("seeded-shadow")
            def _seed2():
                return Plan(steps=[], cache_size=lambda: 0)

            assert ENTRY_POINTS["seeded-shadow"] is _seed2
        finally:
            ENTRY_POINTS.clear()
            ENTRY_POINTS.update(before)

    def test_real_entry_points_are_registered(self):
        for name in ("sharded-brute-search", "brute-delta-scatter",
                     "sharded-ivf-search", "sharded-forest-search",
                     "fused-sharded-search", "fleet-router-search"):
            assert name in ENTRY_POINTS


# ---------------------------------------------------------------------------
# the gate the CI lint job enforces: src/repro itself is clean
# ---------------------------------------------------------------------------


def test_repo_tree_is_clean():
    active, _ = run_static_analysis([os.path.join(REPO, "src", "repro")])
    assert active == [], "\n".join(f.format() for f in active)
