"""repro.adaptive: sketch, estimator, cache, scheduler, reboost loop.

The acceptance check mirrors benchmarks/fig6_adaptive.py at test scale: on
a drifting-Zipf workload the sketch -> drift -> reboost path must recover
at least half of the mean-work gap between a stale-boosted tree and an
oracle rebuild, with the reboost measurably cheaper than the rebuild and
no stale/deleted id ever returned.
"""
import time

import numpy as np
import pytest

from repro.adaptive import (
    CountMinSketch,
    FrequencyAdmissionCache,
    HostIndexBackend,
    MaintenanceScheduler,
    OnlineLikelihoodEstimator,
)
from repro.core.likelihood import (
    decayed_empirical_likelihood,
    empirical_likelihood,
    zipf_likelihood,
)
from repro.serve.engine import ServingEngine

N, D = 2048, 64


# ---------------------------------------------------------------------------
# sketch
# ---------------------------------------------------------------------------


def test_sketch_overestimates_and_tracks_heavy_hitters():
    rng = np.random.default_rng(0)
    s = CountMinSketch(width=1024, depth=4, topk=16, seed=0)
    ids = rng.choice(100, 4000, p=zipf_likelihood(100, 1.2))
    for lo in range(0, ids.size, 512):
        s.update(ids[lo : lo + 512])
    true = np.bincount(ids, minlength=100)
    est = s.query(np.arange(100))
    assert (est >= true - 1e-3).all(), "CMS estimates must be conservative"
    hh, he = s.heavy_hitters()
    top5 = set(np.argsort(true)[::-1][:5].tolist())
    assert len(top5 & set(hh.tolist())) >= 4
    assert (np.diff(he) <= 1e-6).all(), "heavy hitters sorted descending"


def test_sketch_decay_fades_old_traffic():
    s = CountMinSketch(width=1024, depth=4, topk=8, halflife=100, seed=0)
    s.update(np.zeros(200, np.int64))
    s.update(np.ones(400, np.int64))         # id 0 decayed by 0.5**4
    e = s.query(np.array([0, 1]))
    assert e[0] < 0.2 * 200 and e[1] >= 400 - 1e-3
    s.reset()
    assert s.query(np.array([0, 1])).sum() == 0 and s.n_observed == 0


def test_sketch_width_must_be_power_of_two():
    with pytest.raises(ValueError, match="power of two"):
        CountMinSketch(width=1000)


# ---------------------------------------------------------------------------
# likelihood helpers
# ---------------------------------------------------------------------------


def test_decayed_empirical_likelihood_chains_and_degenerates():
    rng = np.random.default_rng(1)
    log = rng.integers(0, 50, 300)
    p_once = decayed_empirical_likelihood(log, 50, 64.0)
    _, c1 = decayed_empirical_likelihood(log[:120], 50, 64.0,
                                         return_counts=True)
    p_chain = decayed_empirical_likelihood(log[120:], 50, 64.0,
                                           prior_counts=c1)
    np.testing.assert_allclose(p_chain, p_once, rtol=1e-10)
    # halflife=inf recovers the undecayed estimator exactly
    np.testing.assert_allclose(
        decayed_empirical_likelihood(log, 50, np.inf),
        empirical_likelihood(log, 50), rtol=1e-12)
    # recency: with a short halflife the newest id dominates the oldest
    p = decayed_empirical_likelihood(np.array([7] * 50 + [9] * 50), 10, 5.0,
                                     smoothing=0.0)
    assert p[9] > 0.9 and p[7] < 0.1
    with pytest.raises(ValueError, match="out of range"):
        decayed_empirical_likelihood(np.array([50]), 50, 8.0)


# ---------------------------------------------------------------------------
# estimator
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("width", [4096, None])
def test_estimator_drift_detects_rotation_and_resets(width):
    rng = np.random.default_rng(2)
    n = 512
    z = zipf_likelihood(n, 1.2)
    p0 = np.empty(n)
    p0[rng.permutation(n)] = z
    p1 = np.empty(n)
    p1[rng.permutation(n)] = z
    est = OnlineLikelihoodEstimator(n, reference=p0, halflife=1024,
                                    width=width)
    for _ in range(8):
        est.observe(rng.choice(n, 256, p=p0))
    stationary = est.drift()
    for _ in range(8):
        est.observe(rng.choice(n, 256, p=p1))
    drifted = est.drift()
    assert drifted["tv"] > stationary["tv"] + 0.2, (stationary, drifted)
    assert drifted["kl"] > stationary["kl"]
    # re-anchoring on the current estimate resets the gauge
    est.set_reference(est.likelihood())
    assert est.drift()["tv"] < stationary["tv"] + 0.05


def test_estimator_sketch_matches_exact_counts():
    rng = np.random.default_rng(3)
    n = 256
    p = zipf_likelihood(n, 1.2)
    obs = rng.choice(n, 4000, p=p)
    sk = OnlineLikelihoodEstimator(n, halflife=1e9, width=4096)
    ex = OnlineLikelihoodEstimator(n, halflife=1e9, width=None)
    sk.observe(obs)
    ex.observe(obs)
    tv = 0.5 * np.abs(sk.likelihood() - ex.likelihood()).sum()
    assert tv < 0.02, tv
    hh, _ = sk.heavy_hitters()
    assert np.argmax(np.bincount(obs)) in hh


def test_estimator_ignores_invalid_ids():
    est = OnlineLikelihoodEstimator(16, width=None)
    assert est.observe(np.array([-1, 3, 99, 5])) == 2
    assert est.n_total == 2


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------


def test_cache_frequency_admission_protects_head():
    cache = FrequencyAdmissionCache(capacity=4)
    keys = [cache.key_for(np.full(3, i, np.float32)) for i in range(8)]
    for _ in range(5):
        cache.get(keys[0])                    # key 0 is hot
    cache.offer(keys[0], "r0")
    for i in range(1, 4):
        cache.get(keys[i])
        cache.offer(keys[i], f"r{i}")
    cache.get(keys[7])                        # cold one-off
    assert not cache.offer(keys[7], "r7"), "cold key must not evict"
    assert cache.get(keys[0]) == "r0"
    st = cache.stats()
    assert st["rejected"] == 1 and st["size"] == 4


def test_cache_generation_guard_drops_stale_offers():
    cache = FrequencyAdmissionCache(capacity=8)
    q = np.arange(4, dtype=np.float32)
    key = cache.key_for(q)
    cache.get(key)
    gen = cache.generation
    cache.invalidate_all()                    # index mutated mid-flight
    assert not cache.offer(key, "stale", generation=gen)
    assert cache.get(key) is None
    assert cache.offer(key, "fresh", generation=cache.generation)
    assert cache.get(key) == "fresh"


def test_cache_key_distinguishes_dtype_and_shape():
    cache = FrequencyAdmissionCache()
    a = np.zeros(4, np.float32)
    assert cache.key_for(a) != cache.key_for(a.astype(np.float64))
    assert cache.key_for(a) != cache.key_for(a.reshape(2, 2))
    assert cache.key_for(a) == cache.key_for(np.zeros(4, np.float32))


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------


def test_engine_search_timeout_raises():
    def slow_fn(qs):
        time.sleep(0.5)
        b = qs.shape[0]
        return np.zeros((b, 1), np.float32), np.zeros((b, 1), np.int32)

    eng = ServingEngine(slow_fn, max_wait_ms=0.1)
    try:
        with pytest.raises(TimeoutError, match="timed out"):
            eng.search(np.zeros(4, np.float32), timeout=0.05)
        # and a sane timeout still gets the answer
        d, i = eng.search(np.zeros(4, np.float32), timeout=5.0)
        assert i.shape == (1,)
    finally:
        eng.close()


class _VersionedBackend:
    """Returns ids stamped with the current index 'version'."""

    def __init__(self):
        self.version = 0

    def __call__(self, qs):
        b = qs.shape[0]
        return (np.zeros((b, 1), np.float32),
                np.full((b, 1), self.version, np.int32))

    def apply_updates(self, target, **kw):
        self.version = target


def test_engine_apply_updates_invalidates_cache():
    """Stale-result regression: after apply_updates the cache must never
    serve results computed against the old index."""
    backend = _VersionedBackend()
    cache = FrequencyAdmissionCache(capacity=32)
    eng = ServingEngine(backend, cache=cache, max_wait_ms=0.1)
    try:
        q = np.arange(6, dtype=np.float32)
        _, i0 = eng.search(q, timeout=5.0)
        assert i0[0] == 0
        _, i1 = eng.search(q, timeout=5.0)    # cache hit, same version
        assert i1[0] == 0 and eng.stats().cache_hits == 1
        eng.apply_updates(7)                  # index mutated
        _, i2 = eng.search(q, timeout=5.0)
        assert i2[0] == 7, "cache served a stale pre-update result"
    finally:
        eng.close()


def test_engine_estimator_sees_hits_and_misses():
    backend = _VersionedBackend()
    backend.version = 3
    est = OnlineLikelihoodEstimator(16, width=None)
    cache = FrequencyAdmissionCache(capacity=8)
    eng = ServingEngine(backend, cache=cache, estimator=est,
                        max_wait_ms=0.1)
    try:
        q = np.arange(5, dtype=np.float32)
        eng.search(q, timeout=5.0)            # miss -> engine observes
        for _ in range(3):
            eng.search(q, timeout=5.0)        # hits -> observed too
        deadline = time.time() + 5
        while est.n_total < 4 and time.time() < deadline:
            time.sleep(0.01)                  # worker observe is async
        assert est.n_total == 4, est.n_total
        assert eng.stats().cache_hits == 3
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------


class _StubEstimator:
    def __init__(self):
        self.tv = 0.0
        self.mass = 1e9
        self.n_total = 10_000
        self.reference = None

    def drift(self):
        return {"tv": self.tv, "kl": 0.0, "n_observed": self.mass}

    def likelihood(self):
        return np.full(8, 1 / 8)

    def set_reference(self, p):
        self.reference = p
        self.tv = 0.0


class _StubIndex:
    def __init__(self):
        self.calls = []
        self.two_level = object()      # make rebalance="auto" chain it

    def reboost(self, p):
        self.calls.append("reboost")
        return {"n_reboosted": 1}

    def rebalance(self):
        self.calls.append("rebalance")
        return {"n_drifted": 0}


class _StubEngine:
    def __init__(self):
        self.published = []

    def apply_updates(self, target):
        self.published.append(target)


def test_scheduler_trigger_chain_and_cooldown():
    est, idx, eng = _StubEstimator(), _StubIndex(), _StubEngine()
    sched = MaintenanceScheduler(est, idx, engine=eng, interval_s=None,
                                 drift_threshold=0.3,
                                 min_observations=100,
                                 cooldown_observations=500)
    assert sched.check_now() is None          # no drift
    est.tv = 0.9
    ev = sched.check_now()
    assert ev is not None and idx.calls == ["reboost", "rebalance"]
    assert eng.published == [idx]             # republished through engine
    assert est.reference is not None          # re-anchored
    est.tv = 0.9
    assert sched.check_now() is None, "cooldown must debounce"
    est.n_total += 600                        # fresh traffic arrives
    assert sched.check_now() is not None
    assert sched.n_reboosts == 2


def test_scheduler_gates_on_observation_mass():
    est, idx = _StubEstimator(), _StubIndex()
    est.tv, est.mass = 0.9, 10.0
    sched = MaintenanceScheduler(est, idx, interval_s=None,
                                 min_observations=100)
    assert sched.check_now() is None and idx.calls == []


def test_scheduler_background_thread_fires_and_survives_errors():
    est, idx = _StubEstimator(), _StubIndex()
    cache = FrequencyAdmissionCache(capacity=4)
    est.tv = 0.9
    boom = {"n": 0}

    def on_event(ev):
        boom["n"] += 1
        if boom["n"] == 1:
            raise RuntimeError("observer exploded")

    sched = MaintenanceScheduler(est, idx, cache=cache, interval_s=0.02,
                                 min_observations=100,
                                 cooldown_observations=0,
                                 on_event=on_event)
    try:
        deadline = time.time() + 5
        while boom["n"] < 2 and time.time() < deadline:
            est.tv = 0.9                      # re-arm after reset
            time.sleep(0.02)
        assert boom["n"] >= 2, "thread died after the first error"
        assert isinstance(sched.last_error, RuntimeError)
        assert cache.generation >= 1          # engine-less invalidation
    finally:
        sched.close()
    assert not sched._thread.is_alive()


# ---------------------------------------------------------------------------
# reboost acceptance (fig6 at test scale)
# ---------------------------------------------------------------------------


def _drift_corpus(seed=0):
    rng = np.random.default_rng(seed)
    c = rng.normal(size=(N // 8, D)).astype(np.float32)
    db = (c[:, None, :] + 0.8 * rng.normal(size=(N // 8, 8, D)))
    db = db.reshape(-1, D)[:N].astype(np.float32)
    z = zipf_likelihood(N, 1.1)
    p0 = np.empty(N)
    p0[rng.permutation(N)] = z
    p1 = np.empty(N)
    p1[rng.permutation(N)] = z
    return rng, db, p0, p1


def test_reboost_recovers_half_the_work_gap_and_is_cheaper():
    import jax.numpy as jnp

    from repro.core.likelihood import sample_queries
    from repro.core.metrics import recall_at_k
    from repro.core.tree import build_qlbt, tree_search

    rng, db, p0, p1 = _drift_corpus(0)
    stale = build_qlbt(db, p0, seed=1, n_candidates=16, lam=0.2)
    oracle = build_qlbt(db, p1, seed=1, n_candidates=16, lam=0.2)

    # the adaptive path: estimator observes traffic under p1, reboost
    # fires from its estimate (not from the true p1)
    est = OnlineLikelihoodEstimator(N, reference=p0, halflife=1024)
    for _ in range(8):
        est.observe(rng.choice(N, 256, p=p1))
    assert est.drift()["tv"] > 0.3
    reb = stale.reboost(db, est.likelihood(), seed=2, n_candidates=8,
                        lam=0.2)

    # entity set preserved exactly, ids unique
    for t in (stale, reb):
        flat = t.leaf_entities[t.leaf_entities >= 0]
        assert flat.size == np.unique(flat).size
    assert np.array_equal(
        np.sort(stale.leaf_entities[stale.leaf_entities >= 0]),
        np.sort(reb.leaf_entities[reb.leaf_entities >= 0]))

    q, gt = sample_queries(rng, db, p1, 1024, noise_scale=0.05)
    dbj, qj = jnp.asarray(db), jnp.asarray(q)

    def measure(tree):
        res = tree_search(tree.device_arrays(), dbj, qj, beam_width=4,
                          k=10, max_steps=tree.max_depth + 4)
        work = np.asarray(res.internal_visits) + np.asarray(res.candidates)
        return float(work.mean()), recall_at_k(np.asarray(res.ids), gt)

    w_stale, r_stale = measure(stale)
    w_reb, r_reb = measure(reb)
    w_oracle, _ = measure(oracle)
    gap = w_stale - w_oracle
    assert gap > 0, f"no stale->oracle gap to recover ({w_stale} vs " \
                    f"{w_oracle}); workload regression"
    recovered = (w_stale - w_reb) / gap
    assert recovered >= 0.5, (
        f"adaptive recovered {recovered:.2f} of the work gap "
        f"(stale={w_stale:.1f} reb={w_reb:.1f} oracle={w_oracle:.1f})")
    assert r_reb >= r_stale - 0.02, (r_reb, r_stale)


@pytest.mark.slow
def test_reboost_measurably_cheaper_than_rebuild_at_scale():
    """Cost acceptance: reboost rebuilds only the ~log2(M) top levels, so
    it must beat a from-scratch QLBT build — a scaling property, asserted
    at a corpus size where per-level entity work dominates the fixed
    bookkeeping (at toy sizes the build's shallow recursion is too cheap
    to lose)."""
    from repro.core.likelihood import zipf_likelihood as _z
    from repro.core.tree import build_qlbt

    rng = np.random.default_rng(0)
    n, d = 16384, 64
    c = rng.normal(size=(n // 8, d)).astype(np.float32)
    db = (c[:, None, :] + 0.8 * rng.normal(size=(n // 8, 8, d)))
    db = db.reshape(-1, d)[:n].astype(np.float32)
    p0 = np.empty(n)
    p0[rng.permutation(n)] = _z(n, 1.1)
    p1 = np.empty(n)
    p1[rng.permutation(n)] = _z(n, 1.1)
    t0 = time.perf_counter()
    stale = build_qlbt(db, p0, seed=1, n_candidates=16, lam=0.2)
    t_build = time.perf_counter() - t0
    t0 = time.perf_counter()
    stale.reboost(db, p1, seed=2, n_candidates=8, lam=0.2)
    t_reboost = time.perf_counter() - t0
    assert t_reboost < t_build, (
        f"reboost ({t_reboost:.2f}s) not cheaper than build "
        f"({t_build:.2f}s)")


def test_reboost_never_returns_deleted_and_base_survives_mutation():
    """Conformance of the reboosted + mutated two-level path: deletes stay
    invisible through repeated reboosts (the reboost base must track
    tombstones), adds become findable, bucket invariants hold."""
    from repro.core.two_level import TwoLevelConfig, build_two_level

    rng = np.random.default_rng(4)
    n0, d, K = 1200, 16, 20
    c = rng.normal(size=(10, d)) * 4

    def mk(m):
        return (c[rng.integers(0, 10, m)]
                + rng.normal(size=(m, d))).astype(np.float32)

    db = mk(n0)
    p = rng.dirichlet(np.full(n0, 0.5))
    cfg = TwoLevelConfig(n_clusters=K, top="brute", bottom="qlbt",
                         kmeans_iters=4, kmeans_minibatch=None, tree_leaf=8)
    idx = build_two_level(db, cfg, p=p)
    idx.reboost(rng.dirichlet(np.full(idx.n, 0.5)))   # base_trees created
    # delete whole buckets' membership (keeps other buckets clean so the
    # second reboost exercises BOTH paths: fresh rebuild of the dirty
    # buckets and top-level re-split of the untouched ones)
    dele = np.nonzero(np.isin(idx.entity_bucket, [0, 1]))[0][:150]
    idx.delete_entities(dele)                          # after first reboost
    new_ids = idx.add_entities(mk(40), refresh=False)
    stats = idx.reboost(rng.dirichlet(np.full(idx.n, 0.5)))
    assert stats["n_refreshed"] > 0, stats
    assert stats["n_reboosted"] > 0, stats
    q = mk(64)
    _, ids, _ = idx.search(q, 10, nprobe=K, beam_width=16)
    assert not np.isin(ids, dele).any(), "reboost resurrected deleted ids"
    le = np.asarray(idx.forest.arrays["leaf_entities"])
    live = np.nonzero(idx.alive)[0]
    assert np.array_equal(np.sort(le[le >= 0]), live)
    _, got, _ = idx.search(idx.db[new_ids][:32], 1, nprobe=K, beam_width=16)
    assert (np.asarray(got)[:, 0] >= n0).mean() > 0.85


def test_search_index_repeated_reboost_from_base_no_erosion():
    from repro.core.index import build_index
    from repro.core.protocol import IndexSpec

    rng, db, p0, p1 = _drift_corpus(5)
    si = build_index(IndexSpec(kind="qlbt"), db, p=p0)
    probe = si.db[100:164]
    _, got0, _ = si.search(probe, 1, beam_width=8)
    acc0 = (np.asarray(got0)[:, 0] == np.arange(100, 164)).mean()
    for r in range(5):                         # repeated drift cycles
        pr = np.empty(N)
        pr[rng.permutation(N)] = zipf_likelihood(N, 1.1)
        si.reboost(pr, seed=r)
    assert si.base_tree is not None
    _, got, _ = si.search(probe, 1, beam_width=8)
    acc = (np.asarray(got)[:, 0] == np.arange(100, 164)).mean()
    assert acc >= acc0 - 0.05, (
        f"repeated reboosts eroded recall {acc0:.3f} -> {acc:.3f}")
