"""Fleet tier tests: cell cancellation/failure semantics, router
admission/affinity/hedging/rerouting, leader fan-out, and submesh
partitioning.  Conformance (bitwise router-vs-engine and fan-out
parity) lives in test_conformance.py; these tests cover the *behavior*
the fleet adds on top of a correct cell."""
import threading
import time

import numpy as np
import pytest

from repro.serve.cell import CellFailure, ServingCell
from repro.serve.fleet import CellRouter, FleetOverloadError, build_fleet


def _ok_fn(qs):
    b = qs.shape[0]
    return (np.zeros((b, 3), np.float32),
            np.tile(np.arange(3), (b, 1)).astype(np.int64))


def _slow_fn(delay_s):
    def fn(qs):
        time.sleep(delay_s)
        return _ok_fn(qs)

    return fn


def _query(rng):
    return rng.normal(size=(4,)).astype(np.float32)


def _query_for(router, rng, cell_name):
    """A query whose affinity-preferred cell is ``cell_name``."""
    for _ in range(1000):
        q = _query(rng)
        if router.preferred_cell(q).name == cell_name:
            return q
    raise AssertionError(f"no query routed to {cell_name} in 1000 draws")


# ---------------------------------------------------------------------------
# cell: timeout cancellation (the PR-7 leak fix) and failure sentinels
# ---------------------------------------------------------------------------


def test_cell_timeout_cancels_and_excludes_from_stats():
    """A timed-out request must be dropped by the batch worker — not
    computed anyway — and must never land in the latency stats (the
    pre-PR-7 leak: it stayed queued, was later served to nobody, and
    its enormous latency polluted the percentiles)."""
    cell = ServingCell(_slow_fn(0.3), name="slow", max_wait_ms=0.5)
    try:
        with pytest.raises(TimeoutError):
            cell.search(np.ones(4, np.float32), timeout=0.05)
        time.sleep(0.8)                      # let the worker churn past it
        st = cell.stats()
        assert st.cancelled == 1
        assert st.n == 0, "abandoned request landed in latency stats"
        # the cell still serves fine afterwards
        d, i = cell.search(np.ones(4, np.float32), timeout=5.0)
        assert d.shape == (3,)
        st = cell.stats()
        assert st.n == 1 and st.cancelled == 1
    finally:
        cell.close()


def test_cell_backend_failure_fails_fast_not_timeout():
    """A backend exception must surface as an immediate error on every
    request of the batch (CellFailure sentinel), not as a 30s timeout,
    and must not kill the batch worker."""

    boom = {"on": True}

    def flaky(qs):
        if boom["on"]:
            raise RuntimeError("boom")
        return _ok_fn(qs)

    cell = ServingCell(flaky, name="flaky", max_wait_ms=0.5)
    try:
        t0 = time.perf_counter()
        with pytest.raises(RuntimeError, match="backend failed"):
            cell.search(np.ones(4, np.float32), timeout=10.0)
        assert time.perf_counter() - t0 < 5.0, "failure took the timeout path"
        assert isinstance(cell.failure(), RuntimeError)
        boom["on"] = False                    # worker survived the raise
        d, _ = cell.search(np.ones(4, np.float32), timeout=5.0)
        assert d.shape == (3,)
    finally:
        cell.close()


def test_cell_close_fails_queued_requests():
    cell = ServingCell(_slow_fn(0.5), name="c", max_wait_ms=0.5,
                       max_batch=1)
    fut1 = cell.submit(np.ones(4, np.float32))
    fut2 = cell.submit(np.ones(4, np.float32))
    cell.close()
    # whatever was still queued at close resolves to CellFailure, so a
    # routed caller re-dispatches instead of waiting out its timeout
    outs = [fut1.get(timeout=6.0), fut2.get(timeout=6.0)]
    assert any(isinstance(o, CellFailure) for o in outs)


# ---------------------------------------------------------------------------
# router: admission, affinity, hedging, rerouting
# ---------------------------------------------------------------------------


def test_router_admission_sheds_with_retriable_signal():
    gate = threading.Event()

    def blocked(qs):
        gate.wait(60.0)       # generous: a loaded CI box must not let
        return _ok_fn(qs)     # the queue drain before the shed probe

    cell = ServingCell(blocked, name="cell0", max_wait_ms=0.5, max_batch=1)
    router = CellRouter([cell], max_queue_depth=2)
    try:
        threads = [
            threading.Thread(
                target=lambda j=j: router.search(
                    np.full(4, j, np.float32), timeout=90.0),
                daemon=True)
            for j in range(3)]                # 1 in compute + 2 queued
        for t in threads:
            t.start()
        deadline = time.perf_counter() + 15.0
        while cell.depth() < 2 and time.perf_counter() < deadline:
            time.sleep(0.01)
        assert cell.depth() >= 2, "setup never saturated the queue"
        with pytest.raises(FleetOverloadError) as ei:
            router.search(np.full(4, 99, np.float32), timeout=1.0)
        assert ei.value.retriable is True
        assert router.stats().shed == 1
    finally:
        gate.set()
        for t in threads:
            t.join(timeout=5.0)
        router.close()


def test_router_affinity_is_stable_and_balanced():
    cells = [ServingCell(_ok_fn, name=f"cell{i}", max_wait_ms=0.5)
             for i in range(4)]
    router = CellRouter(cells)
    try:
        rng = np.random.default_rng(0)
        qs = [_query(rng) for _ in range(400)]
        first = [router.preferred_cell(q).name for q in qs]
        again = [router.preferred_cell(q).name for q in qs]
        assert first == again, "affinity not deterministic"
        counts = {n: first.count(n) for n in set(first)}
        assert len(counts) == 4
        assert all(c > 400 / 4 / 3 for c in counts.values()), (
            f"rendezvous badly unbalanced: {counts}")
    finally:
        router.close()


def test_router_affinity_remaps_only_failed_cells_keys():
    """Rendezvous property: when a cell dies, only ITS keys move —
    survivors keep their cache heads."""
    cells = [ServingCell(_ok_fn, name=f"cell{i}", max_wait_ms=0.5)
             for i in range(4)]
    router = CellRouter(cells)
    try:
        rng = np.random.default_rng(1)
        qs = [_query(rng) for _ in range(300)]
        before = [router.preferred_cell(q).name for q in qs]
        with router._lock:
            router._mark_down("cell2", RuntimeError("x"))
        after = [router.preferred_cell(q).name for q in qs]
        for b, a in zip(before, after):
            if b != "cell2":
                assert a == b, "a healthy cell's key moved on failure"
            else:
                assert a != "cell2"
        router.revive("cell2")
        assert [router.preferred_cell(q).name for q in qs] == before
    finally:
        router.close()


def test_router_cross_cell_hedge():
    """A straggling primary mesh must not stall the request: after
    hedge_ms the router duplicates onto a different cell and the fast
    cell's answer wins."""
    cells = [ServingCell(_slow_fn(0.5), name="cell0", max_wait_ms=0.5),
             ServingCell(_ok_fn, name="cell1", max_wait_ms=0.5)]
    router = CellRouter(cells, hedge_ms=30.0)
    try:
        q = _query_for(router, np.random.default_rng(2), "cell0")
        t0 = time.perf_counter()
        d, _ = router.search(q, timeout=10.0)
        elapsed = time.perf_counter() - t0
        assert d.shape == (3,)
        assert elapsed < 0.4, f"hedge did not win: {elapsed:.3f}s"
        assert router.stats().hedge_cell == 1
    finally:
        router.close()


def test_router_reroutes_on_cell_failure():
    def failing(qs):
        raise RuntimeError("dead mesh")

    cells = [ServingCell(failing, name="cell0", max_wait_ms=0.5),
             ServingCell(_ok_fn, name="cell1", max_wait_ms=0.5)]
    router = CellRouter(cells)
    try:
        rng = np.random.default_rng(3)
        q = _query_for(router, rng, "cell0")
        d, _ = router.search(q, timeout=10.0)        # rerouted, not raised
        assert d.shape == (3,)
        st = router.stats()
        assert st.rerouted == 1
        assert "cell0" in router.down_cells()
        # admission now avoids the downed cell entirely
        assert router.preferred_cell(q).name == "cell1"
        # all cells down -> shed with the retriable signal
        with router._lock:
            router._mark_down("cell1", RuntimeError("x"))
        with pytest.raises(FleetOverloadError):
            router.search(q, timeout=1.0)
    finally:
        router.close()


def test_router_zero_lost_requests_under_cell_failure():
    """The fig8 acceptance at test scale: a cell failing mid-stream
    loses NOTHING — every request completes via fail-fast rerouting."""
    switch = threading.Event()

    def flaky(qs):
        if switch.is_set():
            raise RuntimeError("injected failure")
        return _ok_fn(qs)

    cells = [ServingCell(flaky, name="cell0", max_wait_ms=0.5),
             ServingCell(_ok_fn, name="cell1", max_wait_ms=0.5),
             ServingCell(_ok_fn, name="cell2", max_wait_ms=0.5)]
    router = CellRouter(cells, max_queue_depth=64)
    try:
        rng = np.random.default_rng(4)
        queries = [_query(rng) for _ in range(60)]
        ok, errors = [], []

        def client(chunk):
            for q in chunk:
                try:
                    d, _ = router.search(q, timeout=15.0)
                    ok.append(d.shape)
                except Exception as e:       # noqa: BLE001 — counting loss
                    errors.append(e)

        chunks = [queries[i::6] for i in range(6)]
        threads = [threading.Thread(target=client, args=(c,), daemon=True)
                   for c in chunks]
        for t in threads:
            t.start()
        time.sleep(0.02)
        switch.set()                          # cell0 dies mid-stream
        for t in threads:
            t.join(timeout=30.0)
        assert not errors, f"lost {len(errors)} requests: {errors[:3]}"
        assert len(ok) == 60
    finally:
        router.close()


def test_router_search_uses_affinity_cell_cache():
    from repro.adaptive import FrequencyAdmissionCache

    cells = [ServingCell(_ok_fn, name=f"cell{i}", max_wait_ms=0.5,
                         cache=FrequencyAdmissionCache(capacity=32))
             for i in range(2)]
    router = CellRouter(cells)
    try:
        q = _query(np.random.default_rng(5))
        pref = router.preferred_cell(q)
        router.search(q, timeout=5.0)
        router.search(q, timeout=5.0)         # exact repeat: cache hit
        assert pref.cache.hits >= 1
        other = next(c for c in router.cells if c is not pref)
        assert other.cache.hits == 0, "affinity leaked to the other cache"
    finally:
        router.close()


# ---------------------------------------------------------------------------
# leader fan-out
# ---------------------------------------------------------------------------


def test_router_apply_updates_rolls_and_aggregates():
    class _Backend:
        def __init__(self):
            self.applied = []

        def __call__(self, qs):
            return _ok_fn(qs)

        def apply_updates(self, target, delta=None, **kw):
            self.applied.append(delta)
            return {"mode": "delta" if delta is not None else "full",
                    "bytes": 7, "full_bytes": 100, "reason": None}

    class _Target:
        def __init__(self):
            self.pops = 0

        def pop_delta(self):
            self.pops += 1
            return f"manifest-{self.pops}"

    backends = [_Backend() for _ in range(3)]
    cells = [ServingCell(b, name=f"cell{i}", max_wait_ms=0.5)
             for i, b in enumerate(backends)]
    router = CellRouter(cells)
    try:
        target = _Target()
        agg = router.apply_updates(target)
        # leader contract: ONE pop, the SAME manifest to every cell
        assert target.pops == 1
        assert all(b.applied == ["manifest-1"] for b in backends)
        assert agg["mode"] == "delta"
        assert agg["bytes"] == 21 and agg["full_bytes"] == 300
        assert set(agg["cells"]) == {"cell0", "cell1", "cell2"}
        # down cells are skipped, not crashed into
        with router._lock:
            router._mark_down("cell1", RuntimeError("x"))
        agg2 = router.apply_updates(target)
        assert target.pops == 2
        assert agg2["cells"]["cell1"]["mode"] == "skipped"
        assert len(backends[1].applied) == 1
        assert len(backends[0].applied) == 2
        # fleet stats aggregate the republish gauges across cells
        st = router.stats()
        assert st.republished_bytes == 7 * 3 + 7 * 2
    finally:
        router.close()


def test_revive_replays_missed_manifests_before_rejoin():
    """A down cell misses rolling delta fan-outs; revive() must replay
    the merged missed window against the last published target BEFORE
    the cell rejoins — never re-admit it serving a stale index — and
    count the resync in stats()."""
    from repro.core.delta import DeltaManifest

    class _Backend:
        def __init__(self):
            self.applied = []

        def __call__(self, qs):
            return _ok_fn(qs)

        def apply_updates(self, target, delta=None, **kw):
            self.applied.append(delta)
            return {"mode": "delta" if delta is not None else "full",
                    "bytes": 7, "full_bytes": 100, "reason": None}

    def _man(bv, v, bn, n, dirty, tombs=()):
        return DeltaManifest(
            base_version=bv, version=v, base_n=bn, n=n,
            dirty_buckets=np.asarray(dirty, np.int64),
            tombstones=np.asarray(tombs, np.int64))

    backends = [_Backend() for _ in range(3)]
    cells = [ServingCell(b, name=f"cell{i}", max_wait_ms=0.5)
             for i, b in enumerate(backends)]
    router = CellRouter(cells)
    try:
        target = object()
        router.apply_updates(target, delta=_man(0, 1, 10, 10, [0]))
        with router._lock:
            router._mark_down("cell1", RuntimeError("x"))
        # cell1 misses two rolling fan-outs
        router.apply_updates(target, delta=_man(1, 2, 10, 12, [1, 3]))
        router.apply_updates(target, delta=_man(2, 3, 12, 12, [3, 5],
                                                tombs=[7]))
        assert len(backends[1].applied) == 1
        rep = router.revive("cell1")
        # the replay is ONE apply carrying the merged covering window
        assert len(backends[1].applied) == 2
        merged = backends[1].applied[-1]
        assert (merged.base_version, merged.version) == (1, 3)
        assert (merged.base_n, merged.n) == (10, 12)
        assert merged.dirty_buckets.tolist() == [1, 3, 5]
        assert merged.tombstones.tolist() == [7]
        assert rep["mode"] == "delta"
        assert "cell1" not in router.down_cells()
        assert router.stats().resyncs == 1
        # a fan-out with no manifest while down -> full re-place on revive
        with router._lock:
            router._mark_down("cell2", RuntimeError("x"))
        router.apply_updates(target, delta=_man(3, 4, 12, 13, [2]))
        router.apply_updates(target, delta=None)
        router.revive("cell2")
        assert backends[2].applied[-1] is None, "expected forced re-place"
        assert router.stats().resyncs == 2
        # reviving a cell that missed nothing replays nothing
        with router._lock:
            router._mark_down("cell0", RuntimeError("x"))
        n_before = len(backends[0].applied)
        assert router.revive("cell0") is None
        assert len(backends[0].applied) == n_before
        assert router.stats().resyncs == 2
    finally:
        router.close()


def test_maintenance_scheduler_as_fleet_leader():
    """A MaintenanceScheduler pointed at the router IS the fleet
    leader: one drift decision on the shared estimator, one reboost,
    one manifest fanned to every cell, every cell's cache
    invalidated."""
    from repro.adaptive import (
        FrequencyAdmissionCache,
        HostIndexBackend,
        MaintenanceScheduler,
        OnlineLikelihoodEstimator,
    )
    from repro.core.index import SearchIndex
    from repro.core.protocol import IndexSpec
    from repro.core.tree import build_qlbt

    rng = np.random.default_rng(6)
    n, d = 256, 8
    db = rng.normal(size=(n, d)).astype(np.float32)
    p0 = np.full(n, 1.0 / n)
    idx = SearchIndex(spec=IndexSpec(kind="qlbt"), db=db,
                      tree=build_qlbt(db, p0, seed=1), p=p0)
    est = OnlineLikelihoodEstimator(n, reference=p0, halflife=64)
    backends = [HostIndexBackend(idx, k=5) for _ in range(2)]
    cells = [ServingCell(b, name=f"cell{i}", max_wait_ms=0.5,
                         cache=FrequencyAdmissionCache(capacity=16),
                         estimator=est)
             for i, b in enumerate(backends)]
    router = CellRouter(cells)
    sched = MaintenanceScheduler(
        est, idx, engine=router, interval_s=None,
        drift_threshold=0.05, min_observations=32,
        cooldown_observations=1, rebalance=False)
    try:
        gens = [c.cache.generation for c in cells]
        # skew every observation onto a tiny head: drift explodes
        head = np.arange(4)
        for _ in range(40):
            est.observe(head)
        ev = sched.check_now()
        assert ev is not None, "leader never triggered"
        rep = ev["republish"]
        assert set(rep["cells"]) == {"cell0", "cell1"}
        # every cell got the same republished index reference
        assert all(b.index is idx for b in backends)
        assert all(b.last_delta is backends[0].last_delta
                   for b in backends)
        assert all(c.cache.generation == g + 1
                   for c, g in zip(cells, gens)), (
            "a cell's cache survived the fan-out")
    finally:
        sched.close()
        router.close()


# ---------------------------------------------------------------------------
# disjoint submesh partitioning
# ---------------------------------------------------------------------------


def test_make_cell_meshes_single_device_requires_sharing():
    import jax

    from repro.launch.mesh import make_cell_meshes

    if len(jax.devices()) > 1:
        pytest.skip("pool has multiple devices")
    with pytest.raises(RuntimeError, match="share_devices"):
        make_cell_meshes(2)
    meshes = make_cell_meshes(2, share_devices=True)
    assert len(meshes) == 2
    assert all(m.axis_names == ("data",) for m in meshes)
    assert all(m.devices.size == 1 for m in meshes)
    # one cell over the whole pool needs no sharing
    (m,) = make_cell_meshes(1)
    assert m.devices.size == len(jax.devices())


def test_make_cell_meshes_disjoint_blocks():
    """Disjoint partitioning over a fake pool: consecutive blocks, no
    device in two cells."""
    import jax

    from repro.launch.mesh import make_cell_meshes

    devs = list(jax.devices()) * 4           # fake a 4x pool by reuse
    meshes = make_cell_meshes(4, devices=devs, shape=(1,))
    assert len(meshes) == 4
    for i, m in enumerate(meshes):
        assert list(m.devices.ravel()) == devs[i:i + 1]
    with pytest.raises(ValueError):
        make_cell_meshes(0)


def test_build_fleet_cells_one_spec_per_mesh():
    from repro.configs.base import AnnConfig, ShapeSpec
    from repro.launch.cells import build_fleet_cells
    from repro.launch.mesh import make_cell_meshes

    cfg = AnnConfig(name="fleet-test", n=2048, d=32, n_clusters=16,
                    nprobe=4)
    shape = ShapeSpec("serve_sm", "serve", dims={"batch": 8, "k": 10})
    meshes = make_cell_meshes(2, share_devices=True)
    specs = build_fleet_cells(cfg, "ann", meshes, shape)
    assert len(specs) == 2
    for spec, mesh in zip(specs, meshes):
        assert spec.step_fn is not None
        assert spec.in_shardings[0].mesh is mesh
    # replicas are identical up to mesh
    assert specs[0].note == specs[1].note
    assert [a.shape for a in specs[0].args] == \
        [a.shape for a in specs[1].args]


# ---------------------------------------------------------------------------
# stats plumbing
# ---------------------------------------------------------------------------


def test_fleet_stats_and_lat_summary_breakdown():
    import os
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks.common import lat_summary

    cells = [ServingCell(_ok_fn, name=f"cell{i}", max_wait_ms=0.5)
             for i in range(2)]
    router = CellRouter(cells)
    try:
        rng = np.random.default_rng(7)
        ts = []
        for _ in range(12):
            q = _query(rng)
            t0 = time.perf_counter()
            router.search(q, timeout=5.0)
            ts.append(time.perf_counter() - t0)
        st = router.stats()
        assert st.n == 12
        assert set(st.cells) == {"cell0", "cell1"}
        assert sum(s.n for s in st.cells.values()) == 12
        out = lat_summary(ts, stats=st)
        assert set(out["cells"]) == {"cell0", "cell1"}
        assert all("p99_ms" in v for v in out["cells"].values())
        # zero-valued routing counters stay out of the row; force one in
        router.metrics.counter("rerouted").inc()
        out2 = lat_summary(ts, stats=router.stats())
        assert out2["rerouted"] == 1 and "shed" not in out2
    finally:
        router.close()


def test_build_fleet_shares_one_estimator():
    from repro.adaptive import OnlineLikelihoodEstimator
    from repro.launch.mesh import make_cell_meshes

    rng = np.random.default_rng(8)
    db = rng.normal(size=(128, 8)).astype(np.float32)
    est = OnlineLikelihoodEstimator(128)
    meshes = make_cell_meshes(2, share_devices=True)
    router = build_fleet(meshes, db, kind="brute", k=5,
                         cache_capacity=16, estimator=est,
                         cell_kw=dict(max_wait_ms=0.5))
    try:
        assert len(router.cells) == 2
        assert all(c.estimator is est for c in router.cells)
        caches = [c.cache for c in router.cells]
        assert caches[0] is not caches[1], "caches must be per-cell"
        d, i = router.search(db[0], timeout=30.0)
        assert d.shape == (5,)
        # the worker observes AFTER delivering the result — poll briefly
        deadline = time.perf_counter() + 5.0
        while est.n_total == 0 and time.perf_counter() < deadline:
            time.sleep(0.01)
        assert est.n_total > 0, "shared estimator saw no traffic"
    finally:
        router.close()
