"""Online index mutation: add/delete/rebalance lifecycle.

Parity contract (the tentpole's acceptance): after interleaved adds and
deletes, searching the mutated index must match searching an index built
from scratch on the same surviving corpus — *exactly* for the brute
bottom at full probe (both are exact scans over the survivors), and
recall-bounded for the approximate bottoms (qlbt forest / LSH), whose
structures legitimately differ between an incremental and a fresh build.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.brute import brute_search
from repro.core.index import build_index
from repro.core.metrics import recall_at_k
from repro.core.protocol import IndexSpec
from repro.core.two_level import TwoLevelConfig, build_two_level

N, D, K = 1500, 12, 24


def _gen(seed):
    rng = np.random.default_rng(seed)
    c = rng.normal(size=(12, D)) * 4

    def mk(n):
        return (c[rng.integers(0, 12, n)]
                + rng.normal(size=(n, D))).astype(np.float32)

    return rng, mk


def _cfg(bottom, **kw):
    kw.setdefault("tree_leaf", 8)
    return TwoLevelConfig(n_clusters=K, top="brute", bottom=bottom,
                          kmeans_iters=4, kmeans_minibatch=None, **kw)


def _mutate_30pct(idx, mk, seed, rounds=3, chunk=75):
    """Interleave ``rounds`` x (delete chunk, add chunk) ~= 30% of N."""
    rng = np.random.default_rng(seed)
    deleted = []
    for _ in range(rounds):
        live = np.nonzero(idx.alive)[0]
        dele = rng.choice(live, chunk, replace=False)
        idx.delete_entities(dele)
        deleted.append(dele)
        idx.add_entities(mk(chunk))
    return np.concatenate(deleted)


# ---------------------------------------------------------------------------
# basic visibility / invisibility invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bottom", ["brute", "tree", "qlbt", "lsh"])
def test_mutation_visibility_all_bottoms(bottom):
    """Adds are findable, deletes unreturnable, bucket invariants hold."""
    rng, mk = _gen(0)
    db = mk(N)
    p = rng.dirichlet(np.full(N, 0.5)) if bottom == "qlbt" else None
    idx = build_two_level(db, _cfg(bottom), p=p)
    deleted = _mutate_30pct(idx, mk, seed=1)

    # every live entity sits in exactly one bucket slot, no deleted slot
    flat = idx.bucket_ids[idx.bucket_ids >= 0]
    live = np.nonzero(idx.alive)[0]
    assert sorted(flat.tolist()) == live.tolist()
    assert np.array_equal(
        idx.bucket_counts,
        (idx.bucket_ids >= 0).sum(axis=1).astype(idx.bucket_counts.dtype))

    q = mk(64)
    _, ids, _ = idx.search(q, 10, nprobe=K, beam_width=16)
    assert not np.isin(ids, deleted).any(), "deleted id returned"

    # freshly added entities are findable (query = the vectors themselves)
    new = idx.db[live[live >= N]][:32]
    if new.shape[0]:
        _, ids, _ = idx.search(new, 1, nprobe=K, beam_width=16)
        assert (np.asarray(ids)[:, 0] >= N).mean() > 0.85


def test_deleted_forest_leaves_are_masked_without_rebuild():
    """A tree-bottom delete must be invisible even with refresh deferred:
    the leaf slots are blanked in place (bounded staleness, never wrong)."""
    rng, mk = _gen(2)
    db = mk(600)
    idx = build_two_level(db, _cfg("tree", tree_leaf=4))
    target = np.asarray([5, 17, 300])
    idx.delete_entities(target)
    le = np.asarray(idx.forest.arrays["leaf_entities"])
    assert not np.isin(le, target).any()
    q = idx.db[target] + 0.0          # query exactly the deleted vectors
    _, ids, _ = idx.search(q, 5, nprobe=K, beam_width=16)
    assert not np.isin(ids, target).any()


def test_slot_reuse_and_no_pad_growth():
    """Tombstoned slots are compacted and reused: delete m then add m must
    not grow the bucket pad width."""
    rng, mk = _gen(3)
    db = mk(800)
    idx = build_two_level(db, _cfg("brute"))
    cap0 = idx.bucket_ids.shape[1]
    dele = rng.choice(800, 120, replace=False)
    idx.delete_entities(dele)
    idx.add_entities(mk(120))
    assert idx.bucket_ids.shape[1] == cap0
    assert idx.n_live == 800


def test_add_validates_partition_features_both_ways():
    rng, mk = _gen(4)
    db = mk(400)
    feats = db[:, :3].copy()
    idx = build_two_level(db, _cfg("brute"), partition_features=feats)
    with pytest.raises(ValueError, match="partition_features"):
        idx.add_entities(mk(8))                      # missing
    with pytest.raises(ValueError, match="rows for"):
        idx.add_entities(mk(8), partition_features=feats[:3])  # wrong len
    new = mk(8)
    ids = idx.add_entities(new, partition_features=new[:, :3])
    assert ids.size == 8 and idx.part_feats.shape[0] == 408
    # ...and the reverse direction: features on a plain-embedding index
    # would be silently ignored, so it must refuse
    idx2 = build_two_level(db, _cfg("brute"))
    with pytest.raises(ValueError, match="ignored"):
        idx2.add_entities(new, partition_features=new[:, :3])


def test_deferred_refresh_bounded_staleness():
    """``refresh=False`` defers the dirty-bucket rebuild: new entities are
    invisible to the forest descent (stale, not wrong) until
    ``refresh_forest()`` — after which they are findable."""
    rng, mk = _gen(5)
    db = mk(600)
    idx = build_two_level(db, _cfg("tree", tree_leaf=4))
    new = mk(40)
    ids = idx.add_entities(new, refresh=False)
    assert idx.dirty.any()
    _, got, _ = idx.search(new, 1, nprobe=K, beam_width=16)
    assert not np.isin(got, ids).any()           # stale: not yet descended
    rebuilt = idx.refresh_forest()
    assert rebuilt > 0 and not idx.dirty.any()
    _, got, _ = idx.search(new, 1, nprobe=K, beam_width=16)
    assert (np.asarray(got)[:, 0] >= 600).mean() > 0.85


# ---------------------------------------------------------------------------
# mutation parity vs from-scratch rebuild
# ---------------------------------------------------------------------------


def test_interleaved_mutation_exact_parity_brute():
    """Brute bottom at full probe is an exact scan over the survivors, so
    the mutated index, a from-scratch rebuild, and the oracle must agree
    (id sets per query; distances to float tolerance)."""
    rng, mk = _gen(6)
    db = mk(N)
    idx = build_two_level(db, _cfg("brute"))
    _mutate_30pct(idx, mk, seed=7)
    live = np.nonzero(idx.alive)[0]
    surv = idx.db[live]
    idx2 = build_two_level(surv, _cfg("brute"))
    q = mk(64)
    d0, i0 = brute_search(q, surv, 10)
    d1, i1, _ = idx.search(q, 10, nprobe=K)
    d2, i2, _ = idx2.search(q, 10, nprobe=K)
    np.testing.assert_allclose(np.asarray(d1), d0, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(d2), d0, rtol=1e-4, atol=1e-4)
    # map mutated-index global ids -> surviving-corpus row ids
    inv = np.full(idx.n, -1, np.int64)
    inv[live] = np.arange(live.size)
    for b in range(q.shape[0]):
        assert set(inv[i1[b]].tolist()) == set(i0[b].tolist())
        assert set(np.asarray(i2[b]).tolist()) == set(i0[b].tolist())


@pytest.mark.parametrize("bottom", ["qlbt", "lsh"])
def test_interleaved_mutation_recall_bounded(bottom):
    """Approximate bottoms: the mutated index's recall@10 must stay within
    0.1 of a from-scratch rebuild on the surviving corpus."""
    rng, mk = _gen(8)
    db = mk(N)
    p = rng.dirichlet(np.full(N, 0.5)) if bottom == "qlbt" else None
    idx = build_two_level(db, _cfg(bottom), p=p)
    _mutate_30pct(idx, mk, seed=9)
    live = np.nonzero(idx.alive)[0]
    surv = idx.db[live]
    p2 = None if idx.p is None else idx.p[live]
    idx2 = build_two_level(surv, _cfg(bottom), p=p2)
    q = mk(64)
    _, it = brute_search(q, surv, 10)
    _, i1, _ = idx.search(q, 10, nprobe=8, beam_width=8)
    _, i2, _ = idx2.search(q, 10, nprobe=8, beam_width=8)
    r_mut = recall_at_k(np.asarray(i1), live[it])
    r_new = recall_at_k(np.asarray(i2), it)
    assert r_mut > r_new - 0.1, f"{bottom}: {r_mut:.3f} vs {r_new:.3f}"


def test_rebalance_acceptance_30pct_within_one_point():
    """Acceptance: 30% interleaved adds/deletes + one rebalance() -> the
    mutated qlbt index's recall@10 is within 1 point of a from-scratch
    rebuild on the same corpus (beam wide enough that the per-bucket
    descent is near-exhaustive — measuring the *index*, not the beam)."""
    rng, mk = _gen(10)
    db = mk(N)
    p = rng.dirichlet(np.full(N, 0.5))
    idx = build_two_level(db, _cfg("qlbt"), p=p)
    _mutate_30pct(idx, mk, seed=11)
    stats = idx.rebalance()
    assert stats["n_rebuilt_buckets"] >= 0 and not idx.dirty.any()
    live = np.nonzero(idx.alive)[0]
    surv = idx.db[live]
    idx2 = build_two_level(surv, _cfg("qlbt"), p=idx.p[live])
    q = mk(64)
    _, it = brute_search(q, surv, 10)
    _, i1, _ = idx.search(q, 10, nprobe=12, beam_width=32)
    _, i2, _ = idx2.search(q, 10, nprobe=12, beam_width=32)
    r_mut = recall_at_k(np.asarray(i1), live[it])
    r_new = recall_at_k(np.asarray(i2), it)
    assert r_mut >= r_new - 0.01, f"{r_mut:.4f} vs rebuilt {r_new:.4f}"


def test_rebalance_recenters_drifted_buckets():
    """Skewed growth (every add lands in one region) must trip the drift
    detector: rebalance recenters and re-routes, leaving every entity in
    exactly one slot and centroids closer to their members."""
    rng, mk = _gen(12)
    db = mk(1000)
    idx = build_two_level(db, _cfg("brute"))
    # pour new mass into one corner of the space
    shift = np.zeros(D, np.float32)
    shift[0] = 6.0
    new = mk(300) * 0.25 + shift
    idx.add_entities(new.astype(np.float32))
    stats = idx.rebalance(drift_threshold=0.2)
    assert stats["n_drifted"] >= 1
    assert stats["n_moved"] >= 0
    flat = idx.bucket_ids[idx.bucket_ids >= 0]
    assert sorted(flat.tolist()) == np.nonzero(idx.alive)[0].tolist()
    # recall is intact after the re-route
    q = mk(32)
    live = np.nonzero(idx.alive)[0]
    _, it = brute_search(q, idx.db[live], 10)
    _, ids, _ = idx.search(q, 10, nprobe=K)
    assert recall_at_k(np.asarray(ids), live[it]) > 0.95


# ---------------------------------------------------------------------------
# SearchIndex-level lifecycle (single-tree protocol path)
# ---------------------------------------------------------------------------


def test_search_index_single_tree_lifecycle():
    rng, mk = _gen(13)
    db = mk(500)
    p = rng.dirichlet(np.full(500, 0.5))
    si = build_index(IndexSpec(kind="qlbt"), db, p=p)
    ids = si.add_entities(mk(50))
    assert ids.tolist() == list(range(500, 550))
    si.delete_entities(np.arange(10))
    q = si.db[:10]
    _, got, _ = si.search(q, 5, beam_width=16)
    assert not np.isin(got, np.arange(10)).any()
    stats = si.rebalance()
    assert stats["n_rebuilt_buckets"] == 1
    _, got, _ = si.search(q, 5, beam_width=16)
    assert not np.isin(got, np.arange(10)).any()
    # surviving entities still findable after the rebuild
    probe = si.db[200:232]
    _, got, _ = si.search(probe, 1, beam_width=16)
    assert (np.asarray(got)[:, 0] == np.arange(200, 232)).mean() > 0.9


def test_engine_apply_updates_reaches_hedge_replica():
    """A hedge replica must be updated with the primary: a stale replica
    would serve deleted entities on every hedged request.  A hedge_fn
    without apply_updates is an error, not a silent staleness hole."""
    from repro.serve.engine import ServingEngine

    class _Backend:
        def __init__(self):
            self.seen = []

        def __call__(self, qs):
            b = qs.shape[0]
            return np.zeros((b, 1), np.float32), np.zeros((b, 1), np.int32)

        def apply_updates(self, target, **kw):
            self.seen.append(target)

    primary, replica = _Backend(), _Backend()
    eng = ServingEngine(primary, hedge_fn=replica, hedge_ms=1000.0)
    try:
        eng.apply_updates("snapshot-1")
        assert primary.seen == ["snapshot-1"]
        assert replica.seen == ["snapshot-1"]
        eng.hedge_fn = lambda qs: None          # replica w/o apply_updates
        with pytest.raises(TypeError, match="hedge_fn"):
            eng.apply_updates("snapshot-2")
        assert primary.seen == ["snapshot-1"]   # nothing half-applied
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# delta shipping (PR-5): manifest lifecycle, fallback boundaries, counters
# ---------------------------------------------------------------------------


def test_delta_manifest_accumulates_and_pops():
    """Mutations accumulate into one manifest; pop resets the log and
    chains versions; an untouched index pops an empty manifest."""
    rng, mk = _gen(20)
    db = mk(600)
    idx = build_two_level(db, _cfg("tree", tree_leaf=4))
    man0 = idx.pop_delta()
    assert man0.empty and man0.base_version == man0.version

    b = int(np.argmax(idx.bucket_counts))
    dele = idx.bucket_ids[b][:3].copy()
    idx.delete_entities(dele)
    ids = idx.add_entities(mk(4))
    man = idx.pop_delta()
    assert not man.empty and not man.full
    assert man.base_version == man0.version and man.version > man.base_version
    assert man.base_n == 600 and man.n == 604
    assert set(dele.tolist()) == set(man.tombstones.tolist())
    assert b in man.dirty_buckets.tolist()
    # every receiving bucket of the adds is named dirty
    for e in ids:
        assert int(idx.entity_bucket[e]) in man.dirty_buckets.tolist()
    # the pop cleared the log: next manifest is empty and chains on
    man2 = idx.pop_delta()
    assert man2.empty and man2.base_version == man.version

    # SearchIndex single-tree path: deletes are a delta, adds are full
    si = build_index(IndexSpec(kind="tree"), mk(300))
    si.delete_entities(np.arange(5))
    m = si.pop_delta()
    assert not m.full and m.tombstones.size == 5
    si.add_entities(mk(10))
    assert si.pop_delta().full        # whole-tree rebuild -> no delta


def _mesh1():
    import jax

    return jax.make_mesh((1,), ("data",))


def test_delta_threshold_boundary_falls_back_to_full():
    """The payload-vs-full size cutoff: the same manifest ships as a
    delta under a permissive threshold and falls back to a full re-place
    (reason="threshold") under a tight one — with identical results
    either way.  The localized mutation itself must cost <= 25% of a
    full re-place (the fig7 acceptance bound at <=10% mutation)."""
    from repro.distributed.backend import ShardedSearchBackend

    rng, mk = _gen(21)
    db = mk(N)
    idx = build_two_level(db, _cfg("tree"))
    mesh = _mesh1()
    kw = dict(k=10, axes=("data",), nprobe_local=K, beam_width=8,
              headroom=1.5)
    be = ShardedSearchBackend(mesh, idx, **kw)

    b = int(np.argmax(idx.bucket_counts))
    dele = idx.bucket_ids[b][:6].copy()
    idx.delete_entities(dele)
    man = idx.pop_delta()
    be.delta_max_fraction = 0.0                 # tighter than any payload
    st = be.apply_updates(idx, delta=man)
    assert st["mode"] == "full" and st["reason"] == "threshold"

    dele2 = idx.bucket_ids[b][:4].copy()
    idx.delete_entities(dele2)
    man2 = idx.pop_delta()
    be.delta_max_fraction = 1.0
    st2 = be.apply_updates(idx, delta=man2)
    assert st2["mode"] == "delta"
    assert st2["bytes"] <= 0.25 * st2["full_bytes"], (
        f"localized delta shipped {st2['bytes']} of "
        f"{st2['full_bytes']} bytes")
    q = mk(32)
    _, i1 = be(q)
    assert not np.isin(i1, np.concatenate([dele, dele2])).any()


def test_delta_version_mismatch_falls_back_to_full():
    """A manifest whose base version is AHEAD of what the backend last
    placed under-covers the backend's staleness (a pop went missing) —
    it must fall back to a full re-place, never apply partially."""
    from repro.distributed.backend import ShardedSearchBackend

    rng, mk = _gen(22)
    db = mk(N)
    idx = build_two_level(db, _cfg("tree"))
    mesh = _mesh1()
    be = ShardedSearchBackend(mesh, idx, k=10, axes=("data",),
                              nprobe_local=K, beam_width=8, headroom=1.5)
    b = int(np.argmax(idx.bucket_counts))
    d1 = idx.bucket_ids[b][:3].copy()
    idx.delete_entities(d1)
    idx.pop_delta()                       # popped but never applied
    d2 = idx.bucket_ids[b][:3].copy()
    idx.delete_entities(d2)
    man = idx.pop_delta()                 # base is ahead of the backend
    st = be.apply_updates(idx, delta=man)
    assert st["mode"] == "full" and st["reason"] == "version"
    q = mk(32)
    _, ids = be(q)
    assert not np.isin(ids, np.concatenate([d1, d2])).any()


def test_delta_full_manifest_and_missing_manifest_fall_back():
    """A ``full`` manifest (single-tree rebuild semantics) and a plain
    ``apply_updates`` without a manifest both take the bulk path."""
    from repro.distributed.backend import ShardedSearchBackend

    rng, mk = _gen(23)
    idx = build_two_level(mk(N), _cfg("brute"))
    mesh = _mesh1()
    be = ShardedSearchBackend(mesh, idx, k=10, axes=("data",),
                              nprobe_local=K, headroom=1.3)
    idx.add_entities(mk(8))
    st = be.apply_updates(idx)
    assert st["mode"] == "full" and st["reason"] == "no-manifest"
    idx.delete_entities(np.asarray([0]))
    man = idx.pop_delta()
    man = dataclasses.replace(man, full=True)
    st2 = be.apply_updates(idx, delta=man)
    assert st2["mode"] == "full" and st2["reason"] == "manifest-full"


def test_engine_delta_counters_and_cache_invalidation():
    """ServingEngine.apply_updates pops the manifest itself, ships the
    delta, surfaces republished_bytes / delta_fraction in EngineStats,
    and still invalidates the result cache (no stale hit can survive a
    delta republish any more than a full one)."""
    from repro.adaptive import FrequencyAdmissionCache
    from repro.distributed.backend import ShardedSearchBackend
    from repro.serve.engine import ServingEngine

    rng, mk = _gen(24)
    idx = build_two_level(mk(N), _cfg("tree"))
    mesh = _mesh1()
    be = ShardedSearchBackend(mesh, idx, k=5, axes=("data",),
                              nprobe_local=K, beam_width=16, headroom=1.5)
    cache = FrequencyAdmissionCache(capacity=64)
    eng = ServingEngine(be, cache=cache, max_wait_ms=0.5)
    try:
        b = int(np.argmax(idx.bucket_counts))
        target = int(idx.bucket_ids[b][0])
        q = idx.db[target].copy()
        _, ids0 = eng.search(q, timeout=30.0)
        assert target in ids0
        _, _ = eng.search(q, timeout=30.0)
        assert eng.stats().cache_hits >= 1
        idx.delete_entities(np.asarray([target]))
        st = eng.apply_updates(idx)       # pops + ships the delta
        assert st["mode"] == "delta"
        stats = eng.stats()
        assert stats.republished_bytes == st["bytes"] > 0
        assert 0.0 < stats.delta_fraction <= 0.25
        _, ids2 = eng.search(q, timeout=30.0)
        assert target not in ids2, "stale cached result after delta ship"
    finally:
        eng.close()


def test_reboost_refresh_of_stale_dirty_bucket_reenters_delta_log():
    """Regression: a bucket dirtied before a pop (deferred refresh) and
    rebuilt by a later reboost() must re-enter the CURRENT delta log —
    omitting it would delta-ship a stale slab and silently diverge from
    a full re-place."""
    rng, mk = _gen(26)
    db = mk(600)
    p = rng.dirichlet(np.full(600, 0.5))
    idx = build_two_level(db, _cfg("qlbt", tree_leaf=4), p=p)
    ids = idx.add_entities(mk(8), refresh=False)   # dirty, tree stale
    idx.pop_delta()                                # log reset, dirty stays
    b = {int(idx.entity_bucket[e]) for e in ids}
    assert idx.dirty.any()
    idx.reboost(rng.dirichlet(np.full(idx.n, 0.5)))  # rebuilds dirty trees
    man = idx.pop_delta()
    assert b <= set(man.dirty_buckets.tolist()), (
        "reboost-refreshed bucket missing from the delta manifest")


def test_brute_delta_applies_manifest_tombstones_without_alive():
    """The brute delta path must flip liveness for the manifest's
    tombstones even when the caller forgets the ``alive`` kwarg — a
    delta republish may never resurrect a tombstoned row."""
    from repro.core.delta import DeltaManifest
    from repro.distributed.backend import ShardedSearchBackend

    rng, mk = _gen(27)
    db = mk(400)
    mesh = _mesh1()
    be = ShardedSearchBackend(mesh, db, k=5, axes=("data",), headroom=1.5)
    man = DeltaManifest(base_version=0, version=1, base_n=400, n=400,
                        tombstones=np.asarray([7, 11]))
    st = be.apply_updates(db, delta=man)           # no alive kwarg
    assert st["mode"] == "delta"
    q = db[[7, 11]]
    _, ids = be(q)
    assert not np.isin(ids, [7, 11]).any(), "tombstoned row resurrected"
    # a LATER append-only window must not forget the earlier flips
    # (liveness is cumulative on the backend, not rebuilt per manifest)
    grown = np.concatenate([db, mk(20)])
    man2 = DeltaManifest(base_version=1, version=2, base_n=400, n=420)
    st2 = be.apply_updates(grown, delta=man2)
    assert st2["mode"] == "delta"
    _, ids = be(q)
    assert not np.isin(ids, [7, 11]).any(), (
        "earlier window's tombstones resurrected by a later delta")
    # and a manifest that skips a window in the chain falls back to full
    man4 = DeltaManifest(base_version=3, version=4, base_n=420, n=420,
                         tombstones=np.asarray([20]))
    st3 = be.apply_updates(grown, delta=man4)
    assert st3["mode"] == "full" and st3["reason"] == "version"


def test_scheduler_event_records_republish_stats():
    """A drift-triggered maintenance pass reports what its republish
    shipped (the host backend republishes by reference: zero bytes)."""
    from repro.adaptive import HostIndexBackend, MaintenanceScheduler
    from repro.serve.engine import ServingEngine

    rng, mk = _gen(25)
    db = mk(600)
    p = rng.dirichlet(np.full(600, 0.5))
    idx = build_two_level(db, _cfg("qlbt"), p=p)

    class _Est:                        # minimal estimator stub
        n_total = 1e6

        def drift(self):
            return {"tv": 1.0, "kl": 1.0, "n_observed": 1e6}

        def likelihood(self):
            return rng.dirichlet(np.full(600, 0.5))

        def set_reference(self, p):
            pass

    backend = HostIndexBackend(idx, k=5, nprobe=K)
    eng = ServingEngine(backend, max_wait_ms=0.5)
    sched = MaintenanceScheduler(_Est(), idx, engine=eng, interval_s=None,
                                 drift_threshold=0.5, min_observations=1)
    try:
        ev = sched.check_now()
        assert ev is not None
        assert ev["republish"]["mode"] == "swap"
        assert ev["republish"]["bytes"] == 0
        assert backend.last_delta is not None     # manifest reached it
    finally:
        sched.close()
        eng.close()


def test_search_index_single_tree_add_does_not_resurrect_deleted():
    """Regression: the single-tree add path rebuilds the whole tree; it
    must rebuild over the *survivors*, not the full db — a rebuild over
    every row silently resurrects tombstoned entities."""
    rng, mk = _gen(14)
    db = mk(400)
    si = build_index(IndexSpec(kind="tree"), db)
    dead = np.arange(7)
    si.delete_entities(dead)
    si.add_entities(mk(30))                 # delete THEN add
    q = db[dead]                            # query the deleted vectors
    _, got, _ = si.search(q, 5, beam_width=16)
    assert not np.isin(got, dead).any(), "deleted ids resurrected by add"
    si.rebalance()
    _, got, _ = si.search(q, 5, beam_width=16)
    assert not np.isin(got, dead).any(), "deleted ids resurrected by rebalance"
