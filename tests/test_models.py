"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
shape + finiteness assertions (the brief's required smoke per arch)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_arch
from repro.data.graph import (
    NeighborSampler,
    make_graph,
    molecule_batch,
    pad_edges,
)
from repro.data.lm import LMStream
from repro.data.recsys import batch_for
from repro.models import recsys as R
from repro.models import schnet as S
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)
LM_ARCHS = ["qwen3-14b", "granite-34b", "qwen3-0.6b", "deepseek-v3-671b",
            "kimi-k2-1t-a32b"]
RECSYS_ARCHS = ["din", "dlrm-mlperf", "sasrec", "dcn-v2"]


def _finite(tree):
    return all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(tree)
               if hasattr(x, "dtype") and jnp.issubdtype(x.dtype,
                                                         jnp.floating))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    cfg, family = get_arch(arch)
    assert family == "lm"
    rc = cfg.reduced()
    params = T.init(rc, KEY)
    stream = LMStream(rc.vocab, 16, 2, seed=0)
    batch = stream.batch_at(0)
    loss, aux = T.loss_fn(params, batch, rc)
    assert np.isfinite(float(loss)) and float(loss) > 0
    g = jax.grad(lambda p: T.loss_fn(p, batch, rc)[0])(params)
    assert _finite(g)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_prefill_decode_consistency(arch):
    """decode_step after prefill(S) == prefill(S+1) last logits.

    Run in f32 precision (policy knob) to separate path logic from bf16
    noise; MoE capacity is raised so no tokens drop — capacity-based
    dispatch legitimately drops differently for different batches, which
    is not a decode bug (see EXPERIMENTS.md).
    """
    import jax.numpy as jnp

    cfg, _ = get_arch(arch)
    rc = cfg.reduced()
    rc = dataclasses.replace(rc, remat=False, mtp=False)
    if rc.moe is not None:
        rc = dataclasses.replace(
            rc, moe=dataclasses.replace(rc.moe, capacity_factor=8.0))
    T.set_precision(jnp.float32, jnp.float32)
    try:
        params = T.init(rc, KEY)
        toks = jax.random.randint(KEY, (2, 9), 0, rc.vocab)
        logits_a, cache = T.prefill(params, toks[:, :8], rc, max_len=12)
        assert int(np.asarray(cache["lengths"])[0]) == 8
        logits_d, cache2 = T.decode_step(params, cache, toks[:, 8:9], rc)
        logits_b, _ = T.prefill(params, toks, rc, max_len=12)
        np.testing.assert_allclose(
            np.asarray(logits_d, np.float32),
            np.asarray(logits_b, np.float32), rtol=2e-3, atol=2e-3,
        )
        assert int(np.asarray(cache2["lengths"])[0]) == 9
    finally:
        T.set_precision()


def test_moe_routing_respects_capacity_and_gates():
    from repro.configs.base import LMConfig, MoEConfig
    from repro.models.moe import _dispatch_indices, _route, moe_capacity

    cfg, _ = get_arch("deepseek-v3-671b")
    rc = cfg.reduced()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, rc.d_model)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(rc.d_model, rc.moe.n_experts))
                    .astype(np.float32))
    b = jnp.zeros((rc.moe.n_experts,))
    top_i, gates = _route(x, w, b, rc.moe)
    assert top_i.shape == (64, rc.moe.top_k)
    g = np.asarray(gates)
    np.testing.assert_allclose(g.sum(-1), rc.moe.routed_scaling, rtol=1e-4)
    cap = moe_capacity(rc, 64)
    dispatch, _ = _dispatch_indices(top_i, rc.moe.e_pad, cap)
    d = np.asarray(dispatch)
    real = d[d < 64]
    # no token slot is double-assigned within one expert row
    for e in range(rc.moe.e_pad):
        row = d[e][d[e] < 64]
        assert len(row) == len(set(row.tolist()))


def test_schnet_smoke_all_shapes():
    cfg, family = get_arch("schnet")
    assert family == "gnn"
    rc = dataclasses.replace(cfg.reduced(), d_feat=12, n_out=4)
    params = S.init(rc, KEY)
    g = make_graph(200, 900, 12, n_classes=4, seed=0)
    snd, rcv = g.edge_list()
    full = {"feats": g.feats, "pos": g.pos, "senders": snd,
            "receivers": rcv, "labels": g.labels}
    loss, aux = S.loss_fn(params, full, rc)
    assert np.isfinite(float(loss))
    # sampled minibatch (real neighbor sampler)
    sub = pad_edges(NeighborSampler(g, (4, 3), seed=0).sample(
        np.arange(16)), 400, 1200)
    loss2, _ = S.loss_fn(params, {k: sub[k] for k in
                                  ("feats", "pos", "senders", "receivers",
                                   "labels", "node_mask")}, rc)
    assert np.isfinite(float(loss2))
    # molecule batch (energy head)
    mb = molecule_batch(3, 8, 24, 12, step=0)
    loss3, _ = S.loss_fn(params, mb, rc)
    assert np.isfinite(float(loss3))
    gr = jax.grad(lambda p: S.loss_fn(p, full, rc)[0])(params)
    assert _finite(gr)


def test_neighbor_sampler_fanout_bounds():
    g = make_graph(500, 3000, 8, seed=1)
    samp = NeighborSampler(g, (5, 3), seed=0)
    sub = samp.sample(np.arange(32))
    assert sub["senders"].shape == sub["receivers"].shape
    assert sub["senders"].size <= 32 * 5 + 32 * 5 * 3
    assert sub["feats"].shape[0] <= 32 * (1 + 5 + 15)
    # edges reference local ids
    assert sub["senders"].max() < sub["feats"].shape[0]
    assert sub["receivers"].max() < sub["feats"].shape[0]


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke_train_and_serve(arch):
    cfg, family = get_arch(arch)
    assert family == "recsys"
    rc = cfg.reduced()
    params = R.init(rc, KEY)
    batch = batch_for(rc, 16, step=0)
    loss, aux = R.loss_fn(params, batch, rc)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: R.loss_fn(p, batch, rc)[0])(params)
    assert _finite(g)
    if arch == "sasrec":
        serve = {"seq": batch["seq"], "target_item": batch["pos"][:, -1]}
    else:
        serve = {k: v for k, v in batch.items() if k != "label"}
    logits = R.serve_logits(params, serve, rc)
    assert logits.shape == (16,)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_retrieval_topk(arch):
    cfg, _ = get_arch(arch)
    rc = cfg.reduced()
    params = R.init(rc, KEY)
    b = batch_for(rc, 4, step=0)
    n_cand = 64
    cand = np.arange(n_cand, dtype=np.int32)
    if arch == "sasrec":
        rb = {"seq": b["seq"][:1], "candidates": cand}
    elif arch == "din":
        rb = {"hist_items": b["hist_items"][:1],
              "hist_cates": b["hist_cates"][:1],
              "candidates": cand,
              "cand_cates": (cand % rc.n_cates).astype(np.int32)}
    else:
        rb = {"dense": b["dense"][:1], "sparse": b["sparse"][:1],
              "candidates": cand}
    d, i = R.retrieval_logits(params, rb, rc, k=8)
    assert i.shape == (8,)
    assert len(set(np.asarray(i).tolist())) == 8   # distinct candidates
    # scores descend
    s = np.asarray(d)
    assert (np.diff(s) <= 1e-5).all()
