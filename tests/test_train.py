"""Substrate: optimizers, compression, checkpoint/restart, fault injection,
watchdog, serving engine."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LMConfig
from repro.data.lm import LMStream
from repro.models import transformer as T
from repro.serve.engine import ServingEngine
from repro.train import checkpoint as C
from repro.train import optim
from repro.train.compression import (
    dequantize_int8,
    make_ef_transform,
    quantize_int8,
)
from repro.train.fault import (
    FaultInjected,
    Watchdog,
    make_fault_injector,
    run_with_restart,
)
from repro.train.loop import init_state, make_train_step, train

CFG = LMConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
               d_head=16, d_ff=64, vocab=128, scan_layers=True, remat=False)
KEY = jax.random.PRNGKey(0)
STREAM = LMStream(CFG.vocab, 16, 4, seed=0)


def _loss(p, b):
    return T.loss_fn(p, b, CFG)


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor", "sgd"])
def test_optimizers_reduce_loss(opt_name):
    opt = {
        "adamw": optim.adamw(optim.constant_lr(1e-3)),
        "adafactor": optim.adafactor(optim.constant_lr(1e-2),
                                     min_dim_factored=16),
        "sgd": optim.sgd(optim.constant_lr(1e-2)),
    }[opt_name]
    state = init_state(T.init(CFG, KEY), opt)
    res = train(state, make_train_step(_loss, opt), STREAM.batch_at, 25,
                log_every=8)
    assert res.history[-1]["loss"] < res.history[0]["loss"]


def test_grad_accumulation_matches_big_batch():
    opt = optim.sgd(optim.constant_lr(1e-2), momentum=0.0)
    big = STREAM.batch_at(0)
    micro = {k: v.reshape(2, 2, *v.shape[1:]) for k, v in big.items()}
    s1 = init_state(T.init(CFG, KEY), opt)
    s2 = init_state(T.init(CFG, KEY), opt)
    step1 = jax.jit(make_train_step(_loss, opt))
    stepa = jax.jit(make_train_step(_loss, opt, accum=2))
    s1, _ = step1(s1, big)
    s2, _ = stepa(s2, micro)
    a = np.asarray(jax.tree.leaves(s1.params)[0])
    b = np.asarray(jax.tree.leaves(s2.params)[0])
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)


def test_int8_quantization_bounds_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x)).max()
    assert err <= float(s) * 0.5 + 1e-7


def test_error_feedback_carries_residual():
    init, apply = make_ef_transform()
    g = {"w": jnp.full((8, 8), 0.003)}
    buf = init(g)
    total = np.zeros((8, 8), np.float32)
    for _ in range(30):
        out, buf = apply(g, buf)
        total += np.asarray(out["w"])
    # mean emitted gradient converges to the true gradient despite int8
    np.testing.assert_allclose(total / 30, 0.003, rtol=0.05)


def test_compressed_training_parity():
    opt = optim.adamw(optim.constant_lr(1e-3))
    plain = train(init_state(T.init(CFG, KEY), opt),
                  make_train_step(_loss, opt), STREAM.batch_at, 25,
                  log_every=24)
    opt2 = optim.adamw(optim.constant_lr(1e-3))
    comp = train(init_state(T.init(CFG, KEY), opt2, compress=True),
                 make_train_step(_loss, opt2, compress=True),
                 STREAM.batch_at, 25, log_every=24)
    assert abs(plain.history[-1]["loss"] - comp.history[-1]["loss"]) < 0.1


def test_checkpoint_restart_bit_identical():
    opt = optim.adamw(optim.constant_lr(1e-3))
    step = make_train_step(_loss, opt)
    with tempfile.TemporaryDirectory() as d:
        full = train(init_state(T.init(CFG, KEY), opt), step,
                     STREAM.batch_at, 14, ckpt_dir=d, ckpt_every=7,
                     ckpt_async=False)
        assert C.latest_step(d) == 14
        resumed_state = C.restore(d, 7, init_state(T.init(CFG, KEY), opt))
        resumed = train(resumed_state, step, STREAM.batch_at, 14)
        for a, b in zip(jax.tree.leaves(full.state.params),
                        jax.tree.leaves(resumed.state.params)):
            assert (np.asarray(a) == np.asarray(b)).all()


def test_checkpoint_crc_detects_corruption():
    opt = optim.sgd(optim.constant_lr(1e-2))
    with tempfile.TemporaryDirectory() as d:
        state = init_state(T.init(CFG, KEY), opt)
        C.save(d, 5, state)
        target = None
        for f in os.listdir(os.path.join(d, "step_00000005")):
            if f.endswith(".npy"):
                target = os.path.join(d, "step_00000005", f)
                break
        with open(target, "r+b") as fh:
            fh.seek(100)
            fh.write(b"\xde\xad")
        with pytest.raises(IOError):
            C.restore(d, 5, state)


def test_fault_injection_restart_recovers():
    """Crash at step 9 -> supervisor restarts from ckpt -> final params
    bit-identical to an uninterrupted run (stateless data order)."""
    opt = optim.adamw(optim.constant_lr(1e-3))
    step = make_train_step(_loss, opt)
    with tempfile.TemporaryDirectory() as d:
        baseline = train(init_state(T.init(CFG, KEY), opt), step,
                         STREAM.batch_at, 16)
        inject = make_fault_injector({9})

        def run(resume):
            if resume is None:
                state = init_state(T.init(CFG, KEY), opt)
            else:
                last = C.latest_step(d)
                state = C.restore(d, last,
                                  init_state(T.init(CFG, KEY), opt))
            return train(state, step, STREAM.batch_at, 16, ckpt_dir=d,
                         ckpt_every=4, ckpt_async=False,
                         fault_injector=inject)

        result, restarts = run_with_restart(run, max_restarts=2)
        assert restarts == 1
        for a, b in zip(jax.tree.leaves(baseline.state.params),
                        jax.tree.leaves(result.state.params)):
            assert (np.asarray(a) == np.asarray(b)).all()


def test_watchdog_flags_stragglers():
    wd = Watchdog(factor=3.0, warmup=3)
    for i in range(20):
        wd.observe(i, 0.01 if i != 15 else 0.2)
    assert wd.straggler_steps == [15]


def test_serving_engine_batches_and_tracks_latency():
    def search_fn(qs):
        d = np.zeros((qs.shape[0], 5), np.float32)
        i = np.tile(np.arange(5, dtype=np.int32), (qs.shape[0], 1))
        return d, i

    eng = ServingEngine(search_fn, max_batch=8, max_wait_ms=5.0)
    futs = [eng.submit(np.ones(4, np.float32)) for _ in range(20)]
    outs = [f.get(timeout=10) for f in futs]
    assert all(o[1].shape == (5,) for o in outs)
    st = eng.stats()
    assert st.n == 20 and st.p90_ms >= st.p50_ms >= 0
    assert max(st.batch_sizes) > 1      # micro-batching actually batched
    eng.close()


def test_serving_engine_hedges_stragglers():
    import time as _t

    def slow(qs):
        _t.sleep(0.2)
        return np.zeros((qs.shape[0], 1)), np.zeros((qs.shape[0], 1),
                                                    np.int32)

    def fast(qs):
        return (np.ones((qs.shape[0], 1)),
                np.ones((qs.shape[0], 1), np.int32))

    eng = ServingEngine(slow, hedge_fn=fast, hedge_ms=20.0, max_batch=4)
    d, i = eng.search(np.zeros(3, np.float32))
    assert eng.hedges >= 1
    assert i[0] == 1          # the hedge's answer won
    assert eng.stats().hedges == eng.hedges   # stats report the hedge
    eng.close()


def test_serving_engine_fast_primary_never_hedges():
    """The hedge only fires after hedge_ms: a primary that answers well
    inside the deadline keeps the hedge count at zero."""
    def fast(qs):
        return (np.zeros((qs.shape[0], 1)),
                np.zeros((qs.shape[0], 1), np.int32))

    def hedge(qs):
        raise AssertionError("hedge must not fire for a fast primary")

    eng = ServingEngine(fast, hedge_fn=hedge, hedge_ms=500.0, max_batch=4)
    for _ in range(5):
        d, i = eng.search(np.zeros(3, np.float32))
        assert i[0] == 0                      # the primary's answer
    st = eng.stats()
    assert st.hedges == 0 and st.n == 5
    eng.close()
