"""Seeded property-sweep harness (hypothesis is unavailable offline).

``sweep`` decorates a property with N randomized cases; each case gets a
``Case`` with deterministic draws.  Failures report the reproduction seed.
"""
from __future__ import annotations

import functools

import numpy as np


class Case:
    def __init__(self, seed: int):
        self.seed = seed
        self.rng = np.random.default_rng(seed)

    def ints(self, lo, hi, size=None):
        return self.rng.integers(lo, hi, size=size)

    def int_(self, lo, hi):
        return int(self.rng.integers(lo, hi))

    def floats(self, lo, hi, size=None):
        return self.rng.uniform(lo, hi, size=size)

    def choice(self, xs):
        return xs[int(self.rng.integers(0, len(xs)))]

    def array(self, shape, dtype=np.float32, scale=1.0):
        return (self.rng.normal(size=shape) * scale).astype(dtype)


def run_cases(fn, n_cases: int = 10, base_seed: int = 0, **kw):
    """Imperative form of :func:`sweep` for properties that also take
    pytest-parametrized arguments (``fn(case=..., **kw)``).  Failures
    re-raise with the reproduction seed, like the decorator."""
    for i in range(n_cases):
        seed = base_seed * 10_000 + i
        try:
            fn(case=Case(seed), **kw)
        except AssertionError as e:
            raise AssertionError(
                f"{fn.__name__} failed on case seed={seed}: {e}"
            ) from e


def sweep(n_cases: int = 10, base_seed: int = 0):
    """Run the property for ``n_cases`` deterministic seeds.

    NOTE: deliberately does NOT functools.wraps — pytest would introspect
    the wrapped signature and treat ``case`` as a fixture.
    """

    def deco(fn):
        def wrapper():
            for i in range(n_cases):
                seed = base_seed * 10_000 + i
                try:
                    fn(case=Case(seed))
                except AssertionError as e:
                    raise AssertionError(
                        f"{fn.__name__} failed on case seed={seed}: {e}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
