import os
import sys

# tests should see ONE device (dry-run forces 512 in its own process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__)))

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def subprocess_env():
    """Env for subprocess tests that re-import JAX with their own XLA_FLAGS.

    ``JAX_PLATFORMS=cpu`` is mandatory: the image ships a TPU PJRT plugin
    and without the pin the child probes for TPU hardware and can hang for
    minutes before falling back to CPU.
    """
    return {
        "PYTHONPATH": os.path.join(REPO, "src"),
        "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
        "HOME": os.environ.get("HOME", "/root"),
        "JAX_PLATFORMS": "cpu",
    }
