"""End-to-end behaviour of the paper's system.

The full edge-ER story on one (small) corpus: traffic estimation -> §5.3
protocol -> index build -> batched serving through the engine -> recall +
latency accounting.
"""
import numpy as np

from repro.core.brute import brute_search
from repro.core.index import auto_build_index
from repro.core.likelihood import (
    empirical_likelihood,
    sample_queries,
    simulate_beta_likelihood,
    unbalance_score,
)
from repro.core.metrics import recall_at_k
from repro.serve.engine import ServingEngine


def _corpus(rng, n, d=64, k=32):
    c = rng.normal(size=(k, d)) * 4
    return (c[rng.integers(0, k, n)] + rng.normal(size=(n, d))) \
        .astype(np.float32)


def test_small_corpus_edge_flow_qlbt():
    """<30K entities + observed traffic -> QLBT; recall@10 >= 0.9."""
    rng = np.random.default_rng(0)
    db = _corpus(rng, 3000)
    p_true = simulate_beta_likelihood(rng, 3000, 0.1, 8.0)
    # traffic log -> empirical likelihood (what a device would estimate)
    log_q, log_ids = sample_queries(rng, db, p_true, 5000)
    p_est = empirical_likelihood(log_ids, 3000)
    assert unbalance_score(p_est) > 0.05
    idx = auto_build_index(db, p=p_est)
    assert idx.spec.kind == "qlbt"
    q, gt = sample_queries(rng, db, p_true, 512, noise_scale=0.05)
    _, ids, work = idx.search(q, 10, beam_width=16)
    assert recall_at_k(ids, gt) >= 0.9
    assert work["candidates"] > 0


def test_large_corpus_two_level_flow():
    """>30K entities -> two-level PQ+brute; recall@10 >= 0.8 (paper's
    deployability bar)."""
    rng = np.random.default_rng(1)
    db = _corpus(rng, 40_000, d=32, k=128)
    idx = auto_build_index(db)
    assert idx.spec.kind == "two_level"
    q = db[:256] + rng.normal(0, 0.05, size=(256, 32)).astype(np.float32)
    _, gt = brute_search(q, db, 10)
    _, ids, _ = idx.search(q, 10, nprobe=32)
    assert recall_at_k(ids, gt) >= 0.8


def test_serving_engine_end_to_end_with_index():
    rng = np.random.default_rng(2)
    db = _corpus(rng, 2000, d=32)
    idx = auto_build_index(db)   # tree (no traffic)

    def search_fn(qs):
        d, i, _ = idx.search(qs, 10, beam_width=16)
        return d, i

    eng = ServingEngine(search_fn, max_batch=32, max_wait_ms=2.0)
    q = db[:100] + rng.normal(0, 0.02, size=(100, 32)).astype(np.float32)
    futs = [eng.submit(q[j]) for j in range(100)]
    outs = [f.get(timeout=60) for f in futs]
    eng.close()
    _, gt = brute_search(q, db, 10)
    ids = np.stack([o[1] for o in outs])
    assert recall_at_k(ids, gt) >= 0.9
    st = eng.stats()
    assert st.n == 100 and st.p99_ms > 0


def test_personalization_rebuild_with_new_likelihood():
    """Paper §3.1: rebuilding the QLBT for a new traffic distribution is a
    config-preserving operation (the personalization path)."""
    from repro.core.likelihood import beta_for_unbalance

    rng = np.random.default_rng(3)
    db = _corpus(rng, 2000, d=48)
    _, _, p1 = beta_for_unbalance(0.35, 2000, seed=1)
    idx = auto_build_index(db, p=p1)
    d1 = idx.tree.expected_depth(p1)
    # traffic shifts: a different user's head entities
    p2 = np.roll(p1, 997)
    d_stale = idx.tree.expected_depth(p2)
    idx.rebuild_with_likelihood(p2, seed=1)
    d2 = idx.tree.expected_depth(p2)
    assert d2 <= d_stale + 1e-9         # rebuilt tree fits the new traffic
    q, gt = sample_queries(rng, db, p2, 256, noise_scale=0.05)
    _, ids, _ = idx.search(q, 10, beam_width=16)
    assert recall_at_k(ids, gt) >= 0.9


def test_two_level_incremental_insert():
    from repro.core.two_level import TwoLevelConfig, build_two_level

    rng = np.random.default_rng(4)
    db = _corpus(rng, 5000, d=32)
    idx_tl = build_two_level(db, TwoLevelConfig(
        n_clusters=64, top="brute", bottom="brute", kmeans_iters=4))
    new = _corpus(rng, 200, d=32)
    ids = idx_tl.add_entities(new)
    assert ids.min() == 5000 and ids.max() == 5199
    # every new entity is indexed exactly once
    flat = idx_tl.bucket_ids[idx_tl.bucket_ids >= 5000]
    assert sorted(flat.tolist()) == list(range(5000, 5200))
    # and findable: query exactly at the new points
    d, i, _ = idx_tl.search(new[:64], 1, nprobe=8)
    hit = (i[:, 0] >= 5000).mean()
    assert hit > 0.9
