import numpy as np
import pytest

from proptest import sweep
from repro.core.likelihood import (
    beta_for_unbalance,
    empirical_likelihood,
    simulate_beta_likelihood,
    unbalance_score,
    zipf_likelihood,
)


def test_uniform_is_zero():
    p = np.full(256, 1 / 256)
    assert abs(unbalance_score(p)) < 1e-9


def test_concentrated_is_near_one():
    p = np.full(1024, 1e-12)
    p[0] = 1.0
    assert unbalance_score(p) > 0.99


@sweep(n_cases=8, base_seed=1)
def test_unbalance_bounds(case):
    n = case.int_(2, 5000)
    p = case.rng.dirichlet(np.full(n, case.floats(0.05, 5.0)))
    u = unbalance_score(p)
    assert -1e-9 <= u <= 1.0 + 1e-9


@sweep(n_cases=5, base_seed=2)
def test_beta_simulation_normalized(case):
    p = simulate_beta_likelihood(case.rng, case.int_(10, 2000),
                                 case.floats(0.05, 2.0),
                                 case.floats(1.0, 16.0))
    assert abs(p.sum() - 1.0) < 1e-9
    assert (p > 0).all()


@pytest.mark.parametrize("target", [0.1, 0.23, 0.4])
def test_beta_for_unbalance_hits_target(target):
    # the paper's Fig-1 sweep knob: achieve a requested unbalance score
    _, achieved, p = beta_for_unbalance(target, 256, seed=3)
    assert abs(achieved - target) < 0.05
    assert abs(p.sum() - 1.0) < 1e-9


def test_zipf_more_skewed_with_alpha():
    u1 = unbalance_score(zipf_likelihood(512, 0.5))
    u2 = unbalance_score(zipf_likelihood(512, 1.5))
    assert u2 > u1 > 0


def test_empirical_likelihood_counts():
    ids = np.array([0, 0, 0, 1, 2])
    p = empirical_likelihood(ids, 4, smoothing=0.0)
    assert p[0] == pytest.approx(0.6)
    assert p[3] == 0.0
