"""Multi-device semantics on 8 fake devices (subprocess: tests themselves
run single-device).  Covers: distributed exact/IVF/forest search, query+
corpus 2-axis sharding, the serving backend, compressed psum, elastic
checkpoint resharding, and a sharded LM train step.

The subprocess tests are marked ``slow`` (each pays a fresh 8-device JAX
start-up); the in-process compat/slicing tests run in the default CI job.
"""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from conftest import REPO, subprocess_env

slow = pytest.mark.slow

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
"""


def _run(body: str):
    code = _PRELUDE + textwrap.dedent(body)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=600, env=subprocess_env(), cwd=REPO,
    )
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    return r.stdout


# ---------------------------------------------------------------------------
# fast, in-process: the compat shim and the forest slicer
# ---------------------------------------------------------------------------


def test_compat_shard_map_single_device():
    """The shim resolves a working shard_map and rewrites check_vma /
    check_rep to whatever the installed JAX accepts."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.compat import SHARD_MAP_CHECK_KWARG, shard_map

    assert SHARD_MAP_CHECK_KWARG in ("check_vma", "check_rep", None)
    mesh = jax.make_mesh((1,), ("data",))
    x = np.arange(4, dtype=np.float32)
    for kw in ({"check_vma": False}, {"check_rep": False}, {}):
        fn = shard_map(lambda s: s * 2, mesh=mesh, in_specs=(P("data"),),
                       out_specs=P("data"), **kw)
        assert np.allclose(np.asarray(fn(x)), x * 2)
    with pytest.raises(ValueError):
        shard_map(lambda s: s, mesh=mesh, in_specs=(P("data"),),
                  out_specs=P("data"), check_vma=True, check_rep=False)


def test_query_axes_must_be_disjoint_from_corpus_axes():
    """A shared axis would top-k-merge results of *different* queries —
    refuse loudly instead of returning silently wrong neighbors."""
    import jax

    from repro.distributed import sharded_brute_search

    mesh = jax.make_mesh((1,), ("data",))
    db = np.zeros((8, 4), np.float32)
    with pytest.raises(ValueError, match="disjoint"):
        sharded_brute_search(mesh, db, db[:2], 2,
                             axes=("data",), query_axes=("data",))


def test_core_distributed_shim_reexports():
    """Old import path keeps working after the move to repro.distributed."""
    from repro.core import distributed as old
    from repro.distributed import sharding as new

    assert old.sharded_brute_search is new.sharded_brute_search
    assert old.sharded_ivf_search is new.sharded_ivf_search
    assert old.sharded_forest_search is new.sharded_forest_search


def test_shard_forest_slices_conserve_entities():
    """Slicing the concatenated forest into shards keeps every node and
    maps each leaf slot id back to the entity the global forest holds."""
    from repro.core.two_level import TwoLevelConfig, build_two_level
    from repro.distributed import shard_forest

    rng = np.random.default_rng(0)
    db = rng.normal(size=(600, 8)).astype(np.float32)
    idx = build_two_level(db, TwoLevelConfig(
        n_clusters=16, top="brute", bottom="tree", kmeans_iters=3,
        tree_leaf=4))
    n_dev = 4
    sh = shard_forest(idx, n_dev)
    K, cap = idx.bucket_ids.shape
    Kloc = -(-K // n_dev)
    # a real node is internal (children >= 0) or a leaf (leaf_row >= 0);
    # everything else is shard padding / the dead node
    total_nodes = sum(
        int(((sh["children"][s, :, 0] >= 0)
             | (sh["leaf_row"][s] >= 0)).sum())
        for s in range(n_dev))
    assert total_nodes == np.asarray(idx.forest.arrays["children"]).shape[0]
    seen = []
    for s in range(n_dev):
        assert sh["valid"][s].sum() == min(Kloc, max(0, K - s * Kloc))
        le = sh["leaf_entities"][s]
        slots = le[le >= 0]
        gids = sh["bucket_ids"][s].reshape(-1)[slots]
        assert (gids >= 0).all()      # every slot id resolves to an entity
        seen.append(gids)
    seen = np.concatenate(seen)
    # forests partition entities: each appears exactly once across shards
    assert np.array_equal(np.sort(seen), np.arange(db.shape[0]))


def test_shard_forest_shapes_stable_across_mutation():
    """Slicing a mutated forest into the shapes recorded before the
    mutation yields identically-shaped shards (the no-re-jit contract),
    and outgrowing the reservation raises instead of silently reshaping."""
    from repro.core.two_level import TwoLevelConfig, build_two_level
    from repro.distributed import forest_shard_shapes, shard_forest

    rng = np.random.default_rng(1)
    db = rng.normal(size=(600, 8)).astype(np.float32)
    idx = build_two_level(db, TwoLevelConfig(
        n_clusters=16, top="brute", bottom="tree", kmeans_iters=3,
        tree_leaf=4))
    n_dev = 4
    shapes = forest_shard_shapes(idx, n_dev, headroom=1.5)
    sh0 = shard_forest(idx, n_dev, shapes=shapes)
    idx.delete_entities(rng.choice(600, 150, replace=False))
    idx.add_entities(rng.normal(size=(180, 8)).astype(np.float32))
    idx.rebalance()
    sh1 = shard_forest(idx, n_dev, shapes=shapes)
    for name in sh0:
        if name == "max_depth":
            assert sh0[name] == sh1[name]
            continue
        assert sh0[name].shape == sh1[name].shape, name
    # shard contents track the mutation: no deleted slot survives
    le = sh1["leaf_entities"]
    slots = le[le >= 0]
    # every remaining slot resolves to a live entity
    for s in range(n_dev):
        les = sh1["leaf_entities"][s]
        gids = sh1["bucket_ids"][s].reshape(-1)[les[les >= 0]]
        assert (gids >= 0).all()
        assert idx.alive[gids].all()
    # tiny reservation -> loud failure, not silent reshape
    import dataclasses

    small = dataclasses.replace(
        forest_shard_shapes(idx, n_dev, headroom=1.0), nodes=2)
    with pytest.raises(ValueError, match="outgrew"):
        shard_forest(idx, n_dev, shapes=small)


def test_shard_forest_slab_layout_conserves_entities():
    """The slab layout (delta-shipping layout: fixed per-bucket node/leaf
    windows) must hold exactly the same entities as the packed layout —
    same contract as the packed slicer test, plus every bucket's nodes
    land inside its own slab."""
    from repro.core.two_level import TwoLevelConfig, build_two_level
    from repro.distributed import forest_shard_shapes, shard_forest

    rng = np.random.default_rng(7)
    db = rng.normal(size=(600, 8)).astype(np.float32)
    idx = build_two_level(db, TwoLevelConfig(
        n_clusters=16, top="brute", bottom="tree", kmeans_iters=3,
        tree_leaf=4))
    n_dev = 4
    shapes = forest_shard_shapes(idx, n_dev, headroom=1.0, layout="slab")
    assert shapes.node_slab > 0
    assert shapes.nodes == shapes.kloc * shapes.node_slab
    sh = shard_forest(idx, n_dev, shapes=shapes)
    seen = []
    for s in range(n_dev):
        le = sh["leaf_entities"][s]
        slots = le[le >= 0]
        gids = sh["bucket_ids"][s].reshape(-1)[slots]
        assert (gids >= 0).all()
        seen.append(gids)
        # every real root sits at its slot's slab start
        val = sh["valid"][s]
        for j in np.nonzero(val)[0]:
            assert sh["roots"][s, j] == j * shapes.node_slab
    seen = np.concatenate(seen)
    assert np.array_equal(np.sort(seen), np.arange(db.shape[0]))


def test_shard_forest_slab_shapes_stable_across_mutation():
    """Slab re-slicing of a mutated forest keeps identical shapes (the
    no-re-jit contract), and a bucket outgrowing its slab raises."""
    import dataclasses

    from repro.core.two_level import TwoLevelConfig, build_two_level
    from repro.distributed import forest_shard_shapes, shard_forest

    rng = np.random.default_rng(8)
    db = rng.normal(size=(600, 8)).astype(np.float32)
    idx = build_two_level(db, TwoLevelConfig(
        n_clusters=16, top="brute", bottom="tree", kmeans_iters=3,
        tree_leaf=4))
    n_dev = 4
    shapes = forest_shard_shapes(idx, n_dev, headroom=1.5, layout="slab")
    sh0 = shard_forest(idx, n_dev, shapes=shapes)
    idx.delete_entities(rng.choice(600, 150, replace=False))
    idx.add_entities(rng.normal(size=(180, 8)).astype(np.float32))
    idx.rebalance()
    sh1 = shard_forest(idx, n_dev, shapes=shapes)
    for name in sh0:
        if name == "max_depth":
            continue
        assert sh0[name].shape == sh1[name].shape, name
    small = dataclasses.replace(shapes, node_slab=1)
    with pytest.raises(ValueError, match="outgrew"):
        shard_forest(idx, n_dev, shapes=small)


def test_slice_forest_delta_matches_full_slab_slice():
    """A dirty bucket's delta slab must be byte-identical to the same
    bucket's window in a full slab re-slice — the invariant that makes
    the device scatter equivalent to a full re-place."""
    from repro.core.two_level import TwoLevelConfig, build_two_level
    from repro.distributed import (
        forest_shard_shapes,
        shard_forest,
        slice_forest_delta,
    )

    rng = np.random.default_rng(9)
    db = rng.normal(size=(600, 8)).astype(np.float32)
    idx = build_two_level(db, TwoLevelConfig(
        n_clusters=16, top="brute", bottom="tree", kmeans_iters=3,
        tree_leaf=4))
    n_dev = 4
    shapes = forest_shard_shapes(idx, n_dev, headroom=1.5, layout="slab")
    b = int(np.argmax(idx.bucket_counts))
    idx.delete_entities(idx.bucket_ids[b][:4].copy())
    man = idx.pop_delta()
    pay = slice_forest_delta(idx, shapes, man.dirty_buckets)
    full = shard_forest(idx, n_dev, shapes=shapes)
    ns, ls = shapes.node_slab, shapes.leaf_slab
    for u in range(pay["shard"].size):
        s, j = int(pay["shard"][u]), int(pay["slot"][u])
        np.testing.assert_array_equal(
            pay["proj"][u], full["proj"][s, j * ns:(j + 1) * ns])
        np.testing.assert_array_equal(
            pay["children"][u], full["children"][s, j * ns:(j + 1) * ns])
        np.testing.assert_array_equal(
            pay["leaf_entities"][u],
            full["leaf_entities"][s, j * ls:(j + 1) * ls])
        np.testing.assert_array_equal(
            pay["bucket_ids"][u], full["bucket_ids"][s, j])
        np.testing.assert_array_equal(pay["bvecs"][u], full["bvecs"][s, j])
        assert pay["roots"][u] == full["roots"][s, j]


# ---------------------------------------------------------------------------
# slow, subprocess: real 8-device semantics
# ---------------------------------------------------------------------------


@slow
def test_sharded_brute_matches_exact():
    out = _run("""
    from repro.distributed import sharded_brute_search
    from repro.core.brute import brute_search
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    db = rng.normal(size=(3000, 16)).astype(np.float32)
    q = rng.normal(size=(32, 16)).astype(np.float32)
    d, i = sharded_brute_search(mesh, db, q, 10)
    dt, it = brute_search(q, db, 10)
    print("MATCH", float((np.asarray(i) == it).mean()))
    """)
    assert "MATCH 1.0" in out


@slow
def test_query_and_corpus_2axis_sharded_matches_exact():
    """Corpus sharded over one mesh axis, query batch over the other —
    results identical to the single-device scan (B not divisible by the
    query axis exercises the host-side batch pad)."""
    out = _run("""
    from repro.distributed import sharded_brute_search
    from repro.core.brute import brute_search
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(1)
    db = rng.normal(size=(2500, 16)).astype(np.float32)
    q = rng.normal(size=(37, 16)).astype(np.float32)   # 37 % 4 != 0
    d, i = sharded_brute_search(mesh, db, q, 10,
                                axes=("data",), query_axes=("model",))
    dt, it = brute_search(q, db, 10)
    print("MATCH", float((np.asarray(i) == it).mean()),
          float(np.abs(np.asarray(d) - dt).max()))
    """)
    assert "MATCH 1.0" in out


@slow
def test_sharded_ivf_recall():
    out = _run("""
    from repro.distributed import sharded_ivf_search
    from repro.core.two_level import TwoLevelConfig, build_two_level
    from repro.core.brute import brute_search
    from repro.core.metrics import recall_at_k
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    c = rng.normal(size=(32, 16)) * 4
    db = (c[rng.integers(0, 32, 4000)] + rng.normal(size=(4000, 16))).astype(np.float32)
    q = db[:64] + rng.normal(size=(64, 16)).astype(np.float32) * 0.05
    idx = build_two_level(db, TwoLevelConfig(n_clusters=64, top="brute",
                          bottom="brute", kmeans_iters=5))
    d, i = sharded_ivf_search(mesh, idx, q, 10, nprobe_local=4)
    _, it = brute_search(q, db, 10)
    print("RECALL", recall_at_k(np.asarray(i), it))
    """)
    recall = float(out.split("RECALL")[1].strip())
    assert recall > 0.8


@slow
def test_sharded_forest_recall():
    """Tree/QLBT forest bottom level, sharded: each chip descends its own
    slice of the concatenated forest; merged recall clears the paper bar."""
    out = _run("""
    from repro.distributed import sharded_forest_search
    from repro.core.two_level import TwoLevelConfig, build_two_level
    from repro.core.brute import brute_search
    from repro.core.metrics import recall_at_k
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    c = rng.normal(size=(32, 16)) * 4
    db = (c[rng.integers(0, 32, 4000)] + rng.normal(size=(4000, 16))).astype(np.float32)
    q = db[:64] + rng.normal(size=(64, 16)).astype(np.float32) * 0.05
    idx = build_two_level(db, TwoLevelConfig(n_clusters=64, top="brute",
                          bottom="tree", kmeans_iters=5, tree_leaf=8))
    d, i = sharded_forest_search(mesh, idx, q, 10, nprobe_local=4,
                                 beam_width=8)
    _, it = brute_search(q, db, 10)
    print("RECALL", recall_at_k(np.asarray(i), it))
    d2, i2 = sharded_forest_search(mesh, idx, q, 10, nprobe_local=4,
                                   beam_width=8, axes=("data",),
                                   query_axes=("model",))
    print("RECALL2", recall_at_k(np.asarray(i2), it))
    """)
    assert float(out.split("RECALL2")[1].strip()) > 0.8
    assert float(out.split("RECALL")[1].split()[0]) > 0.8


@slow
def test_sharded_ivf_full_probe_identical_to_single_device():
    """At full probe both paths are exact scans over the bucketed corpus,
    so the sharded IVF must return the *identical* (id, distance) sets as
    the unsharded index — including bucket-grid padding (K % shards != 0)
    and row padding (N % shards != 0), the PR 2 edge cases."""
    out = _run("""
    from repro.distributed import sharded_ivf_search
    from repro.core.two_level import TwoLevelConfig, build_two_level
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(3)
    c = rng.normal(size=(32, 16)) * 4
    db = (c[rng.integers(0, 32, 2500)] + rng.normal(size=(2500, 16))).astype(np.float32)
    q = db[:40] + rng.normal(size=(40, 16)).astype(np.float32) * 0.05
    idx = build_two_level(db, TwoLevelConfig(n_clusters=50, top="brute",
                          bottom="brute", kmeans_iters=5))
    Kp = -(-50 // 8) * 8
    d, i = sharded_ivf_search(mesh, idx, q, 10, nprobe_local=Kp // 8)
    ds, js, _ = idx.search(q, 10, nprobe=50)
    ok_d = np.allclose(np.sort(d), np.sort(ds), rtol=1e-4, atol=1e-4)
    ok_i = all(set(i[b].tolist()) == set(js[b].tolist()) for b in range(40))
    print("IDENT", bool(ok_d and ok_i))
    """)
    assert "IDENT True" in out


@slow
def test_sharded_forest_full_probe_identical_to_single_device():
    """Every shard descends the same per-bucket trees the single-device
    forest holds; with every bucket probed on both sides the candidate
    sets coincide, so the merged (id, distance) sets must be identical."""
    out = _run("""
    from repro.distributed import sharded_forest_search
    from repro.core.two_level import TwoLevelConfig, build_two_level
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(4)
    c = rng.normal(size=(32, 16)) * 4
    db = (c[rng.integers(0, 32, 2700)] + rng.normal(size=(2700, 16))).astype(np.float32)
    q = db[:40] + rng.normal(size=(40, 16)).astype(np.float32) * 0.05
    idx = build_two_level(db, TwoLevelConfig(n_clusters=50, top="brute",
                          bottom="tree", kmeans_iters=5, tree_leaf=8))
    Kp = -(-50 // 8) * 8
    d, i = sharded_forest_search(mesh, idx, q, 10, nprobe_local=Kp // 8,
                                 beam_width=8)
    ds, js, _ = idx.search(q, 10, nprobe=50, beam_width=8)
    ok_d = np.allclose(np.sort(d), np.sort(ds), rtol=1e-4, atol=1e-4)
    ok_i = all(set(i[b].tolist()) == set(js[b].tolist()) for b in range(40))
    print("IDENT", bool(ok_d and ok_i))
    """)
    assert "IDENT True" in out


@slow
def test_serving_engine_sharded_survives_mutation_without_rejit():
    """Acceptance: ServingEngine.sharded keeps answering through a 30%
    interleaved add/delete + rebalance — deleted ids never served, the
    jitted search kernel's compile cache is untouched (no re-jit)."""
    out = _run("""
    from repro.serve.engine import ServingEngine
    from repro.core.two_level import TwoLevelConfig, build_two_level
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(5)
    c = rng.normal(size=(32, 16)) * 4
    def mk(n):
        return (c[rng.integers(0, 32, n)] + rng.normal(size=(n, 16))).astype(np.float32)
    db = mk(3000)
    idx = build_two_level(db, TwoLevelConfig(n_clusters=64, top="brute",
                          bottom="tree", kmeans_iters=4, tree_leaf=8))
    eng = ServingEngine.sharded(mesh, idx, kind="forest", k=10,
                                nprobe_local=4, beam_width=8, headroom=1.5,
                                max_batch=16, max_wait_ms=2.0)
    q = mk(48)
    futs = [eng.submit(q[j]) for j in range(48)]
    _ = [f.get(timeout=120) for f in futs]
    cache0 = eng.search_fn.jit_cache_size()
    deleted = []
    for r in range(3):
        live = np.nonzero(idx.alive)[0]
        dele = rng.choice(live, 300, replace=False)
        idx.delete_entities(dele); deleted.append(dele)
        idx.add_entities(mk(300))
    idx.rebalance()
    eng.apply_updates(idx)
    deleted = np.concatenate(deleted)
    futs = [eng.submit(q[j]) for j in range(48)]
    ids = np.stack([f.get(timeout=120)[1] for f in futs])
    cache1 = eng.search_fn.jit_cache_size()
    eng.close()
    print("CACHE", cache0, cache1, "CLEAN", bool(not np.isin(ids, deleted).any()))
    """)
    parts = out.split()
    c0 = int(parts[parts.index("CACHE") + 1])
    c1 = int(parts[parts.index("CACHE") + 2])
    assert "CLEAN True" in out
    assert c1 == c0, f"search kernel re-jitted: {c0} -> {c1}"


@slow
def test_sharded_delta_apply_identical_to_full_8dev():
    """Real 8-device mesh: a delta apply must leave the backend bitwise
    identical to a full re-place of the same mutated index, ship a small
    fraction of the full bytes for a localized mutation, and never touch
    the search kernel's compile cache."""
    out = _run("""
    from repro.core.two_level import TwoLevelConfig, build_two_level
    from repro.distributed.backend import ShardedSearchBackend
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(6)
    c = rng.normal(size=(32, 16)) * 4
    def mk(n):
        return (c[rng.integers(0, 32, n)] + rng.normal(size=(n, 16))).astype(np.float32)
    db = mk(3000)
    idx = build_two_level(db, TwoLevelConfig(n_clusters=64, top="brute",
                          bottom="tree", kmeans_iters=4, tree_leaf=8))
    kw = dict(kind="forest", k=10, nprobe_local=4, beam_width=8, headroom=1.5)
    beA = ShardedSearchBackend(mesh, idx, **kw)
    beB = ShardedSearchBackend(mesh, idx, **kw)
    q = mk(32)
    dA0, _ = beA(q)
    cache0 = beA.jit_cache_size()
    b = int(np.argmax(idx.bucket_counts))
    dele = idx.bucket_ids[b][:10].copy()
    idx.delete_entities(dele)
    idx.add_entities(mk(12))
    man = idx.pop_delta()
    st = beA.apply_updates(idx, delta=man)
    beB.apply_updates(idx)
    same = all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(beA._args, beB._args))
    dA, iA = beA(q)
    dB, iB = beB(q)
    print("MODE", st["mode"], "FRAC", round(st["bytes"] / st["full_bytes"], 3),
          "SAME", bool(same and np.array_equal(dA, dB)
                       and np.array_equal(iA, iB)),
          "CACHE", cache0, beA.jit_cache_size(),
          "CLEAN", bool(not np.isin(iA, dele).any()))
    """)
    parts = out.split()
    assert "MODE delta" in out
    assert float(parts[parts.index("FRAC") + 1]) < 0.5
    assert "SAME True" in out and "CLEAN True" in out
    c0 = int(parts[parts.index("CACHE") + 1])
    c1 = int(parts[parts.index("CACHE") + 2])
    assert c1 == c0, f"search kernel re-jitted: {c0} -> {c1}"


@slow
def test_serving_engine_sharded_backend():
    """ServingEngine.sharded: exact sharded scan behind the micro-batcher
    returns the single-device answers."""
    out = _run("""
    from repro.serve.engine import ServingEngine
    from repro.core.brute import brute_search
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(2)
    db = rng.normal(size=(2000, 16)).astype(np.float32)
    eng = ServingEngine.sharded(mesh, db, k=5, max_batch=16, max_wait_ms=2.0)
    q = rng.normal(size=(40, 16)).astype(np.float32)
    futs = [eng.submit(q[j]) for j in range(40)]
    ids = np.stack([f.get(timeout=60)[1] for f in futs])
    eng.close()
    _, it = brute_search(q, db, 5)
    print("MATCH", float((ids == it).mean()))
    """)
    assert "MATCH 1.0" in out


@slow
def test_compressed_psum_approximates_mean():
    out = _run("""
    from repro.compat import shard_map
    from repro.train.compression import compressed_psum
    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    x = rng.normal(size=(8, 64)).astype(np.float32)
    fn = shard_map(lambda s: compressed_psum(s[0], "data"),
                   mesh=mesh, in_specs=P("data", None),
                   out_specs=P(None), check_vma=False)
    got = np.asarray(fn(x))
    want = x.mean(0)
    err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
    print("ERR", err)
    """)
    assert float(out.split("ERR")[1]) < 0.05


@slow
def test_elastic_reshard_restore_1_to_8_devices():
    out = _run("""
    import tempfile
    from repro.train import checkpoint as C
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
    with tempfile.TemporaryDirectory() as d:
        C.save(d, 1, tree)                      # saved "single-host"
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        shard = {"w": NamedSharding(mesh, P("data", "model")),
                 "b": NamedSharding(mesh, P("model"))}
        out = C.restore(d, 1, tree, shardings=shard)
        ok1 = (np.asarray(out["w"]) == np.asarray(tree["w"])).all()
        ok2 = len(out["w"].sharding.device_set) == 8
        print("OK", bool(ok1 and ok2))
    """)
    assert "OK True" in out


@slow
def test_lm_train_step_sharded_equals_local():
    """One train step on a 2x4 mesh == the same step on one device."""
    out = _run("""
    from repro.configs.base import LMConfig
    from repro.models import transformer as T
    from repro.distributed.sharding import ShardPlan
    from repro.train import optim
    from repro.train.loop import init_state, make_train_step
    from repro.data.lm import LMStream

    cfg = LMConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                   n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
                   qk_norm=True, remat=False)
    key = jax.random.PRNGKey(0)
    stream = LMStream(cfg.vocab, 16, 8, seed=0)
    batch = stream.batch_at(0)
    opt = optim.adamw(optim.constant_lr(1e-3))

    # local
    s0 = init_state(T.init(cfg, key), opt)
    local_step = jax.jit(make_train_step(
        lambda p, b: T.loss_fn(p, b, cfg), opt))
    s1, aux1 = local_step(s0, batch)

    # sharded
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    plan = ShardPlan(dp=("data",), fsdp=("data",), tp=("model",),
                     ep=("data", "model"), mesh=mesh)
    s0b = init_state(T.init(cfg, key), opt)
    sh_step = jax.jit(make_train_step(
        lambda p, b: T.loss_fn(p, b, cfg, plan), opt))
    with mesh:
        s2, aux2 = sh_step(s0b, batch)
    da = abs(float(aux1["loss"]) - float(aux2["loss"]))
    pa = np.asarray(jax.tree.leaves(s1.params)[0])
    pb = np.asarray(jax.tree.leaves(s2.params)[0])
    print("LOSSDIFF", da, "PARAMDIFF", float(np.abs(pa - pb).max()))
    """)
    parts = out.split()
    loss_diff = float(parts[parts.index("LOSSDIFF") + 1])
    param_diff = float(parts[parts.index("PARAMDIFF") + 1])
    assert loss_diff < 1e-3
    assert param_diff < 1e-3
