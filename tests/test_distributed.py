"""Multi-device semantics on 8 fake devices (subprocess: tests themselves
run single-device).  Covers: distributed exact/IVF search, compressed psum,
elastic checkpoint resharding, and a sharded LM train step."""
import subprocess
import sys
import textwrap

import pytest

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
"""


def _run(body: str):
    code = _PRELUDE + textwrap.dedent(body)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_sharded_brute_matches_exact():
    out = _run("""
    from repro.core.distributed import sharded_brute_search
    from repro.core.brute import brute_search
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    db = rng.normal(size=(3000, 16)).astype(np.float32)
    q = rng.normal(size=(32, 16)).astype(np.float32)
    d, i = sharded_brute_search(mesh, db, q, 10)
    dt, it = brute_search(q, db, 10)
    print("MATCH", float((np.asarray(i) == it).mean()))
    """)
    assert "MATCH 1.0" in out


def test_sharded_ivf_recall():
    out = _run("""
    from repro.core.distributed import sharded_ivf_search
    from repro.core.two_level import TwoLevelConfig, build_two_level
    from repro.core.brute import brute_search
    from repro.core.metrics import recall_at_k
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    c = rng.normal(size=(32, 16)) * 4
    db = (c[rng.integers(0, 32, 4000)] + rng.normal(size=(4000, 16))).astype(np.float32)
    q = db[:64] + rng.normal(size=(64, 16)).astype(np.float32) * 0.05
    idx = build_two_level(db, TwoLevelConfig(n_clusters=64, top="brute",
                          bottom="brute", kmeans_iters=5))
    d, i = sharded_ivf_search(mesh, idx, q, 10, nprobe_local=4)
    _, it = brute_search(q, db, 10)
    print("RECALL", recall_at_k(np.asarray(i), it))
    """)
    recall = float(out.split("RECALL")[1].strip())
    assert recall > 0.8


def test_compressed_psum_approximates_mean():
    out = _run("""
    from repro.train.compression import compressed_psum
    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    x = rng.normal(size=(8, 64)).astype(np.float32)
    fn = jax.shard_map(lambda s: compressed_psum(s[0], "data"),
                       mesh=mesh, in_specs=P("data", None),
                       out_specs=P(None), check_vma=False)
    got = np.asarray(fn(x))
    want = x.mean(0)
    err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
    print("ERR", err)
    """)
    assert float(out.split("ERR")[1]) < 0.05


def test_elastic_reshard_restore_1_to_8_devices():
    out = _run("""
    import tempfile
    from repro.train import checkpoint as C
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
    with tempfile.TemporaryDirectory() as d:
        C.save(d, 1, tree)                      # saved "single-host"
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        shard = {"w": NamedSharding(mesh, P("data", "model")),
                 "b": NamedSharding(mesh, P("model"))}
        out = C.restore(d, 1, tree, shardings=shard)
        ok1 = (np.asarray(out["w"]) == np.asarray(tree["w"])).all()
        ok2 = len(out["w"].sharding.device_set) == 8
        print("OK", bool(ok1 and ok2))
    """)
    assert "OK True" in out


def test_lm_train_step_sharded_equals_local():
    """One train step on a 2x4 mesh == the same step on one device."""
    out = _run("""
    from repro.configs.base import LMConfig
    from repro.models import transformer as T
    from repro.distributed.sharding import ShardPlan
    from repro.train import optim
    from repro.train.loop import init_state, make_train_step
    from repro.data.lm import LMStream

    cfg = LMConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                   n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
                   qk_norm=True, remat=False)
    key = jax.random.PRNGKey(0)
    stream = LMStream(cfg.vocab, 16, 8, seed=0)
    batch = stream.batch_at(0)
    opt = optim.adamw(optim.constant_lr(1e-3))

    # local
    s0 = init_state(T.init(cfg, key), opt)
    local_step = jax.jit(make_train_step(
        lambda p, b: T.loss_fn(p, b, cfg), opt))
    s1, aux1 = local_step(s0, batch)

    # sharded
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    plan = ShardPlan(dp=("data",), fsdp=("data",), tp=("model",),
                     ep=("data", "model"), mesh=mesh)
    s0b = init_state(T.init(cfg, key), opt)
    sh_step = jax.jit(make_train_step(
        lambda p, b: T.loss_fn(p, b, cfg, plan), opt))
    with mesh:
        s2, aux2 = sh_step(s0b, batch)
    da = abs(float(aux1["loss"]) - float(aux2["loss"]))
    pa = np.asarray(jax.tree.leaves(s1.params)[0])
    pb = np.asarray(jax.tree.leaves(s2.params)[0])
    print("LOSSDIFF", da, "PARAMDIFF", float(np.abs(pa - pb).max()))
    """)
    parts = out.split()
    loss_diff = float(parts[parts.index("LOSSDIFF") + 1])
    param_diff = float(parts[parts.index("PARAMDIFF") + 1])
    assert loss_diff < 1e-3
    assert param_diff < 1e-3
