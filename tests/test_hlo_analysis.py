"""Regex-parsing tests for repro.launch.hlo_analysis on synthetic HLO.

The roofline's inputs come from text parses of post-optimization HLO
dumps; XLA's dump format varies (typed vs untyped dot operands, async
collective pairs), so each variant gets a fixture here.  Weighting is
checked against the nested-while trip-count product by hand.
"""
import textwrap

from repro.launch.hlo_analysis import analyze_hlo, peak_liveness


def hlo(s: str) -> str:
    return textwrap.dedent(s).strip("\n") + "\n"


_TYPED_DOT = hlo("""
    %cond.1 (arg.1: (s32[], f32[8,64])) -> pred[] {
      %arg.1 = (s32[], f32[8,64]) parameter(0)
      %i.1 = s32[] get-tuple-element(%arg.1), index=0
      %limit.1 = s32[] constant(12)
      ROOT %lt.1 = pred[] compare(%i.1, %limit.1), direction=LT
    }

    %body.1 (arg.2: (s32[], f32[8,64])) -> (s32[], f32[8,64]) {
      %lhs = f32[8,64]{1,0} parameter(0)
      %rhs = f32[64,32]{1,0} parameter(1)
      %d = f32[8,32]{1,0} dot(f32[8,64]{1,0} %lhs, f32[64,32]{1,0} %rhs), lhs_contracting_dims={1}, rhs_contracting_dims={0}
    }

    ENTRY %main (p0: f32[8,64]) -> f32[8,32] {
      %p0 = f32[8,64]{1,0} parameter(0)
      ROOT %w = (s32[], f32[8,64]) while((s32[], f32[8,64]) %p0), condition=%cond.1, body=%body.1
    }
""")


def test_typed_dot_operands_and_trip_weighting():
    out = analyze_hlo(_TYPED_DOT)
    # 2 * (8*32 out) * (64 contraction) * 12 trips
    assert out["matmul_flops"] == 2.0 * 8 * 32 * 64 * 12
    assert out["while_trip_multipliers"] == {"body.1": 12.0}
    assert out["n_computations"] == 3


def test_untyped_dot_operands():
    out = analyze_hlo(hlo("""
        ENTRY %main (a: f32[4,8], b: f32[8,4]) -> f32[4,4] {
          %a = f32[4,8]{1,0} parameter(0)
          %b = f32[8,4]{1,0} parameter(1)
          ROOT %d = f32[4,4]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
        }
    """))
    assert out["matmul_flops"] == 2.0 * 16 * 8


def test_missing_contracting_dims_falls_back_to_k1():
    out = analyze_hlo(hlo("""
        ENTRY %main (a: f32[4,8], b: f32[8,4]) -> f32[4,4] {
          %a = f32[4,8]{1,0} parameter(0)
          %b = f32[8,4]{1,0} parameter(1)
          ROOT %d = f32[4,4]{1,0} dot(%a, %b)
        }
    """))
    assert out["matmul_flops"] == 2.0 * 16 * 1


def test_nested_while_bodies_multiply():
    out = analyze_hlo(hlo("""
        %cond.outer (a: s32[]) -> pred[] {
          %c.o = s32[] constant(12)
        }

        %cond.inner (a: s32[]) -> pred[] {
          %c.i = s32[] constant(5)
        }

        %body.inner (x: f32[2,2]) -> f32[2,2] {
          %xi = f32[2,2]{1,0} parameter(0)
          ROOT %di = f32[2,2]{1,0} dot(%xi, %xi), lhs_contracting_dims={1}
        }

        %body.outer (x: f32[2,2]) -> f32[2,2] {
          %xo = f32[2,2]{1,0} parameter(0)
          ROOT %wi = f32[2,2] while(f32[2,2] %xo), condition=%cond.inner, body=%body.inner
        }

        ENTRY %main (x: f32[2,2]) -> f32[2,2] {
          %x = f32[2,2]{1,0} parameter(0)
          ROOT %wo = f32[2,2] while(f32[2,2] %x), condition=%cond.outer, body=%body.outer
        }
    """))
    assert out["while_trip_multipliers"] == {"body.inner": 60.0,
                                             "body.outer": 12.0}
    # inner dot: lhs is a param f32[2,2], contraction dim 1 -> k=2
    assert out["matmul_flops"] == 2.0 * 4 * 2 * 60


def test_cond_without_constant_uses_default_trip():
    txt = hlo("""
        %cond.1 (a: s32[]) -> pred[] {
          %one = s32[] constant(1)
        }

        %body.1 (x: f32[8]) -> f32[8] {
          %xb = f32[8]{0} parameter(0)
          ROOT %cp = f32[8]{0} copy(%xb)
        }

        ENTRY %main (x: f32[8]) -> f32[8] {
          %x = f32[8]{0} parameter(0)
          ROOT %w = f32[8] while(f32[8] %x), condition=%cond.1, body=%body.1
        }
    """)
    # constant(1) is filtered (loop counters start at 0/1); default applies
    assert analyze_hlo(txt)["while_trip_multipliers"] == {"body.1": 1.0}
    assert analyze_hlo(txt, default_trip=7)["while_trip_multipliers"] \
        == {"body.1": 7.0}


def test_collectives_counted_and_all_reduce_doubled():
    out = analyze_hlo(hlo("""
        ENTRY %main (x: f32[1024]) -> f32[1024] {
          %x = f32[1024]{0} parameter(0)
          %ar = f32[1024]{0} all-reduce(%x), replica_groups={}
          %ags = f32[1024]{0} all-gather-start(%x), dimensions={0}
          ROOT %agd = f32[1024]{0} all-gather-done(%ags)
        }
    """))
    cb = out["collective_bytes"]
    assert cb["all-reduce"] == 2.0 * 4096        # ring factor
    assert cb["all-gather"] == 4096.0            # -start counted once
    assert cb["total"] == 3 * 4096.0
    assert out["collective_counts"]["all-reduce"] == 1
    assert out["collective_counts"]["all-gather"] == 1


def test_mem_proxy_skips_aliasing_ops():
    out = analyze_hlo(hlo("""
        ENTRY %main (x: f32[256]) -> f32[256] {
          %x = f32[256]{0} parameter(0)
          %t = (f32[256]) tuple(%x)
          %g = f32[256]{0} get-tuple-element(%t), index=0
          ROOT %cp = f32[256]{0} copy(%g)
        }
    """))
    # only the copy streams: 2 * 1024 bytes read+write
    assert out["mem_bytes_proxy"] == 2.0 * 1024


def test_entry_f32_hoist_detection():
    out = analyze_hlo(hlo("""
        ENTRY %main (w: bf16[300000000]) -> f32[300000000] {
          %w = bf16[300000000]{0} parameter(0)
          ROOT %convert.5 = f32[300000000]{0} convert(bf16[300000000]{0} %w)
        }
    """))
    assert out["entry_f32_weight_convert_bytes"] == 4.0 * 300_000_000


def test_no_entry_reports_error():
    out = analyze_hlo(hlo("""
        %helper (x: f32[4]) -> f32[4] {
          %x = f32[4]{0} parameter(0)
        }
    """))
    assert out == {"error": "no ENTRY computation found"}


def test_peak_liveness_frees_after_last_use():
    out = peak_liveness(hlo("""
        ENTRY %main (p: f32[1048576]) -> f32[1048576] {
          %p = f32[1048576]{0} parameter(0)
          %a = f32[1048576]{0} copy(%p)
          %b = f32[1048576]{0} add(%a, %a)
          %c = f32[1048576]{0} multiply(%b, %b)
          ROOT %r = f32[1048576]{0} copy(%c)
        }
    """))
    m = out["main"]
    # two 4 MiB buffers overlap at most (a+b), never three
    assert m["peak_bytes"] == 2 * 4 * 1048576
    names = {n for n, _b, _s in m["top_buffers"]}
    assert names == {"a", "b"}
    shapes = {s for _n, _b, s in m["top_buffers"]}
    assert shapes == {"f32[1048576]"}


def test_peak_liveness_walks_while_bodies():
    out = peak_liveness(hlo("""
        %cond.1 (a: s32[]) -> pred[] {
          %c = s32[] constant(3)
        }

        %body.1 (x: f32[1048576]) -> f32[1048576] {
          %x = f32[1048576]{0} parameter(0)
          ROOT %y = f32[1048576]{0} copy(%x)
        }

        ENTRY %main (x: f32[1048576]) -> f32[1048576] {
          %x = f32[1048576]{0} parameter(0)
          ROOT %w = f32[1048576] while(f32[1048576] %x), condition=%cond.1, body=%body.1
        }
    """))
    assert "body.1" in out
    assert out["body.1"]["peak_bytes"] == 4 * 1048576
