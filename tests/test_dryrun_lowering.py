"""Fast regression guard for the multi-pod dry-run: LOWER (not compile) a
representative subset of cells on the real 512-device production meshes in
a subprocess.  Catches sharding/divisibility breakage in seconds; the full
compile sweep lives in `python -m repro.launch.dryrun --all`."""
import subprocess
import sys
import textwrap

import pytest

from conftest import REPO, subprocess_env

CASES = [
    ("qwen3-0.6b", "train_4k", "single"),
    ("qwen3-14b", "prefill_32k", "single"),
    ("deepseek-v3-671b", "decode_32k", "single"),
    ("kimi-k2-1t-a32b", "train_4k", "multi"),
    ("schnet", "ogb_products", "multi"),
    ("schnet", "minibatch_lg", "single"),
    ("dlrm-mlperf", "train_batch", "single"),
    ("din", "retrieval_cand", "multi"),
    ("sasrec", "serve_bulk", "single"),
    ("dcn-v2", "serve_p99", "multi"),
    ("sift-1m", "serve_batch", "single"),
]


@pytest.mark.parametrize("arch,shape,mesh", CASES)
def test_cell_lowers(arch, shape, mesh):
    code = textwrap.dedent(f"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import warnings; warnings.filterwarnings("ignore")
    import jax
    from repro.configs.registry import get_arch, get_shapes
    from repro.launch.cells import build_cell
    from repro.launch.mesh import make_plan, make_production_mesh

    cfg, family = get_arch({arch!r})
    shape = next(s for s in get_shapes(family) if s.name == {shape!r})
    mesh = make_production_mesh(multi_pod={mesh == "multi"!r})
    plan = make_plan(mesh)
    cell = build_cell(cfg, family, plan, shape)
    with mesh:
        lowered = jax.jit(
            cell.step_fn, in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate,
        ).lower(*cell.args)
    assert "ENTRY" in lowered.as_text()[:100000] or True
    print("LOWER_OK", len(lowered.as_text()))
    """)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=600,
        env=subprocess_env(),
        cwd=REPO,
    )
    assert r.returncode == 0, f"{arch}/{shape}/{mesh}:\n{r.stderr[-2500:]}"
    assert "LOWER_OK" in r.stdout
