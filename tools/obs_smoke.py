#!/usr/bin/env python
"""Observability smoke check: drive a short fleet workload, then assert
the telemetry surfaces are live — the metrics snapshot JSON-serializes
and carries nonzero key series, the Prometheus exposition round-trips
through the parser, and the Chrome-trace export contains the request
span taxonomy.  Writes the trace to ``benchmarks/results/obs_trace.json``
so CI can upload it as a Perfetto-loadable artifact.

Used by the CI ``test`` job; run locally with

    JAX_PLATFORMS=cpu PYTHONPATH=src python tools/obs_smoke.py
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.obs import Tracer, parse_exposition, set_tracer  # noqa: E402
from repro.serve.cell import ServingCell  # noqa: E402
from repro.serve.fleet import CellRouter  # noqa: E402

OUT = os.path.join(REPO, "benchmarks", "results", "obs_trace.json")
N_REQUESTS = 64
# the stage series every routed request must feed (router + per cell)
KEY_SERIES = ("latency_ms", "queue_ms", "batch_ms", "dispatch_ms")


def _fn(qs):
    b = qs.shape[0]
    return (np.zeros((b, 3), np.float32),
            np.tile(np.arange(3), (b, 1)).astype(np.int64))


def main() -> int:
    tracer = Tracer(capacity=8192)
    prev = set_tracer(tracer)
    cells = [ServingCell(_fn, name=f"cell{i}", max_wait_ms=0.5)
             for i in range(2)]
    router = CellRouter(cells)
    try:
        rng = np.random.default_rng(0)
        for _ in range(N_REQUESTS):
            router.search(rng.normal(size=(8,)).astype(np.float32),
                          timeout=10.0)
        st = router.stats()

        # 1. snapshot parses as JSON and the key series are nonzero
        snap = json.loads(json.dumps(router.metrics_snapshot()))
        for series in KEY_SERIES:
            keys = [k for k in snap if k.endswith(series)]
            total = sum(snap[k].get("count", 0) for k in keys)
            assert keys and total > 0, \
                f"key series {series!r} is missing or empty: {keys}"
        route = [k for k in snap if k.endswith("route_ms")]
        assert route and snap[route[0]]["count"] == N_REQUESTS

        # 2. exposition round-trips through the scrape-side parser
        back = parse_exposition(router.exposition())
        assert any(v.get("type") == "histogram" and v.get("count")
                   for v in back.values()), "no live histogram scraped"

        # 3. the trace export carries the request span taxonomy
        os.makedirs(os.path.dirname(OUT), exist_ok=True)
        tracer.export(OUT)
        doc = json.load(open(OUT, encoding="utf-8"))
        names = {e["name"] for e in doc["traceEvents"]}
        need = {"route", "admission", "queue", "batch", "dispatch"}
        assert need <= names, f"trace missing spans: {need - names}"
        assert st.n == N_REQUESTS and st.stages["queue"]["n"] > 0
    finally:
        set_tracer(prev)
        router.close()
    print(f"obs smoke OK: {N_REQUESTS} requests, "
          f"{len(tracer.events())} trace events -> {OUT}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
