#!/usr/bin/env python
"""Fail on broken intra-repo links in the repo's markdown docs.

Scans every tracked ``*.md`` (skipping caches and third-party dirs) for
``[text](target)`` links and verifies that each *relative* target —
after stripping any ``#anchor`` — resolves to an existing file or
directory relative to the markdown file.  External links (``http(s)``,
``mailto:``) and pure in-page anchors are ignored; anchors into other
files are checked for file existence only (heading slugs are not
validated).

Used by the CI ``docs`` job; run locally with

    python tools/check_doc_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SKIP_DIRS = {".git", ".pytest_cache", "__pycache__", ".claude",
             "node_modules", ".venv"}
# [text](target) — target up to the first unescaped ')' (no nesting in
# our docs); tolerate an optional "title" suffix
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")


def iter_md_files():
    for p in sorted(REPO.rglob("*.md")):
        if any(part in SKIP_DIRS for part in p.relative_to(REPO).parts):
            continue
        yield p


def check_file(md: Path) -> list:
    broken = []
    text = md.read_text(encoding="utf-8")
    # fenced code blocks routinely contain notation like [b0, b1) —
    # strip them before scanning for links
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("#"):          # in-page anchor
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            broken.append((md.relative_to(REPO), target))
    return broken


def main() -> int:
    broken = []
    n_files = 0
    for md in iter_md_files():
        n_files += 1
        broken.extend(check_file(md))
    if broken:
        print(f"{len(broken)} broken intra-repo link(s):")
        for src, target in broken:
            print(f"  {src}: ({target})")
        return 1
    print(f"doc links OK ({n_files} markdown files checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
