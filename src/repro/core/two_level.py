"""Two-level approximate search (paper §3.2, Fig. 2a).

Build: (1) choose partition features (entity embeddings by default, or any
low-dim metadata such as geolocation); (2) k-means them into ``n_clusters``
sub-datasets; (3) index the *top level* over the centroids
(brute | kd-tree | PQ) and search the *bottom level* inside the probed
buckets (brute | QLBT/tree | LSH).

TPU layout: buckets are padded to a fixed width so a probe is a dense
gather; the bottom-level brute scan is the `kernels/l2_topk` tile loop; the
top-level PQ scan is `kernels/pq_adc`.  Per-bucket trees are stored as one
concatenated *forest* (single SoA node table + per-bucket root ids) so the
beam descent stays a single batched kernel.

Mutation model (online index lifecycle): the index is long-lived under
shifting traffic, so it supports in-place updates with bounded staleness
instead of build-once:

  * ``add_entities``    — route new vectors to the nearest centroid with a
    free bucket slot (spill to next-nearest, grow the pad on overflow) and
    incrementally rebuild *only the dirty buckets'* trees (the forest is a
    list of per-bucket trees re-concatenated on refresh);
  * ``delete_entities`` — tombstones: the db row stays (ids are stable),
    the bucket slot is compacted for reuse, and forest leaves are masked in
    place so a deleted id can never be returned;
  * ``rebalance``       — a Lloyd step restricted to *drifted* buckets
    (member mean moved vs the stored centroid): recenters them, re-routes
    their members through the capped assignment, rebuilds the top-level
    centroid index and every dirty bucket's tree.

Staleness guarantees: deletes are immediately invisible on every search
path; adds are immediately visible on brute/LSH bottoms and visible on
tree bottoms after the (default, per-call) dirty-bucket refresh; centroid
drift only degrades *recall*, never correctness, until ``rebalance()``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tree as tree_mod
from repro.core.brute import batched_l2sq, l2_topk_exact, pairwise_l2sq
from repro.core.delta import DeltaLog, DeltaManifest
from repro.core.kmeans import kmeans_fit
from repro.core.lsh import LSHIndex, hamming_scores, lsh_build, pack_bits
from repro.core.pq import ProductQuantizer, adc_lut, adc_scores, pq_train
from repro.core.tree import FlatTree, build_qlbt, build_rp_tree, build_kd_tree

__all__ = ["TwoLevelConfig", "TwoLevelIndex", "build_two_level"]

TOP_ALGOS = ("brute", "kdtree", "pq")
BOTTOM_ALGOS = ("brute", "tree", "qlbt", "lsh")


@dataclasses.dataclass
class TwoLevelConfig:
    n_clusters: int = 1024
    top: str = "brute"            # brute | kdtree | pq
    bottom: str = "brute"         # brute | tree | qlbt | lsh
    pq_m: int = 8                 # top-level PQ subspaces
    lsh_bits: int = 64
    kmeans_iters: int = 10
    kmeans_minibatch: Optional[int] = 262144
    bucket_cap: Optional[int] = None   # pad width; default = max bucket
    tree_leaf: int = 8
    tree_candidates: int = 4
    qlbt_boost_depth: int = 3
    qlbt_lambda: float = 0.5
    seed: int = 0


@dataclasses.dataclass
class _Forest:
    """Per-bucket trees concatenated into one node table.

    ``trees`` keeps the per-bucket :class:`FlatTree` segments (leaf ids
    already global) so a mutation can rebuild one bucket's tree and
    re-concatenate without touching the other K-1 — the incremental path
    ``add_entities``/``rebalance`` take.
    """
    arrays: dict                  # device arrays (see FlatTree.device_arrays)
    roots: np.ndarray             # (K,) int32 root node per bucket
    max_depth: int
    nbytes: int
    trees: Optional[list] = None  # per-bucket FlatTrees (global leaf ids)


@dataclasses.dataclass
class TwoLevelIndex:
    config: TwoLevelConfig
    db: np.ndarray                      # (N, d) float32 original vectors
    centroids: np.ndarray               # (K, d)
    bucket_ids: np.ndarray              # (K, cap) int32, -1 padded
    bucket_counts: np.ndarray           # (K,)
    top_pq: Optional[ProductQuantizer] = None
    top_kd: Optional[FlatTree] = None
    bottom_lsh: Optional[LSHIndex] = None
    forest: Optional[_Forest] = None
    # ---- mutation state (online lifecycle; see module docstring) ----
    alive: Optional[np.ndarray] = None          # (N,) bool, False = tombstone
    entity_bucket: Optional[np.ndarray] = None  # (N,) int32, -1 = deleted
    dirty: Optional[np.ndarray] = None          # (K,) bool, membership changed
    p: Optional[np.ndarray] = None              # (N,) likelihood (qlbt)
    part_feats: Optional[np.ndarray] = None     # (N, pd) if built on features
    n_adds: int = 0                             # mutations since last rebalance
    n_deletes: int = 0
    # last fully-BUILT per-bucket trees: reboost always derives from these,
    # never from a previous reboost (chained incremental re-splits compound
    # float relocations until recall erodes).  None until the first reboost.
    base_trees: Optional[list] = None
    # ---- delta shipping (see repro.core.delta) ----
    mutation_version: int = 0                   # bumped per mutation batch
    delta_log: Optional[DeltaLog] = dataclasses.field(
        default=None, repr=False)
    # ---- per-entity metadata / lexical sidecars (docs/filtering.md) ----
    # row-aligned with db: appends grow them in lockstep, tombstones leave
    # them in place (stable ids), so FilterSpec masks and BM25 slabs can be
    # compiled against the same row numbering the scan returns
    metadata: Optional[object] = dataclasses.field(default=None, repr=False)
    lexical: Optional[object] = dataclasses.field(default=None, repr=False)

    # ---------------- construction helpers ----------------
    @property
    def n(self) -> int:
        return int(self.db.shape[0])

    @property
    def n_live(self) -> int:
        return self.n if self.alive is None else int(self.alive.sum())

    @property
    def k_clusters(self) -> int:
        return int(self.centroids.shape[0])

    @property
    def feats(self) -> np.ndarray:
        """Partition-feature view of the corpus (db itself by default)."""
        return self.db if self.part_feats is None else self.part_feats

    def _ensure_mutable(self):
        """Lazily create mutation state for indexes built before it."""
        if self.alive is None:
            self.alive = np.ones(self.n, dtype=bool)
        if self.dirty is None:
            self.dirty = np.zeros(self.k_clusters, dtype=bool)
        if self.entity_bucket is None:
            eb = np.full(self.n, -1, dtype=np.int32)
            rr, cc = np.nonzero(self.bucket_ids >= 0)
            eb[self.bucket_ids[rr, cc]] = rr
            self.entity_bucket = eb
        if self.delta_log is None:
            # created BEFORE the first mutation touches any state, so
            # base_n/base_version name the last published snapshot
            self.delta_log = DeltaLog(
                base_version=self.mutation_version, base_n=self.n)

    def pop_delta(self) -> DeltaManifest:
        """Emit (and reset) the record of everything mutated since the
        last pop — the input to
        ``ShardedSearchBackend.apply_updates(target, delta=...)``.

        The manifest is metadata only; payload bytes are sliced from the
        index's *current* state at apply time, which is what makes
        applying a stale-but-superset manifest safe (see
        :mod:`repro.core.delta`).  ``ServingEngine.apply_updates`` pops
        once per republish and feeds the same manifest to the primary and
        the hedge replica so both track the same version chain.
        """
        self._ensure_mutable()
        return self.delta_log.pop(self.mutation_version, self.n)

    def _place(self, feat_rows: np.ndarray, gids: np.ndarray) -> None:
        """Route rows into buckets: nearest centroid with a free slot,
        spill to next-nearest, grow the pad width on overflow.  Marks the
        receiving buckets dirty."""
        from repro.core.kmeans import _assign_topm

        top_b, _ = _assign_topm(feat_rows, self.centroids,
                                min(4, self.k_clusters))
        cap = self.bucket_ids.shape[1]
        counts = self.bucket_counts.astype(np.int64).copy()
        for j in range(gids.size):
            for b in top_b[j]:
                if counts[b] < cap:
                    break
            else:
                b = int(top_b[j, 0])
                if counts[b] >= cap:          # grow the pad width
                    grow = max(8, cap // 4)
                    self.bucket_ids = np.pad(
                        self.bucket_ids, ((0, 0), (0, grow)),
                        constant_values=-1)
                    cap += grow
            self.bucket_ids[b, counts[b]] = gids[j]
            counts[b] += 1
            self.entity_bucket[gids[j]] = b
            self.dirty[b] = True
            self.delta_log.mark_buckets(b)
        self.bucket_counts = counts.astype(np.int32)

    def add_entities(
        self,
        new_vecs: np.ndarray,
        *,
        partition_features: Optional[np.ndarray] = None,
        p: Optional[np.ndarray] = None,
        refresh: bool = True,
        metadata: Optional[dict] = None,
        docs: Optional[list] = None,
    ) -> np.ndarray:
        """Incremental insert for every bottom level.  Returns the new
        global entity ids (db rows are append-only; deleted rows are
        tombstones, so ids never shift).

        Routing reuses the build path's capped spill; freed (tombstoned)
        slots are reused before the pad grows.  Bottom-level upkeep:
        brute — none; lsh — append packed codes under the shared
        projections; tree/qlbt — rebuild the *dirty buckets'* trees only,
        then re-concatenate the forest.  The re-concat is O(forest size)
        even for one dirty bucket, so for a high-rate insert stream pass
        ``refresh=False`` and call ``refresh_forest()`` once per batch
        (or let the next ``rebalance()`` do it); until then new entities
        are invisible to the forest descent — bounded staleness, never
        wrong results.

        Centroids are NOT refit here — drift accumulates until
        ``rebalance()`` (the paper's offline-update model, made online).
        """
        self._ensure_mutable()
        new_vecs = np.ascontiguousarray(new_vecs, dtype=np.float32)
        if self.part_feats is not None:
            if partition_features is None:
                raise ValueError(
                    "index was built on side partition features; "
                    "add_entities needs partition_features for new rows")
            partition_features = np.ascontiguousarray(
                partition_features, np.float32)
            if partition_features.shape[0] != new_vecs.shape[0]:
                raise ValueError(
                    f"partition_features has {partition_features.shape[0]} "
                    f"rows for {new_vecs.shape[0]} new vectors")
        elif partition_features is not None:
            raise ValueError(
                "index was built on the embeddings themselves; "
                "partition_features would be silently ignored")
        m = new_vecs.shape[0]
        start = self.n
        ids = np.arange(start, start + m, dtype=np.int32)
        self.db = np.concatenate([self.db, new_vecs], axis=0)
        self.alive = np.concatenate([self.alive, np.ones(m, bool)])
        self.entity_bucket = np.concatenate(
            [self.entity_bucket, np.full(m, -1, np.int32)])
        if self.part_feats is not None:
            self.part_feats = np.concatenate(
                [self.part_feats, partition_features])
        if self.p is not None:
            if p is None:
                # no traffic estimate yet: assume average likelihood
                p = np.full(m, float(np.mean(self.p)), self.p.dtype)
            self.p = np.concatenate([self.p, np.asarray(p, self.p.dtype)])

        if self.metadata is not None:
            # rows not named in ``metadata`` get the column fill (0) —
            # appended before _place so a failed placement can't leave
            # the table short of the db
            self.metadata.append_rows(metadata or {}, m)
        elif metadata:
            raise ValueError(
                "index has no metadata table; build with metadata= to "
                "accept per-entity metadata on add_entities")
        if self.lexical is not None:
            self.lexical.append_docs(
                docs if docs is not None else [[] for _ in range(m)])
        elif docs is not None:
            raise ValueError(
                "index has no lexical slabs; build with lexical= to "
                "accept docs on add_entities")

        feat_rows = (new_vecs if self.part_feats is None
                     else self.part_feats[ids])
        self._place(feat_rows, ids)
        self.n_adds += m

        if self.bottom_lsh is not None:
            bits = (new_vecs @ self.bottom_lsh.proj > 0).astype(np.uint8)
            self.bottom_lsh.codes = np.concatenate(
                [self.bottom_lsh.codes, pack_bits(bits)], axis=0)
            self.delta_log.lsh_rows += m
        self.mutation_version += 1
        if self.forest is not None and refresh:
            self.refresh_forest()
        return ids

    def delete_entities(self, ids: np.ndarray) -> None:
        """Tombstone-delete: compact the bucket slot for reuse, mask any
        forest leaves holding the id, keep the db row (stable ids).  A
        deleted id is immediately invisible on every search path."""
        self._ensure_mutable()
        ids = np.unique(np.asarray(ids, dtype=np.int64))
        if ids.size == 0:
            return
        if ids.min() < 0 or ids.max() >= self.n:
            raise ValueError("delete_entities: id out of range")
        if not self.alive[ids].all():
            raise ValueError("delete_entities: id already deleted")
        for e in ids:
            b = int(self.entity_bucket[e])
            row = self.bucket_ids[b]
            col = int(np.nonzero(row == e)[0][0])
            last = int(self.bucket_counts[b]) - 1
            row[col] = row[last]              # swap-fill the hole
            row[last] = -1
            self.bucket_counts[b] = last
            self.dirty[b] = True
            self.delta_log.mark_buckets(b)
        self.alive[ids] = False
        self.entity_bucket[ids] = -1
        self.delta_log.mark_tombstones(ids)
        self.n_deletes += ids.size
        self.mutation_version += 1
        if self.forest is not None:
            # mask in the live device arrays AND the per-bucket segments so
            # a later partial refresh can't resurrect a deleted id
            le = np.asarray(self.forest.arrays["leaf_entities"]).copy()
            le[np.isin(le, ids) & (le >= 0)] = -1
            self.forest.arrays["leaf_entities"] = jnp.asarray(le)
            if self.forest.trees is not None:
                for t in self.forest.trees:
                    t.drop_entities(ids)
        if self.base_trees is not None:
            # the reboost base must drop the ids too, or the next reboost
            # would resurrect them
            for t in self.base_trees:
                t.drop_entities(ids)

    def refresh_forest(self) -> int:
        """Rebuild the trees of dirty buckets only and re-concatenate the
        forest (clears the dirty flags; no-op for non-tree bottoms).
        Returns #buckets rebuilt."""
        self._ensure_mutable()
        if self.forest is None:
            self.dirty[:] = False
            return 0
        if not self.dirty.any():
            return 0
        rebuilt = 0
        for b in np.nonzero(self.dirty)[0]:
            ids = self.bucket_ids[b][: self.bucket_counts[b]]
            ids = ids[ids >= 0]
            self.forest.trees[b] = _bucket_tree(
                self.db, ids.astype(np.int64), self.config, self.p, int(b))
            if self.base_trees is not None:
                self.base_trees[b] = self.forest.trees[b]
            self.delta_log.mark_buckets(b)
            rebuilt += 1
        self.mutation_version += 1
        self.dirty[:] = False
        # publish with a single reference swap (like reboost): a reader
        # snapshotting self.forest must never see new roots with old
        # arrays — the scheduler chains rebalance() on a background
        # thread while serving continues
        self.forest = _concat_forest(self.forest.trees)
        return rebuilt

    def rebalance(
        self,
        *,
        drift_threshold: float = 0.25,
        recenter: bool = True,
    ) -> dict:
        """Restore partition quality after accumulated mutations.

        A bucket has *drifted* when its live-member mean (in partition-
        feature space) moved more than ``drift_threshold`` of the bucket's
        own radius from the stored centroid.  For drifted buckets: move
        the centroid to the member mean (one Lloyd step, restricted), pull
        their members out and re-route them through the capped assignment
        against the updated centroids.  Then rebuild the top-level
        centroid index (PQ/kd) if centroids moved, rebuild every dirty
        bucket's tree, and clear the mutation counters.

        Returns a stats dict: ``n_drifted``, ``n_moved``,
        ``n_rebuilt_buckets``, ``max_drift``.
        """
        self._ensure_mutable()
        K = self.k_clusters
        feats = self.feats
        # live-member mean + radius per bucket
        drifted, max_drift = [], 0.0
        means = {}
        for b in range(K):
            ids = self.bucket_ids[b][: self.bucket_counts[b]]
            ids = ids[ids >= 0]
            if ids.size == 0:
                continue
            fb = feats[ids]
            mean = fb.mean(axis=0)
            radius = float(
                np.sqrt(((fb - self.centroids[b]) ** 2).sum(1).mean()))
            drift = float(np.linalg.norm(mean - self.centroids[b]))
            rel = drift / max(radius, 1e-12)
            max_drift = max(max_drift, rel)
            if rel > drift_threshold:
                drifted.append(b)
                means[b] = mean
        moved_ids = []
        if drifted and recenter:
            if not self.centroids.flags.writeable:   # np view of a jax array
                self.centroids = np.array(self.centroids, np.float32)
            for b in drifted:
                self.centroids[b] = means[b]
            # pull every member of a drifted bucket and re-route it
            for b in drifted:
                ids = self.bucket_ids[b][: self.bucket_counts[b]]
                ids = ids[ids >= 0]
                moved_ids.append(ids.astype(np.int64))
                self.bucket_ids[b, :] = -1
                self.bucket_counts[b] = 0
                self.entity_bucket[ids] = -1
                self.dirty[b] = True
                self.delta_log.mark_buckets(b)
            moved = np.concatenate(moved_ids) if moved_ids else \
                np.zeros(0, np.int64)
            if moved.size:
                self._place(feats[moved], moved)
            # centroids changed -> the top-level index over them is stale
            if self.top_pq is not None:
                self.top_pq = pq_train(
                    self.centroids, m=self.config.pq_m,
                    seed=self.config.seed, train_sample=None)
            if self.top_kd is not None:
                self.top_kd = build_kd_tree(self.centroids, leaf_size=4)
        n_rebuilt = self.refresh_forest()
        self.n_adds = 0
        self.n_deletes = 0
        self.mutation_version += 1
        return {
            "n_drifted": len(drifted),
            "n_moved": int(sum(x.size for x in moved_ids)),
            "n_rebuilt_buckets": n_rebuilt,
            "max_drift": max_drift,
        }

    def reboost(
        self,
        p: np.ndarray,
        *,
        frontier_depth: Optional[int] = None,
        max_move: float = 0.3,
    ) -> dict:
        """Incremental likelihood re-boost for the forest bottom.

        Stores ``p`` as the index's new traffic estimate and re-runs the
        boosted top-level splits of every per-bucket tree via
        :meth:`FlatTree.reboost` (subtrees below the frontier are reused).
        Pending dirty buckets are folded in first, so a drift-triggered
        reboost also completes any deferred ``add_entities`` refresh.  The
        rebuilt forest is assembled off to the side and swapped in with a
        single reference assignment — concurrent searches keep reading the
        old forest until the swap, never a half-built one (the same
        single-writer host mutation model as ``add/delete/rebalance``).

        No-op (beyond storing ``p``) for brute/LSH bottoms, whose search
        order does not depend on the likelihood.  Returns a stats dict:
        ``n_reboosted`` buckets re-split, ``n_refreshed`` dirty buckets
        rebuilt from scratch.
        """
        self._ensure_mutable()
        p = np.asarray(p, dtype=np.float64)
        if p.shape[0] != self.n:
            raise ValueError(
                f"p has {p.shape[0]} entries for {self.n} entities")
        self.p = p
        if self.forest is None or self.forest.trees is None:
            self.mutation_version += 1
            return {"n_reboosted": 0, "n_refreshed": 0}
        cfg = self.config
        p_eff = np.where(self.alive, p, 0.0)
        if self.base_trees is None:
            self.base_trees = list(self.forest.trees)
        n_ref = 0
        refreshed = set()
        for b in np.nonzero(self.dirty)[0]:
            ids = self.bucket_ids[b][: self.bucket_counts[b]]
            ids = ids[ids >= 0]
            self.base_trees[b] = _bucket_tree(
                self.db, ids.astype(np.int64), cfg, self.p, int(b))
            # self.dirty may predate the last pop_delta (deferred
            # refresh): the rebuilt tree must re-enter the CURRENT log
            # or the next delta ships a stale slab for this bucket
            self.delta_log.mark_buckets(b)
            refreshed.add(int(b))
            n_ref += 1
        n_re = 0
        trees = list(self.base_trees)
        for b, t in enumerate(trees):
            if t.n_nodes <= 1 or b in refreshed:
                # freshly rebuilt buckets were built with the new p — a
                # second top-level re-split would only relocate floats
                continue
            trees[b] = t.reboost(
                self.db, p_eff,
                boost_depth=cfg.qlbt_boost_depth,
                frontier_depth=frontier_depth,
                n_candidates=cfg.tree_candidates,
                lam=cfg.qlbt_lambda,
                max_move=max_move,
                seed=cfg.seed + b)
            self.delta_log.mark_buckets(b)
            n_re += 1
        self.forest = _concat_forest(trees)   # atomic swap for readers
        self.dirty[:] = False
        self.mutation_version += 1
        return {"n_reboosted": n_re, "n_refreshed": n_ref}

    def footprint_bytes(self, include_db: bool = True) -> int:
        tot = self.centroids.nbytes + self.bucket_ids.nbytes
        tot += self.bucket_counts.nbytes
        if include_db:
            tot += self.db.nbytes
        if self.top_pq is not None:
            tot += self.top_pq.footprint_bytes()
        if self.top_kd is not None:
            tot += self.top_kd.footprint_bytes()
        if self.bottom_lsh is not None:
            tot += self.bottom_lsh.footprint_bytes()
        if self.forest is not None:
            tot += self.forest.nbytes
        return tot

    # ---------------- search ----------------
    def search(
        self,
        queries: np.ndarray,
        k: int = 10,
        *,
        nprobe: int = 8,
        beam_width: int = 8,
        lsh_candidates: int = 128,
        query_chunk: int = 1024,
        query_partition_features: Optional[np.ndarray] = None,
    ) -> tuple[np.ndarray, np.ndarray, dict]:
        """Returns (dists (B,k), ids (B,k), work dict).

        ``query_partition_features`` must be supplied when the index was
        built on side features (e.g. geolocation) — the top level probes in
        partition-feature space, the bottom level in embedding space.
        """
        q = np.ascontiguousarray(queries, dtype=np.float32)
        qp = (
            q
            if query_partition_features is None
            else np.ascontiguousarray(query_partition_features, np.float32)
        )
        outs_d, outs_i = [], []
        work = {"top_scored": 0, "candidates": 0}
        for s in range(0, q.shape[0], query_chunk):
            qc = jnp.asarray(q[s : s + query_chunk])
            qpc = jnp.asarray(qp[s : s + query_chunk])
            d, i, w = self._search_chunk(
                qc, qpc, k, nprobe=nprobe, beam_width=beam_width,
                lsh_candidates=lsh_candidates,
            )
            outs_d.append(np.asarray(d))
            outs_i.append(np.asarray(i))
            for key in work:
                work[key] += int(w[key])
        return np.concatenate(outs_d), np.concatenate(outs_i), work

    def _search_chunk(self, q, qp, k, *, nprobe, beam_width, lsh_candidates):
        nprobe = min(nprobe, self.k_clusters)
        buckets, top_work = self._top_probe(qp, nprobe)      # (B, nprobe)
        B = q.shape[0]
        counts = jnp.asarray(self.bucket_counts)[buckets]
        work = {"top_scored": top_work * B,
                "candidates": int(np.asarray(counts).sum())}

        bottom = self.config.bottom
        db = jnp.asarray(self.db)
        bids = jnp.asarray(self.bucket_ids)
        if bottom == "brute":
            d, i = _probe_scan_brute(db, bids, buckets, q, k)
            return d, i, work
        if bottom == "lsh":
            cap = self.bucket_ids.shape[1]
            shortlist = min(lsh_candidates, nprobe * cap)
            cand = _probe_scan_lsh(
                jnp.asarray(self.bottom_lsh.codes),
                jnp.asarray(self.bottom_lsh.proj),
                bids, buckets, q, shortlist,
            )
            work["candidates"] = int(cand.shape[0] * cand.shape[1])
            d, i = _rerank(db, q, cand, k)
            return d, i, work
        # tree / qlbt forest
        cand = self._forest_candidates(q, buckets, beam_width)
        work["candidates"] = int((np.asarray(cand) >= 0).sum())
        d, i = _rerank(db, q, cand, k)
        return d, i, work

    def _top_probe(self, qp, nprobe):
        """Top-level search over centroids -> (bucket ids, work/query)."""
        c = jnp.asarray(self.centroids)
        top = self.config.top
        if top == "brute":
            d2 = pairwise_l2sq(qp, c)
            _, b = jax.lax.top_k(-d2, nprobe)
            return b, self.k_clusters
        if top == "pq":
            lut = adc_lut(qp, jnp.asarray(self.top_pq.codebooks))
            scores = adc_scores(lut, jnp.asarray(self.top_pq.codes))
            _, b = jax.lax.top_k(-scores, nprobe)
            return b, self.k_clusters  # ADC ops, cheaper per item
        if top == "kdtree":
            arrays = self.top_kd.device_arrays()
            res = tree_mod.tree_search(
                arrays, c, qp, kind="kd",
                beam_width=max(2 * nprobe, 8), k=nprobe,
                max_steps=self.top_kd.max_depth + 4,
            )
            return jnp.maximum(res.ids, 0), int(res.candidates.mean())
        raise ValueError(f"unknown top {top!r}")

    def _forest_candidates(self, q, buckets, beam_width):
        """Descend each probed bucket's tree; union of leaf candidates."""
        # snapshot the forest once: reboost() publishes a rebuilt forest by
        # swapping the reference, so a single read keeps roots/arrays/depth
        # mutually consistent even when a maintenance thread swaps mid-call
        forest = self.forest
        B, nprobe = buckets.shape
        roots = jnp.asarray(forest.roots)[buckets]           # (B, np)
        qq = jnp.repeat(q, nprobe, axis=0)                   # (B*np, d)
        rr = roots.reshape(-1)
        res = tree_mod.tree_search(
            forest.arrays, jnp.asarray(self.db), qq,
            kind="rp", beam_width=beam_width,
            k=beam_width * self.config.tree_leaf,
            max_steps=forest.max_depth + 4,
            rerank=False, roots=rr,
        )
        return res.ids.reshape(B, -1)


def _popcount32(x):
    x = x - ((x >> 1) & 0x55555555)
    x = (x & 0x33333333) + ((x >> 2) & 0x33333333)
    x = (x + (x >> 4)) & 0x0F0F0F0F
    return (x * 0x01010101) >> 24


def _pack_bits_jax(bits):
    B, nb = bits.shape
    pad = (-nb) % 32
    if pad:
        bits = jnp.pad(bits, ((0, 0), (0, pad)))
    b = bits.reshape(B, -1, 32).astype(jnp.uint32)
    w = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return (b * w).sum(axis=2, dtype=jnp.uint32).astype(jnp.int32)


@partial(jax.jit, static_argnames=("k",))
def _probe_scan_brute(db, bucket_ids, buckets, q, k):
    """Stream probed buckets with a running top-k merge (bounded memory).

    One probe step gathers a (B, cap, d) tile — the TPU layout this maps to
    is the `kernels/l2_topk` tile loop over the probed buckets.
    """
    B = q.shape[0]

    def step(carry, bs):                       # bs: (B,) bucket id per query
        best_d, best_i = carry
        cand = bucket_ids[bs]                  # (B, cap)
        vecs = db[jnp.maximum(cand, 0)]        # (B, cap, d)
        d2 = batched_l2sq(vecs, q)
        d2 = jnp.where(cand >= 0, d2, jnp.inf)
        cat_d = jnp.concatenate([best_d, d2], axis=1)
        cat_i = jnp.concatenate([best_i, cand], axis=1)
        neg, sel = jax.lax.top_k(-cat_d, k)
        return (-neg, jnp.take_along_axis(cat_i, sel, axis=1)), None

    best0 = (
        jnp.full((B, k), jnp.inf, jnp.float32),
        jnp.full((B, k), -1, jnp.int32),
    )
    (d, i), _ = jax.lax.scan(step, best0, jnp.moveaxis(buckets, 1, 0))
    i = jnp.where(jnp.isinf(d), -1, i)
    return d, i


@partial(jax.jit, static_argnames=("shortlist",))
def _probe_scan_lsh(codes, proj, bucket_ids, buckets, q, shortlist):
    """Stream probed buckets, keep a running Hamming top-``shortlist``."""
    B = q.shape[0]
    qcodes = _pack_bits_jax(q @ proj > 0)

    def step(carry, bs):
        best_h, best_i = carry
        cand = bucket_ids[bs]                  # (B, cap)
        ccodes = codes[jnp.maximum(cand, 0)]   # (B, cap, W)
        x = jnp.bitwise_xor(qcodes[:, None, :], ccodes)
        ham = _popcount32(x).sum(-1).astype(jnp.float32)
        ham = jnp.where(cand >= 0, ham, jnp.inf)
        cat_h = jnp.concatenate([best_h, ham], axis=1)
        cat_i = jnp.concatenate([best_i, cand], axis=1)
        neg, sel = jax.lax.top_k(-cat_h, shortlist)
        return (-neg, jnp.take_along_axis(cat_i, sel, axis=1)), None

    best0 = (
        jnp.full((B, shortlist), jnp.inf, jnp.float32),
        jnp.full((B, shortlist), -1, jnp.int32),
    )
    (_, cand), _ = jax.lax.scan(step, best0, jnp.moveaxis(buckets, 1, 0))
    return cand


@partial(jax.jit, static_argnames=("k",))
def _rerank(db, q, cand, k):
    vecs = db[jnp.maximum(cand, 0)]
    d2 = batched_l2sq(vecs, q)
    d2 = jnp.where(cand >= 0, d2, jnp.inf)
    # mask duplicate ids (the same entity can enter via two overlapping
    # probes): stable-sort the ids, flag every repeat of its left
    # neighbour, scatter the flags back, and penalize all but the first
    # occurrence so one entity holds at most one top-k slot.
    B = cand.shape[0]
    order = jnp.argsort(cand, axis=1)
    sorted_ids = jnp.take_along_axis(cand, order, axis=1)
    dup_sorted = jnp.concatenate(
        [jnp.zeros((B, 1), bool),
         (sorted_ids[:, 1:] == sorted_ids[:, :-1]) & (sorted_ids[:, 1:] >= 0)],
        axis=1,
    )
    dup = jnp.zeros(cand.shape, bool) \
        .at[jnp.arange(B, dtype=jnp.int32)[:, None], order].set(dup_sorted)
    d2 = jnp.where(dup, jnp.inf, d2)
    k = min(k, cand.shape[1])
    neg, sel = jax.lax.top_k(-d2, k)
    ids = jnp.take_along_axis(cand, sel, axis=1)
    ids = jnp.where(jnp.isinf(-neg), -1, ids)
    return -neg, ids


def build_two_level(
    db: np.ndarray,
    config: TwoLevelConfig,
    *,
    p: Optional[np.ndarray] = None,
    partition_features: Optional[np.ndarray] = None,
    metadata=None,
    lexical=None,
) -> TwoLevelIndex:
    """Paper §3.2 build: partition features -> k-means -> per-level indexes.

    ``metadata`` (a :class:`repro.core.metadata.MetadataTable`) and
    ``lexical`` (a :class:`repro.core.lexical.LexicalSlabs`) are optional
    row-aligned sidecars carried through mutations and sharded placement —
    the filter/hybrid surface (docs/filtering.md)."""
    if config.top not in TOP_ALGOS:
        raise ValueError(f"top must be one of {TOP_ALGOS}")
    if config.bottom not in BOTTOM_ALGOS:
        raise ValueError(f"bottom must be one of {BOTTOM_ALGOS}")
    db = np.ascontiguousarray(db, dtype=np.float32)
    n, d = db.shape
    feats = db if partition_features is None else np.ascontiguousarray(
        partition_features, dtype=np.float32
    )
    k = min(config.n_clusters, n)
    km = kmeans_fit(
        feats, k, iters=config.kmeans_iters, seed=config.seed,
        minibatch=config.kmeans_minibatch,
    )
    counts = np.bincount(km.assignments, minlength=k)
    if config.bucket_cap is not None:
        cap = config.bucket_cap
    else:
        # fixed pad width keeps probe tiles dense on TPU; spill overflow to
        # the next-nearest centroid instead of padding to the max bucket.
        cap = int(min(counts.max(), max(int(np.ceil(2.5 * n / k)), 32)))
    bucket_ids, counts = _capped_assign(feats, km.centroids, k, cap)

    entity_bucket = np.full(n, -1, dtype=np.int32)
    rr, cc = np.nonzero(bucket_ids >= 0)
    entity_bucket[bucket_ids[rr, cc]] = rr
    idx = TwoLevelIndex(
        config=config, db=db,
        centroids=km.centroids,
        bucket_ids=bucket_ids,
        bucket_counts=counts.astype(np.int32),
        alive=np.ones(n, dtype=bool),
        entity_bucket=entity_bucket,
        dirty=np.zeros(k, dtype=bool),
        p=None if p is None else np.asarray(p, np.float64),
        part_feats=None if partition_features is None else feats,
        metadata=metadata,
        lexical=lexical,
    )
    if metadata is not None and metadata.n_rows != n:
        raise ValueError(
            f"metadata table has {metadata.n_rows} rows for a {n}-row db")
    if lexical is not None and lexical.n_docs != n:
        raise ValueError(
            f"lexical slabs hold {lexical.n_docs} docs for a {n}-row db")

    if config.top == "pq":
        idx.top_pq = pq_train(km.centroids, m=config.pq_m, seed=config.seed,
                              train_sample=None)
    elif config.top == "kdtree":
        idx.top_kd = build_kd_tree(km.centroids, leaf_size=4)

    if config.bottom == "lsh":
        idx.bottom_lsh = lsh_build(db, n_bits=config.lsh_bits,
                                   seed=config.seed)
    elif config.bottom in ("tree", "qlbt"):
        idx.forest = _build_forest(db, bucket_ids, counts, config, p)
    return idx


def _capped_assign(
    feats: np.ndarray, centroids: np.ndarray, k: int, cap: int, m: int = 4
):
    """Capacity-capped bucket fill with spill to next-nearest centroid.

    Round r offers every unplaced entity a seat in its r-th nearest bucket;
    seats go to the closest applicants.  Entities unplaced after ``m``
    rounds land in the globally least-loaded bucket (rare at cap>=2x mean).
    Returns (bucket_ids (k, cap) int32 -1-padded, counts (k,) int32).
    """
    from repro.core.kmeans import _assign_topm

    n = feats.shape[0]
    top_b, top_d = _assign_topm(feats, centroids, min(m, k))
    bucket_of = np.full(n, -1, dtype=np.int64)
    fill = np.zeros(k, dtype=np.int64)
    unplaced = np.arange(n, dtype=np.int64)
    for r in range(top_b.shape[1]):
        if unplaced.size == 0:
            break
        b = top_b[unplaced, r].astype(np.int64)
        d = top_d[unplaced, r]
        order = np.lexsort((d, b))
        bs, ds, ids = b[order], d[order], unplaced[order]
        first = np.searchsorted(bs, bs, side="left")
        rank = np.arange(bs.size) - first
        seats = cap - fill[bs]
        ok = rank < seats
        placed_ids, placed_b = ids[ok], bs[ok]
        bucket_of[placed_ids] = placed_b
        fill += np.bincount(placed_b, minlength=k)
        unplaced = ids[~ok]
    if unplaced.size:
        for e in unplaced:                      # rare fallback
            b = int(np.argmin(fill))
            bucket_of[e] = b
            fill[b] += 1
    cap_eff = int(max(cap, fill.max()))
    bucket_ids = np.full((k, cap_eff), -1, dtype=np.int32)
    order = np.argsort(bucket_of, kind="stable")
    offsets = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(fill, out=offsets[1:])
    sorted_ids = np.arange(n, dtype=np.int32)[order]
    for b in range(k):
        ids = sorted_ids[offsets[b] : offsets[b + 1]]
        bucket_ids[b, : ids.size] = ids
    return bucket_ids, fill.astype(np.int32)


def _bucket_tree(db, ids, config: TwoLevelConfig, p, b: int) -> FlatTree:
    """Build one bucket's tree with leaf entity ids remapped to *global*
    ids — the unit the incremental refresh rebuilds."""
    ids = np.asarray(ids, dtype=np.int64)
    sub = db[ids] if ids.size else np.zeros((1, db.shape[1]), np.float32)
    if config.bottom == "qlbt" and p is not None and ids.size:
        t = build_qlbt(
            sub, p[ids], leaf_size=config.tree_leaf,
            n_candidates=config.tree_candidates,
            boost_depth=config.qlbt_boost_depth,
            lam=config.qlbt_lambda, seed=config.seed + b,
        )
    else:
        t = build_rp_tree(
            sub, leaf_size=config.tree_leaf,
            n_candidates=config.tree_candidates, seed=config.seed + b,
        )
    le = t.leaf_entities.copy()
    if ids.size:
        mask = le >= 0
        le[mask] = ids[le[mask]].astype(le.dtype)
    else:
        le[:] = -1
    return dataclasses.replace(t, leaf_entities=le)


def _build_forest(db, bucket_ids, counts, config: TwoLevelConfig, p):
    """Concatenate per-bucket trees into one node table (global entity ids)."""
    trees: list[FlatTree] = []
    for b in range(bucket_ids.shape[0]):
        ids = bucket_ids[b][: counts[b]]
        ids = ids[ids >= 0]
        trees.append(_bucket_tree(db, ids, config, p, b))
    return _concat_forest(trees)


def _concat_forest(trees: list) -> _Forest:
    """Concatenate per-bucket trees into one SoA node table.

    Leaf tables may have different widths after per-bucket rebuilds with a
    changed leaf size — they are right-padded to the widest.
    """
    roots = np.zeros(len(trees), dtype=np.int32)
    offset = 0
    for b, t in enumerate(trees):
        roots[b] = offset
        offset += t.n_nodes

    def cat(field, fill_shift=None):
        parts = []
        shift = 0
        for t in trees:
            v = getattr(t, field)
            if fill_shift is not None:
                v = v.copy()
                mask = v >= 0
                v[mask] += shift
            parts.append(v)
            shift += t.n_nodes
        return np.concatenate(parts, axis=0)

    # leaf_row indexes into the concatenated leaf table -> shift by leaves
    leaf_rows = []
    lshift = 0
    for t in trees:
        lr = t.leaf_row.copy()
        lr[lr >= 0] += lshift
        lshift += t.n_leaves
        leaf_rows.append(lr)

    leaf_w = max(t.leaf_entities.shape[1] for t in trees)
    leaf_parts = [
        np.pad(t.leaf_entities, ((0, 0), (0, leaf_w - t.leaf_entities.shape[1])),
               constant_values=-1)
        for t in trees
    ]
    arrays = dict(
        proj=jnp.asarray(cat("proj")),
        dims=jnp.asarray(cat("dims")),
        tau=jnp.asarray(cat("tau")),
        children=jnp.asarray(cat("children", fill_shift=True)),
        leaf_row=jnp.asarray(np.concatenate(leaf_rows)),
        leaf_entities=jnp.asarray(np.concatenate(leaf_parts, axis=0)),
    )
    nbytes = sum(
        int(np.asarray(v).nbytes) for v in arrays.values()
    )
    return _Forest(
        arrays=arrays, roots=roots,
        max_depth=max(t.max_depth for t in trees),
        nbytes=nbytes,
        trees=trees,
    )
