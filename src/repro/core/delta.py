"""Delta manifests: *what changed* in a mutable index since its last publish.

The online lifecycle (``add_entities`` / ``delete_entities`` /
``rebalance`` / ``reboost``) keeps an index servable under shifting
traffic, but republishing it to a serving backend used to ship the whole
corpus even when a maintenance pass touched a handful of buckets.  A
:class:`DeltaManifest` closes that gap: every mutation records which
buckets it dirtied (and which entities it tombstoned), and
``pop_delta()`` emits the accumulated record so
``ShardedSearchBackend.apply_updates(target, delta=...)`` can re-place
only the dirty slices (see ``repro/distributed/backend.py``).

Design rules the consumers rely on:

* **The manifest is metadata, not payload.**  It names dirty buckets /
  tombstones / appended row ranges; the bytes themselves are sliced from
  the *current* index state at apply time.  That makes applying a
  manifest idempotent — re-applying (or applying a superset of) already-
  published changes rewrites slices with their current content, never
  corrupts.
* **Versions are a single monotone counter per index.**  ``base_version``
  is the index's ``mutation_version`` when the previous manifest was
  popped; a backend that last placed at version ``v`` may apply any
  manifest with ``base_version <= v`` (superset-or-exact coverage) and
  must fall back to a full re-place otherwise — it missed a pop and the
  manifest under-covers its staleness.
* **Append-only rows.**  ``db`` rows never move or change in place
  (deletes are tombstones), so the changed-row set for a flat corpus is
  exactly ``[base_n, n)`` plus the validity flips named by
  ``tombstones``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["DeltaManifest", "DeltaLog", "merge_manifests"]

_EMPTY = np.zeros(0, dtype=np.int64)


def merge_manifests(manifests) -> "DeltaManifest":
    """Collapse a version-ordered run of manifests into one covering
    window — the revived-cell replay record.

    Because manifests are metadata (dirty names, not payload) and
    application is idempotent and superset-safe, the union of dirty
    buckets / tombstones over ``[first.base_version, last.version)``
    applied against the *current* index state replays every change the
    run described.  ``full`` is sticky: one inexpressible window makes
    the merged window inexpressible.
    """
    ms = sorted(manifests, key=lambda m: m.base_version)
    if not ms:
        raise ValueError("merge_manifests needs at least one manifest")
    return DeltaManifest(
        base_version=ms[0].base_version,
        version=ms[-1].version,
        base_n=ms[0].base_n,
        n=ms[-1].n,
        dirty_buckets=np.unique(np.concatenate(
            [np.asarray(m.dirty_buckets, np.int64) for m in ms])),
        tombstones=np.unique(np.concatenate(
            [np.asarray(m.tombstones, np.int64) for m in ms])),
        lsh_rows_appended=sum(m.lsh_rows_appended for m in ms),
        full=any(m.full for m in ms),
    )


@dataclasses.dataclass(frozen=True)
class DeltaManifest:
    """What changed in an index between two published versions.

    base_version : ``mutation_version`` the delta applies on top of
    version      : ``mutation_version`` after applying it
    base_n       : corpus rows at ``base_version`` (appends = [base_n, n))
    n            : corpus rows at ``version``
    dirty_buckets: sorted unique bucket ids whose membership, centroid,
                   vectors, or per-bucket tree changed
    tombstones   : entity ids deleted in the window (already absent from
                   ``bucket_ids``; named so flat/valid-mask consumers can
                   flip their liveness bits — single-tree deletes are
                   fully described by these plus the in-place leaf
                   masking they already performed)
    lsh_rows_appended : packed LSH code rows appended under the shared
                   projections (code tables are append-only between
                   rebuilds)
    full         : the window contained a change deltas cannot express
                   (e.g. a whole-tree rebuild) — consumers must re-place
    """

    base_version: int
    version: int
    base_n: int
    n: int
    dirty_buckets: np.ndarray = _EMPTY
    tombstones: np.ndarray = _EMPTY
    lsh_rows_appended: int = 0
    full: bool = False

    @property
    def empty(self) -> bool:
        """True when the window holds no change at all."""
        return (not self.full
                and self.dirty_buckets.size == 0
                and self.tombstones.size == 0
                and self.lsh_rows_appended == 0
                and self.n == self.base_n)

    def describe(self) -> str:
        if self.full:
            kind = "full"
        elif self.empty:
            kind = "empty"
        else:
            kind = "delta"
        return (f"{kind} v{self.base_version}->v{self.version}: "
                f"{self.dirty_buckets.size} dirty buckets, "
                f"{self.tombstones.size} tombstones, "
                f"rows {self.base_n}->{self.n}")


@dataclasses.dataclass
class DeltaLog:
    """Mutable accumulator behind ``pop_delta()``.

    One lives on each mutable index; mutations call the ``mark_*``
    helpers and ``pop`` snapshots + resets it.  Not thread-safe on its
    own — it inherits the host mutation model (single writer).
    """

    base_version: int
    base_n: int
    dirty: set = dataclasses.field(default_factory=set)
    tombstones: list = dataclasses.field(default_factory=list)
    lsh_rows: int = 0
    full: bool = False

    def mark_buckets(self, buckets) -> None:
        self.dirty.update(int(b) for b in np.atleast_1d(buckets))

    def mark_tombstones(self, ids) -> None:
        self.tombstones.extend(int(e) for e in np.atleast_1d(ids))

    def mark_full(self) -> None:
        self.full = True

    def pop(self, version: int, n: int) -> DeltaManifest:
        man = DeltaManifest(
            base_version=self.base_version,
            version=version,
            base_n=self.base_n,
            n=n,
            dirty_buckets=np.sort(
                np.fromiter(self.dirty, dtype=np.int64, count=len(self.dirty))
            ),
            tombstones=np.unique(
                np.fromiter(self.tombstones, dtype=np.int64,
                            count=len(self.tombstones))
            ),
            lsh_rows_appended=self.lsh_rows,
            full=self.full,
        )
        self.base_version = version
        self.base_n = n
        self.dirty = set()
        self.tombstones = []
        self.lsh_rows = 0
        self.full = False
        return man
