"""Mesh-sharded ANN search — the paper's algorithm at datacenter scale.

The two-level structure gains one more level: the mesh.  Buckets (and their
centroids) are sharded across every chip; queries are replicated; each chip
runs the paper's top+bottom search over its local shard; a tiny
``all_gather`` of per-chip top-k (k * 8 bytes per query) merges globally.
The collective term is therefore O(devices * B * k) bytes — independent of
corpus size, which is what makes the approach scale-out friendly
(EXPERIMENTS.md §Roofline, ann rows).

Functions here are built with ``shard_map`` so the communication pattern is
explicit and auditable in the lowered HLO (one all-gather per search).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.brute import pairwise_l2sq

__all__ = [
    "sharded_brute_search",
    "sharded_ivf_search",
    "make_sharded_brute_fn",
    "make_sharded_ivf_fn",
]


def _merge_gathered(gd, gi, k):
    """(S, B, k) per-shard results -> global (B, k)."""
    s, b, kk = gd.shape
    cat_d = jnp.moveaxis(gd, 0, 1).reshape(b, s * kk)
    cat_i = jnp.moveaxis(gi, 0, 1).reshape(b, s * kk)
    neg, sel = jax.lax.top_k(-cat_d, k)
    return -neg, jnp.take_along_axis(cat_i, sel, axis=1)


def make_sharded_brute_fn(mesh: Mesh, axes: tuple[str, ...], k: int,
                          shard_rows: int):
    """Exact distributed search: db row-sharded over ``axes``."""

    def local(db_shard, q):
        d2 = pairwise_l2sq(q, db_shard)                    # (B, rows)
        neg, ids = jax.lax.top_k(-d2, k)
        lin = jax.lax.axis_index(axes)                     # flattened index
        gids = (ids + lin * shard_rows).astype(jnp.int32)
        gd = jax.lax.all_gather(-neg, axes, tiled=False)   # (S, B, k)
        gi = jax.lax.all_gather(gids, axes, tiled=False)
        return _merge_gathered(gd, gi, k)

    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(axes, None), P(None, None)),
        out_specs=(P(None, None), P(None, None)),
        check_vma=False,   # outputs replicated by the final all-gather merge
    )


def sharded_brute_search(mesh, db, queries, k=10,
                         axes=("data", "model")):
    """Host entry: shards db over the mesh and runs the distributed scan."""
    n = db.shape[0]
    n_dev = 1
    for a in axes:
        n_dev *= mesh.shape[a]
    rows = -(-n // n_dev)
    dbp = jnp.pad(jnp.asarray(db), ((0, rows * n_dev - n), (0, 0)),
                  constant_values=jnp.inf)   # inf rows never win top-k
    fn = make_sharded_brute_fn(mesh, axes, k, rows)
    with mesh:
        dbs = jax.device_put(dbp, NamedSharding(mesh, P(axes, None)))
        qs = jax.device_put(jnp.asarray(queries),
                            NamedSharding(mesh, P(None, None)))
        d, i = fn(dbs, qs)
    d, i = jax.device_get((d, i))
    i = jnp.where(i < n, i, -1)
    return d, i


def make_sharded_ivf_fn(mesh: Mesh, axes: tuple[str, ...], k: int,
                        nprobe_local: int, buckets_per_shard: int):
    """Distributed two-level: centroids + padded buckets sharded over mesh.

    Each chip: (1) scores its local centroids, (2) probes its local
    ``nprobe_local`` best buckets, (3) contributes its local top-k to the
    global all-gather merge.  Global nprobe = nprobe_local * n_shards —
    probing is *wider* than single-chip at equal latency, a scale-out win
    the paper's single-device protocol cannot reach.
    """

    def local(cents, bucket_ids, bucket_vecs, q):
        # cents: (Kloc, d); bucket_ids: (Kloc, cap); bucket_vecs (Kloc, cap, d)
        d2c = pairwise_l2sq(q, cents)                      # (B, Kloc)
        _, probe = jax.lax.top_k(-d2c, nprobe_local)       # (B, np)

        def scan_probe(carry, j):
            best_d, best_i = carry
            bsel = probe[:, j]                             # (B,)
            ids = bucket_ids[bsel]                         # (B, cap)
            vecs = bucket_vecs[bsel]                       # (B, cap, d)
            d2 = (
                jnp.sum(vecs * vecs, -1)
                - 2.0 * jnp.einsum("bcd,bd->bc", vecs, q)
                + jnp.sum(q * q, -1, keepdims=True)
            )
            d2 = jnp.where(ids >= 0, d2, jnp.inf)
            cat_d = jnp.concatenate([best_d, d2], axis=1)
            cat_i = jnp.concatenate([best_i, ids], axis=1)
            neg, sel = jax.lax.top_k(-cat_d, k)
            return (-neg, jnp.take_along_axis(cat_i, sel, 1)), None

        B = q.shape[0]
        init = (jnp.full((B, k), jnp.inf, jnp.float32),
                jnp.full((B, k), -1, jnp.int32))
        (ld, li), _ = jax.lax.scan(scan_probe, init,
                                   jnp.arange(nprobe_local))
        gd = jax.lax.all_gather(ld, axes, tiled=False)
        gi = jax.lax.all_gather(li, axes, tiled=False)
        return _merge_gathered(gd, gi, k)

    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(axes, None), P(axes, None), P(axes, None, None),
                  P(None, None)),
        out_specs=(P(None, None), P(None, None)),
        check_vma=False,   # outputs replicated by the final all-gather merge
    )


def sharded_ivf_search(mesh, index, queries, k=10, nprobe_local=2,
                       axes=("data", "model")):
    """Host entry: shards a built TwoLevelIndex over the mesh.

    ``index.bucket_ids`` keeps *global* entity ids, so the merged result
    ids are directly comparable with the single-chip index.
    """
    n_dev = 1
    for a in axes:
        n_dev *= mesh.shape[a]
    K, cap = index.bucket_ids.shape
    Kp = -(-K // n_dev) * n_dev
    pad = Kp - K
    cents = jnp.pad(jnp.asarray(index.centroids), ((0, pad), (0, 0)),
                    constant_values=jnp.inf)
    bids = jnp.pad(jnp.asarray(index.bucket_ids), ((0, pad), (0, 0)),
                   constant_values=-1)
    dbj = jnp.asarray(index.db)
    bvecs = dbj[jnp.maximum(bids, 0)]
    bvecs = jnp.where((bids >= 0)[..., None], bvecs, 0.0)
    fn = make_sharded_ivf_fn(mesh, axes, k, nprobe_local, Kp // n_dev)
    with mesh:
        put = lambda x, spec: jax.device_put(x, NamedSharding(mesh, spec))
        d, i = fn(
            put(cents, P(axes, None)),
            put(bids, P(axes, None)),
            put(bvecs, P(axes, None, None)),
            put(jnp.asarray(queries, jnp.float32), P(None, None)),
        )
    return jax.device_get((d, i))
