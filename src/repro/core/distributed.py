"""Back-compat shim: the sharded search moved to ``repro.distributed``.

This module re-exports the distributed ANN entry points from their new
home next to ``ShardPlan`` in :mod:`repro.distributed.sharding`, where the
subsystem also gained a tree/QLBT forest bottom level, query-axis
batch sharding, and a serving backend (``repro.distributed.backend``).
Import from ``repro.distributed`` in new code.
"""
from repro.distributed.sharding import (  # noqa: F401
    make_sharded_brute_fn,
    make_sharded_forest_fn,
    make_sharded_ivf_fn,
    sharded_brute_search,
    sharded_forest_search,
    sharded_ivf_search,
)

__all__ = [
    "sharded_brute_search",
    "sharded_ivf_search",
    "sharded_forest_search",
    "make_sharded_brute_fn",
    "make_sharded_ivf_fn",
    "make_sharded_forest_fn",
]
