"""Product quantization (paper §2/§3.2 — top-level index over centroids).

Classic Jégou-style PQ: split d dims into M subspaces, k-means a 256-entry
codebook per subspace, encode vectors as M uint8 codes.  Query-time
asymmetric distance computation (ADC) builds a (M, 256) LUT of exact
subspace distances and scores a code as ``sum_m LUT[m, code[n, m]]``.

The hot loop (LUT gather-accumulate over millions of codes) is the
`kernels/pq_adc` Pallas kernel; `adc_scores` below is the jnp path used on
CPU and as the kernel oracle.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import kmeans_fit

__all__ = ["ProductQuantizer", "pq_train", "adc_lut", "adc_scores",
           "pq_search"]


@dataclasses.dataclass
class ProductQuantizer:
    codebooks: np.ndarray   # (M, 256, d_sub) float32
    codes: np.ndarray       # (N, M) uint8
    d: int

    @property
    def m(self) -> int:
        return int(self.codebooks.shape[0])

    @property
    def n(self) -> int:
        return int(self.codes.shape[0])

    def footprint_bytes(self) -> int:
        return self.codebooks.nbytes + self.codes.nbytes


def _subspaces(x: np.ndarray, m: int) -> np.ndarray:
    n, d = x.shape
    if d % m:
        x = np.pad(x, ((0, 0), (0, m - d % m)))
    return x.reshape(n, m, -1)


def pq_train(
    x: np.ndarray,
    m: int = 8,
    n_codes: int = 256,
    *,
    iters: int = 12,
    seed: int = 0,
    train_sample: int | None = 200_000,
) -> ProductQuantizer:
    """Train per-subspace codebooks and encode the full corpus."""
    x = np.ascontiguousarray(x, dtype=np.float32)
    n, d = x.shape
    subs = _subspaces(x, m)                               # (n, m, ds)
    rng = np.random.default_rng(seed)
    if train_sample is not None and train_sample < n:
        sel = rng.choice(n, size=train_sample, replace=False)
    else:
        sel = slice(None)
    books, codes = [], []
    for j in range(m):
        km = kmeans_fit(subs[sel, j], min(n_codes, n), iters=iters,
                        seed=seed + j)
        cb = km.centroids
        if cb.shape[0] < n_codes:                          # tiny corpora
            cb = np.concatenate(
                [cb, np.repeat(cb[-1:], n_codes - cb.shape[0], 0)], 0
            )
        books.append(cb)
        # encode everything against this codebook
        from repro.core.kmeans import kmeans_assign

        a, _ = kmeans_assign(subs[:, j], cb)
        codes.append(a.astype(np.uint8))
    return ProductQuantizer(
        codebooks=np.stack(books), codes=np.stack(codes, axis=1), d=d
    )


@jax.jit
def adc_lut(queries: jnp.ndarray, codebooks: jnp.ndarray) -> jnp.ndarray:
    """(B, M, 256) exact subspace distances query→codewords."""
    B = queries.shape[0]
    m, c, ds = codebooks.shape
    d = m * ds
    q = queries.astype(jnp.float32)
    if q.shape[1] != d:
        q = jnp.pad(q, ((0, 0), (0, d - q.shape[1])))
    qs = q.reshape(B, m, ds)
    diff = qs[:, :, None, :] - codebooks[None]            # (B, M, 256, ds)
    return jnp.sum(diff * diff, axis=-1)


@partial(jax.jit, static_argnames=("chunk",))
def adc_scores(
    lut: jnp.ndarray, codes: jnp.ndarray, chunk: int = 131072
) -> jnp.ndarray:
    """(B, N) approximate distances: sum_m LUT[b, m, codes[n, m]].

    jnp oracle for `kernels/pq_adc`.  Scans code chunks to bound memory.
    """
    B, m, _ = lut.shape
    n = codes.shape[0]
    chunk = min(chunk, n)
    n_chunks = -(-n // chunk)
    pad = n_chunks * chunk - n
    cp = jnp.pad(codes.astype(jnp.int32), ((0, pad), (0, 0)))

    def step(_, cs):                                      # cs: (chunk, m)
        # gather per subspace: lut (B, m, 256) indexed at cs.T (m, chunk)
        g = jnp.take_along_axis(
            lut, cs.T[None].astype(jnp.int32), axis=2
        )                                                 # (B, m, chunk)
        return None, g.sum(axis=1)                        # (B, chunk)

    _, out = jax.lax.scan(step, None,
                          cp.reshape(n_chunks, chunk, m))
    return jnp.moveaxis(out, 0, 1).reshape(B, n_chunks * chunk)[:, :n]


def pq_search(
    pq: ProductQuantizer, queries: np.ndarray, k: int
) -> tuple[np.ndarray, np.ndarray]:
    """ADC top-k over all codes (approximate dists, ids)."""
    lut = adc_lut(jnp.asarray(queries, dtype=jnp.float32),
                  jnp.asarray(pq.codebooks))
    scores = adc_scores(lut, jnp.asarray(pq.codes))
    neg, ids = jax.lax.top_k(-scores, min(k, pq.n))
    return np.asarray(-neg), np.asarray(ids, dtype=np.int32)
