"""Per-entity metadata columns and the filter predicate surface.

The paper's edge scenarios (contact / entity retrieval on-device) are
filtered-first in practice: a metadata hard-filter runs before semantic
ranking.  This module provides the two host-side pieces:

* :class:`MetadataTable` — fixed-dtype int32 columns, one row per
  entity, append-only alongside the corpus (rows never move; deletes
  are tombstones carried by the index ``alive`` mask, not by the
  table).  Column values are small ints / categorical codes; anything
  richer (strings, floats) is expected to be dictionary-encoded by the
  caller before it reaches the table.
* :class:`FilterSpec` — a frozen conjunction of equality / range /
  set-membership predicates over named columns, compiled by
  :meth:`FilterSpec.mask` to a per-row boolean mask.  The mask is
  *data*, never shape: the sharded backends AND it into the existing
  ``valid`` row operand (or mask ``bucket_ids`` slots to ``-1``), so a
  filtered query reuses the exact jit signature of an unfiltered one —
  the recompile gate (``repro.analysis`` ``filtered-sharded-search``
  entry) verifies this.

Staleness contract: backends snapshot the table at placement time and
compile filter masks from that snapshot, so a filter observes metadata
as of the last ``apply_updates`` — exactly the same staleness window as
the vectors themselves (see ``docs/filtering.md``).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional

import numpy as np

__all__ = ["MetadataTable", "FilterSpec"]


class MetadataTable:
    """Named int32 columns, one row per entity. Append-only."""

    def __init__(self, columns: "dict[str, np.ndarray]"):
        self._cols: "dict[str, np.ndarray]" = {}
        n = None
        for name, col in columns.items():
            a = np.ascontiguousarray(col, dtype=np.int32)
            if a.ndim != 1:
                raise ValueError(f"column {name!r} must be 1-D")
            if n is None:
                n = a.shape[0]
            elif a.shape[0] != n:
                raise ValueError(
                    f"column {name!r} has {a.shape[0]} rows, expected {n}")
            self._cols[name] = a
        self._n = 0 if n is None else int(n)

    # ---------------- read surface ----------------
    @property
    def n_rows(self) -> int:
        return self._n

    @property
    def column_names(self) -> "tuple[str, ...]":
        return tuple(self._cols)

    def column(self, name: str) -> np.ndarray:
        if name not in self._cols:
            raise KeyError(
                f"unknown metadata column {name!r}; "
                f"have {sorted(self._cols)}")
        return self._cols[name]

    def footprint_bytes(self) -> int:
        return sum(c.nbytes for c in self._cols.values())

    # ---------------- mutation surface ----------------
    def append_rows(self, rows: "Optional[dict[str, np.ndarray]]",
                    count: int, *, fill: int = 0) -> None:
        """Append ``count`` rows; missing columns get ``fill``.

        Called from ``add_entities`` with the same count as the vector
        append so the table and the corpus stay row-aligned.
        """
        rows = rows or {}
        unknown = set(rows) - set(self._cols)
        if unknown:
            raise KeyError(f"unknown metadata columns {sorted(unknown)}")
        for name, col in self._cols.items():
            if name in rows:
                a = np.ascontiguousarray(rows[name], dtype=np.int32)
                if a.shape != (count,):
                    raise ValueError(
                        f"column {name!r}: expected {count} new rows, "
                        f"got shape {a.shape}")
            else:
                a = np.full(count, fill, dtype=np.int32)
            self._cols[name] = np.concatenate([col, a])
        self._n += count

    def snapshot(self) -> "MetadataTable":
        """Deep copy — what a backend pins at placement time."""
        return MetadataTable(
            {k: v.copy() for k, v in self._cols.items()})

    def __repr__(self) -> str:
        return (f"MetadataTable(n_rows={self._n}, "
                f"columns={list(self._cols)})")


_OPS = ("eq", "range", "isin")


@dataclasses.dataclass(frozen=True)
class FilterSpec:
    """A conjunction of predicates over metadata columns.

    ``predicates`` is a tuple of tuples:

    * ``("eq", col, value)`` — ``col == value``
    * ``("range", col, lo, hi)`` — ``lo <= col <= hi`` (inclusive)
    * ``("isin", col, (v0, v1, ...))`` — membership

    Instances are hashable and order-sensitive; :meth:`key` gives a
    stable digest for cache keys (admission cache, backend mask cache).
    """

    predicates: "tuple[tuple, ...]" = ()

    # ---------------- constructors ----------------
    @staticmethod
    def eq(col: str, value: int) -> "FilterSpec":
        return FilterSpec((("eq", col, int(value)),))

    @staticmethod
    def range(col: str, lo: int, hi: int) -> "FilterSpec":
        return FilterSpec((("range", col, int(lo), int(hi)),))

    @staticmethod
    def isin(col: str, values) -> "FilterSpec":
        vals = tuple(sorted(int(v) for v in values))
        return FilterSpec((("isin", col, vals),))

    def __and__(self, other: "FilterSpec") -> "FilterSpec":
        return FilterSpec(self.predicates + other.predicates)

    def __post_init__(self):
        for p in self.predicates:
            if not p or p[0] not in _OPS:
                raise ValueError(f"bad predicate {p!r}")

    # ---------------- compilation ----------------
    def mask(self, table: "Optional[MetadataTable]", n: int) -> np.ndarray:
        """Row mask of length ``n`` (True = row passes every predicate).

        ``n`` may exceed ``table.n_rows`` (headroom rows in a placed
        backend); rows beyond the table are False — they hold no entity
        yet, so no predicate can admit them.
        """
        out = np.ones(n, dtype=bool)
        if not self.predicates:
            return out
        if table is None:
            raise ValueError(
                "FilterSpec with predicates needs a MetadataTable")
        m = min(n, table.n_rows)
        out[m:] = False
        for p in self.predicates:
            col = table.column(p[1])[:m]
            if p[0] == "eq":
                pm = col == p[2]
            elif p[0] == "range":
                pm = (col >= p[2]) & (col <= p[3])
            else:  # isin
                pm = np.isin(col, np.asarray(p[2], dtype=np.int32))
            out[:m] &= pm
        return out

    def key(self) -> bytes:
        """Stable 16-byte digest (mask caches, admission-cache keys)."""
        h = hashlib.blake2b(digest_size=16)
        for p in self.predicates:
            h.update(repr(p).encode())
        return h.digest()

    @property
    def empty(self) -> bool:
        return not self.predicates

    def describe(self) -> str:
        if not self.predicates:
            return "unfiltered"
        return " AND ".join(
            f"{p[1]}=={p[2]}" if p[0] == "eq"
            else f"{p[2]}<={p[1]}<={p[3]}" if p[0] == "range"
            else f"{p[1]} in {list(p[2])}"
            for p in self.predicates)
