"""Exact (brute-force) top-k search.

The paper's best-performing bottom level (§5.2): with ~100-entity buckets a
dense scan beats tree/LSH.  On TPU this is an MXU matmul + streaming top-k —
the `kernels/l2_topk` Pallas kernel implements the fused tile loop; this
module is the jnp implementation used as (a) the oracle, (b) the CPU path,
and (c) the chunked whole-corpus scan for ground-truth generation.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["l2_topk_exact", "brute_search", "pairwise_l2sq", "batched_l2sq"]


def pairwise_l2sq(q: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """(B, N) squared L2 via the matmul expansion (MXU-friendly)."""
    qn = jnp.sum(q * q, axis=-1, keepdims=True)         # (B, 1)
    xn = jnp.sum(x * x, axis=-1)                        # (N,)
    return qn + xn[None, :] - 2.0 * (q @ x.T)


def batched_l2sq(vecs: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """(B, C, d) candidates x (B, d) queries -> (B, C) squared L2.

    The per-query candidate-tile counterpart of ``pairwise_l2sq``; every
    rerank/probe scan shares this one expansion so the numerics cannot
    drift between the single-device and sharded paths."""
    return (
        jnp.sum(vecs * vecs, -1)
        - 2.0 * jnp.einsum("bcd,bd->bc", vecs, q)
        + jnp.sum(q * q, -1, keepdims=True)
    )


@partial(jax.jit, static_argnames=("k", "chunk"))
def l2_topk_exact(
    queries: jnp.ndarray, db: jnp.ndarray, k: int, chunk: int = 65536
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact top-k by streaming db chunks with a running merge.

    Returns (dists (B,k) ascending, ids (B,k)).  ``db`` rows beyond the
    chunk grid are handled by padding with +inf distance.
    """
    queries = queries.astype(jnp.float32)
    db = db.astype(jnp.float32)
    B = queries.shape[0]
    n = db.shape[0]
    chunk = min(chunk, n)
    n_chunks = -(-n // chunk)
    pad = n_chunks * chunk - n
    dbp = jnp.pad(db, ((0, pad), (0, 0)))

    def step(carry, i):
        best_d, best_i = carry
        start = i * chunk
        xs = jax.lax.dynamic_slice_in_dim(dbp, start, chunk, axis=0)
        d2 = pairwise_l2sq(queries, xs)                  # (B, chunk)
        ids = start + jnp.arange(chunk, dtype=jnp.int32)
        d2 = jnp.where(ids[None, :] < n, d2, jnp.inf)
        cat_d = jnp.concatenate([best_d, d2], axis=1)
        cat_i = jnp.concatenate(
            [best_i, jnp.broadcast_to(ids, (B, chunk))], axis=1
        )
        neg, sel = jax.lax.top_k(-cat_d, k)
        return (-neg, jnp.take_along_axis(cat_i, sel, axis=1)), None

    best0 = (
        jnp.full((B, k), jnp.inf, jnp.float32),
        jnp.full((B, k), -1, jnp.int32),
    )
    (d, i), _ = jax.lax.scan(step, best0,
                        jnp.arange(n_chunks, dtype=jnp.int32))
    return d, i


def brute_search(
    queries: np.ndarray, db: np.ndarray, k: int, chunk: int = 65536
) -> tuple[np.ndarray, np.ndarray]:
    """Host wrapper returning numpy (dists, ids)."""
    d, i = l2_topk_exact(jnp.asarray(queries), jnp.asarray(db), k,
                         min(chunk, db.shape[0]))
    return np.asarray(d), np.asarray(i)
