"""Flattened projection trees: balanced SPPT, QLBT (paper Alg. 1), kd-tree.

TPU adaptation (see DESIGN.md §2): the paper's pointer tree + best-first
backtracking becomes a structure-of-arrays node table traversed by a
*batched, level-synchronous beam descent* — thousands of queries walk the
tree in lockstep with gathers, the beam plays the role of multi-probe
backtracking (priority = accumulated split margin), and leaves are
pre-grouped (paper: 8 entities) so the final rerank is a dense scan that
maps onto the MXU (`kernels/l2_topk`).

Builders run host-side in numpy (index construction is offline in the paper
too); search is pure JAX (`jit` + `lax.while_loop`) with early exit when
every query's beam has bottomed out — this is what realizes QLBT's
shallower-depth latency win for head traffic.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.brute import batched_l2sq

__all__ = [
    "FlatTree",
    "build_rp_tree",
    "build_qlbt",
    "build_kd_tree",
    "tree_search",
    "TreeSearchResult",
]

_NEG_INF = np.float32(-np.inf)


@dataclasses.dataclass
class FlatTree:
    """Structure-of-arrays tree. Node 0 is the root.

    kind        : "rp" (dense random projections) or "kd" (coordinate splits)
    proj        : (n_nodes, d) float32 for "rp"; unused for "kd"
    dims        : (n_nodes,) int32 split coordinate for "kd"; unused for "rp"
    tau         : (n_nodes,) float32 split threshold
    children    : (n_nodes, 2) int32, -1 for leaves
    leaf_row    : (n_nodes,) int32 row into ``leaf_entities`` (-1 = internal)
    leaf_entities : (n_leaves, leaf_size) int32 entity ids, -1 padded
    depth       : (n_nodes,) int32 node depth (root = 0)
    entity_depth: (n_entities,) int32 leaf depth of each entity
    """

    kind: str
    proj: np.ndarray
    dims: np.ndarray
    tau: np.ndarray
    children: np.ndarray
    leaf_row: np.ndarray
    leaf_entities: np.ndarray
    depth: np.ndarray
    entity_depth: np.ndarray

    @property
    def n_nodes(self) -> int:
        return int(self.tau.shape[0])

    @property
    def n_leaves(self) -> int:
        return int(self.leaf_entities.shape[0])

    @property
    def leaf_size(self) -> int:
        return int(self.leaf_entities.shape[1])

    @property
    def max_depth(self) -> int:
        return int(self.depth.max()) if self.n_nodes else 0

    def expected_depth(self, p: np.ndarray) -> float:
        """E[Depth(X)] under query likelihood p — the paper's objective."""
        p = np.asarray(p, dtype=np.float64)
        return float((p / p.sum() * self.entity_depth).sum())

    def footprint_bytes(self) -> int:
        tot = 0
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, np.ndarray):
                tot += v.nbytes
        return tot

    def device_arrays(self) -> dict:
        """JAX-side arrays consumed by ``tree_search``."""
        return dict(
            proj=jnp.asarray(self.proj),
            dims=jnp.asarray(self.dims),
            tau=jnp.asarray(self.tau),
            children=jnp.asarray(self.children),
            leaf_row=jnp.asarray(self.leaf_row),
            leaf_entities=jnp.asarray(self.leaf_entities),
        )

    def reboost(
        self,
        emb: np.ndarray,
        p: np.ndarray,
        *,
        boost_depth: int = 3,
        frontier_depth: Optional[int] = None,
        n_candidates: int = 8,
        lam: float = 0.5,
        max_move: float = 0.3,
        seed: int = 0,
    ) -> "FlatTree":
        """Incremental QLBT re-boost: rebuild only the top levels for a new
        likelihood ``p``, reusing whole subtrees below.

        The subtrees rooted at ``frontier_depth`` (default
        ``boost_depth + 3``) become atomic *items*: each keeps its internal
        structure and is summarized by its live-entity mean embedding and
        its total likelihood mass.  The levels above the frontier are
        rebuilt over those items, scored by the greedy expected-depth
        objective (§3.1 one level at a time), so subtrees that got hot
        under the drifted traffic move shallower and cold ones sink —
        without re-running the entity-level build the frontier subtrees
        already paid for.  Candidates per rebuilt node are (a) fresh
        random projections with taus refined against the items' entity
        clouds (``_refine_tau``), (b) the original splits above the
        frontier that are empirically clean for the node's item set, and
        (c) the items' deepest common original ancestor as a guaranteed
        fallback.  A fresh hyperplane may straddle an item; the straddling
        entities are not misrouted but *floated*: removed from their home
        subtree's leaf and re-inserted, by their own split margins, into a
        leaf on the side they actually route to.  ``max_move`` caps the
        likelihood mass a single split may float.

        ``emb``/``p`` are indexed by the ids stored in ``leaf_entities``
        (global ids for forest bucket trees); tombstoned ids should carry
        zero mass.  Cost is O(n * n_candidates * d * log M) for M frontier
        items — only the ~log2(M) rebuilt top levels touch entities, vs
        every level of a full ``build_qlbt``, hence measurably cheaper.
        Returns a new tree; ``self`` is left untouched (callers swap the
        reference atomically so concurrent searches never see a
        half-built table).
        """
        if self.kind != "rp":
            raise ValueError("reboost supports projection trees only")
        if self.n_nodes <= 1:
            return dataclasses.replace(self)
        if frontier_depth is None:
            # aim for items of ~8 leaves: fine enough granularity that mass
            # balance can isolate hot regions, coarse enough that the bulk
            # of the structure is reused
            n_live = int((self.leaf_entities >= 0).sum())
            frontier_depth = max(
                boost_depth + 3,
                int(np.ceil(np.log2(max(n_live / (8 * self.leaf_size), 2)))))
        frontier_depth = max(1, frontier_depth)
        emb = np.ascontiguousarray(emb, dtype=np.float32)
        p = np.asarray(p, dtype=np.float64)
        d = emb.shape[1]

        # ---- 1. find frontier roots (depth == frontier or shallower leaf)
        # and the internal nodes above them (whose splits are recyclable)
        roots: list[int] = []
        tops: list[int] = []
        walk = [0]
        while walk:
            g = walk.pop()
            if self.children[g, 0] < 0 or self.depth[g] >= frontier_depth:
                roots.append(g)
            else:
                tops.append(g)
                walk.append(int(self.children[g, 0]))
                walk.append(int(self.children[g, 1]))
        if len(roots) <= 1:
            return dataclasses.replace(self)

        # ---- 2. summarize each frontier subtree: nodes, live entity ids,
        # mass, representative.  Entity masses get a uniform floor so cold
        # entities still count against misrouting thresholds.
        rng = np.random.default_rng(seed)
        sub_nodes: list[list[int]] = []
        sub_ids: list[np.ndarray] = []
        reps = np.zeros((len(roots), d), dtype=np.float32)
        mass = np.zeros(len(roots), dtype=np.float64)
        for j, f in enumerate(roots):
            nodes = []
            walk = [f]
            ent: list[np.ndarray] = []
            while walk:
                g = walk.pop()
                nodes.append(g)
                if self.children[g, 0] >= 0:
                    walk.append(int(self.children[g, 1]))
                    walk.append(int(self.children[g, 0]))
                else:
                    row = self.leaf_entities[self.leaf_row[g]]
                    ent.append(row[row >= 0])
            sub_nodes.append(nodes)
            ids = (np.concatenate(ent) if ent
                   else np.zeros(0, np.int64)).astype(np.int64)
            sub_ids.append(ids)
            if ids.size:
                reps[j] = emb[ids].mean(axis=0)
                mass[j] = float(p[ids].sum())
        if mass.sum() <= 0:
            mass = np.ones_like(mass)
        n_ent_total = int(sum(ids.size for ids in sub_ids))
        w_floor = 0.25 * mass.sum() / max(n_ent_total, 1)

        # root->frontier paths (incl. the frontier root itself): the deepest
        # common ancestor's original split is always a *clean* fallback
        # candidate — every item sits wholly on one side by construction
        parent = np.full(self.n_nodes, -1, dtype=np.int64)
        for g in range(self.n_nodes):
            for c in self.children[g]:
                if c >= 0:
                    parent[c] = g
        paths: list[np.ndarray] = []
        for f in roots:
            pth = [int(f)]
            g = int(f)
            while parent[g] >= 0:
                g = int(parent[g])
                pth.append(g)
            paths.append(np.asarray(pth[::-1], dtype=np.int64))

        # item side per recycled original split: -1 all-left, +1 all-right,
        # 0 straddling.  A split that leaves no item straddling routes every
        # entity of every item consistently — reusing those (in any order)
        # is what lets the rebuilt top adapt depths with zero misrouting.
        top_proj = self.proj[tops].astype(np.float32)      # (G, d)
        top_tau = self.tau[tops].astype(np.float32)        # (G,)
        M, G = len(roots), len(tops)
        item_side = np.zeros((M, G), dtype=np.int8)
        for j in range(M):
            if sub_ids[j].size == 0:
                a = reps[j] @ top_proj.T <= top_tau
                item_side[j] = np.where(a, -1, 1)
                continue
            le = (emb[sub_ids[j]] @ top_proj.T) <= top_tau[None, :]
            cnt = le.sum(axis=0)
            item_side[j] = np.where(
                cnt == sub_ids[j].size, -1, np.where(cnt == 0, 1, 0))

        # ---- 3. rebuild the top over items with likelihood-balanced splits.
        # Entities whose own projection disagrees with their item's side
        # become *floaters*: they leave their home subtree (slot blanked at
        # splice time) and descend by their own margins into a leaf on the
        # side they actually route to — so a fresh mass-balancing hyperplane
        # never misroutes a query, it just relocates the few straddlers.
        proj_rows, tau_vals, children, depths, leaf_rows = [], [], [], [], []
        leaf_tables: list[list[int]] = []     # variable width; padded at end

        def splice(item: int, home: np.ndarray, floats: np.ndarray,
                   at_depth: int, parent: int, side: int):
            """Copy item's subtree, blank floated-away ids, insert floaters."""
            base = len(tau_vals)
            if parent >= 0:
                children[parent][side] = base
            nodes = sub_nodes[item]
            local = {g: i for i, g in enumerate(nodes)}
            root_depth = int(self.depth[nodes[0]])
            row_of: dict[int, int] = {}
            for g in nodes:
                proj_rows.append(self.proj[g])
                tau_vals.append(float(self.tau[g]))
                c0, c1 = self.children[g]
                children.append([
                    -1 if c0 < 0 else base + local[int(c0)],
                    -1 if c1 < 0 else base + local[int(c1)],
                ])
                depths.append(at_depth + int(self.depth[g]) - root_depth)
                lr = int(self.leaf_row[g])
                if lr >= 0:
                    row_of[g] = len(leaf_tables)
                    leaf_rows.append(len(leaf_tables))
                    leaf_tables.append(self.leaf_entities[lr].tolist())
                else:
                    leaf_rows.append(-1)
            gone = np.setdiff1d(sub_ids[item], home)
            if gone.size:
                gs = set(gone.tolist())
                for g, ri in row_of.items():
                    row = leaf_tables[ri]
                    for t, x in enumerate(row):
                        if x in gs:
                            row[t] = -1
            if floats.size:
                # level-synchronous batched descent to each float's leaf
                cur = np.full(floats.size, nodes[0], dtype=np.int64)
                active = self.children[cur, 0] >= 0
                while active.any():
                    g = cur[active]
                    a = np.einsum("ed,ed->e", emb[floats[active]],
                                  self.proj[g]) - self.tau[g]
                    cur[active] = np.where(
                        a <= 0, self.children[g, 0], self.children[g, 1])
                    active = self.children[cur, 0] >= 0
                for e, g in zip(floats.tolist(), cur.tolist()):
                    row = leaf_tables[row_of[g]]
                    try:
                        row[row.index(-1)] = e
                    except ValueError:
                        row.append(e)

        empty = np.zeros(0, dtype=np.int64)
        all_home = np.concatenate(
            [ids for ids in sub_ids if ids.size]) if n_ent_total else empty
        ent_item = np.full(emb.shape[0], -1, dtype=np.int64)
        for j, ids in enumerate(sub_ids):
            ent_item[ids] = j
        pos_of = np.full(len(roots), -1, dtype=np.int64)

        stack = [(np.arange(len(roots), dtype=np.int64),
                  all_home, empty, 0, -1, 0)]
        while stack:
            items, home_ids, float_ids, depth, parent, side = stack.pop()
            if items.size == 1:
                splice(int(items[0]), home_ids, float_ids, depth, parent,
                       side)
                continue
            slot = len(tau_vals)
            if parent >= 0:
                children[parent][side] = slot
            r = reps[items]
            pos_of[items] = np.arange(items.size)
            seg = pos_of[ent_item[home_ids]]
            m_items = np.bincount(
                seg, weights=p[home_ids], minlength=items.size)
            if m_items.sum() <= 0:
                m_items = np.ones_like(m_items)
            ids_cat = home_ids
            w_ent = p[ids_cat] + w_floor
            E_sub = emb[ids_cat]
            # threshold refinement runs on a bounded subsample — the floats
            # at the *chosen* split are still computed over every entity
            refine_cap = 2048
            if ids_cat.size > refine_cap:
                sel = rng.choice(ids_cat.size, refine_cap, replace=False)
            else:
                sel = np.arange(ids_cat.size)
            w_ref, seg_ref = w_ent[sel], seg[sel]

            # candidate list: (proj, tau, left_mask, misroute, sigma2)
            cand: list[tuple] = []

            # (a) fresh likelihood-balanced projections (Alg.1 l.4-12 over
            # items), taus refined against entity clouds
            v = rng.normal(size=(n_candidates, d)).astype(np.float32)
            v /= np.linalg.norm(v, axis=1, keepdims=True) + 1e-12
            alphas = r @ v.T                      # (m, K) rep projections
            a_ent_ref = emb[ids_cat[sel]] @ v.T   # (E', K) sampled ent proj
            sigma2_f = alphas.var(axis=0)
            for i in range(n_candidates):
                tau_i, nl_i = _likelihood_tau(alphas[:, i], m_items)
                order = np.argsort(alphas[:, i], kind="stable")
                side_left = np.zeros(items.size, dtype=bool)
                side_left[order[:nl_i]] = True
                tau_i, mis_i = _refine_tau(
                    alphas[:, i], nl_i, tau_i,
                    a_ent_ref[:, i], w_ref, side_left[seg_ref])
                mask = alphas[:, i] <= tau_i
                if mask.all() or not mask.any():
                    mask = side_left
                cand.append((v[i], tau_i, mask, mis_i, float(sigma2_f[i])))

            # (b) recycled original splits that are clean for this item set
            # (no straddler, both sides present) — zero misroute candidates
            # that let mass balance reorder the hierarchy
            sides = item_side[items]              # (m, G)
            usable = ((sides != 0).all(axis=0)
                      & (sides == -1).any(axis=0)
                      & (sides == 1).any(axis=0))
            for g in np.nonzero(usable)[0]:
                mask = sides[:, g] == -1
                a_rep = r @ top_proj[g]
                cand.append((top_proj[g], float(top_tau[g]), mask,
                             0.0, float(a_rep.var())))

            if len(cand) == n_candidates:
                # no clean recycled split: fall back to the deepest common
                # original ancestor (paths are root-prefixes, so the LCA is
                # the last shared node; its children each hold >= 1 item)
                pth = np.stack([paths[int(j)][: min(
                    paths[int(jj)].size for jj in items)] for j in items])
                div = int(np.argmin((pth == pth[0]).all(axis=0)))
                lca = int(pth[0, div - 1])
                mask = pth[:, div] == self.children[lca, 0]
                lp = self.proj[lca].astype(np.float32)
                lt = float(self.tau[lca])
                left_ent = mask[seg_ref]
                a_lca = emb[ids_cat[sel]] @ lp
                mis = float(w_ref[np.where(
                    left_ent, a_lca > lt, a_lca <= lt)].sum()
                    / (float(w_ref.sum()) or 1.0))
                cand.append((lp, lt, mask, mis, float((r @ lp).var())))

            misroute = np.asarray([c[3] for c in cand])
            sigma2 = np.asarray([c[4] for c in cand])
            n_l = np.asarray([int(c[2].sum()) for c in cand], np.float64)
            m_l = np.asarray([float(m_items[c[2]].sum()) for c in cand])
            n_r = items.size - n_l
            # greedy expected-depth objective at item granularity (the §3.1
            # objective one level at a time, cf. _greedy_depth_tau): a side
            # with item count N needs ~log2 N more splits, weighted by the
            # likelihood mass routed there — what reboost exists to shrink
            m_tot = float(m_items.sum())
            p_l = m_l / m_tot
            cost = (p_l * np.log2(np.maximum(n_l, 1.0))
                    + (1.0 - p_l) * np.log2(np.maximum(n_r, 1.0)))
            c_hat = (cost - cost.min()) / (np.ptp(cost) + 1e-12)
            sig_hat = sigma2 / (sigma2.max() + 1e-12)
            # "misroute" is now a *movement* budget: straddlers are floated
            # to the side they route to instead of being lost, so candidates
            # within the budget compete on the depth objective
            eligible = misroute <= max(misroute.min() + 1e-12, max_move)
            score = lam * sig_hat + (1.0 - lam) * (1.0 - c_hat)
            score = np.where(eligible, score, -np.inf)
            best = int(np.argmax(score))
            proj_best, tau, left_mask = cand[best][0], cand[best][1], \
                cand[best][2]

            # split entities: home entities follow their item unless their
            # own projection disagrees — those float to their routed side
            a_home = E_sub @ proj_best <= tau     # True = routes left
            it_left = left_mask[seg]
            go_l = it_left & a_home
            go_r = ~it_left & ~a_home
            f_l = [home_ids[~it_left & a_home]]
            f_r = [home_ids[it_left & ~a_home]]
            if float_ids.size:
                a_f = emb[float_ids] @ proj_best <= tau
                f_l.append(float_ids[a_f])
                f_r.append(float_ids[~a_f])
            proj_rows.append(proj_best)
            tau_vals.append(float(tau))
            children.append([-1, -1])
            depths.append(depth)
            leaf_rows.append(-1)
            stack.append((items[left_mask], home_ids[go_l],
                          np.concatenate(f_l), depth + 1, slot, 0))
            stack.append((items[~left_mask], home_ids[go_r],
                          np.concatenate(f_r), depth + 1, slot, 1))

        # split overfull leaves (float insertions) into small median-split
        # subtrees so the leaf table width — and with it the rerank load —
        # stays bounded by the original leaf size
        for g in range(len(tau_vals)):
            ri = leaf_rows[g]
            if ri < 0:
                continue
            row = [x for x in leaf_tables[ri] if x >= 0]
            if len(row) <= self.leaf_size:
                continue
            ids = np.asarray(row, dtype=np.int64)
            sub = _build_projection_tree(
                emb[ids], None, leaf_size=self.leaf_size, n_candidates=4,
                boost_depth=-1, lam=1.0, seed=seed + g, boosted=False)

            def remap(c: int) -> int:
                return -1 if c < 0 else (g if c == 0 else base + c - 1)

            base = len(tau_vals)
            proj_rows[g] = sub.proj[0]
            tau_vals[g] = float(sub.tau[0])
            children[g] = [remap(int(sub.children[0, 0])),
                           remap(int(sub.children[0, 1]))]
            leaf_tables[ri] = []
            leaf_rows[g] = -1
            d0 = depths[g]
            for t in range(1, sub.n_nodes):
                proj_rows.append(sub.proj[t])
                tau_vals.append(float(sub.tau[t]))
                children.append([remap(int(sub.children[t, 0])),
                                 remap(int(sub.children[t, 1]))])
                depths.append(d0 + int(sub.depth[t]))
                lr = int(sub.leaf_row[t])
                if lr >= 0:
                    leaf_rows.append(len(leaf_tables))
                    leaf_tables.append(
                        [int(ids[x]) if x >= 0 else -1
                         for x in sub.leaf_entities[lr]])
                else:
                    leaf_rows.append(-1)

        # compact the leaf table: the overfull-split pass orphans replaced
        # rows, and downstream forest sharding requires every row in a
        # tree's segment to be referenced (dense [0, n_leaves) windows)
        packed: list[list[int]] = []
        for g, ri in enumerate(leaf_rows):
            if ri >= 0:
                leaf_rows[g] = len(packed)
                packed.append(leaf_tables[ri])
        leaf_tables = packed

        n_nodes = len(tau_vals)
        depth_arr = np.asarray(depths, dtype=np.int32)
        if leaf_tables:
            width = max(self.leaf_size,
                        max(len(row) for row in leaf_tables))
            leaf_ents = np.full((len(leaf_tables), width), -1, np.int32)
            for t, row in enumerate(leaf_tables):
                leaf_ents[t, : len(row)] = row
        else:
            leaf_ents = np.zeros((0, self.leaf_size), np.int32)
        leaf_row_arr = np.asarray(leaf_rows, dtype=np.int32)
        # entity_depth is only meaningful when leaf ids index it directly
        # (single trees); forest bucket trees keep their (unused, already
        # remapped-away) table — mirroring _bucket_tree.
        if self.entity_depth.shape[0] == emb.shape[0]:
            entity_depth = self.entity_depth.copy()
            for g in range(n_nodes):
                if leaf_row_arr[g] >= 0:
                    ids = leaf_ents[leaf_row_arr[g]]
                    entity_depth[ids[ids >= 0]] = depth_arr[g]
        else:
            entity_depth = self.entity_depth.copy()
        return FlatTree(
            kind="rp",
            proj=np.stack(proj_rows),
            dims=np.zeros(n_nodes, dtype=np.int32),
            tau=np.asarray(tau_vals, dtype=np.float32),
            children=np.asarray(children, dtype=np.int32),
            leaf_row=leaf_row_arr,
            leaf_entities=leaf_ents,
            depth=depth_arr,
            entity_depth=entity_depth,
        )

    def drop_entities(self, ids: np.ndarray) -> np.ndarray:
        """Tombstone-delete: blank the leaf slots holding ``ids`` in place.

        The split structure is untouched (it becomes stale, not wrong): a
        descent can still route through regions the dropped entities shaped,
        but the dropped ids can never be returned.  This is the cheap half
        of the mutation model — rebuild (``build_qlbt``/``build_rp_tree``)
        when enough mass has been dropped that depth quality matters.
        Returns the leaf-table rows that were masked.  The delta manifest
        does not record them — the tombstoned *entity ids* fully describe
        the change, and host-resident serving republishes by reference.
        """
        ids = np.asarray(ids)
        if ids.size == 0 or self.leaf_entities.size == 0:
            return np.zeros(0, dtype=np.int64)
        mask = np.isin(self.leaf_entities, ids) & (self.leaf_entities >= 0)
        self.leaf_entities[mask] = -1
        return np.unique(np.nonzero(mask)[0]).astype(np.int64)


# ---------------------------------------------------------------------------
# Builders (host-side numpy; vectorized per node)
# ---------------------------------------------------------------------------


def _likelihood_tau(alpha: np.ndarray, p: np.ndarray) -> tuple[float, int]:
    """tau* = argmin_tau |sum_{alpha<=tau} p - sum_{alpha>tau} p| (Alg.1 l.7).

    Returns (tau, n_left). Ties broken toward the more count-balanced split
    so degenerate all-on-one-side splits never occur.
    """
    order = np.argsort(alpha, kind="stable")
    a_sorted = alpha[order]
    prefix = np.cumsum(p[order])
    total = prefix[-1]
    # candidate split after position i (left = [0..i]); forbid empty sides
    m = alpha.size
    idx = np.arange(m - 1)
    gap = np.abs(2.0 * prefix[:-1] - total)
    best = int(np.argmin(gap))
    tau = float(0.5 * (a_sorted[best] + a_sorted[best + 1]))
    # guard: equal projections collapse a side; nudge split point
    n_left = int(np.searchsorted(a_sorted, tau, side="right"))
    if n_left == 0 or n_left == m:
        n_left = m // 2
        tau = float(0.5 * (a_sorted[n_left - 1] + a_sorted[n_left]))
    return tau, n_left


def _refine_tau(
    alpha: np.ndarray,
    n_left: int,
    tau: float,
    a_ent: np.ndarray,
    w_ent: np.ndarray,
    left_ent: np.ndarray,
) -> tuple[float, float]:
    """Slide ``tau`` inside the boundary gap to minimize misrouted mass.

    ``alpha`` (m,) are item-representative projections whose mass-balanced
    partition (lowest ``n_left`` by alpha go left) is already fixed;
    ``a_ent``/``w_ent``/``left_ent`` ((E,)) are the items' *entity*
    projections, likelihood masses, and assigned sides.  A threshold set
    between representatives can still cut through an item's entity cloud,
    silently misrouting the query-time descent toward the wrong subtree;
    we sweep every breakpoint of the wrong-side-mass step function that
    keeps the representative partition intact (tau strictly between the
    boundary reps) and return (tau, misrouted-mass fraction).  The
    fraction is exact, so the caller can reject candidates whose clouds
    straddle any admissible threshold.
    """
    order = np.argsort(alpha, kind="stable")
    lo = float(alpha[order[n_left - 1]])
    hi = float(alpha[order[n_left]])
    total = float(w_ent.sum())
    if total <= 0:
        return tau, 0.0
    o = np.argsort(a_ent, kind="stable")
    a_s, w_s, l_s = a_ent[o], w_ent[o], left_ent[o]
    # f[k] = wrong-side mass for tau in [a_s[k], a_s[k+1})
    f = w_s[l_s].sum() + np.cumsum(np.where(l_s, -w_s, w_s))
    mids = 0.5 * (a_s[:-1] + a_s[1:])
    ok = (mids > lo) & (mids < hi)
    if not ok.any():                       # boundary gap holds no entities
        t = 0.5 * (lo + hi)
        k = int(np.searchsorted(a_s, t, side="right")) - 1
        mis = float(f[k]) if k >= 0 else float(w_s[l_s].sum())
        return t, mis / total
    fk = f[:-1][ok]
    best = int(np.argmin(fk))
    return float(mids[ok][best]), float(fk[best] / total)


def _median_tau(alpha: np.ndarray) -> float:
    a_sorted = np.sort(alpha)
    m = alpha.size
    return float(0.5 * (a_sorted[(m - 1) // 2] + a_sorted[m // 2]))


def _greedy_depth_tau(
    alpha: np.ndarray, p: np.ndarray, leaf_size: int
) -> tuple[float, int, float]:
    """Beyond-paper split: directly minimize the greedy expected-depth bound

        cost(i) = P_L log2(max(N_L/leaf,1)) + P_R log2(max(N_R/leaf,1))

    over all split positions (the paper's §3.1 objective applied one level
    at a time, instead of the mass-balance proxy).  Returns
    (tau, n_left, -cost) — higher score is better.
    """
    order = np.argsort(alpha, kind="stable")
    a_sorted = alpha[order]
    prefix = np.cumsum(p[order])
    total = prefix[-1]
    m = alpha.size
    n_l = np.arange(1, m, dtype=np.float64)
    n_r = m - n_l
    p_l = prefix[:-1]
    p_r = total - p_l
    cost = p_l * np.log2(np.maximum(n_l / leaf_size, 1.0)) + \
        p_r * np.log2(np.maximum(n_r / leaf_size, 1.0))
    best = int(np.argmin(cost))
    tau = float(0.5 * (a_sorted[best] + a_sorted[best + 1]))
    n_left = int(np.searchsorted(a_sorted, tau, side="right"))
    if n_left == 0 or n_left == m:
        n_left = m // 2
        tau = float(0.5 * (a_sorted[n_left - 1] + a_sorted[n_left]))
    return tau, n_left, float(-cost[best])


def _build_projection_tree(
    emb: np.ndarray,
    p: Optional[np.ndarray],
    *,
    leaf_size: int,
    n_candidates: int,
    boost_depth: int,
    lam: float,
    seed: int,
    boosted: bool,
    objective: str = "massbalance",
) -> FlatTree:
    """Shared recursive builder for balanced SPPT and QLBT (Alg. 1)."""
    emb = np.ascontiguousarray(emb, dtype=np.float32)
    n, d = emb.shape
    if p is None:
        p = np.full(n, 1.0 / n, dtype=np.float64)
    else:
        p = np.asarray(p, dtype=np.float64)
        p = p / p.sum()
    rng = np.random.default_rng(seed)

    proj_rows, tau_vals, children, depths, leaf_rows = [], [], [], [], []
    leaf_tables: list[np.ndarray] = []
    entity_depth = np.zeros(n, dtype=np.int32)

    # stack of (entity_ids, depth, parent_slot, which_child)
    stack = [(np.arange(n, dtype=np.int64), 0, -1, 0)]
    while stack:
        ids, depth, parent, side = stack.pop()
        slot = len(tau_vals)
        if parent >= 0:
            children[parent][side] = slot
        m = ids.size
        if m <= leaf_size:
            proj_rows.append(np.zeros(d, dtype=np.float32))
            tau_vals.append(0.0)
            children.append([-1, -1])
            depths.append(depth)
            leaf_rows.append(len(leaf_tables))
            row = np.full(leaf_size, -1, dtype=np.int32)
            row[:m] = ids
            leaf_tables.append(row)
            entity_depth[ids] = depth
            continue

        sub = emb[ids]                      # (m, d)
        sub_p = p[ids]
        # Alg.1 l.4: K random unit projections
        v = rng.normal(size=(n_candidates, d)).astype(np.float32)
        v /= np.linalg.norm(v, axis=1, keepdims=True) + 1e-12
        alphas = sub @ v.T                  # (m, K)

        sigma2 = alphas.var(axis=0)         # Alg.1 l.10
        use_boost = boosted and depth <= boost_depth
        taus = np.empty(n_candidates, dtype=np.float64)
        n_lefts = np.empty(n_candidates, dtype=np.int64)
        if use_boost and objective == "greedy":
            # beyond-paper: direct greedy E[depth] minimization per split
            neg_cost = np.empty(n_candidates)
            for i in range(n_candidates):
                taus[i], n_lefts[i], neg_cost[i] = _greedy_depth_tau(
                    alphas[:, i], sub_p, leaf_size
                )
            sig_hat = sigma2 / (sigma2.max() + 1e-12)
            c_hat = neg_cost - neg_cost.min()
            c_hat = c_hat / (c_hat.max() + 1e-12)
            score = lam * sig_hat + (1.0 - lam) * c_hat
        elif use_boost:
            for i in range(n_candidates):
                taus[i], n_lefts[i] = _likelihood_tau(alphas[:, i], sub_p)
            n_rights = m - n_lefts
            b = np.maximum(n_lefts / n_rights, n_rights / n_lefts)  # Alg.1 l.9
            # scale-free normalization (DESIGN.md §1): sigma^2 -> [0,1],
            # b in [1, inf) -> 1 - 1/b in [0, 1)
            sig_hat = sigma2 / (sigma2.max() + 1e-12)
            b_hat = 1.0 - 1.0 / b
            score = lam * sig_hat + (1.0 - lam) * b_hat       # Alg.1 l.12
        else:
            for i in range(n_candidates):
                taus[i] = _median_tau(alphas[:, i])
                n_lefts[i] = int((alphas[:, i] <= taus[i]).sum())
            score = sigma2                                     # Alg.1 l.14

        best = int(np.argmax(score))                           # Alg.1 l.17
        alpha, tau = alphas[:, best], taus[best]
        left_mask = alpha <= tau
        if left_mask.all() or not left_mask.any():   # duplicate-point guard
            half = m // 2
            order = np.argsort(alpha, kind="stable")
            left_mask = np.zeros(m, dtype=bool)
            left_mask[order[:half]] = True

        proj_rows.append(v[best])
        tau_vals.append(float(tau))
        children.append([-1, -1])
        depths.append(depth)
        leaf_rows.append(-1)
        stack.append((ids[left_mask], depth + 1, slot, 0))
        stack.append((ids[~left_mask], depth + 1, slot, 1))

    n_nodes = len(tau_vals)
    return FlatTree(
        kind="rp",
        proj=np.stack(proj_rows),
        dims=np.zeros(n_nodes, dtype=np.int32),
        tau=np.asarray(tau_vals, dtype=np.float32),
        children=np.asarray(children, dtype=np.int32),
        leaf_row=np.asarray(leaf_rows, dtype=np.int32),
        leaf_entities=(
            np.stack(leaf_tables)
            if leaf_tables
            else np.zeros((0, leaf_size), np.int32)
        ),
        depth=np.asarray(depths, dtype=np.int32),
        entity_depth=entity_depth,
    )


def build_rp_tree(
    emb: np.ndarray,
    *,
    leaf_size: int = 8,
    n_candidates: int = 8,
    seed: int = 0,
) -> FlatTree:
    """Balanced randomized SPPT — the paper's baseline tree (SmallER)."""
    return _build_projection_tree(
        emb, None, leaf_size=leaf_size, n_candidates=n_candidates,
        boost_depth=-1, lam=1.0, seed=seed, boosted=False,
    )


def build_qlbt(
    emb: np.ndarray,
    p: np.ndarray,
    *,
    leaf_size: int = 8,
    n_candidates: int = 8,
    boost_depth: int = 3,
    lam: float = 0.5,
    seed: int = 0,
    objective: str = "massbalance",
) -> FlatTree:
    """Query Likelihood Boosted Tree — paper Algorithm 1.

    ``boost_depth`` is the paper's early-stop level l (=3): below it the
    builder reverts to balanced (count-median, variance-scored) splits.
    ``lam`` trades projection variance against count-unbalance (grid-searched
    in the paper).  ``objective``: "massbalance" = paper Alg. 1 (tau from
    equal-probability split, score from unbalance ratio); "greedy" =
    beyond-paper direct greedy minimization of E[depth] (DESIGN.md §2,
    recorded separately in EXPERIMENTS.md).
    """
    return _build_projection_tree(
        emb, p, leaf_size=leaf_size, n_candidates=n_candidates,
        boost_depth=boost_depth, lam=lam, seed=seed, boosted=True,
        objective=objective,
    )


def build_kd_tree(
    points: np.ndarray, *, leaf_size: int = 8
) -> FlatTree:
    """Array kd-tree for low-dim top-level features (paper §3.2, geo)."""
    points = np.ascontiguousarray(points, dtype=np.float32)
    n, d = points.shape
    dims_l, tau_vals, children, depths, leaf_rows = [], [], [], [], []
    leaf_tables: list[np.ndarray] = []
    entity_depth = np.zeros(n, dtype=np.int32)
    stack = [(np.arange(n, dtype=np.int64), 0, -1, 0)]
    while stack:
        ids, depth, parent, side = stack.pop()
        slot = len(tau_vals)
        if parent >= 0:
            children[parent][side] = slot
        m = ids.size
        if m <= leaf_size:
            dims_l.append(0)
            tau_vals.append(0.0)
            children.append([-1, -1])
            depths.append(depth)
            leaf_rows.append(len(leaf_tables))
            row = np.full(leaf_size, -1, dtype=np.int32)
            row[:m] = ids
            leaf_tables.append(row)
            entity_depth[ids] = depth
            continue
        sub = points[ids]
        dim = int(np.argmax(sub.max(0) - sub.min(0)))   # widest spread
        alpha = sub[:, dim]
        tau = _median_tau(alpha)
        left_mask = alpha <= tau
        if left_mask.all() or not left_mask.any():
            order = np.argsort(alpha, kind="stable")
            left_mask = np.zeros(m, dtype=bool)
            left_mask[order[: m // 2]] = True
        dims_l.append(dim)
        tau_vals.append(tau)
        children.append([-1, -1])
        depths.append(depth)
        leaf_rows.append(-1)
        stack.append((ids[left_mask], depth + 1, slot, 0))
        stack.append((ids[~left_mask], depth + 1, slot, 1))
    n_nodes = len(tau_vals)
    return FlatTree(
        kind="kd",
        proj=np.zeros((n_nodes, 1), dtype=np.float32),
        dims=np.asarray(dims_l, dtype=np.int32),
        tau=np.asarray(tau_vals, dtype=np.float32),
        children=np.asarray(children, dtype=np.int32),
        leaf_row=np.asarray(leaf_rows, dtype=np.int32),
        leaf_entities=(
            np.stack(leaf_tables)
            if leaf_tables
            else np.zeros((0, leaf_size), np.int32)
        ),
        depth=np.asarray(depths, dtype=np.int32),
        entity_depth=entity_depth,
    )


# ---------------------------------------------------------------------------
# Batched beam search (JAX)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TreeSearchResult:
    ids: jnp.ndarray          # (B, k) int32 entity ids (-1 pad)
    dists: jnp.ndarray        # (B, k) float32 squared L2
    steps: jnp.ndarray        # (B,) int32 descent iterations per query
    internal_visits: jnp.ndarray  # (B,) int32 internal-node dot products
    candidates: jnp.ndarray   # (B,) int32 exact distance evals (leaf scan)

    def tree_flatten(self):
        return (
            (self.ids, self.dists, self.steps, self.internal_visits,
             self.candidates),
            None,
        )

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


def _split_margin(kind: str, arrays: dict, nodes: jnp.ndarray, q: jnp.ndarray):
    """alpha = proj[node]·q - tau[node]   (or coordinate split for kd)."""
    if kind == "kd":
        dim = arrays["dims"][nodes]                      # (B, W)
        coord = jnp.take_along_axis(q, dim, axis=1)      # (B, W)
        return coord - arrays["tau"][nodes]
    pv = arrays["proj"][nodes]                           # (B, W, d)
    return jnp.einsum("bwd,bd->bw", pv, q) - arrays["tau"][nodes]


@partial(
    jax.jit,
    static_argnames=("kind", "beam_width", "k", "max_steps", "rerank"),
)
def tree_search(
    arrays: dict,
    db: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    kind: str = "rp",
    beam_width: int = 8,
    k: int = 10,
    max_steps: int = 64,
    rerank: bool = True,
    roots: jnp.ndarray | None = None,
) -> TreeSearchResult:
    """Batched multi-probe descent + exact rerank of gathered leaves.

    Beam priority = accumulated negative split margin along the path (the
    near child inherits the parent's priority; the far child pays |alpha|),
    the TPU-native analogue of SmallER's best-first backtracking queue.
    ``roots`` optionally gives a per-query start node (forest descent in the
    two-level index); default is node 0.
    """
    queries = queries.astype(jnp.float32)
    B, d = queries.shape
    W = beam_width
    children = arrays["children"]
    leaf_row = arrays["leaf_row"]
    leaf_entities = arrays["leaf_entities"]
    leaf_size = leaf_entities.shape[1]

    start = (
        jnp.zeros((B,), jnp.int32)
        if roots is None
        else roots.astype(jnp.int32)
    )
    nodes0 = jnp.full((B, W), -1, jnp.int32).at[:, 0].set(start)
    prios0 = jnp.full((B, W), _NEG_INF, jnp.float32).at[:, 0].set(0.0)
    steps0 = jnp.zeros((B,), jnp.int32)
    visits0 = jnp.zeros((B,), jnp.int32)

    def not_done(state):
        nodes, _, steps, _ = state
        valid = nodes >= 0
        is_leaf = jnp.where(valid, children[jnp.maximum(nodes, 0), 0] < 0, True)
        return jnp.logical_and(
            jnp.any(~jnp.all(is_leaf, axis=1)), steps.max() < max_steps
        )

    def body(state):
        nodes, prios, steps, visits = state
        safe = jnp.maximum(nodes, 0)
        valid = nodes >= 0
        is_leaf = children[safe, 0] < 0
        active = valid & ~is_leaf                         # internal, live
        alpha = _split_margin(kind, arrays, safe, queries)
        left = children[safe, 0]
        right = children[safe, 1]
        near = jnp.where(alpha <= 0, left, right)
        far = jnp.where(alpha <= 0, right, left)
        # slot A: internal -> near child (same prio); leaf -> itself
        a_nodes = jnp.where(active, near, nodes)
        a_prios = jnp.where(valid, prios, _NEG_INF)
        # slot B: internal -> far child (prio - |alpha|); leaf/pad -> dead
        b_nodes = jnp.where(active, far, -1)
        b_prios = jnp.where(active, prios - jnp.abs(alpha), _NEG_INF)
        cand_nodes = jnp.concatenate([a_nodes, b_nodes], axis=1)
        cand_prios = jnp.concatenate([a_prios, b_prios], axis=1)
        top_p, top_i = jax.lax.top_k(cand_prios, W)
        new_nodes = jnp.take_along_axis(cand_nodes, top_i, axis=1)
        new_nodes = jnp.where(top_p == _NEG_INF, -1, new_nodes)
        row_active = jnp.any(active, axis=1)
        return (
            new_nodes,
            top_p,
            steps + row_active.astype(jnp.int32),
            visits + active.sum(axis=1).astype(jnp.int32),
        )

    nodes, prios, steps, visits = jax.lax.while_loop(
        not_done, body, (nodes0, prios0, steps0, visits0)
    )

    # gather leaf entity ids
    safe = jnp.maximum(nodes, 0)
    rows = jnp.where(nodes >= 0, leaf_row[safe], -1)       # (B, W)
    ents = jnp.where(
        rows[..., None] >= 0,
        leaf_entities[jnp.maximum(rows, 0)],
        -1,
    )                                                      # (B, W, leaf)
    cand = ents.reshape(B, W * leaf_size)
    n_cand = (cand >= 0).sum(axis=1).astype(jnp.int32)

    if not rerank:
        return TreeSearchResult(cand, jnp.zeros_like(cand, jnp.float32),
                                steps, visits, n_cand)

    vecs = db[jnp.maximum(cand, 0)]                        # (B, C, d)
    diff2 = batched_l2sq(vecs, queries)
    diff2 = jnp.where(cand >= 0, diff2, jnp.inf)
    # dedupe identical ids from overlapping beams is unnecessary: leaves
    # partition entities, so ids are unique by construction.
    k_eff = min(k, cand.shape[1])
    neg, idx = jax.lax.top_k(-diff2, k_eff)
    ids = jnp.take_along_axis(cand, idx, axis=1)
    ids = jnp.where(jnp.isinf(-neg), -1, ids)
    if k_eff < k:
        pad = k - k_eff
        ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
        neg = jnp.pad(neg, ((0, 0), (0, pad)), constant_values=-jnp.inf)
    return TreeSearchResult(ids, -neg, steps, visits, n_cand)
