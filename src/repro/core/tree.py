"""Flattened projection trees: balanced SPPT, QLBT (paper Alg. 1), kd-tree.

TPU adaptation (see DESIGN.md §2): the paper's pointer tree + best-first
backtracking becomes a structure-of-arrays node table traversed by a
*batched, level-synchronous beam descent* — thousands of queries walk the
tree in lockstep with gathers, the beam plays the role of multi-probe
backtracking (priority = accumulated split margin), and leaves are
pre-grouped (paper: 8 entities) so the final rerank is a dense scan that
maps onto the MXU (`kernels/l2_topk`).

Builders run host-side in numpy (index construction is offline in the paper
too); search is pure JAX (`jit` + `lax.while_loop`) with early exit when
every query's beam has bottomed out — this is what realizes QLBT's
shallower-depth latency win for head traffic.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.brute import batched_l2sq

__all__ = [
    "FlatTree",
    "build_rp_tree",
    "build_qlbt",
    "build_kd_tree",
    "tree_search",
    "TreeSearchResult",
]

_NEG_INF = np.float32(-np.inf)


@dataclasses.dataclass
class FlatTree:
    """Structure-of-arrays tree. Node 0 is the root.

    kind        : "rp" (dense random projections) or "kd" (coordinate splits)
    proj        : (n_nodes, d) float32 for "rp"; unused for "kd"
    dims        : (n_nodes,) int32 split coordinate for "kd"; unused for "rp"
    tau         : (n_nodes,) float32 split threshold
    children    : (n_nodes, 2) int32, -1 for leaves
    leaf_row    : (n_nodes,) int32 row into ``leaf_entities`` (-1 = internal)
    leaf_entities : (n_leaves, leaf_size) int32 entity ids, -1 padded
    depth       : (n_nodes,) int32 node depth (root = 0)
    entity_depth: (n_entities,) int32 leaf depth of each entity
    """

    kind: str
    proj: np.ndarray
    dims: np.ndarray
    tau: np.ndarray
    children: np.ndarray
    leaf_row: np.ndarray
    leaf_entities: np.ndarray
    depth: np.ndarray
    entity_depth: np.ndarray

    @property
    def n_nodes(self) -> int:
        return int(self.tau.shape[0])

    @property
    def n_leaves(self) -> int:
        return int(self.leaf_entities.shape[0])

    @property
    def leaf_size(self) -> int:
        return int(self.leaf_entities.shape[1])

    @property
    def max_depth(self) -> int:
        return int(self.depth.max()) if self.n_nodes else 0

    def expected_depth(self, p: np.ndarray) -> float:
        """E[Depth(X)] under query likelihood p — the paper's objective."""
        p = np.asarray(p, dtype=np.float64)
        return float((p / p.sum() * self.entity_depth).sum())

    def footprint_bytes(self) -> int:
        tot = 0
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, np.ndarray):
                tot += v.nbytes
        return tot

    def device_arrays(self) -> dict:
        """JAX-side arrays consumed by ``tree_search``."""
        return dict(
            proj=jnp.asarray(self.proj),
            dims=jnp.asarray(self.dims),
            tau=jnp.asarray(self.tau),
            children=jnp.asarray(self.children),
            leaf_row=jnp.asarray(self.leaf_row),
            leaf_entities=jnp.asarray(self.leaf_entities),
        )

    def drop_entities(self, ids: np.ndarray) -> int:
        """Tombstone-delete: blank the leaf slots holding ``ids`` in place.

        The split structure is untouched (it becomes stale, not wrong): a
        descent can still route through regions the dropped entities shaped,
        but the dropped ids can never be returned.  This is the cheap half
        of the mutation model — rebuild (``build_qlbt``/``build_rp_tree``)
        when enough mass has been dropped that depth quality matters.
        Returns the number of slots blanked.
        """
        ids = np.asarray(ids)
        if ids.size == 0 or self.leaf_entities.size == 0:
            return 0
        mask = np.isin(self.leaf_entities, ids) & (self.leaf_entities >= 0)
        self.leaf_entities[mask] = -1
        return int(mask.sum())


# ---------------------------------------------------------------------------
# Builders (host-side numpy; vectorized per node)
# ---------------------------------------------------------------------------


def _likelihood_tau(alpha: np.ndarray, p: np.ndarray) -> tuple[float, int]:
    """tau* = argmin_tau |sum_{alpha<=tau} p - sum_{alpha>tau} p| (Alg.1 l.7).

    Returns (tau, n_left). Ties broken toward the more count-balanced split
    so degenerate all-on-one-side splits never occur.
    """
    order = np.argsort(alpha, kind="stable")
    a_sorted = alpha[order]
    prefix = np.cumsum(p[order])
    total = prefix[-1]
    # candidate split after position i (left = [0..i]); forbid empty sides
    m = alpha.size
    idx = np.arange(m - 1)
    gap = np.abs(2.0 * prefix[:-1] - total)
    best = int(np.argmin(gap))
    tau = float(0.5 * (a_sorted[best] + a_sorted[best + 1]))
    # guard: equal projections collapse a side; nudge split point
    n_left = int(np.searchsorted(a_sorted, tau, side="right"))
    if n_left == 0 or n_left == m:
        n_left = m // 2
        tau = float(0.5 * (a_sorted[n_left - 1] + a_sorted[n_left]))
    return tau, n_left


def _median_tau(alpha: np.ndarray) -> float:
    a_sorted = np.sort(alpha)
    m = alpha.size
    return float(0.5 * (a_sorted[(m - 1) // 2] + a_sorted[m // 2]))


def _greedy_depth_tau(
    alpha: np.ndarray, p: np.ndarray, leaf_size: int
) -> tuple[float, int, float]:
    """Beyond-paper split: directly minimize the greedy expected-depth bound

        cost(i) = P_L log2(max(N_L/leaf,1)) + P_R log2(max(N_R/leaf,1))

    over all split positions (the paper's §3.1 objective applied one level
    at a time, instead of the mass-balance proxy).  Returns
    (tau, n_left, -cost) — higher score is better.
    """
    order = np.argsort(alpha, kind="stable")
    a_sorted = alpha[order]
    prefix = np.cumsum(p[order])
    total = prefix[-1]
    m = alpha.size
    n_l = np.arange(1, m, dtype=np.float64)
    n_r = m - n_l
    p_l = prefix[:-1]
    p_r = total - p_l
    cost = p_l * np.log2(np.maximum(n_l / leaf_size, 1.0)) + \
        p_r * np.log2(np.maximum(n_r / leaf_size, 1.0))
    best = int(np.argmin(cost))
    tau = float(0.5 * (a_sorted[best] + a_sorted[best + 1]))
    n_left = int(np.searchsorted(a_sorted, tau, side="right"))
    if n_left == 0 or n_left == m:
        n_left = m // 2
        tau = float(0.5 * (a_sorted[n_left - 1] + a_sorted[n_left]))
    return tau, n_left, float(-cost[best])


def _build_projection_tree(
    emb: np.ndarray,
    p: Optional[np.ndarray],
    *,
    leaf_size: int,
    n_candidates: int,
    boost_depth: int,
    lam: float,
    seed: int,
    boosted: bool,
    objective: str = "massbalance",
) -> FlatTree:
    """Shared recursive builder for balanced SPPT and QLBT (Alg. 1)."""
    emb = np.ascontiguousarray(emb, dtype=np.float32)
    n, d = emb.shape
    if p is None:
        p = np.full(n, 1.0 / n, dtype=np.float64)
    else:
        p = np.asarray(p, dtype=np.float64)
        p = p / p.sum()
    rng = np.random.default_rng(seed)

    proj_rows, tau_vals, children, depths, leaf_rows = [], [], [], [], []
    leaf_tables: list[np.ndarray] = []
    entity_depth = np.zeros(n, dtype=np.int32)

    # stack of (entity_ids, depth, parent_slot, which_child)
    stack = [(np.arange(n, dtype=np.int64), 0, -1, 0)]
    while stack:
        ids, depth, parent, side = stack.pop()
        slot = len(tau_vals)
        if parent >= 0:
            children[parent][side] = slot
        m = ids.size
        if m <= leaf_size:
            proj_rows.append(np.zeros(d, dtype=np.float32))
            tau_vals.append(0.0)
            children.append([-1, -1])
            depths.append(depth)
            leaf_rows.append(len(leaf_tables))
            row = np.full(leaf_size, -1, dtype=np.int32)
            row[:m] = ids
            leaf_tables.append(row)
            entity_depth[ids] = depth
            continue

        sub = emb[ids]                      # (m, d)
        sub_p = p[ids]
        # Alg.1 l.4: K random unit projections
        v = rng.normal(size=(n_candidates, d)).astype(np.float32)
        v /= np.linalg.norm(v, axis=1, keepdims=True) + 1e-12
        alphas = sub @ v.T                  # (m, K)

        sigma2 = alphas.var(axis=0)         # Alg.1 l.10
        use_boost = boosted and depth <= boost_depth
        taus = np.empty(n_candidates, dtype=np.float64)
        n_lefts = np.empty(n_candidates, dtype=np.int64)
        if use_boost and objective == "greedy":
            # beyond-paper: direct greedy E[depth] minimization per split
            neg_cost = np.empty(n_candidates)
            for i in range(n_candidates):
                taus[i], n_lefts[i], neg_cost[i] = _greedy_depth_tau(
                    alphas[:, i], sub_p, leaf_size
                )
            sig_hat = sigma2 / (sigma2.max() + 1e-12)
            c_hat = neg_cost - neg_cost.min()
            c_hat = c_hat / (c_hat.max() + 1e-12)
            score = lam * sig_hat + (1.0 - lam) * c_hat
        elif use_boost:
            for i in range(n_candidates):
                taus[i], n_lefts[i] = _likelihood_tau(alphas[:, i], sub_p)
            n_rights = m - n_lefts
            b = np.maximum(n_lefts / n_rights, n_rights / n_lefts)  # Alg.1 l.9
            # scale-free normalization (DESIGN.md §1): sigma^2 -> [0,1],
            # b in [1, inf) -> 1 - 1/b in [0, 1)
            sig_hat = sigma2 / (sigma2.max() + 1e-12)
            b_hat = 1.0 - 1.0 / b
            score = lam * sig_hat + (1.0 - lam) * b_hat       # Alg.1 l.12
        else:
            for i in range(n_candidates):
                taus[i] = _median_tau(alphas[:, i])
                n_lefts[i] = int((alphas[:, i] <= taus[i]).sum())
            score = sigma2                                     # Alg.1 l.14

        best = int(np.argmax(score))                           # Alg.1 l.17
        alpha, tau = alphas[:, best], taus[best]
        left_mask = alpha <= tau
        if left_mask.all() or not left_mask.any():   # duplicate-point guard
            half = m // 2
            order = np.argsort(alpha, kind="stable")
            left_mask = np.zeros(m, dtype=bool)
            left_mask[order[:half]] = True

        proj_rows.append(v[best])
        tau_vals.append(float(tau))
        children.append([-1, -1])
        depths.append(depth)
        leaf_rows.append(-1)
        stack.append((ids[left_mask], depth + 1, slot, 0))
        stack.append((ids[~left_mask], depth + 1, slot, 1))

    n_nodes = len(tau_vals)
    return FlatTree(
        kind="rp",
        proj=np.stack(proj_rows),
        dims=np.zeros(n_nodes, dtype=np.int32),
        tau=np.asarray(tau_vals, dtype=np.float32),
        children=np.asarray(children, dtype=np.int32),
        leaf_row=np.asarray(leaf_rows, dtype=np.int32),
        leaf_entities=(
            np.stack(leaf_tables)
            if leaf_tables
            else np.zeros((0, leaf_size), np.int32)
        ),
        depth=np.asarray(depths, dtype=np.int32),
        entity_depth=entity_depth,
    )


def build_rp_tree(
    emb: np.ndarray,
    *,
    leaf_size: int = 8,
    n_candidates: int = 8,
    seed: int = 0,
) -> FlatTree:
    """Balanced randomized SPPT — the paper's baseline tree (SmallER)."""
    return _build_projection_tree(
        emb, None, leaf_size=leaf_size, n_candidates=n_candidates,
        boost_depth=-1, lam=1.0, seed=seed, boosted=False,
    )


def build_qlbt(
    emb: np.ndarray,
    p: np.ndarray,
    *,
    leaf_size: int = 8,
    n_candidates: int = 8,
    boost_depth: int = 3,
    lam: float = 0.5,
    seed: int = 0,
    objective: str = "massbalance",
) -> FlatTree:
    """Query Likelihood Boosted Tree — paper Algorithm 1.

    ``boost_depth`` is the paper's early-stop level l (=3): below it the
    builder reverts to balanced (count-median, variance-scored) splits.
    ``lam`` trades projection variance against count-unbalance (grid-searched
    in the paper).  ``objective``: "massbalance" = paper Alg. 1 (tau from
    equal-probability split, score from unbalance ratio); "greedy" =
    beyond-paper direct greedy minimization of E[depth] (DESIGN.md §2,
    recorded separately in EXPERIMENTS.md).
    """
    return _build_projection_tree(
        emb, p, leaf_size=leaf_size, n_candidates=n_candidates,
        boost_depth=boost_depth, lam=lam, seed=seed, boosted=True,
        objective=objective,
    )


def build_kd_tree(
    points: np.ndarray, *, leaf_size: int = 8
) -> FlatTree:
    """Array kd-tree for low-dim top-level features (paper §3.2, geo)."""
    points = np.ascontiguousarray(points, dtype=np.float32)
    n, d = points.shape
    dims_l, tau_vals, children, depths, leaf_rows = [], [], [], [], []
    leaf_tables: list[np.ndarray] = []
    entity_depth = np.zeros(n, dtype=np.int32)
    stack = [(np.arange(n, dtype=np.int64), 0, -1, 0)]
    while stack:
        ids, depth, parent, side = stack.pop()
        slot = len(tau_vals)
        if parent >= 0:
            children[parent][side] = slot
        m = ids.size
        if m <= leaf_size:
            dims_l.append(0)
            tau_vals.append(0.0)
            children.append([-1, -1])
            depths.append(depth)
            leaf_rows.append(len(leaf_tables))
            row = np.full(leaf_size, -1, dtype=np.int32)
            row[:m] = ids
            leaf_tables.append(row)
            entity_depth[ids] = depth
            continue
        sub = points[ids]
        dim = int(np.argmax(sub.max(0) - sub.min(0)))   # widest spread
        alpha = sub[:, dim]
        tau = _median_tau(alpha)
        left_mask = alpha <= tau
        if left_mask.all() or not left_mask.any():
            order = np.argsort(alpha, kind="stable")
            left_mask = np.zeros(m, dtype=bool)
            left_mask[order[: m // 2]] = True
        dims_l.append(dim)
        tau_vals.append(tau)
        children.append([-1, -1])
        depths.append(depth)
        leaf_rows.append(-1)
        stack.append((ids[left_mask], depth + 1, slot, 0))
        stack.append((ids[~left_mask], depth + 1, slot, 1))
    n_nodes = len(tau_vals)
    return FlatTree(
        kind="kd",
        proj=np.zeros((n_nodes, 1), dtype=np.float32),
        dims=np.asarray(dims_l, dtype=np.int32),
        tau=np.asarray(tau_vals, dtype=np.float32),
        children=np.asarray(children, dtype=np.int32),
        leaf_row=np.asarray(leaf_rows, dtype=np.int32),
        leaf_entities=(
            np.stack(leaf_tables)
            if leaf_tables
            else np.zeros((0, leaf_size), np.int32)
        ),
        depth=np.asarray(depths, dtype=np.int32),
        entity_depth=entity_depth,
    )


# ---------------------------------------------------------------------------
# Batched beam search (JAX)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TreeSearchResult:
    ids: jnp.ndarray          # (B, k) int32 entity ids (-1 pad)
    dists: jnp.ndarray        # (B, k) float32 squared L2
    steps: jnp.ndarray        # (B,) int32 descent iterations per query
    internal_visits: jnp.ndarray  # (B,) int32 internal-node dot products
    candidates: jnp.ndarray   # (B,) int32 exact distance evals (leaf scan)

    def tree_flatten(self):
        return (
            (self.ids, self.dists, self.steps, self.internal_visits,
             self.candidates),
            None,
        )

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


def _split_margin(kind: str, arrays: dict, nodes: jnp.ndarray, q: jnp.ndarray):
    """alpha = proj[node]·q - tau[node]   (or coordinate split for kd)."""
    if kind == "kd":
        dim = arrays["dims"][nodes]                      # (B, W)
        coord = jnp.take_along_axis(q, dim, axis=1)      # (B, W)
        return coord - arrays["tau"][nodes]
    pv = arrays["proj"][nodes]                           # (B, W, d)
    return jnp.einsum("bwd,bd->bw", pv, q) - arrays["tau"][nodes]


@partial(
    jax.jit,
    static_argnames=("kind", "beam_width", "k", "max_steps", "rerank"),
)
def tree_search(
    arrays: dict,
    db: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    kind: str = "rp",
    beam_width: int = 8,
    k: int = 10,
    max_steps: int = 64,
    rerank: bool = True,
    roots: jnp.ndarray | None = None,
) -> TreeSearchResult:
    """Batched multi-probe descent + exact rerank of gathered leaves.

    Beam priority = accumulated negative split margin along the path (the
    near child inherits the parent's priority; the far child pays |alpha|),
    the TPU-native analogue of SmallER's best-first backtracking queue.
    ``roots`` optionally gives a per-query start node (forest descent in the
    two-level index); default is node 0.
    """
    queries = queries.astype(jnp.float32)
    B, d = queries.shape
    W = beam_width
    children = arrays["children"]
    leaf_row = arrays["leaf_row"]
    leaf_entities = arrays["leaf_entities"]
    leaf_size = leaf_entities.shape[1]

    start = (
        jnp.zeros((B,), jnp.int32)
        if roots is None
        else roots.astype(jnp.int32)
    )
    nodes0 = jnp.full((B, W), -1, jnp.int32).at[:, 0].set(start)
    prios0 = jnp.full((B, W), _NEG_INF, jnp.float32).at[:, 0].set(0.0)
    steps0 = jnp.zeros((B,), jnp.int32)
    visits0 = jnp.zeros((B,), jnp.int32)

    def not_done(state):
        nodes, _, steps, _ = state
        valid = nodes >= 0
        is_leaf = jnp.where(valid, children[jnp.maximum(nodes, 0), 0] < 0, True)
        return jnp.logical_and(
            jnp.any(~jnp.all(is_leaf, axis=1)), steps.max() < max_steps
        )

    def body(state):
        nodes, prios, steps, visits = state
        safe = jnp.maximum(nodes, 0)
        valid = nodes >= 0
        is_leaf = children[safe, 0] < 0
        active = valid & ~is_leaf                         # internal, live
        alpha = _split_margin(kind, arrays, safe, queries)
        left = children[safe, 0]
        right = children[safe, 1]
        near = jnp.where(alpha <= 0, left, right)
        far = jnp.where(alpha <= 0, right, left)
        # slot A: internal -> near child (same prio); leaf -> itself
        a_nodes = jnp.where(active, near, nodes)
        a_prios = jnp.where(valid, prios, _NEG_INF)
        # slot B: internal -> far child (prio - |alpha|); leaf/pad -> dead
        b_nodes = jnp.where(active, far, -1)
        b_prios = jnp.where(active, prios - jnp.abs(alpha), _NEG_INF)
        cand_nodes = jnp.concatenate([a_nodes, b_nodes], axis=1)
        cand_prios = jnp.concatenate([a_prios, b_prios], axis=1)
        top_p, top_i = jax.lax.top_k(cand_prios, W)
        new_nodes = jnp.take_along_axis(cand_nodes, top_i, axis=1)
        new_nodes = jnp.where(top_p == _NEG_INF, -1, new_nodes)
        row_active = jnp.any(active, axis=1)
        return (
            new_nodes,
            top_p,
            steps + row_active.astype(jnp.int32),
            visits + active.sum(axis=1).astype(jnp.int32),
        )

    nodes, prios, steps, visits = jax.lax.while_loop(
        not_done, body, (nodes0, prios0, steps0, visits0)
    )

    # gather leaf entity ids
    safe = jnp.maximum(nodes, 0)
    rows = jnp.where(nodes >= 0, leaf_row[safe], -1)       # (B, W)
    ents = jnp.where(
        rows[..., None] >= 0,
        leaf_entities[jnp.maximum(rows, 0)],
        -1,
    )                                                      # (B, W, leaf)
    cand = ents.reshape(B, W * leaf_size)
    n_cand = (cand >= 0).sum(axis=1).astype(jnp.int32)

    if not rerank:
        return TreeSearchResult(cand, jnp.zeros_like(cand, jnp.float32),
                                steps, visits, n_cand)

    vecs = db[jnp.maximum(cand, 0)]                        # (B, C, d)
    diff2 = batched_l2sq(vecs, queries)
    diff2 = jnp.where(cand >= 0, diff2, jnp.inf)
    # dedupe identical ids from overlapping beams is unnecessary: leaves
    # partition entities, so ids are unique by construction.
    k_eff = min(k, cand.shape[1])
    neg, idx = jax.lax.top_k(-diff2, k_eff)
    ids = jnp.take_along_axis(cand, idx, axis=1)
    ids = jnp.where(jnp.isinf(-neg), -1, ids)
    if k_eff < k:
        pad = k - k_eff
        ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
        neg = jnp.pad(neg, ((0, 0), (0, pad)), constant_values=-jnp.inf)
    return TreeSearchResult(ids, -neg, steps, visits, n_cand)
