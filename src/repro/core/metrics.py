"""Evaluation metrics for ANN search (paper §4)."""
from __future__ import annotations

import time

import numpy as np

__all__ = ["recall_at_k", "percentile_ms", "LatencyTimer"]


def recall_at_k(pred_ids: np.ndarray, truth_ids: np.ndarray) -> float:
    """Paper definition: fraction of queries whose ground-truth entity is
    among the top-k returned.  ``truth_ids`` may be (B,) — the single true
    entity (ER-style) — or (B, m) — true m nearest neighbors, in which case
    a hit means any overlap counts proportionally (recall@k over the set).
    """
    pred_ids = np.asarray(pred_ids)
    truth_ids = np.asarray(truth_ids)
    if truth_ids.ndim == 1:
        hit = (pred_ids == truth_ids[:, None]).any(axis=1)
        return float(hit.mean())
    inter = np.zeros(pred_ids.shape[0], dtype=np.float64)
    for b in range(pred_ids.shape[0]):
        inter[b] = np.intersect1d(pred_ids[b], truth_ids[b]).size
    return float((inter / truth_ids.shape[1]).mean())


def percentile_ms(samples_s: list[float], q: float = 90.0) -> float:
    return float(np.percentile(np.asarray(samples_s) * 1e3, q))


class LatencyTimer:
    """Collects per-call wall-clock latencies (P50/P90/P99 like the paper)."""

    def __init__(self) -> None:
        self.samples: list[float] = []

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.samples.append(time.perf_counter() - self._t0)

    def stats(self) -> dict:
        if not self.samples:
            return {}
        a = np.asarray(self.samples) * 1e3
        return {
            "n": len(self.samples),
            "mean_ms": float(a.mean()),
            "p50_ms": float(np.percentile(a, 50)),
            "p90_ms": float(np.percentile(a, 90)),
            "p99_ms": float(np.percentile(a, 99)),
        }
