"""Query-likelihood tooling (paper §4.2).

The paper characterizes traffic skew with an information-entropy based
"unbalance score"::

    U(p) = 1 - H(p) / log2(N),   H(p) = -sum_i p_i log2 p_i

U = 0 for uniform traffic, U -> 1 as all mass concentrates on one entity.
The real Radio-Station traffic in the paper has U = 0.23.

Traffic is simulated by sampling entity weights from a Beta(a, b)
distribution and normalizing (§4.2).  ``beta_for_unbalance`` inverts the
simulation: it searches Beta shape parameters that achieve a target
unbalance score so Fig.-1-style sweeps can be reproduced exactly.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

__all__ = [
    "unbalance_score",
    "simulate_beta_likelihood",
    "beta_for_unbalance",
    "zipf_likelihood",
    "empirical_likelihood",
    "decayed_empirical_likelihood",
    "sample_queries",
]


def unbalance_score(p: np.ndarray) -> float:
    """1 - H(p)/log2(N); 0 == uniform, ~1 == fully concentrated."""
    p = np.asarray(p, dtype=np.float64)
    if p.ndim != 1:
        raise ValueError(f"p must be 1-D, got shape {p.shape}")
    n = p.size
    if n <= 1:
        return 1.0
    s = p.sum()
    if s <= 0:
        raise ValueError("p must have positive mass")
    p = p / s
    nz = p[p > 0]
    h = -(nz * np.log2(nz)).sum()
    return float(1.0 - h / np.log2(n))


def simulate_beta_likelihood(
    rng: np.random.Generator, n: int, a: float, b: float
) -> np.ndarray:
    """Sample a query-likelihood vector for ``n`` entities (paper §4.2)."""
    w = rng.beta(a, b, size=n)
    w = np.maximum(w, 1e-12)
    return w / w.sum()


def beta_for_unbalance(
    target: float,
    n: int,
    seed: int = 0,
    b: float = 8.0,
    tol: float = 5e-3,
    max_iter: int = 60,
) -> tuple[float, float, np.ndarray]:
    """Find Beta(a, b) whose normalized sample has ``unbalance_score ~ target``.

    Lowering ``a`` concentrates mass (higher unbalance).  Deterministic given
    ``seed``.  Returns (a, achieved_score, p).
    """
    if not 0.0 <= target < 1.0:
        raise ValueError("target unbalance must be in [0, 1)")
    lo, hi = 1e-3, 64.0

    def score_for(a: float) -> tuple[float, np.ndarray]:
        p = simulate_beta_likelihood(np.random.default_rng(seed), n, a, b)
        return unbalance_score(p), p

    s_lo, _ = score_for(lo)
    s_hi, _ = score_for(hi)
    # unbalance decreases as `a` grows; clamp target into achievable range.
    for _ in range(max_iter):
        mid = np.sqrt(lo * hi)
        s, p = score_for(mid)
        if abs(s - target) < tol:
            return mid, s, p
        if s > target:
            lo = mid
        else:
            hi = mid
    s, p = score_for(np.sqrt(lo * hi))
    return float(np.sqrt(lo * hi)), s, p


def zipf_likelihood(n: int, alpha: float = 1.0) -> np.ndarray:
    """Zipfian likelihood (classic fathead/long-tail traffic)."""
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** (-alpha)
    return w / w.sum()


def empirical_likelihood(query_ids: np.ndarray, n: int, smoothing: float = 0.5):
    """Estimate p from an observed query log.

    The ``smoothing`` term is Laplace-style additive smoothing: every
    entity's count gets ``+ smoothing`` pseudo-observations before
    normalization, so unseen entities keep a small positive likelihood
    (the default 0.5 is the Jeffreys prior) instead of an exact zero that
    a KL-divergence drift check could not handle.
    """
    counts = np.bincount(np.asarray(query_ids, dtype=np.int64), minlength=n)
    counts = counts.astype(np.float64) + smoothing
    return counts / counts.sum()


def decayed_empirical_likelihood(
    query_ids: np.ndarray,
    n: int,
    halflife: float,
    smoothing: float = 0.5,
    *,
    prior_counts: Optional[np.ndarray] = None,
    return_counts: bool = False,
):
    """Exponentially-decayed empirical likelihood from a query log.

    The observation ``t`` positions before the newest carries weight
    ``0.5 ** (t / halflife)`` — the estimator tracks *recent* traffic, the
    regime index maintenance cares about, rather than the all-time
    average (``halflife=np.inf`` recovers :func:`empirical_likelihood`).
    ``smoothing`` is the same Laplace-style additive term.

    ``prior_counts`` chains calls over a stream: pass the counts returned
    by the previous call (``return_counts=True``) and they are decayed by
    the new batch's total age before being added, so feeding a log in
    batches is exactly equivalent to one call over the concatenated log.
    Shared by ``repro.adaptive.OnlineLikelihoodEstimator`` (its exact,
    sketch-free mode) and the benchmarks.
    """
    ids = np.asarray(query_ids, dtype=np.int64)
    if ids.size and (ids.min() < 0 or ids.max() >= n):
        raise ValueError(f"query id out of range [0, {n})")
    t = ids.size
    if t:
        age = (t - 1) - np.arange(t)
        w = 0.5 ** (age / halflife) if np.isfinite(halflife) else \
            np.ones(t, np.float64)
        counts = np.bincount(ids, weights=w, minlength=n)
    else:
        counts = np.zeros(n, np.float64)
    if prior_counts is not None:
        decay = 0.5 ** (t / halflife) if np.isfinite(halflife) else 1.0
        counts = counts + np.asarray(prior_counts, np.float64) * decay
    p = counts + smoothing
    p = p / p.sum()
    return (p, counts) if return_counts else p


def sample_queries(
    rng: np.random.Generator,
    embeddings: np.ndarray,
    p: np.ndarray,
    n_queries: int,
    noise_scale: float = 0.05,
) -> tuple[np.ndarray, np.ndarray]:
    """Draw queries from the entity distribution ``p`` (paper §4.2).

    Each query is its ground-truth entity's embedding plus Gaussian noise
    scaled by ``noise_scale``·(mean pairwise scale), mimicking ASR/embedding
    noise around the true entity.  Returns (queries, ground_truth_ids).
    """
    n, d = embeddings.shape
    ids = rng.choice(n, size=n_queries, p=p / p.sum())
    scale = float(np.std(embeddings)) * noise_scale
    q = embeddings[ids] + rng.normal(0.0, scale, size=(n_queries, d))
    return q.astype(np.float32), ids.astype(np.int32)
