"""On-device search configuration protocol (paper §5.3).

Encodes the paper's decision tree verbatim:

  N < 30K:
    traffic distribution available      -> QLBT
    traffic distribution not available  -> standard projection tree
  N >= 30K:
    partition feature high-dim (embeddings) -> two-level PQ top + brute
        bottom, ~100 entities per bucket
    partition feature low-dim (geo)         -> two-level kd-tree top;
        bottom brute if <=100 entities/bucket else tree
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.two_level import TwoLevelConfig

__all__ = ["IndexSpec", "select_index_spec", "SMALL_CORPUS_THRESHOLD",
           "TARGET_BUCKET_ENTITIES"]

SMALL_CORPUS_THRESHOLD = 30_000     # paper Fig. 3 crossover
TARGET_BUCKET_ENTITIES = 100        # paper §5.2 optimum
LOW_DIM_THRESHOLD = 8               # "low dimension (e.g., geolocation)"


@dataclasses.dataclass
class IndexSpec:
    kind: str                                  # "qlbt" | "tree" | "two_level"
    two_level: Optional[TwoLevelConfig] = None
    reason: str = ""


def select_index_spec(
    n_entities: int,
    *,
    traffic_available: bool = False,
    partition_dim: Optional[int] = None,
    embedding_dim: int = 128,
    avg_bucket_entities: int = TARGET_BUCKET_ENTITIES,
) -> IndexSpec:
    """Paper §5.3 guideline, mechanized."""
    if n_entities < SMALL_CORPUS_THRESHOLD:
        if traffic_available:
            return IndexSpec("qlbt", reason="N<30K and traffic known (§5.3)")
        return IndexSpec("tree", reason="N<30K, no traffic (§5.3)")

    part_dim = embedding_dim if partition_dim is None else partition_dim
    n_clusters = max(1, int(round(n_entities / avg_bucket_entities)))
    # round to a power of two like the paper's 2^s sweeps
    n_clusters = 1 << max(0, int(round(np.log2(n_clusters))))

    if part_dim > LOW_DIM_THRESHOLD:
        cfg = TwoLevelConfig(n_clusters=n_clusters, top="pq", bottom="brute")
        return IndexSpec(
            "two_level", cfg,
            reason=f"N>=30K, high-dim partition feature -> PQ top + brute "
                   f"bottom, {n_clusters} buckets (~{avg_bucket_entities}/"
                   f"bucket) (§5.3)",
        )
    avg = n_entities / n_clusters
    bottom = "brute" if avg <= TARGET_BUCKET_ENTITIES else "tree"
    cfg = TwoLevelConfig(n_clusters=n_clusters, top="kdtree", bottom=bottom)
    return IndexSpec(
        "two_level", cfg,
        reason=f"N>=30K, low-dim partition feature -> kd-tree top + "
               f"{bottom} bottom (§5.3)",
    )
