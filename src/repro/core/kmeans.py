"""K-means for two-level partitioning (paper §3.2 step 2).

Lloyd iterations in JAX with chunked assignment (matmul-expanded L2) and
``segment_sum`` centroid updates, plus a mini-batch mode for very large
corpora.  The same assignment kernel handles PQ codebook training
(`core/pq.py`) and bucket routing at build time.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["KMeansResult", "kmeans_fit", "kmeans_assign", "pad_to_multiple"]


@dataclasses.dataclass
class KMeansResult:
    centroids: np.ndarray       # (k, d) float32
    assignments: np.ndarray     # (n,) int32
    inertia: float
    n_iter: int


def pad_to_multiple(x: np.ndarray, m: int, axis: int = 0, value=0.0):
    n = x.shape[axis]
    pad = (-n) % m
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths, constant_values=value), n


@partial(jax.jit, static_argnames=("chunk",))
def _assign_chunked(x: jnp.ndarray, c: jnp.ndarray, chunk: int):
    """argmin_j ||x_i - c_j||^2 via scan over query chunks."""
    n, d = x.shape
    c_norm = jnp.sum(c * c, axis=1)                     # (k,)

    def step(_, xi):
        d2 = c_norm[None, :] - 2.0 * (xi @ c.T)         # (chunk, k) + const
        a = jnp.argmin(d2, axis=1).astype(jnp.int32)
        best = jnp.min(d2, axis=1) + jnp.sum(xi * xi, axis=1)
        return None, (a, best)

    xs = x.reshape(n // chunk, chunk, d)
    _, (a, best) = jax.lax.scan(step, None, xs)
    return a.reshape(n), best.reshape(n)


@partial(jax.jit, static_argnames=("chunk", "m"))
def _assign_topm_chunked(x: jnp.ndarray, c: jnp.ndarray, m: int, chunk: int):
    n, d = x.shape
    c_norm = jnp.sum(c * c, axis=1)

    def step(_, xi):
        d2 = c_norm[None, :] - 2.0 * (xi @ c.T)
        neg, ids = jax.lax.top_k(-d2, m)
        return None, (ids.astype(jnp.int32),
                      -neg + jnp.sum(xi * xi, axis=1, keepdims=True))

    xs = x.reshape(n // chunk, chunk, d)
    _, (ids, d2) = jax.lax.scan(step, None, xs)
    return ids.reshape(n, m), d2.reshape(n, m)


def _assign_topm(x: np.ndarray, centroids: np.ndarray, m: int,
                 chunk: int = 4096):
    """Host helper: m nearest centroids per row (ids, sq-dists)."""
    x = np.ascontiguousarray(x, dtype=np.float32)
    xp, n = pad_to_multiple(x, min(chunk, max(1, x.shape[0])))
    ids, d2 = _assign_topm_chunked(
        jnp.asarray(xp), jnp.asarray(centroids), m,
        min(chunk, max(1, x.shape[0]))
    )
    return np.asarray(ids[:n]), np.asarray(d2[:n])


def kmeans_assign(x: np.ndarray, centroids: np.ndarray, chunk: int = 4096):
    """Host helper: nearest-centroid ids for (possibly huge) x."""
    x = np.ascontiguousarray(x, dtype=np.float32)
    xp, n = pad_to_multiple(x, chunk)
    a, d2 = _assign_chunked(jnp.asarray(xp), jnp.asarray(centroids), chunk)
    return np.asarray(a[:n]), np.asarray(d2[:n])


@partial(jax.jit, static_argnames=("k", "chunk"))
def _lloyd_iter(x: jnp.ndarray, c: jnp.ndarray, k: int, chunk: int):
    a, d2 = _assign_chunked(x, c, chunk)
    sums = jax.ops.segment_sum(x, a, num_segments=k)
    cnts = jax.ops.segment_sum(jnp.ones_like(a, jnp.float32), a,
                               num_segments=k)
    new_c = jnp.where(cnts[:, None] > 0, sums / jnp.maximum(cnts, 1)[:, None],
                      c)
    return new_c, a, d2.sum(), cnts


def _init_centroids(rng: np.random.Generator, x: np.ndarray, k: int,
                    init: str) -> np.ndarray:
    n = x.shape[0]
    if init == "random" or k >= n:
        ids = rng.choice(n, size=min(k, n), replace=False)
        c = x[ids]
        if k > n:  # degenerate: duplicate
            c = np.concatenate([c, c[rng.integers(0, n, k - n)]], 0)
        return c.astype(np.float32)
    if init == "kmeans++":  # exact D^2 sampling; fine for k <= ~4096
        ids = [int(rng.integers(0, n))]
        d2 = ((x - x[ids[0]]) ** 2).sum(1)
        for _ in range(k - 1):
            probs = d2 / (d2.sum() + 1e-30)
            nxt = int(rng.choice(n, p=probs))
            ids.append(nxt)
            d2 = np.minimum(d2, ((x - x[nxt]) ** 2).sum(1))
        return x[np.asarray(ids)].astype(np.float32)
    raise ValueError(f"unknown init {init!r}")


def kmeans_fit(
    x: np.ndarray,
    k: int,
    *,
    iters: int = 15,
    chunk: int = 4096,
    seed: int = 0,
    init: str = "random",
    minibatch: int | None = None,
    tol: float = 1e-4,
) -> KMeansResult:
    """Lloyd (or mini-batch) k-means. Deterministic given ``seed``.

    ``minibatch``: if set, each iteration runs Lloyd on a fresh uniform
    sample of that size (Sculley-style), then a final full assignment —
    used for the 2^13..2^15-cluster builds on 1M+ corpora.
    """
    x = np.ascontiguousarray(x, dtype=np.float32)
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    c = _init_centroids(rng, x, k, init)
    chunk = min(chunk, max(1, n))

    prev = np.inf
    it = 0
    for it in range(1, iters + 1):
        if minibatch is not None and minibatch < n:
            sample = x[rng.choice(n, size=minibatch, replace=False)]
        else:
            sample = x
        sp, sn = pad_to_multiple(sample, chunk)
        # padded rows park on centroid of their own (they're zeros); mask by
        # assigning them weight via distance -> they still land somewhere, so
        # instead drop them: run on the largest chunk-multiple prefix.
        m = (sample.shape[0] // chunk) * chunk
        if m == 0:
            m = sample.shape[0]
            sp = sample
            local_chunk = m
        else:
            sp = sample[:m]
            local_chunk = chunk
        new_c, _, inertia, _ = _lloyd_iter(
            jnp.asarray(sp), jnp.asarray(c), k, local_chunk
        )
        new_c = np.asarray(new_c)
        inertia = float(inertia)
        shift = float(np.abs(new_c - c).max())
        c = new_c
        if shift < tol or abs(prev - inertia) < tol * max(prev, 1.0):
            break
        prev = inertia

    a, d2 = kmeans_assign(x, c, chunk=chunk)
    return KMeansResult(centroids=c, assignments=a,
                        inertia=float(d2.sum()), n_iter=it)
