"""Fixed-shape BM25 postings slabs + the pure-numpy lexical oracle.

The hybrid serving path (``docs/filtering.md``) fuses a BM25-ish
lexical score with the semantic L2 distance.  Edge constraints rule out
a classic inverted index (pointer-chasing, variable-length lists), so
documents carry their term data as **fixed-shape slabs**, the same
layout discipline as every other operand in the repo:

* ``terms``  — ``(N, S)`` int32, the up-to-``S`` highest-tf term ids of
  each document, ``-1``-padded.  Rows are append-only and aligned with
  the corpus (row i describes entity i).
* ``tf_sat`` — ``(N, S)`` f32, the *saturated* term-frequency factor
  ``tf * (k1 + 1) / (tf + k1_norm_d)`` with
  ``k1_norm_d = k1 * (1 - b + b * len_d / avg_len)`` precomputed on the
  host.  Kernels then only match + weight + sum — no division on the
  scan path.

Scores follow the BM25 shape ``sum_t idf_t * sat(tf_{t,d})`` over the
query's unique terms; the *ranking distance* is ``-score`` so lower is
better and the ``(inf, -1)`` sentinel contract carries over unchanged.

``idf`` and ``avg_len`` are frozen at build time: appended documents are
scored under the corpus statistics of the last build (re-deriving them
per append would silently re-rank the whole corpus between deltas).
``build_lexical_slabs`` on the current docs refreshes them — the same
rebuild-vs-delta trade every other structure here makes.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["LexicalSlabs", "build_lexical_slabs", "query_operands",
           "bm25_dists"]


@dataclasses.dataclass
class LexicalSlabs:
    terms: np.ndarray        # (N, S) int32, -1 padded
    tf_sat: np.ndarray       # (N, S) f32, saturated tf factor
    idf: np.ndarray          # (V,) f32, frozen at build
    k1: float
    b: float
    avg_len: float           # frozen at build

    @property
    def n_docs(self) -> int:
        return int(self.terms.shape[0])

    @property
    def slots(self) -> int:
        return int(self.terms.shape[1])

    @property
    def n_vocab(self) -> int:
        return int(self.idf.shape[0])

    def footprint_bytes(self) -> int:
        return self.terms.nbytes + self.tf_sat.nbytes + self.idf.nbytes

    def append_docs(self, docs) -> None:
        """Append one slab row per document (term-id sequences), scored
        under the *frozen* idf / avg_len (see module docstring)."""
        t, s = _slab_rows(docs, self.slots, self.k1, self.b, self.avg_len)
        self.terms = np.concatenate([self.terms, t])
        self.tf_sat = np.concatenate([self.tf_sat, s])


def _slab_rows(docs, slots: int, k1: float, b: float, avg_len: float):
    n = len(docs)
    terms = np.full((n, slots), -1, dtype=np.int32)
    tf_sat = np.zeros((n, slots), dtype=np.float32)
    for i, doc in enumerate(docs):
        ids, tf = np.unique(np.asarray(doc, dtype=np.int64),
                            return_counts=True)
        ids = ids[ids >= 0]
        tf = tf[-ids.size:] if ids.size else tf[:0]
        length = float(np.sum(tf))
        if ids.size > slots:        # keep the highest-tf terms
            keep = np.argsort(-tf, kind="stable")[:slots]
            keep.sort()
            ids, tf = ids[keep], tf[keep]
        k1n = k1 * (1.0 - b + b * (length / max(avg_len, 1e-9)))
        terms[i, :ids.size] = ids.astype(np.int32)
        tf_sat[i, :ids.size] = (
            tf * (k1 + 1.0) / (tf + k1n)).astype(np.float32)
    return terms, tf_sat


def build_lexical_slabs(docs, n_vocab: int, *, slots: int = 16,
                        k1: float = 1.2, b: float = 0.75) -> LexicalSlabs:
    """Build slabs + corpus statistics from term-id sequences."""
    n = len(docs)
    df = np.zeros(n_vocab, dtype=np.int64)
    lengths = np.zeros(n, dtype=np.float64)
    for i, doc in enumerate(docs):
        ids = np.unique(np.asarray(doc, dtype=np.int64))
        ids = ids[(ids >= 0) & (ids < n_vocab)]
        df[ids] += 1
        lengths[i] = len(doc)
    avg_len = float(lengths.mean()) if n else 1.0
    idf = np.log(1.0 + (n - df + 0.5) / (df + 0.5)).astype(np.float32)
    terms, tf_sat = _slab_rows(docs, slots, k1, b, avg_len)
    return LexicalSlabs(terms=terms, tf_sat=tf_sat, idf=idf,
                        k1=float(k1), b=float(b), avg_len=avg_len)


def query_operands(q_docs, slabs: LexicalSlabs, *, slots: int = 8):
    """Fixed-shape query operands: ``(B, T)`` unique term ids (-1 pad)
    and their idf weights.  Terms beyond ``slots`` are dropped highest-
    idf-first-kept (rarest terms carry the score)."""
    bsz = len(q_docs)
    qt = np.full((bsz, slots), -1, dtype=np.int32)
    qw = np.zeros((bsz, slots), dtype=np.float32)
    for i, doc in enumerate(q_docs):
        ids = np.unique(np.asarray(doc, dtype=np.int64))
        ids = ids[(ids >= 0) & (ids < slabs.n_vocab)]
        w = slabs.idf[ids]
        if ids.size > slots:
            keep = np.argsort(-w, kind="stable")[:slots]
            keep.sort()
            ids, w = ids[keep], w[keep]
        qt[i, :ids.size] = ids.astype(np.int32)
        qw[i, :ids.size] = w.astype(np.float32)
    return qt, qw


def bm25_dists(terms: np.ndarray, tf_sat: np.ndarray,
               q_terms: np.ndarray, q_weights: np.ndarray) -> np.ndarray:
    """Pure-numpy oracle: ``(B, N)`` ranking distances (``-score``)."""
    bsz, tq = q_terms.shape
    score = np.zeros((bsz, terms.shape[0]), dtype=np.float32)
    for t in range(tq):
        qt = q_terms[:, t]                                   # (B,)
        m = (terms[None, :, :] == qt[:, None, None])         # (B, N, S)
        m &= qt[:, None, None] >= 0
        score += (m * tf_sat[None, :, :]).sum(-1) * q_weights[:, t:t + 1]
    return -score
