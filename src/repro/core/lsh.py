"""Footprint-reduced LSH (paper §3.2 bottom-level option 3).

Sign-random-projection LSH with a *fixed, shared* projection set (the
paper's footprint reduction: one (d, n_bits) matrix reused by every bucket
instead of per-bucket hash tables).  Codes are bit-packed into int32 lanes;
search = XOR + popcount Hamming ranking, then exact rerank of the top
candidates.  The packed XOR-popcount loop is the `kernels/hamming` Pallas
kernel; `hamming_scores` is the jnp oracle/CPU path.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["LSHIndex", "lsh_build", "pack_bits", "hamming_scores",
           "lsh_search"]


def _popcount32(x: jnp.ndarray) -> jnp.ndarray:
    """Bit-twiddling popcount on int32 lanes (TPU has no popcnt op)."""
    x = x - ((x >> 1) & 0x55555555)
    x = (x & 0x33333333) + ((x >> 2) & 0x33333333)
    x = (x + (x >> 4)) & 0x0F0F0F0F
    return (x * 0x01010101) >> 24


def pack_bits(bits: np.ndarray) -> np.ndarray:
    """(N, n_bits) {0,1} -> (N, ceil(n_bits/32)) int32 little-endian."""
    n, nb = bits.shape
    pad = (-nb) % 32
    if pad:
        bits = np.pad(bits, ((0, 0), (0, pad)))
    b = bits.reshape(n, -1, 32).astype(np.uint64)
    weights = (1 << np.arange(32, dtype=np.uint64))
    packed = (b * weights).sum(axis=2)
    return packed.astype(np.uint32).view(np.int32).reshape(n, -1)


@dataclasses.dataclass
class LSHIndex:
    proj: np.ndarray      # (d, n_bits) float32 — the fixed shared projections
    codes: np.ndarray     # (N, W) int32 packed sign bits
    n_bits: int

    @property
    def n(self) -> int:
        return int(self.codes.shape[0])

    def footprint_bytes(self) -> int:
        return self.proj.nbytes + self.codes.nbytes


def lsh_build(x: np.ndarray, n_bits: int = 64, seed: int = 0,
              proj: np.ndarray | None = None) -> LSHIndex:
    x = np.ascontiguousarray(x, dtype=np.float32)
    d = x.shape[1]
    if proj is None:
        rng = np.random.default_rng(seed)
        proj = rng.normal(size=(d, n_bits)).astype(np.float32)
        proj /= np.linalg.norm(proj, axis=0, keepdims=True)
    bits = (x @ proj > 0).astype(np.uint8)
    return LSHIndex(proj=proj, codes=pack_bits(bits), n_bits=n_bits)


@partial(jax.jit, static_argnames=("chunk",))
def hamming_scores(qcodes: jnp.ndarray, codes: jnp.ndarray,
                   chunk: int = 262144) -> jnp.ndarray:
    """(B, N) Hamming distances between packed codes (jnp oracle)."""
    B, w = qcodes.shape
    n = codes.shape[0]
    chunk = min(chunk, n)
    n_chunks = -(-n // chunk)
    pad = n_chunks * chunk - n
    cp = jnp.pad(codes, ((0, pad), (0, 0)))

    def step(_, cs):                                     # (chunk, w)
        x = jnp.bitwise_xor(qcodes[:, None, :], cs[None, :, :])
        return None, _popcount32(x).sum(-1)              # (B, chunk)

    _, out = jax.lax.scan(step, None, cp.reshape(n_chunks, chunk, w))
    return jnp.moveaxis(out, 0, 1).reshape(B, -1)[:, :n]


def lsh_search(
    index: LSHIndex,
    db: np.ndarray,
    queries: np.ndarray,
    k: int,
    *,
    n_candidates: int = 128,
) -> tuple[np.ndarray, np.ndarray]:
    """Hamming shortlist of ``n_candidates`` then exact rerank to top-k."""
    q = np.ascontiguousarray(queries, dtype=np.float32)
    qbits = (q @ index.proj > 0).astype(np.uint8)
    qcodes = jnp.asarray(pack_bits(qbits))
    ham = hamming_scores(qcodes, jnp.asarray(index.codes))
    n_candidates = min(n_candidates, index.n)
    _, cand = jax.lax.top_k(-ham.astype(jnp.float32), n_candidates)
    # exact rerank
    dbj = jnp.asarray(db, dtype=jnp.float32)
    qj = jnp.asarray(q)
    vecs = dbj[cand]                                     # (B, C, d)
    d2 = (
        jnp.sum(vecs * vecs, -1)
        - 2.0 * jnp.einsum("bcd,bd->bc", vecs, qj)
        + jnp.sum(qj * qj, -1, keepdims=True)
    )
    k = min(k, n_candidates)
    neg, sel = jax.lax.top_k(-d2, k)
    ids = jnp.take_along_axis(cand, sel, axis=1)
    return np.asarray(-neg), np.asarray(ids, dtype=np.int32)
