"""Unified index API: build from an IndexSpec, search with one signature.

This is the public entry point used by the serving engine, the examples,
and the benchmark harness.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import tree as tree_mod
from repro.core.delta import DeltaLog, DeltaManifest
from repro.core.protocol import IndexSpec, select_index_spec
from repro.core.tree import FlatTree, build_qlbt, build_rp_tree
from repro.core.two_level import TwoLevelIndex, build_two_level

__all__ = ["SearchIndex", "build_index", "auto_build_index"]


@dataclasses.dataclass
class SearchIndex:
    spec: IndexSpec
    db: np.ndarray
    tree: Optional[FlatTree] = None
    two_level: Optional[TwoLevelIndex] = None
    p: Optional[np.ndarray] = None      # traffic estimate (qlbt rebuilds)
    alive: Optional[np.ndarray] = None  # single-tree tombstones
    # last fully-built tree: reboost always derives from it, never from a
    # previous reboost — chained incremental re-splits compound the float
    # relocations until recall erodes
    base_tree: Optional[FlatTree] = None
    # ---- delta shipping (single-tree path; two-level delegates) ----
    mutation_version: int = 0
    delta_log: Optional[DeltaLog] = dataclasses.field(
        default=None, repr=False)
    # single-tree metadata sidecar; two-level indexes own theirs (the
    # ``metadata`` property routes either way)
    _metadata: Optional[object] = dataclasses.field(default=None, repr=False)

    @property
    def metadata(self):
        """Row-aligned :class:`repro.core.metadata.MetadataTable` (or
        None) — the table ``FilterSpec`` predicates resolve against."""
        if self.two_level is not None:
            return self.two_level.metadata
        return self._metadata

    @property
    def lexical(self):
        """Row-aligned :class:`repro.core.lexical.LexicalSlabs` (or None)
        — the BM25 postings the lexical/hybrid modes scan."""
        if self.two_level is not None:
            return self.two_level.lexical
        return None

    def search(
        self,
        queries: np.ndarray,
        k: int = 10,
        *,
        beam_width: int = 8,
        nprobe: int = 8,
        query_chunk: int = 1024,
    ) -> tuple[np.ndarray, np.ndarray, dict]:
        """Returns (dists, ids, work)."""
        q = np.ascontiguousarray(queries, dtype=np.float32)
        if self.spec.kind in ("qlbt", "tree"):
            # snapshot once: a maintenance-thread reboost() swaps
            # self.tree between reads, and mixing the old arrays with the
            # new tree's max_depth would truncate the descent
            t = self.tree
            res = tree_mod.tree_search(
                t.device_arrays(), jnp.asarray(self.db),
                jnp.asarray(q), kind=t.kind, beam_width=beam_width,
                k=k, max_steps=t.max_depth + 4,
            )
            work = {
                "internal_visits": int(np.asarray(res.internal_visits).sum()),
                "candidates": int(np.asarray(res.candidates).sum()),
                "steps_mean": float(np.asarray(res.steps).mean()),
            }
            return np.asarray(res.dists), np.asarray(res.ids), work
        d, i, work = self.two_level.search(
            q, k, nprobe=nprobe, beam_width=beam_width,
            query_chunk=query_chunk,
        )
        return d, i, work

    def footprint_bytes(self, include_db: bool = True) -> int:
        tot = self.db.nbytes if include_db else 0
        if self.tree is not None:
            tot += self.tree.footprint_bytes()
        if self.two_level is not None:
            tot += self.two_level.footprint_bytes(include_db=False)
        return tot

    # ---------------- online mutation (lifecycle API) ----------------
    def _tree_rebuild(self) -> None:
        """Rebuild the single tree over *live* rows only (tombstoned rows
        must never be re-indexed) and remap leaf ids back to global."""
        live = np.nonzero(self.alive)[0]
        if self.spec.kind == "qlbt" and self.p is not None:
            t = build_qlbt(self.db[live], self.p[live])
        else:
            t = build_rp_tree(self.db[live])
        leaf = t.leaf_entities
        m = leaf >= 0
        leaf[m] = live[leaf[m]].astype(leaf.dtype)
        self.tree = t
        self.base_tree = None          # fresh build is the new reboost base

    def _ensure_alive(self) -> None:
        if self.alive is None:
            self.alive = np.ones(self.db.shape[0], dtype=bool)
        if self.delta_log is None:
            self.delta_log = DeltaLog(
                base_version=self.mutation_version,
                base_n=int(self.db.shape[0]))

    def pop_delta(self) -> DeltaManifest:
        """Emit (and reset) the mutation record since the last pop.

        Two-level indexes delegate to
        :meth:`repro.core.two_level.TwoLevelIndex.pop_delta` (bucket-
        granular).  A single tree has no bucket structure to slice, so
        only tombstone-deletes are expressible as a delta (masked leaf
        rows + liveness flips); any structural change — add (whole-tree
        rebuild at this scale), rebalance, reboost — marks the manifest
        ``full``.
        """
        if self.two_level is not None:
            return self.two_level.pop_delta()
        self._ensure_alive()
        return self.delta_log.pop(self.mutation_version,
                                  int(self.db.shape[0]))

    def add_entities(self, new_vecs: np.ndarray, **kw) -> np.ndarray:
        """Insert new entities; returns their global ids.

        Two-level indexes take the incremental path (bucket routing +
        dirty-bucket forest rebuild, see ``TwoLevelIndex.add_entities``).
        Single-tree indexes (protocol: small corpora) rebuild the tree
        over the surviving rows — a whole-tree build at that scale is the
        paper's own update model.
        """
        if self.two_level is not None:
            ids = self.two_level.add_entities(new_vecs, **kw)
            self.db = self.two_level.db
            return ids
        self._ensure_alive()
        new_vecs = np.ascontiguousarray(new_vecs, dtype=np.float32)
        start = self.db.shape[0]
        ids = np.arange(start, start + new_vecs.shape[0], dtype=np.int32)
        self.db = np.concatenate([self.db, new_vecs], axis=0)
        self.alive = np.concatenate([self.alive, np.ones(ids.size, bool)])
        meta_rows = kw.pop("metadata", None)
        if self._metadata is not None:
            self._metadata.append_rows(meta_rows or {}, ids.size)
        elif meta_rows:
            raise ValueError(
                "index has no metadata table; build with metadata= to "
                "accept per-entity metadata on add_entities")
        if self.spec.kind == "qlbt" and self.p is not None:
            p_new = kw.get("p")
            if p_new is None:
                p_new = np.full(ids.size, float(np.mean(self.p)))
            self.p = np.concatenate([self.p, np.asarray(p_new)])
        self._tree_rebuild()
        self.delta_log.mark_full()      # whole-tree rebuild, no delta
        self.mutation_version += 1
        return ids

    def delete_entities(self, ids: np.ndarray) -> None:
        """Tombstone-delete: ids stay stable, deleted ids are immediately
        unreturnable (bucket-slot compaction / in-place leaf masking)."""
        if self.two_level is not None:
            self.two_level.delete_entities(ids)
            return
        self._ensure_alive()
        ids = np.asarray(ids)
        self.alive[ids] = False
        self.tree.drop_entities(ids)
        self.delta_log.mark_tombstones(ids)
        self.mutation_version += 1
        if self.base_tree is not None and self.base_tree is not self.tree:
            # keep the reboost base in sync — a later reboost from a base
            # still holding the id would resurrect a deleted entity
            self.base_tree.drop_entities(ids)

    def rebalance(self, **kw) -> dict:
        """Two-level: drifted-bucket Lloyd step + dirty-tree rebuild.
        Single-tree: full rebuild from the surviving corpus."""
        if self.two_level is not None:
            return self.two_level.rebalance(**kw)
        self._ensure_alive()
        self._tree_rebuild()
        self.delta_log.mark_full()
        self.mutation_version += 1
        return {"n_rebuilt_buckets": 1, "n_moved": 0,
                "n_drifted": 0, "max_drift": 0.0}

    def reboost(self, p: np.ndarray, **kw) -> dict:
        """Incremental re-boost from a new traffic estimate ``p``.

        Single-tree indexes re-run the boosted split objective on the top
        levels only, reusing subtrees below (:meth:`FlatTree.reboost`);
        two-level indexes reboost every bucket tree and swap the forest
        atomically (:meth:`TwoLevelIndex.reboost`).  Orders of magnitude
        cheaper than :meth:`rebuild_with_likelihood`'s full rebuild — the
        drift-triggered maintenance path
        (``repro.adaptive.MaintenanceScheduler``) calls this.
        """
        if self.two_level is not None:
            stats = self.two_level.reboost(p, **kw)
            self.p = self.two_level.p
            return stats
        self._ensure_alive()
        p = np.asarray(p, dtype=np.float64)
        if p.shape[0] != self.db.shape[0]:
            raise ValueError(
                f"p has {p.shape[0]} entries for {self.db.shape[0]} rows")
        self.p = p
        p_eff = np.where(self.alive, p, 0.0)
        if self.base_tree is None:
            self.base_tree = self.tree
        self.tree = self.base_tree.reboost(self.db, p_eff, **kw)
        self.delta_log.mark_full()      # node table re-split wholesale
        self.mutation_version += 1
        return {"n_reboosted": 1, "n_refreshed": 0}

    def rebuild_with_likelihood(self, p: np.ndarray, *, seed: int = 0):
        """Paper §3.1: 'if only this distribution changes, a new search
        tree can be easily built, keeping other configurations the same'
        — the personalization path.  Rebuilds the QLBT in place from the
        stored vectors and the new traffic estimate; no effect on
        two-level indexes (their buckets don't depend on p)."""
        if self.spec.kind not in ("qlbt", "tree"):
            return self
        self._ensure_alive()
        self.tree = build_qlbt(self.db, p, seed=seed)
        self.base_tree = None          # fresh build is the new reboost base
        self.delta_log.mark_full()
        self.mutation_version += 1
        self.spec = dataclasses.replace(self.spec, kind="qlbt")
        return self


def build_index(
    spec: IndexSpec,
    db: np.ndarray,
    *,
    p: Optional[np.ndarray] = None,
    partition_features: Optional[np.ndarray] = None,
    metadata=None,
    lexical=None,
    seed: int = 0,
) -> SearchIndex:
    db = np.ascontiguousarray(db, dtype=np.float32)
    if metadata is not None and metadata.n_rows != db.shape[0]:
        raise ValueError(
            f"metadata table has {metadata.n_rows} rows for a "
            f"{db.shape[0]}-row db")
    if spec.kind == "qlbt":
        if p is None:
            raise ValueError("QLBT requires a query-likelihood vector p")
        t = build_qlbt(db, p, seed=seed)
        return SearchIndex(spec=spec, db=db, tree=t,
                           p=np.asarray(p, np.float64), _metadata=metadata)
    if spec.kind == "tree":
        return SearchIndex(spec=spec, db=db,
                           tree=build_rp_tree(db, seed=seed),
                           _metadata=metadata)
    if spec.kind == "two_level":
        tl = build_two_level(
            db, spec.two_level, p=p, partition_features=partition_features,
            metadata=metadata, lexical=lexical,
        )
        return SearchIndex(spec=spec, db=db, two_level=tl)
    raise ValueError(f"unknown index kind {spec.kind!r}")


def auto_build_index(
    db: np.ndarray,
    *,
    p: Optional[np.ndarray] = None,
    partition_features: Optional[np.ndarray] = None,
    seed: int = 0,
) -> SearchIndex:
    """Apply the paper's §5.3 protocol end-to-end."""
    part_dim = (
        partition_features.shape[1]
        if partition_features is not None
        else None
    )
    spec = select_index_spec(
        db.shape[0],
        traffic_available=p is not None,
        partition_dim=part_dim,
        embedding_dim=db.shape[1],
    )
    return build_index(
        spec, db, p=p, partition_features=partition_features, seed=seed
    )
