"""Unified index API: build from an IndexSpec, search with one signature.

This is the public entry point used by the serving engine, the examples,
and the benchmark harness.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import tree as tree_mod
from repro.core.protocol import IndexSpec, select_index_spec
from repro.core.tree import FlatTree, build_qlbt, build_rp_tree
from repro.core.two_level import TwoLevelIndex, build_two_level

__all__ = ["SearchIndex", "build_index", "auto_build_index"]


@dataclasses.dataclass
class SearchIndex:
    spec: IndexSpec
    db: np.ndarray
    tree: Optional[FlatTree] = None
    two_level: Optional[TwoLevelIndex] = None

    def search(
        self,
        queries: np.ndarray,
        k: int = 10,
        *,
        beam_width: int = 8,
        nprobe: int = 8,
        query_chunk: int = 1024,
    ) -> tuple[np.ndarray, np.ndarray, dict]:
        """Returns (dists, ids, work)."""
        q = np.ascontiguousarray(queries, dtype=np.float32)
        if self.spec.kind in ("qlbt", "tree"):
            res = tree_mod.tree_search(
                self.tree.device_arrays(), jnp.asarray(self.db),
                jnp.asarray(q), kind=self.tree.kind, beam_width=beam_width,
                k=k, max_steps=self.tree.max_depth + 4,
            )
            work = {
                "internal_visits": int(np.asarray(res.internal_visits).sum()),
                "candidates": int(np.asarray(res.candidates).sum()),
                "steps_mean": float(np.asarray(res.steps).mean()),
            }
            return np.asarray(res.dists), np.asarray(res.ids), work
        d, i, work = self.two_level.search(
            q, k, nprobe=nprobe, beam_width=beam_width,
            query_chunk=query_chunk,
        )
        return d, i, work

    def footprint_bytes(self, include_db: bool = True) -> int:
        tot = self.db.nbytes if include_db else 0
        if self.tree is not None:
            tot += self.tree.footprint_bytes()
        if self.two_level is not None:
            tot += self.two_level.footprint_bytes(include_db=False)
        return tot

    def rebuild_with_likelihood(self, p: np.ndarray, *, seed: int = 0):
        """Paper §3.1: 'if only this distribution changes, a new search
        tree can be easily built, keeping other configurations the same'
        — the personalization path.  Rebuilds the QLBT in place from the
        stored vectors and the new traffic estimate; no effect on
        two-level indexes (their buckets don't depend on p)."""
        if self.spec.kind not in ("qlbt", "tree"):
            return self
        self.tree = build_qlbt(self.db, p, seed=seed)
        self.spec = dataclasses.replace(self.spec, kind="qlbt")
        return self


def build_index(
    spec: IndexSpec,
    db: np.ndarray,
    *,
    p: Optional[np.ndarray] = None,
    partition_features: Optional[np.ndarray] = None,
    seed: int = 0,
) -> SearchIndex:
    db = np.ascontiguousarray(db, dtype=np.float32)
    if spec.kind == "qlbt":
        if p is None:
            raise ValueError("QLBT requires a query-likelihood vector p")
        t = build_qlbt(db, p, seed=seed)
        return SearchIndex(spec=spec, db=db, tree=t)
    if spec.kind == "tree":
        return SearchIndex(spec=spec, db=db, tree=build_rp_tree(db, seed=seed))
    if spec.kind == "two_level":
        tl = build_two_level(
            db, spec.two_level, p=p, partition_features=partition_features
        )
        return SearchIndex(spec=spec, db=db, two_level=tl)
    raise ValueError(f"unknown index kind {spec.kind!r}")


def auto_build_index(
    db: np.ndarray,
    *,
    p: Optional[np.ndarray] = None,
    partition_features: Optional[np.ndarray] = None,
    seed: int = 0,
) -> SearchIndex:
    """Apply the paper's §5.3 protocol end-to-end."""
    part_dim = (
        partition_features.shape[1]
        if partition_features is not None
        else None
    )
    spec = select_index_spec(
        db.shape[0],
        traffic_available=p is not None,
        partition_dim=part_dim,
        embedding_dim=db.shape[1],
    )
    return build_index(
        spec, db, p=p, partition_features=partition_features, seed=seed
    )
