"""Radius-graph construction with the paper's two-level machinery.

SchNet (and molecular GNNs generally) need a neighbor list within a cutoff
radius.  Building it is literally a nearest-neighbor search — the paper's
bucketed two-level scan applies directly (DESIGN.md §5): k-means the atom
positions into buckets, probe each atom's nearest buckets, keep pairs
within the cutoff.  Brute fallback for small systems.
"""
from __future__ import annotations

import numpy as np

from repro.core.kmeans import _assign_topm, kmeans_fit

__all__ = ["radius_graph"]


def radius_graph(
    positions: np.ndarray,
    cutoff: float,
    *,
    max_neighbors: int | None = None,
    method: str = "auto",
    n_buckets: int | None = None,
    nprobe: int = 8,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (senders, receivers) int32 edge lists, i != j, |xi-xj|<=cutoff.

    method: "brute" | "two_level" | "auto" (two_level for n > 4096).
    """
    pos = np.ascontiguousarray(positions, dtype=np.float32)
    n = pos.shape[0]
    if method == "auto":
        method = "two_level" if n > 4096 else "brute"
    if method == "brute":
        d2 = ((pos[:, None, :] - pos[None, :, :]) ** 2).sum(-1)
        np.fill_diagonal(d2, np.inf)
        src, dst = np.nonzero(d2 <= cutoff * cutoff)
        return _cap(src, dst, d2, max_neighbors, n)

    k = n_buckets or max(8, n // 128)
    km = kmeans_fit(pos, k, iters=8, seed=seed)
    # candidate buckets per atom
    top_b, _ = _assign_topm(pos, km.centroids, min(nprobe, k))
    # bucket membership lists
    order = np.argsort(km.assignments, kind="stable")
    counts = np.bincount(km.assignments, minlength=k)
    offsets = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    members = order.astype(np.int32)

    srcs, dsts = [], []
    c2 = cutoff * cutoff
    for i in range(n):
        cand = np.concatenate(
            [members[offsets[b] : offsets[b + 1]] for b in top_b[i]]
        )
        cand = cand[cand != i]
        d2 = ((pos[cand] - pos[i]) ** 2).sum(-1)
        keep = d2 <= c2
        cand, d2 = cand[keep], d2[keep]
        if max_neighbors is not None and cand.size > max_neighbors:
            sel = np.argsort(d2)[:max_neighbors]
            cand = cand[sel]
        srcs.append(np.full(cand.size, i, dtype=np.int32))
        dsts.append(cand)
    src = np.concatenate(srcs) if srcs else np.zeros(0, np.int32)
    dst = np.concatenate(dsts) if dsts else np.zeros(0, np.int32)
    return src, dst


def _cap(src, dst, d2, max_neighbors, n):
    if max_neighbors is None:
        return src.astype(np.int32), dst.astype(np.int32)
    out_s, out_d = [], []
    for i in range(n):
        m = src == i
        di = d2[i, dst[m]]
        keep = np.argsort(di)[:max_neighbors]
        out_s.append(np.full(keep.size, i, dtype=np.int32))
        out_d.append(dst[m][keep].astype(np.int32))
    return np.concatenate(out_s), np.concatenate(out_d)
