# The paper's primary contribution: QLBT (tree.py), two-level approximate
# search (two_level.py), the §5.3 configuration protocol (protocol.py), and
# the mesh-sharded datacenter extension (distributed.py).
from repro.core.delta import DeltaManifest
from repro.core.index import SearchIndex, auto_build_index, build_index
from repro.core.lexical import (
    LexicalSlabs,
    build_lexical_slabs,
    query_operands,
)
from repro.core.likelihood import (
    beta_for_unbalance,
    simulate_beta_likelihood,
    unbalance_score,
)
from repro.core.metadata import FilterSpec, MetadataTable
from repro.core.protocol import IndexSpec, select_index_spec
from repro.core.tree import build_kd_tree, build_qlbt, build_rp_tree, tree_search
from repro.core.two_level import TwoLevelConfig, TwoLevelIndex, build_two_level

__all__ = [
    "DeltaManifest",
    "SearchIndex", "auto_build_index", "build_index",
    "LexicalSlabs", "build_lexical_slabs", "query_operands",
    "beta_for_unbalance", "simulate_beta_likelihood", "unbalance_score",
    "FilterSpec", "MetadataTable",
    "IndexSpec", "select_index_spec",
    "build_kd_tree", "build_qlbt", "build_rp_tree", "tree_search",
    "TwoLevelConfig", "TwoLevelIndex", "build_two_level",
]
