"""dlrm-mlperf [recsys] n_dense=13 n_sparse=26 embed_dim=128
bot_mlp=13-512-256-128 top_mlp=1024-1024-512-256-1 interaction=dot —
MLPerf DLRM benchmark config (Criteo 1TB)  [arXiv:1906.00091; paper]"""
from repro.configs.base import DLRMConfig

CONFIG = DLRMConfig(name="dlrm-mlperf")
FAMILY = "recsys"
