"""Config dataclasses for every architecture family.

Full configs are exercised only through the dry-run (ShapeDtypeStruct
lowering); every arch also defines ``reduced()`` — a same-family shrink for
CPU smoke tests (few layers, tiny tables/graphs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = [
    "MoEConfig", "MLAConfig", "LMConfig", "SchNetConfig",
    "DLRMConfig", "DCNConfig", "DINConfig", "SASRecConfig",
    "AnnConfig", "ShapeSpec",
]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell (name + kind + dims)."""
    name: str
    kind: str            # train | prefill | decode | serve | retrieval | ...
    dims: dict

    def __getitem__(self, k):
        return self.dims[k]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                       # per-expert intermediate
    n_shared: int = 1
    n_experts_padded: int = 0       # 0 = no padding; launcher may pad for EP
    capacity_factor: float = 1.25
    routed_scaling: float = 2.5     # DeepSeek-V3 gate scale
    score_fn: str = "sigmoid"       # sigmoid (V3) | softmax (classic)

    @property
    def e_pad(self) -> int:
        return self.n_experts_padded or self.n_experts


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    attn_kind: str = "gqa"          # gqa | mla
    qk_norm: bool = False
    mlp_kind: str = "swiglu"        # swiglu | gelu
    rope_theta: float = 1e6
    moe: Optional[MoEConfig] = None
    n_dense_layers: int = 0         # leading dense layers in MoE models
    mla: Optional[MLAConfig] = None
    mtp: bool = False               # DeepSeek-V3 multi-token prediction
    tie_embeddings: bool = False
    param_dtype: str = "float32"    # giants use bfloat16 (DESIGN.md §4)
    # --- distribution knobs (overridden by the launcher per mesh) ---
    attn_shard: str = "heads"       # heads | seq (when n_heads % tp != 0)
    moe_groups: int = 1             # data-parallel dispatch groups
    attn_chunk: int = 0             # 0 = dense; else KV block size
    scan_layers: bool = True
    remat: bool = True
    residual_dtype: str = "float32"  # bfloat16 halves TP all-reduce bytes
    #                                  + scan-carry memory (§Perf lever)
    grad_accum: int = 1             # microbatches per step (activation
    #                                 memory / accum; giants use 4)

    @property
    def n_moe_layers(self) -> int:
        return self.n_layers - self.n_dense_layers if self.moe else 0

    def reduced(self) -> "LMConfig":
        moe = self.moe
        if moe is not None:
            moe = dataclasses.replace(
                moe, n_experts=8, n_experts_padded=8, top_k=2, d_ff=64,
            )
        return dataclasses.replace(
            self, n_layers=2 if not self.moe else 3, d_model=64,
            n_heads=4, n_kv_heads=min(self.n_kv_heads, 2),
            d_head=16, d_ff=128, vocab=512, moe=moe, n_dense_layers=1
            if self.moe else 0,
            mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                          qk_nope_head_dim=16, qk_rope_head_dim=8,
                          v_head_dim=16) if self.mla else None,
            param_dtype="float32", moe_groups=1, attn_chunk=0,
        )


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    d_feat: int = 128               # input node-feature dim (shape-specific)
    n_out: int = 1                  # regression targets or classes
    message_dtype: str = "float32"  # bfloat16 halves the per-interaction
    #                                 node-aggregate all-reduce (§Perf)

    def reduced(self) -> "SchNetConfig":
        return dataclasses.replace(self, n_interactions=2, d_hidden=32,
                                   n_rbf=16)


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-mlperf"
    n_dense: int = 13
    embed_dim: int = 128
    bot_mlp: tuple = (512, 256, 128)
    top_mlp: tuple = (1024, 1024, 512, 256, 1)
    # MLPerf Criteo-Terabyte per-table row counts (26 tables)
    table_sizes: tuple = (
        39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
        2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
        25641295, 39664984, 585935, 12972, 108, 36,
    )
    interaction: str = "dot"

    @property
    def n_sparse(self) -> int:
        return len(self.table_sizes)

    def reduced(self) -> "DLRMConfig":
        return dataclasses.replace(
            self, embed_dim=16, bot_mlp=(32, 16), top_mlp=(32, 16, 1),
            table_sizes=tuple([100, 50, 200, 30]),
        )


@dataclasses.dataclass(frozen=True)
class DCNConfig:
    name: str = "dcn-v2"
    n_dense: int = 13
    embed_dim: int = 16
    n_cross_layers: int = 3
    mlp: tuple = (1024, 1024, 512)
    table_sizes: tuple = (
        39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
        2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
        25641295, 39664984, 585935, 12972, 108, 36,
    )
    interaction: str = "cross"

    @property
    def n_sparse(self) -> int:
        return len(self.table_sizes)

    def reduced(self) -> "DCNConfig":
        return dataclasses.replace(
            self, embed_dim=8, n_cross_layers=2, mlp=(32, 16),
            table_sizes=tuple([100, 50, 200, 30]),
        )


@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    n_items: int = 1_000_000
    n_cates: int = 10_000
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: tuple = (80, 40)
    mlp: tuple = (200, 80)
    interaction: str = "target-attn"

    def reduced(self) -> "DINConfig":
        return dataclasses.replace(self, n_items=1000, n_cates=50,
                                   embed_dim=8, seq_len=10)


@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    n_items: int = 1_000_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    interaction: str = "self-attn-seq"

    def reduced(self) -> "SASRecConfig":
        return dataclasses.replace(self, n_items=1000, embed_dim=16,
                                   seq_len=8)


@dataclasses.dataclass(frozen=True)
class AnnConfig:
    """The paper's own serving configs (radio/sift/deep)."""
    name: str
    n: int
    d: int
    n_clusters: int
    top: str = "pq"
    bottom: str = "brute"
    nprobe: int = 32

    def reduced(self) -> "AnnConfig":
        return dataclasses.replace(self, n=2000, n_clusters=32)
