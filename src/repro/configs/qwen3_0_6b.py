"""qwen3-0.6b [dense] 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936 — qk_norm, GQA  [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="qwen3-0.6b",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,              # qwen3 family uses head_dim 128 (q: 1024->2048)
    d_ff=3072,
    vocab=151936,
    qk_norm=True,
    mlp_kind="swiglu",
    rope_theta=1e6,
    tie_embeddings=True,     # qwen3-0.6b ties embed/unembed
    attn_shard="heads",      # 16 % 16 == 0
)
FAMILY = "lm"
