"""deepseek-v3-671b [moe] 61L d_model=7168 128H d_ff=2048(experts)
vocab=129280, MoE 256e top-8 — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437; hf]

First 3 layers are dense FFN (d_ff=18432); MLA dims per the V3 report.
Trains with bf16 params + Adafactor so optimizer state fits 16 GB/chip on
the 256/512-chip meshes (DESIGN.md §4).
"""
from repro.configs.base import LMConfig, MLAConfig, MoEConfig

CONFIG = LMConfig(
    name="deepseek-v3-671b",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,          # MLA: per-head latent KV (cache is shared)
    d_head=128,
    d_ff=18432,              # dense (first-3) layers
    vocab=129280,
    attn_kind="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff=2048, n_shared=1,
                  n_experts_padded=256, capacity_factor=1.25,
                  routed_scaling=2.5, score_fn="sigmoid"),
    n_dense_layers=3,
    mtp=True,
    rope_theta=1e4,
    param_dtype="bfloat16",
    attn_shard="heads",      # 128 % 16 == 0
    grad_accum=4,            # microbatching: activation memory /4
    residual_dtype="bfloat16",  # halves TP all-reduce + carry bytes (§Perf)
)
FAMILY = "lm"
