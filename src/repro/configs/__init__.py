from repro.configs.registry import ARCHS, SHAPES, get_arch, get_shapes

__all__ = ["ARCHS", "SHAPES", "get_arch", "get_shapes"]
