"""qwen3-14b [dense] 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936 — qk_norm, GQA  [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="qwen3-14b",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=17408,
    vocab=151936,
    qk_norm=True,
    mlp_kind="swiglu",
    rope_theta=1e6,
    # 40 heads % 16 mesh != 0 -> sequence-sharded attention (DESIGN.md §4)
    attn_shard="seq",
    residual_dtype="bfloat16",  # halves TP all-reduce + carry bytes (§Perf)
)
FAMILY = "lm"
