"""dcn-v2 [recsys] n_dense=13 n_sparse=26 embed_dim=16 n_cross_layers=3
mlp=1024-1024-512 interaction=cross  [arXiv:2008.13535; paper]"""
from repro.configs.base import DCNConfig

CONFIG = DCNConfig(name="dcn-v2")
FAMILY = "recsys"
