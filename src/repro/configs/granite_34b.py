"""granite-34b [dense] 88L d_model=6144 48H (GQA kv=1) d_ff=24576
vocab=49152 — llama-arch, code  [arXiv:2405.04324; hf]

Granite-34B-Code is MQA (kv=1) with a 2-matrix GELU MLP — with a gated
3-matrix MLP the listed dims would give ~46B params, with GELU they give
~34B, matching the model card.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="granite-34b",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_head=128,
    d_ff=24576,
    vocab=49152,
    qk_norm=False,
    mlp_kind="gelu",
    rope_theta=1e5,
    attn_shard="heads",      # 48 % 16 == 0
    grad_accum=2,            # 88-layer carry stack: activation memory /2
    residual_dtype="bfloat16",  # halves TP all-reduce + carry bytes (§Perf)
)
FAMILY = "lm"
