"""kimi-k2-1t-a32b [moe] 61L d_model=7168 64H d_ff=2048(experts)
vocab=163840, MoE 384e top-8 — trillion-param MoE (paper-table)
[arXiv:2501.kimi2; unverified]

DeepSeek-V3-style MLA MoE with 64 heads and 384 routed experts; first layer
dense.  384 experts are padded to 512 for 256-way expert parallelism
(DESIGN.md §4 — dummy experts receive no tokens; the FLOP overhead shows up
in the roofline MODEL_FLOPS ratio).
"""
from repro.configs.base import LMConfig, MLAConfig, MoEConfig

CONFIG = LMConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=64,
    d_head=128,
    d_ff=18432,              # dense (first) layer
    vocab=163840,
    attn_kind="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(n_experts=384, top_k=8, d_ff=2048, n_shared=1,
                  n_experts_padded=512, capacity_factor=1.25,
                  routed_scaling=2.5, score_fn="sigmoid"),
    n_dense_layers=1,
    mtp=False,
    rope_theta=5e4,
    param_dtype="bfloat16",
    attn_shard="heads",      # 64 % 16 == 0
    grad_accum=4,            # microbatching: activation memory /4
    residual_dtype="bfloat16",  # halves TP all-reduce + carry bytes (§Perf)
)
FAMILY = "lm"
