"""schnet [gnn] n_interactions=3 d_hidden=64 rbf=300 cutoff=10
[arXiv:1706.08566; paper]"""
from repro.configs.base import SchNetConfig

CONFIG = SchNetConfig(
    name="schnet",
    n_interactions=3,
    d_hidden=64,
    n_rbf=300,
    cutoff=10.0,
)
FAMILY = "gnn"
