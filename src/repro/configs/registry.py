"""Architecture + shape registry: ``--arch <id> --shape <name>``.

40 assigned cells = 10 archs x their family's 4 shapes.  ``long_500k`` is a
*listed skip* for the five full-attention LM archs (DESIGN.md §5).  The
paper's own ANN corpora are registered additionally under family "ann".
"""
from __future__ import annotations

import importlib

from repro.configs.base import ShapeSpec

_ARCH_MODULES = {
    "qwen3-14b": "repro.configs.qwen3_14b",
    "granite-34b": "repro.configs.granite_34b",
    "qwen3-0.6b": "repro.configs.qwen3_0_6b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2",
    "schnet": "repro.configs.schnet",
    "din": "repro.configs.din",
    "dlrm-mlperf": "repro.configs.dlrm_mlperf",
    "sasrec": "repro.configs.sasrec",
    "dcn-v2": "repro.configs.dcn_v2",
}

_ANN_ARCHS = {"radio-station": "RADIO_STATION", "sift-1m": "SIFT_1M",
              "deep-10m": "DEEP_10M"}

ARCHS = tuple(_ARCH_MODULES) + tuple(_ANN_ARCHS)

SHAPES = {
    "lm": [
        ShapeSpec("train_4k", "train", dict(seq=4096, batch=256)),
        ShapeSpec("prefill_32k", "prefill", dict(seq=32768, batch=32)),
        ShapeSpec("decode_32k", "decode", dict(seq=32768, batch=128)),
        # needs sub-quadratic attention; all 5 LM archs are full softmax
        # attention -> listed skip (DESIGN.md §5)
        ShapeSpec("long_500k", "decode",
                  dict(seq=524288, batch=1, subquadratic_required=True)),
    ],
    "gnn": [
        ShapeSpec("full_graph_sm", "train",
                  dict(n_nodes=2708, n_edges=10556, d_feat=1433)),
        ShapeSpec("minibatch_lg", "train",
                  dict(n_nodes=232965, n_edges=114615892,
                       batch_nodes=1024, fanout=(15, 10), d_feat=602)),
        ShapeSpec("ogb_products", "train",
                  dict(n_nodes=2449029, n_edges=61859140, d_feat=100)),
        ShapeSpec("molecule", "train",
                  dict(n_nodes=30, n_edges=64, batch=128, d_feat=16)),
    ],
    "recsys": [
        ShapeSpec("train_batch", "train", dict(batch=65536)),
        ShapeSpec("serve_p99", "serve", dict(batch=512)),
        ShapeSpec("serve_bulk", "serve", dict(batch=262144)),
        ShapeSpec("retrieval_cand", "retrieval",
                  dict(batch=1, n_candidates=1_000_000)),
    ],
    "ann": [
        ShapeSpec("serve_edge", "serve", dict(batch=16, k=10)),
        ShapeSpec("serve_batch", "serve", dict(batch=1024, k=10)),
        ShapeSpec("serve_bulk", "serve", dict(batch=16384, k=10)),
    ],
}


def get_arch(arch_id: str):
    """Returns (config, family) for an arch id."""
    if arch_id in _ANN_ARCHS:
        mod = importlib.import_module("repro.configs.ann_corpora")
        return getattr(mod, _ANN_ARCHS[arch_id]), "ann"
    if arch_id not in _ARCH_MODULES:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(ARCHS)}"
        )
    mod = importlib.import_module(_ARCH_MODULES[arch_id])
    return mod.CONFIG, mod.FAMILY


def get_shapes(family: str):
    return SHAPES[family]


def get_shape(family: str, name: str) -> ShapeSpec:
    for s in SHAPES[family]:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r} for family {family!r}")


def iter_cells(include_ann: bool = False):
    """Yield (arch_id, config, family, ShapeSpec) for every assigned cell."""
    for arch_id in _ARCH_MODULES:
        cfg, family = get_arch(arch_id)
        for shape in SHAPES[family]:
            yield arch_id, cfg, family, shape
    if include_ann:
        for arch_id in _ANN_ARCHS:
            cfg, family = get_arch(arch_id)
            for shape in SHAPES[family]:
                yield arch_id, cfg, family, shape
