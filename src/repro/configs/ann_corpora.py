"""The paper's own serving corpora (§4.1) as arch configs.

radio-station : 10 K x 256  (private VA traffic; QLBT territory, <30 K)
sift-1m       : 1 M  x 128  (public SIFT; two-level PQ+brute, 2^13 buckets)
deep-10m      : 10 M x 96   (public DEEP subset; two-level, 2^15 buckets)
"""
from repro.configs.base import AnnConfig

RADIO_STATION = AnnConfig(name="radio-station", n=10_000, d=256,
                          n_clusters=128, top="brute", bottom="brute",
                          nprobe=8)
SIFT_1M = AnnConfig(name="sift-1m", n=1_000_000, d=128, n_clusters=8192,
                    top="pq", bottom="brute", nprobe=32)
DEEP_10M = AnnConfig(name="deep-10m", n=10_000_000, d=96, n_clusters=32768,
                     top="pq", bottom="brute", nprobe=32)
FAMILY = "ann"
