"""din [recsys] embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80
interaction=target-attn  [arXiv:1706.06978; paper]"""
from repro.configs.base import DINConfig

CONFIG = DINConfig(
    name="din",
    n_items=1_000_000,       # Alibaba-scale item vocabulary
    n_cates=10_000,
    embed_dim=18,
    seq_len=100,
    attn_mlp=(80, 40),
    mlp=(200, 80),
)
FAMILY = "recsys"
