"""sasrec [recsys] embed_dim=50 n_blocks=2 n_heads=1 seq_len=50
interaction=self-attn-seq  [arXiv:1808.09781; paper]"""
from repro.configs.base import SASRecConfig

CONFIG = SASRecConfig(
    name="sasrec",
    n_items=1_000_000,       # retrieval_cand scores 1M candidates
    embed_dim=50,
    n_blocks=2,
    n_heads=1,
    seq_len=50,
)
FAMILY = "recsys"
