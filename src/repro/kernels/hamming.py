"""Packed-bit Hamming distance + fused top-k Pallas TPU kernel.

The paper's footprint-reduced LSH bottom level (§3.2): sign-random-
projection codes packed 32 bits per int32 lane.  Distance = popcount(XOR).
The VPU has no popcount instruction; `common.popcount32` is the branch-free
SWAR sequence (4 shifts + 3 ands + 1 mul per lane).

Grid: (B_tiles, N_tiles), N innermost, running top-k as in `l2_topk`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (
    INF, merge_topk, pad_sentinel, popcount32, valid_operand,
)

DEFAULT_BQ = 256
DEFAULT_BN = 1024


def _kernel(q_ref, c_ref, v_ref, bd_ref, bi_ref, *, k: int, bn: int, n: int):
    step = pl.program_id(1)

    @pl.when(step == 0)
    def _init():
        bd_ref[...] = jnp.full_like(bd_ref, INF)
        bi_ref[...] = jnp.full_like(bi_ref, -1)

    q = q_ref[...]                                # (BQ, W) int32
    c = c_ref[...]                                # (BN, W) int32
    x = jnp.bitwise_xor(q[:, None, :], c[None, :, :])   # (BQ, BN, W)
    ham = popcount32(x).sum(axis=-1).astype(jnp.float32)

    ids = step * bn + jax.lax.broadcasted_iota(jnp.int32, ham.shape, 1)
    ham = jnp.where((ids < n) & (v_ref[...] != 0), ham, INF)

    new_d, new_i = merge_topk(bd_ref[...], bi_ref[...], ham, ids, k)
    bd_ref[...] = new_d
    bi_ref[...] = new_i


@functools.partial(jax.jit, static_argnames=("k", "bq", "bn", "interpret"))
def hamming_topk_pallas(
    qcodes: jnp.ndarray,       # (B, W) int32 packed
    codes: jnp.ndarray,        # (N, W) int32 packed
    k: int = 10,
    *,
    valid: jnp.ndarray | None = None,
    bq: int = DEFAULT_BQ,
    bn: int = DEFAULT_BN,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (hamming dists (B, k) ascending fp32, ids (B, k)).

    ``valid`` is an optional (N,) liveness mask (tombstones / filters);
    dead rows are unrankable.  ``k`` is clamped to N; impossible slots
    return the ``(inf, -1)`` sentinel (same contract as
    ``l2_topk_pallas``)."""
    B, W = qcodes.shape
    N = codes.shape[0]
    k_eff = min(k, N)
    bq = min(bq, max(8, B))
    bn = min(bn, max(8, N))
    grid_b = -(-B // bq)
    grid_n = -(-N // bn)
    qp = jnp.pad(qcodes, ((0, grid_b * bq - B), (0, 0)))
    cp = jnp.pad(codes, ((0, grid_n * bn - N), (0, 0)))
    vp = valid_operand(valid, N, grid_n * bn)

    out = pl.pallas_call(
        functools.partial(_kernel, k=k_eff, bn=bn, n=N),
        grid=(grid_b, grid_n),
        in_specs=[
            pl.BlockSpec((bq, W), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, W), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k_eff), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k_eff), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid_b * bq, k_eff), jnp.float32),
            jax.ShapeDtypeStruct((grid_b * bq, k_eff), jnp.int32),
        ],
        interpret=interpret,
    )(qp, cp, vp)
    return pad_sentinel(out[0][:B], out[1][:B], k, k_eff)
