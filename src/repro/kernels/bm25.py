"""Fused BM25 lexical scan + hybrid (semantic ⊕ lexical) Pallas kernels.

The hybrid serving mode ranks by
``alpha * ||q - x||^2 - (1 - alpha) * bm25(q, x)`` — semantic L2 fused
with a BM25-ish lexical score in one streaming pass.  Documents carry
fixed-shape postings slabs (``repro.core.lexical``): ``terms`` (N, S)
int32 -1-padded and ``tf_sat`` (N, S) f32, the *saturated* tf factor
precomputed on the host, so the kernel only matches + weights + sums.

Per (query-tile × doc-tile) step the lexical score is a static loop over
the T query term slots (T is small, ~8): each slot broadcasts one term
id against the (BN, S) slab tile, masks, and contracts over S on the
VPU.  The semantic term rides the MXU exactly as in ``l2_topk``.

``alpha`` is a **(1, 1) operand, not a static argument** — sweeping the
semantic/lexical blend must not mint new executables (the recompile
gate covers the hybrid entry).  Grid, liveness (``valid``), clamp, and
``(inf, -1)`` sentinel contracts match ``l2_topk_pallas``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import INF, merge_topk, pad_sentinel, valid_operand

DEFAULT_BQ = 64
DEFAULT_BN = 256


def _lexical_tile(qt, qw, terms, tf_sat):
    """(BQ, BN) summed BM25 contributions of a doc tile.

    Static loop over the T query slots: slot t contributes
    ``idf_t * tf_sat[d, s]`` wherever ``terms[d, s] == q_term[b, t]``.
    The (t, then s) reduction order is shared with ``ref.bm25_dists_ref``
    so fused and unfused scores agree bitwise on CPU.
    """
    score = jnp.zeros((qt.shape[0], terms.shape[0]), jnp.float32)
    for t in range(qt.shape[1]):
        slot = qt[:, t]                                       # (BQ,)
        m = (terms[None, :, :] == slot[:, None, None]) & (
            slot[:, None, None] >= 0)                         # (BQ, BN, S)
        hit = jnp.sum(
            jnp.where(m, tf_sat[None, :, :], 0.0), axis=-1)   # (BQ, BN)
        score = score + hit * qw[:, t][:, None]
    return score


def _mask_tile(dist, v_ref, step, bn: int, n: int):
    ids = step * bn + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    live = (ids < n) & (v_ref[...] != 0)
    return jnp.where(live, dist, INF), ids


def _kernel_bm25(qt_ref, qw_ref, t_ref, f_ref, v_ref, bd_ref, bi_ref,
                 *, k: int, bn: int, n: int):
    step = pl.program_id(1)

    @pl.when(step == 0)
    def _init():
        bd_ref[...] = jnp.full_like(bd_ref, INF)
        bi_ref[...] = jnp.full_like(bi_ref, -1)

    score = _lexical_tile(qt_ref[...], qw_ref[...].astype(jnp.float32),
                          t_ref[...], f_ref[...].astype(jnp.float32))
    dist, ids = _mask_tile(-score, v_ref, step, bn, n)
    new_d, new_i = merge_topk(bd_ref[...], bi_ref[...], dist, ids, k)
    bd_ref[...] = new_d
    bi_ref[...] = new_i


def _kernel_hybrid(q_ref, x_ref, qt_ref, qw_ref, t_ref, f_ref, a_ref,
                   v_ref, bd_ref, bi_ref, *, k: int, bn: int, n: int):
    step = pl.program_id(1)

    @pl.when(step == 0)
    def _init():
        bd_ref[...] = jnp.full_like(bd_ref, INF)
        bi_ref[...] = jnp.full_like(bi_ref, -1)

    q = q_ref[...].astype(jnp.float32)            # (BQ, D)
    x = x_ref[...].astype(jnp.float32)            # (BN, D)
    qn = jnp.sum(q * q, axis=1, keepdims=True)
    xn = jnp.sum(x * x, axis=1)
    dots = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    d2 = qn + xn[None, :] - 2.0 * dots            # (BQ, BN)
    score = _lexical_tile(qt_ref[...], qw_ref[...].astype(jnp.float32),
                          t_ref[...], f_ref[...].astype(jnp.float32))
    a = a_ref[0, 0]
    dist = a * d2 - (1.0 - a) * score
    dist, ids = _mask_tile(dist, v_ref, step, bn, n)
    new_d, new_i = merge_topk(bd_ref[...], bi_ref[...], dist, ids, k)
    bd_ref[...] = new_d
    bi_ref[...] = new_i


def _grid(bsz, n, bq, bn):
    bq = min(bq, max(8, bsz))
    bn = min(bn, max(8, n))
    return bq, bn, -(-bsz // bq), -(-n // bn)


@functools.partial(jax.jit, static_argnames=("k", "bq", "bn", "interpret"))
def bm25_topk_pallas(
    q_terms: jnp.ndarray,        # (B, T) int32, -1 padded
    q_weights: jnp.ndarray,      # (B, T) f32 idf weights
    terms: jnp.ndarray,          # (N, S) int32, -1 padded
    tf_sat: jnp.ndarray,         # (N, S) f32 saturated tf
    k: int = 10,
    *,
    valid: jnp.ndarray | None = None,
    bq: int = DEFAULT_BQ,
    bn: int = DEFAULT_BN,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (ranking dists = -bm25 (B, k) ascending, ids (B, k))."""
    B, T = q_terms.shape
    N, S = terms.shape
    k_eff = min(k, N)
    bq, bn, grid_b, grid_n = _grid(B, N, bq, bn)
    qtp = jnp.pad(q_terms, ((0, grid_b * bq - B), (0, 0)),
                  constant_values=-1)
    qwp = jnp.pad(q_weights, ((0, grid_b * bq - B), (0, 0)))
    tp = jnp.pad(terms, ((0, grid_n * bn - N), (0, 0)),
                 constant_values=-1)
    fp = jnp.pad(tf_sat, ((0, grid_n * bn - N), (0, 0)))
    vp = valid_operand(valid, N, grid_n * bn)

    out = pl.pallas_call(
        functools.partial(_kernel_bm25, k=k_eff, bn=bn, n=N),
        grid=(grid_b, grid_n),
        in_specs=[
            pl.BlockSpec((bq, T), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, T), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, S), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, S), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k_eff), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k_eff), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid_b * bq, k_eff), jnp.float32),
            jax.ShapeDtypeStruct((grid_b * bq, k_eff), jnp.int32),
        ],
        interpret=interpret,
    )(qtp, qwp, tp, fp, vp)
    return pad_sentinel(out[0][:B], out[1][:B], k, k_eff)


@functools.partial(jax.jit, static_argnames=("k", "bq", "bn", "interpret"))
def hybrid_topk_pallas(
    queries: jnp.ndarray,        # (B, D) f32
    db: jnp.ndarray,             # (N, D) f32
    q_terms: jnp.ndarray,        # (B, T) int32
    q_weights: jnp.ndarray,      # (B, T) f32
    terms: jnp.ndarray,          # (N, S) int32
    tf_sat: jnp.ndarray,         # (N, S) f32
    alpha: jnp.ndarray,          # (1, 1) f32 blend — operand, not static
    k: int = 10,
    *,
    valid: jnp.ndarray | None = None,
    bq: int = DEFAULT_BQ,
    bn: int = DEFAULT_BN,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused ``alpha * l2sq - (1 - alpha) * bm25`` top-k."""
    B, D = queries.shape
    N = db.shape[0]
    T = q_terms.shape[1]
    S = terms.shape[1]
    k_eff = min(k, N)
    bq, bn, grid_b, grid_n = _grid(B, N, bq, bn)
    qp = jnp.pad(queries, ((0, grid_b * bq - B), (0, 0)))
    xp = jnp.pad(db, ((0, grid_n * bn - N), (0, 0)))
    qtp = jnp.pad(q_terms, ((0, grid_b * bq - B), (0, 0)),
                  constant_values=-1)
    qwp = jnp.pad(q_weights, ((0, grid_b * bq - B), (0, 0)))
    tp = jnp.pad(terms, ((0, grid_n * bn - N), (0, 0)),
                 constant_values=-1)
    fp = jnp.pad(tf_sat, ((0, grid_n * bn - N), (0, 0)))
    ap = jnp.asarray(alpha, jnp.float32).reshape(1, 1)
    vp = valid_operand(valid, N, grid_n * bn)

    out = pl.pallas_call(
        functools.partial(_kernel_hybrid, k=k_eff, bn=bn, n=N),
        grid=(grid_b, grid_n),
        in_specs=[
            pl.BlockSpec((bq, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, D), lambda i, j: (j, 0)),
            pl.BlockSpec((bq, T), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, T), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, S), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, S), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k_eff), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k_eff), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid_b * bq, k_eff), jnp.float32),
            jax.ShapeDtypeStruct((grid_b * bq, k_eff), jnp.int32),
        ],
        interpret=interpret,
    )(qp, xp, qtp, qwp, tp, fp, ap, vp)
    return pad_sentinel(out[0][:B], out[1][:B], k, k_eff)
