"""Fused L2-distance + streaming top-k Pallas TPU kernel.

The paper's hot loop: brute-force scan of probed buckets / small corpora
(§5.2 found brute the best bottom level at ~100-entity buckets).  On TPU
the scan is an MXU matmul per (query-tile x db-tile) using the expansion
``||q - x||^2 = ||q||^2 - 2 q.x + ||x||^2`` with the running top-k held in
the revisited output block (sequential innermost grid dim).

Grid: (B_tiles, N_tiles), N innermost.  VMEM per step:
  q tile (BQ, D) + x tile (BN, D) + dist tile (BQ, BN) + best (BQ, K)*2
e.g. BQ=256, BN=512, D=128 fp32 ~ (128 + 256 + 512) KiB * 4 -> well under
the ~16 MiB VMEM budget; BN is the tuning knob for arithmetic intensity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import INF, merge_topk

DEFAULT_BQ = 256
DEFAULT_BN = 512


def _kernel(q_ref, x_ref, bd_ref, bi_ref, *, k: int, bn: int, n: int):
    step = pl.program_id(1)

    @pl.when(step == 0)
    def _init():
        bd_ref[...] = jnp.full_like(bd_ref, INF)
        bi_ref[...] = jnp.full_like(bi_ref, -1)

    q = q_ref[...].astype(jnp.float32)            # (BQ, D)
    x = x_ref[...].astype(jnp.float32)            # (BN, D)

    qn = jnp.sum(q * q, axis=1, keepdims=True)    # (BQ, 1)
    xn = jnp.sum(x * x, axis=1)                   # (BN,)
    # MXU: (BQ, D) @ (D, BN)
    dots = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    d2 = qn + xn[None, :] - 2.0 * dots            # (BQ, BN)

    ids = step * bn + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    d2 = jnp.where(ids < n, d2, INF)           # mask grid padding rows

    new_d, new_i = merge_topk(bd_ref[...], bi_ref[...], d2, ids, k)
    bd_ref[...] = new_d
    bi_ref[...] = new_i


@functools.partial(
    jax.jit, static_argnames=("k", "bq", "bn", "interpret")
)
def l2_topk_pallas(
    queries: jnp.ndarray,
    db: jnp.ndarray,
    k: int = 10,
    *,
    bq: int = DEFAULT_BQ,
    bn: int = DEFAULT_BN,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (dists (B, k) ascending fp32, ids (B, k) int32)."""
    B, D = queries.shape
    N = db.shape[0]
    bq = min(bq, max(8, B))
    bn = min(bn, max(8, N))
    grid_b = -(-B // bq)
    grid_n = -(-N // bn)
    qp = jnp.pad(queries, ((0, grid_b * bq - B), (0, 0)))
    xp = jnp.pad(db, ((0, grid_n * bn - N), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, k=k, bn=bn, n=N),
        grid=(grid_b, grid_n),
        in_specs=[
            pl.BlockSpec((bq, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, D), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid_b * bq, k), jnp.float32),
            jax.ShapeDtypeStruct((grid_b * bq, k), jnp.int32),
        ],
        interpret=interpret,
    )(qp, xp)
    return out[0][:B], out[1][:B]
