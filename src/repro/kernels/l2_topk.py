"""Fused L2-distance + streaming top-k Pallas TPU kernel.

The paper's hot loop: brute-force scan of probed buckets / small corpora
(§5.2 found brute the best bottom level at ~100-entity buckets).  On TPU
the scan is an MXU matmul per (query-tile x db-tile) using the expansion
``||q - x||^2 = ||q||^2 - 2 q.x + ||x||^2`` with the running top-k held in
the revisited output block (sequential innermost grid dim).

Grid: (B_tiles, N_tiles), N innermost.  VMEM per step:
  q tile (BQ, D) + x tile (BN, D) + dist tile (BQ, BN) + best (BQ, K)*2
e.g. BQ=256, BN=512, D=128 fp32 ~ (128 + 256 + 512) KiB * 4 -> well under
the ~16 MiB VMEM budget; BN is the tuning knob for arithmetic intensity.

Liveness: every kernel takes a ``valid`` row mask (tombstoned / mutated
shards keep dead rows in place — see ``distributed/sharding.py``); dead
rows score +inf and can never outrank a live candidate.  Result slots
that never saw a live row return the ``(inf, -1)`` sentinel — callers
must treat id ``-1`` as "no candidate" (the `_rerank`-style consumers
mask it uniformly).  ``k`` is clamped to the db row count inside the
wrapper; the requested width is restored by sentinel padding.

``l2_topk_int8_pallas`` is the footprint variant: the db is stored as
int8 codes with one fp32 scale per row (4x less HBM traffic for the
dominant term of this bandwidth-bound scan), accumulated in fp32 on the
MXU via ``preferred_element_type``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import INF, merge_topk, pad_sentinel, valid_operand

DEFAULT_BQ = 256
DEFAULT_BN = 512


def _mask_tile(d2, v_ref, step, bn: int, n: int):
    """Grid pads (row id >= n) and dead rows (valid == 0) score +inf;
    returns (masked distances, global row ids) for the merge."""
    ids = step * bn + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    live = (ids < n) & (v_ref[...] != 0)
    return jnp.where(live, d2, INF), ids


def _kernel(q_ref, x_ref, v_ref, bd_ref, bi_ref, *, k: int, bn: int, n: int):
    step = pl.program_id(1)

    @pl.when(step == 0)
    def _init():
        bd_ref[...] = jnp.full_like(bd_ref, INF)
        bi_ref[...] = jnp.full_like(bi_ref, -1)

    q = q_ref[...].astype(jnp.float32)            # (BQ, D)
    x = x_ref[...].astype(jnp.float32)            # (BN, D)

    qn = jnp.sum(q * q, axis=1, keepdims=True)    # (BQ, 1)
    xn = jnp.sum(x * x, axis=1)                   # (BN,)
    # MXU: (BQ, D) @ (D, BN)
    dots = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    d2 = qn + xn[None, :] - 2.0 * dots            # (BQ, BN)
    d2, ids = _mask_tile(d2, v_ref, step, bn, n)

    new_d, new_i = merge_topk(bd_ref[...], bi_ref[...], d2, ids, k)
    bd_ref[...] = new_d
    bi_ref[...] = new_i


def _kernel_int8(q_ref, x_ref, s_ref, v_ref, bd_ref, bi_ref,
                 *, k: int, bn: int, n: int):
    step = pl.program_id(1)

    @pl.when(step == 0)
    def _init():
        bd_ref[...] = jnp.full_like(bd_ref, INF)
        bi_ref[...] = jnp.full_like(bi_ref, -1)

    q = q_ref[...].astype(jnp.float32)            # (BQ, D)
    x8 = x_ref[...]                               # (BN, D) int8
    s = s_ref[...][0]                             # (BN,) fp32 row scales

    # int8 codes ride the MXU with fp32 accumulation; the per-row scale
    # is applied to the *reduced* terms, so the cheap operand stays int8
    # all the way through the dominant (D-contraction) traffic
    xf = x8.astype(jnp.float32)
    qn = jnp.sum(q * q, axis=1, keepdims=True)    # (BQ, 1)
    xn8 = jnp.sum(xf * xf, axis=1)                # (BN,) code-space norms
    dots = jax.lax.dot_general(
        q, xf, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                             # (BQ, BN) code-space
    d2 = qn + (s * s * xn8)[None, :] - 2.0 * s[None, :] * dots
    d2, ids = _mask_tile(d2, v_ref, step, bn, n)

    new_d, new_i = merge_topk(bd_ref[...], bi_ref[...], d2, ids, k)
    bd_ref[...] = new_d
    bi_ref[...] = new_i


@functools.partial(
    jax.jit, static_argnames=("k", "bq", "bn", "interpret")
)
def l2_topk_pallas(
    queries: jnp.ndarray,
    db: jnp.ndarray,
    k: int = 10,
    *,
    valid: jnp.ndarray | None = None,
    bq: int = DEFAULT_BQ,
    bn: int = DEFAULT_BN,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (dists (B, k) ascending fp32, ids (B, k) int32).

    ``valid`` is an optional (N,) liveness mask (bool/int); dead rows are
    unrankable.  Slots beyond the live row count come back as the
    ``(inf, -1)`` sentinel — including the ``k > N`` case, which is
    clamped internally rather than erroring.
    """
    B, D = queries.shape
    N = db.shape[0]
    k_eff = min(k, N)
    bq = min(bq, max(8, B))
    bn = min(bn, max(8, N))
    grid_b = -(-B // bq)
    grid_n = -(-N // bn)
    qp = jnp.pad(queries, ((0, grid_b * bq - B), (0, 0)))
    xp = jnp.pad(db, ((0, grid_n * bn - N), (0, 0)))
    vp = valid_operand(valid, N, grid_n * bn)

    out = pl.pallas_call(
        functools.partial(_kernel, k=k_eff, bn=bn, n=N),
        grid=(grid_b, grid_n),
        in_specs=[
            pl.BlockSpec((bq, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, D), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k_eff), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k_eff), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid_b * bq, k_eff), jnp.float32),
            jax.ShapeDtypeStruct((grid_b * bq, k_eff), jnp.int32),
        ],
        interpret=interpret,
    )(qp, xp, vp)
    return pad_sentinel(out[0][:B], out[1][:B], k, k_eff)


@functools.partial(
    jax.jit, static_argnames=("k", "bq", "bn", "interpret")
)
def l2_topk_int8_pallas(
    queries: jnp.ndarray,
    db_codes: jnp.ndarray,       # (N, D) int8
    scales: jnp.ndarray,         # (N,) fp32 per-row dequant scale
    k: int = 10,
    *,
    valid: jnp.ndarray | None = None,
    bq: int = DEFAULT_BQ,
    bn: int = DEFAULT_BN,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """int8-footprint variant of :func:`l2_topk_pallas`: the db rows are
    int8 codes with a per-row fp32 scale (``row ~= scale * codes``); the
    contraction accumulates in fp32 (``preferred_element_type``).  Same
    clamp / ``valid`` / sentinel contract as the fp32 kernel."""
    B, D = queries.shape
    N = db_codes.shape[0]
    k_eff = min(k, N)
    bq = min(bq, max(8, B))
    bn = min(bn, max(8, N))
    grid_b = -(-B // bq)
    grid_n = -(-N // bn)
    qp = jnp.pad(queries.astype(jnp.float32), ((0, grid_b * bq - B), (0, 0)))
    xp = jnp.pad(db_codes, ((0, grid_n * bn - N), (0, 0)))
    sp = jnp.pad(scales.astype(jnp.float32),
                 (0, grid_n * bn - N))[None, :]
    vp = valid_operand(valid, N, grid_n * bn)

    out = pl.pallas_call(
        functools.partial(_kernel_int8, k=k_eff, bn=bn, n=N),
        grid=(grid_b, grid_n),
        in_specs=[
            pl.BlockSpec((bq, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, D), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k_eff), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k_eff), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid_b * bq, k_eff), jnp.float32),
            jax.ShapeDtypeStruct((grid_b * bq, k_eff), jnp.int32),
        ],
        interpret=interpret,
    )(qp, xp, sp, vp)
    return pad_sentinel(out[0][:B], out[1][:B], k, k_eff)
