"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

Each function computes the same result as its kernel with no tiling and no
Pallas — used by tests (`tests/test_kernels.py`) for allclose sweeps and by
`ops.py` as the CPU fallback.

Shared contract (matches the kernels): ``k`` is clamped to the candidate
count internally; slots with no live candidate come back as the
``(inf, -1)`` sentinel — callers treat id ``-1`` as "no candidate".  The
distance expansions are *exactly* the ones in ``core.brute``
(``pairwise_l2sq`` / ``batched_l2sq``), which is what keeps the fused
sharded path bitwise-identical to the unfused jnp path on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.brute import batched_l2sq, pairwise_l2sq
from repro.kernels.common import popcount32


def _finish(d2, k: int):
    """top_k + sentinel masking + clamp-restoring pad, shared by the
    shared-db oracles (ids are the scan positions)."""
    k_eff = min(k, d2.shape[1])
    neg, ids = jax.lax.top_k(-d2, k_eff)
    d = -neg
    ids = jnp.where(jnp.isinf(d), -1, ids.astype(jnp.int32))
    if k_eff < k:
        d = jnp.pad(d, ((0, 0), (0, k - k_eff)), constant_values=jnp.inf)
        ids = jnp.pad(ids, ((0, 0), (0, k - k_eff)), constant_values=-1)
    return d, ids


def _apply_valid(d2, valid):
    if valid is None:
        return d2
    live = jnp.asarray(valid).astype(jnp.int32) != 0
    return jnp.where(live[None, :], d2, jnp.inf)


def l2_topk_ref(queries, db, k: int = 10, *, valid=None):
    q = queries.astype(jnp.float32)
    x = db.astype(jnp.float32)
    d2 = pairwise_l2sq(q, x)
    return _finish(_apply_valid(d2, valid), k)


def l2_topk_int8_ref(queries, db_codes, scales, k: int = 10, *, valid=None):
    """Oracle for the int8-footprint scan: dequantized term-by-term the
    same way the kernel does (scale applied to the reduced terms)."""
    q = queries.astype(jnp.float32)
    xf = db_codes.astype(jnp.float32)
    s = scales.astype(jnp.float32)
    qn = jnp.sum(q * q, axis=1, keepdims=True)
    xn8 = jnp.sum(xf * xf, axis=1)
    d2 = qn + (s * s * xn8)[None, :] - 2.0 * s[None, :] * (q @ xf.T)
    return _finish(_apply_valid(d2, valid), k)


def candidate_topk_ref(queries, vecs, ids, k: int = 10,
                       *, best_d=None, best_i=None):
    """Oracle for `bucket_topk`: per-query candidate tiles, optional
    carried running best (the IVF probe-chain pattern).

    Uses ``batched_l2sq`` + ``lax.top_k`` — the literal ops of the
    unfused sharded IVF/forest locals — so the CPU dispatch of the fused
    path cannot drift from the unfused path by construction.
    """
    q = queries.astype(jnp.float32)
    v = vecs.astype(jnp.float32)
    ids = ids.astype(jnp.int32)
    d2 = jnp.where(ids >= 0, batched_l2sq(v, q), jnp.inf)
    if best_d is not None:
        cat_d = jnp.concatenate([best_d.astype(jnp.float32), d2], axis=1)
        cat_i = jnp.concatenate([best_i.astype(jnp.int32), ids], axis=1)
        neg, sel = jax.lax.top_k(-cat_d, k)
        d = -neg
        out_i = jnp.take_along_axis(cat_i, sel, axis=1)
    else:
        k_eff = min(k, ids.shape[1])
        neg, sel = jax.lax.top_k(-d2, k_eff)
        d = -neg
        out_i = jnp.take_along_axis(ids, sel, axis=1)
        if k_eff < k:
            d = jnp.pad(d, ((0, 0), (0, k - k_eff)),
                        constant_values=jnp.inf)
            out_i = jnp.pad(out_i, ((0, 0), (0, k - k_eff)),
                            constant_values=-1)
    return d, jnp.where(jnp.isinf(d), -1, out_i)


def pq_adc_topk_ref(lut, codes, k: int = 10, *, valid=None):
    lut = lut.astype(jnp.float32)
    c = codes.astype(jnp.int32)                    # (N, M)
    # scores[b, n] = sum_m lut[b, m, c[n, m]]
    g = jnp.take_along_axis(
        lut, c.T[None, :, :], axis=2
    )                                              # (B, M, N)
    scores = g.sum(axis=1)
    return _finish(_apply_valid(scores, valid), k)


def hamming_topk_ref(qcodes, codes, k: int = 10, *, valid=None):
    x = jnp.bitwise_xor(qcodes[:, None, :], codes[None, :, :])
    ham = popcount32(x).sum(-1).astype(jnp.float32)
    return _finish(_apply_valid(ham, valid), k)


def bm25_dists_ref(q_terms, q_weights, terms, tf_sat):
    """(B, N) BM25 ranking distances (``-score``), reduced in the same
    (term-slot, then doc-slot) order as the fused kernel's static loop —
    the order match is what keeps fused vs unfused bitwise on CPU."""
    qt = q_terms.astype(jnp.int32)
    qw = q_weights.astype(jnp.float32)
    t = terms.astype(jnp.int32)
    f = tf_sat.astype(jnp.float32)
    score = jnp.zeros((qt.shape[0], t.shape[0]), jnp.float32)
    for slot in range(qt.shape[1]):
        s = qt[:, slot]                                       # (B,)
        m = (t[None, :, :] == s[:, None, None]) & (
            s[:, None, None] >= 0)                            # (B, N, S)
        hit = jnp.sum(jnp.where(m, f[None, :, :], 0.0), axis=-1)
        score = score + hit * qw[:, slot][:, None]
    return -score


def bm25_topk_ref(q_terms, q_weights, terms, tf_sat, k: int = 10,
                  *, valid=None):
    """Oracle for the fused BM25 scan (dists = -score, ascending)."""
    dist = bm25_dists_ref(q_terms, q_weights, terms, tf_sat)
    return _finish(_apply_valid(dist, valid), k)


def hybrid_topk_ref(queries, db, q_terms, q_weights, terms, tf_sat,
                    alpha, k: int = 10, *, valid=None):
    """Oracle for the fused hybrid scan:
    ``alpha * l2sq - (1 - alpha) * bm25``, ``alpha`` a (1, 1) operand."""
    q = queries.astype(jnp.float32)
    x = db.astype(jnp.float32)
    d2 = pairwise_l2sq(q, x)
    score = -bm25_dists_ref(q_terms, q_weights, terms, tf_sat)
    a = jnp.asarray(alpha, jnp.float32).reshape(1, 1)
    dist = a * d2 - (1.0 - a) * score
    return _finish(_apply_valid(dist, valid), k)
