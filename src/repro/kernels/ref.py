"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

Each function computes the same result as its kernel with no tiling and no
Pallas — used by tests (`tests/test_kernels.py`) for allclose sweeps and by
`ops.py` as the CPU fallback.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import popcount32


def l2_topk_ref(queries, db, k: int = 10):
    q = queries.astype(jnp.float32)
    x = db.astype(jnp.float32)
    d2 = (
        jnp.sum(q * q, 1, keepdims=True)
        + jnp.sum(x * x, 1)[None, :]
        - 2.0 * (q @ x.T)
    )
    neg, ids = jax.lax.top_k(-d2, k)
    return -neg, ids.astype(jnp.int32)


def pq_adc_topk_ref(lut, codes, k: int = 10):
    lut = lut.astype(jnp.float32)
    c = codes.astype(jnp.int32)                    # (N, M)
    # scores[b, n] = sum_m lut[b, m, c[n, m]]
    g = jnp.take_along_axis(
        lut, c.T[None, :, :], axis=2
    )                                              # (B, M, N)
    scores = g.sum(axis=1)
    neg, ids = jax.lax.top_k(-scores, k)
    return -neg, ids.astype(jnp.int32)


def hamming_topk_ref(qcodes, codes, k: int = 10):
    x = jnp.bitwise_xor(qcodes[:, None, :], codes[None, :, :])
    ham = popcount32(x).sum(-1).astype(jnp.float32)
    neg, ids = jax.lax.top_k(-ham, k)
    return -neg, ids.astype(jnp.int32)
