"""Batched candidate-tile L2 + streaming top-k Pallas TPU kernel.

The gathered-candidate counterpart of `l2_topk`: each query row carries
its **own** candidate list — IVF probe steps score ``(B, C, d)`` bucket
gathers, the forest rerank scores leaf unions — so the contraction is a
batched matvec per query rather than one shared db matmul.  Fusing the
distance + merge here is what removes the materialized ``(B, C)``
distance matrix from the sharded IVF/forest locals.

Grid: (B_tiles, C_tiles), C innermost, running top-k in the revisited
output block.  The kernel optionally *continues* a running best list
(``best_d``/``best_i`` operands seed the step-0 state), which is how the
IVF ``lax.scan`` over probe steps chains one kernel launch per probe
without re-ranking from scratch.

Ids are caller-supplied (bucket slot ids / global entity ids), already
arbitrary-order; ``id < 0`` marks a dead candidate (empty bucket slot or
grid pad) and scores +inf.  Ties break on the (distance, id) pair (see
``common.merge_topk``); a candidate duplicated *with identical distance*
is emitted once, not twice — the jnp oracle used on the CPU dispatch
path keeps ``lax.top_k`` column-order semantics instead, which agree
whenever ids are distinct.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import INF, merge_topk

DEFAULT_BQ = 64
DEFAULT_BC = 256


def _kernel(q_ref, v_ref, i_ref, b0d_ref, b0i_ref, bd_ref, bi_ref,
            *, k: int):
    step = pl.program_id(1)

    @pl.when(step == 0)
    def _init():
        bd_ref[...] = b0d_ref[...]
        bi_ref[...] = b0i_ref[...]

    q = q_ref[...].astype(jnp.float32)            # (BQ, D)
    vecs = v_ref[...].astype(jnp.float32)         # (BQ, BC, D)
    ids = i_ref[...]                              # (BQ, BC) int32

    # same expansion as core.brute.batched_l2sq, batched on the MXU
    vn = jnp.sum(vecs * vecs, axis=-1)            # (BQ, BC)
    qn = jnp.sum(q * q, axis=-1, keepdims=True)   # (BQ, 1)
    dots = jax.lax.dot_general(
        vecs, q, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                             # (BQ, BC)
    d2 = vn - 2.0 * dots + qn
    d2 = jnp.where(ids >= 0, d2, INF)

    new_d, new_i = merge_topk(bd_ref[...], bi_ref[...], d2, ids, k)
    bd_ref[...] = new_d
    bi_ref[...] = new_i


@functools.partial(
    jax.jit, static_argnames=("k", "bq", "bc", "interpret")
)
def candidate_topk_pallas(
    queries: jnp.ndarray,        # (B, D)
    vecs: jnp.ndarray,           # (B, C, D) per-query candidate vectors
    ids: jnp.ndarray,            # (B, C) int32, < 0 = dead slot
    k: int = 10,
    *,
    best_d: jnp.ndarray | None = None,   # (B, k) carried running best
    best_i: jnp.ndarray | None = None,
    bq: int = DEFAULT_BQ,
    bc: int = DEFAULT_BC,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (dists (B, k) ascending fp32, ids (B, k) int32).

    When ``best_d``/``best_i`` are given the result is the merge of the
    carried list with the candidate tile (the IVF probe-chain pattern);
    otherwise the list starts from the ``(inf, -1)`` sentinel.  ``k``
    may exceed C — unfilled slots return the sentinel.
    """
    B, C, D = vecs.shape
    bq = min(bq, max(8, B))
    bc = min(bc, max(8, C))
    grid_b = -(-B // bq)
    grid_c = -(-C // bc)
    qp = jnp.pad(queries.astype(jnp.float32), ((0, grid_b * bq - B), (0, 0)))
    vp = jnp.pad(vecs, ((0, grid_b * bq - B), (0, grid_c * bc - C), (0, 0)))
    ip = jnp.pad(ids.astype(jnp.int32),
                 ((0, grid_b * bq - B), (0, grid_c * bc - C)),
                 constant_values=-1)
    # repro: allow(missing-static-argnames): branches on operand PRESENCE (None vs array) — pytree structure jit already specializes on; static_argnames would reject array operands
    if best_d is None:
        b0d = jnp.full((grid_b * bq, k), INF, jnp.float32)
        b0i = jnp.full((grid_b * bq, k), -1, jnp.int32)
    else:
        b0d = jnp.pad(best_d.astype(jnp.float32),
                      ((0, grid_b * bq - B), (0, 0)), constant_values=INF)
        b0i = jnp.pad(best_i.astype(jnp.int32),
                      ((0, grid_b * bq - B), (0, 0)), constant_values=-1)

    out = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(grid_b, grid_c),
        in_specs=[
            pl.BlockSpec((bq, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, bc, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bq, bc), lambda i, j: (i, j)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid_b * bq, k), jnp.float32),
            jax.ShapeDtypeStruct((grid_b * bq, k), jnp.int32),
        ],
        interpret=interpret,
    )(qp, vp, ip, b0d, b0i)
    return out[0][:B], out[1][:B]
