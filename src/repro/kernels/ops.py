"""Public jit'd wrappers for the ANN kernels.

Dispatch policy: on TPU backends call the Pallas kernel compiled natively;
on CPU (this container) call the pure-jnp oracle by default — identical
results, XLA-optimized — or the Pallas kernel in interpret mode when
``force_pallas=True`` (used by tests to execute the real kernel body).

All ops share the kernel result contract: ``k`` is clamped internally to
the candidate count, dead rows (``valid == 0`` / ``id < 0``) never rank,
and unfilled slots return the ``(inf, -1)`` sentinel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import bm25, bucket_topk, hamming, l2_topk, pq_adc, ref

__all__ = [
    "l2_topk_op",
    "l2_topk_int8_op",
    "candidate_topk_op",
    "pq_adc_topk_op",
    "hamming_topk_op",
    "bm25_topk_op",
    "hybrid_topk_op",
    "quantize_rows_int8",
]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _tiles(bq=None, bn=None, bc=None):
    kw = {}
    if bq:
        kw["bq"] = bq
    if bn:
        kw["bn"] = bn
    if bc:
        kw["bc"] = bc
    return kw


def quantize_rows_int8(db) -> tuple[np.ndarray, np.ndarray]:
    """Per-row symmetric int8 quantization: ``row ~= scale * codes``.

    Returns (codes (N, D) int8, scales (N,) float32).  Host-side (numpy)
    — used at placement time; all-zero rows get scale 1.0 so the
    dequantized row is exactly zero.
    """
    x = np.asarray(db, dtype=np.float32)
    amax = np.max(np.abs(x), axis=1)
    scales = np.where(amax > 0.0, amax / 127.0, 1.0).astype(np.float32)
    codes = np.clip(np.rint(x / scales[:, None]), -127, 127).astype(np.int8)
    return codes, scales


def l2_topk_op(queries, db, k: int = 10, *, valid=None,
               force_pallas: bool = False,
               bq: int | None = None, bn: int | None = None):
    """Fused brute-force L2 top-k. (dists ascending, ids)."""
    v = None if valid is None else jnp.asarray(valid)
    if _on_tpu() or force_pallas:
        return l2_topk.l2_topk_pallas(
            jnp.asarray(queries), jnp.asarray(db), k, valid=v,
            interpret=not _on_tpu(), **_tiles(bq, bn),
        )
    return ref.l2_topk_ref(jnp.asarray(queries), jnp.asarray(db), k, valid=v)


def l2_topk_int8_op(queries, db_codes, scales, k: int = 10, *, valid=None,
                    force_pallas: bool = False,
                    bq: int | None = None, bn: int | None = None):
    """int8-footprint brute scan (db as per-row-scaled int8 codes)."""
    v = None if valid is None else jnp.asarray(valid)
    if _on_tpu() or force_pallas:
        return l2_topk.l2_topk_int8_pallas(
            jnp.asarray(queries), jnp.asarray(db_codes),
            jnp.asarray(scales), k, valid=v,
            interpret=not _on_tpu(), **_tiles(bq, bn),
        )
    return ref.l2_topk_int8_ref(
        jnp.asarray(queries), jnp.asarray(db_codes),
        jnp.asarray(scales), k, valid=v,
    )


def candidate_topk_op(queries, vecs, ids, k: int = 10, *,
                      best_d=None, best_i=None,
                      force_pallas: bool = False,
                      bq: int | None = None, bc: int | None = None):
    """Per-query candidate-tile L2 top-k with optional carried best
    (IVF probe chains, forest rerank). (dists ascending, ids)."""
    if _on_tpu() or force_pallas:
        return bucket_topk.candidate_topk_pallas(
            jnp.asarray(queries), jnp.asarray(vecs), jnp.asarray(ids), k,
            best_d=best_d, best_i=best_i,
            interpret=not _on_tpu(), **_tiles(bq, bc=bc),
        )
    return ref.candidate_topk_ref(
        jnp.asarray(queries), jnp.asarray(vecs), jnp.asarray(ids), k,
        best_d=best_d, best_i=best_i,
    )


def pq_adc_topk_op(lut, codes, k: int = 10, *, valid=None,
                   force_pallas: bool = False,
                   bq: int | None = None, bn: int | None = None):
    """PQ ADC scan + top-k from a per-query LUT. (adc dists, ids)."""
    v = None if valid is None else jnp.asarray(valid)
    if _on_tpu() or force_pallas:
        return pq_adc.pq_adc_topk_pallas(
            jnp.asarray(lut), jnp.asarray(codes), k, valid=v,
            interpret=not _on_tpu(), **_tiles(bq, bn),
        )
    return ref.pq_adc_topk_ref(jnp.asarray(lut), jnp.asarray(codes), k,
                               valid=v)


def hamming_topk_op(qcodes, codes, k: int = 10, *, valid=None,
                    force_pallas: bool = False,
                    bq: int | None = None, bn: int | None = None):
    """Packed-bit Hamming top-k. (dists, ids)."""
    v = None if valid is None else jnp.asarray(valid)
    if _on_tpu() or force_pallas:
        return hamming.hamming_topk_pallas(
            jnp.asarray(qcodes), jnp.asarray(codes), k, valid=v,
            interpret=not _on_tpu(), **_tiles(bq, bn),
        )
    return ref.hamming_topk_ref(jnp.asarray(qcodes), jnp.asarray(codes), k,
                                valid=v)


def bm25_topk_op(q_terms, q_weights, terms, tf_sat, k: int = 10, *,
                 valid=None, force_pallas: bool = False,
                 bq: int | None = None, bn: int | None = None):
    """Fused BM25 lexical scan over fixed-shape postings slabs.
    (ranking dists = -score ascending, ids)."""
    v = None if valid is None else jnp.asarray(valid)
    if _on_tpu() or force_pallas:
        return bm25.bm25_topk_pallas(
            jnp.asarray(q_terms), jnp.asarray(q_weights),
            jnp.asarray(terms), jnp.asarray(tf_sat), k, valid=v,
            interpret=not _on_tpu(), **_tiles(bq, bn),
        )
    return ref.bm25_topk_ref(
        jnp.asarray(q_terms), jnp.asarray(q_weights),
        jnp.asarray(terms), jnp.asarray(tf_sat), k, valid=v,
    )


def hybrid_topk_op(queries, db, q_terms, q_weights, terms, tf_sat, alpha,
                   k: int = 10, *, valid=None, force_pallas: bool = False,
                   bq: int | None = None, bn: int | None = None):
    """Fused hybrid ``alpha * l2sq - (1 - alpha) * bm25`` top-k.
    ``alpha`` is a (1, 1) operand — sweeping it mints no executables."""
    v = None if valid is None else jnp.asarray(valid)
    if _on_tpu() or force_pallas:
        return bm25.hybrid_topk_pallas(
            jnp.asarray(queries), jnp.asarray(db),
            jnp.asarray(q_terms), jnp.asarray(q_weights),
            jnp.asarray(terms), jnp.asarray(tf_sat),
            jnp.asarray(alpha), k, valid=v,
            interpret=not _on_tpu(), **_tiles(bq, bn),
        )
    return ref.hybrid_topk_ref(
        jnp.asarray(queries), jnp.asarray(db),
        jnp.asarray(q_terms), jnp.asarray(q_weights),
        jnp.asarray(terms), jnp.asarray(tf_sat), jnp.asarray(alpha), k,
        valid=v,
    )
