"""Public jit'd wrappers for the ANN kernels.

Dispatch policy: on TPU backends call the Pallas kernel compiled natively;
on CPU (this container) call the pure-jnp oracle by default — identical
results, XLA-optimized — or the Pallas kernel in interpret mode when
``force_pallas=True`` (used by tests to execute the real kernel body).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import hamming, l2_topk, pq_adc, ref

__all__ = ["l2_topk_op", "pq_adc_topk_op", "hamming_topk_op"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def l2_topk_op(queries, db, k: int = 10, *, force_pallas: bool = False,
               bq: int | None = None, bn: int | None = None):
    """Fused brute-force L2 top-k. (dists ascending, ids)."""
    if _on_tpu() or force_pallas:
        kw = {}
        if bq:
            kw["bq"] = bq
        if bn:
            kw["bn"] = bn
        return l2_topk.l2_topk_pallas(
            jnp.asarray(queries), jnp.asarray(db), k,
            interpret=not _on_tpu(), **kw,
        )
    return ref.l2_topk_ref(jnp.asarray(queries), jnp.asarray(db), k)


def pq_adc_topk_op(lut, codes, k: int = 10, *, force_pallas: bool = False,
                   bq: int | None = None, bn: int | None = None):
    """PQ ADC scan + top-k from a per-query LUT. (adc dists, ids)."""
    if _on_tpu() or force_pallas:
        kw = {}
        if bq:
            kw["bq"] = bq
        if bn:
            kw["bn"] = bn
        return pq_adc.pq_adc_topk_pallas(
            jnp.asarray(lut), jnp.asarray(codes), k,
            interpret=not _on_tpu(), **kw,
        )
    return ref.pq_adc_topk_ref(jnp.asarray(lut), jnp.asarray(codes), k)


def hamming_topk_op(qcodes, codes, k: int = 10, *, force_pallas: bool = False,
                    bq: int | None = None, bn: int | None = None):
    """Packed-bit Hamming top-k. (dists, ids)."""
    if _on_tpu() or force_pallas:
        kw = {}
        if bq:
            kw["bq"] = bq
        if bn:
            kw["bn"] = bn
        return hamming.hamming_topk_pallas(
            jnp.asarray(qcodes), jnp.asarray(codes), k,
            interpret=not _on_tpu(), **kw,
        )
    return ref.hamming_topk_ref(jnp.asarray(qcodes), jnp.asarray(codes), k)
