"""PQ asymmetric-distance (ADC) + fused top-k Pallas TPU kernel.

The paper's top-level index on large corpora is PQ over 2^13..2^15 k-means
centroids (§3.2/§5.2): a query builds a (M, 256) LUT of exact subspace
distances once, then every centroid code is scored as
``sum_m LUT[m, code[n, m]]``.

TPU adaptation (DESIGN.md §2): the CPU implementation is a random-access
byte gather — hostile to the VPU.  We instead materialize each subspace's
one-hot code matrix on the fly (iota compare) and score with an MXU matmul

    scores += LUT[:, m, :] @ onehot(codes[:, m])      # (B,256) x (256,BN)

turning the gather into M dense (B, 256, BN) matmul tiles — the classic
"gather as one-hot matmul" TPU idiom.  Running top-k merges per tile as in
`l2_topk`.

Grid: (B_tiles, N_tiles), N innermost.  VMEM: LUT tile (BQ, M, 256) +
codes tile (BN, M) + scores (BQ, BN).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import INF, merge_topk, pad_sentinel, valid_operand

DEFAULT_BQ = 128
DEFAULT_BN = 512


def _kernel(lut_ref, codes_ref, v_ref, bd_ref, bi_ref,
            *, k: int, bn: int, n: int):
    step = pl.program_id(1)

    @pl.when(step == 0)
    def _init():
        bd_ref[...] = jnp.full_like(bd_ref, INF)
        bi_ref[...] = jnp.full_like(bi_ref, -1)

    lut = lut_ref[...].astype(jnp.float32)        # (BQ, M, C)
    codes = codes_ref[...]                        # (BN, M) int32
    bq, m, c = lut.shape

    def body(j, acc):
        cj = codes[:, j]                          # (BN,)
        onehot = (
            jax.lax.broadcasted_iota(jnp.int32, (c, cj.shape[0]), 0)
            == cj[None, :]
        ).astype(jnp.float32)                     # (C, BN)
        return acc + jax.lax.dot_general(
            lut[:, j, :], onehot, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    scores = jax.lax.fori_loop(
        0, m, body, jnp.zeros((bq, codes.shape[0]), jnp.float32)
    )

    ids = step * bn + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    live = (ids < n) & (v_ref[...] != 0)
    scores = jnp.where(live, scores, INF)

    new_d, new_i = merge_topk(bd_ref[...], bi_ref[...], scores, ids, k)
    bd_ref[...] = new_d
    bi_ref[...] = new_i


@functools.partial(jax.jit, static_argnames=("k", "bq", "bn", "interpret"))
def pq_adc_topk_pallas(
    lut: jnp.ndarray,          # (B, M, 256) float32
    codes: jnp.ndarray,        # (N, M) int32/uint8
    k: int = 10,
    *,
    valid: jnp.ndarray | None = None,
    bq: int = DEFAULT_BQ,
    bn: int = DEFAULT_BN,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (adc_dists (B, k) ascending, ids (B, k)).

    Same contract as ``l2_topk_pallas``: optional ``valid`` liveness
    mask, ``k`` clamped to N, dead slots are the ``(inf, -1)``
    sentinel."""
    B, M, C = lut.shape
    N = codes.shape[0]
    k_eff = min(k, N)
    bq = min(bq, max(8, B))
    bn = min(bn, max(8, N))
    grid_b = -(-B // bq)
    grid_n = -(-N // bn)
    lp = jnp.pad(lut, ((0, grid_b * bq - B), (0, 0), (0, 0)))
    cp = jnp.pad(codes.astype(jnp.int32), ((0, grid_n * bn - N), (0, 0)))
    vp = valid_operand(valid, N, grid_n * bn)

    out = pl.pallas_call(
        functools.partial(_kernel, k=k_eff, bn=bn, n=N),
        grid=(grid_b, grid_n),
        in_specs=[
            pl.BlockSpec((bq, M, C), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((bn, M), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k_eff), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k_eff), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid_b * bq, k_eff), jnp.float32),
            jax.ShapeDtypeStruct((grid_b * bq, k_eff), jnp.int32),
        ],
        interpret=interpret,
    )(lp, cp, vp)
    return pad_sentinel(out[0][:B], out[1][:B], k, k_eff)
