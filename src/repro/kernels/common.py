"""Shared helpers for the ANN Pallas kernels.

All three kernels (`l2_topk`, `pq_adc`, `hamming`) are streaming scans over
database tiles with a running per-query top-k kept in the revisited output
block — the canonical TPU accumulation pattern (sequential innermost grid
dimension revisits the same output tile).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF = float("inf")  # python float: jnp closures may not capture arrays


def merge_topk(best_d, best_i, tile_d, tile_i, k: int):
    """Merge a (B, T) score tile into the running (B, K) best lists.

    K is static and small (<=32); extraction is K iterative masked mins —
    no sort needed, VPU-friendly, works identically under Pallas interpret
    mode and on the TPU vector unit.

    Ordering is deterministic on the **(distance, id) pair**: equal
    distances break toward the smaller id, matching ``lax.top_k``'s
    lower-index-first rule on an id-ordered scan.  A plain per-round
    ``argmin`` would instead prefer whichever tied candidate entered the
    running list in an earlier tile — an order that depends on the ``bn``
    tiling — so the lexicographic rule is what makes fused-vs-reference
    conformance bitwise rather than merely set-equal.
    Returns updated (best_d (B,K) ascending, best_i (B,K)).
    """
    cat_d = jnp.concatenate([best_d, tile_d], axis=1)          # (B, K+T)
    cat_i = jnp.concatenate([best_i, tile_i], axis=1)
    imax = jnp.iinfo(jnp.int32).max
    out_d, out_i = [], []
    for _ in range(k):
        md = jnp.min(cat_d, axis=1)                            # (B,)
        tie = cat_d == md[:, None]
        mi = jnp.min(jnp.where(tie, cat_i, imax), axis=1)
        out_d.append(md)
        out_i.append(mi)
        # retire exactly the selected (distance, id) entry; duplicate
        # (INF, -1) sentinels re-selecting is harmless and intended
        cat_d = jnp.where(tie & (cat_i == mi[:, None]), INF, cat_d)
    return jnp.stack(out_d, axis=1), jnp.stack(out_i, axis=1)


def valid_operand(valid, n: int, n_pad: int) -> jnp.ndarray:
    """Liveness mask as a (1, n_pad) int32 kernel operand.

    Grid-pad rows are dead; ``valid=None`` means all ``n`` rows live.
    Kernels broadcast ``v_ref[...] != 0`` against the (BQ, BN) tile.
    """
    if valid is None:
        v = jnp.ones((n,), jnp.int32)
    else:
        v = jnp.asarray(valid).astype(jnp.int32)
    return jnp.pad(v, (0, n_pad - n))[None, :]


def pad_sentinel(d, i, k: int, k_eff: int):
    """Restore the caller's requested ``k`` after an internal clamp: the
    impossible slots are the documented ``(inf, -1)`` sentinel."""
    if k_eff == k:
        return d, i
    return (jnp.pad(d, ((0, 0), (0, k - k_eff)), constant_values=INF),
            jnp.pad(i, ((0, 0), (0, k - k_eff)), constant_values=-1))


def popcount32(x):
    """Branch-free popcount on int32 lanes (no popcnt op on the VPU)."""
    x = x - ((x >> 1) & 0x55555555)
    x = (x & 0x33333333) + ((x >> 2) & 0x33333333)
    x = (x + (x >> 4)) & 0x0F0F0F0F
    return (x * 0x01010101) >> 24
