"""Shared helpers for the ANN Pallas kernels.

All three kernels (`l2_topk`, `pq_adc`, `hamming`) are streaming scans over
database tiles with a running per-query top-k kept in the revisited output
block — the canonical TPU accumulation pattern (sequential innermost grid
dimension revisits the same output tile).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF = float("inf")  # python float: jnp closures may not capture arrays


def merge_topk(best_d, best_i, tile_d, tile_i, k: int):
    """Merge a (B, T) score tile into the running (B, K) best lists.

    K is static and small (<=32); extraction is K iterative masked argmins —
    no sort needed, VPU-friendly, works identically under Pallas interpret
    mode and on the TPU vector unit.
    Returns updated (best_d (B,K) ascending, best_i (B,K)).
    """
    cat_d = jnp.concatenate([best_d, tile_d], axis=1)          # (B, K+T)
    cat_i = jnp.concatenate([best_i, tile_i], axis=1)
    cols = jax.lax.broadcasted_iota(jnp.int32, cat_d.shape, 1)
    out_d, out_i = [], []
    for _ in range(k):
        am = jnp.argmin(cat_d, axis=1)                         # (B,)
        md = jnp.min(cat_d, axis=1)
        mi = jnp.take_along_axis(cat_i, am[:, None], axis=1)[:, 0]
        out_d.append(md)
        out_i.append(mi)
        cat_d = jnp.where(cols == am[:, None], INF, cat_d)
    return jnp.stack(out_d, axis=1), jnp.stack(out_i, axis=1)


def popcount32(x):
    """Branch-free popcount on int32 lanes (no popcnt op on the VPU)."""
    x = x - ((x >> 1) & 0x55555555)
    x = (x & 0x33333333) + ((x >> 2) & 0x33333333)
    x = (x + (x >> 4)) & 0x0F0F0F0F
    return (x * 0x01010101) >> 24
