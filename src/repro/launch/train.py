"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
      --steps 100 --reduced            # CPU-sized smoke run
  ... --mesh single                    # sharded (needs real devices)

With ``--reduced`` (default on CPU) the arch's same-family reduced config
trains for real; full configs require the target mesh.  Checkpoints,
watchdog, and deterministic restart come from `train.loop`.
"""
from __future__ import annotations

import argparse
import os

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--no-reduced", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    from repro.configs.registry import get_arch
    from repro.train import checkpoint as C
    from repro.train import optim
    from repro.train.fault import Watchdog
    from repro.train.loop import init_state, make_train_step, train

    cfg, family = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)

    if family == "lm":
        from repro.data.lm import LMStream
        from repro.models import transformer as M

        params = M.init(cfg, key)
        stream = LMStream(cfg.vocab, args.seq, args.batch, seed=0)
        batch_at = stream.batch_at
        loss = lambda p, b: M.loss_fn(p, b, cfg)
    elif family == "recsys":
        from repro.data.recsys import batch_for
        from repro.models import recsys as M

        params = M.init(cfg, key)
        batch_at = lambda step: batch_for(cfg, args.batch, step)
        loss = lambda p, b: M.loss_fn(p, b, cfg)
    elif family == "gnn":
        import dataclasses

        from repro.data.graph import make_graph
        from repro.models import schnet as M

        cfg = dataclasses.replace(cfg, d_feat=32, n_out=8)
        params = M.init(cfg, key)
        g = make_graph(2000, 10000, 32, n_classes=8, seed=0)
        snd, rcv = g.edge_list()
        fixed = {"feats": g.feats, "pos": g.pos, "senders": snd,
                 "receivers": rcv, "labels": g.labels}
        batch_at = lambda step: fixed
        loss = lambda p, b: M.loss_fn(p, b, cfg)
    else:
        raise SystemExit(f"train launcher does not apply to family "
                         f"{family!r} (ANN corpora are built, not trained)")

    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{args.arch} ({'reduced' if args.reduced else 'full'}): "
          f"{n / 1e6:.2f}M params")
    opt = optim.adamw(optim.warmup_cosine(3e-4, 20, args.steps))
    state = init_state(params, opt)
    if args.resume and args.ckpt_dir:
        last = C.latest_step(args.ckpt_dir)
        if last is not None:
            state = C.restore(args.ckpt_dir, last, state)
            print(f"resumed from step {last}")
    wd = Watchdog()
    res = train(state, make_train_step(loss, opt), batch_at, args.steps,
                log_every=args.log_every, ckpt_dir=args.ckpt_dir,
                watchdog=wd)
    for h in res.history:
        print("  ", h)
    print(f"stragglers: {len(wd.events)}")


if __name__ == "__main__":
    main()
