import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves on 512 placeholder devices what would run on the
real pods: the sharding is coherent (SPMD partitioner accepts it), the
program fits (memory_analysis), and the collective schedule is what the
roofline expects (cost_analysis + HLO collective byte parse).

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --all                 # every assigned cell
  python -m repro.launch.dryrun --all --mesh single   # one mesh only

Results append to benchmarks/results/dryrun.json (cache keyed by
arch/shape/mesh; --force recomputes).
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.configs.registry import get_arch, get_shapes, iter_cells
from repro.launch.cells import build_cell
from repro.launch.mesh import make_plan, make_production_mesh

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "results", "dryrun.json")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|s64|s32|s16|s8|u64|u32|u16|u8|"
                       r"pred)\[([0-9,]*)\]")


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def parse_collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved per collective op kind (result-shape sum).

    Methodology: for each collective instruction line, take the max shape
    literal on the line (covers operand + result forms) — a lower bound on
    link traffic per device; ring-algorithm constants are applied in the
    roofline, not here.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("%") or s.startswith("ROOT"):
            for kind in _COLLECTIVES:
                if re.search(rf"\b{kind}(-start|-done)?\(", s) or \
                        re.search(rf"= [^=]*\b{kind}(-start)?\b", s):
                    sizes = [_shape_bytes(m)
                             for m in _SHAPE_RE.finditer(s)]
                    if sizes:
                        out[kind] += max(sizes)
                        counts[kind] += 1
                    break
    out["counts"] = counts
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             verbose: bool = True) -> dict:
    cfg, family = get_arch(arch_id)
    shape = next(s for s in get_shapes(family) if s.name == shape_name)
    mesh_name = "multi_pod_2x16x16" if multi_pod else "single_pod_16x16"
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "family": family, "status": "ok"}
    t0 = time.time()
    try:
        if shape.dims.get("subquadratic_required") and family == "lm":
            rec["status"] = "skipped"
            rec["reason"] = ("long_500k needs sub-quadratic attention; "
                             "arch is full softmax attention (DESIGN.md §5)")
            return rec
        mesh = make_production_mesh(multi_pod=multi_pod)
        plan = make_plan(mesh)
        cell = build_cell(cfg, family, plan, shape)
        with mesh:
            jitted = jax.jit(
                cell.step_fn,
                in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.donate,
            )
            lowered = jitted.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "code_bytes": int(mem.generated_code_size_in_bytes),
        }
        rec["memory"]["per_device_total"] = (
            rec["memory"]["argument_bytes"]
            + rec["memory"]["temp_bytes"]
            + rec["memory"]["output_bytes"]
            - rec["memory"]["alias_bytes"]
        )
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        # raw cost_analysis counts while bodies ONCE — kept for reference;
        # the roofline uses the trip-count-corrected analyzer below.
        rec["cost_raw"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0)),
        }
        txt = compiled.as_text()
        from repro.launch.hlo_analysis import analyze_hlo

        rec["analysis"] = analyze_hlo(txt)
        rec["collectives"] = parse_collective_bytes(txt)  # unweighted ref
        rec["hlo_chars"] = len(txt)
        rec["times"] = {"lower_s": round(t_lower, 2),
                        "compile_s": round(t_compile, 2)}
        if cell.note:
            rec["note"] = cell.note
        if verbose:
            m = rec["memory"]["per_device_total"] / 2**30
            a = rec["analysis"]
            print(f"[ok] {arch_id} x {shape_name} x {mesh_name}: "
                  f"{m:.2f} GiB/dev, {a['matmul_flops']:.3e} mmflops/dev, "
                  f"coll {a['collective_bytes']['total']/2**20:.1f} MiB/dev"
                  f" (lower {t_lower:.0f}s compile {t_compile:.0f}s)",
                  flush=True)
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[ERR] {arch_id} x {shape_name} x {mesh_name}: "
                  f"{rec['error']}", flush=True)
    return rec


def load_results(path: str = RESULTS) -> dict:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_results(results: dict, path: str = RESULTS):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1)
    os.replace(tmp, path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-ann", action="store_true",
                    help="also run the paper's own ANN corpora cells")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=RESULTS)
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    results = load_results(args.out)

    def key(a, s, mp):
        return f"{a}|{s}|{'multi' if mp else 'single'}"

    cells = []
    if args.all:
        for arch_id, cfg, family, shape in iter_cells(
                include_ann=args.include_ann):
            for mp in meshes:
                cells.append((arch_id, shape.name, mp))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required without --all")
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    for arch_id, shape_name, mp in cells:
        k = key(arch_id, shape_name, mp)
        if not args.force and k in results and \
                results[k].get("status") in ("ok", "skipped"):
            print(f"[cached] {k}", flush=True)
            continue
        rec = run_cell(arch_id, shape_name, mp)
        rec.pop("traceback", None) if rec["status"] == "ok" else None
        results[k] = rec
        save_results(results, args.out)

    n_ok = sum(1 for r in results.values() if r["status"] == "ok")
    n_skip = sum(1 for r in results.values() if r["status"] == "skipped")
    n_err = sum(1 for r in results.values() if r["status"] == "error")
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped (listed), "
          f"{n_err} errors", flush=True)
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
