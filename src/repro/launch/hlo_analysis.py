"""Trip-count-aware HLO accounting for the roofline.

``compiled.cost_analysis()`` counts every while-loop body ONCE — a
scan-over-layers model under-reports FLOPs/bytes/collectives by ~L×
(verified: scan vs unrolled ratio == trip count).  This module re-derives
the three roofline inputs from the post-optimization HLO text:

  * matmul FLOPs: every ``dot`` instruction, 2 * prod(result) * contraction
    size, weighted by the product of enclosing while trip counts;
  * HBM byte proxy: sum of instruction *result* bytes (x2 for read+write)
    over non-trivial ops, same weighting — counts the per-layer
    dynamic-slice reads of stacked scan params, fusion outputs, etc.;
  * collective bytes by kind (all-reduce doubled for the ring), weighted.

Trip counts come from the integer constant in each while's condition
computation.  Methodology notes recorded in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["analyze_hlo"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->.*\{")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
# operands may carry their type ("dot(f32[8,64]{1,0} %lhs, ...)" — newer
# XLA dumps) or not ("dot(%lhs, ...)"); skip the optional type prefix.
_DOT_RE = re.compile(
    r"dot\(\s*(?:[\w\[\]\{\},]+\s+)?%?([\w\.\-]+)\s*,")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops whose results genuinely stream through HBM; aliasing ops
# (get-tuple-element, bitcast, tuple, parameter) and op-fusable elementwise
# chains (a TPU compiler fuses those into neighbors) are excluded.
_MEM_OPS = ("fusion", "dot", "copy", "dynamic-slice",
            "dynamic-update-slice", "reduce", "convert", "concatenate",
            "gather", "scatter", "sort", "pad", "reduce-window",
            "select-and-scatter", "transpose",
            *_COLLECTIVES)


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _result_bytes(rhs: str) -> int:
    """Bytes of the result type(s) at the start of an instruction RHS."""
    # result types precede the op name: 'f32[8,512]{1,0} dot(' or a tuple
    head = rhs.split("(", 1)[0]
    return sum(
        _shape_elems(m.group(2)) * _DTYPE_BYTES[m.group(1)]
        for m in _SHAPE_RE.finditer(head)
    )


def _split_computations(txt: str) -> tuple[dict, str]:
    comps: dict[str, list[str]] = {}
    cur = None
    entry = None
    for raw in txt.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not raw.startswith(" "):
            m = _HEADER_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
            elif line.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        s = line.strip()
        if s == "}":
            cur = None
        else:
            comps[cur].append(s)
    return comps, entry


def analyze_hlo(txt: str, default_trip: int = 1) -> dict:
    comps, entry = _split_computations(txt)
    if entry is None:
        return {"error": "no ENTRY computation found"}

    # while structure: parent comp -> [(cond, body)]
    whiles = defaultdict(list)
    for name, instrs in comps.items():
        for s in instrs:
            m = _WHILE_RE.search(s)
            if m:
                whiles[name].append((m.group(1), m.group(2)))

    def trip_count(cond: str) -> int:
        consts = [int(c) for ins in comps.get(cond, ())
                  for c in _CONST_RE.findall(ins)]
        consts = [c for c in consts if c > 1]
        return max(consts) if consts else default_trip

    # control multiplier propagation (entry + nested while bodies)
    mult = {entry: 1.0}
    stack = [entry]
    control = {entry}
    while stack:
        c = stack.pop()
        for cond, body in whiles.get(c, ()):
            t = trip_count(cond)
            mult[body] = mult.get(body, 0.0) + mult[c] * t
            if body not in control:
                control.add(body)
                stack.append(body)

    flops = 0.0
    mem_bytes = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    coll_counts = {k: 0 for k in _COLLECTIVES}
    trips = {b: mult[b] for b in control if b != entry}
    # CPU-backend artifact: XLA CPU has no native bf16 GEMM, so it hoists
    # f32 conversions of whole bf16 weight stacks out of the layer scan —
    # multi-GiB f32 buffers a TPU (native bf16 MXU) never materializes.
    # Quantified here so the roofline reports TPU-adjusted memory.
    f32_hoist_bytes = 0.0

    for cname in control:
        w = mult[cname]
        symtab = {}
        for s in comps[cname]:
            mi = _INSTR_RE.match(s)
            if not mi:
                continue
            symtab[mi.group(1)] = mi.group(2)
        for s in comps[cname]:
            mi = _INSTR_RE.match(s)
            if not mi:
                continue
            rhs = mi.group(2)
            rb = _result_bytes(rhs)
            head_toks = rhs.split("(", 1)[0].split()
            opname = head_toks[-1] if head_toks else ""
            opbase = opname.replace("-start", "").replace("-done", "")
            if opbase in _MEM_OPS and not opname.endswith("-done"):
                mem_bytes += 2.0 * rb * w          # read+write proxy
            if cname == entry and rb >= 1 << 30 \
                    and ("convert" in mi.group(1) or opbase == "convert") \
                    and rhs.lstrip().startswith("f32"):
                f32_hoist_bytes += rb
            # collectives (skip -done halves of async pairs)
            if opbase in _COLLECTIVES and not opname.endswith("-done"):
                factor = 2.0 if opbase == "all-reduce" else 1.0
                coll[opbase] += factor * rb * w
                coll_counts[opbase] += 1
            # dot flops
            dm = _DOT_RE.search(rhs)
            if dm and " dot(" in " " + rhs:
                out_elems = 0
                head = rhs.split("(", 1)[0]
                for m in _SHAPE_RE.finditer(head):
                    out_elems += _shape_elems(m.group(2))
                lhs_name = dm.group(1)
                cdims = _LHS_CDIMS_RE.search(rhs)
                k = 1
                if cdims and lhs_name in symtab:
                    lhs_head = symtab[lhs_name].split("(", 1)[0]
                    lm = _SHAPE_RE.search(lhs_head)
                    if lm:
                        lhs_dims = [int(d) for d in
                                    lm.group(2).split(",") if d]
                        for ci in cdims.group(1).split(","):
                            if ci and int(ci) < len(lhs_dims):
                                k *= lhs_dims[int(ci)]
                flops += 2.0 * out_elems * k * w

    coll_total = sum(coll.values())
    return {
        "matmul_flops": flops,
        "mem_bytes_proxy": mem_bytes,
        "collective_bytes": {**{k: v for k, v in coll.items()},
                             "total": coll_total},
        "collective_counts": coll_counts,
        "while_trip_multipliers": {k: v for k, v in sorted(trips.items())},
        "n_computations": len(comps),
        "entry_f32_weight_convert_bytes": f32_hoist_bytes,
    }


def peak_liveness(txt: str, top_n: int = 12) -> dict:
    """Approximate peak live bytes per control computation from the
    *scheduled* HLO (is_scheduled=true): walk instructions in order, free a
    buffer after its last textual use.  Reports the top live buffers at the
    peak — the tool that finds which tensors blow the 16 GB budget."""
    comps, entry = _split_computations(txt)
    whiles = {}
    for name, instrs in comps.items():
        for s in instrs:
            m = _WHILE_RE.search(s)
            if m:
                whiles.setdefault(name, []).append(m.group(2))
    control = {entry}
    stack = [entry]
    while stack:
        c = stack.pop()
        for body in whiles.get(c, ()):
            if body not in control:
                control.add(body)
                stack.append(body)

    use_re = re.compile(r"%([\w\.\-]+)")
    out = {}
    for cname in control:
        instrs = comps[cname]
        sizes, defs, last_use = {}, {}, {}
        for idx, s in enumerate(instrs):
            mi = _INSTR_RE.match(s)
            if not mi:
                continue
            name, rhs = mi.group(1), mi.group(2)
            head_toks = rhs.split("(", 1)[0].split()
            op = head_toks[-1] if head_toks else ""
            if op in ("get-tuple-element", "bitcast", "tuple",
                      "parameter", "constant"):
                continue          # aliases / module inputs
            sm = _SHAPE_RE.search(rhs.split("(", 1)[0])
            sizes[name] = _result_bytes(rhs)
            defs[name + "@shape"] = sm.group(0) if sm else "?"
            defs[name] = idx
            last_use[name] = idx
            for used in use_re.findall(rhs):
                if used in last_use:
                    last_use[used] = idx
        peak, live, cur = 0, {}, 0
        peak_set = {}
        frees = {}
        for name, lu in last_use.items():
            frees.setdefault(lu, []).append(name)
        for idx in range(len(instrs)):
            mi = _INSTR_RE.match(instrs[idx])
            if mi and mi.group(1) in sizes:
                n = mi.group(1)
                live[n] = sizes[n]
                cur += sizes[n]
            if cur > peak:
                peak = cur
                peak_set = dict(live)
            for n in frees.get(idx, ()):
                if n in live:
                    cur -= live.pop(n)
        top = sorted(peak_set.items(), key=lambda kv: -kv[1])[:top_n]
        out[cname] = {
            "peak_bytes": peak,
            "top_buffers": [
                (n, b, defs.get(n + "@shape", "?"))
                for n, b in top if b > 1 << 20
            ],
        }
    return out
