"""Serving launcher for the paper's ANN corpora.

  PYTHONPATH=src python -m repro.launch.serve --arch sift-1m --scale 0.05 \
      --n-requests 256

Builds the arch's configured two-level index over a synthetic corpus at
``--scale`` of the paper size and serves batched requests through the
micro-batching engine, reporting recall + latency percentiles (the paper's
P90 < 80 ms / recall@10 > 0.8 bar).
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="sift-1m",
                    choices=["radio-station", "sift-1m", "deep-10m"])
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--n-requests", type=int, default=256)
    ap.add_argument("--nprobe", type=int, default=None)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    from repro.configs.registry import get_arch
    from repro.core.brute import brute_search
    from repro.core.metrics import recall_at_k
    from repro.core.two_level import TwoLevelConfig, build_two_level
    from repro.data.synthetic import make_corpus, make_queries
    from repro.serve.engine import ServingEngine

    cfg, _ = get_arch(args.arch)
    name = {"radio-station": "radio_station", "sift-1m": "sift",
            "deep-10m": "deep"}[args.arch]
    db = np.asarray(make_corpus(name, scale=args.scale, seed=0))
    n = db.shape[0]
    n_clusters = max(16, int(cfg.n_clusters * min(1.0, args.scale * 2)))
    print(f"{args.arch}: corpus {n} x {db.shape[1]}, "
          f"{n_clusters} buckets, top={cfg.top} bottom={cfg.bottom}")
    t0 = time.time()
    idx = build_two_level(db, TwoLevelConfig(
        n_clusters=n_clusters, top=cfg.top, bottom=cfg.bottom,
        kmeans_iters=6, kmeans_minibatch=min(131072, n)))
    print(f"built in {time.time() - t0:.1f}s")

    nprobe = args.nprobe or cfg.nprobe

    def search_fn(qs):
        d, i, _ = idx.search(qs, args.k, nprobe=nprobe)
        return d, i

    eng = ServingEngine(search_fn, max_batch=64, max_wait_ms=3.0)
    q = make_queries(db, args.n_requests, seed=1)
    futs = [eng.submit(q[j]) for j in range(args.n_requests)]
    outs = [f.get(timeout=300) for f in futs]
    st = eng.stats()
    eng.close()
    ids = np.stack([o[1] for o in outs])
    _, gt = brute_search(q, db, args.k)
    r = recall_at_k(ids, gt)
    print(f"recall@{args.k} = {r:.3f}  "
          f"p50={st.p50_ms:.1f}ms p90={st.p90_ms:.1f}ms "
          f"p99={st.p99_ms:.1f}ms")
    print(f"paper bars: recall>0.8 {'PASS' if r > 0.8 else 'FAIL'}; "
          f"P90<80ms {'PASS' if st.p90_ms < 80 else 'FAIL'}")


if __name__ == "__main__":
    main()
