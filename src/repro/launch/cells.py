"""Dry-run cell builders: (arch x shape x mesh) -> lowerable step + specs.

For every cell this module produces:
  step_fn        : the jit-able train/serve/retrieval step
  args           : ShapeDtypeStruct pytree (no allocation)
  in_shardings   : NamedSharding pytree matching args
  out_shardings  : NamedSharding pytree (or None -> let SPMD choose)

The full configs only ever flow through here as shapes; smoke tests use
``cfg.reduced()`` with real arrays.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import (
    AnnConfig,
    DCNConfig,
    DINConfig,
    DLRMConfig,
    LMConfig,
    SASRecConfig,
    SchNetConfig,
    ShapeSpec,
)
from repro.distributed.sharding import ShardPlan
from repro.models import recsys as R
from repro.models import schnet as S
from repro.models import transformer as T
from repro.train import optim
from repro.train.loop import TrainState, make_train_step

__all__ = ["build_cell", "CellSpec", "lm_config_for_mesh",
           "build_fleet_cells"]


@dataclasses.dataclass
class CellSpec:
    step_fn: Any
    args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate: tuple = ()
    note: str = ""


def _shard_tree(mesh, spec_tree):
    is_leaf = lambda x: isinstance(x, P)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree, is_leaf=is_leaf
    )


def _pad_to(n: int, m: int) -> int:
    return -(-n // m) * m


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def lm_config_for_mesh(cfg: LMConfig, plan: ShardPlan,
                       shape: ShapeSpec) -> LMConfig:
    """Bind distribution knobs to the mesh (DESIGN.md §4)."""
    dp = max(plan.axis_size("dp"), 1)
    seq = shape["seq"]
    chunk = 0
    if shape.kind in ("train", "prefill") and seq >= 4096:
        chunk = min(1024, seq // 4)
    # sequence-shard the residual stream for whole-sequence shapes
    # (Megatron-SP): the scan saves one carry per layer for backward — an
    # unsharded fp32 carry is L x (b_loc, S, D) and blows 16 GB/chip on
    # 61-88-layer models (EXPERIMENTS.md §Perf).  Decode keeps the arch
    # default (S == 1).
    attn_shard = "seq" if shape.kind in ("train", "prefill") \
        else cfg.attn_shard
    return dataclasses.replace(
        cfg,
        moe_groups=dp if cfg.moe else 1,
        attn_chunk=chunk,
        attn_shard=attn_shard,
        scan_layers=True,
        remat=shape.kind == "train",
    )


def _lm_optimizer(cfg: LMConfig):
    # giants: adafactor (factored 2nd moment) so state fits 16 GB/chip
    if cfg.param_dtype == "bfloat16":
        return optim.adafactor(optim.warmup_cosine(1e-4, 2000, 100_000))
    return optim.adamw(optim.warmup_cosine(3e-4, 2000, 100_000))


def _moe_plan(cfg: LMConfig, plan: ShardPlan) -> ShardPlan:
    """Widen expert parallelism across pods when experts divide the full
    mesh (kimi: 512 padded experts over 512 chips -> 1 expert/chip,
    halving expert param+grad bytes; all-to-all crosses pods — the
    memory/collective trade is visible in the roofline)."""
    if cfg.moe and plan.pp:
        full = plan.size_of(("pp", "ep"))
        if cfg.moe.e_pad % full == 0:
            return dataclasses.replace(plan, ep=plan.pp + plan.ep, pp=())
    return plan


def _lm_train_cell(cfg: LMConfig, plan: ShardPlan, shape: ShapeSpec):
    mesh = plan.mesh
    plan = _moe_plan(cfg, plan)
    cfg = lm_config_for_mesh(cfg, plan, shape)
    b, s = shape["batch"], shape["seq"]
    opt = _lm_optimizer(cfg)
    p_shapes = T.param_shapes(cfg, plan)
    p_specs = T.param_specs(cfg, plan)
    o_shapes = jax.eval_shape(opt.init, p_shapes)
    o_specs = optim.state_specs(opt, p_specs, p_shapes)
    state_sds = TrainState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        params=p_shapes, opt_state=o_shapes, ef_buf=None,
    )
    state_spec = TrainState(step=P(), params=p_specs, opt_state=o_specs,
                            ef_buf=None)
    accum = max(1, cfg.grad_accum)
    if accum > 1:
        # microbatched: leading accum axis scanned inside the step
        assert b % accum == 0, (b, accum)
        bshape = (accum, b // accum, s)
        bspec = plan.p(None, "dp", None)
    else:
        bshape = (b, s)
        bspec = plan.p("dp", None)
    batch_sds = {
        "tokens": jax.ShapeDtypeStruct(bshape, jnp.int32),
        "labels": jax.ShapeDtypeStruct(bshape, jnp.int32),
    }
    batch_spec = {"tokens": bspec, "labels": bspec}

    loss = partial(T.loss_fn, cfg=cfg, plan=plan)
    step = make_train_step(loss, opt, accum=accum)
    aux_spec = {
        "nll": P(), "accuracy": P(), "loss": P(), "grad_norm": P(),
    }
    if cfg.mtp:
        aux_spec["mtp_nll"] = P()
    return CellSpec(
        step_fn=step,
        args=(state_sds, batch_sds),
        in_shardings=(_shard_tree(mesh, state_spec),
                      _shard_tree(mesh, batch_spec)),
        out_shardings=(_shard_tree(mesh, state_spec),
                       _shard_tree(mesh, aux_spec)),
        donate=(0,),
    )


def _lm_prefill_cell(cfg: LMConfig, plan: ShardPlan, shape: ShapeSpec):
    mesh = plan.mesh
    plan = _moe_plan(cfg, plan)
    cfg = lm_config_for_mesh(cfg, plan, shape)
    b, s = shape["batch"], shape["seq"]
    p_shapes = T.param_shapes(cfg, plan)
    p_specs = T.param_specs(cfg, plan)
    tok_sds = jax.ShapeDtypeStruct((b, s), jnp.int32)

    def step(params, tokens):
        return T.prefill(params, tokens, cfg, plan)

    cache_spec = T.cache_specs(cfg, plan)
    out_spec = (plan.p("dp", "tp"), cache_spec)
    return CellSpec(
        step_fn=step,
        args=(p_shapes, tok_sds),
        in_shardings=(_shard_tree(mesh, p_specs),
                      NamedSharding(mesh, plan.p("dp", None))),
        out_shardings=_shard_tree(mesh, out_spec),
    )


def _lm_decode_cell(cfg: LMConfig, plan: ShardPlan, shape: ShapeSpec):
    mesh = plan.mesh
    # serving plan: no FSDP — weights stay resident (tp/ep-sharded);
    # FSDP-gathering every layer's weights *per generated token* costs
    # ~2 GB/step/chip of all-gather (EXPERIMENTS.md §Perf).  Serving
    # weights are bf16 (standard deployment precision).
    plan = dataclasses.replace(_moe_plan(cfg, plan), fsdp=())
    cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    cfg = lm_config_for_mesh(cfg, plan, shape)
    b, s = shape["batch"], shape["seq"]
    p_shapes = T.param_shapes(cfg, plan)
    p_specs = T.param_specs(cfg, plan)
    cache_sds = T.cache_shapes(cfg, b, s)
    cache_spec = T.cache_specs(cfg, plan)
    tok_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)

    def step(params, cache, tokens):
        return T.decode_step(params, cache, tokens, cfg, plan)

    return CellSpec(
        step_fn=step,
        args=(p_shapes, cache_sds, tok_sds),
        in_shardings=(_shard_tree(mesh, p_specs),
                      _shard_tree(mesh, cache_spec),
                      NamedSharding(mesh, plan.p("dp", None))),
        out_shardings=(NamedSharding(mesh, plan.p("dp", "tp")),
                       _shard_tree(mesh, cache_spec)),
        donate=(1,),
    )


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def _gnn_cell(cfg: SchNetConfig, plan: ShardPlan, shape: ShapeSpec):
    mesh = plan.mesh
    n_dev = plan.axis_size("dp") * plan.axis_size("tp")
    dims = shape.dims
    d_feat = dims.get("d_feat", cfg.d_feat)
    cfg = dataclasses.replace(
        cfg, d_feat=d_feat,
        n_out=16 if "batch" not in dims else 1,
        message_dtype="bfloat16",   # §Perf: halves the aggregate all-reduce
    )
    if shape.name == "minibatch_lg":
        # padded sampled-subgraph sizes (seeds x fanout closure)
        bn = dims["batch_nodes"]
        f1, f2 = dims["fanout"]
        n_nodes = _pad_to(bn * (1 + f1) + bn * f1 * f2, 256)
        n_edges = _pad_to(bn * f1 + bn * f1 * f2, max(256, n_dev))
        n_graphs = None
    elif shape.name == "molecule":
        g = dims["batch"]
        n_nodes = g * dims["n_nodes"]
        n_edges = _pad_to(g * dims["n_edges"], max(256, n_dev))
        n_graphs = g
    else:
        n_nodes = dims["n_nodes"]
        n_edges = _pad_to(dims["n_edges"], max(256, n_dev))
        n_graphs = None

    edge_spec = plan.p(("dp", "tp"))
    batch_sds = {
        "feats": jax.ShapeDtypeStruct((n_nodes, d_feat), jnp.float32),
        "pos": jax.ShapeDtypeStruct((n_nodes, 3), jnp.float32),
        "senders": jax.ShapeDtypeStruct((n_edges,), jnp.int32),
        "receivers": jax.ShapeDtypeStruct((n_edges,), jnp.int32),
    }
    batch_spec = {
        "feats": plan.p(None, None),
        "pos": plan.p(None, None),
        "senders": edge_spec,
        "receivers": edge_spec,
    }
    if n_graphs is not None:
        batch_sds["graph_ids"] = jax.ShapeDtypeStruct((n_nodes,), jnp.int32)
        batch_sds["energy"] = jax.ShapeDtypeStruct((n_graphs,), jnp.float32)
        batch_spec["graph_ids"] = plan.p(None)
        batch_spec["energy"] = plan.p(None)
    else:
        batch_sds["labels"] = jax.ShapeDtypeStruct((n_nodes,), jnp.int32)
        batch_sds["node_mask"] = jax.ShapeDtypeStruct((n_nodes,),
                                                      jnp.float32)
        batch_spec["labels"] = plan.p(None)
        batch_spec["node_mask"] = plan.p(None)

    opt = optim.adamw(optim.warmup_cosine(1e-3, 100, 10_000))
    p_shapes = S.param_shapes(cfg, plan)
    p_specs = S.param_specs(cfg, plan)
    o_shapes = jax.eval_shape(opt.init, p_shapes)
    o_specs = optim.state_specs(opt, p_specs, p_shapes)
    state_sds = TrainState(jax.ShapeDtypeStruct((), jnp.int32), p_shapes,
                           o_shapes, None)
    state_spec = TrainState(P(), p_specs, o_specs, None)
    loss = partial(S.loss_fn, cfg=cfg, plan=plan)
    step = make_train_step(loss, opt)
    aux_keys = ["loss", "grad_norm"] + (
        ["accuracy"] if n_graphs is None else [])
    aux_spec = {k: P() for k in aux_keys}
    return CellSpec(
        step_fn=step,
        args=(state_sds, batch_sds),
        in_shardings=(_shard_tree(mesh, state_spec),
                      _shard_tree(mesh, batch_spec)),
        out_shardings=(_shard_tree(mesh, state_spec),
                       _shard_tree(mesh, aux_spec)),
        donate=(0,),
    )


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------


def _recsys_batch_specs(cfg, b: int, plan: ShardPlan):
    dp = plan.p("dp")
    dp2 = plan.p("dp", None)
    if isinstance(cfg, (DLRMConfig, DCNConfig)):
        sds = {
            "dense": jax.ShapeDtypeStruct((b, cfg.n_dense), jnp.float32),
            "sparse": jax.ShapeDtypeStruct((b, cfg.n_sparse), jnp.int32),
            "label": jax.ShapeDtypeStruct((b,), jnp.float32),
        }
        spec = {"dense": dp2, "sparse": dp2, "label": dp}
    elif isinstance(cfg, DINConfig):
        sds = {
            "hist_items": jax.ShapeDtypeStruct((b, cfg.seq_len), jnp.int32),
            "hist_cates": jax.ShapeDtypeStruct((b, cfg.seq_len), jnp.int32),
            "target_item": jax.ShapeDtypeStruct((b,), jnp.int32),
            "target_cate": jax.ShapeDtypeStruct((b,), jnp.int32),
            "label": jax.ShapeDtypeStruct((b,), jnp.float32),
        }
        spec = {"hist_items": dp2, "hist_cates": dp2, "target_item": dp,
                "target_cate": dp, "label": dp}
    else:  # SASRec
        sds = {
            "seq": jax.ShapeDtypeStruct((b, cfg.seq_len), jnp.int32),
            "pos": jax.ShapeDtypeStruct((b, cfg.seq_len), jnp.int32),
            "neg": jax.ShapeDtypeStruct((b, cfg.seq_len), jnp.int32),
        }
        spec = {"seq": dp2, "pos": dp2, "neg": dp2}
    return sds, spec


def _recsys_train_cell(cfg, plan: ShardPlan, shape: ShapeSpec):
    mesh = plan.mesh
    b = shape["batch"]
    opt = optim.adamw(optim.warmup_cosine(1e-3, 1000, 100_000))
    p_shapes = R.param_shapes(cfg, plan)
    p_specs = R.param_specs(cfg, plan)
    batch_sds, batch_spec = _recsys_batch_specs(cfg, b, plan)
    aux = {"loss": P(), "grad_norm": P()}
    if not isinstance(cfg, SASRecConfig):
        aux["accuracy"] = P()

    if isinstance(cfg, (DLRMConfig, DCNConfig)):
        # sparse-update path: row-wise AdaGrad on the big table — dense
        # AdamW state/grads for it would be 3x table bytes per chip
        # (train/sparse_embed.py; EXPERIMENTS.md §Perf)
        from repro.train.sparse_embed import make_ctr_sparse_train_step

        init_state_fn, step = make_ctr_sparse_train_step(cfg, plan, opt)
        state_sds = jax.eval_shape(init_state_fn, p_shapes)
        rest_specs = {k: v for k, v in p_specs.items() if k != "table"}
        rest_shapes = {k: v for k, v in p_shapes.items() if k != "table"}
        rows = p_shapes["table"].shape[0]
        acc_spec = plan.div_p((rows,), "tp")
        state_spec = TrainState(
            step=P(), params=p_specs,
            opt_state={
                "dense": optim.state_specs(opt, rest_specs, rest_shapes),
                "embed_acc": acc_spec,
            },
            ef_buf=None,
        )
    else:
        o_shapes = jax.eval_shape(opt.init, p_shapes)
        o_specs = optim.state_specs(opt, p_specs, p_shapes)
        state_sds = TrainState(jax.ShapeDtypeStruct((), jnp.int32),
                               p_shapes, o_shapes, None)
        state_spec = TrainState(P(), p_specs, o_specs, None)
        loss = partial(R.loss_fn, cfg=cfg, plan=plan)
        step = make_train_step(loss, opt)
    return CellSpec(
        step_fn=step,
        args=(state_sds, batch_sds),
        in_shardings=(_shard_tree(mesh, state_spec),
                      _shard_tree(mesh, batch_spec)),
        out_shardings=(_shard_tree(mesh, state_spec),
                       _shard_tree(mesh, aux)),
        donate=(0,),
    )


def _recsys_serve_cell(cfg, plan: ShardPlan, shape: ShapeSpec):
    mesh = plan.mesh
    b = shape["batch"]
    p_shapes = R.param_shapes(cfg, plan)
    p_specs = R.param_specs(cfg, plan)
    if isinstance(cfg, SASRecConfig):
        batch_sds = {
            "seq": jax.ShapeDtypeStruct((b, cfg.seq_len), jnp.int32),
            "target_item": jax.ShapeDtypeStruct((b,), jnp.int32),
        }
        batch_spec = {"seq": plan.p("dp", None), "target_item": plan.p("dp")}
    else:
        batch_sds, batch_spec = _recsys_batch_specs(cfg, b, plan)
        batch_sds.pop("label", None)
        batch_spec.pop("label", None)
        if isinstance(cfg, DINConfig):
            pass
    def step(params, batch):
        return R.serve_logits(params, batch, cfg, plan)

    return CellSpec(
        step_fn=step,
        args=(p_shapes, batch_sds),
        in_shardings=(_shard_tree(mesh, p_specs),
                      _shard_tree(mesh, batch_spec)),
        out_shardings=NamedSharding(mesh, plan.p("dp")),
    )


def _recsys_retrieval_cell(cfg, plan: ShardPlan, shape: ShapeSpec):
    mesh = plan.mesh
    n_dev = plan.size_of(("dp", "tp"))
    # pad the candidate list so it shards across the whole mesh
    c = _pad_to(shape["n_candidates"], max(n_dev, 512))
    k = 100
    p_shapes = R.param_shapes(cfg, plan)
    p_specs = R.param_specs(cfg, plan)
    cand_spec = plan.p(("dp", "tp"))
    if isinstance(cfg, SASRecConfig):
        batch_sds = {
            "seq": jax.ShapeDtypeStruct((1, cfg.seq_len), jnp.int32),
            "candidates": jax.ShapeDtypeStruct((c,), jnp.int32),
        }
        batch_spec = {"seq": plan.p(None, None), "candidates": cand_spec}
    elif isinstance(cfg, DINConfig):
        batch_sds = {
            "hist_items": jax.ShapeDtypeStruct((1, cfg.seq_len), jnp.int32),
            "hist_cates": jax.ShapeDtypeStruct((1, cfg.seq_len), jnp.int32),
            "candidates": jax.ShapeDtypeStruct((c,), jnp.int32),
            "cand_cates": jax.ShapeDtypeStruct((c,), jnp.int32),
        }
        batch_spec = {"hist_items": plan.p(None, None),
                      "hist_cates": plan.p(None, None),
                      "candidates": cand_spec, "cand_cates": cand_spec}
    else:
        batch_sds = {
            "dense": jax.ShapeDtypeStruct((1, cfg.n_dense), jnp.float32),
            "sparse": jax.ShapeDtypeStruct((1, cfg.n_sparse), jnp.int32),
            "candidates": jax.ShapeDtypeStruct((c,), jnp.int32),
        }
        batch_spec = {"dense": plan.p(None, None),
                      "sparse": plan.p(None, None),
                      "candidates": cand_spec}

    def step(params, batch):
        return R.retrieval_logits(params, batch, cfg, plan, k=k)

    return CellSpec(
        step_fn=step,
        args=(p_shapes, batch_sds),
        in_shardings=(_shard_tree(mesh, p_specs),
                      _shard_tree(mesh, batch_spec)),
        out_shardings=(NamedSharding(mesh, P()),
                       NamedSharding(mesh, P())),
    )


# ---------------------------------------------------------------------------
# ANN (paper) cells
# ---------------------------------------------------------------------------


def _ann_cell(cfg: AnnConfig, plan: ShardPlan, shape: ShapeSpec):
    from repro.distributed import make_sharded_ivf_fn

    mesh = plan.mesh
    axes = tuple(a for a in mesh.axis_names)
    n_dev = int(np.prod([mesh.shape[a] for a in axes]))
    b, k = shape["batch"], shape["k"]
    K = _pad_to(cfg.n_clusters, n_dev)
    cap = _pad_to(int(np.ceil(2.5 * cfg.n / cfg.n_clusters)), 8)
    nprobe_local = max(1, cfg.nprobe // n_dev)
    fn = make_sharded_ivf_fn(mesh, axes, k, nprobe_local, K // n_dev,
                             cfg.n_clusters)
    args = (
        jax.ShapeDtypeStruct((K, cfg.d), jnp.float32),
        jax.ShapeDtypeStruct((K, cap), jnp.int32),
        jax.ShapeDtypeStruct((K, cap, cfg.d), jnp.float32),
        jax.ShapeDtypeStruct((b, cfg.d), jnp.float32),
    )
    in_spec = (
        NamedSharding(mesh, P(axes, None)),
        NamedSharding(mesh, P(axes, None)),
        NamedSharding(mesh, P(axes, None, None)),
        NamedSharding(mesh, P(None, None)),
    )
    return CellSpec(
        step_fn=fn,
        args=args,
        in_shardings=in_spec,
        out_shardings=(NamedSharding(mesh, P(None, None)),
                       NamedSharding(mesh, P(None, None))),
        note=f"distributed two-level search: {K} buckets x cap {cap}, "
             f"nprobe_local={nprobe_local}",
    )


# ---------------------------------------------------------------------------


def build_cell(cfg, family: str, plan: ShardPlan,
               shape: ShapeSpec) -> CellSpec:
    if family == "lm":
        if shape.dims.get("subquadratic_required"):
            raise ValueError(
                "long_500k requires sub-quadratic attention; all assigned "
                "LM archs are full softmax attention -> listed skip "
                "(DESIGN.md §5)"
            )
        if shape.kind == "train":
            return _lm_train_cell(cfg, plan, shape)
        if shape.kind == "prefill":
            return _lm_prefill_cell(cfg, plan, shape)
        return _lm_decode_cell(cfg, plan, shape)
    if family == "gnn":
        return _gnn_cell(cfg, plan, shape)
    if family == "recsys":
        if shape.kind == "train":
            return _recsys_train_cell(cfg, plan, shape)
        if shape.kind == "retrieval":
            return _recsys_retrieval_cell(cfg, plan, shape)
        return _recsys_serve_cell(cfg, plan, shape)
    if family == "ann":
        return _ann_cell(cfg, plan, shape)
    raise ValueError(family)


def build_fleet_cells(cfg, family: str, meshes,
                      shape: ShapeSpec) -> list:
    """One :class:`CellSpec` per disjoint submesh — the dry-run view of
    a serving fleet (``repro.serve.fleet``).

    ``meshes`` comes from :func:`repro.launch.mesh.make_cell_meshes`;
    each submesh gets its own role plan (``make_plan``) and its own
    lowerable step, matching production where every serving cell owns a
    private ``ShardedSearchBackend`` on its own devices.  The specs are
    intentionally *identical up to mesh*: a fleet is N replicas of one
    cell, not N different cells.
    """
    from repro.launch.mesh import make_plan

    return [build_cell(cfg, family, make_plan(mesh), shape)
            for mesh in meshes]
