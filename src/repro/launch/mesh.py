"""Production meshes.

Functions, not module-level constants — importing this module never touches
jax device state.  The dry-run (and only the dry-run) forces 512 host
devices; tests and benches see the real single CPU device.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.distributed.sharding import (
    MULTI_POD_PLAN,
    SINGLE_POD_PLAN,
    ShardPlan,
)

__all__ = ["make_production_mesh", "make_plan", "make_test_mesh",
           "make_cell_meshes"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single pod (256 chips) or 2x16x16 two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices, found {len(devs)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (dryrun.py does this)"
        )
    dev_array = np.asarray(devs[:n]).reshape(shape)
    return Mesh(dev_array, axes)


def make_plan(mesh: Mesh) -> ShardPlan:
    """Bind the role plan matching a mesh's axis names."""
    if "pod" in mesh.axis_names:
        return MULTI_POD_PLAN.with_mesh(mesh)
    return SINGLE_POD_PLAN.with_mesh(mesh)


def make_test_mesh(shape=(2, 4), axes=("data", "model")) -> Mesh:
    """Small mesh over however many fake devices a test forced."""
    n = int(np.prod(shape))
    dev_array = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(dev_array, axes)


def make_cell_meshes(n_cells: int, *, shape=None, axes=None, devices=None,
                     share_devices: bool = False) -> list:
    """Partition the device pool into ``n_cells`` disjoint submeshes.

    The fleet tier (``repro.serve.fleet``) gives each serving cell its
    own mesh so a straggling or failed mesh cannot stall its siblings
    and a cross-cell hedge really rides different hardware.  Cells are
    carved as *consecutive* device blocks (cell i gets devices
    ``[i*per_cell, (i+1)*per_cell)``), which keeps each cell's devices
    physically adjacent under the usual torus enumeration.

    ``shape``/``axes`` describe ONE cell's mesh (default: all of the
    cell's devices on a flat ``("data",)`` axis — the serving scan
    shards the corpus over it).  ``share_devices=True`` relaxes
    disjointness and assigns devices round-robin — meshes are still
    *logically* separate (separate jit caches, separate backends), for
    tests and single-host benchmarks where the pool is smaller than the
    fleet; production fleets must keep the default.
    """
    if n_cells <= 0:
        raise ValueError("n_cells must be positive")
    devs = list(jax.devices()) if devices is None else list(devices)
    if shape is None:
        if share_devices:
            per_cell = max(len(devs) // n_cells, 1)
        else:
            per_cell = len(devs) // n_cells
            if per_cell == 0:
                raise RuntimeError(
                    f"{n_cells} disjoint cells need at least {n_cells} "
                    f"devices, found {len(devs)} — pass "
                    "share_devices=True for logically-separate meshes "
                    "over a shared pool (tests/single-host)")
        shape = (per_cell,)
    n_per = int(np.prod(shape))
    if axes is None:
        axes = ("data", "model")[:len(shape)] if len(shape) <= 2 else \
            ("pod", "data", "model")[:len(shape)]
    need = n_cells * n_per
    if len(devs) < need and not share_devices:
        raise RuntimeError(
            f"{n_cells} disjoint cells of shape {tuple(shape)} need "
            f"{need} devices, found {len(devs)} — pass "
            "share_devices=True for logically-separate meshes over a "
            "shared pool (tests/single-host), or force more host "
            "devices via XLA_FLAGS=--xla_force_host_platform_device_count")
    meshes = []
    for i in range(n_cells):
        if share_devices and len(devs) < need:
            block = [devs[(i * n_per + j) % len(devs)]
                     for j in range(n_per)]
        else:
            block = devs[i * n_per:(i + 1) * n_per]
        meshes.append(Mesh(np.asarray(block).reshape(shape), tuple(axes)))
    return meshes
