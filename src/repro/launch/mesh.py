"""Production meshes.

Functions, not module-level constants — importing this module never touches
jax device state.  The dry-run (and only the dry-run) forces 512 host
devices; tests and benches see the real single CPU device.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.distributed.sharding import (
    MULTI_POD_PLAN,
    SINGLE_POD_PLAN,
    ShardPlan,
)

__all__ = ["make_production_mesh", "make_plan", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single pod (256 chips) or 2x16x16 two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices, found {len(devs)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (dryrun.py does this)"
        )
    dev_array = np.asarray(devs[:n]).reshape(shape)
    return Mesh(dev_array, axes)


def make_plan(mesh: Mesh) -> ShardPlan:
    """Bind the role plan matching a mesh's axis names."""
    if "pod" in mesh.axis_names:
        return MULTI_POD_PLAN.with_mesh(mesh)
    return SINGLE_POD_PLAN.with_mesh(mesh)


def make_test_mesh(shape=(2, 4), axes=("data", "model")) -> Mesh:
    """Small mesh over however many fake devices a test forced."""
    n = int(np.prod(shape))
    dev_array = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(dev_array, axes)
