"""Registered jitted entry points for the recompile-stability gate.

Each entry point builds the real serving object on a 1-device mesh and
returns a :class:`repro.analysis.recompile.Plan` whose steps walk the
index through its online lifecycle — mutations, delta applies, reboosts
— while the jitted callable's compile cache is watched.  The invariant
under test is the stack's core claim: **the search (and scatter) jitted
at construction survives every mutation without a new compile**.

Registering a new entry point (see docs/analysis.md):

    from repro.analysis.recompile import Plan
    from repro.analysis.registry import register_entry_point

    @register_entry_point("my-kernel")
    def _my_kernel():
        thing = build_it()                     # compile happens here or
        steps = [("warmup", lambda: thing(x)), # in the warm-up step
                 ("mutate", lambda: mutate_and_call(thing))]
        return Plan(steps=steps, cache_size=thing.jit_cache_size)

Builders import jax lazily so the static passes never pay for it.
Corpora are small (the gate checks *signatures*, not quality) and every
shape-feeding size is kept inside the backend's headroom reservation —
an outgrown reservation is a loud rebuild, not a silent recompile, and
has its own test coverage.
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.analysis.recompile import Plan

__all__ = ["ENTRY_POINTS", "register_entry_point"]

ENTRY_POINTS: Dict[str, Callable[[], Plan]] = {}

_N, _D, _K = 96, 8, 4


def register_entry_point(name: str):
    """Register a Plan builder under ``name`` (last registration wins,
    so tests can shadow real entry points with seeded ones)."""

    def deco(builder: Callable[[], Plan]):
        ENTRY_POINTS[name] = builder
        return builder

    return deco


def _mesh1():
    import jax

    return jax.make_mesh((1,), ("data",))


def _corpus(rng, n):
    import numpy as np

    c = rng.normal(size=(8, _D)) * 4
    return (c[rng.integers(0, 8, n)]
            + rng.normal(size=(n, _D))).astype(np.float32)


def _index(rng, bottom: str):
    import numpy as np

    from repro.core.two_level import TwoLevelConfig, build_two_level

    db = _corpus(rng, _N)
    cfg = TwoLevelConfig(
        n_clusters=_K, top="brute", bottom=bottom, kmeans_iters=2,
        kmeans_minibatch=None, bucket_cap=64, tree_leaf=4,
        lsh_bits=16, pq_m=4)
    p = (rng.dirichlet(np.full(_N, 0.5)).astype(np.float64)
         if bottom == "qlbt" else None)
    return db, build_two_level(db, cfg, p=p)


def _localized_mutation(rng, idx):
    """Delete a few rows of the fullest bucket, add mass near another
    centroid — the canonical dirty-handful-of-buckets maintenance pass."""
    import numpy as np

    b = int(np.argmax(idx.bucket_counts))
    dele = np.asarray(idx.bucket_ids[b][:3]).copy()
    idx.delete_entities(dele)
    new = (np.asarray(idx.centroids[1])[None, :]
           + 0.1 * rng.normal(size=(3, _D))).astype(np.float32)
    idx.add_entities(new)


@register_entry_point("sharded-brute-search")
def _sharded_brute_search() -> Plan:
    import numpy as np

    from repro.distributed.backend import ShardedSearchBackend

    rng = np.random.default_rng(0)
    db = _corpus(rng, _N)
    be = ShardedSearchBackend(
        _mesh1(), db, kind="brute", k=5, axes=("data",), headroom=2.0)
    q = _corpus(rng, 4)
    grown = np.concatenate([db, _corpus(rng, 16)])
    alive = np.ones(grown.shape[0], bool)
    alive[:5] = False

    def grow():
        be.apply_updates(grown)
        be(q)

    def tombstone():
        be.apply_updates(grown, alive=alive)
        be(q)

    return Plan(
        steps=[("warmup-search", lambda: be(q)),
               ("full-republish-grown-corpus", grow),
               ("full-republish-tombstones", tombstone)],
        cache_size=be.jit_cache_size)


@register_entry_point("brute-delta-scatter")
def _brute_delta_scatter() -> Plan:
    import numpy as np

    from repro.core.delta import DeltaLog
    from repro.distributed.backend import ShardedSearchBackend

    rng = np.random.default_rng(1)
    db = _corpus(rng, 64)
    be = ShardedSearchBackend(
        _mesh1(), db, kind="brute", k=5, axes=("data",), headroom=2.0)
    log = DeltaLog(base_version=0, base_n=64)
    state = {"db": db, "version": 0}

    def apply_delta(n_append, n_tomb):
        def step():
            cur = state["db"]
            if n_append:
                state["db"] = np.concatenate(
                    [cur, _corpus(rng, n_append)])
            if n_tomb:
                log.mark_tombstones(
                    rng.choice(cur.shape[0], n_tomb, replace=False))
            state["version"] += 1
            man = log.pop(state["version"], state["db"].shape[0])
            st = be.apply_updates(state["db"], delta=man)
            assert st["mode"] == "delta", st

        return step

    # two warm-up shape buckets — append windows (rows pad to 4) and
    # tombstone-only windows (rows pad to 1) — then re-drive both:
    # same pow2 buckets, so the scatter must not compile again
    return Plan(
        steps=[("warmup-append-3-tombstone-2", apply_delta(3, 2)),
               ("warmup-tombstone-only-2", apply_delta(0, 2)),
               ("delta-append-4-tombstone-2", apply_delta(4, 2)),
               ("delta-tombstone-only-2", apply_delta(0, 2))],
        cache_size=lambda: (be._delta_fn._cache_size()
                            if be._delta_fn is not None else -1),
        warmup_steps=2)


@register_entry_point("sharded-ivf-search")
def _sharded_ivf_search() -> Plan:
    import numpy as np

    from repro.distributed.backend import ShardedSearchBackend

    rng = np.random.default_rng(2)
    _, idx = _index(rng, "brute")          # bucketed flat bottom -> IVF
    be = ShardedSearchBackend(
        _mesh1(), idx, k=5, axes=("data",), nprobe_local=_K,
        headroom=2.0)
    q = _corpus(rng, 4)

    def mutate_and_apply():
        _localized_mutation(rng, idx)
        be.apply_updates(idx, delta=idx.pop_delta())
        be(q)

    return Plan(
        steps=[("warmup-search", lambda: be(q)),
               ("delta-republish-1", mutate_and_apply),
               ("delta-republish-2", mutate_and_apply)],
        cache_size=be.jit_cache_size)


@register_entry_point("sharded-forest-search")
def _sharded_forest_search() -> Plan:
    import numpy as np

    from repro.distributed.backend import ShardedSearchBackend

    rng = np.random.default_rng(3)
    _, idx = _index(rng, "qlbt")           # per-bucket trees -> forest
    be = ShardedSearchBackend(
        _mesh1(), idx, k=5, axes=("data",), nprobe_local=_K,
        beam_width=8, headroom=1.5)
    q = _corpus(rng, 4)

    def mutate_and_apply():
        _localized_mutation(rng, idx)
        be.apply_updates(idx, delta=idx.pop_delta())
        be(q)

    def reboost_and_apply():
        n_now = int(idx.db.shape[0])
        idx.reboost(rng.dirichlet(np.full(n_now, 0.5)))
        be.apply_updates(idx, delta=idx.pop_delta())
        be(q)

    return Plan(
        steps=[("warmup-search", lambda: be(q)),
               ("delta-republish", mutate_and_apply),
               ("reboost-republish", reboost_and_apply)],
        cache_size=be.jit_cache_size)


@register_entry_point("fused-sharded-search")
def _fused_sharded_search() -> Plan:
    """PR-8 paths: ``fused=True`` routes the per-shard scan+top-k
    through the kernel dispatch (``repro.kernels.ops``) and
    ``precision="int8"`` additionally swaps the placed corpus for
    per-row-scaled codes.  Both callables jit at construction and must
    survive delta windows (scatters into the quantized corpus included)
    without a single new compile."""
    import numpy as np

    from repro.core.delta import DeltaLog
    from repro.distributed.backend import ShardedSearchBackend

    rng = np.random.default_rng(5)
    db = _corpus(rng, 64)
    be8 = ShardedSearchBackend(
        _mesh1(), db, kind="brute", k=5, axes=("data",), headroom=2.0,
        fused=True, precision="int8")
    _, idx = _index(rng, "brute")          # bucketed flat bottom -> IVF
    bei = ShardedSearchBackend(
        _mesh1(), idx, k=5, axes=("data",), nprobe_local=_K,
        headroom=2.0, fused=True)
    q = _corpus(rng, 4)
    log = DeltaLog(base_version=0, base_n=64)
    state = {"db": db, "version": 0}

    def int8_delta(n_append, n_tomb):
        def step():
            cur = state["db"]
            if n_append:
                state["db"] = np.concatenate([cur, _corpus(rng, n_append)])
            if n_tomb:
                log.mark_tombstones(
                    rng.choice(cur.shape[0], n_tomb, replace=False))
            state["version"] += 1
            man = log.pop(state["version"], state["db"].shape[0])
            st = be8.apply_updates(state["db"], delta=man)
            assert st["mode"] == "delta", st
            be8(q)

        return step

    def ivf_mutate():
        _localized_mutation(rng, idx)
        bei.apply_updates(idx, delta=idx.pop_delta())
        bei(q)

    def cache_size():
        sizes = [be8.jit_cache_size(), bei.jit_cache_size()]
        return -1 if any(s < 0 for s in sizes) else sum(sizes)

    return Plan(
        steps=[("warmup-fused-searches", lambda: (be8(q), bei(q))),
               ("warmup-int8-delta-append-3-tombstone-2", int8_delta(3, 2)),
               ("int8-delta-append-4-tombstone-2", int8_delta(4, 2)),
               ("fused-ivf-delta-republish-1", ivf_mutate),
               ("fused-ivf-delta-republish-2", ivf_mutate)],
        cache_size=cache_size,
        warmup_steps=2)


@register_entry_point("filtered-sharded-search")
def _filtered_sharded_search() -> Plan:
    """Filter + mode surface: predicates compile to mask *operands*
    (brute valid-AND, ivf/forest bucket-slot -1s) and hybrid alpha is a
    (1, 1) operand, so sweeping filters, modes, and alphas across delta
    windows must not mint one new executable beyond the three per-mode
    callables jitted at construction."""
    import numpy as np

    from repro.core.lexical import build_lexical_slabs, query_operands
    from repro.core.metadata import FilterSpec, MetadataTable
    from repro.distributed.backend import ShardedSearchBackend

    rng = np.random.default_rng(6)
    db = _corpus(rng, 64)
    meta = MetadataTable(
        {"cat": rng.integers(0, 4, 64).astype(np.int32)})
    docs = [list(rng.integers(0, 32, 5)) for _ in range(64)]
    slabs = build_lexical_slabs(docs, 32)
    beb = ShardedSearchBackend(
        _mesh1(), db, kind="brute", k=5, axes=("data",), headroom=2.0,
        metadata=meta, lexical=slabs)
    _, idx = _index(rng, "brute")          # bucketed flat bottom -> IVF
    imeta = MetadataTable(
        {"cat": rng.integers(0, 4, _N).astype(np.int32)})
    bei = ShardedSearchBackend(
        _mesh1(), idx, k=5, axes=("data",), nprobe_local=_K,
        headroom=2.0, metadata=imeta)
    q = _corpus(rng, 4)
    qt, qw = query_operands([docs[0], docs[1], docs[2], docs[3]], slabs)
    state = {"db": db, "round": 0}

    def sweep():
        # fresh predicates every round: each compiles to a new mask
        # operand and must hit the same executables
        r = state["round"]
        state["round"] += 1
        specs = (FilterSpec.eq("cat", r % 4),
                 FilterSpec.range("cat", 0, 1 + r % 3),
                 FilterSpec.isin("cat", (r % 4, (r + 1) % 4)))
        for fs in specs:
            beb(q, filter_spec=fs)
            bei(q, filter_spec=fs)
        beb(q, mode="lexical", q_terms=qt, q_weights=qw,
            filter_spec=specs[0])
        for alpha in (0.1 + 0.2 * r, 0.9):
            beb(q, mode="hybrid", alpha=alpha, q_terms=qt, q_weights=qw,
                filter_spec=specs[1])

    def mutate_and_sweep():
        # grow the brute corpus (+slabs +metadata) through a delta
        # window, then sweep filters over the post-delta state
        from repro.core.delta import DeltaManifest

        cur = state["db"]
        n0, n1 = cur.shape[0], cur.shape[0] + 4
        state["db"] = np.concatenate([cur, _corpus(rng, 4)])
        slabs.append_docs([list(rng.integers(0, 32, 5))
                           for _ in range(4)])
        meta.append_rows(
            {"cat": rng.integers(0, 4, 4).astype(np.int32)}, 4)
        man = DeltaManifest(
            base_version=0, version=1, base_n=n0, n=n1,
            dirty_buckets=np.zeros(0, np.int64),
            tombstones=np.asarray([1, 3], np.int64),
            lsh_rows_appended=0, full=False)
        beb.apply_updates(state["db"], delta=man)
        _localized_mutation(rng, idx)
        imeta.append_rows(
            {"cat": rng.integers(0, 4, 3).astype(np.int32)}, 3)
        bei.apply_updates(idx, delta=idx.pop_delta())
        sweep()

    def cache_size():
        sizes = [beb.jit_cache_size(), bei.jit_cache_size()]
        return -1 if any(s < 0 for s in sizes) else sum(sizes)

    return Plan(
        steps=[("warmup-filter-mode-sweep", sweep),
               ("filter-sweep-new-predicates", sweep),
               ("delta-republish-filter-sweep", mutate_and_sweep)],
        cache_size=cache_size)


@register_entry_point("fleet-router-search")
def _fleet_router_search() -> Plan:
    import numpy as np

    from repro.launch.mesh import make_cell_meshes
    from repro.serve.fleet import build_fleet

    rng = np.random.default_rng(4)
    _, idx = _index(rng, "brute")          # bucketed flat bottom -> IVF
    # two logically-separate cells over the gate's 1-device pool: each
    # owns a private ShardedSearchBackend with its own jit cache — the
    # invariant is that ROUTED traffic plus a leader fan-out keeps every
    # cell's search cache fixed, same as the single-backend entries
    meshes = make_cell_meshes(2, share_devices=True)
    router = build_fleet(
        meshes, idx, k=5,
        backend_kw={"nprobe_local": _K, "headroom": 2.0},
        cell_kw={"max_wait_ms": 1.0})
    qs = _corpus(rng, 8)

    def warmup():
        # the router batches blocking callers one at a time, so the
        # served shape is the 1-query pow2 bucket; warm it on EVERY
        # cell directly — rendezvous routing alone might leave a cell
        # cold and turn its first spill/hedge into a false recompile
        for cell in router.cells:
            cell.search_fn(qs[:1])
        for q in qs[:4]:
            router.search(q)

    def mutate_and_fanout():
        _localized_mutation(rng, idx)
        # leader contract: ONE pop, the same manifest to every cell
        router.apply_updates(idx)
        for q in qs[:4]:
            router.search(q)

    def cache_size():
        sizes = [c.search_fn.jit_cache_size() for c in router.cells]
        return -1 if any(s < 0 for s in sizes) else sum(sizes)

    return Plan(
        steps=[("warmup-routed-search", warmup),
               ("fleet-delta-fanout-1", mutate_and_fanout),
               ("fleet-delta-fanout-2", mutate_and_fanout)],
        cache_size=cache_size)
