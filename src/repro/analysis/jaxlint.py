"""JAX compile-path lint: AST rules over jit-traced function bodies.

The serving stack's latency claims assume the hot path never leaves the
device and never recompiles.  This pass finds the code patterns that
break those assumptions *statically*, before a trace ever runs:

``host-sync``
    Host synchronization on a traced value inside jitted code —
    ``.item()`` / ``.tolist()`` / ``.block_until_ready()``,
    ``float()/int()/bool()`` on a tracer, ``np.asarray``/``np.array`` of
    a tracer, ``jax.device_get``.  Each of these blocks the caller on
    device work and breaks the paper's latency model.
``traced-branch``
    Python ``if``/``while``/``assert`` on a traced *value* — the branch
    either fails at trace time or silently bakes one side into the
    compiled program.  Use ``jnp.where`` / ``lax.cond``.
``missing-static-argnames``
    The same branch pattern, but the traced value is a bare parameter of
    the jitted callee — the fix is declaring it in ``static_argnames``
    (and accepting a compile per distinct value) rather than rewriting
    the branch.
``implicit-dtype``
    ``jnp`` array creation without an explicit dtype inside jitted code.
    Implicit dtypes are how x64 promotion and weak-type widening sneak
    into a cached compile signature.
``scatter-not-donated``
    A jit-wrapped function scatters into one of its own array parameters
    (``p.at[...].set(...)``) and returns the result, but the ``jax.jit``
    wrapper declares no ``donate_argnums`` — on accelerators the update
    silently becomes a copy, doubling republish bandwidth.
``non-pow2-pad``
    A function that invokes a jitted callable pads an array's leading
    dim to a size not derived from a recognized shape-bucketing helper
    (``_pow2`` / ``_bucket`` / ``bit_length`` / ceil-to-multiple) — each
    distinct pad target becomes a fresh compile-cache entry.

Taint model (deliberately simple, intra-function): parameters of a
jitted function are traced unless named static; ``jnp``/``jax`` call
results are traced; ``.shape``/``.ndim``/``.dtype``/``.size`` and
``len()`` of anything are static.  Nested ``def``s inside a jitted
function (scan bodies, branches) are traced contexts too.  Helpers that
are only *called* from jitted code are out of scope — annotate them by
wrapping in ``jax.jit`` or accept the blind spot (documented in
docs/analysis.md).
"""
from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.core import STATIC_RULES, Finding

__all__ = ["check_module"]

STATIC_RULES.update({
    "host-sync": "host synchronization on a traced value in jitted code",
    "traced-branch": "Python branch on a traced value in jitted code",
    "missing-static-argnames":
        "branch on a jitted parameter that should be static_argnames",
    "implicit-dtype": "jnp array creation without explicit dtype in jit",
    "scatter-not-donated":
        "jitted in-place scatter into a parameter without donate_argnums",
    "non-pow2-pad":
        "pad at a jit boundary not derived from a shape-bucketing helper",
})

_UNTAINT_ATTRS = {"shape", "ndim", "dtype", "size", "weak_type"}
_UNTAINT_CALLS = {"len", "isinstance", "type", "range", "enumerate",
                  "zip", "getattr", "hasattr"}
_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_CAST_FUNCS = {"float", "int", "bool", "complex"}
_MUTATOR_NONE = frozenset()
_CREATION_MIN_POS = {  # positional index at which dtype appears
    "zeros": 1, "ones": 1, "empty": 1, "asarray": 1, "array": 1,
    "full": 2, "arange": 3, "linspace": 5,
}
_BUCKET_HELPERS = {"_pow2", "_bucket", "next_pow2", "pow2", "next_power_of_2"}
_PAD_FUNCS = {"pad"}
_PAD_HELPERS = {"_pad_rows"}


def _attr_path(node: ast.AST) -> Optional[str]:
    """Dotted name of an attribute/name chain, or None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jnp_path(path: Optional[str]) -> bool:
    return bool(path) and path.split(".")[0] in ("jnp", "jax", "lax")


def _is_np_path(path: Optional[str]) -> bool:
    return bool(path) and path.split(".")[0] in ("np", "numpy", "onp")


def _const_names(node: ast.AST) -> set:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


class _JitMarker:
    """How a function got jitted: static/donate info from the wrapper."""

    def __init__(self, static_names=(), static_nums=(), donated=False,
                 via_shard_map=False):
        self.static_names = set(static_names)
        self.static_nums = tuple(static_nums)
        self.donated = donated
        self.via_shard_map = via_shard_map


def _jit_call_info(call: ast.Call) -> Optional[_JitMarker]:
    """``jax.jit(...)`` / ``partial(jax.jit, ...)`` -> marker, else None."""
    path = _attr_path(call.func)
    if path in ("jax.jit", "jit"):
        return _marker_from_kwargs(call.keywords)
    if path in ("partial", "functools.partial") and call.args:
        inner = _attr_path(call.args[0])
        if inner in ("jax.jit", "jit"):
            return _marker_from_kwargs(call.keywords)
    return None


def _marker_from_kwargs(keywords) -> _JitMarker:
    static_names: list = []
    static_nums: list = []
    donated = False
    for kw in keywords:
        if kw.arg == "static_argnames":
            static_names.extend(
                c.value for c in ast.walk(kw.value)
                if isinstance(c, ast.Constant) and isinstance(c.value, str))
        elif kw.arg == "static_argnums":
            static_nums.extend(
                c.value for c in ast.walk(kw.value)
                if isinstance(c, ast.Constant) and isinstance(c.value, int))
        elif kw.arg in ("donate_argnums", "donate_argnames"):
            donated = True
    return _JitMarker(static_names, static_nums, donated)


def _collect_jitted(tree: ast.Module) -> dict:
    """name -> (_JitMarker) for every function the module jits.

    Three idioms are recognized: decorators (``@jax.jit``,
    ``@partial(jax.jit, ...)``), wrap sites (``jax.jit(fn, ...)``), and
    ``shard_map(fn, ...)`` (a shard-mapped body is traced the same way
    once the caller jits it — every sharded search fn here is).
    """
    marked: dict[str, _JitMarker] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                info = None
                if isinstance(dec, ast.Call):
                    info = _jit_call_info(dec)
                elif _attr_path(dec) in ("jax.jit", "jit"):
                    info = _JitMarker()
                if info is not None:
                    marked[node.name] = info
        elif isinstance(node, ast.Call):
            path = _attr_path(node.func)
            info = _jit_call_info(node)
            if info is not None and node.args and isinstance(
                    node.args[0], ast.Name):
                marked.setdefault(node.args[0].id, info)
            elif path is not None and path.split(".")[-1] == "shard_map" \
                    and node.args and isinstance(node.args[0], ast.Name):
                marked.setdefault(node.args[0].id,
                                  _JitMarker(via_shard_map=True))
    return marked


class _TaintChecker:
    """Walks one jitted function body, tracking which local names hold
    traced values, and emits host-sync / traced-branch / implicit-dtype
    findings."""

    def __init__(self, path: str, fn: ast.FunctionDef, marker: _JitMarker,
                 findings: list):
        self.path = path
        self.fn = fn
        self.findings = findings
        args = fn.args
        names = [a.arg for a in
                 args.posonlyargs + args.args + args.kwonlyargs]
        static = set(marker.static_names)
        static.update(names[i] for i in marker.static_nums
                      if 0 <= i < len(names))
        self.params = set(names)
        self.tainted = self.params - static

    # -- taint ---------------------------------------------------------
    def is_tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _UNTAINT_ATTRS:
                return False
            return self.is_tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_tainted(node.value)
        if isinstance(node, ast.Call):
            fpath = _attr_path(node.func)
            if fpath in _UNTAINT_CALLS:
                return False
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "bit_length":
                return False
            args_tainted = any(self.is_tainted(a) for a in node.args) or \
                any(self.is_tainted(kw.value) for kw in node.keywords)
            if isinstance(node.func, ast.Attribute) and \
                    self.is_tainted(node.func.value):
                return True                      # traced.method(...)
            return args_tainted
        if isinstance(node, (ast.BinOp,)):
            return self.is_tainted(node.left) or self.is_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_tainted(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.is_tainted(v) for v in node.values)
        if isinstance(node, ast.Compare):
            return self.is_tainted(node.left) or \
                any(self.is_tainted(c) for c in node.comparators)
        if isinstance(node, ast.IfExp):
            return any(self.is_tainted(n)
                       for n in (node.test, node.body, node.orelse))
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.is_tainted(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.is_tainted(node.value)
        return False

    def _bind(self, target: ast.AST, tainted: bool) -> None:
        if isinstance(target, ast.Name):
            if tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._bind(e, tainted)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, tainted)

    # -- rules ---------------------------------------------------------
    def _emit(self, rule: str, node: ast.AST, msg: str) -> None:
        self.findings.append(Finding(
            rule, self.path, node.lineno, node.col_offset + 1, msg))

    def _check_call(self, node: ast.Call) -> None:
        fpath = _attr_path(node.func)
        # host syncs
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr in _SYNC_METHODS and \
                self.is_tainted(node.func.value):
            self._emit("host-sync", node,
                       f".{node.func.attr}() on a traced value inside "
                       f"jitted '{self.fn.name}' blocks on device work — "
                       "keep the value on device or hoist it out of jit")
        elif fpath in _CAST_FUNCS and node.args and \
                self.is_tainted(node.args[0]):
            self._emit("host-sync", node,
                       f"{fpath}() on a traced value inside jitted "
                       f"'{self.fn.name}' forces a host sync — use "
                       "jnp.astype / keep it traced")
        elif _is_np_path(fpath) and fpath.split(".")[-1] in (
                "asarray", "array") and node.args and \
                self.is_tainted(node.args[0]):
            self._emit("host-sync", node,
                       f"{fpath}() materializes a traced value on host "
                       f"inside jitted '{self.fn.name}' — use jnp.asarray")
        elif fpath in ("jax.device_get",):
            self._emit("host-sync", node,
                       f"jax.device_get inside jitted '{self.fn.name}' "
                       "is a host round-trip")
        # implicit dtype on jnp creations
        if fpath and _is_jnp_path(fpath):
            base = fpath.split(".")[-1]
            if base in _CREATION_MIN_POS:
                has_dtype = any(kw.arg == "dtype" for kw in node.keywords) \
                    or len(node.args) > _CREATION_MIN_POS[base]
                if not has_dtype:
                    self._emit(
                        "implicit-dtype", node,
                        f"jnp.{base}(...) without an explicit dtype inside "
                        f"jitted '{self.fn.name}' — implicit dtypes let "
                        "promotion drift into the compile signature")

    def _branch_rule(self, node, test: ast.AST, kind: str) -> None:
        if not self.is_tainted(test):
            return
        names = _const_names(test)
        tainted_names = names & self.tainted
        if tainted_names and tainted_names <= self.params:
            self._emit(
                "missing-static-argnames", node,
                f"Python {kind} on traced parameter(s) "
                f"{sorted(tainted_names)} of jitted '{self.fn.name}' — "
                "declare them in static_argnames or rewrite with "
                "jnp.where/lax.cond")
        else:
            self._emit(
                "traced-branch", node,
                f"Python {kind} on a traced value inside jitted "
                f"'{self.fn.name}' — the branch is baked in at trace "
                "time; use jnp.where/lax.cond")

    def run(self) -> None:
        self._walk(self.fn.body)

    def _walk(self, body) -> None:
        for stmt in body:
            self._stmt(stmt)

    def _exprs_in(self, node: ast.AST) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                self._check_call(sub)

    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested defs (scan bodies, cond branches) are traced too:
            # their array params come in as tracers
            _TaintChecker(self.path, stmt, _JitMarker(),
                          self.findings).run()
            return
        if isinstance(stmt, ast.Assign):
            self._exprs_in(stmt.value)
            t = self.is_tainted(stmt.value)
            for target in stmt.targets:
                self._bind(target, t)
            return
        if isinstance(stmt, ast.AugAssign):
            self._exprs_in(stmt.value)
            if self.is_tainted(stmt.value):
                self._bind(stmt.target, True)
            return
        if isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._exprs_in(stmt.value)
            self._bind(stmt.target, self.is_tainted(stmt.value))
            return
        if isinstance(stmt, ast.If):
            self._exprs_in(stmt.test)
            self._branch_rule(stmt, stmt.test, "if")
            self._walk(stmt.body)
            self._walk(stmt.orelse)
            return
        if isinstance(stmt, ast.While):
            self._exprs_in(stmt.test)
            self._branch_rule(stmt, stmt.test, "while")
            self._walk(stmt.body)
            self._walk(stmt.orelse)
            return
        if isinstance(stmt, ast.Assert):
            self._exprs_in(stmt.test)
            self._branch_rule(stmt, stmt.test, "assert")
            return
        if isinstance(stmt, ast.For):
            self._exprs_in(stmt.iter)
            if self.is_tainted(stmt.iter):
                self._emit(
                    "traced-branch", stmt,
                    f"Python for-loop over a traced value inside jitted "
                    f"'{self.fn.name}' — unrolls (or fails) at trace "
                    "time; use lax.scan/fori_loop")
                self._bind(stmt.target, True)
            self._walk(stmt.body)
            self._walk(stmt.orelse)
            return
        if isinstance(stmt, (ast.With,)):
            for item in stmt.items:
                self._exprs_in(item.context_expr)
            self._walk(stmt.body)
            return
        if isinstance(stmt, (ast.Try,)):
            self._walk(stmt.body)
            for h in stmt.handlers:
                self._walk(h.body)
            self._walk(stmt.orelse)
            self._walk(stmt.finalbody)
            return
        # Return / Expr / Raise / Pass / etc: just scan calls
        self._exprs_in(stmt)


def _check_scatter_donation(path: str, fn: ast.FunctionDef,
                            marker: _JitMarker, findings: list) -> None:
    """``scatter-not-donated``: a directly-jitted fn that updates one of
    its own parameters in place must donate it."""
    if marker.donated or marker.via_shard_map:
        return
    params = {a.arg for a in fn.args.posonlyargs + fn.args.args
              + fn.args.kwonlyargs}
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute) and node.attr == "at" and \
                isinstance(node.value, ast.Name) and \
                node.value.id in params:
            findings.append(Finding(
                "scatter-not-donated", path, node.lineno,
                node.col_offset + 1,
                f"jitted '{fn.name}' scatters into parameter "
                f"'{node.value.id}' but the jax.jit wrapper declares no "
                "donate_argnums — on accelerators the in-place update "
                "becomes a copy"))
            return


# ---------------------------------------------------------------------------
# non-pow2-pad: pads feeding jitted callables must come from a bucketer
# ---------------------------------------------------------------------------


def _is_bucketed_expr(node: ast.AST, bucketed: set) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            fpath = _attr_path(sub.func)
            if fpath and fpath.split(".")[-1] in _BUCKET_HELPERS:
                return True
            if isinstance(sub.func, ast.Attribute) and \
                    sub.func.attr == "bit_length":
                return True
        if isinstance(sub, ast.Name) and sub.id in bucketed:
            return True
        if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mult):
            # ceil-to-multiple: -(-a // b) * b
            for side in (sub.left, sub.right):
                if any(isinstance(x, ast.BinOp)
                       and isinstance(x.op, ast.FloorDiv)
                       for x in ast.walk(side)):
                    return True
        if isinstance(sub, ast.Constant) and isinstance(sub.value, int):
            if sub.value > 0 and (sub.value & (sub.value - 1)) == 0:
                return True
    return False


def _check_pads(path: str, fn: ast.FunctionDef, jitted_names: set,
                findings: list) -> None:
    calls_jit = False
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            fpath = _attr_path(node.func)
            if fpath is None:
                continue
            leaf = fpath.split(".")[-1]
            if leaf in ("_fn", "_delta_fn") or leaf in jitted_names:
                calls_jit = True
    if not calls_jit:
        return
    bucketed: set = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and \
                _is_bucketed_expr(node.value, bucketed):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    bucketed.add(t.id)
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        fpath = _attr_path(node.func)
        if fpath is None:
            continue
        leaf = fpath.split(".")[-1]
        size_expr = None
        if leaf in _PAD_HELPERS and len(node.args) >= 2:
            size_expr = node.args[1]
        elif leaf in _PAD_FUNCS and len(node.args) >= 2:
            size_expr = node.args[1]
        if size_expr is None:
            continue
        names = _const_names(size_expr)
        if not names:
            continue                      # constant pad: shape is fixed
        if _is_bucketed_expr(size_expr, bucketed):
            continue
        findings.append(Finding(
            "non-pow2-pad", path, node.lineno, node.col_offset + 1,
            f"'{fn.name}' pads an operand of a jitted callable to a size "
            "not derived from a shape-bucketing helper (_pow2/_bucket/"
            "ceil-to-multiple) — every distinct size is a fresh compile"))


def check_module(path: str, tree: ast.Module) -> list:
    findings: list = []
    marked = _collect_jitted(tree)
    fns = [n for n in ast.walk(tree)
           if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    jitted_names = set(marked)
    for fn in fns:
        marker = marked.get(fn.name)
        if marker is not None:
            _TaintChecker(path, fn, marker, findings).run()
            _check_scatter_donation(path, fn, marker, findings)
        else:
            _check_pads(path, fn, jitted_names, findings)
    return findings
