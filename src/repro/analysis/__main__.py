"""CLI: ``python -m repro.analysis [paths...] [--strict]``.

Exit code 0 when no unsuppressed finding remains, 1 otherwise — the CI
``lint`` job runs ``python -m repro.analysis src/repro --strict`` as a
blocking gate.  Without ``--strict`` only the pure-AST passes run (no
jax import, sub-second); ``--strict`` adds the dynamic recompile gate,
which builds real backends on a 1-device mesh.
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    # must be set before any jax import: the TPU plugin probe hangs on
    # hosts without an accelerator
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="compile-path & concurrency lint for the repro stack")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files/directories to analyze (default: src/repro)")
    ap.add_argument("--strict", action="store_true",
                    help="also run the dynamic recompile-stability gate "
                         "(imports jax, drives real backends)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to report "
                         "(suppression-hygiene rules always run)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    # importing the checkers populates the full rule catalog
    from repro.analysis import core
    from repro.analysis import jaxlint, locks  # noqa: F401

    if args.list_rules:
        width = max(len(r) for r in core.STATIC_RULES)
        for rid in sorted(core.STATIC_RULES):
            print(f"{rid:<{width}}  {core.STATIC_RULES[rid]}")
        return 0

    extra = []
    if args.strict:
        from repro.analysis.recompile import run_recompile_gate

        extra = run_recompile_gate()

    rules = (set(r.strip() for r in args.rules.split(",") if r.strip())
             if args.rules else None)
    active, suppressed = core.run_static_analysis(
        args.paths, rules=rules, extra_findings=extra)
    for f in active:
        print(f.format())
    print(f"{len(active)} finding(s), {len(suppressed)} suppressed"
          + (" [strict]" if args.strict else ""),
          file=sys.stderr)
    return 1 if active else 0


if __name__ == "__main__":
    raise SystemExit(main())
