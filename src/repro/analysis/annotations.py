"""Declarative concurrency annotations consumed by `repro.analysis.locks`.

``@guarded_by("lock_attr")`` documents that a method must only run while
``self.<lock_attr>`` is held.  At runtime it is a no-op (zero overhead on
the serving hot path); the static lock checker uses it two ways:

* the method body is analyzed as if the lock were held, and
* every call site of the method inside the class must itself be
  dominated by ``with self.<lock_attr>:`` (or sit in another
  ``@guarded_by`` method for the same lock) — otherwise the checker
  reports ``unguarded-call``.

``__init__`` is exempt everywhere: the object is unpublished there, so
writes and guarded-method calls are safe by happens-before.
"""
from __future__ import annotations

__all__ = ["guarded_by"]


def guarded_by(lock_attr: str):
    """Mark a method as requiring ``self.<lock_attr>`` to be held."""

    def deco(fn):
        fn.__guarded_by__ = lock_attr
        return fn

    return deco
