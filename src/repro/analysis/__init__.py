"""Compile-path & concurrency lint for the repro stack.

Three rule families enforce the invariants the serving-latency claims
rest on (see docs/analysis.md):

* :mod:`repro.analysis.jaxlint` — no host syncs / traced branches /
  implicit dtypes / undonated scatters / unbucketed pads in jitted code;
* :mod:`repro.analysis.locks` — every write to a shared attribute is
  dominated by the class's designated lock (``@guarded_by`` declares
  methods that require it);
* :mod:`repro.analysis.recompile` — registered jitted entry points keep
  a fixed compile-signature set across mutation-perturbed shapes.

CLI: ``python -m repro.analysis src/repro --strict`` (the CI lint gate).
Suppress a finding inline with ``# repro: allow(<rule>): <why>``.
"""
from repro.analysis.annotations import guarded_by
from repro.analysis.core import (
    STATIC_RULES,
    Finding,
    Suppression,
    collect_suppressions,
    run_static_analysis,
)

__all__ = [
    "Finding", "Suppression", "collect_suppressions",
    "run_static_analysis", "STATIC_RULES", "guarded_by",
]
