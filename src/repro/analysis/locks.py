"""Lock-discipline checker: every write to a shared attribute must be
dominated by the class's designated lock.

The serving stack runs three kinds of threads against the same objects —
the engine's micro-batch worker, the maintenance daemon, and caller
threads invoking ``search``/``apply_updates``/``stats``.  This pass
checks, per class:

1. **Designated locks** — attributes assigned ``threading.Lock()`` /
   ``RLock()`` / ``Condition()`` in ``__init__`` (e.g. ``self._lock``).
   Classes without one are skipped: no declared discipline, nothing to
   enforce (attach a lock or a ``@guarded_by`` method to opt in).
2. **Guarded attributes** — inferred: any attribute written under
   ``with self.<lock>:`` (or inside a ``@guarded_by``-annotated method)
   anywhere in the class, outside ``__init__``, is shared mutable state
   guarded by that lock.
3. **Write sites** — plain assigns, aug-assigns, subscript stores, and
   mutator-method calls (``append``/``extend``/``pop``/``update``/...)
   on a guarded attribute.  Each must be dominated by the guarding
   lock's ``with`` block or sit in a method annotated
   ``@guarded_by("<lock>")``.

Rules:

``unguarded-write``
    A write to a guarded attribute outside the lock.
``unguarded-call``
    A call to a ``@guarded_by`` method from class code that does not
    hold the lock.
``unknown-lock``
    ``@guarded_by("x")`` naming an attribute that is not a designated
    lock of the class.

Exemptions baked into the model (not suppressions):

* ``__init__`` — the object is unpublished; happens-before on thread
  start makes initialization writes safe.
* Nested ``def``s inside a method are analyzed with an *empty* held-lock
  set even when the enclosing block holds the lock: closures here are
  thread targets (``_dispatch``'s hedge primary) and run later, without
  the lock.
* **Internally-locked instruments** — attributes assigned from a
  ``repro.obs`` constructor in ``__init__`` (``MetricsRegistry()``,
  ``Tracer()``, ``Counter``/``Gauge``/``Histogram``, or a registry's
  ``counter()``/``gauge()``/``histogram()`` get-or-create).  Every obs
  instrument owns a private lock and serializes its own mutations, so
  the class-level lock discipline does not apply to them — no
  ``# repro: allow`` waiver needed at the call sites.
"""
from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.core import STATIC_RULES, Finding

__all__ = ["check_module"]

STATIC_RULES.update({
    "unguarded-write":
        "write to a lock-guarded attribute outside the designated lock",
    "unguarded-call":
        "call to a @guarded_by method without holding its lock",
    "unknown-lock":
        "@guarded_by names an attribute that is not a designated lock",
})

_LOCK_CTORS = {"Lock", "RLock", "Condition"}
_MUTATORS = {"append", "extend", "insert", "remove", "pop", "popitem",
             "clear", "update", "add", "discard", "setdefault",
             "appendleft", "popleft"}
# repro.obs instrument constructors: classes (MetricsRegistry(),
# Tracer(), Counter/Gauge/Histogram(...)) and the registry's
# get-or-create methods (self.metrics.counter("x"), ...).  An attribute
# initialized from one of these in __init__ is *internally locked* — the
# instrument serializes its own mutations — so the class's lock
# discipline is not inferred from (or enforced on) writes to it.
_OBS_CTORS = {"MetricsRegistry", "Tracer", "Counter", "Gauge",
              "Histogram", "counter", "gauge", "histogram"}


def _self_attr(node: ast.AST) -> Optional[str]:
    """``self.X`` -> ``X``, else None."""
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def _call_name(node: ast.AST) -> Optional[str]:
    """Terminal callee name of a Call (``a.b.c(...)`` -> ``c``)."""
    if not isinstance(node, ast.Call):
        return None
    path = node.func
    return path.attr if isinstance(path, ast.Attribute) else \
        path.id if isinstance(path, ast.Name) else None


def _is_lock_ctor(node: ast.AST) -> bool:
    return _call_name(node) in _LOCK_CTORS


def _is_obs_ctor(node: ast.AST) -> bool:
    return _call_name(node) in _OBS_CTORS


def _guarded_by_of(fn: ast.FunctionDef) -> Optional[str]:
    for dec in fn.decorator_list:
        if isinstance(dec, ast.Call):
            name = dec.func.attr if isinstance(dec.func, ast.Attribute) \
                else dec.func.id if isinstance(dec.func, ast.Name) else None
            if name == "guarded_by" and dec.args and \
                    isinstance(dec.args[0], ast.Constant) and \
                    isinstance(dec.args[0].value, str):
                return dec.args[0].value
    return None


def _iter_writes(node: ast.AST):
    """Yield ``(attr, node)`` for every self-attribute write in ``node``
    (non-recursive into nested defs — caller controls that)."""
    if isinstance(node, ast.Assign):
        for t in node.targets:
            yield from _targets(t)
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        yield from _targets(node.target)
    elif isinstance(node, ast.Expr) and isinstance(node.value, ast.Call):
        call = node.value
        if isinstance(call.func, ast.Attribute) and \
                call.func.attr in _MUTATORS:
            attr = _self_attr(call.func.value)
            if attr is not None:
                yield attr, node


def _targets(t: ast.AST):
    if isinstance(t, (ast.Tuple, ast.List)):
        for e in t.elts:
            yield from _targets(e)
        return
    if isinstance(t, ast.Starred):
        yield from _targets(t.value)
        return
    attr = _self_attr(t)
    if attr is not None:
        yield attr, t
        return
    # subscript store on a self attribute: self.x[k] = v
    if isinstance(t, ast.Subscript):
        attr = _self_attr(t.value)
        if attr is not None:
            yield attr, t


class _ClassChecker:
    def __init__(self, path: str, cls: ast.ClassDef, findings: list):
        self.path = path
        self.cls = cls
        self.findings = findings
        self.methods = [n for n in cls.body
                        if isinstance(n, (ast.FunctionDef,
                                          ast.AsyncFunctionDef))]
        self.locks = self._designated_locks()
        self.internally_locked = self._internally_locked()
        self.guarded_methods = {m.name: g for m in self.methods
                                if (g := _guarded_by_of(m)) is not None}

    def _init_assigns(self, pred):
        out = set()
        for m in self.methods:
            if m.name != "__init__":
                continue
            for node in ast.walk(m):
                if isinstance(node, ast.Assign) and pred(node.value):
                    for t in node.targets:
                        attr = _self_attr(t)
                        if attr is not None:
                            out.add(attr)
        return out

    def _designated_locks(self) -> set:
        return self._init_assigns(_is_lock_ctor)

    def _internally_locked(self) -> set:
        """Attrs holding a repro.obs instrument: each owns a private
        lock, so the class lock discipline is neither inferred from nor
        enforced on writes to them."""
        return self._init_assigns(_is_obs_ctor)

    # -- pass 1: infer guarded attributes ------------------------------
    def _infer_guarded(self) -> dict:
        """attr -> set of locks it has been seen written under."""
        guarded: dict[str, set] = {}

        def note(attr, lock):
            if attr in self.internally_locked:
                return
            guarded.setdefault(attr, set()).add(lock)

        for m in self.methods:
            if m.name == "__init__":
                continue
            held0 = set()
            g = self.guarded_methods.get(m.name)
            if g in self.locks:
                held0.add(g)
            self._walk_infer(m.body, held0, note)
        return guarded

    def _walk_infer(self, body, held: set, note) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._walk_infer(stmt.body, set(), note)
                continue
            if isinstance(stmt, ast.With):
                inner = set(held)
                for item in stmt.items:
                    attr = _self_attr(item.context_expr)
                    if attr in self.locks:
                        inner.add(attr)
                self._walk_infer(stmt.body, inner, note)
                continue
            for attr, _node in _iter_writes(stmt):
                for lock in held:
                    note(attr, lock)
            # recurse into compound statements, preserving held set
            for field in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, field, None)
                if sub:
                    self._walk_infer(sub, held, note)
            for h in getattr(stmt, "handlers", ()):
                self._walk_infer(h.body, held, note)

    # -- pass 2: check every write / guarded call ----------------------
    def check(self) -> None:
        for name, lock in self.guarded_methods.items():
            if lock not in self.locks:
                m = next(m for m in self.methods if m.name == name)
                self.findings.append(Finding(
                    "unknown-lock", self.path, m.lineno, m.col_offset + 1,
                    f"@guarded_by('{lock}') on {self.cls.name}.{name} "
                    f"names no designated lock of the class "
                    f"(designated: {sorted(self.locks) or 'none'})"))
        if not self.locks:
            return
        guarded = self._infer_guarded()
        for m in self.methods:
            if m.name == "__init__":
                continue
            held0 = set()
            g = self.guarded_methods.get(m.name)
            if g in self.locks:
                held0.add(g)
            self._walk_check(m, m.body, held0, guarded)

    def _walk_check(self, method, body, held: set, guarded: dict) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # closures may run on another thread, without the lock
                self._walk_check(method, stmt.body, set(), guarded)
                continue
            if isinstance(stmt, ast.With):
                inner = set(held)
                for item in stmt.items:
                    attr = _self_attr(item.context_expr)
                    if attr in self.locks:
                        inner.add(attr)
                self._walk_check(method, stmt.body, inner, guarded)
                continue
            for attr, node in _iter_writes(stmt):
                if attr in self.internally_locked:
                    continue
                locks_for = guarded.get(attr)
                if locks_for and not (held & locks_for):
                    self.findings.append(Finding(
                        "unguarded-write", self.path, node.lineno,
                        node.col_offset + 1,
                        f"{self.cls.name}.{method.name} writes "
                        f"self.{attr} without holding "
                        f"{'/'.join(sorted(locks_for))} (other sites "
                        "write it under the lock)"))
            # scan only this statement's own expressions for guarded
            # calls — sub-statements are visited by the recursion below,
            # with their correct held-lock set
            compound = isinstance(stmt, (ast.If, ast.While, ast.For,
                                         ast.Try))
            if compound:
                headers = [getattr(stmt, "test", None),
                           getattr(stmt, "iter", None)]
                exprs = [h for h in headers if h is not None]
            else:
                exprs = [stmt]
            for expr in exprs:
                for node in ast.walk(expr):
                    if isinstance(node, ast.Call) and \
                            isinstance(node.func, ast.Attribute):
                        callee = _self_attr(node.func)
                        lock = self.guarded_methods.get(callee)
                        if lock in self.locks and lock not in held:
                            self.findings.append(Finding(
                                "unguarded-call", self.path, node.lineno,
                                node.col_offset + 1,
                                f"{self.cls.name}.{method.name} calls "
                                f"@guarded_by('{lock}') method "
                                f"self.{callee}() without holding "
                                f"self.{lock}"))
            for field in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, field, None)
                if sub:
                    self._walk_check(method, sub, held, guarded)
            for h in getattr(stmt, "handlers", ()):
                self._walk_check(method, h.body, held, guarded)


def check_module(path: str, tree: ast.Module) -> list:
    findings: list = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            _ClassChecker(path, node, findings).check()
    return findings
