"""Finding/reporting core shared by every `repro.analysis` rule family.

The analyzer turns the stack's prose invariants ("no re-jit through
mutations", "pow2-padded scatters", "apply_updates under the backend
lock") into machine-checked findings.  This module owns the pieces every
rule family shares:

* :class:`Finding` — one violation: rule id, location, message;
* inline suppressions — ``# repro: allow(<rule>): <justification>`` on
  the offending line (or the line directly above it).  A suppression
  **must** carry a justification; a bare ``allow`` is itself reported
  (``bad-suppression``), and a suppression that never matches a finding
  is reported too (``unused-suppression``) so stale waivers can't
  accumulate;
* file walking + the driver that runs the static rule families and
  reconciles findings against suppressions.

The static passes are pure-AST — they never import jax — so the lint
stays fast and runs anywhere.  The dynamic recompile gate
(:mod:`repro.analysis.recompile`) is layered on top by the CLI.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Iterable, Optional

__all__ = [
    "Finding", "Suppression", "collect_suppressions", "iter_py_files",
    "run_static_analysis", "STATIC_RULES",
]

# one catalog for --list-rules and docs/analysis.md; checkers register
# their ids here so an unknown id in an allow() is caught early
STATIC_RULES: dict[str, str] = {
    "bad-suppression":
        "a `# repro: allow(...)` without a one-line justification",
    "unknown-rule":
        "a suppression names a rule id the analyzer does not define",
    "unused-suppression":
        "a suppression that matched no finding (stale waiver)",
    "parse-error": "a file the analyzer could not read or parse",
    # dynamic (recompile-gate) rule ids, reported via --strict:
    "recompile":
        "a registered jitted entry point recompiled across "
        "mutation-perturbed shapes",
    "entry-point-error":
        "a registered recompile-gate entry point failed to run",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: " \
               f"{self.message}"


@dataclasses.dataclass
class Suppression:
    """One inline ``# repro: allow(rule[, rule...]): justification``."""

    path: str
    line: int               # line the comment sits on
    rules: tuple
    justification: str
    used: bool = False

    def covers(self, finding: Finding) -> bool:
        """A suppression covers findings on its own line and on the line
        directly below it (the standalone-comment-above idiom)."""
        return (finding.path == self.path
                and finding.rule in self.rules
                and finding.line in (self.line, self.line + 1))


_ALLOW_RE = re.compile(
    r"#\s*repro:\s*allow\(\s*([\w\-, ]+?)\s*\)\s*[:—-]?\s*(.*)$")


def collect_suppressions(path: str, source: str) -> list[Suppression]:
    out = []
    for i, line in enumerate(source.splitlines(), start=1):
        m = _ALLOW_RE.search(line)
        if m:
            rules = tuple(r.strip() for r in m.group(1).split(",")
                          if r.strip())
            out.append(Suppression(path=path, line=i, rules=rules,
                                   justification=m.group(2).strip()))
    return out


def iter_py_files(paths: Iterable[str]) -> list[str]:
    files = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
        else:
            for root, dirs, names in os.walk(p):
                dirs[:] = [d for d in dirs
                           if d not in ("__pycache__", ".git")]
                files.extend(os.path.join(root, n)
                             for n in sorted(names) if n.endswith(".py"))
    return sorted(files)


def _suppression_findings(sups: list[Suppression],
                          known_rules: set) -> list[Finding]:
    out = []
    for s in sups:
        if not s.justification:
            out.append(Finding(
                "bad-suppression", s.path, s.line, 1,
                f"allow({', '.join(s.rules)}) carries no justification — "
                "say why the violation is acceptable"))
        for r in s.rules:
            if r not in known_rules:
                out.append(Finding(
                    "unknown-rule", s.path, s.line, 1,
                    f"allow({r}) names an unknown rule id"))
    return out


def run_static_analysis(
    paths: Iterable[str],
    *,
    rules: Optional[set] = None,
    extra_findings: Iterable[Finding] = (),
    flag_unused: bool = True,
) -> tuple[list[Finding], list[Finding]]:
    """Run the static rule families over ``paths``.

    Returns ``(active, suppressed)`` findings.  ``extra_findings`` lets
    the CLI merge dynamic (recompile-gate) findings into the same
    suppression reconciliation.  ``rules`` restricts which rule ids are
    reported (suppression hygiene rules always run).
    """
    from repro.analysis.jaxlint import check_module as check_jax
    from repro.analysis.locks import check_module as check_locks

    known = set(STATIC_RULES)
    findings: list[Finding] = list(extra_findings)
    suppressions: list[Suppression] = []
    for path in iter_py_files(paths):
        try:
            source = open(path, encoding="utf-8").read()
            tree = ast.parse(source, filename=path)
        except (OSError, SyntaxError) as e:
            findings.append(Finding("parse-error", path, 1, 1, str(e)))
            continue
        sups = collect_suppressions(path, source)
        suppressions.extend(sups)
        findings.extend(_suppression_findings(sups, known))
        findings.extend(check_jax(path, tree))
        findings.extend(check_locks(path, tree))

    if rules is not None:
        hygiene = {"bad-suppression", "unknown-rule", "unused-suppression",
                   "parse-error"}
        findings = [f for f in findings
                    if f.rule in rules or f.rule in hygiene]

    active, suppressed = [], []
    for f in findings:
        hit = next((s for s in suppressions if s.covers(f)), None)
        if hit is None:
            active.append(f)
        else:
            hit.used = True
            suppressed.append(f)
    if flag_unused:
        for s in suppressions:
            if not s.used:
                active.append(Finding(
                    "unused-suppression", s.path, s.line, 1,
                    f"allow({', '.join(s.rules)}) matched no finding — "
                    "drop the stale waiver"))
    active.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return active, suppressed
