"""Recompile-stability gate: registered jitted entry points must keep a
fixed compile-signature set across mutation-perturbed shapes.

``test_mutation.py`` spot-checks "no re-jit through mutations" for a few
hand-picked flows; this gate makes the claim exhaustive per entry point.
Each entry in :mod:`repro.analysis.registry` builds a real backend on a
1-device mesh and returns a :class:`Plan` — an ordered list of
``(label, thunk)`` steps (searches, mutations, delta applies, reboosts)
plus a ``cache_size`` probe for the jitted callable under test.  The
runner executes the steps in order, snapshots the compiled-variant count
after the first (warm-up) step, and reports a ``recompile`` finding for
every later step that changes it — with the step label, so the diff
names the mutation that introduced the new compile trigger.

Findings carry the source location of the entry point's builder in
``registry.py`` so they participate in the same suppression mechanism
as the static rules.  A builder or step that *raises* is reported as
``entry-point-error`` — a gate that silently skips a broken entry point
would report stability it never measured.

The gate logs through :mod:`repro.obs`: every run records per-step wall
times and compile counts into the process-wide ``PROFILE`` registry and
emits ``gate.entry-point``/``gate.step`` spans, so a gate run under an
active tracer shows up on the same Perfetto timeline as the serving
traffic it certifies.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Iterable, Optional

from repro.analysis.core import Finding
from repro.obs.profile import PROFILE
from repro.obs.trace import get_tracer

__all__ = ["Plan", "run_entry_point", "run_recompile_gate"]


@dataclasses.dataclass
class Plan:
    """One entry point's executable stability plan.

    steps      : ordered ``(label, thunk)`` pairs; the first
                 ``warmup_steps`` are warm-ups (their compiles are
                 expected — one per distinct pow2 shape bucket the entry
                 point legitimately serves)
    cache_size : zero-arg probe returning the compiled-variant count of
                 the jitted callable under test (< 0 = not measurable on
                 this jax version; the plan is skipped)
    """

    steps: list
    cache_size: Callable[[], int]
    warmup_steps: int = 1


def _loc(builder) -> tuple:
    code = builder.__code__
    path = code.co_filename
    rel = os.path.relpath(path)
    return (rel if not rel.startswith("..") else path,
            code.co_firstlineno)


def run_entry_point(name: str, builder: Callable[[], Plan]) -> list:
    path, line = _loc(builder)
    tracer = get_tracer()
    h_step = PROFILE.histogram("gate_step_ms", lo=1e-3, hi=1e7)
    with tracer.span("gate.entry-point", entry=name) as esp:
        try:
            plan = builder()
        except Exception as e:
            esp.set(error=type(e).__name__)
            return [Finding(
                "entry-point-error", path, line, 1,
                f"{name}: builder failed: {e!r}")]
        findings: list = []
        baseline: Optional[int] = None
        for step_i, (label, thunk) in enumerate(plan.steps):
            t0 = time.perf_counter()
            try:
                with tracer.span("gate.step", entry=name, step=label):
                    thunk()
            except Exception as e:
                findings.append(Finding(
                    "entry-point-error", path, line, 1,
                    f"{name}: step '{label}' failed: {e!r}"))
                return findings
            h_step.observe((time.perf_counter() - t0) * 1e3)
            size = plan.cache_size()
            if size < 0:
                return findings      # no cache introspection: skip
            if step_i < plan.warmup_steps or baseline is None:
                baseline = size      # warm-up compiles are expected
            elif size != baseline:
                PROFILE.counter("gate_recompiles").inc()
                tracer.instant("gate-recompile", entry=name, step=label,
                               variants=size)
                findings.append(Finding(
                    "recompile", path, line, 1,
                    f"{name}: step '{label}' changed the "
                    f"compile-signature set ({baseline} -> {size} cached "
                    "variants) — a mutation-perturbed shape reached the "
                    "jitted entry point"))
                baseline = size      # report each new trigger once
        esp.set(steps=len(plan.steps),
                variants=baseline if baseline is not None else -1)
    return findings


def run_recompile_gate(entry_points: Optional[Iterable[str]] = None) -> list:
    """Run every registered entry point (or the named subset)."""
    from repro.analysis.registry import ENTRY_POINTS

    names = sorted(ENTRY_POINTS) if entry_points is None \
        else list(entry_points)
    findings: list = []
    for name in names:
        findings.extend(run_entry_point(name, ENTRY_POINTS[name]))
    return findings
