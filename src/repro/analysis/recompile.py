"""Recompile-stability gate: registered jitted entry points must keep a
fixed compile-signature set across mutation-perturbed shapes.

``test_mutation.py`` spot-checks "no re-jit through mutations" for a few
hand-picked flows; this gate makes the claim exhaustive per entry point.
Each entry in :mod:`repro.analysis.registry` builds a real backend on a
1-device mesh and returns a :class:`Plan` — an ordered list of
``(label, thunk)`` steps (searches, mutations, delta applies, reboosts)
plus a ``cache_size`` probe for the jitted callable under test.  The
runner executes the steps in order, snapshots the compiled-variant count
after the first (warm-up) step, and reports a ``recompile`` finding for
every later step that changes it — with the step label, so the diff
names the mutation that introduced the new compile trigger.

Findings carry the source location of the entry point's builder in
``registry.py`` so they participate in the same suppression mechanism
as the static rules.  A builder or step that *raises* is reported as
``entry-point-error`` — a gate that silently skips a broken entry point
would report stability it never measured.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Iterable, Optional

from repro.analysis.core import Finding

__all__ = ["Plan", "run_entry_point", "run_recompile_gate"]


@dataclasses.dataclass
class Plan:
    """One entry point's executable stability plan.

    steps      : ordered ``(label, thunk)`` pairs; the first
                 ``warmup_steps`` are warm-ups (their compiles are
                 expected — one per distinct pow2 shape bucket the entry
                 point legitimately serves)
    cache_size : zero-arg probe returning the compiled-variant count of
                 the jitted callable under test (< 0 = not measurable on
                 this jax version; the plan is skipped)
    """

    steps: list
    cache_size: Callable[[], int]
    warmup_steps: int = 1


def _loc(builder) -> tuple:
    code = builder.__code__
    path = code.co_filename
    rel = os.path.relpath(path)
    return (rel if not rel.startswith("..") else path,
            code.co_firstlineno)


def run_entry_point(name: str, builder: Callable[[], Plan]) -> list:
    path, line = _loc(builder)
    try:
        plan = builder()
    except Exception as e:
        return [Finding(
            "entry-point-error", path, line, 1,
            f"{name}: builder failed: {e!r}")]
    findings: list = []
    baseline: Optional[int] = None
    for step_i, (label, thunk) in enumerate(plan.steps):
        try:
            thunk()
        except Exception as e:
            findings.append(Finding(
                "entry-point-error", path, line, 1,
                f"{name}: step '{label}' failed: {e!r}"))
            return findings
        size = plan.cache_size()
        if size < 0:
            return findings          # no cache introspection: skip
        if step_i < plan.warmup_steps or baseline is None:
            baseline = size          # warm-up compiles are expected
        elif size != baseline:
            findings.append(Finding(
                "recompile", path, line, 1,
                f"{name}: step '{label}' changed the compile-signature "
                f"set ({baseline} -> {size} cached variants) — a "
                "mutation-perturbed shape reached the jitted entry "
                "point"))
            baseline = size          # report each new trigger once
    return findings


def run_recompile_gate(entry_points: Optional[Iterable[str]] = None) -> list:
    """Run every registered entry point (or the named subset)."""
    from repro.analysis.registry import ENTRY_POINTS

    names = sorted(ENTRY_POINTS) if entry_points is None \
        else list(entry_points)
    findings: list = []
    for name in names:
        findings.extend(run_entry_point(name, ENTRY_POINTS[name]))
    return findings
