"""Serving tier: single-replica cells and the multi-cell fleet router.

  * :mod:`repro.serve.cell` — ``ServingCell``: micro-batching, hedged
    dispatch, result cache + estimator hooks, cancellation, fail-fast
    failure sentinels; the unit of replication;
  * :mod:`repro.serve.engine` — ``ServingEngine``: back-compat alias
    for one cell per process;
  * :mod:`repro.serve.fleet` — ``CellRouter``: admission control,
    load-aware + cache-affinity dispatch, cross-cell hedging, and
    rolling leader-driven delta fan-out across cells on disjoint
    meshes.
"""
from repro.serve.cell import CellFailure, EngineStats, ServingCell
from repro.serve.engine import ServingEngine
from repro.serve.fleet import CellRouter, FleetOverloadError, build_fleet

__all__ = [
    "CellFailure",
    "CellRouter",
    "EngineStats",
    "FleetOverloadError",
    "ServingCell",
    "ServingEngine",
    "build_fleet",
]
