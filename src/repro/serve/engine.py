"""Single-replica serving engine — back-compat alias for ``ServingCell``.

The batching/hedging/cache/telemetry implementation lives in
:mod:`repro.serve.cell` (the unit of replication in the fleet tier);
``ServingEngine`` is the historical name for running exactly one cell
per process.  New code composing multiple replicas should use
:class:`repro.serve.cell.ServingCell` plus
:class:`repro.serve.fleet.CellRouter` directly.
"""
from __future__ import annotations

from repro.serve.cell import CellFailure, EngineStats, ServingCell, _bucket

__all__ = ["ServingEngine", "EngineStats", "CellFailure"]


class ServingEngine(ServingCell):
    """One-cell process: identical surface to :class:`ServingCell`."""


# _bucket is re-exported for callers that imported the pow2 helper from
# here (tests / benchmarks predating the cell split)
_bucket = _bucket
