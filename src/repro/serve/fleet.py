"""Multi-cell fleet tier: routed admission, off-mesh hedging, fan-out.

One :class:`repro.serve.cell.ServingCell` serves one mesh; fleet-scale
traffic needs N of them on *disjoint* meshes with a router in front.
:class:`CellRouter` provides:

  * **admission control** — a cell whose queue is deeper than
    ``max_queue_depth`` is not dispatched to; when every live cell is
    saturated the request is shed with :class:`FleetOverloadError`
    (``retriable = True`` — the client should back off and retry, the
    condition is load, not a broken fleet);
  * **cache-affinity + load-aware dispatch** — the preferred cell is
    chosen by rendezvous (highest-random-weight) hashing of a stable
    query key, so a recurring head query always lands on the same cell
    and that cell's TinyLFU cache sees a coherent head; when the
    preferred cell is saturated the request spills to the least-loaded
    open cell (counted in ``rerouted``).  Rendezvous hashing remaps
    only the failed cell's keys when a cell goes down — the survivors'
    cache heads stay intact;
  * **cross-cell hedging** — after ``hedge_ms`` without a result, the
    request is duplicated onto a *different* cell's mesh (counted in
    ``hedge_cell``).  Unlike the in-cell ``hedge_fn`` replica (which
    shares the primary's process and mesh), a fleet hedge rides a
    disjoint mesh, so a straggling or wedged mesh cannot stall both
    copies.  First responder wins; the loser is cancelled;
  * **fail-fast rerouting** — a :class:`repro.serve.cell.CellFailure`
    sentinel marks the cell down and immediately re-dispatches the
    request to a surviving cell (counted in ``rerouted``); no request
    is lost to a single-cell failure;
  * **leader fan-out** — :meth:`CellRouter.apply_updates` pops the
    target's :class:`repro.core.delta.DeltaManifest` **once** and
    applies that same manifest to every cell with a *rolling drain*:
    one cell at a time stops admitting (``_draining``), drains its
    queue, republishes, and rejoins while the other cells absorb its
    traffic.  A ``MaintenanceScheduler`` pointed at the router (one
    shared estimator, one drift decision) becomes the fleet's
    maintenance leader with no scheduler changes — see
    ``repro.adaptive.maintenance``.

Staleness across the rolling drain is bounded: a cell serves either the
pre-manifest or post-manifest index (manifest application is atomic per
cell, idempotent, and superset-safe), never a torn mix, and every cell's
result cache is invalidated at its own swap.  See ``docs/serving.md``.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Optional, Sequence

import numpy as np

from repro.analysis.annotations import guarded_by
from repro.obs.metrics import Histogram, MetricsRegistry, merge_snapshots
from repro.obs.trace import get_tracer
from repro.serve.cell import (
    CellFailure,
    EngineStats,
    ServingCell,
    _opts_extra,
)

__all__ = ["CellRouter", "FleetOverloadError", "build_fleet", "query_key"]


class FleetOverloadError(RuntimeError):
    """Every live cell is at ``max_queue_depth`` (or no cell is live):
    the request was shed, not enqueued.  ``retriable`` signals the
    client to back off and retry — shedding is a load condition, not a
    broken fleet."""

    retriable = True


def _mix64(x: int) -> int:
    """splitmix64 finalizer: cheap, well-distributed 64-bit mixing for
    rendezvous scores."""
    x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return x ^ (x >> 31)


def query_key(query: np.ndarray) -> int:
    """Stable 64-bit routing key over the query's bytes/dtype/shape —
    the same digest the result cache keys on, so affinity routing and
    cache keying agree byte-for-byte."""
    from repro.adaptive.cache import FrequencyAdmissionCache

    return int.from_bytes(
        FrequencyAdmissionCache.key_for(query)[:8], "little", signed=False)


def _salt_of(name: str) -> int:
    import hashlib

    return int.from_bytes(
        hashlib.blake2b(name.encode(), digest_size=8).digest(), "little")


class CellRouter:
    """Routes single-query requests across a fleet of serving cells."""

    def __init__(self, cells: Sequence[ServingCell], *,
                 max_queue_depth: int = 64,
                 hedge_ms: Optional[float] = None):
        """``hedge_ms=None`` disables cross-cell hedging (a request
        waits on its primary until ``timeout``); a float arms it."""
        cells = list(cells)
        if not cells:
            raise ValueError("CellRouter needs at least one cell")
        names = [c.name for c in cells]
        if len(set(names)) != len(names):
            raise ValueError(f"cell names must be unique, got {names}")
        self.cells = cells
        self._by_name = {c.name: c for c in cells}
        self._salts = {c.name: _salt_of(c.name) for c in cells}
        self.max_queue_depth = max_queue_depth
        self.hedge_ms = hedge_ms
        # one lock for all routing state: caller threads (search),
        # the leader (apply_updates drain marks), and stats() readers
        self._lock = threading.Lock()
        self._down: dict[str, BaseException] = {}
        self._draining: set[str] = set()
        # per-down-cell record of fan-outs it missed: a list of
        # DeltaManifest (replayable) or None (that fan-out had no
        # manifest — only a full re-place can cover it); consumed by
        # revive()'s replay together with the last published target
        self._missed: dict[str, list] = {}
        self._last_publish: Optional[tuple] = None
        # routing telemetry lives in a fixed-footprint registry: the
        # route-latency histogram replaces the old unbounded list
        self.metrics = MetricsRegistry()
        self._h_route = self.metrics.histogram("route_ms")
        self._c_shed = self.metrics.counter("shed")
        self._c_rerouted = self.metrics.counter("rerouted")
        self._c_hedge_cell = self.metrics.counter("hedge_cell")
        self._c_cancelled = self.metrics.counter("cancelled")
        self._c_resyncs = self.metrics.counter("resyncs")

    # -- registry-backed compatibility counters ------------------------
    @property
    def shed(self) -> int:
        return self._c_shed.value

    @property
    def rerouted(self) -> int:
        return self._c_rerouted.value

    @property
    def hedge_cell(self) -> int:
        return self._c_hedge_cell.value

    @property
    def n_cancelled(self) -> int:
        return self._c_cancelled.value

    @property
    def n_resyncs(self) -> int:
        return self._c_resyncs.value

    # -- routing policy (all under self._lock) -------------------------
    @guarded_by("_lock")
    def _routable(self, exclude=()) -> list:
        """Live cells preferred in non-draining order: draining cells
        are only routed to when nothing else is left (a 1-cell fleet
        must keep admitting through its own maintenance)."""
        alive = [c for c in self.cells
                 if c.name not in self._down and c.name not in exclude]
        ready = [c for c in alive if c.name not in self._draining]
        return ready or alive

    def _rendezvous(self, key: int, cells: list) -> ServingCell:
        """Highest-random-weight choice: stable per key, minimal remap
        when the candidate set changes (only the lost cell's keys
        move)."""
        return max(cells, key=lambda c: _mix64(key ^ self._salts[c.name]))

    @guarded_by("_lock")
    def _admit(self, key: int) -> ServingCell:
        """Admission decision for one request: preferred-by-affinity,
        spill to least-loaded, shed when saturated."""
        open_cells = self._routable()
        if not open_cells:
            self._c_shed.inc()
            raise FleetOverloadError("no live cells in the fleet")
        pref = self._rendezvous(key, open_cells)
        if pref.depth() < self.max_queue_depth:
            return pref
        alt = min(open_cells, key=lambda c: c.depth())
        if alt.depth() < self.max_queue_depth:
            self._c_rerouted.inc()
            return alt
        self._c_shed.inc()
        raise FleetOverloadError(
            f"all {len(open_cells)} live cells at "
            f"max_queue_depth={self.max_queue_depth}")

    @guarded_by("_lock")
    def _pick_open(self, key: int, exclude=()) -> Optional[ServingCell]:
        """Best alternative cell for a hedge or a failure re-dispatch;
        None when no un-tried open cell remains."""
        open_cells = [c for c in self._routable(exclude)
                      if c.depth() < self.max_queue_depth]
        if not open_cells:
            return None
        return self._rendezvous(key, open_cells)

    @guarded_by("_lock")
    def _mark_down(self, name: str, error: BaseException) -> None:
        if name in self._by_name:
            self._down[name] = error

    def preferred_cell(self, query: np.ndarray) -> Optional[ServingCell]:
        """The cell affinity routing would pick right now (load
        ignored) — what a client cache-warms against, and what tests
        pin routing expectations on."""
        key = query_key(query)
        with self._lock:
            open_cells = self._routable()
        if not open_cells:
            return None
        return self._rendezvous(key, open_cells)

    def down_cells(self) -> dict:
        """name -> error for every cell currently marked down."""
        with self._lock:
            return dict(self._down)

    def revive(self, name: str) -> Optional[dict]:
        """Put a repaired cell back into rotation (its keys rendezvous
        back to it; survivors' cache heads are untouched).

        A down cell missed every fan-out since it failed, so before it
        rejoins the router **replays** what it missed against the last
        published target: the missed manifests merged into one covering
        window (:func:`repro.core.delta.merge_manifests` — idempotent,
        superset-safe), or a forced full re-place when any missed
        fan-out had no manifest.  The replay happens while the cell is
        still marked down (no request can reach the stale index); if it
        raises, the cell *stays* down.  Returns the replay's republish
        stats, or None when nothing was missed.
        """
        cell = self._by_name.get(name)
        with self._lock:
            if name not in self._down:
                return None
            missed = self._missed.pop(name, [])
            publish = self._last_publish
        stats = None
        if cell is not None and missed and publish is not None:
            target, kw = publish
            if any(m is None for m in missed):
                manifest = None          # forces a full re-place
            else:
                from repro.core.delta import merge_manifests

                manifest = merge_manifests(missed)
            try:
                with get_tracer().span("maint.revive", cell=name,
                                       missed=len(missed)):
                    stats = cell.apply_updates(target, delta=manifest, **kw)
            except BaseException:
                with self._lock:     # keep the record for a retry
                    self._missed[name] = missed + self._missed.get(name, [])
                raise
            self._c_resyncs.inc()
        with self._lock:
            self._down.pop(name, None)
        return stats

    # -- request path --------------------------------------------------
    def search(self, query: np.ndarray, timeout: float = 30.0, *,
               filter=None, mode: str = "semantic", alpha: float = 0.5,
               q_terms=None, q_weights=None):
        """Route one query through the fleet; returns ``(dists, ids)``.

        ``filter``/``mode``/``alpha``/``q_terms``/``q_weights`` are the
        filtered + hybrid search options (docs/filtering.md), forwarded
        with every dispatch — primary, hedge, and failure re-dispatch all
        carry the same options, and the per-cell cache key folds them in
        so results from different option sets never alias.  Affinity
        routing stays keyed on the query vector alone: a head query hits
        the same cell whatever it filters by, keeping one cell's cache
        hot for all of that query's variants.

        Raises :class:`FleetOverloadError` when shed at admission,
        :class:`TimeoutError` when no cell answered in ``timeout``
        seconds (all in-flight copies are cancelled), and
        :class:`RuntimeError` when every dispatched cell failed and no
        open cell remains to re-dispatch to.

        The whole routed request runs under a ``route`` span whose
        ``trace_id`` is threaded through every cell dispatch, so the
        per-request ``queue``/``batch``/``dispatch``/``kernel`` spans
        recorded by the worker threads key back to it; the span's
        ``outcome`` attribute ends as ``ok``/``hedged``/``rerouted``/
        ``shed``/``cancelled``.
        """
        tracer = get_tracer()
        key = query_key(query)
        with tracer.span("route") as rsp:
            trace_id = rsp.trace_id
            try:
                with tracer.span("admission"):
                    with self._lock:
                        primary = self._admit(key)
            except FleetOverloadError:
                rsp.set(outcome="shed")
                raise
            rsp.set(cell=primary.name)
            # per-cell exact-match cache, checked against the affinity
            # target: recurring head queries short-circuit here, and the
            # generation token makes a post-swap offer of a pre-swap
            # result impossible (see FrequencyAdmissionCache)
            opt_kw = dict(filter_spec=filter, mode=mode, alpha=alpha,
                          q_terms=q_terms, q_weights=q_weights)
            ckey = cgen = None
            if primary.cache is not None:
                ckey = primary.cache.key_for(
                    query, _opts_extra(filter, mode, alpha))
                cgen = primary.cache.generation
                hit = primary.cache.get(ckey)
                if hit is not None:
                    if primary.estimator is not None:
                        # hits are head traffic: the shared drift
                        # estimator must see them (same contract as
                        # ServingCell.search)
                        try:
                            primary.estimator.observe(
                                np.asarray(hit[1])[:1])
                        except Exception:
                            pass
                    rsp.set(outcome="cache-hit")
                    return hit
            t0 = time.perf_counter()
            deadline = t0 + timeout
            hedge_at = (t0 + self.hedge_ms / 1e3
                        if self.hedge_ms is not None else None)
            cancelled = threading.Event()
            fut = primary.submit(query, cancelled=cancelled,
                                 trace_id=trace_id, **opt_kw)
            tried = {primary.name}
            outstanding = 1
            hedged = rerouted = False
            last_error: Optional[CellFailure] = None
            while True:
                now = time.perf_counter()
                if now >= deadline:
                    # abandon every in-flight copy: the cell workers
                    # drop cancelled requests instead of computing them
                    cancelled.set()
                    self._c_cancelled.inc()
                    rsp.set(outcome="cancelled")
                    tracer.instant("cancel", trace_id=trace_id)
                    raise TimeoutError(
                        f"fleet search timed out after {timeout}s "
                        f"(tried cells: {sorted(tried)})")
                wait_until = deadline
                if hedge_at is not None and hedge_at < wait_until:
                    wait_until = hedge_at
                try:
                    out = fut.get(timeout=max(wait_until - now, 1e-4))
                except queue.Empty:
                    if hedge_at is not None and \
                            time.perf_counter() >= hedge_at:
                        hedge_at = None     # hedge fires at most once
                        with self._lock:
                            alt = self._pick_open(key, exclude=tried)
                        if alt is not None:
                            self._c_hedge_cell.inc()
                            hedged = True
                            tracer.instant("hedge-cell", cell=alt.name,
                                           trace_id=trace_id)
                            # same future, same cancelled flag: first
                            # responder wins, the loser is dropped by
                            # its own cell's worker
                            alt.submit(query, future=fut,
                                       cancelled=cancelled,
                                       trace_id=trace_id, **opt_kw)
                            tried.add(alt.name)
                            outstanding += 1
                    continue
                if isinstance(out, CellFailure):
                    outstanding -= 1
                    last_error = out
                    with self._lock:
                        self._mark_down(out.cell, out.error)
                        alt = self._pick_open(key, exclude=tried)
                    if alt is not None:
                        self._c_rerouted.inc()
                        rerouted = True
                        tracer.instant("reroute", failed=out.cell,
                                       cell=alt.name, trace_id=trace_id)
                        alt.submit(query, future=fut, cancelled=cancelled,
                                   trace_id=trace_id, **opt_kw)
                        tried.add(alt.name)
                        outstanding += 1
                    elif outstanding <= 0:
                        raise RuntimeError(
                            f"every dispatched cell failed "
                            f"(tried: {sorted(tried)})"
                        ) from last_error.error
                    continue
                # success: cancel the hedge loser (if any) and record
                # the end-to-end routed latency
                cancelled.set()
                self._h_route.observe((time.perf_counter() - t0) * 1e3)
                rsp.set(outcome=("hedged" if hedged
                                 else "rerouted" if rerouted else "ok"))
                if primary.cache is not None:
                    primary.cache.offer(ckey, out, generation=cgen)
                return out

    # -- leader fan-out ------------------------------------------------
    def apply_updates(self, target, *, delta="auto",
                      drain_timeout_s: float = 10.0, **kw):
        """Fan one index republish out to every cell, rolling.

        ``delta="auto"`` pops the target's accumulated
        :class:`repro.core.delta.DeltaManifest` exactly **once** and
        hands the same manifest to every cell — the fleet-leader
        contract (one drift decision upstream, one pop here, N
        idempotent applications).  Cells republish one at a time: the
        cell is marked draining (admission prefers its siblings), its
        queue drains (bounded by ``drain_timeout_s``), it applies the
        manifest under its backend's lock, then rejoins.  Down cells
        are skipped (recorded as ``mode="skipped"``), but the manifest
        they missed is remembered per cell so :meth:`revive` can replay
        the merged window (or force a full re-place) before the cell
        rejoins — a revived cell never serves a stale index.

        Returns ``{"mode", "bytes", "full_bytes", "cells"}`` where
        ``cells`` maps cell name to its backend's republish stats and
        the aggregate mode is ``"full"`` if any cell fell back to a
        full re-place, else ``"delta"`` if any shipped a delta.
        """
        tracer = get_tracer()
        if delta == "auto":
            delta = (target.pop_delta()
                     if hasattr(target, "pop_delta") else None)
        per_cell: dict[str, dict] = {}
        with self._lock:
            self._last_publish = (target, dict(kw))
        with tracer.span("maint.fanout", cells=len(self.cells),
                         manifest=delta is not None):
            for cell in self.cells:
                with self._lock:
                    skip = cell.name in self._down
                    if skip:
                        # remember what this down cell missed so
                        # revive() can replay it before the cell rejoins
                        self._missed.setdefault(cell.name,
                                                []).append(delta)
                    else:
                        self._draining.add(cell.name)
                if skip:
                    per_cell[cell.name] = {
                        "mode": "skipped", "bytes": 0,
                        "full_bytes": 0, "reason": "down"}
                    continue
                try:
                    with tracer.span("maint.drain", cell=cell.name):
                        t_end = time.perf_counter() + drain_timeout_s
                        while (cell.depth() > 0
                               and time.perf_counter() < t_end):
                            time.sleep(1e-3)
                    # cell.apply_updates emits its own "republish" span
                    st = cell.apply_updates(target, delta=delta, **kw)
                    per_cell[cell.name] = st if isinstance(st, dict) else {}
                finally:
                    with self._lock:
                        self._draining.discard(cell.name)
        modes = {s.get("mode") for s in per_cell.values()}
        mode = ("full" if "full" in modes
                else "delta" if "delta" in modes
                else "none")
        return {
            "mode": mode,
            "bytes": sum(int(s.get("bytes", 0)) for s in per_cell.values()),
            "full_bytes": sum(int(s.get("full_bytes", 0))
                              for s in per_cell.values()),
            "cells": per_cell,
        }

    # -- telemetry -----------------------------------------------------
    def registries(self) -> dict:
        """Prefix -> :class:`MetricsRegistry` for every component in the
        fleet: the router's own, each cell's, and each cell backend's
        (when it exposes one) — the unit :meth:`metrics_snapshot` and
        :meth:`exposition` aggregate over."""
        parts = {"router.": self.metrics}
        for c in self.cells:
            parts[f"{c.name}."] = c.metrics
            bm = getattr(c.search_fn, "metrics", None)
            if isinstance(bm, MetricsRegistry):
                parts[f"{c.name}.backend."] = bm
        return parts

    def metrics_snapshot(self) -> dict:
        """One JSON-safe snapshot over every registry in the fleet."""
        return merge_snapshots(self.registries())

    def exposition(self) -> str:
        """Prometheus text exposition over every registry in the fleet."""
        return "".join(reg.exposition(prefix=prefix)
                       for prefix, reg in sorted(self.registries().items()))

    def _fleet_stages(self, per_cell: dict) -> dict:
        """Fleet-level per-stage summaries: identically-bucketed stage
        histograms merged across cells, plus the router's route span."""
        stages: dict = {}
        for stage, source, hname in (
                ("queue", "cell", "queue_ms"),
                ("batch", "cell", "batch_ms"),
                ("dispatch", "cell", "dispatch_ms"),
                ("kernel", "backend", "kernel_ms"),
                ("rerank", "backend", "rerank_ms")):
            hists = []
            for c in self.cells:
                reg = (c.metrics if source == "cell"
                       else getattr(c.search_fn, "metrics", None))
                if not isinstance(reg, MetricsRegistry):
                    continue
                h = reg.get(hname)
                if h is not None and h.count:
                    hists.append(h)
            if hists:
                stages[stage] = Histogram.merged(hname, hists).stats_dict()
        if self._h_route.count:
            stages["route"] = self._h_route.stats_dict()
        return stages

    def stats(self) -> EngineStats:
        """Fleet-level :class:`EngineStats`: percentiles over routed
        end-to-end latencies, routing counters, and a per-cell
        breakdown in ``.cells``."""
        a = self._h_route
        shed = self._c_shed.value
        rerouted = self._c_rerouted.value
        hedge_cell = self._c_hedge_cell.value
        cancelled = self._c_cancelled.value
        resyncs = self._c_resyncs.value
        per_cell = {c.name: c.stats() for c in self.cells}
        vals = list(per_cell.values())
        hedges = sum(s.hedges for s in vals)
        ch = sum(s.cache_hits for s in vals)
        cm = sum(s.cache_misses for s in vals)
        cancelled += sum(s.cancelled for s in vals)
        rb = sum(s.republished_bytes for s in vals)
        # delta_fraction needs the raw full-bytes denominators, which
        # the cells keep privately; recompute from their gauges
        rfb = sum(c.republish_full_bytes for c in self.cells)
        frac = rb / rfb if rfb else 0.0
        # drift is fleet-global: the estimator is shared, so any cell's
        # reading is THE reading
        drift = max((s.drift for s in vals), default=0.0)
        n_w = sum(s.n for s in vals)
        queue_ms = (sum(s.queue_ms * s.n for s in vals) / n_w
                    if n_w else 0.0)
        batch_sizes: list = []
        for s in vals:
            batch_sizes.extend(s.batch_sizes[-25:])
        common = dict(batch_sizes=batch_sizes, hedges=hedges,
                      cache_hits=ch, cache_misses=cm, drift=drift,
                      republished_bytes=rb, delta_fraction=frac,
                      cancelled=cancelled, shed=shed, rerouted=rerouted,
                      hedge_cell=hedge_cell, resyncs=resyncs,
                      cells=per_cell, stages=self._fleet_stages(per_cell))
        if a.count == 0:
            return EngineStats(0, 0, 0, 0, 0, queue_ms, **common)
        return EngineStats(
            n=a.count,
            p50_ms=a.quantile(0.5),
            p90_ms=a.quantile(0.9),
            p99_ms=a.quantile(0.99),
            mean_ms=a.mean(),
            queue_ms=queue_ms,
            **common,
        )

    def close(self):
        for cell in self.cells:
            cell.close()


def build_fleet(meshes, target, *, kind: str = "auto", k: int = 10,
                cache_capacity: Optional[int] = None, estimator=None,
                backend_kw: Optional[dict] = None,
                cell_kw: Optional[dict] = None,
                **router_kw) -> CellRouter:
    """Fleet constructor: one ``ShardedSearchBackend`` per disjoint
    mesh (see :func:`repro.launch.mesh.make_cell_meshes`), a per-cell
    TinyLFU cache (affinity routing keeps each head coherent), and ONE
    shared estimator so the maintenance leader makes a single fleet-wide
    drift decision (``OnlineLikelihoodEstimator`` is internally locked —
    safe to share across cell workers).
    """
    from repro.distributed.backend import ShardedSearchBackend

    cells = []
    for i, mesh in enumerate(meshes):
        fn = ShardedSearchBackend(
            mesh, target, kind=kind, k=k, axes=tuple(mesh.axis_names),
            **(backend_kw or {}))
        cache = None
        if cache_capacity:
            from repro.adaptive.cache import FrequencyAdmissionCache

            cache = FrequencyAdmissionCache(cache_capacity)
        cells.append(ServingCell(
            fn, name=f"cell{i}", cache=cache, estimator=estimator,
            **(cell_kw or {})))
    return CellRouter(cells, **router_kw)
