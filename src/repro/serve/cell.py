"""Single-replica serving cell: request queue, micro-batcher, latency SLOs.

The paper's deployment target is per-query P90 < 80 ms on-device; the
datacenter deployment batches concurrent queries instead.  A
:class:`ServingCell` is the production shell around one search/scoring
function — the *unit of replication* in the fleet tier
(:mod:`repro.serve.fleet` routes across many cells on disjoint meshes):

  * micro-batching: collect up to ``max_batch`` requests or ``max_wait_ms``
    (whichever first), pad to the next power-of-two bucket so jit caches a
    handful of shapes;
  * per-request latency tracking (P50/P90/P99, queue vs compute split) in
    a fixed-footprint :class:`repro.obs.metrics.MetricsRegistry` — the
    cell's memory does not grow with traffic — plus per-request
    ``queue``/``batch``/``dispatch`` spans through :mod:`repro.obs.trace`;
  * optional hedged dispatch to a replica after ``hedge_ms`` (straggler
    mitigation inside the cell; the *fleet* hedges onto a different
    cell's mesh instead — see ``CellRouter``);
  * adaptive-serving hooks: an exact-match result cache fronting
    :meth:`ServingCell.search` (invalidated on ``apply_updates``) and a
    likelihood estimator fed the top-1 id of every served query, both
    surfaced through :class:`EngineStats` (see ``repro.adaptive``);
  * cancellation: a request abandoned by its caller (timeout) is dropped
    by the batch worker instead of being computed anyway, and never
    lands in the latency/queue-wait stats;
  * fail-fast failure: a backend exception does not strand the batch —
    every affected request receives a :class:`CellFailure` sentinel so a
    router can re-dispatch it to a healthy cell immediately.

``ServingEngine`` (:mod:`repro.serve.engine`) is the single-replica
alias kept for existing callers.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import get_tracer

__all__ = ["ServingCell", "EngineStats", "CellFailure"]


@dataclasses.dataclass
class CellFailure:
    """Sentinel future value: the cell's backend raised while computing
    the batch holding this request.  A routed caller (``CellRouter``)
    marks the cell down and re-dispatches; a direct :meth:`search`
    caller gets the underlying error re-raised."""

    cell: str
    error: BaseException


def _opts_extra(filter_spec, mode: str, alpha: float) -> bytes:
    """Cache-key suffix for request options that change the answer
    (filter predicates, search mode, hybrid alpha).  Returns ``b""`` for
    a default semantic unfiltered request so existing cache keys — and
    fleet affinity routing, which shares the digest — are unchanged."""
    if mode == "semantic" and (filter_spec is None or filter_spec.empty):
        return b""
    fkey = (b"" if filter_spec is None or filter_spec.empty
            else filter_spec.key())
    return b"|".join((fkey, mode.encode(),
                      np.float32(alpha).tobytes()))


@dataclasses.dataclass
class _Request:
    query: np.ndarray
    t_enqueue: float
    future: "queue.Queue"
    cancelled: threading.Event
    trace_id: int = 0
    t_batch: float = 0.0
    # request options: ``opts`` is the hashable micro-batch grouping key
    # (empty for a default semantic request — those batch exactly as
    # before); requests with different opts never share a backend call,
    # because one dispatch carries one filter/mode/alpha
    opts: tuple = ()
    filter_spec: "object | None" = None
    mode: str = "semantic"
    alpha: float = 0.5
    q_terms: "np.ndarray | None" = None
    q_weights: "np.ndarray | None" = None


@dataclasses.dataclass
class EngineStats:
    """Read-only view over the cell's metrics registry.

    Constructed fresh by :meth:`ServingCell.stats` from the registry's
    histograms and counters — no field here is live mutable state.
    """

    n: int
    p50_ms: float
    p90_ms: float
    p99_ms: float
    mean_ms: float
    queue_ms: float
    batch_sizes: list
    hedges: int
    # adaptive-serving gauges (0 when no cache/estimator is attached):
    # benchmarks and the maintenance scheduler read this one struct
    # instead of poking engine internals
    cache_hits: int = 0
    cache_misses: int = 0
    drift: float = 0.0
    # republish gauges (apply_updates): bytes actually shipped to the
    # backend(s), and shipped / what-full-re-places-would-have-shipped —
    # 1.0 means every republish was a full re-place, 0.0 means none
    # happened yet.  fig6/fig7 and docs/tuning.md quote these counters.
    republished_bytes: int = 0
    delta_fraction: float = 0.0
    # requests whose caller timed out before a result was computed; they
    # are dropped by the batch worker and excluded from the latency and
    # queue-wait percentiles above
    cancelled: int = 0
    # fleet routing counters (0 on a standalone cell; a CellRouter's
    # stats() fills them so fig8 can attribute p99 to routing decisions)
    shed: int = 0
    rerouted: int = 0
    hedge_cell: int = 0
    # revived-cell replays: fan-outs a down cell missed and had applied
    # (merged manifest or forced full re-place) at CellRouter.revive()
    resyncs: int = 0
    # per-cell breakdown: name -> EngineStats of that cell (None on a
    # standalone cell)
    cells: "dict | None" = None
    # per-stage latency breakdown: stage name (queue/batch/dispatch/
    # kernel/rerank) -> {"n", "p50_ms", "p99_ms", "mean_ms"} from the
    # registry's stage histograms (None when nothing was recorded)
    stages: "dict | None" = None


def _bucket(n: int) -> int:
    b = 1
    while b < n:
        b <<= 1
    return b


class ServingCell:
    """search_fn(queries (B, d)) -> (dists (B,k), ids (B,k))."""

    def __init__(
        self,
        search_fn: Callable,
        *,
        name: str = "cell0",
        max_batch: int = 64,
        max_wait_ms: float = 2.0,
        hedge_fn: Optional[Callable] = None,
        hedge_ms: float = 50.0,
        cache=None,
        estimator=None,
    ):
        """``cache`` (repro.adaptive.FrequencyAdmissionCache) fronts
        :meth:`search` with exact-match results and is invalidated by
        :meth:`apply_updates`; ``estimator``
        (repro.adaptive.OnlineLikelihoodEstimator) observes the top-1 id
        of every served query so drift-triggered maintenance can follow
        the live traffic.  In a fleet, the estimator is *shared* across
        cells (one drift decision) while the cache is per-cell (affinity
        routing keeps each cell's head coherent)."""
        self.search_fn = search_fn
        self.name = name
        self.hedge_fn = hedge_fn
        self.hedge_ms = hedge_ms
        self.cache = cache
        self.estimator = estimator
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1e3
        self.q: "queue.Queue[_Request]" = queue.Queue()
        # every latency/size series lives in fixed-footprint instruments:
        # observing 10 requests or 10 million costs the same bytes
        self.metrics = MetricsRegistry()
        self._h_latency = self.metrics.histogram("latency_ms")
        self._h_queue = self.metrics.histogram("queue_ms")
        self._h_batch = self.metrics.histogram("batch_ms")
        self._h_dispatch = self.metrics.histogram("dispatch_ms")
        self._h_bsize = self.metrics.histogram("batch_size", lo=1.0,
                                               hi=4096.0)
        self._c_hedges = self.metrics.counter("hedges")
        self._c_cancelled = self.metrics.counter("cancelled")
        self._c_repub = self.metrics.counter("republished_bytes")
        self._c_repub_full = self.metrics.counter("republish_full_bytes")
        self._c_est_err = self.metrics.counter("estimator_errors")
        self._c_failures = self.metrics.counter("backend_failures")
        # last-100 batch sizes, kept as a *bounded* deque purely for the
        # EngineStats.batch_sizes compatibility list
        self._recent_batches: deque = deque(maxlen=100)
        self._failure: Optional[BaseException] = None
        # guards the failure slot and the recent-batch deque; metric
        # instruments are internally locked and never need it
        self._stats_lock = threading.Lock()
        self._stop = threading.Event()
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    # -- registry-backed compatibility counters ------------------------
    @property
    def hedges(self) -> int:
        return self._c_hedges.value

    @property
    def n_cancelled(self) -> int:
        return self._c_cancelled.value

    @property
    def republished_bytes(self) -> int:
        return self._c_repub.value

    @property
    def republish_full_bytes(self) -> int:
        return self._c_repub_full.value

    @property
    def estimator_errors(self) -> int:
        return self._c_est_err.value

    @classmethod
    def sharded(cls, mesh, target, *, kind: str = "auto", k: int = 10,
                axes=("data", "model"), query_axes=(), nprobe_local: int = 2,
                beam_width: int = 8, headroom: float = 1.0,
                **engine_kw) -> "ServingCell":
        """Cell over a mesh-sharded corpus/index.

        Builds a :class:`repro.distributed.backend.ShardedSearchBackend`
        (corpus pre-placed on the mesh, shard_map search jitted once) and
        serves it; ``engine_kw`` passes through to the cell constructor
        (``max_batch``, ``hedge_fn``, ...).  ``headroom`` > 1 reserves
        device-array growth room so later ``apply_updates`` calls (online
        index mutation) keep hitting the jitted search.
        """
        from repro.distributed.backend import ShardedSearchBackend

        fn = ShardedSearchBackend(
            mesh, target, kind=kind, k=k, axes=axes, query_axes=query_axes,
            nprobe_local=nprobe_local, beam_width=beam_width,
            headroom=headroom)
        return cls(fn, **engine_kw)

    def apply_updates(self, target, *, delta="auto", **kw):
        """Swap in a mutated corpus/index without stopping the cell.

        Delegates to the backend's ``apply_updates`` (e.g.
        :class:`repro.distributed.backend.ShardedSearchBackend`): device
        placement happens under the backend's lock, in-flight batches
        finish against the old arrays, later batches see the new ones,
        and the jitted search kernel is reused — no cold (re-compiling)
        batch anywhere in the swap.  A hedge replica is updated too —
        a stale replica would keep serving deleted entities on every
        hedged request, so a hedge_fn without ``apply_updates`` is an
        error rather than a silent staleness hole.

        ``delta="auto"`` pops the target's accumulated
        :class:`repro.core.delta.DeltaManifest` (``pop_delta()``) **once**
        and hands the same manifest to the primary and the hedge replica,
        so both walk the same version chain and a dirty-bucket
        maintenance pass ships only its dirty slices (the backend decides
        delta vs full per manifest).  Pass ``delta=None`` to force a full
        re-place, or an explicit manifest to manage popping yourself —
        the fleet leader does exactly that: one pop, the same manifest
        handed to every cell (manifest application is idempotent and
        superset-safe, see ``repro.core.delta``).
        Returns the primary backend's republish stats dict when it
        provides one (``mode``/``bytes``/``full_bytes``), which also
        feeds :class:`EngineStats`' ``republished_bytes`` /
        ``delta_fraction`` gauges.
        """
        for name, fn in (("search_fn", self.search_fn),
                         ("hedge_fn", self.hedge_fn)):
            if fn is None:
                continue
            if not hasattr(fn, "apply_updates"):
                raise TypeError(
                    f"{name} {type(fn).__name__} has no apply_updates; "
                    "only pre-placed backends support online mutation")
        if delta == "auto":
            delta = (target.pop_delta()
                     if hasattr(target, "pop_delta") else None)
        # legacy backends without a delta kwarg keep working: only pass
        # the manifest when there is one
        dkw = {} if delta is None else {"delta": delta}
        with get_tracer().span("republish", cell=self.name) as sp:
            stats = self.search_fn.apply_updates(target, **dkw, **kw)
            hstats = None
            if self.hedge_fn is not None:
                hstats = self.hedge_fn.apply_updates(target, **dkw, **kw)
            # the counters track bytes shipped to EVERY backend — a hedge
            # replica that fell back to a full re-place must show up even
            # when the primary took the delta path
            for st in (stats, hstats):
                if isinstance(st, dict):
                    self._c_repub.inc(int(st.get("bytes", 0)))
                    self._c_repub_full.inc(int(st.get("full_bytes", 0)))
            if isinstance(stats, dict):
                sp.set(mode=stats.get("mode"),
                       bytes=int(stats.get("bytes", 0)))
        if self.cache is not None:
            # invalidate AFTER the swap: the generation token handed out
            # at miss time stops in-flight pre-swap results from being
            # re-inserted (see FrequencyAdmissionCache.offer)
            self.cache.invalidate_all()
        return stats if isinstance(stats, dict) else None

    # ------------------------------------------------------------------
    def submit(self, query: np.ndarray, *, future: "queue.Queue" = None,
               cancelled: Optional[threading.Event] = None,
               trace_id: int = 0, filter_spec=None, mode: str = "semantic",
               alpha: float = 0.5, q_terms=None,
               q_weights=None) -> "queue.Queue":
        """Enqueue one request; returns the future its result lands in.

        ``future`` lets a router share one result queue between a
        primary and a hedge dispatch on another cell (first responder
        wins); ``cancelled`` is the abandon flag — once set, the batch
        worker drops the request instead of computing it.  ``trace_id``
        threads a router-assigned trace through the worker's spans so
        the queue wait and dispatch of one request share an id.
        ``filter_spec``/``mode``/``alpha``/``q_terms``/``q_weights`` are
        the filtered/hybrid search options (docs/filtering.md); the
        worker micro-batches only requests sharing the same options.
        """
        fut = queue.Queue() if future is None else future
        extra = _opts_extra(filter_spec, mode, alpha)
        self.q.put(_Request(
            query=query, t_enqueue=time.perf_counter(), future=fut,
            cancelled=cancelled if cancelled is not None
            else threading.Event(), trace_id=trace_id,
            opts=(extra,) if extra else (),
            filter_spec=filter_spec, mode=mode, alpha=alpha,
            q_terms=None if q_terms is None
            else np.asarray(q_terms, np.int32).reshape(-1),
            q_weights=None if q_weights is None
            else np.asarray(q_weights, np.float32).reshape(-1)))
        return fut

    def depth(self) -> int:
        """Queued (not yet batched) request count — the router's
        admission-control load signal."""
        return self.q.qsize()

    def failure(self) -> Optional[BaseException]:
        """Last backend exception, or None while healthy."""
        with self._stats_lock:
            return self._failure

    def search(self, query: np.ndarray, timeout: float = 30.0, *,
               filter=None, mode: str = "semantic", alpha: float = 0.5,
               q_terms=None, q_weights=None):
        """Blocking single-query call, fronted by the result cache.

        Raises :class:`TimeoutError` when no result arrives in
        ``timeout`` seconds (worker wedged / search_fn stalled); the
        abandoned request is *cancelled* — the batch worker drops it
        instead of computing it, and it never lands in the latency
        stats.  Cached results are only offered back under the
        generation observed at miss time, so a search that raced an
        ``apply_updates`` can never re-insert a stale result.

        ``filter`` (a :class:`repro.core.metadata.FilterSpec`), ``mode``
        (``"semantic"``/``"lexical"``/``"hybrid"``), ``alpha``, and the
        lexical query operands ``q_terms``/``q_weights`` pass through to
        the backend; they are folded into the cache key, so a filtered
        result can never satisfy an unfiltered request for the same
        query vector (or any other option mix-up).
        """
        tracer = get_tracer()
        key = gen = None
        if self.cache is not None:
            key = self.cache.key_for(query,
                                     _opts_extra(filter, mode, alpha))
            gen = self.cache.generation
            hit = self.cache.get(key)
            if hit is not None:
                if self.estimator is not None:
                    # cache hits ARE head traffic — skipping them would
                    # blind the drift estimator to exactly the queries
                    # the index should stay boosted for
                    try:
                        self.estimator.observe(np.asarray(hit[1])[:1])
                    except Exception:
                        self._c_est_err.inc()
                return hit
        cancelled = threading.Event()
        trace_id = tracer.new_trace_id()
        fut = self.submit(query, cancelled=cancelled, trace_id=trace_id,
                          filter_spec=filter, mode=mode, alpha=alpha,
                          q_terms=q_terms, q_weights=q_weights)
        try:
            out = fut.get(timeout=timeout)
        except queue.Empty:
            cancelled.set()
            self._c_cancelled.inc()
            tracer.instant("cancel", cell=self.name, trace_id=trace_id)
            raise TimeoutError(
                f"search timed out after {timeout}s (batch worker "
                "stalled or search_fn hung)") from None
        if isinstance(out, CellFailure):
            raise RuntimeError(
                f"cell {out.cell!r} backend failed") from out.error
        if self.cache is not None:
            self.cache.offer(key, out, generation=gen)
        return out

    def close(self):
        self._stop.set()
        self._worker.join(timeout=5)
        # a closed cell must not strand queued requests: fail them fast
        # so routed callers re-dispatch instead of timing out
        fail = CellFailure(cell=self.name,
                           error=RuntimeError(f"cell {self.name} closed"))
        while True:
            try:
                self.q.get_nowait().future.put(fail)
            except queue.Empty:
                break

    # ------------------------------------------------------------------
    def _collect(self) -> "tuple[list[_Request], float]":
        """Returns (batch, t_first): the requests collected and the
        instant the first one was dequeued — the micro-batch assembly
        span runs from t_first to dispatch."""
        try:
            first = self.q.get(timeout=0.1)
        except queue.Empty:
            return [], 0.0
        t_first = time.perf_counter()
        batch = [first]
        deadline = t_first + self.max_wait
        while len(batch) < self.max_batch:
            rem = deadline - time.perf_counter()
            if rem <= 0:
                break
            try:
                batch.append(self.q.get(timeout=rem))
            except queue.Empty:
                break
        return batch, t_first

    def _run(self):
        while not self._stop.is_set():
            collected, t_first = self._collect()
            # requests abandoned by their caller (timeout) are dropped
            # here — computing them anyway would waste backend work AND
            # pollute the latency stats with latencies nobody observed
            collected = [r for r in collected if not r.cancelled.is_set()]
            if not collected:
                continue
            # one backend dispatch carries one filter/mode/alpha, so a
            # collected batch is served as one group per distinct option
            # set; default semantic requests all share the () group and
            # batch exactly as before
            groups: "dict[tuple, list[_Request]]" = {}
            for r in collected:
                groups.setdefault(r.opts, []).append(r)
            for batch in groups.values():
                self._serve_batch(batch, t_first)

    def _serve_batch(self, batch: "list[_Request]", t_first: float):
            tracer = get_tracer()
            qs = np.stack([r.query for r in batch])
            b = qs.shape[0]
            bb = _bucket(b)
            if bb > b:
                qs = np.pad(qs, ((0, bb - b), (0, 0)))
            t0 = time.perf_counter()
            # per-request queue waits started on the caller thread and
            # end here, on the worker — the cross-thread recording form
            for r in batch:
                tracer.record_span("queue", r.t_enqueue, t_first,
                                   trace_id=r.trace_id, cell=self.name)
            tracer.record_span("batch", t_first, t0,
                               trace_id=batch[0].trace_id,
                               cell=self.name, size=b, bucket=bb)
            try:
                with tracer.span("dispatch",
                                 trace_id=batch[0].trace_id,
                                 cell=self.name, size=b, bucket=bb):
                    result = self._dispatch(qs, self._group_kw(batch, bb))
            except Exception as e:
                # fail fast, keep the worker alive: every request in the
                # batch gets a CellFailure sentinel so a router can
                # re-dispatch it immediately instead of timing out
                self._c_failures.inc()
                with self._stats_lock:
                    self._failure = e
                fail = CellFailure(cell=self.name, error=e)
                for r in batch:
                    r.future.put(fail)
                return
            t1 = time.perf_counter()
            d, i = result
            served = [(j, r) for j, r in enumerate(batch)
                      if not r.cancelled.is_set()]   # timed out: drop
            # telemetry BEFORE resolving futures: a caller that read its
            # result and immediately calls stats() must see this batch
            for _, r in served:
                self._h_latency.observe((t1 - r.t_enqueue) * 1e3)
                self._h_queue.observe((t_first - r.t_enqueue) * 1e3)
            self._h_batch.observe((t0 - t_first) * 1e3)
            self._h_dispatch.observe((t1 - t0) * 1e3)
            self._h_bsize.observe(b)
            with self._stats_lock:
                self._recent_batches.append(b)
            for j, r in served:
                r.future.put((np.asarray(d[j]), np.asarray(i[j])))
            if self.estimator is not None and served:
                try:
                    top = np.asarray(i)[:b, 0]
                    self.estimator.observe(top)
                except Exception:       # telemetry must never kill serving
                    self._c_est_err.inc()

    @staticmethod
    def _group_kw(batch: "list[_Request]", bb: int) -> dict:
        """Backend kwargs for one option group: the shared
        filter/mode/alpha plus the stacked per-request lexical operands
        (term rows padded to the group's pow2 slot width with -1/0, the
        bucket's pad queries scoring nothing)."""
        r0 = batch[0]
        if not r0.opts:
            return {}
        kw = {"filter_spec": r0.filter_spec, "mode": r0.mode,
              "alpha": r0.alpha}
        if r0.mode != "semantic" and r0.q_terms is not None:
            slots = _bucket(max(r.q_terms.size for r in batch))
            qt = np.full((bb, slots), -1, np.int32)
            qw = np.zeros((bb, slots), np.float32)
            for j, r in enumerate(batch):
                qt[j, :r.q_terms.size] = r.q_terms
                qw[j, :r.q_weights.size] = r.q_weights
            kw["q_terms"] = qt
            kw["q_weights"] = qw
        return kw

    def _dispatch(self, qs, skw: Optional[dict] = None):
        # plain-callable backends (tests pass lambdas) only ever see the
        # bare positional call; option kwargs are only forwarded when a
        # request actually set them
        call = (self.search_fn if not skw
                else lambda q: self.search_fn(q, **skw))
        if self.hedge_fn is None:
            return call(qs)
        holder: dict = {}
        done = threading.Event()

        def primary():
            out = call(qs)
            holder.setdefault("out", out)
            done.set()

        t = threading.Thread(target=primary, daemon=True)
        t.start()
        if not done.wait(self.hedge_ms / 1e3):
            self._c_hedges.inc()
            get_tracer().instant("hedge-fired", cell=self.name)
            # replica answers the hedge under the same request options
            out = (self.hedge_fn(qs) if not skw
                   else self.hedge_fn(qs, **skw))
            holder.setdefault("out", out)
            done.set()
        done.wait()
        return holder["out"]

    # ------------------------------------------------------------------
    def _stage_stats(self) -> dict:
        """Per-stage latency summaries; kernel/rerank come from the
        backend's own registry when it exposes one."""
        stages = {
            "queue": self._h_queue.stats_dict(),
            "batch": self._h_batch.stats_dict(),
            "dispatch": self._h_dispatch.stats_dict(),
        }
        bm = getattr(self.search_fn, "metrics", None)
        if isinstance(bm, MetricsRegistry):
            for hname, stage in (("kernel_ms", "kernel"),
                                 ("rerank_ms", "rerank")):
                h = bm.get(hname)
                if h is not None and h.count:
                    stages[stage] = h.stats_dict()
        return stages

    def stats(self) -> EngineStats:
        lat = self._h_latency
        hedges = self._c_hedges.value
        cancelled = self._c_cancelled.value
        rb = self._c_repub.value
        rfb = self._c_repub_full.value
        with self._stats_lock:
            batch_sizes = list(self._recent_batches)
        ch = cm = 0
        drift = 0.0
        if self.cache is not None:
            ch, cm = self.cache.hits, self.cache.misses
        if self.estimator is not None:
            drift = float(self.estimator.drift()["tv"])
        frac = rb / rfb if rfb else 0.0
        stages = self._stage_stats()
        if lat.count == 0:
            return EngineStats(0, 0, 0, 0, 0, 0, [], hedges,
                               cache_hits=ch, cache_misses=cm, drift=drift,
                               republished_bytes=rb,
                               delta_fraction=frac, cancelled=cancelled,
                               stages=stages)
        return EngineStats(
            n=lat.count,
            p50_ms=lat.quantile(0.5),
            p90_ms=lat.quantile(0.9),
            p99_ms=lat.quantile(0.99),
            mean_ms=lat.mean(),
            queue_ms=self._h_queue.mean(),
            batch_sizes=batch_sizes,
            hedges=hedges,
            cache_hits=ch,
            cache_misses=cm,
            drift=drift,
            republished_bytes=rb,
            delta_fraction=frac,
            cancelled=cancelled,
            stages=stages,
        )
