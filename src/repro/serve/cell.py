"""Single-replica serving cell: request queue, micro-batcher, latency SLOs.

The paper's deployment target is per-query P90 < 80 ms on-device; the
datacenter deployment batches concurrent queries instead.  A
:class:`ServingCell` is the production shell around one search/scoring
function — the *unit of replication* in the fleet tier
(:mod:`repro.serve.fleet` routes across many cells on disjoint meshes):

  * micro-batching: collect up to ``max_batch`` requests or ``max_wait_ms``
    (whichever first), pad to the next power-of-two bucket so jit caches a
    handful of shapes;
  * per-request latency tracking (P50/P90/P99, queue vs compute split);
  * optional hedged dispatch to a replica after ``hedge_ms`` (straggler
    mitigation inside the cell; the *fleet* hedges onto a different
    cell's mesh instead — see ``CellRouter``);
  * adaptive-serving hooks: an exact-match result cache fronting
    :meth:`ServingCell.search` (invalidated on ``apply_updates``) and a
    likelihood estimator fed the top-1 id of every served query, both
    surfaced through :class:`EngineStats` (see ``repro.adaptive``);
  * cancellation: a request abandoned by its caller (timeout) is dropped
    by the batch worker instead of being computed anyway, and never
    lands in the latency/queue-wait stats;
  * fail-fast failure: a backend exception does not strand the batch —
    every affected request receives a :class:`CellFailure` sentinel so a
    router can re-dispatch it to a healthy cell immediately.

``ServingEngine`` (:mod:`repro.serve.engine`) is the single-replica
alias kept for existing callers.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Optional

import numpy as np

__all__ = ["ServingCell", "EngineStats", "CellFailure"]


@dataclasses.dataclass
class CellFailure:
    """Sentinel future value: the cell's backend raised while computing
    the batch holding this request.  A routed caller (``CellRouter``)
    marks the cell down and re-dispatches; a direct :meth:`search`
    caller gets the underlying error re-raised."""

    cell: str
    error: BaseException


@dataclasses.dataclass
class _Request:
    query: np.ndarray
    t_enqueue: float
    future: "queue.Queue"
    cancelled: threading.Event
    t_batch: float = 0.0


@dataclasses.dataclass
class EngineStats:
    n: int
    p50_ms: float
    p90_ms: float
    p99_ms: float
    mean_ms: float
    queue_ms: float
    batch_sizes: list
    hedges: int
    # adaptive-serving gauges (0 when no cache/estimator is attached):
    # benchmarks and the maintenance scheduler read this one struct
    # instead of poking engine internals
    cache_hits: int = 0
    cache_misses: int = 0
    drift: float = 0.0
    # republish gauges (apply_updates): bytes actually shipped to the
    # backend(s), and shipped / what-full-re-places-would-have-shipped —
    # 1.0 means every republish was a full re-place, 0.0 means none
    # happened yet.  fig6/fig7 and docs/tuning.md quote these counters.
    republished_bytes: int = 0
    delta_fraction: float = 0.0
    # requests whose caller timed out before a result was computed; they
    # are dropped by the batch worker and excluded from the latency and
    # queue-wait percentiles above
    cancelled: int = 0
    # fleet routing counters (0 on a standalone cell; a CellRouter's
    # stats() fills them so fig8 can attribute p99 to routing decisions)
    shed: int = 0
    rerouted: int = 0
    hedge_cell: int = 0
    # revived-cell replays: fan-outs a down cell missed and had applied
    # (merged manifest or forced full re-place) at CellRouter.revive()
    resyncs: int = 0
    # per-cell breakdown: name -> EngineStats of that cell (None on a
    # standalone cell)
    cells: "dict | None" = None


def _bucket(n: int) -> int:
    b = 1
    while b < n:
        b <<= 1
    return b


class ServingCell:
    """search_fn(queries (B, d)) -> (dists (B,k), ids (B,k))."""

    def __init__(
        self,
        search_fn: Callable,
        *,
        name: str = "cell0",
        max_batch: int = 64,
        max_wait_ms: float = 2.0,
        hedge_fn: Optional[Callable] = None,
        hedge_ms: float = 50.0,
        cache=None,
        estimator=None,
    ):
        """``cache`` (repro.adaptive.FrequencyAdmissionCache) fronts
        :meth:`search` with exact-match results and is invalidated by
        :meth:`apply_updates`; ``estimator``
        (repro.adaptive.OnlineLikelihoodEstimator) observes the top-1 id
        of every served query so drift-triggered maintenance can follow
        the live traffic.  In a fleet, the estimator is *shared* across
        cells (one drift decision) while the cache is per-cell (affinity
        routing keeps each cell's head coherent)."""
        self.search_fn = search_fn
        self.name = name
        self.hedge_fn = hedge_fn
        self.hedge_ms = hedge_ms
        self.cache = cache
        self.estimator = estimator
        self.estimator_errors = 0
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1e3
        self.q: "queue.Queue[_Request]" = queue.Queue()
        self.latencies: list[float] = []
        self.queue_waits: list[float] = []
        self.batch_sizes: list[int] = []
        self.hedges = 0
        self.n_cancelled = 0
        self.republished_bytes = 0
        self.republish_full_bytes = 0
        self._failure: Optional[BaseException] = None
        # one lock for every telemetry counter: the batch worker, hedge
        # path, callers of search()/apply_updates(), and stats() readers
        # all touch these from different threads
        self._stats_lock = threading.Lock()
        self._stop = threading.Event()
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    @classmethod
    def sharded(cls, mesh, target, *, kind: str = "auto", k: int = 10,
                axes=("data", "model"), query_axes=(), nprobe_local: int = 2,
                beam_width: int = 8, headroom: float = 1.0,
                **engine_kw) -> "ServingCell":
        """Cell over a mesh-sharded corpus/index.

        Builds a :class:`repro.distributed.backend.ShardedSearchBackend`
        (corpus pre-placed on the mesh, shard_map search jitted once) and
        serves it; ``engine_kw`` passes through to the cell constructor
        (``max_batch``, ``hedge_fn``, ...).  ``headroom`` > 1 reserves
        device-array growth room so later ``apply_updates`` calls (online
        index mutation) keep hitting the jitted search.
        """
        from repro.distributed.backend import ShardedSearchBackend

        fn = ShardedSearchBackend(
            mesh, target, kind=kind, k=k, axes=axes, query_axes=query_axes,
            nprobe_local=nprobe_local, beam_width=beam_width,
            headroom=headroom)
        return cls(fn, **engine_kw)

    def apply_updates(self, target, *, delta="auto", **kw):
        """Swap in a mutated corpus/index without stopping the cell.

        Delegates to the backend's ``apply_updates`` (e.g.
        :class:`repro.distributed.backend.ShardedSearchBackend`): device
        placement happens under the backend's lock, in-flight batches
        finish against the old arrays, later batches see the new ones,
        and the jitted search kernel is reused — no cold (re-compiling)
        batch anywhere in the swap.  A hedge replica is updated too —
        a stale replica would keep serving deleted entities on every
        hedged request, so a hedge_fn without ``apply_updates`` is an
        error rather than a silent staleness hole.

        ``delta="auto"`` pops the target's accumulated
        :class:`repro.core.delta.DeltaManifest` (``pop_delta()``) **once**
        and hands the same manifest to the primary and the hedge replica,
        so both walk the same version chain and a dirty-bucket
        maintenance pass ships only its dirty slices (the backend decides
        delta vs full per manifest).  Pass ``delta=None`` to force a full
        re-place, or an explicit manifest to manage popping yourself —
        the fleet leader does exactly that: one pop, the same manifest
        handed to every cell (manifest application is idempotent and
        superset-safe, see ``repro.core.delta``).
        Returns the primary backend's republish stats dict when it
        provides one (``mode``/``bytes``/``full_bytes``), which also
        feeds :class:`EngineStats`' ``republished_bytes`` /
        ``delta_fraction`` gauges.
        """
        for name, fn in (("search_fn", self.search_fn),
                         ("hedge_fn", self.hedge_fn)):
            if fn is None:
                continue
            if not hasattr(fn, "apply_updates"):
                raise TypeError(
                    f"{name} {type(fn).__name__} has no apply_updates; "
                    "only pre-placed backends support online mutation")
        if delta == "auto":
            delta = (target.pop_delta()
                     if hasattr(target, "pop_delta") else None)
        # legacy backends without a delta kwarg keep working: only pass
        # the manifest when there is one
        dkw = {} if delta is None else {"delta": delta}
        stats = self.search_fn.apply_updates(target, **dkw, **kw)
        hstats = None
        if self.hedge_fn is not None:
            hstats = self.hedge_fn.apply_updates(target, **dkw, **kw)
        # the gauges count bytes shipped to EVERY backend — a hedge
        # replica that fell back to a full re-place must show up even
        # when the primary took the delta path
        with self._stats_lock:
            for st in (stats, hstats):
                if isinstance(st, dict):
                    self.republished_bytes += int(st.get("bytes", 0))
                    self.republish_full_bytes += int(
                        st.get("full_bytes", 0))
        if self.cache is not None:
            # invalidate AFTER the swap: the generation token handed out
            # at miss time stops in-flight pre-swap results from being
            # re-inserted (see FrequencyAdmissionCache.offer)
            self.cache.invalidate_all()
        return stats if isinstance(stats, dict) else None

    # ------------------------------------------------------------------
    def submit(self, query: np.ndarray, *, future: "queue.Queue" = None,
               cancelled: Optional[threading.Event] = None) -> "queue.Queue":
        """Enqueue one request; returns the future its result lands in.

        ``future`` lets a router share one result queue between a
        primary and a hedge dispatch on another cell (first responder
        wins); ``cancelled`` is the abandon flag — once set, the batch
        worker drops the request instead of computing it.
        """
        fut = queue.Queue() if future is None else future
        self.q.put(_Request(
            query=query, t_enqueue=time.perf_counter(), future=fut,
            cancelled=cancelled if cancelled is not None
            else threading.Event()))
        return fut

    def depth(self) -> int:
        """Queued (not yet batched) request count — the router's
        admission-control load signal."""
        return self.q.qsize()

    def failure(self) -> Optional[BaseException]:
        """Last backend exception, or None while healthy."""
        with self._stats_lock:
            return self._failure

    def search(self, query: np.ndarray, timeout: float = 30.0):
        """Blocking single-query call, fronted by the result cache.

        Raises :class:`TimeoutError` when no result arrives in
        ``timeout`` seconds (worker wedged / search_fn stalled); the
        abandoned request is *cancelled* — the batch worker drops it
        instead of computing it, and it never lands in the latency
        stats.  Cached results are only offered back under the
        generation observed at miss time, so a search that raced an
        ``apply_updates`` can never re-insert a stale result.
        """
        key = gen = None
        if self.cache is not None:
            key = self.cache.key_for(query)
            gen = self.cache.generation
            hit = self.cache.get(key)
            if hit is not None:
                if self.estimator is not None:
                    # cache hits ARE head traffic — skipping them would
                    # blind the drift estimator to exactly the queries
                    # the index should stay boosted for
                    try:
                        self.estimator.observe(np.asarray(hit[1])[:1])
                    except Exception:
                        with self._stats_lock:
                            self.estimator_errors += 1
                return hit
        cancelled = threading.Event()
        fut = self.submit(query, cancelled=cancelled)
        try:
            out = fut.get(timeout=timeout)
        except queue.Empty:
            cancelled.set()
            with self._stats_lock:
                self.n_cancelled += 1
            raise TimeoutError(
                f"search timed out after {timeout}s (batch worker "
                "stalled or search_fn hung)") from None
        if isinstance(out, CellFailure):
            raise RuntimeError(
                f"cell {out.cell!r} backend failed") from out.error
        if self.cache is not None:
            self.cache.offer(key, out, generation=gen)
        return out

    def close(self):
        self._stop.set()
        self._worker.join(timeout=5)
        # a closed cell must not strand queued requests: fail them fast
        # so routed callers re-dispatch instead of timing out
        fail = CellFailure(cell=self.name,
                           error=RuntimeError(f"cell {self.name} closed"))
        while True:
            try:
                self.q.get_nowait().future.put(fail)
            except queue.Empty:
                break

    # ------------------------------------------------------------------
    def _collect(self) -> list[_Request]:
        try:
            first = self.q.get(timeout=0.1)
        except queue.Empty:
            return []
        batch = [first]
        deadline = time.perf_counter() + self.max_wait
        while len(batch) < self.max_batch:
            rem = deadline - time.perf_counter()
            if rem <= 0:
                break
            try:
                batch.append(self.q.get(timeout=rem))
            except queue.Empty:
                break
        return batch

    def _run(self):
        while not self._stop.is_set():
            batch = self._collect()
            # requests abandoned by their caller (timeout) are dropped
            # here — computing them anyway would waste backend work AND
            # pollute the latency stats with latencies nobody observed
            batch = [r for r in batch if not r.cancelled.is_set()]
            if not batch:
                continue
            t0 = time.perf_counter()
            qs = np.stack([r.query for r in batch])
            b = qs.shape[0]
            bb = _bucket(b)
            if bb > b:
                qs = np.pad(qs, ((0, bb - b), (0, 0)))
            try:
                result = self._dispatch(qs)
            except Exception as e:
                # fail fast, keep the worker alive: every request in the
                # batch gets a CellFailure sentinel so a router can
                # re-dispatch it immediately instead of timing out
                with self._stats_lock:
                    self._failure = e
                fail = CellFailure(cell=self.name, error=e)
                for r in batch:
                    r.future.put(fail)
                continue
            t1 = time.perf_counter()
            d, i = result
            served = []
            for j, r in enumerate(batch):
                if r.cancelled.is_set():
                    continue          # timed out mid-compute: drop
                r.future.put((np.asarray(d[j]), np.asarray(i[j])))
                served.append(r)
            with self._stats_lock:
                for r in served:
                    self.latencies.append(t1 - r.t_enqueue)
                    self.queue_waits.append(t0 - r.t_enqueue)
                self.batch_sizes.append(b)
            if self.estimator is not None and served:
                try:
                    top = np.asarray(i)[:b, 0]
                    self.estimator.observe(top)
                except Exception:       # telemetry must never kill serving
                    with self._stats_lock:
                        self.estimator_errors += 1

    def _dispatch(self, qs):
        if self.hedge_fn is None:
            return self.search_fn(qs)
        holder: dict = {}
        done = threading.Event()

        def primary():
            out = self.search_fn(qs)
            holder.setdefault("out", out)
            done.set()

        t = threading.Thread(target=primary, daemon=True)
        t.start()
        if not done.wait(self.hedge_ms / 1e3):
            with self._stats_lock:
                self.hedges += 1
            out = self.hedge_fn(qs)      # replica answers the hedge
            holder.setdefault("out", out)
            done.set()
        done.wait()
        return holder["out"]

    # ------------------------------------------------------------------
    def stats(self) -> EngineStats:
        with self._stats_lock:
            # snapshot under the lock so a stats() racing the batch
            # worker never sees a latency without its queue_wait twin
            a = np.asarray(self.latencies) * 1e3
            qw = np.asarray(self.queue_waits) * 1e3
            batch_sizes = self.batch_sizes[-100:]
            hedges = self.hedges
            cancelled = self.n_cancelled
            rb = self.republished_bytes
            rfb = self.republish_full_bytes
        ch = cm = 0
        drift = 0.0
        if self.cache is not None:
            ch, cm = self.cache.hits, self.cache.misses
        if self.estimator is not None:
            drift = float(self.estimator.drift()["tv"])
        frac = rb / rfb if rfb else 0.0
        if a.size == 0:
            return EngineStats(0, 0, 0, 0, 0, 0, [], hedges,
                               cache_hits=ch, cache_misses=cm, drift=drift,
                               republished_bytes=rb,
                               delta_fraction=frac, cancelled=cancelled)
        return EngineStats(
            n=a.size,
            p50_ms=float(np.percentile(a, 50)),
            p90_ms=float(np.percentile(a, 90)),
            p99_ms=float(np.percentile(a, 99)),
            mean_ms=float(a.mean()),
            queue_ms=float(qw.mean()),
            batch_sizes=batch_sizes,
            hedges=hedges,
            cache_hits=ch,
            cache_misses=cm,
            drift=drift,
            republished_bytes=rb,
            delta_fraction=frac,
            cancelled=cancelled,
        )
