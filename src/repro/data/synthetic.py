"""Synthetic ANN corpora statistically matched to the paper's datasets.

Real SIFT-1M / DEEP-10M / Radio-Station are not downloadable offline
(DESIGN.md §8).  We generate mixture-of-Gaussians corpora with anisotropic
clusters — the structure IVF/tree methods exploit — at the same (N, d):

  radio_station : 10 K x 256   (private VA entity embeddings)
  sift          : 1 M  x 128   (SIFT descriptors, uint8-ranged)
  deep          : 10 M x 96    (unit-norm CNN descriptors)

Sizes scale down via ``scale`` for CI/benchmark tiers.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["CorpusSpec", "CORPORA", "make_corpus", "make_queries"]


@dataclasses.dataclass(frozen=True)
class CorpusSpec:
    name: str
    n: int
    d: int
    n_clusters: int
    unit_norm: bool = False
    uint8_range: bool = False


CORPORA = {
    "radio_station": CorpusSpec("radio_station", 10_000, 256, 64),
    "sift": CorpusSpec("sift", 1_000_000, 128, 4096, uint8_range=True),
    "deep": CorpusSpec("deep", 10_000_000, 96, 16384, unit_norm=True),
}


def make_corpus(
    spec_or_name, *, scale: float = 1.0, seed: int = 0, dtype=np.float32
) -> np.ndarray:
    """Anisotropic Gaussian-mixture corpus (chunked generation, ~O(N d))."""
    spec = CORPORA[spec_or_name] if isinstance(spec_or_name, str) else \
        spec_or_name
    n = max(64, int(spec.n * scale))
    k = max(4, int(spec.n_clusters * min(1.0, scale * 4)))
    rng = np.random.default_rng(seed)
    centers = rng.normal(0.0, 4.0, size=(k, spec.d)).astype(np.float32)
    # anisotropy: per-cluster axis-aligned scales, long-tailed
    scales = rng.lognormal(mean=-0.5, sigma=0.6, size=(k, spec.d)) \
        .astype(np.float32)
    out = np.empty((n, spec.d), dtype=np.float32)
    sizes = rng.multinomial(n, rng.dirichlet(np.full(k, 2.0)))
    pos = 0
    for c in range(k):
        m = sizes[c]
        if m == 0:
            continue
        out[pos : pos + m] = centers[c] + rng.normal(
            size=(m, spec.d)
        ).astype(np.float32) * scales[c]
        pos += m
    rng.shuffle(out)
    if spec.unit_norm:
        out /= np.linalg.norm(out, axis=1, keepdims=True) + 1e-12
    if spec.uint8_range:
        lo, hi = out.min(), out.max()
        out = np.round((out - lo) / (hi - lo) * 255.0)
    return out.astype(dtype)


def make_queries(
    db: np.ndarray,
    n_queries: int,
    *,
    seed: int = 0,
    noise_scale: float = 0.1,
) -> np.ndarray:
    """Held-out-style queries: perturbed corpus points (uniform likelihood).

    For likelihood-weighted traffic use ``core.likelihood.sample_queries``.
    """
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, db.shape[0], size=n_queries)
    scale = float(np.std(db)) * noise_scale
    q = db[ids] + rng.normal(0.0, scale, size=(n_queries, db.shape[1]))
    return q.astype(np.float32)
