"""Deterministic synthetic LM token stream.

Stateless: ``batch_at(step)`` derives every batch from (seed, step) alone,
so checkpoint-restart resumes the exact data order with no sampler state to
save (DESIGN.md §4 fault tolerance).  The stream is a mixture of Zipfian
unigrams and short repeated motifs so the loss has learnable structure.
"""
from __future__ import annotations

import numpy as np

__all__ = ["LMStream"]


class LMStream:
    def __init__(self, vocab: int, seq: int, batch: int, seed: int = 0,
                 motif_len: int = 8, n_motifs: int = 256):
        self.vocab, self.seq, self.batch, self.seed = vocab, seq, batch, seed
        rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self.unigram = (ranks ** -1.1) / (ranks ** -1.1).sum()
        self.motifs = rng.integers(0, vocab,
                                   size=(n_motifs, motif_len)).astype(np.int32)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.choice(self.vocab, p=self.unigram,
                          size=(self.batch, self.seq + 1)).astype(np.int32)
        # splice motifs for structure
        n_splice = self.seq // 16
        for b in range(self.batch):
            ids = rng.integers(0, self.motifs.shape[0], size=n_splice)
            pos = rng.integers(0, self.seq - self.motifs.shape[1],
                               size=n_splice)
            for m, p in zip(ids, pos):
                toks[b, p : p + self.motifs.shape[1]] = self.motifs[m]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
