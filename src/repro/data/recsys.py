"""Synthetic recsys batches (Criteo-like CTR + behavior sequences).

Stateless per-step generation like `data.lm` — (seed, step) determines the
batch.  Labels follow a planted logistic model over a few latent factors so
training has signal.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import DCNConfig, DINConfig, DLRMConfig, SASRecConfig
from repro.models.embedding import concat_table_offsets

__all__ = ["ctr_batch", "din_batch", "sasrec_batch", "batch_for"]


def ctr_batch(cfg, batch: int, step: int, seed: int = 0) -> dict:
    """DLRM/DCN batch: dense (B,13), sparse (B,26) global-offset ids."""
    rng = np.random.default_rng((seed, step))
    offsets, _ = concat_table_offsets(cfg.table_sizes)
    dense = rng.normal(size=(batch, cfg.n_dense)).astype(np.float32)
    cols = []
    for j, size in enumerate(cfg.table_sizes):
        # Zipf-ish id popularity
        ids = np.minimum(
            rng.zipf(1.2, size=batch) - 1, size - 1
        ).astype(np.int64)
        cols.append(offsets[j] + ids)
    sparse = np.stack(cols, axis=1).astype(np.int64)
    w = np.sin(np.arange(cfg.n_dense)) * 0.5
    logit = dense @ w + 0.1 * ((sparse.sum(1) % 7) - 3)
    label = (logit + rng.normal(size=batch) > 0).astype(np.float32)
    return {"dense": dense, "sparse": sparse.astype(np.int32),
            "label": label}


def din_batch(cfg: DINConfig, batch: int, step: int, seed: int = 0) -> dict:
    rng = np.random.default_rng((seed, step))
    L = cfg.seq_len
    hist = rng.integers(0, cfg.n_items, size=(batch, L)).astype(np.int32)
    lens = rng.integers(L // 4, L + 1, size=batch)
    hist[np.arange(L)[None, :] >= lens[:, None]] = -1
    hist_c = np.where(hist >= 0, hist % cfg.n_cates, -1).astype(np.int32)
    target = rng.integers(0, cfg.n_items, size=batch).astype(np.int32)
    target_c = (target % cfg.n_cates).astype(np.int32)
    # planted signal: click if target's category appears in history
    match = (hist_c == target_c[:, None]).any(axis=1)
    label = np.where(
        match, (rng.random(batch) < 0.8), (rng.random(batch) < 0.2)
    ).astype(np.float32)
    return {"hist_items": hist, "hist_cates": hist_c,
            "target_item": target, "target_cate": target_c, "label": label}


def sasrec_batch(cfg: SASRecConfig, batch: int, step: int,
                 seed: int = 0) -> dict:
    rng = np.random.default_rng((seed, step))
    L = cfg.seq_len
    # random-walk sequences over a ring of items (structure to learn)
    start = rng.integers(0, cfg.n_items, size=batch)
    steps = rng.integers(1, 5, size=(batch, L + 1))
    seq_full = (start[:, None] + np.cumsum(steps, axis=1)) % cfg.n_items
    seq = seq_full[:, :L].astype(np.int32)
    pos = seq_full[:, 1 : L + 1].astype(np.int32)
    neg = rng.integers(0, cfg.n_items, size=(batch, L)).astype(np.int32)
    lens = rng.integers(2, L + 1, size=batch)
    mask = np.arange(L)[None, :] >= lens[:, None]
    seq[mask] = -1
    pos[mask] = -1
    return {"seq": seq, "pos": pos, "neg": neg}


def batch_for(cfg, batch: int, step: int, seed: int = 0) -> dict:
    if isinstance(cfg, (DLRMConfig, DCNConfig)):
        return ctr_batch(cfg, batch, step, seed)
    if isinstance(cfg, DINConfig):
        return din_batch(cfg, batch, step, seed)
    if isinstance(cfg, SASRecConfig):
        return sasrec_batch(cfg, batch, step, seed)
    raise TypeError(type(cfg))
