"""Graph data: synthetic corpora at the assigned shapes + a real CSR
uniform neighbor sampler (required for minibatch_lg — taxonomy §GNN).

Synthetic graphs are degree-skewed (preferential-attachment flavored) so
sampled subgraphs have realistic fanout variance.  Node features carry a
planted community signal for the classification loss.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["CSRGraph", "make_graph", "NeighborSampler", "molecule_batch",
           "pad_edges"]


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray        # (N+1,) int64
    indices: np.ndarray       # (E,) int32 neighbor ids
    feats: np.ndarray         # (N, F) float32
    labels: np.ndarray        # (N,) int32
    pos: np.ndarray           # (N, 3) float32 (for SchNet distance filters)

    @property
    def n_nodes(self) -> int:
        return self.indptr.shape[0] - 1

    @property
    def n_edges(self) -> int:
        return int(self.indices.shape[0])

    def edge_list(self) -> tuple[np.ndarray, np.ndarray]:
        senders = np.repeat(
            np.arange(self.n_nodes, dtype=np.int32),
            np.diff(self.indptr).astype(np.int64),
        )
        return senders, self.indices


def make_graph(n_nodes: int, n_edges: int, d_feat: int, *,
               n_classes: int = 16, seed: int = 0) -> CSRGraph:
    """Degree-skewed random graph with community-structured features."""
    rng = np.random.default_rng(seed)
    # heavy-tailed out-degrees summing ~ n_edges
    deg = rng.zipf(1.5, size=n_nodes).astype(np.float64)
    deg = np.maximum(1, np.round(deg * n_edges / deg.sum())).astype(np.int64)
    # adjust to exact edge count
    diff = n_edges - int(deg.sum())
    if diff != 0:
        idx = rng.choice(n_nodes, size=abs(diff))
        np.add.at(deg, idx, np.sign(diff))
        deg = np.maximum(deg, 1)
    e = int(deg.sum())
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    np.cumsum(deg, out=indptr[1:])
    comm = rng.integers(0, n_classes, size=n_nodes).astype(np.int32)
    # neighbors biased to same community
    indices = np.empty(e, dtype=np.int32)
    same = rng.random(e) < 0.6
    rand_all = rng.integers(0, n_nodes, size=e).astype(np.int32)
    indices[:] = rand_all
    # community-preserving rewire (vectorized approximation): map same-comm
    # edges to a random node with the sender's community via sorted pools
    order = np.argsort(comm, kind="stable")
    comm_sorted = comm[order]
    starts = np.searchsorted(comm_sorted, np.arange(n_classes))
    ends = np.searchsorted(comm_sorted, np.arange(n_classes), side="right")
    senders = np.repeat(np.arange(n_nodes, dtype=np.int64), deg)
    sc = comm[senders]
    pool_size = np.maximum(ends[sc] - starts[sc], 1)
    draw = starts[sc] + (rng.random(e) * pool_size).astype(np.int64)
    indices[same] = order[draw[same]].astype(np.int32)
    feats = rng.normal(size=(n_nodes, d_feat)).astype(np.float32) * 0.5
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    feats += centers[comm]
    pos = rng.normal(size=(n_nodes, 3)).astype(np.float32) * 4.0
    pos += rng.normal(size=(n_classes, 3)).astype(np.float32)[comm] * 4.0
    return CSRGraph(indptr=indptr, indices=indices, feats=feats,
                    labels=comm, pos=pos)


class NeighborSampler:
    """Uniform k-hop fanout sampler over CSR (GraphSAGE-style).

    ``sample(seeds)`` returns a padded subgraph dict ready for the SchNet
    step: local node features/positions, local edge list, seed mask.
    """

    def __init__(self, graph: CSRGraph, fanout: tuple[int, ...],
                 seed: int = 0):
        self.g = graph
        self.fanout = fanout
        self.rng = np.random.default_rng(seed)

    def _sample_neighbors(self, nodes: np.ndarray, k: int):
        g = self.g
        deg = (g.indptr[nodes + 1] - g.indptr[nodes]).astype(np.int64)
        # uniform with replacement (standard GraphSAGE); deg==0 -> self-loop
        draw = (self.rng.random((nodes.size, k)) *
                np.maximum(deg, 1)[:, None]).astype(np.int64)
        nbr = g.indices[(g.indptr[nodes][:, None] + draw)]
        nbr = np.where(deg[:, None] > 0, nbr, nodes[:, None])
        src = np.repeat(nodes, k).astype(np.int32)
        return src, nbr.reshape(-1).astype(np.int32)

    def sample(self, seeds: np.ndarray) -> dict:
        """Returns a local-id subgraph with edges from all hops."""
        seeds = np.asarray(seeds, dtype=np.int32)
        frontier = seeds
        all_src, all_dst = [], []
        for k in self.fanout:
            s, d = self._sample_neighbors(frontier, k)
            all_src.append(s)
            all_dst.append(d)
            frontier = np.unique(d)
        src = np.concatenate(all_src)
        dst = np.concatenate(all_dst)
        nodes, inv = np.unique(np.concatenate([seeds, src, dst]),
                               return_inverse=True)
        n_seed = seeds.size
        src_l = inv[n_seed : n_seed + src.size].astype(np.int32)
        dst_l = inv[n_seed + src.size :].astype(np.int32)
        seed_l = inv[:n_seed].astype(np.int32)
        g = self.g
        return {
            "feats": g.feats[nodes],
            "pos": g.pos[nodes],
            # message direction: neighbor -> seed side
            "senders": dst_l,
            "receivers": src_l,
            "labels": g.labels[nodes],
            "seed_local": seed_l,
            "node_ids": nodes,
        }


def pad_edges(batch: dict, n_nodes: int, n_edges: int) -> dict:
    """Pad a sampled subgraph to fixed (n_nodes, n_edges) for jit reuse."""
    out = dict(batch)
    cn = batch["feats"].shape[0]
    ce = batch["senders"].shape[0]
    if cn > n_nodes or ce > n_edges:
        raise ValueError(f"subgraph ({cn},{ce}) exceeds pad ({n_nodes},"
                         f"{n_edges})")
    out["feats"] = np.pad(batch["feats"], ((0, n_nodes - cn), (0, 0)))
    out["pos"] = np.pad(batch["pos"], ((0, n_nodes - cn), (0, 0)))
    out["labels"] = np.pad(batch["labels"], (0, n_nodes - cn))
    out["node_mask"] = (np.arange(n_nodes) < cn).astype(np.float32)
    out["senders"] = np.pad(batch["senders"], (0, n_edges - ce),
                            constant_values=-1)
    out["receivers"] = np.pad(batch["receivers"], (0, n_edges - ce),
                              constant_values=-1)
    return out


def molecule_batch(n_graphs: int, n_nodes: int, n_edges: int, d_feat: int,
                   step: int, seed: int = 0, cutoff: float = 10.0) -> dict:
    """Batched small molecules, flattened with graph_ids (shape `molecule`).

    Edges come from `core.graph_build.radius_graph` — the paper-technique
    integration point for SchNet (DESIGN.md §5).
    """
    from repro.core.graph_build import radius_graph

    rng = np.random.default_rng((seed, step))
    feats, pos, snd, rcv, gid, energy = [], [], [], [], [], []
    off = 0
    for g in range(n_graphs):
        p = rng.normal(size=(n_nodes, 3)).astype(np.float32) * 2.5
        f = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
        s, r = radius_graph(p, cutoff, method="brute",
                            max_neighbors=max(2, n_edges // n_nodes))
        if s.size > n_edges:
            sel = rng.choice(s.size, size=n_edges, replace=False)
            s, r = s[sel], r[sel]
        pad = n_edges - s.size
        s = np.pad(s + off, (0, pad), constant_values=-1)
        r = np.pad(r + off, (0, pad), constant_values=-1)
        feats.append(f)
        pos.append(p)
        snd.append(s)
        rcv.append(r)
        gid.append(np.full(n_nodes, g, dtype=np.int32))
        # planted energy: sum of pairwise 1/d within cutoff (LJ-flavored)
        d = np.sqrt(((p[:, None] - p[None]) ** 2).sum(-1) + 1e-6)
        energy.append(np.float32((1.0 / d[d < cutoff]).sum() / n_nodes))
    return {
        "feats": np.concatenate(feats),
        "pos": np.concatenate(pos),
        "senders": np.concatenate(snd),
        "receivers": np.concatenate(rcv),
        "graph_ids": np.concatenate(gid),
        "energy": np.asarray(energy, dtype=np.float32),
    }
