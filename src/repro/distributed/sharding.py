"""Sharded execution: ShardPlan (symbolic axes) + the sharded ANN search.

Two halves, one subsystem:

``ShardPlan`` — models annotate params/activations with *roles* — "dp"
(batch), "fsdp" (param gather), "tp" (tensor), "ep" (expert) — and the
launcher binds roles to concrete mesh axes:

  single-pod (16,16) ("data","model"): dp=(data,) fsdp=(data,) tp=(model,)
                                       ep=(data,model)
  multi-pod (2,16,16) (+pod):          dp=(pod,data) fsdp=(pod,data) ...

so the same model code lowers on any mesh.  With no mesh bound, ``p()``
returns fully-replicated specs and ``constrain`` is a no-op — the path unit
tests take.

Sharded search — the paper's two-level structure gains one more level: the
mesh.  Buckets (and their centroids) are sharded across chips; each chip
runs the paper's top+bottom search over its local shard; a tiny
``all_gather`` of per-chip top-k (k * 8 bytes per query) merges globally.
The collective term is O(devices * B * k) bytes — independent of corpus
size, which is what makes the approach scale-out friendly (EXPERIMENTS.md
§Roofline, ann rows).  Three bottom levels are distributed here:

  * ``sharded_brute_search``  — exact scan, db row-sharded;
  * ``sharded_ivf_search``    — two-level brute bottom, buckets sharded;
  * ``sharded_forest_search`` — two-level tree/QLBT bottom: each shard
    holds a slice of the concatenated per-bucket forest and descends it
    locally before the global merge.

Every entry point takes ``query_axes`` to additionally shard the *query*
batch over a second mesh axis (corpus over one, queries over the other),
so both B and N scale; the merge all-gathers only over the corpus axes and
results come back sharded over the query axes.

All collectives go through :mod:`repro.compat`'s ``shard_map`` so the
communication pattern is explicit in the lowered HLO and the code runs on
any JAX version (``jax.shard_map`` vs the 0.4.x experimental home).

Online mutation: every sharder here can re-place a *mutated* index into
previously recorded array shapes — ``forest_shard_shapes`` +
``shard_forest(shapes=...)`` for the forest, a reserved row grid with an
explicit ``valid`` operand for the brute scan, a reserved bucket cap for
IVF — so :class:`repro.distributed.backend.ShardedSearchBackend` serves
through ``add_entities``/``delete_entities``/``rebalance`` without
re-jitting (see the README's "Online mutation" section).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.brute import batched_l2sq, pairwise_l2sq
from repro.kernels import ops as kernel_ops

__all__ = [
    "ShardPlan", "SINGLE_POD_PLAN", "MULTI_POD_PLAN", "LOCAL_PLAN",
    "sharded_brute_search", "sharded_ivf_search", "sharded_forest_search",
    "make_sharded_brute_fn", "make_sharded_ivf_fn", "make_sharded_forest_fn",
    "make_sharded_lexical_fn", "make_sharded_hybrid_fn",
    "shard_forest", "forest_shard_shapes", "ForestShardShapes",
    "slice_forest_delta", "slice_ivf_delta",
]


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    dp: tuple = ()
    fsdp: tuple = ()
    tp: tuple = ()
    ep: tuple = ()
    pp: tuple = ()      # pod-parallel remainder of dp once ep covers a pod
    mesh: Any = None

    def resolve(self, sym) -> Optional[tuple]:
        """role symbol | tuple of roles | None -> mesh-axis tuple | None."""
        if sym is None:
            return None
        if isinstance(sym, tuple):
            axes: list = []
            for s in sym:
                r = self.resolve(s)
                if r:
                    axes.extend(r)
            return tuple(dict.fromkeys(axes)) or None
        axes = getattr(self, sym)
        return tuple(axes) or None

    def p(self, *dims) -> P:
        return P(*[self.resolve(d) for d in dims])

    def constrain(self, x, *dims):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.p(*dims))
        )

    def axis_size(self, role: str) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in getattr(self, role):
            n *= self.mesh.shape[a]
        return n

    def size_of(self, sym) -> int:
        """Device count along a role symbol or tuple of roles."""
        if sym is None:
            return 1
        if isinstance(sym, tuple):
            n = 1
            for s in sym:
                n *= self.size_of(s)
            return n
        return self.axis_size(sym)

    def div_p(self, shape, *dims) -> P:
        """Like ``p`` but drops any role whose device count does not divide
        the corresponding dim (small/odd recsys layers stay replicated)."""
        parts = []
        for size, d in zip(shape, dims):
            parts.append(d if d and size % max(self.size_of(d), 1) == 0
                         else None)
        return self.p(*parts)

    def with_mesh(self, mesh) -> "ShardPlan":
        return dataclasses.replace(self, mesh=mesh)


LOCAL_PLAN = ShardPlan()

SINGLE_POD_PLAN = ShardPlan(
    dp=("data",), fsdp=("data",), tp=("model",), ep=("data", "model")
)

# ep stays within a pod (("data","model") = 256-way): experts are replicated
# across pods so the MoE all-to-all never crosses the slow inter-pod links;
# pods combine through the data-parallel gradient reduction only.  The
# dispatch-group dim stays sharded over "pod" (pp) during expert compute —
# without it, a P(None, ep, ...) constraint replicates every pod's tokens
# into both pods (observed 17 TB of cross-pod all-gather).
MULTI_POD_PLAN = ShardPlan(
    dp=("pod", "data"), fsdp=("pod", "data"), tp=("model",),
    ep=("data", "model"), pp=("pod",),
)


# ---------------------------------------------------------------------------
# Sharded search
# ---------------------------------------------------------------------------


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _q_spec(query_axes) -> P:
    return P(tuple(query_axes), None) if query_axes else P(None, None)


def _check_disjoint(axes, query_axes):
    """Corpus and query axes must not overlap: the merge all-gathers over
    the corpus axes, and a shared axis would top-k-merge results belonging
    to *different* queries — silently wrong, so refuse up front."""
    overlap = set(axes) & set(query_axes)
    if overlap:
        raise ValueError(
            f"query_axes {tuple(query_axes)} overlap corpus axes "
            f"{tuple(axes)} on {sorted(overlap)}; pass disjoint axes, e.g. "
            "axes=('data',), query_axes=('model',)")


def _brute_device_arrays(db, n_dev, rows=None, alive=None):
    """Zero-pad db rows to the shard grid.  Pads (and tombstoned rows, via
    ``alive``) are masked by an explicit per-row *valid* array rather than
    a row count baked into the jitted program, so a mutated corpus can be
    re-placed without re-jitting as long as the grid fits.  Returns
    (padded db, valid mask, rows per shard, real rows)."""
    db = jnp.asarray(db, jnp.float32)
    n = db.shape[0]
    if rows is None:
        rows = -(-n // n_dev)
    if rows * n_dev < n:
        raise ValueError(
            f"corpus has {n} rows but the shard grid holds only "
            f"{rows * n_dev}; rebuild the backend (or raise headroom)")
    valid = np.arange(rows * n_dev) < n
    if alive is not None:
        valid[:n] &= np.asarray(alive, bool)
    return (jnp.pad(db, ((0, rows * n_dev - n), (0, 0))),
            jnp.asarray(valid), rows, n)


def _merge_gathered(gd, gi, k):
    """(S, B, k) per-shard results -> merged (B, k)."""
    s, b, kk = gd.shape
    cat_d = jnp.moveaxis(gd, 0, 1).reshape(b, s * kk)
    cat_i = jnp.moveaxis(gi, 0, 1).reshape(b, s * kk)
    neg, sel = jax.lax.top_k(-cat_d, k)
    ids = jnp.take_along_axis(cat_i, sel, axis=1)
    return -neg, jnp.where(jnp.isinf(-neg), -1, ids)


def make_sharded_brute_fn(mesh, axes: tuple, k: int, shard_rows: int,
                          query_axes: tuple = (), *, fused: bool = True,
                          precision: str = "f32"):
    """Exact distributed search: db row-sharded over ``axes``; queries
    optionally batch-sharded over ``query_axes``.

    Pad rows (db zero-padded up to the shard grid) and tombstoned rows are
    masked by the explicit ``valid`` operand — never by inf-valued vectors,
    whose distances evaluate to ``inf - inf = NaN`` and can outrank real
    candidates in XLA's top_k.  ``valid`` being data (not a baked-in row
    count) is what lets ``ShardedSearchBackend.apply_updates`` serve
    through corpus mutations without re-jitting.

    ``fused=True`` (default) routes the per-shard scan through
    ``kernels.ops.l2_topk_op`` — on TPU the Pallas streaming kernel, which
    never materializes the local ``(B, rows)`` distance matrix; on CPU the
    jnp oracle whose ops are literally the unfused path's, so results are
    bitwise-identical either way.  ``precision="int8"`` (fused only)
    switches the operand set to per-row-scaled int8 codes — the callable
    then takes ``(codes, scales, valid, q)``.
    """
    _check_disjoint(axes, query_axes)
    if precision not in ("f32", "int8"):
        raise ValueError(f"precision must be 'f32' or 'int8', "
                         f"got {precision!r}")
    if precision == "int8" and not fused:
        raise ValueError("precision='int8' is a fused-kernel feature; "
                         "pass fused=True")
    k_loc = min(k, shard_rows)   # a shard may hold fewer rows than k

    def _finish_local(ld, li, lin):
        # shard-local slot ids -> global row ids; the (inf, -1) kernel
        # sentinel must stay -1 rather than alias shard 0's rows
        li = jnp.where(li >= 0, li + lin * shard_rows, -1).astype(jnp.int32)
        if k_loc < k:
            ld = jnp.pad(ld, ((0, 0), (0, k - k_loc)),
                         constant_values=jnp.inf)
            li = jnp.pad(li, ((0, 0), (0, k - k_loc)), constant_values=-1)
        gd = jax.lax.all_gather(ld, axes, tiled=False)     # (S, B, k)
        gi = jax.lax.all_gather(li, axes, tiled=False)
        return _merge_gathered(gd, gi, k)

    def local(db_shard, valid_shard, q):
        lin = jax.lax.axis_index(axes)                     # flattened index
        if fused:
            ld, li = kernel_ops.l2_topk_op(q, db_shard, k_loc,
                                           valid=valid_shard)
        else:
            d2 = pairwise_l2sq(q, db_shard)                # (B, rows)
            d2 = jnp.where(valid_shard[None, :], d2, jnp.inf)
            neg, li = jax.lax.top_k(-d2, k_loc)
            ld = -neg
        return _finish_local(ld, li, lin)

    def local_int8(codes_shard, scales_shard, valid_shard, q):
        lin = jax.lax.axis_index(axes)
        ld, li = kernel_ops.l2_topk_int8_op(
            q, codes_shard, scales_shard, k_loc, valid=valid_shard)
        return _finish_local(ld, li, lin)

    qs = _q_spec(query_axes)
    if precision == "int8":
        return shard_map(
            local_int8, mesh=mesh,
            in_specs=(P(tuple(axes), None), P(tuple(axes)),
                      P(tuple(axes)), qs),
            out_specs=(qs, qs),
            check_vma=False,
        )
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(tuple(axes), None), P(tuple(axes)), qs),
        out_specs=(qs, qs),
        check_vma=False,   # merge all-gathers over the corpus axes only
    )


def _brute_int8_device_arrays(db, n_dev, rows=None, alive=None):
    """int8 counterpart of ``_brute_device_arrays``: per-row symmetric
    quantization (``kernels.ops.quantize_rows_int8``) before padding, so
    pad rows are zero codes with scale 1.0 (dequantize to exact zero) and
    are masked by ``valid`` like every other dead row.  Returns
    (codes, scales, valid, rows per shard, real rows)."""
    db = np.asarray(db, np.float32)
    n = db.shape[0]
    if rows is None:
        rows = -(-n // n_dev)
    if rows * n_dev < n:
        raise ValueError(
            f"corpus has {n} rows but the shard grid holds only "
            f"{rows * n_dev}; rebuild the backend (or raise headroom)")
    codes, scales = kernel_ops.quantize_rows_int8(db)
    pad = rows * n_dev - n
    codes = np.pad(codes, ((0, pad), (0, 0)))
    scales = np.pad(scales, (0, pad), constant_values=1.0)
    valid = np.arange(rows * n_dev) < n
    if alive is not None:
        valid[:n] &= np.asarray(alive, bool)
    return (jnp.asarray(codes), jnp.asarray(scales), jnp.asarray(valid),
            rows, n)


def _pad_queries(mesh, queries, query_axes):
    q = jnp.asarray(queries, jnp.float32)
    B = q.shape[0]
    n_q = _axes_size(mesh, query_axes) if query_axes else 1
    Bp = -(-B // n_q) * n_q
    if Bp > B:
        q = jnp.pad(q, ((0, Bp - B), (0, 0)))
    return q, B


def _pad_term_queries(mesh, q_terms, q_weights, query_axes):
    """Batch-pad the lexical query operands to the query-axis grid.

    Pad rows get term id -1 (never matches a slab slot) and weight 0, so
    the padded queries score nothing and are trimmed after the merge —
    same contract as :func:`_pad_queries` for dense queries."""
    qt = jnp.asarray(q_terms, jnp.int32)
    qw = jnp.asarray(q_weights, jnp.float32)
    B = qt.shape[0]
    n_q = _axes_size(mesh, query_axes) if query_axes else 1
    Bp = -(-B // n_q) * n_q
    if Bp > B:
        qt = jnp.pad(qt, ((0, Bp - B), (0, 0)), constant_values=-1)
        qw = jnp.pad(qw, ((0, Bp - B), (0, 0)))
    return qt, qw, B


def sharded_brute_search(mesh, db, queries, k=10, axes=("data", "model"),
                         query_axes=(), fused=True, precision="f32"):
    """Host entry: shards db rows over ``axes`` and runs the distributed
    scan; ``query_axes`` shards the batch dim over a *disjoint* axis set.
    ``fused``/``precision`` select the kernel path (see
    :func:`make_sharded_brute_fn`)."""
    n_dev = _axes_size(mesh, axes)
    q, B = _pad_queries(mesh, queries, query_axes)
    put = lambda x, spec: jax.device_put(x, NamedSharding(mesh, spec))
    if precision == "int8":
        codes, scales, valid, rows, _ = _brute_int8_device_arrays(db, n_dev)
        fn = make_sharded_brute_fn(mesh, tuple(axes), k, rows,
                                   tuple(query_axes), fused=fused,
                                   precision=precision)
        with mesh:
            d, i = fn(put(codes, P(tuple(axes), None)),
                      put(scales, P(tuple(axes))),
                      put(valid, P(tuple(axes))),
                      put(q, _q_spec(query_axes)))
    else:
        dbp, valid, rows, _ = _brute_device_arrays(db, n_dev)
        fn = make_sharded_brute_fn(mesh, tuple(axes), k, rows,
                                   tuple(query_axes), fused=fused,
                                   precision=precision)
        with mesh:
            d, i = fn(put(dbp, P(tuple(axes), None)),
                      put(valid, P(tuple(axes))),
                      put(q, _q_spec(query_axes)))
    d, i = jax.device_get((d, i))
    return np.asarray(d)[:B], np.asarray(i)[:B]


def _lexical_device_arrays(terms, tf_sat, n_dev, rows=None, alive=None):
    """Postings-slab counterpart of ``_brute_device_arrays``: term rows
    padded with -1 (no term id 0 aliasing), tf rows with zeros; pads and
    tombstones are masked by the same explicit ``valid`` operand.
    Returns (padded terms, padded tf_sat, valid, rows per shard, n)."""
    t = np.asarray(terms, np.int32)
    f = np.asarray(tf_sat, np.float32)
    n = t.shape[0]
    if rows is None:
        rows = -(-n // n_dev)
    if rows * n_dev < n:
        raise ValueError(
            f"postings have {n} rows but the shard grid holds only "
            f"{rows * n_dev}; rebuild the backend (or raise headroom)")
    pad = rows * n_dev - n
    tp = np.pad(t, ((0, pad), (0, 0)), constant_values=-1)
    fp = np.pad(f, ((0, pad), (0, 0)))
    valid = np.arange(rows * n_dev) < n
    if alive is not None:
        valid[:n] &= np.asarray(alive, bool)
    return (jnp.asarray(tp), jnp.asarray(fp), jnp.asarray(valid), rows, n)


def make_sharded_lexical_fn(mesh, axes: tuple, k: int, shard_rows: int,
                            query_axes: tuple = (), *, fused: bool = True):
    """Distributed BM25 lexical scan: postings slabs row-sharded over
    ``axes`` — the brute layout with term/tf slabs in place of vectors.
    The callable takes ``(terms, tf_sat, valid, q_terms, q_weights)``;
    filters and tombstones compose through ``valid`` exactly as in the
    brute scan, so a filtered call reuses the unfiltered signature.
    """
    from repro.kernels.ref import bm25_dists_ref

    _check_disjoint(axes, query_axes)
    k_loc = min(k, shard_rows)

    def _finish_local(ld, li, lin):
        li = jnp.where(li >= 0, li + lin * shard_rows, -1).astype(jnp.int32)
        if k_loc < k:
            ld = jnp.pad(ld, ((0, 0), (0, k - k_loc)),
                         constant_values=jnp.inf)
            li = jnp.pad(li, ((0, 0), (0, k - k_loc)), constant_values=-1)
        gd = jax.lax.all_gather(ld, axes, tiled=False)
        gi = jax.lax.all_gather(li, axes, tiled=False)
        return _merge_gathered(gd, gi, k)

    def local(terms_shard, tf_shard, valid_shard, qt, qw):
        lin = jax.lax.axis_index(axes)
        if fused:
            ld, li = kernel_ops.bm25_topk_op(
                qt, qw, terms_shard, tf_shard, k_loc, valid=valid_shard)
        else:
            dist = bm25_dists_ref(qt, qw, terms_shard, tf_shard)
            dist = jnp.where(valid_shard[None, :], dist, jnp.inf)
            neg, li = jax.lax.top_k(-dist, k_loc)
            ld = -neg
        return _finish_local(ld, li, lin)

    qs = _q_spec(query_axes)
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(tuple(axes), None), P(tuple(axes), None),
                  P(tuple(axes)), qs, qs),
        out_specs=(qs, qs),
        check_vma=False,   # merge all-gathers over the corpus axes only
    )


def make_sharded_hybrid_fn(mesh, axes: tuple, k: int, shard_rows: int,
                           query_axes: tuple = (), *, fused: bool = True):
    """Distributed hybrid scan: semantic L2 and BM25 fused per shard as
    ``alpha * l2sq - (1 - alpha) * bm25``.

    The callable takes ``(db, terms, tf_sat, valid, q, q_terms,
    q_weights, alpha)``; ``alpha`` is a replicated (1, 1) f32 *operand*
    — sweeping the blend mints no new executables (the recompile gate's
    ``filtered-sharded-search`` entry covers this).
    """
    from repro.kernels.ref import bm25_dists_ref

    _check_disjoint(axes, query_axes)
    k_loc = min(k, shard_rows)

    def _finish_local(ld, li, lin):
        li = jnp.where(li >= 0, li + lin * shard_rows, -1).astype(jnp.int32)
        if k_loc < k:
            ld = jnp.pad(ld, ((0, 0), (0, k - k_loc)),
                         constant_values=jnp.inf)
            li = jnp.pad(li, ((0, 0), (0, k - k_loc)), constant_values=-1)
        gd = jax.lax.all_gather(ld, axes, tiled=False)
        gi = jax.lax.all_gather(li, axes, tiled=False)
        return _merge_gathered(gd, gi, k)

    def local(db_shard, terms_shard, tf_shard, valid_shard,
              q, qt, qw, alpha):
        lin = jax.lax.axis_index(axes)
        if fused:
            ld, li = kernel_ops.hybrid_topk_op(
                q, db_shard, qt, qw, terms_shard, tf_shard, alpha, k_loc,
                valid=valid_shard)
        else:
            d2 = pairwise_l2sq(q, db_shard)
            score = -bm25_dists_ref(qt, qw, terms_shard, tf_shard)
            a = jnp.asarray(alpha, jnp.float32).reshape(1, 1)
            dist = a * d2 - (1.0 - a) * score
            dist = jnp.where(valid_shard[None, :], dist, jnp.inf)
            neg, li = jax.lax.top_k(-dist, k_loc)
            ld = -neg
        return _finish_local(ld, li, lin)

    qs = _q_spec(query_axes)
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(tuple(axes), None), P(tuple(axes), None),
                  P(tuple(axes), None), P(tuple(axes)),
                  qs, qs, qs, P(None, None)),
        out_specs=(qs, qs),
        check_vma=False,   # merge all-gathers over the corpus axes only
    )


def make_sharded_ivf_fn(mesh, axes: tuple, k: int, nprobe_local: int,
                        buckets_per_shard: int, n_buckets: int,
                        query_axes: tuple = (), *, fused: bool = True):
    """Distributed two-level, brute bottom: centroids + padded buckets
    sharded over the mesh.

    Each chip: (1) scores its local centroids, (2) probes its local
    ``nprobe_local`` best buckets, (3) contributes its local top-k to the
    global all-gather merge.  Global nprobe = nprobe_local * n_shards —
    probing is *wider* than single-chip at equal latency, a scale-out win
    the paper's single-device protocol cannot reach.  Pad centroids (zero
    vectors beyond ``n_buckets``) are masked by global bucket index.
    """

    _check_disjoint(axes, query_axes)
    nprobe_local = min(nprobe_local, buckets_per_shard)

    def local(cents, bucket_ids, bucket_vecs, q):
        # cents: (Kloc, d); bucket_ids: (Kloc, cap); bucket_vecs (Kloc, cap, d)
        lin = jax.lax.axis_index(axes)
        gbucket = lin * buckets_per_shard + jnp.arange(
            buckets_per_shard, dtype=jnp.int32)
        d2c = pairwise_l2sq(q, cents)                      # (B, Kloc)
        d2c = jnp.where(gbucket[None, :] < n_buckets, d2c, jnp.inf)
        _, probe = jax.lax.top_k(-d2c, nprobe_local)       # (B, np)

        def scan_probe(carry, j):
            best_d, best_i = carry
            bsel = probe[:, j]                             # (B,)
            ids = bucket_ids[bsel]                         # (B, cap)
            vecs = bucket_vecs[bsel]                       # (B, cap, d)
            if fused:
                # distance + merge in one op (Pallas candidate kernel on
                # TPU; the same-ops jnp oracle on CPU) — the probe chain
                # carries the running best through the kernel
                return kernel_ops.candidate_topk_op(
                    q, vecs, ids, k, best_d=best_d, best_i=best_i), None
            d2 = batched_l2sq(vecs, q)
            d2 = jnp.where(ids >= 0, d2, jnp.inf)
            cat_d = jnp.concatenate([best_d, d2], axis=1)
            cat_i = jnp.concatenate([best_i, ids], axis=1)
            neg, sel = jax.lax.top_k(-cat_d, k)
            return (-neg, jnp.take_along_axis(cat_i, sel, 1)), None

        B = q.shape[0]
        init = (jnp.full((B, k), jnp.inf, jnp.float32),
                jnp.full((B, k), -1, jnp.int32))
        (ld, li), _ = jax.lax.scan(scan_probe, init,
                                   jnp.arange(nprobe_local, dtype=jnp.int32))
        gd = jax.lax.all_gather(ld, axes, tiled=False)
        gi = jax.lax.all_gather(li, axes, tiled=False)
        return _merge_gathered(gd, gi, k)

    qs = _q_spec(query_axes)
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(tuple(axes), None), P(tuple(axes), None),
                  P(tuple(axes), None, None), qs),
        out_specs=(qs, qs),
        check_vma=False,   # merge all-gathers over the corpus axes only
    )


def _ivf_device_arrays(index, n_dev, cap=None):
    """Pad a built TwoLevelIndex's centroid/bucket tables to the shard grid
    (zero vectors, -1 ids — pads are masked by index, never by inf).
    ``cap`` pads the bucket width beyond the index's own (update headroom:
    a mutated index re-places into the same shapes, so the jitted search
    is reused)."""
    K, cap_now = index.bucket_ids.shape
    if cap is None:
        cap = cap_now
    if cap < cap_now:
        raise ValueError(
            f"bucket cap grew to {cap_now} > reserved {cap}; rebuild the "
            f"backend (or raise headroom)")
    Kp = -(-K // n_dev) * n_dev
    pad = Kp - K
    cents = jnp.pad(jnp.asarray(index.centroids, jnp.float32),
                    ((0, pad), (0, 0)))
    bids = jnp.pad(jnp.asarray(index.bucket_ids),
                   ((0, pad), (0, cap - cap_now)), constant_values=-1)
    dbj = jnp.asarray(index.db)
    bvecs = dbj[jnp.maximum(bids, 0)]
    bvecs = jnp.where((bids >= 0)[..., None], bvecs, 0.0)
    return cents, bids, bvecs, Kp


def sharded_ivf_search(mesh, index, queries, k=10, nprobe_local=2,
                       axes=("data", "model"), query_axes=(), fused=True):
    """Host entry: shards a built TwoLevelIndex (brute bottom) over the
    mesh.  ``index.bucket_ids`` keeps *global* entity ids, so the merged
    result ids are directly comparable with the single-chip index."""
    n_dev = _axes_size(mesh, axes)
    K = index.bucket_ids.shape[0]
    cents, bids, bvecs, Kp = _ivf_device_arrays(index, n_dev)
    fn = make_sharded_ivf_fn(mesh, tuple(axes), k, nprobe_local,
                             Kp // n_dev, K, tuple(query_axes), fused=fused)
    q, B = _pad_queries(mesh, queries, query_axes)
    with mesh:
        put = lambda x, spec: jax.device_put(x, NamedSharding(mesh, spec))
        d, i = fn(
            put(cents, P(tuple(axes), None)),
            put(bids, P(tuple(axes), None)),
            put(bvecs, P(tuple(axes), None, None)),
            put(q, _q_spec(query_axes)),
        )
    d, i = jax.device_get((d, i))
    return np.asarray(d)[:B], np.asarray(i)[:B]


# ---------------------------------------------------------------------------
# Sharded tree/QLBT forest bottom level
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ForestShardShapes:
    """Fixed per-shard array shapes for a sliced forest.

    Recorded at backend construction (optionally with headroom) and
    re-applied by :meth:`ShardedSearchBackend.apply_updates`: a mutated
    index re-slices into the *same* shapes, so the jitted shard_map search
    keeps its compile cache across the whole index lifecycle.

    Two layouts share this record:

    * **packed** (``node_slab == 0``): each shard's buckets are packed
      back-to-back, minimal padding — the host entry points' layout.
    * **slab** (``node_slab > 0``): every bucket owns a fixed
      ``node_slab``-row node window and ``leaf_slab``-row leaf window at
      ``slot * slab``, so one bucket's rebuilt tree overwrites only its
      own slabs.  This is what makes *delta shipping* possible — a dirty
      bucket is a fixed-shape payload scattered in place on device — at
      the cost of padding every bucket to the largest tree
      (``nodes == kloc * node_slab``).
    """
    n_dev: int
    kloc: int       # buckets per shard
    cap: int        # bucket pad width
    nodes: int      # node-table rows per shard (excluding the dead node)
    leaves: int     # leaf-table rows per shard
    leaf_sz: int    # leaf width (entities per leaf row)
    max_depth: int  # bound on descent steps
    node_slab: int = 0   # slab layout: node rows reserved per bucket
    leaf_slab: int = 0   # slab layout: leaf rows reserved per bucket


def _forest_slices(index, n_dev: int):
    """Per-shard (b0, b1, N0, N1, L0, L1) bucket/node/leaf windows."""
    f = index.forest
    if f is None:
        raise ValueError("index has no forest (bottom must be tree/qlbt)")
    K = index.bucket_ids.shape[0]
    Kloc = -(-K // n_dev)
    leaf_row = np.asarray(f.arrays["leaf_row"])
    roots = np.asarray(f.roots, dtype=np.int64)
    n_nodes = leaf_row.shape[0]
    bounds = np.concatenate([roots, [n_nodes]])
    slices = []
    for s in range(n_dev):
        b0 = min(s * Kloc, K)
        b1 = min(b0 + Kloc, K)
        N0 = int(bounds[b0]) if b0 < K else n_nodes
        N1 = int(bounds[b1]) if b0 < K else n_nodes
        lr = leaf_row[N0:N1]
        rows = lr[lr >= 0]
        L0 = int(rows.min()) if rows.size else 0
        L1 = int(rows.max()) + 1 if rows.size else 0
        if rows.size not in (0, L1 - L0):
            raise ValueError(
                f"shard {s}: leaf rows not contiguous ({rows.size} rows in "
                f"window [{L0}, {L1})); _build_forest concatenation order "
                "changed?")
        slices.append((b0, b1, N0, N1, L0, L1))
    return slices, Kloc


def _bucket_windows(index):
    """Per-bucket (N0, N1, L0, L1) node/leaf windows in the concatenated
    forest (bucket ``b`` owns nodes ``[roots[b], roots[b+1])`` and the
    contiguous leaf rows its own tree contributed)."""
    f = index.forest
    if f is None:
        raise ValueError("index has no forest (bottom must be tree/qlbt)")
    K = index.bucket_ids.shape[0]
    leaf_row = np.asarray(f.arrays["leaf_row"])
    roots = np.asarray(f.roots, dtype=np.int64)
    bounds = np.concatenate([roots, [leaf_row.shape[0]]])
    windows = []
    for b in range(K):
        N0, N1 = int(bounds[b]), int(bounds[b + 1])
        lr = leaf_row[N0:N1]
        rows = lr[lr >= 0]
        L0 = int(rows.min()) if rows.size else 0
        L1 = int(rows.max()) + 1 if rows.size else 0
        if rows.size not in (0, L1 - L0):
            raise ValueError(
                f"bucket {b}: leaf rows not contiguous; "
                "_build_forest concatenation order changed?")
        windows.append((N0, N1, L0, L1))
    return windows


def forest_shard_shapes(index, n_dev: int, headroom: float = 1.0,
                        layout: str = "packed") -> ForestShardShapes:
    """Measure the natural per-shard shapes; ``headroom`` > 1 reserves
    room for post-mutation growth (bigger buckets after adds, deeper or
    wider trees after dirty-bucket rebuilds).

    ``layout="slab"`` reserves a fixed node/leaf slab *per bucket*
    (``headroom`` scales the slab against the current largest bucket
    tree) — the delta-shipping layout; see :class:`ForestShardShapes`.
    """
    f = index.forest
    cap = index.bucket_ids.shape[1]
    leaf_sz = np.asarray(f.arrays["leaf_entities"]).shape[1] if f else 0
    grow = lambda x: int(np.ceil(x * headroom))
    extra_depth = 8 if headroom > 1.0 else 0
    if layout == "slab":
        windows = _bucket_windows(index)
        K = index.bucket_ids.shape[0]
        Kloc = -(-K // n_dev)
        node_slab = grow(max(max((N1 - N0 for N0, N1, _, _ in windows),
                                 default=0), 1))
        leaf_slab = grow(max(max((L1 - L0 for *_, L0, L1 in windows),
                                 default=0), 1))
        return ForestShardShapes(
            n_dev=n_dev, kloc=Kloc, cap=grow(cap),
            nodes=Kloc * node_slab, leaves=Kloc * leaf_slab,
            leaf_sz=leaf_sz, max_depth=f.max_depth + extra_depth,
            node_slab=node_slab, leaf_slab=leaf_slab,
        )
    if layout != "packed":
        raise ValueError(f"layout must be 'packed' or 'slab', got {layout!r}")
    slices, Kloc = _forest_slices(index, n_dev)
    maxN = max(max((N1 - N0 for _, _, N0, N1, _, _ in slices), default=0), 1)
    maxL = max(max((L1 - L0 for *_, L0, L1 in slices), default=0), 1)
    return ForestShardShapes(
        n_dev=n_dev, kloc=Kloc, cap=grow(cap), nodes=grow(maxN),
        leaves=grow(maxL), leaf_sz=leaf_sz,
        max_depth=f.max_depth + extra_depth,
    )


def shard_forest(index, n_dev: int, *,
                 shapes: Optional[ForestShardShapes] = None) -> dict:
    """Slice a built forest index into ``n_dev`` equal-shape shards.

    The two-level build concatenates per-bucket trees into one node table
    (``two_level._build_forest``); bucket ``b`` owns node range
    ``[roots[b], roots[b+1])`` and a contiguous run of leaf-table rows.
    Each shard takes a contiguous block of buckets, re-bases node/leaf
    offsets, and remaps leaf entity ids from *global* entity ids to local
    *bucket-slot* ids (``bucket_row * cap + col``) so the rerank gathers
    from the shard's own ``(Kloc, cap, d)`` vector tile — corpus memory
    stays sharded.  One extra dead node per shard backs padded bucket
    roots.  Returns host (numpy) arrays stacked on a leading shard dim.

    ``shapes`` pads every shard to the given fixed sizes (raising if the
    forest outgrew them) so re-slicing a *mutated* index produces arrays
    of identical shape — the no-re-jit update path.  Deleted entities are
    naturally dropped: they are absent from ``bucket_ids``, so their leaf
    slots remap to -1.

    A ``shapes`` with ``node_slab > 0`` switches to the *slab* layout
    (every bucket at a fixed per-slot window — the delta-shipping
    layout); the two layouts produce identical search results, they only
    differ in padding placement.
    """
    if shapes is not None and shapes.node_slab > 0:
        return _shard_forest_slab(index, shapes)
    slices, Kloc = _forest_slices(index, n_dev)
    f = index.forest
    K, cap_now = index.bucket_ids.shape
    arrays = {name: np.asarray(v) for name, v in f.arrays.items()}
    roots = np.asarray(f.roots, dtype=np.int64)
    d = index.db.shape[1]
    leaf_sz_now = arrays["leaf_entities"].shape[1]
    maxN = max(max((N1 - N0 for _, _, N0, N1, _, _ in slices), default=0), 1)
    maxL = max(max((L1 - L0 for *_, L0, L1 in slices), default=0), 1)

    if shapes is None:
        shapes = ForestShardShapes(
            n_dev=n_dev, kloc=Kloc, cap=cap_now, nodes=maxN, leaves=maxL,
            leaf_sz=leaf_sz_now, max_depth=f.max_depth)
    else:
        over = []
        if shapes.n_dev != n_dev:
            over.append(f"n_dev {n_dev} != {shapes.n_dev}")
        if Kloc > shapes.kloc:
            over.append(f"kloc {Kloc} > {shapes.kloc}")
        if cap_now > shapes.cap:
            over.append(f"cap {cap_now} > {shapes.cap}")
        if maxN > shapes.nodes:
            over.append(f"nodes {maxN} > {shapes.nodes}")
        if maxL > shapes.leaves:
            over.append(f"leaves {maxL} > {shapes.leaves}")
        if leaf_sz_now > shapes.leaf_sz:
            over.append(f"leaf_sz {leaf_sz_now} > {shapes.leaf_sz}")
        if f.max_depth > shapes.max_depth:
            over.append(f"max_depth {f.max_depth} > {shapes.max_depth}")
        if over:
            raise ValueError(
                "forest outgrew the reserved shard shapes ("
                + ", ".join(over)
                + "); rebuild the backend (or raise headroom)")
    Kloc, cap = shapes.kloc, shapes.cap
    padN, padL, leaf_sz = shapes.nodes, shapes.leaves, shapes.leaf_sz
    dead = padN                               # per-shard dead-leaf node id

    out = {
        "proj": np.zeros((n_dev, padN + 1, d), np.float32),
        "dims": np.zeros((n_dev, padN + 1), arrays["dims"].dtype),
        "tau": np.zeros((n_dev, padN + 1), np.float32),
        "children": np.full((n_dev, padN + 1, 2), -1, np.int32),
        "leaf_row": np.full((n_dev, padN + 1), -1, np.int32),
        "leaf_entities": np.full((n_dev, padL, leaf_sz), -1, np.int32),
        "roots": np.full((n_dev, Kloc), dead, np.int32),
        "valid": np.zeros((n_dev, Kloc), bool),
        "cents": np.zeros((n_dev, Kloc, d), np.float32),
        "bucket_ids": np.full((n_dev, Kloc, cap), -1, np.int32),
        "bvecs": np.zeros((n_dev, Kloc, cap, d), np.float32),
    }
    for s, (b0, b1, N0, N1, L0, L1) in enumerate(slices):
        nb, nn, nl = b1 - b0, N1 - N0, L1 - L0
        if nb == 0:
            continue
        ch = arrays["children"][N0:N1].copy()
        ch[ch >= 0] -= N0
        lr = arrays["leaf_row"][N0:N1].copy()
        lr[lr >= 0] -= L0
        out["proj"][s, :nn] = arrays["proj"][N0:N1]
        out["dims"][s, :nn] = arrays["dims"][N0:N1]
        out["tau"][s, :nn] = arrays["tau"][N0:N1]
        out["children"][s, :nn] = ch
        out["leaf_row"][s, :nn] = lr
        out["roots"][s, :nb] = (roots[b0:b1] - N0).astype(np.int32)
        out["valid"][s, :nb] = True
        out["cents"][s, :nb] = index.centroids[b0:b1]
        bl = index.bucket_ids[b0:b1]
        out["bucket_ids"][s, :nb, :cap_now] = bl
        bv = index.db[np.maximum(bl, 0)]
        out["bvecs"][s, :nb, :cap_now] = np.where((bl >= 0)[..., None], bv,
                                                  0.0)
        # global entity id -> local bucket-slot id for this shard's leaves
        # (deleted entities are absent from bucket_ids -> slot -1)
        slot_of = np.full(index.db.shape[0], -1, np.int64)
        rr, cc = np.nonzero(bl >= 0)
        slot_of[bl[rr, cc]] = rr * cap + cc
        le = arrays["leaf_entities"][L0:L1]
        le = np.pad(le, ((0, 0), (0, leaf_sz - le.shape[1])),
                    constant_values=-1).copy()
        m = le >= 0
        le[m] = slot_of[le[m]]
        out["leaf_entities"][s, :nl] = le
    out["max_depth"] = shapes.max_depth
    return out


def _slab_slot_of(index, Kloc: int, cap: int) -> np.ndarray:
    """Global entity id -> slab bucket-slot id (``(b % Kloc) * cap + col``)
    for every placed entity; -1 for deleted/absent.  One vectorized pass,
    shared by the full slab slicer and the delta slicer."""
    rr, cc = np.nonzero(index.bucket_ids >= 0)
    keep = cc < cap          # per-bucket overflow is diagnosed later
    rr, cc = rr[keep], cc[keep]
    slot_of = np.full(index.db.shape[0], -1, np.int64)
    slot_of[index.bucket_ids[rr, cc]] = (rr % Kloc) * cap + cc
    return slot_of


def _bucket_slab_payload(index, shapes: ForestShardShapes, b: int, j: int,
                         arrays: dict, roots: np.ndarray,
                         windows, slot_of: np.ndarray) -> dict:
    """One bucket's fixed-shape slab: every per-bucket array padded to the
    reserved slab sizes, node/leaf offsets rebased to slot ``j``'s
    windows, leaf entity ids remapped to the shard's bucket-slot ids
    (via the precomputed ``slot_of``).  Raises when the bucket outgrew a
    reservation (the same loud contract as the packed slicer)."""
    N0, N1, L0, L1 = windows[b]
    nb, nl = N1 - N0, L1 - L0
    cap, node_slab, leaf_slab = shapes.cap, shapes.node_slab, shapes.leaf_slab
    d = index.db.shape[1]
    over = []
    if nb > node_slab:
        over.append(f"nodes {nb} > slab {node_slab}")
    if nl > leaf_slab:
        over.append(f"leaves {nl} > slab {leaf_slab}")
    bl_full = index.bucket_ids[b]
    count = int((bl_full >= 0).sum())
    if count > cap:
        over.append(f"bucket count {count} > cap {cap}")
    le_w = arrays["leaf_entities"].shape[1]
    if le_w > shapes.leaf_sz:
        over.append(f"leaf_sz {le_w} > {shapes.leaf_sz}")
    if over:
        raise ValueError(
            f"bucket {b} outgrew the reserved slab shapes ("
            + ", ".join(over) + "); rebuild the backend (or raise headroom)")

    proj = np.zeros((node_slab, d), np.float32)
    dims = np.zeros((node_slab,), arrays["dims"].dtype)
    tau = np.zeros((node_slab,), np.float32)
    children = np.full((node_slab, 2), -1, np.int32)
    leaf_row = np.full((node_slab,), -1, np.int32)
    leaf_ents = np.full((leaf_slab, shapes.leaf_sz), -1, np.int32)
    proj[:nb] = arrays["proj"][N0:N1]
    dims[:nb] = arrays["dims"][N0:N1]
    tau[:nb] = arrays["tau"][N0:N1]
    ch = arrays["children"][N0:N1].astype(np.int32, copy=True)
    ch[ch >= 0] += j * node_slab - N0
    children[:nb] = ch
    lr = arrays["leaf_row"][N0:N1].astype(np.int32, copy=True)
    lr[lr >= 0] += j * leaf_slab - L0
    leaf_row[:nb] = lr

    # global entity id -> this shard's bucket-slot id (deleted entities
    # are absent from bucket_ids -> slot -1 via the shared slot_of map)
    bids = np.full((cap,), -1, np.int32)
    w = min(cap, bl_full.shape[0])
    bids[:w] = bl_full[:w]
    le = arrays["leaf_entities"][L0:L1]
    le = np.pad(le, ((0, 0), (0, shapes.leaf_sz - le.shape[1])),
                constant_values=-1).astype(np.int32, copy=True)
    m = le >= 0
    le[m] = slot_of[le[m]]
    leaf_ents[:nl] = le

    bv = index.db[np.maximum(bids, 0)].astype(np.float32)
    bv = np.where((bids >= 0)[:, None], bv, 0.0)
    return {
        "proj": proj, "dims": dims, "tau": tau, "children": children,
        "leaf_row": leaf_row, "leaf_entities": leaf_ents,
        "roots": np.int32(j * node_slab + int(roots[b] - N0)),
        "valid": True,
        "cents": index.centroids[b].astype(np.float32),
        "bucket_ids": bids, "bvecs": bv,
    }


def _shard_forest_slab(index, shapes: ForestShardShapes) -> dict:
    """Slab-layout slicer: same output contract as the packed
    ``shard_forest`` (stacked host arrays + ``max_depth``), but bucket
    ``b`` always lands at slot ``b % kloc`` of shard ``b // kloc`` with
    fixed node/leaf windows — so a mutated bucket later re-ships as a
    standalone slab (:func:`slice_forest_delta`)."""
    f = index.forest
    K = index.bucket_ids.shape[0]
    n_dev, Kloc = shapes.n_dev, shapes.kloc
    if -(-K // n_dev) > Kloc:
        raise ValueError(
            f"forest outgrew the reserved shard shapes (kloc "
            f"{-(-K // n_dev)} > {Kloc}); rebuild the backend")
    if f.max_depth > shapes.max_depth:
        raise ValueError(
            f"forest outgrew the reserved shard shapes (max_depth "
            f"{f.max_depth} > {shapes.max_depth}); rebuild the backend "
            "(or raise headroom)")
    arrays = {name: np.asarray(v) for name, v in f.arrays.items()}
    roots = np.asarray(f.roots, dtype=np.int64)
    windows = _bucket_windows(index)
    d = index.db.shape[1]
    padN, padL, cap = shapes.nodes, shapes.leaves, shapes.cap
    dead = padN                               # per-shard dead-leaf node id
    out = {
        "proj": np.zeros((n_dev, padN + 1, d), np.float32),
        "dims": np.zeros((n_dev, padN + 1), arrays["dims"].dtype),
        "tau": np.zeros((n_dev, padN + 1), np.float32),
        "children": np.full((n_dev, padN + 1, 2), -1, np.int32),
        "leaf_row": np.full((n_dev, padN + 1), -1, np.int32),
        "leaf_entities": np.full((n_dev, padL, shapes.leaf_sz), -1,
                                 np.int32),
        "roots": np.full((n_dev, Kloc), dead, np.int32),
        "valid": np.zeros((n_dev, Kloc), bool),
        "cents": np.zeros((n_dev, Kloc, d), np.float32),
        "bucket_ids": np.full((n_dev, Kloc, cap), -1, np.int32),
        "bvecs": np.zeros((n_dev, Kloc, cap, d), np.float32),
    }
    ns, ls = shapes.node_slab, shapes.leaf_slab
    slot_of = _slab_slot_of(index, Kloc, cap)
    for b in range(K):
        s, j = b // Kloc, b % Kloc
        p = _bucket_slab_payload(index, shapes, b, j, arrays, roots,
                                 windows, slot_of)
        out["proj"][s, j * ns:(j + 1) * ns] = p["proj"]
        out["dims"][s, j * ns:(j + 1) * ns] = p["dims"]
        out["tau"][s, j * ns:(j + 1) * ns] = p["tau"]
        out["children"][s, j * ns:(j + 1) * ns] = p["children"]
        out["leaf_row"][s, j * ns:(j + 1) * ns] = p["leaf_row"]
        out["leaf_entities"][s, j * ls:(j + 1) * ls] = p["leaf_entities"]
        out["roots"][s, j] = p["roots"]
        out["valid"][s, j] = True
        out["cents"][s, j] = p["cents"]
        out["bucket_ids"][s, j] = p["bucket_ids"]
        out["bvecs"][s, j] = p["bvecs"]
    out["max_depth"] = shapes.max_depth
    return out


def slice_forest_delta(index, shapes: ForestShardShapes,
                       dirty_buckets) -> dict:
    """Slice only the dirty buckets into stacked fixed-shape slab
    payloads (slab layout required: ``shapes.node_slab > 0``).

    Returns host arrays keyed like the device tables plus ``shard`` /
    ``slot`` index vectors — the operand set of the backend's jitted
    in-place scatter.  Payload bytes are what a delta republish actually
    ships; compare against the full re-place bytes for the fallback
    decision.
    """
    if shapes.node_slab <= 0:
        raise ValueError("delta slicing requires the slab layout "
                         "(forest_shard_shapes(..., layout='slab'))")
    K = index.bucket_ids.shape[0]
    dirty = np.unique(np.asarray(dirty_buckets, dtype=np.int64))
    if dirty.size and (dirty.min() < 0 or dirty.max() >= K):
        raise ValueError(f"dirty bucket id out of range [0, {K})")
    f = index.forest
    if f.max_depth > shapes.max_depth:
        raise ValueError(
            f"forest outgrew the reserved shard shapes (max_depth "
            f"{f.max_depth} > {shapes.max_depth}); rebuild the backend "
            "(or raise headroom)")
    arrays = {name: np.asarray(v) for name, v in f.arrays.items()}
    roots = np.asarray(f.roots, dtype=np.int64)
    windows = _bucket_windows(index)
    Kloc = shapes.kloc
    slot_of = _slab_slot_of(index, Kloc, shapes.cap)
    rows = [_bucket_slab_payload(index, shapes, int(b), int(b % Kloc),
                                 arrays, roots, windows, slot_of)
            for b in dirty]
    out = {"shard": (dirty // Kloc).astype(np.int32),
           "slot": (dirty % Kloc).astype(np.int32)}
    for name in ("proj", "dims", "tau", "children", "leaf_row",
                 "leaf_entities", "roots", "valid", "cents",
                 "bucket_ids", "bvecs"):
        out[name] = np.stack([p[name] for p in rows]) if rows else \
            np.zeros((0,), np.int32)
    return out


def slice_ivf_delta(index, cap: int, dirty_buckets) -> dict:
    """Dirty-bucket rows of the IVF device tables (centroid, padded slot
    row, gathered bucket-vector tile), ready to scatter at ``rows``."""
    K, cap_now = index.bucket_ids.shape
    if cap < cap_now:
        raise ValueError(
            f"bucket cap grew to {cap_now} > reserved {cap}; rebuild the "
            f"backend (or raise headroom)")
    dirty = np.unique(np.asarray(dirty_buckets, dtype=np.int64))
    if dirty.size and (dirty.min() < 0 or dirty.max() >= K):
        raise ValueError(f"dirty bucket id out of range [0, {K})")
    bids = np.full((dirty.size, cap), -1, np.int32)
    bids[:, :cap_now] = index.bucket_ids[dirty]
    bvecs = index.db[np.maximum(bids, 0)].astype(np.float32)
    bvecs = np.where((bids >= 0)[..., None], bvecs, 0.0)
    return {
        "rows": dirty.astype(np.int32),
        "cents": index.centroids[dirty].astype(np.float32),
        "bucket_ids": bids,
        "bvecs": bvecs,
    }


def make_sharded_forest_fn(mesh, axes: tuple, k: int, nprobe_local: int,
                           beam_width: int, leaf_size: int, max_depth: int,
                           query_axes: tuple = (), *, fused: bool = True):
    """Distributed two-level, tree/QLBT bottom.

    Per chip: score local centroids -> descend the local forest for the
    ``nprobe_local`` best buckets (one batched beam search over the
    shard's node table) -> rerank candidates against the shard's bucket
    vector tile -> global all-gather merge, exactly as the brute/IVF paths.
    """
    from repro.core.tree import tree_search

    _check_disjoint(axes, query_axes)

    def local(cents, valid, roots, bids, bvecs,
              proj, dims, tau, children, leaf_row, leaf_ents, q):
        # every corpus-side array carries a leading length-1 shard dim
        cents, valid, roots = cents[0], valid[0], roots[0]
        bids, bvecs = bids[0], bvecs[0]
        arrays = dict(proj=proj[0], dims=dims[0], tau=tau[0],
                      children=children[0], leaf_row=leaf_row[0],
                      leaf_entities=leaf_ents[0])
        B, dd = q.shape
        np_eff = min(nprobe_local, cents.shape[0])
        d2c = pairwise_l2sq(q, cents)
        d2c = jnp.where(valid[None, :], d2c, jnp.inf)
        _, probe = jax.lax.top_k(-d2c, np_eff)             # (B, np)
        rr = roots[probe].reshape(-1)
        qq = jnp.repeat(q, np_eff, axis=0)                 # (B*np, d)
        vecs_flat = bvecs.reshape(-1, dd)                  # (Kloc*cap, d)
        res = tree_search(
            arrays, vecs_flat, qq, kind="rp", beam_width=beam_width,
            k=beam_width * leaf_size, max_steps=max_depth + 4,
            rerank=False, roots=rr,
        )
        cand = res.ids.reshape(B, -1)                      # local slot ids
        # bucket-slot liveness: a probed slot whose bucket entry is -1
        # holds no servable entity — pad slots, compacted deletes, and
        # (since filters mask bucket_ids the same way) filtered-out rows.
        # For an unfiltered placement every live slot has its entity id
        # in bucket_ids, so this is a no-op there; with a filter mask it
        # is what keeps masked entities from ranking in the rerank.
        flat_bids = bids.reshape(-1)
        cand = jnp.where(
            (cand >= 0) & (flat_bids[jnp.maximum(cand, 0)] >= 0), cand, -1)
        vecs = vecs_flat[jnp.maximum(cand, 0)]
        if fused:
            # rerank distance + top-k in one op (internal clamp/pad to k);
            # slot ids map back to global entity ids afterwards
            ld, slot = kernel_ops.candidate_topk_op(q, vecs, cand, k)
            gids = bids.reshape(-1)[jnp.maximum(slot, 0)]
            li = jnp.where((slot >= 0) & ~jnp.isinf(ld), gids,
                           -1).astype(jnp.int32)
        else:
            d2 = batched_l2sq(vecs, q)
            d2 = jnp.where(cand >= 0, d2, jnp.inf)
            k_eff = min(k, cand.shape[1])
            neg, sel = jax.lax.top_k(-d2, k_eff)
            slot = jnp.take_along_axis(cand, sel, axis=1)
            gids = bids.reshape(-1)[jnp.maximum(slot, 0)]
            gids = jnp.where((slot >= 0) & ~jnp.isinf(-neg), gids, -1)
            ld, li = -neg, gids.astype(jnp.int32)
            if k_eff < k:
                ld = jnp.pad(ld, ((0, 0), (0, k - k_eff)),
                             constant_values=jnp.inf)
                li = jnp.pad(li, ((0, 0), (0, k - k_eff)),
                             constant_values=-1)
        gd = jax.lax.all_gather(ld, axes, tiled=False)
        gi = jax.lax.all_gather(li, axes, tiled=False)
        return _merge_gathered(gd, gi, k)

    qs = _q_spec(query_axes)
    corpus = lambda ndim: P(tuple(axes), *([None] * (ndim - 1)))
    return shard_map(
        local, mesh=mesh,
        in_specs=(corpus(3), corpus(2), corpus(2), corpus(3), corpus(4),
                  corpus(3), corpus(2), corpus(2), corpus(3), corpus(2),
                  corpus(3), qs),
        out_specs=(qs, qs),
        check_vma=False,   # merge all-gathers over the corpus axes only
    )


def _forest_device_arrays(mesh, index, axes, n_dev, shapes=None):
    sh = shard_forest(index, n_dev, shapes=shapes)
    max_depth = sh.pop("max_depth")
    put = lambda x: jax.device_put(
        jnp.asarray(x),
        NamedSharding(mesh, P(tuple(axes), *([None] * (np.ndim(x) - 1)))),
    )
    return {name: put(v) for name, v in sh.items()}, max_depth


def sharded_forest_search(mesh, index, queries, k=10, nprobe_local=2,
                          beam_width=8, axes=("data", "model"),
                          query_axes=(), fused=True):
    """Host entry: shards a built TwoLevelIndex with a tree/QLBT forest
    bottom level over the mesh and runs the distributed descent."""
    n_dev = _axes_size(mesh, axes)
    dev, max_depth = _forest_device_arrays(mesh, index, axes, n_dev)
    fn = make_sharded_forest_fn(
        mesh, tuple(axes), k, nprobe_local, beam_width,
        index.config.tree_leaf, max_depth, tuple(query_axes), fused=fused,
    )
    q, B = _pad_queries(mesh, queries, query_axes)
    with mesh:
        qs = jax.device_put(q, NamedSharding(mesh, _q_spec(query_axes)))
        d, i = fn(dev["cents"], dev["valid"], dev["roots"],
                  dev["bucket_ids"], dev["bvecs"],
                  dev["proj"], dev["dims"], dev["tau"], dev["children"],
                  dev["leaf_row"], dev["leaf_entities"], qs)
    d, i = jax.device_get((d, i))
    return np.asarray(d)[:B], np.asarray(i)[:B]
