"""ShardPlan: symbolic sharding axes resolved per mesh.

Models annotate params/activations with *roles* — "dp" (batch), "fsdp"
(param gather), "tp" (tensor), "ep" (expert) — and the launcher binds roles
to concrete mesh axes:

  single-pod (16,16) ("data","model"): dp=(data,) fsdp=(data,) tp=(model,)
                                       ep=(data,model)
  multi-pod (2,16,16) (+pod):          dp=(pod,data) fsdp=(pod,data) ...

so the same model code lowers on any mesh.  With no mesh bound, ``p()``
returns fully-replicated specs and ``constrain`` is a no-op — the path unit
tests take.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["ShardPlan", "SINGLE_POD_PLAN", "MULTI_POD_PLAN", "LOCAL_PLAN"]


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    dp: tuple = ()
    fsdp: tuple = ()
    tp: tuple = ()
    ep: tuple = ()
    pp: tuple = ()      # pod-parallel remainder of dp once ep covers a pod
    mesh: Any = None

    def resolve(self, sym) -> Optional[tuple]:
        """role symbol | tuple of roles | None -> mesh-axis tuple | None."""
        if sym is None:
            return None
        if isinstance(sym, tuple):
            axes: list = []
            for s in sym:
                r = self.resolve(s)
                if r:
                    axes.extend(r)
            return tuple(dict.fromkeys(axes)) or None
        axes = getattr(self, sym)
        return tuple(axes) or None

    def p(self, *dims) -> P:
        return P(*[self.resolve(d) for d in dims])

    def constrain(self, x, *dims):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.p(*dims))
        )

    def axis_size(self, role: str) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in getattr(self, role):
            n *= self.mesh.shape[a]
        return n

    def size_of(self, sym) -> int:
        """Device count along a role symbol or tuple of roles."""
        if sym is None:
            return 1
        if isinstance(sym, tuple):
            n = 1
            for s in sym:
                n *= self.size_of(s)
            return n
        return self.axis_size(sym)

    def div_p(self, shape, *dims) -> P:
        """Like ``p`` but drops any role whose device count does not divide
        the corresponding dim (small/odd recsys layers stay replicated)."""
        parts = []
        for size, d in zip(shape, dims):
            parts.append(d if d and size % max(self.size_of(d), 1) == 0
                         else None)
        return self.p(*parts)

    def with_mesh(self, mesh) -> "ShardPlan":
        return dataclasses.replace(self, mesh=mesh)


LOCAL_PLAN = ShardPlan()

SINGLE_POD_PLAN = ShardPlan(
    dp=("data",), fsdp=("data",), tp=("model",), ep=("data", "model")
)

# ep stays within a pod (("data","model") = 256-way): experts are replicated
# across pods so the MoE all-to-all never crosses the slow inter-pod links;
# pods combine through the data-parallel gradient reduction only.  The
# dispatch-group dim stays sharded over "pod" (pp) during expert compute —
# without it, a P(None, ep, ...) constraint replicates every pod's tokens
# into both pods (observed 17 TB of cross-pod all-gather).
MULTI_POD_PLAN = ShardPlan(
    dp=("pod", "data"), fsdp=("pod", "data"), tp=("model",),
    ep=("data", "model"), pp=("pod",),
)
