"""Sharded search as a ``ServingEngine`` backend.

The host entries in :mod:`repro.distributed.sharding` re-place the corpus
on every call — fine for tests, wrong for serving.  The backend does the
expensive work once at construction (pad, shard, ``device_put``, build and
``jit`` the shard_map callable) and leaves only query placement + the
collective on the per-batch hot path, so the engine's micro-batches hit a
handful of cached jit shapes.

    eng = ServingEngine.sharded(mesh, index, k=10)        # convenience
    eng = ServingEngine(ShardedSearchBackend(mesh, db))   # explicit
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    _axes_size,
    _brute_device_arrays,
    _forest_device_arrays,
    _ivf_device_arrays,
    _pad_queries,
    _q_spec,
    make_sharded_brute_fn,
    make_sharded_forest_fn,
    make_sharded_ivf_fn,
)

__all__ = ["ShardedSearchBackend"]


class ShardedSearchBackend:
    """Callable ``queries (B, d) -> (dists (B, k), ids (B, k))``.

    ``target`` is either a raw ``(N, d)`` corpus (exact sharded scan) or a
    built ``TwoLevelIndex`` (IVF for a brute bottom, forest descent for a
    tree/qlbt bottom).  ``kind="auto"`` picks accordingly.
    """

    def __init__(self, mesh, target, *, kind: str = "auto", k: int = 10,
                 axes=("data", "model"), query_axes=(),
                 nprobe_local: int = 2, beam_width: int = 8):
        self.mesh = mesh
        self.k = k
        self.axes = tuple(axes)
        self.query_axes = tuple(query_axes)
        n_dev = _axes_size(mesh, self.axes)

        if kind == "auto":
            if isinstance(target, np.ndarray) or hasattr(target, "shape"):
                kind = "brute"
            elif getattr(target, "forest", None) is not None:
                kind = "forest"
            else:
                kind = "ivf"
        self.kind = kind

        put = lambda x, spec: jax.device_put(x, NamedSharding(mesh, spec))
        if kind == "brute":
            dbp, rows, n = _brute_device_arrays(target, n_dev)
            self._args = (put(dbp, P(self.axes, None)),)
            self._fn = jax.jit(make_sharded_brute_fn(
                mesh, self.axes, k, rows, n, self.query_axes))
        elif kind == "ivf":
            cents, bids, bvecs, Kp = _ivf_device_arrays(target, n_dev)
            self._args = (
                put(cents, P(self.axes, None)),
                put(bids, P(self.axes, None)),
                put(bvecs, P(self.axes, None, None)),
            )
            self._fn = jax.jit(make_sharded_ivf_fn(
                mesh, self.axes, k, nprobe_local, Kp // n_dev,
                target.bucket_ids.shape[0], self.query_axes))
        elif kind == "forest":
            dev, max_depth = _forest_device_arrays(
                mesh, target, self.axes, n_dev)
            self._args = (dev["cents"], dev["valid"], dev["roots"],
                          dev["bucket_ids"], dev["bvecs"],
                          dev["proj"], dev["dims"], dev["tau"],
                          dev["children"], dev["leaf_row"],
                          dev["leaf_entities"])
            self._fn = jax.jit(make_sharded_forest_fn(
                mesh, self.axes, k, nprobe_local, beam_width,
                target.config.tree_leaf, max_depth, self.query_axes))
        else:
            raise ValueError(f"unknown backend kind {kind!r}")

    def __call__(self, queries):
        q, B = _pad_queries(self.mesh, queries, self.query_axes)
        with self.mesh:
            qs = jax.device_put(
                q, NamedSharding(self.mesh, _q_spec(self.query_axes)))
            d, i = self._fn(*self._args, qs)
        d, i = jax.device_get((d, i))
        return np.asarray(d)[:B], np.asarray(i)[:B]
