"""Sharded search as a ``ServingEngine`` backend.

The host entries in :mod:`repro.distributed.sharding` re-place the corpus
on every call — fine for tests, wrong for serving.  The backend does the
expensive work once at construction (pad, shard, ``device_put``, build and
``jit`` the shard_map callable) and leaves only query placement + the
collective on the per-batch hot path, so the engine's micro-batches hit a
handful of cached jit shapes.

    eng = ServingEngine.sharded(mesh, index, k=10)        # convenience
    eng = ServingEngine(ShardedSearchBackend(mesh, db))   # explicit

Online updates: :meth:`ShardedSearchBackend.apply_updates` re-places a
*mutated* corpus/index (``add_entities`` / ``delete_entities`` /
``rebalance``) into the device-array shapes recorded at construction, so
the jitted search function — and its compile cache — survives the whole
index lifecycle.  ``headroom`` > 1 reserves growth room (more corpus
rows, wider buckets, bigger rebuilt trees); if a mutation outgrows the
reservation, ``apply_updates`` raises and the caller rebuilds the
backend (a cold, re-jitting path — the thing this class exists to avoid
on the common path).  Placement is serialized against in-flight searches
with a lock, so the engine worker thread never sees a half-swapped
argument tuple.
"""
from __future__ import annotations

import threading

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    _axes_size,
    _brute_device_arrays,
    _forest_device_arrays,
    _ivf_device_arrays,
    _pad_queries,
    _q_spec,
    forest_shard_shapes,
    make_sharded_brute_fn,
    make_sharded_forest_fn,
    make_sharded_ivf_fn,
)

__all__ = ["ShardedSearchBackend"]


class ShardedSearchBackend:
    """Callable ``queries (B, d) -> (dists (B, k), ids (B, k))``.

    ``target`` is either a raw ``(N, d)`` corpus (exact sharded scan) or a
    built ``TwoLevelIndex`` (IVF for a brute bottom, forest descent for a
    tree/qlbt bottom).  ``kind="auto"`` picks accordingly.
    """

    def __init__(self, mesh, target, *, kind: str = "auto", k: int = 10,
                 axes=("data", "model"), query_axes=(),
                 nprobe_local: int = 2, beam_width: int = 8,
                 headroom: float = 1.0, alive=None):
        self.mesh = mesh
        self.k = k
        self.axes = tuple(axes)
        self.query_axes = tuple(query_axes)
        self.headroom = headroom
        self.n_dev = _axes_size(mesh, self.axes)
        self._lock = threading.Lock()

        if kind == "auto":
            if isinstance(target, np.ndarray) or not hasattr(
                    target, "bucket_ids"):
                kind = "brute"
            elif getattr(target, "forest", None) is not None:
                kind = "forest"
            else:
                kind = "ivf"
        self.kind = kind

        if kind == "brute":
            n = int(np.shape(target)[0])
            self._rows = -(-int(np.ceil(n * headroom)) // self.n_dev)
            self._fn = jax.jit(make_sharded_brute_fn(
                mesh, self.axes, k, self._rows, self.query_axes))
        elif kind == "ivf":
            self._K = int(target.bucket_ids.shape[0])
            self._cap = int(np.ceil(target.bucket_ids.shape[1] * headroom))
            Kp = -(-self._K // self.n_dev) * self.n_dev
            self._fn = jax.jit(make_sharded_ivf_fn(
                mesh, self.axes, k, nprobe_local, Kp // self.n_dev,
                self._K, self.query_axes))
        elif kind == "forest":
            self._shapes = forest_shard_shapes(target, self.n_dev, headroom)
            self._fn = jax.jit(make_sharded_forest_fn(
                mesh, self.axes, k, nprobe_local, beam_width,
                self._shapes.leaf_sz, self._shapes.max_depth,
                self.query_axes))
        else:
            raise ValueError(f"unknown backend kind {kind!r}")
        self._place(target, alive=alive)

    # ------------------------------------------------------------------
    def _place(self, target, alive=None) -> None:
        """Pad/shard/device_put ``target`` into the recorded shapes."""
        put = lambda x, spec: jax.device_put(
            x, NamedSharding(self.mesh, spec))
        if self.kind == "brute":
            dbp, valid, _, _ = _brute_device_arrays(
                np.asarray(target, np.float32), self.n_dev,
                rows=self._rows, alive=alive)
            self._args = (put(dbp, P(self.axes, None)),
                          put(valid, P(self.axes)))
        elif self.kind == "ivf":
            if int(target.bucket_ids.shape[0]) != self._K:
                raise ValueError(
                    f"cluster count changed ({target.bucket_ids.shape[0]} "
                    f"!= {self._K}); rebuild the backend")
            cents, bids, bvecs, _ = _ivf_device_arrays(
                target, self.n_dev, cap=self._cap)
            self._args = (
                put(cents, P(self.axes, None)),
                put(bids, P(self.axes, None)),
                put(bvecs, P(self.axes, None, None)),
            )
        else:  # forest
            dev, _ = _forest_device_arrays(
                self.mesh, target, self.axes, self.n_dev,
                shapes=self._shapes)
            self._args = (dev["cents"], dev["valid"], dev["roots"],
                          dev["bucket_ids"], dev["bvecs"],
                          dev["proj"], dev["dims"], dev["tau"],
                          dev["children"], dev["leaf_row"],
                          dev["leaf_entities"])

    def apply_updates(self, target, alive=None) -> None:
        """Serve a mutated corpus/index through the already-jitted search.

        Re-pads and re-places the device arrays into the shapes recorded
        at construction; raises ``ValueError`` when the mutation outgrew
        the reservation (rebuild the backend with more ``headroom``).
        The jitted callable is untouched, so queries issued after this
        call hit the existing compile cache — no re-jit, no cold batch.
        ``alive`` (brute kind only) marks tombstoned corpus rows.
        """
        with self._lock:
            self._place(target, alive=alive)

    def jit_cache_size(self) -> int:
        """Compiled-variant count of the underlying search (test hook)."""
        try:
            return int(self._fn._cache_size())
        except AttributeError:          # older jax: no introspection
            return -1

    def __call__(self, queries):
        q, B = _pad_queries(self.mesh, queries, self.query_axes)
        with self._lock, self.mesh:
            qs = jax.device_put(
                q, NamedSharding(self.mesh, _q_spec(self.query_axes)))
            d, i = self._fn(*self._args, qs)
        d, i = jax.device_get((d, i))
        return np.asarray(d)[:B], np.asarray(i)[:B]
