"""Sharded search as a ``ServingEngine`` backend.

The host entries in :mod:`repro.distributed.sharding` re-place the corpus
on every call — fine for tests, wrong for serving.  The backend does the
expensive work once at construction (pad, shard, ``device_put``, build and
``jit`` the shard_map callable) and leaves only query placement + the
collective on the per-batch hot path, so the engine's micro-batches hit a
handful of cached jit shapes.

    eng = ServingEngine.sharded(mesh, index, k=10)        # convenience
    eng = ServingEngine(ShardedSearchBackend(mesh, db))   # explicit

Online updates: :meth:`ShardedSearchBackend.apply_updates` re-places a
*mutated* corpus/index (``add_entities`` / ``delete_entities`` /
``rebalance``) into the device-array shapes recorded at construction, so
the jitted search function — and its compile cache — survives the whole
index lifecycle.  ``headroom`` > 1 reserves growth room (more corpus
rows, wider buckets, bigger rebuilt trees); if a mutation outgrows the
reservation, ``apply_updates`` raises and the caller rebuilds the
backend (a cold, re-jitting path — the thing this class exists to avoid
on the common path).  Placement is serialized against in-flight searches
with a lock, so the engine worker thread never sees a half-swapped
argument tuple.

Delta shipping: ``apply_updates(target, delta=manifest)`` (manifest from
``target.pop_delta()``, see :mod:`repro.core.delta`) re-places only what
the manifest names — appended corpus rows for the brute kind, dirty
bucket rows for IVF, dirty bucket *slabs* for the forest kind (whose
device layout reserves a fixed node/leaf slab per bucket when
``delta_updates=True``).  The update is applied **in place on device** by
a jitted fixed-shape scatter (`.at[rows].set(..., mode="drop")`, i.e.
``dynamic_update_slice`` under the hood; buffers are donated off-CPU), so
a maintenance pass that touched a handful of buckets ships a handful of
slabs instead of the corpus.  The backend falls back to a full re-place
— never an error — when the manifest can't prove coverage
(``base_version`` ahead of the backend's placed version), marks itself
``full``, or when the payload exceeds ``delta_max_fraction`` of the full
re-place bytes (past that point one bulk transfer beats many scatters).
Every apply returns a stats dict (``mode``/``bytes``/``full_bytes``/
``reason``) and feeds the cumulative ``republished_bytes`` counters that
``ServingEngine.stats()`` surfaces.
"""
from __future__ import annotations

import threading
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.analysis.annotations import guarded_by
from repro.kernels.ops import quantize_rows_int8
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import get_tracer
from repro.distributed.sharding import (
    _axes_size,
    _brute_device_arrays,
    _brute_int8_device_arrays,
    _forest_device_arrays,
    _ivf_device_arrays,
    _lexical_device_arrays,
    _pad_queries,
    _pad_term_queries,
    _q_spec,
    forest_shard_shapes,
    make_sharded_brute_fn,
    make_sharded_forest_fn,
    make_sharded_hybrid_fn,
    make_sharded_ivf_fn,
    make_sharded_lexical_fn,
    slice_forest_delta,
    slice_ivf_delta,
)

__all__ = ["ShardedSearchBackend"]

# device-array order of the forest argument tuple (matches the jitted
# search signature minus the trailing queries)
_FOREST_ARGS = ("cents", "valid", "roots", "bucket_ids", "bvecs",
                "proj", "dims", "tau", "children", "leaf_row",
                "leaf_entities")


def _pow2(n: int) -> int:
    return max(1, 1 << (max(n, 1) - 1).bit_length())


def _pad_rows(a: np.ndarray, u: int, fill=0) -> np.ndarray:
    """Pad the leading dim of ``a`` to ``u`` with ``fill``."""
    if a.shape[0] == u:
        return a
    pad = np.full((u - a.shape[0],) + a.shape[1:], fill, a.dtype)
    return np.concatenate([a, pad], axis=0)


class ShardedSearchBackend:
    """Callable ``queries (B, d) -> (dists (B, k), ids (B, k))``.

    ``target`` is either a raw ``(N, d)`` corpus (exact sharded scan) or a
    built ``TwoLevelIndex`` (IVF for a brute bottom, forest descent for a
    tree/qlbt bottom).  ``kind="auto"`` picks accordingly.

    ``delta_updates`` (forest kind) lays the forest out in per-bucket
    slabs so dirty buckets can be delta-shipped; it pads every bucket to
    the largest per-bucket tree, trading device memory for republish
    bandwidth.  ``delta_max_fraction`` is the payload-size cutoff past
    which a delta falls back to one bulk re-place.
    """

    def __init__(self, mesh, target, *, kind: str = "auto", k: int = 10,
                 axes=("data", "model"), query_axes=(),
                 nprobe_local: int = 2, beam_width: int = 8,
                 headroom: float = 1.0, alive=None,
                 delta_updates: bool = True,
                 delta_max_fraction: float = 0.5,
                 fused: bool = True, precision: str = "f32",
                 metadata=None, lexical=None):
        self.mesh = mesh
        self.k = k
        self.axes = tuple(axes)
        self.query_axes = tuple(query_axes)
        self.headroom = headroom
        self.n_dev = _axes_size(mesh, self.axes)
        self.delta_updates = delta_updates
        self.delta_max_fraction = delta_max_fraction
        self.fused = fused
        self.precision = precision
        self.nprobe_local = nprobe_local
        self.beam_width = beam_width
        self._lock = threading.Lock()
        self._delta_fn = None
        self._delta_fn_masked = None     # brute explicit-alive path
        self._lex_delta_fn = None        # postings-slab append scatter
        # filter surface: metadata snapshot (pinned at placement) + the
        # per-FilterSpec compiled mask operands, both lock-guarded; the
        # cache is cleared on every apply so filters observe metadata
        # with the same staleness as the vectors (docs/filtering.md)
        self.metadata_src = metadata
        self.lexical_src = lexical
        self._meta = None
        self._fmask_cache: dict = {}
        self._host_valid: Optional[np.ndarray] = None
        self._host_bids: Optional[np.ndarray] = None
        self._lex_args = None
        self._fn_lex = None
        self._fn_hyb = None
        self._version: Optional[int] = None
        self._n = 0                      # real corpus rows last placed
        self._full_bytes = 0             # host bytes of a full re-place
        self.last_republish: Optional[dict] = None
        # fixed-footprint telemetry: dispatch/kernel/rerank timings plus
        # republish + compile-signature counters (see docs/observability.md)
        self.metrics = MetricsRegistry()
        self._h_kernel = self.metrics.histogram("kernel_ms")
        self._h_rerank = self.metrics.histogram("rerank_ms")
        self._h_first = self.metrics.histogram("first_call_ms",
                                               lo=1e-2, hi=1e7)
        self._c_dispatches = self.metrics.counter("dispatches")
        self._c_sigs = self.metrics.counter("compile_signatures")
        self._c_repub = self.metrics.counter("republished_bytes")
        self._c_repub_full = self.metrics.counter("republish_full_bytes")
        self._c_delta = self.metrics.counter("delta_applies")
        self._c_full = self.metrics.counter("full_applies")
        # abstract query signatures (shape, dtype) already dispatched —
        # the first call per signature is the one that paid trace+compile
        self._seen_sigs: set = set()

        if kind == "auto":
            if isinstance(target, np.ndarray) or not hasattr(
                    target, "bucket_ids"):
                kind = "brute"
            elif getattr(target, "forest", None) is not None:
                kind = "forest"
            else:
                kind = "ivf"
        self.kind = kind

        if precision not in ("f32", "int8"):
            raise ValueError(
                f"precision must be 'f32' or 'int8', got {precision!r}")
        if precision == "int8" and kind != "brute":
            raise ValueError(
                "precision='int8' is only supported for the brute kind")
        if kind == "brute":
            n = int(np.shape(target)[0])
            self._rows = -(-int(np.ceil(n * headroom)) // self.n_dev)
            self._fn = jax.jit(make_sharded_brute_fn(
                mesh, self.axes, k, self._rows, self.query_axes,
                fused=fused, precision=precision))
        elif kind == "ivf":
            self._K = int(target.bucket_ids.shape[0])
            self._cap = int(np.ceil(target.bucket_ids.shape[1] * headroom))
            Kp = -(-self._K // self.n_dev) * self.n_dev
            self._Kp = Kp
            self._fn = jax.jit(make_sharded_ivf_fn(
                mesh, self.axes, k, nprobe_local, Kp // self.n_dev,
                self._K, self.query_axes, fused=fused))
        elif kind == "forest":
            self._shapes = forest_shard_shapes(
                target, self.n_dev, headroom,
                layout="slab" if delta_updates else "packed")
            self._fn = jax.jit(make_sharded_forest_fn(
                mesh, self.axes, k, nprobe_local, beam_width,
                self._shapes.leaf_sz, self._shapes.max_depth,
                self.query_axes, fused=fused))
        else:
            raise ValueError(f"unknown backend kind {kind!r}")
        if self.lexical_src is None:
            self.lexical_src = getattr(target, "lexical", None)
        if self.lexical_src is not None:
            if kind != "brute" or precision != "f32":
                raise ValueError(
                    "lexical slabs (lexical / hybrid modes) require "
                    "kind='brute', precision='f32'")
            self._fn_lex = jax.jit(make_sharded_lexical_fn(
                mesh, self.axes, k, self._rows, self.query_axes,
                fused=fused))
            self._fn_hyb = jax.jit(make_sharded_hybrid_fn(
                mesh, self.axes, k, self._rows, self.query_axes,
                fused=fused))
        self._place(target, alive=alive)

    # -- registry-backed compatibility counters ------------------------
    @property
    def republished_bytes(self) -> int:
        """Cumulative bytes shipped by applies."""
        return self._c_repub.value

    @property
    def republish_full_bytes(self) -> int:
        """What full re-places would have cost."""
        return self._c_repub_full.value

    @property
    def n_delta_applies(self) -> int:
        return self._c_delta.value

    @property
    def n_full_applies(self) -> int:
        return self._c_full.value

    # ------------------------------------------------------------------
    def _corpus_spec(self, ndim: int) -> NamedSharding:
        return NamedSharding(
            self.mesh, P(self.axes, *([None] * (ndim - 1))))

    @guarded_by("_lock")
    def _place(self, target, alive=None) -> None:
        """Pad/shard/device_put ``target`` into the recorded shapes."""
        put = lambda x, spec: jax.device_put(
            x, NamedSharding(self.mesh, spec))
        if self.kind == "brute" and self.precision == "int8":
            codes, scales, valid, _, n = _brute_int8_device_arrays(
                np.asarray(target, np.float32), self.n_dev,
                rows=self._rows, alive=alive)
            self._full_bytes = sum(int(np.asarray(a).nbytes)
                                   for a in (codes, scales, valid))
            self._n = n
            self._host_valid = np.asarray(valid, bool).copy()
            self._args = (put(codes, P(self.axes, None)),
                          put(scales, P(self.axes)),
                          put(valid, P(self.axes)))
        elif self.kind == "brute":
            db_host = np.asarray(
                getattr(target, "db", target), np.float32)
            dbp, valid, _, n = _brute_device_arrays(
                db_host, self.n_dev, rows=self._rows, alive=alive)
            self._full_bytes = int(np.asarray(dbp).nbytes
                                   + np.asarray(valid).nbytes)
            self._n = n
            self._host_valid = np.asarray(valid, bool).copy()
            self._args = (put(dbp, P(self.axes, None)),
                          put(valid, P(self.axes)))
            if self.lexical_src is not None:
                slabs = self.lexical_src
                if slabs.n_docs != n:
                    raise ValueError(
                        f"lexical slabs hold {slabs.n_docs} rows for a "
                        f"{n}-row corpus; append_docs must track "
                        "add_entities")
                tp, fp, _, _, _ = _lexical_device_arrays(
                    slabs.terms, slabs.tf_sat, self.n_dev,
                    rows=self._rows, alive=alive)
                self._full_bytes += int(np.asarray(tp).nbytes
                                        + np.asarray(fp).nbytes)
                self._lex_args = (put(tp, P(self.axes, None)),
                                  put(fp, P(self.axes, None)))
        elif self.kind == "ivf":
            if int(target.bucket_ids.shape[0]) != self._K:
                raise ValueError(
                    f"cluster count changed ({target.bucket_ids.shape[0]} "
                    f"!= {self._K}); rebuild the backend")
            cents, bids, bvecs, _ = _ivf_device_arrays(
                target, self.n_dev, cap=self._cap)
            self._full_bytes = sum(int(np.asarray(a).nbytes)
                                   for a in (cents, bids, bvecs))
            self._n = int(target.db.shape[0])
            self._host_bids = np.asarray(bids, np.int32).copy()
            self._args = (
                put(cents, P(self.axes, None)),
                put(bids, P(self.axes, None)),
                put(bvecs, P(self.axes, None, None)),
            )
        else:  # forest
            dev, _ = _forest_device_arrays(
                self.mesh, target, self.axes, self.n_dev,
                shapes=self._shapes)
            self._full_bytes = sum(int(dev[n].nbytes) for n in _FOREST_ARGS)
            self._n = int(target.db.shape[0])
            self._host_bids = np.asarray(dev["bucket_ids"], np.int32).copy()
            self._args = tuple(dev[name] for name in _FOREST_ARGS)
        self._version = getattr(target, "mutation_version", None)
        self._refresh_meta(target)

    @guarded_by("_lock")
    def _refresh_meta(self, target) -> None:
        """Pin the metadata the *next* filtered queries will see and drop
        every compiled mask — applies move the staleness window for
        filters and vectors together (docs/filtering.md)."""
        meta = (self.metadata_src if self.metadata_src is not None
                else getattr(target, "metadata", None))
        self._meta = meta.snapshot() if meta is not None else None
        self._fmask_cache.clear()

    @guarded_by("_lock")
    def _filter_operand(self, filter_spec):
        """Compile a ``FilterSpec`` to this kind's mask operand (cached
        per spec digest until the next apply).

        brute/lexical/hybrid: the entity mask ANDed into the placed
        ``valid`` row operand.  ivf/forest: filtered entities' slots in
        ``bucket_ids`` masked to -1 — the scan's existing ``id >= 0``
        discipline then keeps them from ranking.  Same shapes and dtypes
        as the unfiltered operands, so the jitted search signature (and
        its compile cache) is untouched — the recompile gate's
        ``filtered-sharded-search`` entry holds this.
        """
        key = filter_spec.key()
        hit = self._fmask_cache.get(key)
        if hit is not None:
            return hit
        put = lambda x, spec: jax.device_put(
            x, NamedSharding(self.mesh, spec))
        if self.kind == "brute":
            emask = filter_spec.mask(self._meta, self._host_valid.shape[0])
            dev = put(jnp.asarray(self._host_valid & emask), P(self.axes))
        elif self.kind == "ivf":
            emask = filter_spec.mask(self._meta, max(self._n, 1))
            b = self._host_bids
            live = (b >= 0) & emask[np.minimum(np.maximum(b, 0),
                                               emask.shape[0] - 1)]
            dev = put(jnp.asarray(np.where(live, b, -1).astype(np.int32)),
                      P(self.axes, None))
        else:  # forest
            emask = filter_spec.mask(self._meta, max(self._n, 1))
            b = self._host_bids
            live = (b >= 0) & emask[np.minimum(np.maximum(b, 0),
                                               emask.shape[0] - 1)]
            dev = put(jnp.asarray(np.where(live, b, -1).astype(np.int32)),
                      P(self.axes, None, None))
        if len(self._fmask_cache) >= 64:
            self._fmask_cache.clear()
        self._fmask_cache[key] = dev
        return dev

    # ------------------------------------------------------------------
    # delta apply: jitted fixed-shape in-place scatters
    # ------------------------------------------------------------------
    def _make_delta_fn(self):
        """Build the jitted in-place scatter for this backend's kind.

        Fixed shapes: the payload's leading (update-count) dim is padded
        to a power of two with out-of-bounds indices, which
        ``mode="drop"`` discards — so the kernel compiles once per pow2
        bucket, never per mutation.  Buffers are donated off-CPU so the
        update really is in place; the CPU backend doesn't support
        donation, so there we let XLA copy.
        """
        donate_ok = jax.default_backend() != "cpu"
        if self.kind == "brute" and self.precision == "int8":
            specs = (self._corpus_spec(2), self._corpus_spec(1),
                     self._corpus_spec(1))

            @partial(jax.jit,
                     donate_argnums=(0, 1, 2) if donate_ok else (),
                     out_shardings=specs)
            def fn(codes, scales, valid, rows, vals8, vscales, tomb):
                # same cumulative-liveness contract as the f32 scatter,
                # over the quantized (codes, scales) pair
                codes = codes.at[rows].set(vals8, mode="drop")
                scales = scales.at[rows].set(vscales, mode="drop")
                valid = valid.at[rows].set(True, mode="drop")
                valid = valid.at[tomb].set(False, mode="drop")
                return codes, scales, valid

            return fn
        if self.kind == "brute":
            specs = (self._corpus_spec(2), self._corpus_spec(1))

            @partial(jax.jit, donate_argnums=(0, 1) if donate_ok else (),
                     out_shardings=specs)
            def fn(db, valid, rows, vals, tomb):
                # liveness is cumulative ON DEVICE: appended rows flip
                # alive, tombstones flip dead, everything else keeps the
                # bits earlier windows left — a tombstone-only manifest
                # ships two index vectors, not the whole mask
                db = db.at[rows].set(vals, mode="drop")
                valid = valid.at[rows].set(True, mode="drop")
                valid = valid.at[tomb].set(False, mode="drop")
                return db, valid

            return fn
        if self.kind == "ivf":
            specs = tuple(self._corpus_spec(nd) for nd in (2, 2, 3))

            @partial(jax.jit,
                     donate_argnums=(0, 1, 2) if donate_ok else (),
                     out_shardings=specs)
            def fn(cents, bids, bvecs, rows, uc, ub, uv):
                cents = cents.at[rows].set(uc, mode="drop")
                bids = bids.at[rows].set(ub, mode="drop")
                bvecs = bvecs.at[rows].set(uv, mode="drop")
                return cents, bids, bvecs

            return fn
        # forest: scatter whole per-bucket slabs into the 11 tables
        ns, ls = self._shapes.node_slab, self._shapes.leaf_slab
        ndims = (3, 2, 2, 3, 4, 3, 2, 2, 3, 2, 3)   # _FOREST_ARGS dims
        specs = tuple(self._corpus_spec(nd) for nd in ndims)

        @partial(jax.jit,
                 donate_argnums=tuple(range(11)) if donate_ok else (),
                 out_shardings=specs)
        def fn(cents, valid, roots, bids, bvecs, proj, dims, tau,
               children, leaf_row, leaf_ents, shard, slot,
               u_cents, u_valid, u_roots, u_bids, u_bvecs, u_proj,
               u_dims, u_tau, u_children, u_leaf_row, u_leaf_ents):
            sh1 = shard[:, None]
            nrow = slot[:, None] * ns + jnp.arange(ns, dtype=jnp.int32)[None, :]
            lrow = slot[:, None] * ls + jnp.arange(ls, dtype=jnp.int32)[None, :]
            cents = cents.at[shard, slot].set(u_cents, mode="drop")
            valid = valid.at[shard, slot].set(u_valid, mode="drop")
            roots = roots.at[shard, slot].set(u_roots, mode="drop")
            bids = bids.at[shard, slot].set(u_bids, mode="drop")
            bvecs = bvecs.at[shard, slot].set(u_bvecs, mode="drop")
            proj = proj.at[sh1, nrow].set(u_proj, mode="drop")
            dims = dims.at[sh1, nrow].set(u_dims, mode="drop")
            tau = tau.at[sh1, nrow].set(u_tau, mode="drop")
            children = children.at[sh1, nrow].set(u_children, mode="drop")
            leaf_row = leaf_row.at[sh1, nrow].set(u_leaf_row, mode="drop")
            leaf_ents = leaf_ents.at[sh1, lrow].set(u_leaf_ents,
                                                    mode="drop")
            return (cents, valid, roots, bids, bvecs, proj, dims, tau,
                    children, leaf_row, leaf_ents)

        return fn

    def _make_masked_delta_fn(self):
        """Brute-kind scatter for the explicit-``alive`` path: the caller
        ships the complete liveness truth as a mask, so only the corpus
        rows are scattered and the mask is re-placed wholesale."""
        donate_ok = jax.default_backend() != "cpu"
        if self.kind == "brute" and self.precision == "int8":
            specs = (self._corpus_spec(2), self._corpus_spec(1))

            @partial(jax.jit, donate_argnums=(0, 1) if donate_ok else (),
                     out_shardings=specs)
            def fn8(codes, scales, rows, vals8, vscales):
                return (codes.at[rows].set(vals8, mode="drop"),
                        scales.at[rows].set(vscales, mode="drop"))

            return fn8

        @partial(jax.jit, donate_argnums=(0,) if donate_ok else (),
                 out_shardings=self._corpus_spec(2))
        def fn(db, rows, vals):
            return db.at[rows].set(vals, mode="drop")

        return fn

    def _make_lex_delta_fn(self):
        """Postings-slab counterpart of the brute row scatter: appended
        docs land their term/tf slab rows at the same row ids as their
        vectors (liveness rides the shared ``valid`` mask)."""
        donate_ok = jax.default_backend() != "cpu"
        specs = (self._corpus_spec(2), self._corpus_spec(2))

        @partial(jax.jit, donate_argnums=(0, 1) if donate_ok else (),
                 out_shardings=specs)
        def fn(terms, tf, rows, u_terms, u_tf):
            return (terms.at[rows].set(u_terms, mode="drop"),
                    tf.at[rows].set(u_tf, mode="drop"))

        return fn

    def _bucket_payload_bytes(self) -> int:
        """Exact per-dirty-bucket payload size — computable up front
        because every slab/row shape is fixed, so an over-threshold
        manifest is rejected *before* paying the host-side slicing."""
        if self.kind == "ivf":
            d = int(np.asarray(self._args[0]).shape[1])
            return 4 * (d + self._cap + self._cap * d + 1)
        sh = self._shapes
        d = int(np.asarray(self._args[0]).shape[2])
        ns, ls = sh.node_slab, sh.leaf_slab
        return (4 * (ns * d + ns + ns + ns * 2 + ns      # node tables
                     + ls * sh.leaf_sz                   # leaf slab
                     + 1 + d + sh.cap + sh.cap * d       # bucket row
                     + 2)                                # shard/slot
                + 1)                                     # valid flag

    def _delta_payload(self, target, alive, delta):
        """Host-side payload for the manifest, or (None, reason) when the
        delta path can't cover this update."""
        if self.kind == "brute":
            if delta.dirty_buckets.size:
                return None, "bucket-delta-on-flat-corpus"
            if delta.base_n > self._n:
                return None, "version"
            if (self._version is not None
                    and delta.base_version > self._version):
                # a raw-corpus backend has no index version at
                # construction, but once a manifest chain starts a gap
                # in it means missed tombstones — full re-place
                return None, "version"
            db = np.asarray(getattr(target, "db", target), np.float32)
            n = db.shape[0]
            if n > self._rows * self.n_dev:
                return None, "outgrew"        # full place raises loudly
            rows_tot = self._rows * self.n_dev
            new = np.arange(delta.base_n, n, dtype=np.int32)
            vals = db[delta.base_n:n]
            u = _pow2(new.size)
            pay = {"rows": _pad_rows(new, u, fill=rows_tot), "n": n}
            if self.precision == "int8":
                vals8, vscales = quantize_rows_int8(vals)
                pay["vals8"] = _pad_rows(vals8, u)
                pay["vscales"] = _pad_rows(vscales, u, fill=1.0)
                vals_bytes = int(vals8.nbytes + vscales.nbytes)
            else:
                pay["vals"] = _pad_rows(vals, u)
                vals_bytes = int(vals.nbytes)
            if self._lex_args is not None:
                slabs = self.lexical_src
                if slabs is None or slabs.n_docs != n:
                    return None, "lexical-misaligned"
                pay["lex_terms"] = _pad_rows(
                    np.asarray(slabs.terms[delta.base_n:n], np.int32),
                    u, fill=-1)
                pay["lex_tf"] = _pad_rows(
                    np.asarray(slabs.tf_sat[delta.base_n:n], np.float32), u)
                vals_bytes += int(pay["lex_terms"].nbytes
                                  + pay["lex_tf"].nbytes)
            if alive is not None:
                # caller supplied the complete liveness truth: ship the
                # whole mask (it IS the payload — nothing to delta)
                valid = np.arange(rows_tot) < n
                valid[:n] &= np.asarray(alive, bool)
                if delta.tombstones.size:
                    valid[delta.tombstones] = False
                pay["valid"] = valid
                pay["bytes"] = int(vals_bytes + new.nbytes + valid.nbytes)
            else:
                # tombstone-only (and append) windows ship two index
                # vectors; the device mask keeps the bits from earlier
                # windows, so liveness stays cumulative without ever
                # pulling the mask back to host
                tomb = np.asarray(delta.tombstones, np.int32)
                pay["tomb"] = _pad_rows(tomb, _pow2(tomb.size),
                                        fill=rows_tot)
                pay["bytes"] = int(vals_bytes + new.nbytes + tomb.nbytes)
            return pay, None
        if self._version is None or delta.base_version > self._version:
            return None, "version"
        if self.kind == "ivf":
            if int(target.bucket_ids.shape[0]) != self._K:
                return None, "outgrew"
            pay = slice_ivf_delta(target, self._cap, delta.dirty_buckets)
            pay["bytes"] = sum(int(v.nbytes) for v in pay.values())
            pay["n"] = int(target.db.shape[0])
            u = _pow2(pay["rows"].shape[0])
            pay["rows"] = _pad_rows(pay["rows"], u, fill=self._Kp)
            for name in ("cents", "bucket_ids", "bvecs"):
                pay[name] = _pad_rows(pay[name], u)
            return pay, None
        # forest
        if not self.delta_updates:
            return None, "packed-layout"
        pay = slice_forest_delta(target, self._shapes, delta.dirty_buckets)
        pay["bytes"] = sum(int(np.asarray(v).nbytes) for v in pay.values())
        pay["n"] = int(target.db.shape[0])
        u = _pow2(pay["shard"].shape[0])
        pay["shard"] = _pad_rows(pay["shard"], u, fill=self.n_dev)  # OOB
        pay["slot"] = _pad_rows(pay["slot"], u)
        for name in _FOREST_ARGS:
            pay[name] = _pad_rows(np.asarray(pay[name]), u)
        return pay, None

    @guarded_by("_lock")
    def _apply_lex_delta(self, pay) -> None:
        """Scatter appended postings-slab rows next to their vectors."""
        if self._lex_args is None or "lex_terms" not in pay:
            return
        if self._lex_delta_fn is None:
            self._lex_delta_fn = self._make_lex_delta_fn()
        self._lex_args = self._lex_delta_fn(
            self._lex_args[0], self._lex_args[1], pay["rows"],
            pay["lex_terms"], pay["lex_tf"])

    @guarded_by("_lock")
    def _apply_delta(self, pay) -> None:
        if self.kind == "brute" and "valid" in pay:
            if self._delta_fn_masked is None:
                self._delta_fn_masked = self._make_masked_delta_fn()
            valid = jax.device_put(
                pay["valid"], NamedSharding(self.mesh, P(self.axes)))
            if self.precision == "int8":
                codes, scales = self._delta_fn_masked(
                    self._args[0], self._args[1], pay["rows"],
                    pay["vals8"], pay["vscales"])
                self._args = (codes, scales, valid)
            else:
                db = self._delta_fn_masked(
                    self._args[0], pay["rows"], pay["vals"])
                self._args = (db, valid)
                self._apply_lex_delta(pay)
            self._host_valid = np.asarray(pay["valid"], bool).copy()
            self._n = pay["n"]
            return
        if self._delta_fn is None:
            self._delta_fn = self._make_delta_fn()
        if self.kind == "brute" and self.precision == "int8":
            self._args = self._delta_fn(
                self._args[0], self._args[1], self._args[2], pay["rows"],
                pay["vals8"], pay["vscales"], pay["tomb"])
            self._mirror_brute_liveness(pay)
        elif self.kind == "brute":
            self._args = self._delta_fn(
                self._args[0], self._args[1], pay["rows"], pay["vals"],
                pay["tomb"])
            self._apply_lex_delta(pay)
            self._mirror_brute_liveness(pay)
        elif self.kind == "ivf":
            self._args = self._delta_fn(
                *self._args, pay["rows"], pay["cents"],
                pay["bucket_ids"], pay["bvecs"])
            rows = np.asarray(pay["rows"])
            keep = rows < self._host_bids.shape[0]
            self._host_bids[rows[keep]] = np.asarray(
                pay["bucket_ids"])[keep]
        else:
            self._args = self._delta_fn(
                *self._args, pay["shard"], pay["slot"],
                *(pay[name] for name in _FOREST_ARGS))
            sh = np.asarray(pay["shard"])
            sl = np.asarray(pay["slot"])
            keep = sh < self._host_bids.shape[0]
            self._host_bids[sh[keep], sl[keep]] = np.asarray(
                pay["bucket_ids"])[keep]
        self._n = pay["n"]

    @guarded_by("_lock")
    def _mirror_brute_liveness(self, pay) -> None:
        """Replay the device liveness flips on the host mirror the filter
        compiler reads (appends flip alive, tombstones flip dead)."""
        rt = self._host_valid.shape[0]
        rows = np.asarray(pay["rows"])
        self._host_valid[rows[rows < rt]] = True
        tomb = np.asarray(pay["tomb"])
        self._host_valid[tomb[tomb < rt]] = False

    # ------------------------------------------------------------------
    def apply_updates(self, target, alive=None, delta=None) -> dict:
        """Serve a mutated corpus/index through the already-jitted search.

        With ``delta`` (a :class:`repro.core.delta.DeltaManifest`, e.g.
        from ``target.pop_delta()``): scatter only the manifest's dirty
        slices into the live device arrays — no full corpus transfer, no
        re-jit — falling back to a full re-place whenever the manifest
        cannot prove coverage or the payload is no cheaper than bulk.
        Without ``delta``: re-pad and re-place everything into the shapes
        recorded at construction.  Either way, raises ``ValueError`` when
        the mutation outgrew the reservation (rebuild the backend with
        more ``headroom``), the jitted search callable is untouched, and
        queries issued after this call hit the existing compile cache.
        ``alive`` (brute kind only) marks tombstoned corpus rows.

        Returns ``{"mode", "bytes", "full_bytes", "reason"}`` — ``mode``
        is ``"delta"``, ``"full"``, or ``"noop"``; ``bytes`` is what was
        actually shipped; ``full_bytes`` is what a full re-place ships.
        """
        with get_tracer().span("republish.place", kind=self.kind) as sp:
            with self._lock:
                stats = self._apply_locked(target, alive, delta)
                self.last_republish = stats
            # counters are internally locked — concurrent maintenance
            # passes can't lose increments even outside the backend lock
            self._c_repub.inc(stats["bytes"])
            self._c_repub_full.inc(stats["full_bytes"])
            if stats["mode"] == "delta":
                self._c_delta.inc()
            elif stats["mode"] == "full":
                self._c_full.inc()
            sp.set(mode=stats["mode"], bytes=stats["bytes"])
        return stats

    @guarded_by("_lock")
    def _apply_locked(self, target, alive, delta) -> dict:
        reason = None
        if delta is None:
            reason = "no-manifest"
        elif delta.full:
            reason = "manifest-full"
        else:
            covered = (self._version is not None
                       and delta.base_version <= self._version)
            if delta.empty and (covered or self.kind == "brute"):
                self._version = delta.version
                self._refresh_meta(target)
                return {"mode": "noop", "bytes": 0,
                        "full_bytes": self._full_bytes, "reason": None}
            if (self.kind in ("ivf", "forest") and self.delta_updates
                    and delta.dirty_buckets.size * self._bucket_payload_bytes()
                    > self.delta_max_fraction * self._full_bytes):
                # fixed shapes make the payload size exact up front —
                # don't pay the slicing for a delta that can't win
                reason = "threshold"
            else:
                pay, reason = self._delta_payload(target, alive, delta)
            if reason is None:
                if pay["bytes"] > self.delta_max_fraction * self._full_bytes:
                    reason = "threshold"
                else:
                    self._apply_delta(pay)
                    self._version = delta.version
                    self._refresh_meta(target)
                    return {"mode": "delta", "bytes": pay["bytes"],
                            "full_bytes": self._full_bytes, "reason": None}
        self._place(target, alive=alive)
        return {"mode": "full", "bytes": self._full_bytes,
                "full_bytes": self._full_bytes, "reason": reason}

    def jit_cache_size(self) -> int:
        """Compiled-variant count of the underlying search (test hook) —
        summed over the semantic/lexical/hybrid callables."""
        total = 0
        for fn in (self._fn, self._fn_lex, self._fn_hyb):
            if fn is None:
                continue
            try:
                total += int(fn._cache_size())
            except AttributeError:      # older jax: no introspection
                return -1
        return total

    def __call__(self, queries, *, filter_spec=None, mode: str = "semantic",
                 alpha: float = 0.5, q_terms=None, q_weights=None):
        """Search.  ``filter_spec`` (a :class:`repro.core.metadata.
        FilterSpec`) restricts results to matching entities; ``mode``
        selects ``"semantic"`` (dense scan), ``"lexical"`` (BM25 over the
        postings slabs), or ``"hybrid"`` (``alpha * l2sq - (1 - alpha) *
        bm25``).  Non-semantic modes need the backend built with lexical
        slabs and per-query ``q_terms``/``q_weights`` operands (see
        :func:`repro.core.lexical.query_operands`).  Filters and alpha are
        data, not shapes — no mode/filter combination mints a new jit
        signature beyond the three per-mode callables.
        """
        tracer = get_tracer()
        if filter_spec is not None and filter_spec.empty:
            filter_spec = None
        if mode not in ("semantic", "lexical", "hybrid"):
            raise ValueError(
                f"mode must be 'semantic', 'lexical', or 'hybrid', "
                f"got {mode!r}")
        if mode != "semantic":
            if self._fn_lex is None:
                raise ValueError(
                    f"mode={mode!r} requires a backend built with lexical "
                    "slabs (kind='brute', lexical=...)")
            if q_terms is None or q_weights is None:
                raise ValueError(
                    f"mode={mode!r} requires q_terms/q_weights (see "
                    "repro.core.lexical.query_operands)")
            qt, qw, B = _pad_term_queries(
                self.mesh, q_terms, q_weights, self.query_axes)
        if mode == "lexical":
            sig = (mode, tuple(qt.shape), str(qt.dtype))
            b_disp = int(qt.shape[0])
        else:
            q, B = _pad_queries(self.mesh, queries, self.query_axes)
            sig = (mode, tuple(q.shape), str(q.dtype))
            b_disp = int(q.shape[0])
        t0 = time.perf_counter()
        # kernel: queue + device execution of the jitted shard_map scan.
        # block_until_ready runs OUTSIDE the lock (same concurrency as
        # before, where device_get did the blocking) so the span measures
        # real device time, not async dispatch.
        with tracer.span("kernel", kind=self.kind, b=b_disp):
            with self._lock, self.mesh:
                first = sig not in self._seen_sigs
                if first:
                    self._seen_sigs.add(sig)
                qspec = NamedSharding(self.mesh, _q_spec(self.query_axes))
                args = self._args
                if filter_spec is not None:
                    fdev = self._filter_operand(filter_spec)
                    if self.kind == "brute":
                        if self.precision == "int8":
                            args = (args[0], args[1], fdev)
                        else:
                            args = (args[0], fdev)
                    elif self.kind == "ivf":
                        args = (args[0], fdev, args[2])
                    else:  # forest: bucket_ids is _FOREST_ARGS[3]
                        args = args[:3] + (fdev,) + args[4:]
                if mode == "semantic":
                    qs = jax.device_put(q, qspec)
                    d, i = self._fn(*args, qs)
                elif mode == "lexical":
                    # args[-1] is the (possibly filtered) valid operand
                    qts = jax.device_put(qt, qspec)
                    qws = jax.device_put(qw, qspec)
                    d, i = self._fn_lex(
                        self._lex_args[0], self._lex_args[1], args[1],
                        qts, qws)
                else:  # hybrid
                    qs = jax.device_put(q, qspec)
                    qts = jax.device_put(qt, qspec)
                    qws = jax.device_put(qw, qspec)
                    a_dev = jax.device_put(
                        jnp.full((1, 1), float(alpha), dtype=jnp.float32),
                        NamedSharding(self.mesh, P(None, None)))
                    d, i = self._fn_hyb(
                        args[0], self._lex_args[0], self._lex_args[1],
                        args[1], qs, qts, qws, a_dev)
            jax.block_until_ready((d, i))
        t1 = time.perf_counter()
        # rerank: pull the per-shard top-k merge result back to host and
        # trim query padding — the host half of candidate re-scoring
        with tracer.span("rerank", kind=self.kind):
            d, i = jax.device_get((d, i))
            out = np.asarray(d)[:B], np.asarray(i)[:B]
        t2 = time.perf_counter()
        self._c_dispatches.inc()
        self._h_kernel.observe((t1 - t0) * 1e3)
        self._h_rerank.observe((t2 - t1) * 1e3)
        if first:
            # first dispatch of this abstract signature paid the
            # trace+compile; record it with the signature that triggered it
            self._c_sigs.inc()
            self._h_first.observe((t1 - t0) * 1e3)
            tracer.instant("compile-signature", kind=self.kind,
                           shape=str(list(sig[0])), dtype=sig[1],
                           ms=round((t1 - t0) * 1e3, 3))
        return out

    def roofline_report(self, b: int = 1, *, peak_bw: float = 0.0) -> dict:
        """Analytic bytes/FLOPs for one dispatch next to the *measured*
        kernel time from live telemetry.

        ``analytic_frac`` is the useful-byte fraction of the cost model
        (what fraction of moved bytes are corpus bytes a perfect kernel
        must move); ``achieved_gbps`` divides the model's moved bytes by
        the median measured kernel time; with ``peak_bw`` (bytes/s, e.g.
        ``benchmarks.roofline.HBM_BW``) the measured useful-byte fraction
        ``measured_frac`` = useful bytes/s over peak is reported too.
        """
        from repro.obs.profile import backend_cost

        if self.kind == "brute":
            d = int(np.asarray(self._args[0]).shape[1])
            cost = backend_cost("brute", fused=self.fused,
                                precision=self.precision, n_rows=self._n,
                                d=d, b=b, k=self.k)
        elif self.kind == "ivf":
            d = int(np.asarray(self._args[0]).shape[1])
            cost = backend_cost(
                "ivf", fused=self.fused, precision=self.precision,
                n_rows=self._n, d=d, b=b, k=self.k,
                n_probe_rows=self.nprobe_local * self.n_dev * self._cap,
                n_centroids=self._Kp)
        else:
            d = int(np.asarray(self._args[0]).shape[2])
            nb = int(np.asarray(self._args[0]).shape[1])
            cost = backend_cost(
                "forest", fused=self.fused, precision=self.precision,
                n_rows=self._n, d=d, b=b, k=self.k,
                n_probe_rows=(self.nprobe_local * self.n_dev
                              * self._shapes.cap),
                n_centroids=self.n_dev * nb)
        med_ms = self._h_kernel.quantile(0.5) if self._h_kernel.count else 0.0
        cost["measured_kernel_ms_p50"] = med_ms
        if med_ms > 0:
            bps = cost["bytes_moved"] / (med_ms / 1e3)
            cost["achieved_gbps"] = bps / 1e9
            if peak_bw > 0:
                cost["measured_frac"] = (
                    cost["useful_bytes"] / (med_ms / 1e3)) / peak_bw
        return cost
