"""Distributed subsystem: sharding plans + mesh-sharded ANN search.

``sharding``   — ShardPlan role->axis resolution and the sharded
                 brute/IVF/forest search (corpus over one mesh axis set,
                 queries optionally over another).
``backend``    — pre-placed search callables that plug into
                 ``serve.engine.ServingEngine`` as ``search_fn``.

All collectives route through :mod:`repro.compat` so the code runs on any
JAX version regardless of where ``shard_map`` lives.
"""
from repro.distributed.backend import ShardedSearchBackend
from repro.distributed.sharding import (
    LOCAL_PLAN,
    MULTI_POD_PLAN,
    SINGLE_POD_PLAN,
    ForestShardShapes,
    ShardPlan,
    forest_shard_shapes,
    make_sharded_brute_fn,
    make_sharded_forest_fn,
    make_sharded_ivf_fn,
    shard_forest,
    sharded_brute_search,
    sharded_forest_search,
    sharded_ivf_search,
    slice_forest_delta,
    slice_ivf_delta,
)

__all__ = [
    "ShardPlan", "SINGLE_POD_PLAN", "MULTI_POD_PLAN", "LOCAL_PLAN",
    "sharded_brute_search", "sharded_ivf_search", "sharded_forest_search",
    "make_sharded_brute_fn", "make_sharded_ivf_fn", "make_sharded_forest_fn",
    "shard_forest", "forest_shard_shapes", "ForestShardShapes",
    "slice_forest_delta", "slice_ivf_delta",
    "ShardedSearchBackend",
]
