"""Gradient compression: int8 quantization with error feedback.

Distributed-optimization trick for the slow cross-pod axis (DESIGN.md §4):
gradients are quantized to int8 with a per-tensor absmax scale before the
data-parallel reduction; the quantization residual is carried in an error-
feedback buffer (Seide et al. / EF-SGD) so the bias vanishes over steps.

Two integration points:
  * ``make_ef_transform`` — a gradient transform inside the train step
    (models the end-to-end numerics anywhere, used by default when
    ``compress_grads`` is on; convergence-parity tested).
  * ``compressed_psum`` — an explicit shard_map collective (build the
    wrapper with :func:`repro.compat.shard_map`, which papers over the
    ``jax.shard_map`` vs ``jax.experimental.shard_map`` move) that
    all-gathers int8 payloads and reduces locally: 4x less cross-pod
    traffic than an fp32 all-reduce.  Used by the hand-rolled DP driver
    and exercised on the fake 8-device mesh in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "make_ef_transform",
           "compressed_psum"]


def quantize_int8(x, axis=None):
    """int8 absmax quantization.  ``axis=None``: one scale per tensor (the
    collective payload layout).  ``axis`` (int or tuple): per-slice scales
    with ``keepdims`` so dequantization is a broadcast multiply."""
    xf = x.astype(jnp.float32)
    if axis is None:
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    else:
        scale = jnp.maximum(
            jnp.max(jnp.abs(xf), axis=axis, keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def make_ef_transform():
    """Returns (init(grads)->buf, apply(grads, buf)->(grads', buf')).

    Matrices quantize with one scale per leading-axis row (per output
    channel): a single per-tensor absmax lets one outlier row (embedding /
    unembedding gradients) wash out every small-magnitude row's signal, and
    the extra scales are dim(row) fp32 — noise next to the int8 payload.
    Convergence parity vs fp32 is tested (test_compressed_training_parity).
    """

    def init(grads):
        return jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def apply(grads, buf):
        def one(g, e):
            corrected = g.astype(jnp.float32) + e
            axis = (tuple(range(1, corrected.ndim))
                    if corrected.ndim > 1 else None)
            q, s = quantize_int8(corrected, axis=axis)
            deq = dequantize_int8(q, s)
            return deq.astype(g.dtype), corrected - deq

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(buf)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (tdef.unflatten([o[0] for o in out]),
                tdef.unflatten([o[1] for o in out]))

    return init, apply


def compressed_psum(x, axis_name):
    """int8 all-gather + local reduce — a compressed mean over ``axis``.

    Must run inside shard_map (``repro.compat.shard_map`` for the
    version-portable entry).  Payload: 1 byte/element + one fp32 scale
    per shard, vs 4 bytes/element for fp32 psum.
    """
    q, scale = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis_name)            # (S, ...) int8
    ss = jax.lax.all_gather(scale, axis_name)        # (S,)
    n = qs.shape[0]
    deq = qs.astype(jnp.float32) * ss.reshape((n,) + (1,) * x.ndim)
    return deq.mean(axis=0).astype(x.dtype)
