"""Training loop: jit'd step, grad accumulation, clipping, compression,
checkpoint/restart, watchdog — the piece that has to survive node failures
at scale (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.train import checkpoint as ckpt_mod
from repro.train.compression import make_ef_transform
from repro.train.fault import Watchdog
from repro.train.optim import Optimizer, clip_by_norm

__all__ = ["TrainState", "make_train_step", "train", "TrainResult"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    step: jnp.ndarray
    params: Any
    opt_state: Any
    ef_buf: Any = None          # error-feedback buffer (compression)

    def tree_flatten(self):
        return (self.step, self.params, self.opt_state, self.ef_buf), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


def init_state(params, opt: Optimizer, compress: bool = False) -> TrainState:
    ef = None
    if compress:
        ef_init, _ = make_ef_transform()
        ef = ef_init(params)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt_state=opt.init(params), ef_buf=ef)


def make_train_step(loss_fn: Callable, opt: Optimizer, *,
                    grad_clip: float = 1.0, compress: bool = False,
                    accum: int = 1):
    """loss_fn(params, batch) -> (loss, aux).  Returns jit-able step fn.

    ``accum`` > 1: batch leaves must have leading dim (accum, micro, ...);
    gradients average over microbatches via lax.scan (memory stays at one
    microbatch).
    """
    _, ef_apply = make_ef_transform()

    def grads_of(params, batch):
        if accum == 1:
            (loss, aux), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, aux, g

        def micro(carry, mb):
            acc = carry
            (loss, aux), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            acc = jax.tree.map(lambda a, b: a + b / accum, acc, g)
            return acc, (loss, aux)

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        g, (losses, auxs) = jax.lax.scan(micro, zeros, batch)
        aux = jax.tree.map(lambda x: x.mean(), auxs)
        return losses.mean(), aux, g

    def train_step(state: TrainState, batch):
        loss, aux, grads = grads_of(state.params, batch)
        if compress:
            grads, ef = ef_apply(grads, state.ef_buf)
        else:
            ef = state.ef_buf
        grads, gnorm = clip_by_norm(grads, grad_clip)
        new_params, new_opt = opt.update(
            grads, state.opt_state, state.params, state.step)
        aux = dict(aux)
        aux["grad_norm"] = gnorm
        return TrainState(step=state.step + 1, params=new_params,
                          opt_state=new_opt, ef_buf=ef), aux

    return train_step


@dataclasses.dataclass
class TrainResult:
    state: TrainState
    history: list
    step_times: list
    restarts: int = 0


def train(
    state: TrainState,
    train_step: Callable,
    batch_at: Callable,              # step -> batch (stateless data)
    n_steps: int,
    *,
    log_every: int = 10,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 100,
    ckpt_async: bool = True,
    watchdog: Optional[Watchdog] = None,
    fault_injector: Optional[Callable] = None,   # step -> None | raise
    jit: bool = True,
) -> TrainResult:
    """Run the loop with checkpointing and (optional) fault injection.

    Restart-on-failure is handled by ``fault.run_with_restart`` around this
    function; data order is reproducible because batches derive from step.
    """
    step_fn = jax.jit(train_step, donate_argnums=(0,)) if jit else train_step
    history, times = [], []
    start = int(state.step)
    for step in range(start, n_steps):
        if fault_injector is not None:
            fault_injector(step)
        t0 = time.perf_counter()
        state, aux = step_fn(state, batch_at(step))
        if watchdog is not None or step % log_every == 0 or \
                step == n_steps - 1:
            jax.block_until_ready(state.params)
        dt = time.perf_counter() - t0
        times.append(dt)
        if watchdog is not None:
            watchdog.observe(step, dt)
        if step % log_every == 0 or step == n_steps - 1:
            history.append({"step": step,
                            **{k: float(v) for k, v in aux.items()}})
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            saver = ckpt_mod.save_async if ckpt_async else ckpt_mod.save
            saver(ckpt_dir, step + 1, state)
    if ckpt_dir:
        ckpt_mod.save(ckpt_dir, n_steps, state)
        ckpt_mod.wait_pending()
    return TrainResult(state=state, history=history, step_times=times)
