"""Sparse embedding training: row-wise AdaGrad on touched rows only.

The dense-autodiff path materializes a full (rows x dim) fp32 gradient for
the embedding table plus AdamW m/v — 3x table bytes, 28 GiB/chip for
MLPerf-DLRM (EXPERIMENTS.md §Perf).  Production recsys trainers
(TorchRec/FBGEMM, MLPerf reference) instead differentiate w.r.t. the
*gathered rows* and scatter the update, with a per-row AdaGrad accumulator:

  state : acc (rows,) fp32                       (1/dim of AdamW state)
  step  : g_e = dLoss/d(gathered rows)  (B, F, D)
          acc[ids]   += mean(g_e^2, -1)
          table[ids] -= lr * g_e / sqrt(acc[ids] + eps)

Duplicate ids within a batch combine through the scatter-add semantics.
Dense (MLP/cross) params keep their regular optimizer.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.embedding import take_embeddings
from repro.train.loop import TrainState
from repro.train.optim import Optimizer, clip_by_norm

__all__ = ["make_ctr_sparse_train_step", "rowwise_adagrad_update"]


def rowwise_adagrad_update(table, acc, ids, g_rows, *, lr: float,
                           eps: float = 1e-8):
    """Scatter row-wise AdaGrad. ids (..., ), g_rows (..., D)."""
    flat_ids = ids.reshape(-1)
    flat_g = g_rows.reshape(-1, g_rows.shape[-1]).astype(jnp.float32)
    row_g2 = jnp.mean(flat_g * flat_g, axis=-1)
    acc = acc.at[flat_ids].add(row_g2)
    scale = lr * jax.lax.rsqrt(acc[flat_ids] + eps)
    upd = (scale[:, None] * flat_g).astype(table.dtype)
    table = table.at[flat_ids].add(-upd)
    return table, acc


def make_ctr_sparse_train_step(cfg, plan, opt_dense: Optimizer,
                               lr_embed: float = 0.01,
                               grad_clip: float = 1.0):
    """Train step for DLRM/DCN: dense params via ``opt_dense``, table via
    sparse row-wise AdaGrad.  State: opt_state = {"dense": ...,
    "embed_acc": (rows,) fp32}."""
    from repro.models import recsys as R

    def init_state(params) -> TrainState:
        rest = {k: v for k, v in params.items() if k != "table"}
        return TrainState(
            step=jnp.zeros((), jnp.int32),
            params=params,
            opt_state={
                "dense": opt_dense.init(rest),
                "embed_acc": jnp.zeros((params["table"].shape[0],),
                                       jnp.float32),
            },
            ef_buf=None,
        )

    def train_step(state: TrainState, batch):
        params = state.params
        table = params["table"]
        rest = {k: v for k, v in params.items() if k != "table"}
        ids = batch["sparse"]
        e = take_embeddings(table, ids)

        def loss_of(rest_p, e_g):
            logits = R.ctr_forward_gathered(rest_p, e_g, batch, cfg, plan)
            y = batch["label"].astype(jnp.float32)
            loss = jnp.mean(
                jnp.maximum(logits, 0) - logits * y
                + jnp.log1p(jnp.exp(-jnp.abs(logits)))
            )
            acc_m = jnp.mean((logits > 0) == (y > 0.5))
            return loss, {"loss": loss, "accuracy": acc_m}

        (loss, aux), (g_rest, g_e) = jax.value_and_grad(
            loss_of, argnums=(0, 1), has_aux=True)(rest, e)
        g_rest, gnorm = clip_by_norm(g_rest, grad_clip)
        new_rest, new_dense = opt_dense.update(
            g_rest, state.opt_state["dense"], rest, state.step)
        new_table, new_acc = rowwise_adagrad_update(
            table, state.opt_state["embed_acc"], ids, g_e, lr=lr_embed)
        aux = dict(aux)
        aux["grad_norm"] = gnorm
        return TrainState(
            step=state.step + 1,
            params={**new_rest, "table": new_table},
            opt_state={"dense": new_dense, "embed_acc": new_acc},
            ef_buf=None,
        ), aux

    return init_state, train_step
