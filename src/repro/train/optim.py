"""Optimizers (no optax in this environment): SGD-M, AdamW, Adafactor.

Functional: ``opt.init(params) -> state``; ``opt.update(grads, state,
params, step) -> (new_params, new_state)``.  ``state_specs`` mirrors a
param PartitionSpec tree onto the optimizer state so state shards exactly
like its parameter (ZeRO-style: the fsdp axis shards both).

Adafactor (factored second moment, no first moment by default) is what the
two giant MoEs train with — O(rows+cols) state instead of O(rows*cols)
keeps the 671B/1T configs inside 16 GB/chip (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["Optimizer", "sgd", "adamw", "adafactor", "state_specs",
           "warmup_cosine", "constant_lr", "global_norm", "clip_by_norm"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable           # (grads, state, params, step) -> (p', s')
    state_spec_fn: Callable    # (param_spec, shape) -> state spec pytree


def warmup_cosine(peak: float, warmup: int, total: int,
                  floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return lr


def constant_lr(v: float):
    return lambda step: jnp.asarray(v, jnp.float32)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)
    ))


def clip_by_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), n


def sgd(lr_fn, momentum: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)
        mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: momentum * m + g, mu, grads)
        else:
            upd = mu
        new_p = jax.tree.map(
            lambda p, u: p - (lr * u).astype(p.dtype), params, upd)
        return new_p, {"mu": mu}

    return Optimizer("sgd", init, update,
                     lambda spec, shape: {"mu": spec})


def adamw(lr_fn, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay and p.ndim >= 2:     # no decay on norms/biases
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v}

    return Optimizer("adamw", init, update,
                     lambda spec, shape: {"m": spec, "v": spec})


def adafactor(lr_fn, eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.8, weight_decay: float = 0.0,
              min_dim_factored: int = 128) -> Optimizer:
    """Factored second-moment Adafactor (Shazeer & Stern), momentum-free."""

    def factored(p):
        return p.ndim >= 2 and p.shape[-1] >= min_dim_factored \
            and p.shape[-2] >= min_dim_factored

    def init(params):
        def st(p):
            if factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"stats": jax.tree.map(
            st, params, is_leaf=lambda x: hasattr(x, "shape"))}

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if factored(p):
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                r = vr / jnp.maximum(
                    vr.mean(axis=-1, keepdims=True), eps)
                u = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
                         + eps)
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g / (jnp.sqrt(v) + eps)
                ns = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay and p.ndim >= 2:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), ns

        is_leaf = lambda x: hasattr(x, "shape")
        flat_p, tdef = jax.tree.flatten(params, is_leaf=is_leaf)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = [jax.tree.map(lambda a: a, s) for s in
                  tdef.flatten_up_to(state["stats"])]
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_s = tdef.unflatten([o[1] for o in out])
        return new_p, {"stats": new_s}

    def spec_fn(spec, shape):
        if len(shape) >= 2 and shape[-1] >= min_dim_factored \
                and shape[-2] >= min_dim_factored:
            parts = list(spec) if spec is not None else [None] * len(shape)
            while len(parts) < len(shape):
                parts.append(None)
            return {"vr": P(*parts[:-1]),
                    "vc": P(*(parts[:-2] + parts[-1:]))}
        return {"v": spec}

    return Optimizer("adafactor", init, update, spec_fn)


def state_specs(opt: Optimizer, param_specs, param_shapes):
    """PartitionSpec pytree matching ``opt.init(params)`` structure."""
    def one(spec, shp):
        return opt.state_spec_fn(spec, shp.shape)

    is_leaf = lambda x: isinstance(x, P) or x is None
    mapped = jax.tree.map(one, param_specs, param_shapes, is_leaf=is_leaf)
    if opt.name == "adamw":
        return {
            "m": jax.tree.map(lambda d: d["m"], mapped,
                              is_leaf=lambda x: isinstance(x, dict)
                              and "m" in x),
            "v": jax.tree.map(lambda d: d["v"], mapped,
                              is_leaf=lambda x: isinstance(x, dict)
                              and "v" in x),
        }
    if opt.name == "sgd":
        return {"mu": jax.tree.map(lambda d: d["mu"], mapped,
                                   is_leaf=lambda x: isinstance(x, dict)
                                   and "mu" in x)}
    if opt.name == "adafactor":
        return {"stats": mapped}
    raise ValueError(opt.name)
