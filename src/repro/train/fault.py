"""Fault tolerance: watchdog, straggler detection, restart-on-failure.

On a real multi-host deployment the same hooks attach to the coordinator:
the watchdog flags hosts whose step time exceeds ``factor`` x the rolling
median (straggler mitigation: evict/hedge), and ``run_with_restart``
implements the checkpoint-restart contract — any crash inside the loop
resumes from the last committed checkpoint with identical data order
(stateless `batch_at(step)` samplers).  Tests inject faults mid-run and
assert bit-identical continuation vs an uninterrupted run.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

__all__ = ["FaultInjected", "Watchdog", "run_with_restart",
           "make_fault_injector"]


class FaultInjected(RuntimeError):
    """Simulated node failure."""


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float


class Watchdog:
    """Rolling-median step-time monitor with straggler events."""

    def __init__(self, factor: float = 3.0, window: int = 50,
                 warmup: int = 5):
        self.factor = factor
        self.window = window
        self.warmup = warmup
        self.durations: list[float] = []
        self.events: list[StragglerEvent] = []

    def observe(self, step: int, duration: float):
        self.durations.append(duration)
        hist = self.durations[-self.window:]
        if len(hist) <= self.warmup:
            return
        med = float(np.median(hist[:-1]))
        if duration > self.factor * med:
            self.events.append(StragglerEvent(step, duration, med))

    @property
    def straggler_steps(self):
        return [e.step for e in self.events]


def make_fault_injector(fail_at_steps, *, once: bool = True):
    """Raise FaultInjected when the loop reaches the given steps."""
    remaining = set(fail_at_steps)

    def inject(step: int):
        if step in remaining:
            if once:
                remaining.discard(step)
            raise FaultInjected(f"injected failure at step {step}")

    return inject


def run_with_restart(
    run_fn: Callable[[Optional[int]], "object"],
    *,
    max_restarts: int = 3,
    on_restart: Optional[Callable[[int, BaseException], None]] = None,
):
    """Crash-loop supervisor.

    ``run_fn(resume_step)`` must itself load the latest checkpoint when
    resume_step is not None.  Returns (result, n_restarts).
    """
    restarts = 0
    resume = None
    while True:
        try:
            return run_fn(resume), restarts
        except FaultInjected as e:  # real deployments catch host failures
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart is not None:
                on_restart(restarts, e)
            resume = -1          # sentinel: resume from latest checkpoint
            time.sleep(0.01)     # backoff placeholder
