"""Sharded, async, resharding checkpoints (no orbax in this environment).

Layout::

    <dir>/step_<N>/
        manifest.json   # leaf paths, shapes, dtypes, crc32s, step, meta
        <leaf>.npy      # one file per pytree leaf
        COMMITTED       # written last; restores ignore uncommitted dirs

Writes go to ``step_<N>.tmp`` and rename atomically after fsync — a crash
mid-save never corrupts the latest checkpoint.  ``save_async`` snapshots to
host (jax.device_get) then writes on a worker thread so the train loop
keeps stepping.  ``restore`` device_puts every leaf with the *target* mesh
sharding — the elastic-scaling path: a checkpoint saved on N chips restores
onto M chips (tests exercise 1 -> 8 fake devices).
"""
from __future__ import annotations

import json
import os
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "wait_pending"]

_pending: list[threading.Thread] = []


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((name.replace("/", "__"), leaf))
    return out


def save(dirpath: str, step: int, tree, meta: Optional[dict] = None,
         keep_last: int = 3):
    """Synchronous atomic checkpoint of an arbitrary pytree."""
    host_tree = jax.device_get(tree)
    _write(dirpath, step, host_tree, meta or {}, keep_last)


def save_async(dirpath: str, step: int, tree, meta: Optional[dict] = None,
               keep_last: int = 3):
    """Snapshot now, write on a background thread."""
    host_tree = jax.device_get(tree)
    t = threading.Thread(
        target=_write, args=(dirpath, step, host_tree, meta or {},
                             keep_last), daemon=True,
    )
    t.start()
    _pending.append(t)
    return t


def wait_pending():
    for t in list(_pending):
        t.join()
        _pending.remove(t)


def _write(dirpath, step, host_tree, meta, keep_last):
    final = os.path.join(dirpath, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "meta": meta, "leaves": {}}
    for name, leaf in _leaf_paths(host_tree):
        arr = np.asarray(leaf)
        fp = os.path.join(tmp, name + ".npy")
        np.save(fp, arr)
        with open(fp, "rb") as f:
            crc = zlib.crc32(f.read())
        manifest["leaves"][name] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype), "crc": crc,
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, "COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        import shutil

        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(dirpath, keep_last)


def _gc(dirpath, keep_last):
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(dirpath)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(dirpath, d, "COMMITTED"))
    )
    import shutil

    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(dirpath, f"step_{s:08d}"),
                      ignore_errors=True)


def latest_step(dirpath: str) -> Optional[int]:
    if not os.path.isdir(dirpath):
        return None
    steps = [
        int(d.split("_")[1]) for d in os.listdir(dirpath)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(dirpath, d, "COMMITTED"))
    ]
    return max(steps) if steps else None


def restore(dirpath: str, step: int, template,
            shardings=None, verify: bool = True) -> Any:
    """Load a checkpoint into ``template``'s structure.

    ``shardings``: optional pytree of NamedSharding matching template — each
    leaf is device_put with its target sharding (elastic resharding).
    """
    d = os.path.join(dirpath, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = {}
    for name, info in manifest["leaves"].items():
        fp = os.path.join(d, name + ".npy")
        if verify:
            with open(fp, "rb") as f:
                if zlib.crc32(f.read()) != info["crc"]:
                    raise IOError(f"checkpoint leaf {name} failed CRC")
        leaves[name] = np.load(fp)
    flat, tdef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (
        jax.tree.leaves(shardings) if shardings is not None
        else [None] * len(flat)
    )
    out = []
    for (path, leaf), shard in zip(flat, shard_flat):
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        ).replace("/", "__")
        arr = leaves[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs template "
                f"{leaf.shape}"
            )
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(tdef, out)
