"""Drift-triggered background index maintenance.

``MaintenanceScheduler`` closes the serving -> index loop: a daemon
thread polls the estimator's drift against the likelihood the deployed
index was boosted with and, past a threshold, runs the incremental
maintenance chain *off* the serving path:

    p_new = estimator.likelihood()
    index.reboost(p_new)          # top-level re-split, subtrees reused
    index.rebalance()             # PR-3 drifted-bucket Lloyd step
    engine.apply_updates(target)  # republish under the backend's lock:
                                  # pops the index's delta manifest so
                                  # only dirty buckets ship (also
                                  # invalidates the result cache)
    estimator.set_reference(p_new)

The serving loop is never blocked: ``reboost`` builds off to the side
and swaps a reference; ``apply_updates`` re-places device arrays under
the existing ``ShardedSearchBackend`` lock (in-flight batches finish on
the old arrays).  For engines serving a host-resident index,
``HostIndexBackend`` provides the same ``apply_updates`` surface as the
sharded backend so cache invalidation and republish work identically.

**Fleet-leader mode.** ``engine`` may be a
:class:`repro.serve.fleet.CellRouter` instead of a single engine: the
scheduler then IS the fleet's maintenance leader.  The estimator is
shared by every cell (it is internally locked), so there is exactly one
drift decision for the whole fleet; the router's ``apply_updates`` pops
the index's delta manifest exactly once and fans the same manifest out
to every cell with a rolling drain (one cell republishes while its
siblings absorb the traffic).  Nothing in the scheduler changes —
running one scheduler per cell would instead race N ``pop_delta()``
calls against each other and republish N different manifests.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Optional

import numpy as np

from repro.analysis.annotations import guarded_by
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import get_tracer

__all__ = ["HostIndexBackend", "MaintenanceScheduler"]


class HostIndexBackend:
    """``queries (B, d) -> (dists, ids)`` over an in-process index.

    The engine-facing twin of ``ShardedSearchBackend`` for single-host
    serving: a callable with ``apply_updates`` so the engine's cache
    invalidation and the scheduler's republish path work unchanged.
    ``index`` is anything with ``.search(queries, k, **kw)`` returning
    ``(dists, ids, work)`` — ``SearchIndex`` or ``TwoLevelIndex``.
    """

    def __init__(self, index, *, k: int = 10, **search_kw):
        self.index = index
        self.k = k
        self.search_kw = search_kw
        self.last_delta = None

    def __call__(self, queries):
        idx = self.index           # snapshot: apply_updates swaps the ref
        d, i, _ = idx.search(np.asarray(queries), self.k, **self.search_kw)
        return np.asarray(d), np.asarray(i)

    def apply_updates(self, index, delta=None, **kw) -> dict:
        """Swap the served index reference.

        A host-resident index republishes by reference, so a delta
        manifest costs nothing to "apply" — it is accepted (and recorded
        as ``last_delta``) so the engine/scheduler delta path works
        identically against host and sharded backends, and returns the
        same stats shape (zero bytes: nothing crossed a device boundary).
        """
        self.index = index
        self.last_delta = delta
        return {"mode": "swap", "bytes": 0, "full_bytes": 0,
                "reason": None}


class MaintenanceScheduler:
    """Background drift watcher driving reboost/rebalance/republish.

    Parameters
    ----------
    estimator : OnlineLikelihoodEstimator (drift + likelihood source)
    index     : object with ``reboost(p)`` — ``SearchIndex`` or
                ``TwoLevelIndex``; ``rebalance()`` is chained when present
    engine    : optional ``ServingEngine`` — republished to via
                ``apply_updates`` (which also invalidates its cache).
                Passing a ``repro.serve.fleet.CellRouter`` here makes
                this scheduler the fleet's maintenance *leader*: one
                drift decision (shared estimator), one ``pop_delta()``,
                the same manifest rolled across every cell
    cache     : optional cache to invalidate when no engine is given
    publish_target : maps the index to the ``apply_updates`` target
                (identity by default: a ``TwoLevelIndex`` is what
                ``ShardedSearchBackend`` re-places)
    interval_s : poll period; ``None`` disables the thread (tests drive
                :meth:`check_now` directly)
    drift_threshold : trigger level on ``metric`` ("tv" or "kl")
    min_observations : decayed observation mass required before a trigger
                (drift of an empty estimator is noise)
    rebalance : chain ``index.rebalance()`` after reboost; "auto" enables
                it only for two-level indexes (a single-tree rebalance is
                a full rebuild — exactly what reboost avoids)
    """

    def __init__(
        self,
        estimator,
        index,
        *,
        engine=None,
        cache=None,
        publish_target: Optional[Callable[[Any], Any]] = None,
        interval_s: Optional[float] = 1.0,
        drift_threshold: float = 0.3,
        metric: str = "tv",
        min_observations: float = 256.0,
        cooldown_observations: Optional[float] = None,
        rebalance: "bool | str" = "auto",
        reboost_kw: Optional[dict] = None,
        on_event: Optional[Callable[[dict], None]] = None,
    ):
        if metric not in ("tv", "kl"):
            raise ValueError(f"metric must be 'tv' or 'kl', got {metric!r}")
        self.estimator = estimator
        self.index = index
        self.engine = engine
        self.cache = cache
        self.publish_target = publish_target or (lambda idx: idx)
        self.interval = interval_s
        self.drift_threshold = drift_threshold
        self.metric = metric
        self.min_observations = min_observations
        # debounce: require this much fresh traffic between triggers so a
        # noisy drift estimate can't thrash reboosts back-to-back
        self.cooldown_observations = (
            min_observations if cooldown_observations is None
            else cooldown_observations)
        self._last_trigger_n = -float("inf")
        if rebalance == "auto":
            rebalance = (getattr(index, "two_level", None) is not None
                         or hasattr(index, "bucket_ids"))
        self.rebalance = bool(rebalance)
        self.reboost_kw = reboost_kw or {}
        self.on_event = on_event
        self.events: list[dict] = []
        self.last_error: Optional[BaseException] = None
        # scheduler telemetry: the live drift reading and estimator mass
        # become gauges (polled by dashboards between triggers), trigger
        # outcomes become a counter + duration histogram
        self.metrics = MetricsRegistry()
        self._g_drift = self.metrics.gauge("drift")
        self._g_mass = self.metrics.gauge("estimator_mass")
        self._c_reboosts = self.metrics.counter("reboosts")
        self._h_maint = self.metrics.histogram("maintenance_ms",
                                               lo=1e-2, hi=1e7)
        # serializes triggers: the daemon loop and direct check_now()
        # callers race on the cooldown watermark and the event log
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        if interval_s is not None:
            self._thread = threading.Thread(target=self._loop, daemon=True)
            self._thread.start()

    # ------------------------------------------------------------------
    @property
    def n_reboosts(self) -> int:
        return self._c_reboosts.value

    def check_now(self) -> Optional[dict]:
        """One synchronous drift check; returns the event dict if it
        triggered maintenance, else None.  Serialized under the
        scheduler lock — a manual call racing the daemon loop must not
        double-trigger inside one cooldown window."""
        with self._lock:
            d = self.estimator.drift()
            self._g_drift.set(float(d[self.metric]))
            self._g_mass.set(float(d["n_observed"]))
            if d["n_observed"] < self.min_observations:
                return None
            n_total = getattr(self.estimator, "n_total", 0)
            if n_total - self._last_trigger_n < self.cooldown_observations:
                return None
            if d[self.metric] <= self.drift_threshold:
                return None
            self._last_trigger_n = n_total
            return self._trigger(d)

    @guarded_by("_lock")
    def _trigger(self, drift: dict) -> dict:
        tracer = get_tracer()
        with tracer.span("maint.trigger",
                         drift=round(float(drift[self.metric]), 4)):
            t0 = time.perf_counter()
            # the corpus may have grown since the estimator was sized
            # (add_entities keeps ids stable and appends) — grow with it
            # so the likelihood vector matches the index
            n_idx = getattr(self.index, "n", None)
            if n_idx is None and hasattr(self.index, "db"):
                n_idx = int(self.index.db.shape[0])
            if (n_idx and hasattr(self.estimator, "resize")
                    and n_idx > getattr(self.estimator, "n", n_idx)):
                self.estimator.resize(n_idx)
            p_new = self.estimator.likelihood()
            with tracer.span("maint.reboost"):
                reboost_stats = self.index.reboost(p_new, **self.reboost_kw)
            rebalance_stats = None
            if self.rebalance and hasattr(self.index, "rebalance"):
                with tracer.span("maint.rebalance"):
                    rebalance_stats = self.index.rebalance()
            republish = None
            if self.engine is not None:
                # the engine pops the target's delta manifest
                # (delta="auto") and the backend ships only the dirty
                # slices — a reboost that re-split every bucket
                # degenerates to a full re-place via the backend's size
                # threshold, a localized rebalance ships a handful of
                # bucket slabs.  Fleet routers / cells emit their own
                # maint.fanout / republish spans underneath this one.
                republish = self.engine.apply_updates(
                    self.publish_target(self.index))
            elif self.cache is not None:
                self.cache.invalidate_all()
            # re-anchor on the RAW estimate (what drift() compares
            # against); the smoothed p_new fed to reboost would read as
            # residual drift at low observation mass
            if hasattr(self.estimator, "current_raw"):
                self.estimator.set_reference(self.estimator.current_raw())
            else:
                self.estimator.set_reference(p_new)
            duration_s = time.perf_counter() - t0
            event = {
                "drift": drift,
                "reboost": reboost_stats,
                "rebalance": rebalance_stats,
                "republish": republish,
                "duration_s": duration_s,
                "t": time.time(),
            }
            self.events.append(event)
            self._c_reboosts.inc()
            self._h_maint.observe(duration_s * 1e3)
        if self.on_event is not None:
            self.on_event(event)
        return event

    def _loop(self) -> None:
        while not self._stop.wait(self.interval):
            try:
                self.check_now()
            except Exception as e:       # keep the daemon alive; surface
                with self._lock:         # the error through stats/tests
                    self.last_error = e

    def close(self, timeout: float = 5.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
