"""Likelihood-aware exact-match result cache (TinyLFU-style admission).

Skewed traffic means the same head queries recur; an exact-match cache in
front of the engine turns those into O(1) hits.  A plain LRU is easily
flushed by the long tail, so admission is *frequency-gated* (TinyLFU): a
small host-side count-min sketch estimates each key's recent popularity,
and a new result only displaces the LRU victim when it has been seen at
least as often — one-off queries never evict head entries.

Staleness contract: results are only valid for one index *generation*.
``invalidate_all()`` (wired into ``ServingEngine.apply_updates``) clears
the cache and bumps the generation; an ``offer`` carrying a stale
generation token is dropped, closing the race where a search computed
against the old index finishes after the swap and would otherwise
re-insert a stale result.
"""
from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from typing import Optional

import numpy as np

__all__ = ["FrequencyAdmissionCache"]


class _HostSketch:
    """Tiny host-side CMS with periodic halving (TinyLFU aging)."""

    def __init__(self, width: int, depth: int, reset_every: int, seed: int):
        rng = np.random.default_rng(seed)
        self.width = width
        self.table = np.zeros((depth, width), np.float32)
        self._salt = rng.integers(1, 2**63 - 1, size=depth).astype(np.uint64)
        self._ops = 0
        self._reset_every = reset_every

    def _cols(self, h: int) -> np.ndarray:
        h64 = np.uint64(h)                 # uint64 wraparound arithmetic
        mix = self._salt * h64 + (self._salt >> np.uint64(7))
        return (mix % np.uint64(self.width)).astype(np.int64)

    def bump(self, h: int) -> None:
        self.table[np.arange(self.table.shape[0]), self._cols(h)] += 1.0
        self._ops += 1
        if self._ops >= self._reset_every:
            self.table *= 0.5
            self._ops = 0

    def estimate(self, h: int) -> float:
        return float(
            self.table[np.arange(self.table.shape[0]), self._cols(h)].min())


class FrequencyAdmissionCache:
    """Exact-match query -> result cache with frequency-gated admission."""

    def __init__(self, capacity: int = 1024, *, sketch_width: int = 8192,
                 sketch_depth: int = 4, seed: int = 0):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self._lru: "OrderedDict[bytes, tuple]" = OrderedDict()
        self._lock = threading.Lock()
        self._sketch = _HostSketch(sketch_width, sketch_depth,
                                   reset_every=8 * capacity, seed=seed)
        self.generation = 0
        self.hits = 0
        self.misses = 0
        self.admitted = 0
        self.rejected = 0

    # ------------------------------------------------------------------
    @staticmethod
    def key_for(query: np.ndarray, extra: bytes = b"") -> bytes:
        """Stable key over the query's bytes, dtype and shape.

        ``extra`` folds request options that change the answer — filter
        digest, search mode, hybrid alpha — into the key, so a filtered
        result can never satisfy an unfiltered request (or vice versa)
        for the same query vector."""
        q = np.ascontiguousarray(query)
        h = hashlib.blake2b(digest_size=16)
        h.update(str(q.dtype).encode())
        h.update(str(q.shape).encode())
        h.update(q.tobytes())
        if extra:
            h.update(extra)
        return h.digest()

    @staticmethod
    def _int_of(key: bytes) -> int:
        return int.from_bytes(key[:8], "little", signed=False)

    def get(self, key: bytes):
        """Cached result or None; every lookup also trains the sketch."""
        h = self._int_of(key)
        with self._lock:
            self._sketch.bump(h)
            hit = self._lru.get(key)
            if hit is not None:
                self._lru.move_to_end(key)
                self.hits += 1
                return hit[1]
            self.misses += 1
            return None

    def offer(self, key: bytes, value, generation: Optional[int] = None
              ) -> bool:
        """Insert under frequency admission; stale generations dropped."""
        h = self._int_of(key)
        with self._lock:
            if generation is not None and generation != self.generation:
                return False                     # computed pre-invalidation
            if key in self._lru:
                self._lru[key] = (h, value)
                self._lru.move_to_end(key)
                return True
            if len(self._lru) >= self.capacity:
                victim_key, (victim_h, _) = next(iter(self._lru.items()))
                if self._sketch.estimate(h) < \
                        self._sketch.estimate(victim_h):
                    self.rejected += 1
                    return False
                self._lru.pop(victim_key)
            self._lru[key] = (h, value)
            self.admitted += 1
            return True

    def invalidate_all(self) -> None:
        """Drop every entry and bump the generation (index mutated)."""
        with self._lock:
            self._lru.clear()
            self.generation += 1

    def __len__(self) -> int:
        return len(self._lru)

    def stats(self) -> dict:
        with self._lock:
            return {
                "hits": self.hits, "misses": self.misses,
                "admitted": self.admitted, "rejected": self.rejected,
                "size": len(self._lru), "generation": self.generation,
            }
