"""Fixed-shape decayed count-min sketch with heavy-hitter tracking (JAX).

The estimator needs per-entity traffic counts without a dense O(N)
counter update on the hot path.  A count-min sketch gives conservative
(over-)estimates in O(depth) per id with a fixed (depth, width) table —
a shape that jits once and batches with search.  Two serving-specific
extensions:

  * **exponential decay** — counts are multiplied by ``0.5**(m/halflife)``
    per ``m``-observation batch, so the sketch tracks the *recent*
    likelihood (what drift detection needs) instead of the all-time one;
  * **heavy hitters** — a top-k id/estimate pair array maintained inside
    the same jitted update (candidates = current top-k union the batch),
    giving the scheduler a cheap read of the current head without a full
    table scan.

Hashing is multiply-shift over uint32 (width must be a power of two), so
an update is one gather-free scatter-add per row — no host dicts, no
recompiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CountMinSketch"]


def _hash(ids: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
          width: int) -> jnp.ndarray:
    """Multiply-shift universal hash -> (depth, m) column indices."""
    shift = 32 - int(np.log2(width))
    x = ids.astype(jnp.uint32)
    h = a[:, None] * x[None, :] + b[:, None]          # uint32 wraparound
    return (h >> shift).astype(jnp.int32)


@jax.jit
def _update(table, a, b, hh_ids, ids, w, decay):
    depth, width = table.shape
    valid = ids >= 0
    h = _hash(jnp.where(valid, ids, 0), a, b, width)
    w = jnp.where(valid, w, 0.0)
    rows = jnp.broadcast_to(
        jnp.arange(depth, dtype=jnp.int32)[:, None], h.shape)
    table = table * decay
    # repro: allow(scatter-not-donated): tiny (depth, width) table, and donation is a no-op on the CPU backend this runs on
    table = table.at[rows, h].add(jnp.broadcast_to(w[None, :], h.shape))

    # heavy hitters: re-rank current top-k union the batch ids by their
    # fresh estimates; duplicates are masked so one id holds one slot
    cand = jnp.concatenate([hh_ids, ids.astype(jnp.int32)])
    est = _query(table, a, b, cand)
    est = jnp.where(cand >= 0, est, -jnp.inf)
    order = jnp.argsort(cand)
    sc = cand[order]
    dup_sorted = jnp.concatenate(
        [jnp.zeros(1, bool), (sc[1:] == sc[:-1]) & (sc[1:] >= 0)])
    dup = jnp.zeros(cand.shape, bool).at[order].set(dup_sorted)
    est = jnp.where(dup, -jnp.inf, est)
    top_est, top_i = jax.lax.top_k(est, hh_ids.shape[0])
    new_ids = jnp.where(jnp.isneginf(top_est), -1, cand[top_i])
    new_est = jnp.where(jnp.isneginf(top_est), 0.0, top_est)
    return table, new_ids, new_est


@jax.jit
def _query(table, a, b, ids):
    depth, width = table.shape
    h = _hash(jnp.where(ids >= 0, ids, 0), a, b, width)
    rows = jnp.broadcast_to(
        jnp.arange(depth, dtype=jnp.int32)[:, None], h.shape)
    est = table[rows, h].min(axis=0)
    return jnp.where(ids >= 0, est, 0.0)


class CountMinSketch:
    """Decayed CMS + top-k heavy hitters over int entity ids.

    ``halflife`` is measured in observations: after ``halflife`` more
    observations, an old count has decayed to half its weight.  ``None``
    disables decay (all-time counts).  Updates pad the batch to the next
    power of two so the jitted kernel sees a handful of shapes.
    """

    def __init__(self, *, width: int = 4096, depth: int = 4,
                 topk: int = 64, halflife: float | None = None,
                 seed: int = 0):
        if width & (width - 1):
            raise ValueError(f"width must be a power of two, got {width}")
        rng = np.random.default_rng(seed)
        self.width = width
        self.depth = depth
        self.halflife = halflife
        # odd multipliers make the multiply-shift family universal enough
        self._a = jnp.asarray(
            rng.integers(1, 2**32, size=depth, dtype=np.uint32) | 1)
        self._b = jnp.asarray(
            rng.integers(0, 2**32, size=depth, dtype=np.uint32))
        self.table = jnp.zeros((depth, width), jnp.float32)
        self.hh_ids = jnp.full((topk,), -1, jnp.int32)
        self.hh_est = jnp.zeros((topk,), jnp.float32)
        self.n_observed = 0.0      # decayed total weight in the table

    def update(self, ids: np.ndarray, weights: np.ndarray | None = None):
        """Fold a batch of observed entity ids into the sketch."""
        ids = np.asarray(ids, dtype=np.int64).ravel()
        if ids.size == 0:
            return
        w = (np.ones(ids.size, np.float32) if weights is None
             else np.asarray(weights, np.float32).ravel())
        # decay follows the REAL observation count — computed before the
        # pow2 padding, which exists only to bound jit shapes and must
        # not make the effective halflife batching-dependent
        decay = (1.0 if self.halflife is None
                 else float(0.5 ** (ids.size / self.halflife)))
        m = 1
        while m < ids.size:
            m <<= 1
        pad = m - ids.size
        if pad:
            ids = np.pad(ids, (0, pad), constant_values=-1)
            w = np.pad(w, (0, pad))
        self.table, self.hh_ids, self.hh_est = _update(
            self.table, self._a, self._b, self.hh_ids,
            jnp.asarray(ids), jnp.asarray(w), jnp.float32(decay))
        self.n_observed = self.n_observed * decay + float(w.sum())

    def query(self, ids: np.ndarray) -> np.ndarray:
        """Conservative count estimates for ``ids`` (0 for id < 0)."""
        ids = np.asarray(ids, dtype=np.int64).ravel()
        if ids.size == 0:
            return np.zeros(0, np.float32)
        return np.asarray(_query(self.table, self._a, self._b,
                                 jnp.asarray(ids)))

    def heavy_hitters(self) -> tuple[np.ndarray, np.ndarray]:
        """(ids, estimates) of the current top-k, highest first."""
        ids = np.asarray(self.hh_ids)
        est = np.asarray(self.hh_est)
        keep = ids >= 0
        return ids[keep], est[keep]

    def reset(self) -> None:
        self.table = jnp.zeros_like(self.table)
        self.hh_ids = jnp.full_like(self.hh_ids, -1)
        self.hh_est = jnp.zeros_like(self.hh_est)
        self.n_observed = 0.0
