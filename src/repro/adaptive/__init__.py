"""Online workload adaptation: serving-time telemetry back into the index.

The paper boosts the tree once at build time from a static likelihood
vector; real traffic is skewed *and shifting*.  This package closes the
loop:

  * :mod:`repro.adaptive.sketch` — fixed-shape decayed count-min sketch
    with heavy-hitter tracking, on JAX arrays;
  * :mod:`repro.adaptive.estimator` — ``OnlineLikelihoodEstimator`` turns
    returned entity ids into a smoothed likelihood and drift metrics;
  * :mod:`repro.adaptive.maintenance` — ``MaintenanceScheduler`` triggers
    incremental ``reboost``/``rebalance`` past a drift threshold and
    republishes through ``ServingEngine.apply_updates``;
  * :mod:`repro.adaptive.cache` — ``FrequencyAdmissionCache``, a
    TinyLFU-style exact-match result cache fronting the engine.
"""
from repro.adaptive.cache import FrequencyAdmissionCache
from repro.adaptive.estimator import OnlineLikelihoodEstimator
from repro.adaptive.maintenance import HostIndexBackend, MaintenanceScheduler
from repro.adaptive.sketch import CountMinSketch

__all__ = [
    "CountMinSketch",
    "FrequencyAdmissionCache",
    "HostIndexBackend",
    "MaintenanceScheduler",
    "OnlineLikelihoodEstimator",
]
